"""Robustness bench: the attack x aggregator matrix, as ONE mixed program.

Part 1 — Krum kernel parity: the (m, m) pairwise squared-distance panel
(``kernels/ops.krum_distances``) against the pure-jnp expansion at bench
tiers, recording panel max |diff| (f32 reassociation roundoff) and — the
load-bearing contract — whether the SELECTED index sets of the full
``krum_select`` recipe are bit-identical ref vs pallas
(``krum_parity_ok``; ``perf_assert`` gates it).

Part 2 — the robustness matrix: every (attack x aggregator) pair runs as a
cell of ONE mixed ``run_batch`` program — fault families and aggregator
families both dispatch through per-cell ``lax.switch`` indices, so the
benign baseline, the sign-flip / model-replacement / straggler cells, and
the fedavg / median / trimmed-mean / krum servers all batch together
(the scenario-diversity headline of ROADMAP item 7).  Paired cells share
seed + availability stream, so a row isolates the (attack, defense) effect.
The record carries ``robust_beats_fedavg_signflip``: under 20% sign-flip,
krum AND trimmed-mean must end at higher val-acc than fedavg on the same
seeds (``perf_assert`` gates this too).

Dumped to ``benchmarks/results/BENCH_robustness.json`` (CI quick pass).

  PYTHONPATH=src python -m benchmarks.robustness_bench [--quick|--full]
"""
from __future__ import annotations

import json
import pathlib
import time

import numpy as np

import jax
import jax.numpy as jnp

RESULTS = pathlib.Path(__file__).resolve().parent / "results"
BENCH_PATH = RESULTS / "BENCH_robustness.json"

# (family, byz frac, family knobs): sign-flip amplified 5x so the attack
# actually breaks the weighted mean — at scale 1 fedavg's size weighting
# dilutes a 20% minority and the matrix shows nothing
ATTACKS = [("none", 0.0, {}), ("sign_flip", 0.2, {"scale": 5.0}),
           ("scaled", 0.2, {}), ("straggler_stale", 0.3, {})]
DEFENSES = ["fedavg", "median", "trimmed_mean", "krum"]


def _time(fn, reps=2):
    fn()
    t0 = time.time()
    for _ in range(reps):
        fn()
    return (time.time() - t0) / reps


# --------------------------------------------- part 1: krum kernel parity
def _kernel_rows(quick: bool) -> list[dict]:
    from repro.fed.aggregator_device import krum_pairwise_ref, krum_select
    from repro.kernels.ops import krum_distances

    ref = jax.jit(krum_pairwise_ref)
    pal = jax.jit(lambda x: krum_distances(x))
    sizes = [(64, 512), (128, 2048), (256, 4096)]
    if not quick:
        sizes += [(512, 16384)]
    rng = np.random.default_rng(0)
    rows = []
    for m, p in sizes:
        x = jnp.asarray(rng.normal(size=(m, p)).astype(np.float32))
        valid = jnp.asarray(rng.random(m) < 0.95)
        d_ref = np.asarray(ref(x))
        d_pal = np.asarray(pal(x))
        maxdiff = float(np.max(np.abs(d_ref - d_pal)))
        f = max(1, m // 5)
        sel_ref, _ = krum_select(x, valid, f, 3, backend="ref")
        sel_pal, _ = krum_select(x, valid, f, 3, backend="pallas")
        sel_ok = bool(np.array_equal(np.asarray(sel_ref),
                                     np.asarray(sel_pal)))
        # the contract CI must fail on, not bury: selection bit-parity
        assert sel_ok, f"krum selections diverge at m={m}, P={p}"
        t_ref = _time(lambda: np.asarray(ref(x)))
        t_pal = _time(lambda: np.asarray(pal(x)))
        rows.append({"table": "robustness_kernel", "m": m, "p": p,
                     "ref_s": round(t_ref, 4), "pallas_s": round(t_pal, 4),
                     "speedup": round(t_ref / max(t_pal, 1e-9), 2),
                     "panel_max_abs_diff": maxdiff,
                     "selection_bit_equal": sel_ok})
        print(f"[robustness_bench] m={m:4d} P={p:6d}: ref {t_ref:7.4f}s  "
              f"pallas {t_pal:7.4f}s ({rows[-1]['speedup']:5.2f}x, "
              f"panel maxdiff {maxdiff:.1e}, sel bit-equal {sel_ok})",
              flush=True)
    return rows


# --------------------------------------------- part 2: the attack matrix
def _matrix_rows(quick: bool) -> list[dict]:
    from repro.core.availability import make_mode
    from repro.data.synthetic import make_synthetic
    from repro.fed.aggregator_device import make_aggregator_process
    from repro.fed.faults_device import make_fault_process
    from repro.fed.models import logistic_regression
    from repro.fed.scan_engine import ScanConfig, ScanEngine

    n = 30 if quick else 100
    rounds = 40 if quick else 100
    # m ODD: the lower median of an even v sits a full order statistic
    # below center (measured -0.2 sigma per coordinate at v=6) and the
    # bias compounds across rounds; odd v makes it the true middle row
    m = max(5, n // 3 - (n // 3 + 1) % 2)
    ds = make_synthetic(n_clients=n, alpha=0.5, beta=0.5, seed=0)
    cfg = ScanConfig(rounds=rounds, m=m, local_steps=5, batch_size=10,
                     lr=0.1, eval_every=1, sampler="uniform")
    eng = ScanEngine(ds, logistic_regression(), cfg)
    mode = make_mode("IDL", n_clients=n, data_sizes=ds.sizes,
                     label_sets=ds.label_sets(), num_labels=ds.num_classes,
                     seed=99)
    # krum must tolerate the worst-case sampled-adversary count:
    # E[byz in S_t] = frac * m, but a uniform draw can exceed it — size f
    # above the mean while keeping nn = m - f - 2 rows in the score
    f_krum = max(1, min(int(np.ceil(0.2 * m)) + 1, (m - 3) // 2))
    defenses = {
        "fedavg": lambda: None,
        "median": lambda: make_aggregator_process("median"),
        "trimmed_mean": lambda: make_aggregator_process("trimmed_mean",
                                                        beta_trim=0.25),
        "krum": lambda: make_aggregator_process("multikrum", krum_f=f_krum,
                                                krum_multi=max(2, m // 2)),
    }
    grid = [(aname, frac, kw, dname) for (aname, frac, kw) in ATTACKS
            for dname in DEFENSES]
    # every (attack, defense) pair shares seed + avail stream: the sampler
    # draw and the honest local updates are identical across a row's cells,
    # so the matrix isolates (attack, defense)
    cells = [eng.cell(seed=0, mode=mode, avail_seed=17,
                      fault_process=make_fault_process(aname, n, frac=frac,
                                                       **kw),
                      aggregator_process=defenses[dname]())
             for (aname, frac, kw, dname) in grid]
    t0 = time.time()
    hists = eng.run_batch(cells)       # ONE mixed attack x defense program
    wall = time.time() - t0
    rows = []
    for (aname, frac, kw, dname), hh in zip(grid, hists):
        rows.append({"table": "robustness_matrix", "attack": aname,
                     "byz_frac": frac, "aggregator": dname,
                     "n_clients": n, "rounds": rounds, "m": m,
                     "final_acc": round(float(hh.val_acc[-1]), 4),
                     "best_loss": round(hh.best_loss, 4),
                     "final_loss": round(float(hh.val_loss[-1]), 4),
                     "batch_wall_s": round(wall, 2)})
        print(f"[robustness_bench] {aname:15s}({frac:.1f}) x {dname:12s}: "
              f"final acc {rows[-1]['final_acc']:.4f}  "
              f"best loss {rows[-1]['best_loss']:.4f}", flush=True)
    return rows


def _flags(rows: list[dict]) -> dict:
    acc = {(r["attack"], r["aggregator"]): r["final_acc"]
           for r in rows if r["table"] == "robustness_matrix"}
    sf = {d: acc.get(("sign_flip", d)) for d in DEFENSES}
    robust_ok = (sf["fedavg"] is not None
                 and sf["krum"] > sf["fedavg"]
                 and sf["trimmed_mean"] > sf["fedavg"])
    krum_ok = all(r["selection_bit_equal"] for r in rows
                  if r["table"] == "robustness_kernel")
    return {"krum_parity_ok": krum_ok,
            "robust_beats_fedavg_signflip": robust_ok,
            "signflip_final_acc": sf}


def run(quick: bool = True) -> list[dict]:
    rows = _kernel_rows(quick) + _matrix_rows(quick)
    RESULTS.mkdir(parents=True, exist_ok=True)
    from benchmarks.common import pallas_backend_mode
    record = {"bench": "robustness", "backend": jax.default_backend(),
              "backend_mode": pallas_backend_mode(),
              "pallas_interpret": jax.default_backend() == "cpu",
              **_flags(rows), "rows": rows}
    BENCH_PATH.write_text(json.dumps(record, indent=1))
    return rows


def summarize(rows) -> list[str]:
    out = ["", "== krum pairwise-distance panel: ref vs pallas =="]
    out.append(f"{'m':>5s} {'P':>7s} {'ref (s)':>9s} {'pallas (s)':>11s} "
               f"{'speedup':>8s} {'panel maxdiff':>14s} {'sel ==':>7s}")
    for r in rows:
        if r["table"] != "robustness_kernel":
            continue
        out.append(f"{r['m']:5d} {r['p']:7d} {r['ref_s']:9.4f} "
                   f"{r['pallas_s']:11.4f} {r['speedup']:7.2f}x "
                   f"{r['panel_max_abs_diff']:14.1e} "
                   f"{str(r['selection_bit_equal']):>7s}")
    out.append("")
    out.append("== attack x aggregator matrix (one mixed run_batch) ==")
    out.append(f"{'attack':>16s} {'frac':>5s} {'aggregator':>13s} "
               f"{'final acc':>10s} {'best loss':>10s}")
    for r in rows:
        if r["table"] != "robustness_matrix":
            continue
        out.append(f"{r['attack']:>16s} {r['byz_frac']:5.1f} "
                   f"{r['aggregator']:>13s} {r['final_acc']:10.4f} "
                   f"{r['best_loss']:10.4f}")
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="the CI pass (default unless --full)")
    ap.add_argument("--full", action="store_true",
                    help="N=100 clients, 100 rounds, the m=512 P=16384 "
                         "kernel tier")
    args = ap.parse_args()
    for line in summarize(run(quick=not args.full)):
        print(line)

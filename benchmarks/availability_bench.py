"""Availability-scenario bench: batched scan-engine throughput per scenario
family (core/availability_device.py).

For every family — the legacy periodic table plus the four stateful
processes (Gilbert–Elliott churn, cluster outages, non-stationary drift,
deadline stragglers) — a (family x seeds) batch runs through
``ScanEngine.run_batch`` and we record batched rounds/sec.  Because every
family compiles to the SAME ``lax.switch`` program, all per-family rows
after the first reuse one compiled executable, and the final MIXED row runs
one cell of EVERY family in a single program — the mixed-scenario batching
the subsystem exists for.  The run is dumped to
``benchmarks/results/BENCH_availability.json`` so the scenario-axis perf
trajectory accumulates across PRs (CI runs the quick pass).

  PYTHONPATH=src python -m benchmarks.availability_bench [--full]
"""
from __future__ import annotations

import json
import pathlib
import time

import numpy as np

import jax

from repro.core.availability import make_mode
from repro.data.synthetic import make_synthetic
from repro.fed.models import logistic_regression
from repro.fed.scan_engine import ScanConfig, ScanEngine

RESULTS = pathlib.Path(__file__).resolve().parent / "results"
BENCH_PATH = RESULTS / "BENCH_availability.json"

SEEDS = (0, 1, 2)


def _processes(ds, rounds):
    """One representative process per scenario family."""
    from benchmarks.common import make_scenario
    table = make_mode("LN", n_clients=ds.n_clients, beta=0.5, seed=99).process()
    procs = {"TABLE(LN)": table}
    for name in ("GE", "CLUSTER", "DRIFT", "DEADLINE"):
        procs[name] = make_scenario(name, ds, rounds=rounds, seed=99)
    return procs


def run(quick: bool = True) -> list[dict]:
    n = 30 if quick else 100
    rounds = 25 if quick else 60
    ds = make_synthetic(n_clients=n, alpha=0.5, beta=0.5, seed=0)
    cfg = ScanConfig(rounds=rounds, m=max(1, n // 5), local_steps=10,
                     batch_size=10, lr=0.1, eval_every=5, sampler="uniform",
                     max_sweeps=16)
    eng = ScanEngine(ds, logistic_regression(), cfg)
    procs = _processes(ds, rounds)

    rows = []

    def bench(label, cells):
        t0 = time.time()
        hists = eng.run_batch(cells)         # may include the one-off compile
        total_s = time.time() - t0
        t0 = time.time()
        hists = eng.run_batch(cells)         # steady state
        run_s = time.time() - t0
        part = float(np.mean([h.counts.sum() / (rounds * cfg.m)
                              for h in hists]))
        row = {"table": "availability_bench", "family": label,
               "n_clients": n, "rounds": rounds, "cells": len(cells),
               "total_s": round(total_s, 3), "run_s": round(run_s, 3),
               "rounds_per_s": round(rounds * len(cells) / max(run_s, 1e-9), 1),
               "sel_fill": round(part, 3),    # |S_t| / M fill factor
               "best_loss_mean": round(float(np.mean([h.best_loss
                                                      for h in hists])), 4)}
        rows.append(row)
        print(f"[availability_bench] {label:11s}: {row['rounds_per_s']:8.1f} "
              f"batched rounds/s ({len(cells)} cells, steady "
              f"{row['run_s']:.3f}s)", flush=True)

    for label, proc in procs.items():
        bench(label, [eng.cell(seed=s, process=proc, avail_seed=40 + s)
                      for s in SEEDS])
    # the headline: one cell of EVERY family in ONE vmapped program
    bench("MIXED", [eng.cell(seed=i, process=proc, avail_seed=40 + i)
                    for i, proc in enumerate(procs.values())])

    RESULTS.mkdir(parents=True, exist_ok=True)
    from benchmarks.common import pallas_backend_mode
    record = {"bench": "availability", "backend": jax.default_backend(),
              "backend_mode": pallas_backend_mode(),
              "n_clients": n, "rounds": rounds, "sampler": cfg.sampler,
              "rows": rows}
    BENCH_PATH.write_text(json.dumps(record, indent=1))
    return rows


def summarize(rows) -> list[str]:
    out = ["", "== availability scenarios: batched scan throughput per "
           "family (one shared program) =="]
    out.append(f"{'family':>11s} {'cells':>6s} {'rounds/s':>9s} "
               f"{'steady s':>9s} {'w/ compile':>11s} {'fill':>6s} "
               f"{'best loss':>10s}")
    for r in rows:
        out.append(f"{r['family']:>11s} {r['cells']:6d} "
                   f"{r['rounds_per_s']:9.1f} {r['run_s']:9.3f} "
                   f"{r['total_s']:11.3f} {r['sel_fill']:6.3f} "
                   f"{r['best_loss_mean']:10.4f}")
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="N=100 clients, 60 rounds")
    args = ap.parse_args()
    for line in summarize(run(quick=not args.full)):
        print(line)

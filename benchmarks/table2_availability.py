"""Paper Table 2: optimal testing loss of every method under every client
availability mode, on all three datasets (Synthetic exact; CIFAR10 /
FashionMNIST as class-Gaussian surrogates with the paper's partitioners).

Since the scan engine landed, each (dataset, method) sweep ROW — all
availability modes x all seeds — executes as ONE jit-compiled
scan-over-rounds / vmap-over-cells program (``common.run_row_batched``),
including Power-of-Choice, whose per-client loss probe now runs in-scan.
Pass ``batched=False`` to force the legacy host loop everywhere.

Beyond the paper: ``scenarios=True`` (CLI ``--scenarios``) extends the
availability axis with the stateful scenario families — Gilbert–Elliott
churn, cluster outages, drift, deadlines (``core/availability_device``) —
as extra Synthetic columns, each (method x family x seed) sweep again one
batched device program (``common.run_scenario_row_batched``).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (
    METHODS, MODES, SCENARIOS, run_row_batched, run_scenario_row_batched,
    run_setting,
)


def _row_cells(ds_name, modes, method, seeds, quick, batched):
    """All (mode, seed) cell records of one sweep row."""
    if batched:
        return run_row_batched(ds_name, modes, method, seeds, quick=quick)
    return [run_setting(ds_name, mode_name, beta, method,
                        quick=quick, seed=seed)
            for mode_name, beta in modes for seed in seeds]


def run(quick: bool = True, seeds=None, batched: bool = True,
        scenarios: bool = False) -> list[dict]:
    if scenarios and not batched:
        # the stateful families draw availability in-scan; there is no host
        # mask table to replay, so a host-loop scenario column cannot exist
        raise ValueError("scenario columns run only through the batched "
                         "scan engine; drop scenarios=True or batched=False")
    rows = []
    for ds_name, modes in MODES.items():
        # paper averages 3 seeds; logreg on Synthetic is cheap enough to do so
        # even in the quick pass, the CNN surrogates use one seed per cell
        ds_seeds = seeds or ((0, 1, 2) if ds_name == "synthetic" else (0,))
        for method in METHODS:
            cells = _row_cells(ds_name, modes, method, ds_seeds, quick, batched)
            if scenarios and ds_name == "synthetic":
                cells = cells + run_scenario_row_batched(
                    ds_name, SCENARIOS, method, ds_seeds, quick=quick)
                modes_out = modes + [(s, None) for s in SCENARIOS]
            else:
                modes_out = modes
            for mode_name, beta in modes_out:
                sub = [c for c in cells if c["mode"] == mode_name]
                rows.append({
                    "table": "table2", "dataset": ds_name, "mode": mode_name,
                    "beta": beta, "method": method,
                    "best_loss": float(np.mean([c["best_loss"] for c in sub])),
                    "count_var": float(np.mean([c["count_var"] for c in sub])),
                })
    return rows


def summarize(rows) -> list[str]:
    out = ["", "== Table 2: optimal testing loss (dataset / mode x method) =="]
    datasets = sorted({r["dataset"] for r in rows})
    for ds in datasets:
        sub = [r for r in rows if r["dataset"] == ds]
        modes = list(dict.fromkeys(r["mode"] for r in sub))
        out.append(f"-- {ds} --")
        header = f"{'method':18s} " + " ".join(f"{m:>7s}" for m in modes)
        out.append(header)
        best_per_mode = {m: min(r["best_loss"] for r in sub if r["mode"] == m)
                         for m in modes}
        for method in METHODS:
            cells = []
            for m in modes:
                r = next(r for r in sub if r["mode"] == m and r["method"] == method)
                star = "*" if abs(r["best_loss"] - best_per_mode[m]) < 1e-9 else " "
                cells.append(f"{r['best_loss']:6.3f}{star}")
            out.append(f"{method:18s} " + " ".join(cells))
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenarios", action="store_true",
                    help="extend the Synthetic columns with the stateful "
                         "scenario families (GE/CLUSTER/DRIFT/DEADLINE)")
    args = ap.parse_args()
    for line in summarize(run(scenarios=args.scenarios)):
        print(line)

"""Beyond-paper: host-loop engine vs the jit-compiled scan engine.

The sweep the paper actually runs (Tables 2–4) is (sampler x availability
mode x seed); here the canonical slice — 7 availability modes x 3 seeds at
N=100 clients, synthetic logreg — is executed two ways:

  host  : ``FLEngine.run`` per cell, serially — one Python round loop with a
          host<->device sync per round (the trainer/eval jits are shared
          across cells so the host side pays compilation only once).
  scan  : ``ScanEngine.run_batch`` — all 21 cells as ONE XLA program
          (lax.scan over rounds, vmap over cells, device-side availability
          and sampling).

Reports steady-state speedup (the scan program is compiled once per
(sampler, shape) and cached — ``lax.scan`` makes compile time independent of
the round count) and the speedup including that one-off compile.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.availability import ALL_MODES, make_mode
from repro.core.sampler import FedGSSampler, make_sampler
from repro.data.synthetic import make_synthetic
from repro.fed.engine import FLConfig, FLEngine
from repro.fed.models import logistic_regression
from repro.fed.scan_engine import ScanConfig, ScanEngine, oracle_h

N_CLIENTS = 100
SEEDS = (0, 1, 2)


def _make_mode(name, ds):
    return make_mode(name, n_clients=ds.n_clients, data_sizes=ds.sizes,
                     label_sets=ds.label_sets(), num_labels=ds.num_classes,
                     seed=99)


def _host_engine(ds, model, sampler_name, mode, cfg, h):
    sampler = (FedGSSampler(alpha=1.0, max_sweeps=32)
               if sampler_name == "fedgs" else make_sampler(sampler_name))
    eng = FLEngine(ds, model, sampler, mode, cfg)
    if sampler_name == "fedgs":
        eng.install_graph_from_H(h)
    return eng


def run(quick: bool = True) -> list[dict]:
    rounds = 30 if quick else 100
    ds = make_synthetic(n_clients=N_CLIENTS, alpha=0.5, beta=0.5, seed=0)
    model = logistic_regression()
    h_raw = None
    rows = []
    for sampler_name in ("uniform", "fedgs"):
        if sampler_name == "fedgs" and h_raw is None:
            from repro.core.graph import build_3dg
            _, _, h_raw = build_3dg(np.asarray(ds.opt_params))
        h_norm = oracle_h(ds.opt_params) if sampler_name == "fedgs" else None

        # ---------------- host loop, serial over cells --------------------
        cells_meta = [(m, s) for m in ALL_MODES for s in SEEDS]
        shared = None
        # warmup engine (compile trainer/eval once, outside the timed region)
        warm_cfg = FLConfig(rounds=2, sample_frac=0.1, local_steps=10,
                            batch_size=10, lr=0.1, eval_every=5, seed=0)
        warm = _host_engine(ds, model, sampler_name, _make_mode("IDL", ds),
                            warm_cfg, h_raw)
        warm.run()
        host_losses = []
        t0 = time.time()
        for mode_name, seed in cells_meta:
            cfg = FLConfig(rounds=rounds, sample_frac=0.1, local_steps=10,
                           batch_size=10, lr=0.1, eval_every=5, seed=seed)
            eng = _host_engine(ds, model, sampler_name,
                               _make_mode(mode_name, ds), cfg, h_raw)
            eng._trainer, eng._eval = warm._trainer, warm._eval  # share jits
            hist = eng.run()
            host_losses.append(hist.best_loss)
        host_s = time.time() - t0

        # ---------------- batched scan engine -----------------------------
        scfg = ScanConfig(rounds=rounds, m=max(1, N_CLIENTS // 10),
                          local_steps=10, batch_size=10, lr=0.1,
                          eval_every=5, sampler=sampler_name, max_sweeps=32)
        seng = ScanEngine(ds, model, scfg)
        cells = [seng.cell(seed=s, mode=_make_mode(m, ds), alpha=1.0,
                           h=h_norm) for m, s in cells_meta]
        t0 = time.time()
        hists = seng.run_batch(cells)          # includes the one-off compile
        scan_total_s = time.time() - t0
        t0 = time.time()
        hists = seng.run_batch(cells)          # steady state
        scan_run_s = time.time() - t0
        scan_losses = [h.best_loss for h in hists]

        rows.append({
            "table": "engine_bench", "sampler": sampler_name,
            "n_clients": N_CLIENTS, "rounds": rounds,
            "cells": len(cells_meta),
            "host_s": round(host_s, 2),
            "scan_total_s": round(scan_total_s, 2),
            "scan_run_s": round(scan_run_s, 2),
            "speedup": round(host_s / max(scan_run_s, 1e-9), 1),
            "speedup_incl_compile": round(host_s / max(scan_total_s, 1e-9), 1),
            "host_best_loss_mean": round(float(np.mean(host_losses)), 4),
            "scan_best_loss_mean": round(float(np.mean(scan_losses)), 4),
        })
        print(f"[engine_bench] {sampler_name}: host {host_s:.1f}s, "
              f"scan {scan_run_s:.2f}s (+{scan_total_s - scan_run_s:.1f}s "
              f"compile) -> {rows[-1]['speedup']}x", flush=True)

    # whole sweep (all sampler rows together): the headline number
    host_all = sum(r["host_s"] for r in rows)
    run_all = sum(r["scan_run_s"] for r in rows)
    total_all = sum(r["scan_total_s"] for r in rows)
    rows.append({
        "table": "engine_bench", "sampler": "ALL",
        "n_clients": N_CLIENTS, "rounds": rows[0]["rounds"],
        "cells": sum(r["cells"] for r in rows),
        "host_s": round(host_all, 2),
        "scan_total_s": round(total_all, 2),
        "scan_run_s": round(run_all, 2),
        "speedup": round(host_all / max(run_all, 1e-9), 1),
        "speedup_incl_compile": round(host_all / max(total_all, 1e-9), 1),
        "host_best_loss_mean": float("nan"),
        "scan_best_loss_mean": float("nan"),
    })
    return rows


def summarize(rows) -> list[str]:
    out = ["", "== engine bench: host round loop vs batched scan engine "
           "(7 modes x 3 seeds) =="]
    out.append(f"{'sampler':>8s} {'cells':>6s} {'rounds':>7s} {'host (s)':>9s} "
               f"{'scan (s)':>9s} {'compile (s)':>12s} {'speedup':>8s} "
               f"{'w/ compile':>11s}")
    for r in rows:
        out.append(
            f"{r['sampler']:>8s} {r['cells']:6d} {r['rounds']:7d} "
            f"{r['host_s']:9.2f} {r['scan_run_s']:9.2f} "
            f"{r['scan_total_s'] - r['scan_run_s']:12.2f} "
            f"{r['speedup']:7.1f}x {r['speedup_incl_compile']:10.1f}x")
    out.append("   (best-loss sanity: host vs scan mean "
               + ", ".join(f"{r['sampler']} {r['host_best_loss_mean']:.3f}/"
                           f"{r['scan_best_loss_mean']:.3f}"
                           for r in rows if r["sampler"] != "ALL")
               + ")")
    return out


if __name__ == "__main__":
    for line in summarize(run()):
        print(line)

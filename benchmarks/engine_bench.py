"""Beyond-paper: host-loop engine vs the jit-compiled scan engine.

The sweep the paper actually runs (Tables 2–4) is (sampler x availability
mode x seed); here the canonical slice — 7 availability modes x 3 seeds at
N=100 clients, synthetic logreg — is executed two ways:

  host  : ``FLEngine.run`` per cell, serially — one Python round loop with a
          host<->device sync per round (the trainer/eval jits are shared
          across cells so the host side pays compilation only once).
  scan  : ``ScanEngine.run_batch`` — all 21 cells as ONE XLA program
          (lax.scan over rounds, vmap over cells, device-side availability
          and sampling).

Reports steady-state speedup (the scan program is compiled once per
(sampler, shape) and cached — ``lax.scan`` makes compile time independent of
the round count) and the speedup including that one-off compile.

``run_shard`` / ``--shard`` adds the sharded-vs-single column: the SAME
cell batch through ``run_batch`` on the ("cells", "silo") engine mesh
(DESIGN.md §13), emitting ``results/BENCH_shard.json``.  Forced CPU host
devices share one physical socket, so the quick number measures shard_map
overhead, not real scale-out — the column exists to track that overhead
and to exercise the meshed program end-to-end in CI.  All repro imports
live inside functions so ``--shard`` can set
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` before jax
initializes (run_shard re-execs itself in a subprocess when the current
process already locked a smaller device count).
"""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys
import time

import numpy as np

N_CLIENTS = 100
SEEDS = (0, 1, 2)
SHARD_MESH = (8, 1)
RESULTS = pathlib.Path(__file__).resolve().parent / "results"
_FORCE_FLAG = "--xla_force_host_platform_device_count=8"


def _make_mode(name, ds):
    from repro.core.availability import make_mode
    return make_mode(name, n_clients=ds.n_clients, data_sizes=ds.sizes,
                     label_sets=ds.label_sets(), num_labels=ds.num_classes,
                     seed=99)


def _host_engine(ds, model, sampler_name, mode, cfg, h):
    from repro.core.sampler import FedGSSampler, make_sampler
    from repro.fed.engine import FLEngine
    sampler = (FedGSSampler(alpha=1.0, max_sweeps=32)
               if sampler_name == "fedgs" else make_sampler(sampler_name))
    eng = FLEngine(ds, model, sampler, mode, cfg)
    if sampler_name == "fedgs":
        eng.install_graph_from_H(h)
    return eng


def run(quick: bool = True) -> list[dict]:
    from repro.core.availability import ALL_MODES
    from repro.data.synthetic import make_synthetic
    from repro.fed.engine import FLConfig
    from repro.fed.models import logistic_regression
    from repro.fed.scan_engine import ScanConfig, ScanEngine, oracle_h

    rounds = 30 if quick else 100
    ds = make_synthetic(n_clients=N_CLIENTS, alpha=0.5, beta=0.5, seed=0)
    model = logistic_regression()
    h_raw = None
    rows = []
    for sampler_name in ("uniform", "fedgs"):
        if sampler_name == "fedgs" and h_raw is None:
            from repro.core.graph import build_3dg
            _, _, h_raw = build_3dg(np.asarray(ds.opt_params))
        h_norm = oracle_h(ds.opt_params) if sampler_name == "fedgs" else None

        # ---------------- host loop, serial over cells --------------------
        cells_meta = [(m, s) for m in ALL_MODES for s in SEEDS]
        shared = None
        # warmup engine (compile trainer/eval once, outside the timed region)
        warm_cfg = FLConfig(rounds=2, sample_frac=0.1, local_steps=10,
                            batch_size=10, lr=0.1, eval_every=5, seed=0)
        warm = _host_engine(ds, model, sampler_name, _make_mode("IDL", ds),
                            warm_cfg, h_raw)
        warm.run()
        host_losses = []
        t0 = time.time()
        for mode_name, seed in cells_meta:
            cfg = FLConfig(rounds=rounds, sample_frac=0.1, local_steps=10,
                           batch_size=10, lr=0.1, eval_every=5, seed=seed)
            eng = _host_engine(ds, model, sampler_name,
                               _make_mode(mode_name, ds), cfg, h_raw)
            eng._trainer, eng._eval = warm._trainer, warm._eval  # share jits
            hist = eng.run()
            host_losses.append(hist.best_loss)
        host_s = time.time() - t0

        # ---------------- batched scan engine -----------------------------
        scfg = ScanConfig(rounds=rounds, m=max(1, N_CLIENTS // 10),
                          local_steps=10, batch_size=10, lr=0.1,
                          eval_every=5, sampler=sampler_name, max_sweeps=32)
        seng = ScanEngine(ds, model, scfg)
        cells = [seng.cell(seed=s, mode=_make_mode(m, ds), alpha=1.0,
                           h=h_norm) for m, s in cells_meta]
        t0 = time.time()
        hists = seng.run_batch(cells)          # includes the one-off compile
        scan_total_s = time.time() - t0
        # the ProgramCache's compile-event timer (DESIGN.md §15) splits the
        # one-off XLA compile out of the first-call wall-clock exactly,
        # instead of inferring it as first-call minus second-call
        compile_ms = seng.runtime_stats()["compile_ms"]
        t0 = time.time()
        hists = seng.run_batch(cells)          # steady state
        scan_run_s = time.time() - t0
        scan_losses = [h.best_loss for h in hists]

        rows.append({
            "table": "engine_bench", "sampler": sampler_name,
            "n_clients": N_CLIENTS, "rounds": rounds,
            "cells": len(cells_meta),
            "host_s": round(host_s, 2),
            "scan_total_s": round(scan_total_s, 2),
            "scan_run_s": round(scan_run_s, 2),
            "compile_ms": round(compile_ms, 1),
            "steady_ms": round(scan_run_s * 1e3, 1),
            "speedup": round(host_s / max(scan_run_s, 1e-9), 1),
            "speedup_incl_compile": round(host_s / max(scan_total_s, 1e-9), 1),
            "host_best_loss_mean": round(float(np.mean(host_losses)), 4),
            "scan_best_loss_mean": round(float(np.mean(scan_losses)), 4),
        })
        print(f"[engine_bench] {sampler_name}: host {host_s:.1f}s, "
              f"scan {scan_run_s:.2f}s (+{scan_total_s - scan_run_s:.1f}s "
              f"compile) -> {rows[-1]['speedup']}x", flush=True)

    # whole sweep (all sampler rows together): the headline number
    host_all = sum(r["host_s"] for r in rows)
    run_all = sum(r["scan_run_s"] for r in rows)
    total_all = sum(r["scan_total_s"] for r in rows)
    rows.append({
        "table": "engine_bench", "sampler": "ALL",
        "n_clients": N_CLIENTS, "rounds": rows[0]["rounds"],
        "cells": sum(r["cells"] for r in rows),
        "host_s": round(host_all, 2),
        "scan_total_s": round(total_all, 2),
        "scan_run_s": round(run_all, 2),
        "compile_ms": round(sum(r["compile_ms"] for r in rows), 1),
        "steady_ms": round(run_all * 1e3, 1),
        "speedup": round(host_all / max(run_all, 1e-9), 1),
        "speedup_incl_compile": round(host_all / max(total_all, 1e-9), 1),
        "host_best_loss_mean": float("nan"),
        "scan_best_loss_mean": float("nan"),
    })
    return rows


def summarize(rows) -> list[str]:
    out = ["", "== engine bench: host round loop vs batched scan engine "
           "(7 modes x 3 seeds) =="]
    out.append(f"{'sampler':>8s} {'cells':>6s} {'rounds':>7s} {'host (s)':>9s} "
               f"{'scan (s)':>9s} {'compile (s)':>12s} {'speedup':>8s} "
               f"{'w/ compile':>11s}")
    for r in rows:
        out.append(
            f"{r['sampler']:>8s} {r['cells']:6d} {r['rounds']:7d} "
            f"{r['host_s']:9.2f} {r['scan_run_s']:9.2f} "
            f"{r['scan_total_s'] - r['scan_run_s']:12.2f} "
            f"{r['speedup']:7.1f}x {r['speedup_incl_compile']:10.1f}x")
    out.append("   (best-loss sanity: host vs scan mean "
               + ", ".join(f"{r['sampler']} {r['host_best_loss_mean']:.3f}/"
                           f"{r['scan_best_loss_mean']:.3f}"
                           for r in rows if r["sampler"] != "ALL")
               + ")")
    return out


# ------------------------------------------------- sharded-vs-single column
def _shard_rows(quick: bool = True) -> list[dict]:
    """Time the SAME 21-cell uniform-sampler batch fused on one device vs
    shard_map'd over the (8,) cells-axis mesh.  Requires >= 8 devices in the
    CURRENT process — call ``run_shard`` for the subprocess fallback."""
    import jax

    from repro.core.availability import ALL_MODES
    from repro.data.synthetic import make_synthetic
    from repro.fed.models import logistic_regression
    from repro.fed.scan_engine import ScanConfig, ScanEngine

    need = int(np.prod(SHARD_MESH))
    if jax.device_count() < need:
        raise RuntimeError(
            f"shard bench needs {need} devices, have {jax.device_count()}; "
            f"set XLA_FLAGS={_FORCE_FLAG} before jax initializes or call "
            "run_shard() for the subprocess fallback")

    rounds = 30 if quick else 100
    ds = make_synthetic(n_clients=N_CLIENTS, alpha=0.5, beta=0.5, seed=0)
    model = logistic_regression()
    cells_meta = [(m, s) for m in ALL_MODES for s in SEEDS]

    timings = {}
    for label, mesh in (("single", None), ("shard", SHARD_MESH)):
        cfg = ScanConfig(rounds=rounds, m=max(1, N_CLIENTS // 10),
                         local_steps=10, batch_size=10, lr=0.1, eval_every=5,
                         sampler="uniform", mesh=mesh)
        eng = ScanEngine(ds, model, cfg)
        cells = [eng.cell(seed=s, mode=_make_mode(m, ds))
                 for m, s in cells_meta]
        t0 = time.time()
        hists = eng.run_batch(cells)       # includes the one-off compile
        total_s = time.time() - t0
        compile_ms = eng.runtime_stats()["compile_ms"]
        t0 = time.time()
        hists = eng.run_batch(cells)       # steady state
        run_s = time.time() - t0
        timings[label] = (total_s, run_s, compile_ms,
                          float(np.mean([h.best_loss for h in hists])))
        print(f"[engine_bench --shard] {label}: run {run_s:.2f}s "
              f"({compile_ms / 1e3:.1f}s compile)", flush=True)

    (s_tot, s_run, s_cms, s_loss), (p_tot, p_run, p_cms, p_loss) = \
        timings["single"], timings["shard"]
    rows = [{
        "table": "engine_bench_shard",
        "mesh": "x".join(str(d) for d in SHARD_MESH),
        "devices": jax.device_count(), "backend": jax.default_backend(),
        "n_clients": N_CLIENTS, "rounds": rounds, "cells": len(cells_meta),
        "single_run_s": round(s_run, 3), "single_total_s": round(s_tot, 3),
        "single_compile_ms": round(s_cms, 1),
        "shard_run_s": round(p_run, 3), "shard_total_s": round(p_tot, 3),
        "shard_compile_ms": round(p_cms, 1),
        # >1 means the meshed program is slower — expected on forced CPU
        # host devices, where this tracks pure shard_map/collective overhead
        "shard_overhead_x": round(p_run / max(s_run, 1e-9), 2),
        "single_best_loss_mean": round(s_loss, 4),
        "shard_best_loss_mean": round(p_loss, 4),
    }]
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / "BENCH_shard.json").write_text(json.dumps(rows, indent=2))
    return rows


def run_shard(quick: bool = True) -> list[dict]:
    """Sharded-vs-single column; re-execs in a subprocess with 8 forced CPU
    host devices when this process already locked a smaller device count
    (XLA_FLAGS only takes effect before jax initializes)."""
    import jax
    if jax.device_count() >= int(np.prod(SHARD_MESH)):
        return _shard_rows(quick)
    repo = pathlib.Path(__file__).resolve().parents[1]
    env = dict(os.environ)
    flags = env.get("XLA_FLAGS", "")
    if "host_platform_device_count" not in flags:
        env["XLA_FLAGS"] = (flags + " " + _FORCE_FLAG).strip()
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (str(repo / "src"), env.get("PYTHONPATH", "")) if p)
    cmd = [sys.executable, "-m", "benchmarks.engine_bench", "--shard"]
    if not quick:
        cmd.append("--full")
    subprocess.run(cmd, check=True, env=env, cwd=str(repo))
    return json.loads((RESULTS / "BENCH_shard.json").read_text())


def summarize_shard(rows) -> list[str]:
    out = ["", "== engine bench: fused single-device vs shard_map'd "
           "run_batch (results/BENCH_shard.json) =="]
    out.append(f"{'mesh':>6s} {'devices':>8s} {'cells':>6s} {'rounds':>7s} "
               f"{'single (s)':>11s} {'shard (s)':>10s} {'overhead':>9s}")
    for r in rows:
        out.append(f"{r['mesh']:>6s} {r['devices']:8d} {r['cells']:6d} "
                   f"{r['rounds']:7d} {r['single_run_s']:11.2f} "
                   f"{r['shard_run_s']:10.2f} {r['shard_overhead_x']:8.2f}x")
        out.append("   (best-loss sanity: single "
                   f"{r['single_best_loss_mean']:.3f} vs shard "
                   f"{r['shard_best_loss_mean']:.3f}; forced host devices "
                   "share one socket, so overhead_x tracks collective cost, "
                   "not scale-out)")
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--shard", action="store_true",
                    help="sharded-vs-single column (forces 8 CPU host "
                         "devices; must be set before jax initializes, which "
                         "is why repro imports are function-local)")
    ap.add_argument("--full", action="store_true", help="100 rounds, not 30")
    a = ap.parse_args()
    if a.shard:
        _flags = os.environ.get("XLA_FLAGS", "")
        if "host_platform_device_count" not in _flags:
            os.environ["XLA_FLAGS"] = (_flags + " " + _FORCE_FLAG).strip()
        for line in summarize_shard(_shard_rows(quick=not a.full)):
            print(line)
    else:
        for line in summarize(run(quick=not a.full)):
            print(line)

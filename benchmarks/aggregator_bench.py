"""Aggregator-subsystem bench (fed/aggregator_device.py): the
memory-rectified (N, P) panel kernel ref vs Pallas, plus the bias sweep the
subsystem exists for.

Part 1 — kernel scaling: for the ``memory`` family's hot path (masked
scatter of the m sampled rows into the (N, P) update-memory panel + the
staleness-weighted row reduction) each row times the pure-jnp ref against
the fused Pallas kernel (``kernels/ops.memory_aggregate``) from identical
inputs at N ∈ {256, 1024, 4096} × P tiers, asserting the scattered panel is
BIT-identical and the reduction numerically equal (max |diff| recorded) —
the same contract ``tests/test_aggregator_device.py`` pins at small N.  On
CPU the Pallas path runs in interpret mode, where every grid step re-writes
the (N, P) output (see DESIGN.md §12) — the ref column is expected to win
here; on TPU the fusion removes one full panel round-trip per round.

Part 2 — bias-vs-rounds sweep: memory-rectified FedGS vs plain (FedAvg)
FedGS under the paper's MDF and YC availability modes, all four
(aggregator × mode) cells as ONE mixed-aggregator ``run_batch`` program
(the batching headline).  Rows record best/final loss and the final
fairness metrics (count variance Eq. 6, Gini) per cell.

Dumped to ``benchmarks/results/BENCH_aggregator.json`` so the aggregator
trajectory accumulates across PRs (CI runs the quick pass).

  PYTHONPATH=src python -m benchmarks.aggregator_bench [--quick|--full]
"""
from __future__ import annotations

import json
import pathlib
import time

import numpy as np

import jax
import jax.numpy as jnp

RESULTS = pathlib.Path(__file__).resolve().parent / "results"
BENCH_PATH = RESULTS / "BENCH_aggregator.json"


def _time(fn, reps=2):
    fn()                                  # compile / warm up
    t0 = time.time()
    for _ in range(reps):
        fn()
    return (time.time() - t0) / reps


# ------------------------------------------------------- part 1: the kernel
def _kernel_rows(quick: bool) -> list[dict]:
    # the SHIPPED backends, not local copies: a ref-semantics change keeps
    # this comparison honest
    from repro.fed.aggregator_device import memory_scatter_reduce_ref
    from repro.kernels.ops import memory_aggregate
    _ref_apply = jax.jit(memory_scatter_reduce_ref)
    pal = jax.jit(lambda a, b, c, d, e: memory_aggregate(a, b, c, d, e))
    sizes = [(256, 512), (256, 2048), (1024, 512), (1024, 2048),
             (4096, 512), (4096, 2048)]
    if not quick:
        sizes += [(4096, 8192), (16384, 2048)]
    rng = np.random.default_rng(0)
    rows = []
    for n, p in sizes:
        m = max(2, n // 10)
        mem = jnp.asarray(rng.normal(size=(n, p)).astype(np.float32))
        upd = jnp.asarray(rng.normal(size=(m, p)).astype(np.float32))
        sel = jnp.asarray(rng.choice(n, size=m, replace=False), jnp.int32)
        valid = jnp.asarray(rng.random(m) < 0.9)
        w = jnp.asarray(rng.random(n).astype(np.float32))
        w = w / w.sum()
        reps = 2 if n * p <= 4096 * 2048 else 1

        def run_ref():
            a, b = _ref_apply(mem, upd, sel, valid, w)
            return np.asarray(a), np.asarray(b)

        def run_pal():
            a, b = pal(mem, upd, sel, valid, w)
            return np.asarray(a), np.asarray(b)

        m_ref, r_ref = run_ref()
        m_pal, r_pal = run_pal()
        # the parity contract is load-bearing: CI must FAIL on a panel /
        # padding regression, not bury it in the JSON
        assert np.array_equal(m_ref, m_pal), \
            f"scattered panels diverge at N={n}, P={p}"
        maxdiff = float(np.max(np.abs(r_ref - r_pal)))
        assert np.allclose(r_ref, r_pal, atol=1e-5, rtol=1e-5), \
            f"reductions diverge at N={n}, P={p} (max |diff| {maxdiff})"
        t_ref = _time(run_ref, reps=reps)
        t_pal = _time(run_pal, reps=reps)
        rows.append({"table": "aggregator_kernel", "n_clients": n, "p": p,
                     "m": m, "ref_s": round(t_ref, 4),
                     "pallas_s": round(t_pal, 4),
                     "speedup": round(t_ref / max(t_pal, 1e-9), 2),
                     "mem_bit_equal": True,
                     "red_max_abs_diff": maxdiff})
        print(f"[aggregator_bench] N={n:6d} P={p:5d} m={m:5d}: "
              f"ref {t_ref:7.4f}s  pallas {t_pal:7.4f}s  "
              f"({rows[-1]['speedup']:5.2f}x, red maxdiff {maxdiff:.1e})",
              flush=True)
    return rows


# ---------------------------------------------------- part 2: the bias sweep
def _bias_rows(quick: bool) -> list[dict]:
    from repro.core.availability import make_mode
    from repro.data.synthetic import make_synthetic
    from repro.fed.aggregator_device import make_aggregator_process
    from repro.fed.models import logistic_regression
    from repro.fed.scan_engine import ScanConfig, ScanEngine, oracle_h

    n = 30 if quick else 100
    rounds = 40 if quick else 80
    ds = make_synthetic(n_clients=n, alpha=0.5, beta=0.5, seed=0)
    h = oracle_h(ds.opt_params)
    cfg = ScanConfig(rounds=rounds, m=max(2, n // 5), local_steps=10,
                     batch_size=10, lr=0.1, eval_every=1, sampler="fedgs",
                     max_sweeps=16)
    eng = ScanEngine(ds, logistic_regression(), cfg)
    modes = {name: make_mode(name, n_clients=n, data_sizes=ds.sizes,
                             label_sets=ds.label_sets(),
                             num_labels=ds.num_classes, seed=99)
             for name in ("MDF", "YC")}
    grid = [(mname, aname) for mname in modes for aname in
            ("fedavg", "memory")]
    # the fedavg/memory pair under one mode SHARES seed + avail stream, so
    # the deterministic FedGS sampler draws identical sets and the row pair
    # isolates the aggregator's effect on the trajectory
    cells = [eng.cell(seed=0, mode=modes[mname], alpha=1.0, h=h,
                      aggregator_process=make_aggregator_process(aname),
                      avail_seed=40 + sorted(modes).index(mname))
             for (mname, aname) in grid]
    t0 = time.time()
    hists = eng.run_batch(cells)          # ONE mixed-aggregator program
    wall = time.time() - t0
    rows = []
    for (mname, aname), hh in zip(grid, hists):
        rows.append({"table": "aggregator_bias", "mode": mname,
                     "aggregator": aname, "n_clients": n, "rounds": rounds,
                     "best_loss": round(hh.best_loss, 4),
                     "final_loss": round(float(hh.val_loss[-1]), 4),
                     "final_count_var": round(float(hh.count_var[-1]), 3),
                     "final_gini": round(float(hh.gini[-1]), 4),
                     "batch_wall_s": round(wall, 2)})
        print(f"[aggregator_bench] {mname:4s} x {aname:7s}: "
              f"best {rows[-1]['best_loss']:.4f}  "
              f"final {rows[-1]['final_loss']:.4f}  "
              f"gini {rows[-1]['final_gini']:.3f}", flush=True)
    return rows


def run(quick: bool = True) -> list[dict]:
    rows = _kernel_rows(quick) + _bias_rows(quick)
    RESULTS.mkdir(parents=True, exist_ok=True)
    from benchmarks.common import pallas_backend_mode
    record = {"bench": "aggregator", "backend": jax.default_backend(),
              "backend_mode": pallas_backend_mode(),
              "pallas_interpret": jax.default_backend() == "cpu",
              "rows": rows}
    BENCH_PATH.write_text(json.dumps(record, indent=1))
    return rows


def summarize(rows) -> list[str]:
    out = ["", "== memory-rectified aggregation: ref vs pallas-fused "
           "(N, P) panel =="]
    out.append(f"{'N':>7s} {'P':>6s} {'M':>6s} {'ref (s)':>9s} "
               f"{'pallas (s)':>11s} {'speedup':>8s} {'red maxdiff':>12s}")
    for r in rows:
        if r["table"] != "aggregator_kernel":
            continue
        out.append(f"{r['n_clients']:7d} {r['p']:6d} {r['m']:6d} "
                   f"{r['ref_s']:9.4f} {r['pallas_s']:11.4f} "
                   f"{r['speedup']:7.2f}x {r['red_max_abs_diff']:12.1e}")
    out.append("")
    out.append("== bias sweep: memory-rectified FedGS vs plain, one mixed-"
               "aggregator batch ==")
    out.append(f"{'mode':>5s} {'aggregator':>11s} {'best loss':>10s} "
               f"{'final loss':>11s} {'count var':>10s} {'gini':>7s}")
    for r in rows:
        if r["table"] != "aggregator_bias":
            continue
        out.append(f"{r['mode']:>5s} {r['aggregator']:>11s} "
                   f"{r['best_loss']:10.4f} {r['final_loss']:11.4f} "
                   f"{r['final_count_var']:10.3f} {r['final_gini']:7.4f}")
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="the CI pass (default unless --full)")
    ap.add_argument("--full", action="store_true",
                    help="adds the N=16384 / P=8192 panels and the "
                         "N=100, 80-round bias sweep")
    args = ap.parse_args()
    for line in summarize(run(quick=not args.full)):
        print(line)

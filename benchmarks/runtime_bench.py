"""Runtime-layer bench (DESIGN.md §15): persistent-compile-cache warm
start and donated/pipelined segmented throughput.

Two claims, one ``results/BENCH_runtime.json`` artifact:

warm start
    A SECOND process pointing ``ScanConfig.compile_cache_dir`` at the same
    directory loads its XLA executables from the persistent cache instead
    of recompiling — measured by running the compile step in two fresh
    subprocesses (cold dir, then warm) so each pays a genuinely cold jax.
    Acceptance: >= 5x reduction in the committed record (the CI gate
    enforces >= 3x to absorb runner noise).

steady state
    The donated + pipelined segmented ``run_batch`` (buffer-donated carry,
    double-buffered ``device_get``, async checkpoint writer) vs the fused
    single-program run, and vs the legacy blocking segmented path
    (``donate_carry=False, async_pipeline=False`` — the pre-runtime-layer
    behavior).  Acceptance: pipelined-no-ckpt within 10% of fused
    rounds/sec in the committed record, decisions bitwise per DESIGN §13.

  PYTHONPATH=src python -m benchmarks.runtime_bench
"""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys
import time

import numpy as np

RESULTS = pathlib.Path(__file__).resolve().parent / "results"
N_CLIENTS = 50
B_CELLS = 8


def _mk(rounds, **kw):
    """(engine, cells) at the bench shape — one stateful scenario family
    per cell so the scan carry has every slot populated."""
    from repro.core.availability_device import make_process
    from repro.data.synthetic import make_synthetic
    from repro.fed.aggregator_device import make_aggregator_process
    from repro.fed.models import logistic_regression
    from repro.fed.scan_engine import ScanConfig, ScanEngine

    ds = make_synthetic(n_clients=N_CLIENTS, alpha=0.5, beta=0.5, seed=0)
    cfg = ScanConfig(rounds=rounds, m=5, local_steps=5, batch_size=8,
                     eval_every=5, sampler="uniform", aggregator="memory",
                     **kw)
    eng = ScanEngine(ds, logistic_regression(), cfg)
    scen = ("GE", "CLUSTER", "DRIFT", "DEADLINE")
    aggs = ("memory", "fedavgm", "fedadam", "fedavg")
    cells = [eng.cell(
        seed=i, avail_seed=40 + i,
        process=make_process(scen[i % 4], n_clients=ds.n_clients,
                             data_sizes=ds.sizes, label_sets=ds.label_sets(),
                             num_labels=ds.num_classes, rounds=rounds,
                             seed=9 + i),
        aggregator_process=make_aggregator_process(aggs[i % 4]))
        for i in range(B_CELLS)]
    return eng, cells


def _child_compile(cache_dir: str, rounds: int) -> None:
    """Subprocess body: compile the batched program in a FRESH jax process
    with the persistent cache at ``cache_dir``; print the compile seconds."""
    eng, cells = _mk(rounds, compile_cache_dir=cache_dir)
    lowered = eng.lower_batch(cells)     # trace+lower: NOT what the cache
    t0 = time.perf_counter()             # persists — time compile alone
    lowered.compile()
    print(json.dumps({"compile_s": time.perf_counter() - t0}))


def _spawn_compile(cache_dir: str, rounds: int) -> float:
    repo = pathlib.Path(__file__).resolve().parents[1]
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (str(repo / "src"), env.get("PYTHONPATH", "")) if p)
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.runtime_bench", "--child",
         cache_dir, "--rounds", str(rounds)],
        check=True, env=env, cwd=str(repo), capture_output=True, text=True)
    return float(json.loads(out.stdout.strip().splitlines()[-1])["compile_s"])


def run(quick: bool = True) -> list[dict]:
    import tempfile

    import jax

    from benchmarks.common import pallas_backend_mode

    rounds = 40 if quick else 120
    seg = 8

    # ---------------- warm start: persistent compile cache ---------------
    with tempfile.TemporaryDirectory() as td:
        cache = os.path.join(td, "xla-cache")
        cold_s = _spawn_compile(cache, rounds)
        warm_s = _spawn_compile(cache, rounds)
    warm_speedup = cold_s / max(warm_s, 1e-9)
    print(f"[runtime_bench] warm start: cold {cold_s:.2f}s -> warm "
          f"{warm_s:.2f}s ({warm_speedup:.1f}x)", flush=True)

    # ---------------- steady state: fused vs pipelined vs legacy ---------
    def steady(eng, cells, **kw):
        """Second-call wall-clock (first call pays the compiles)."""
        eng.run_batch(cells, **kw)
        t0 = time.perf_counter()
        hists = eng.run_batch(cells, **kw)
        return time.perf_counter() - t0, hists

    eng, cells = _mk(rounds)
    fused_s, fused_h = steady(eng, cells)
    pipe_s, pipe_h = steady(eng, cells, ckpt_every=seg)
    with tempfile.TemporaryDirectory() as td:
        pipe_ck_s, _ = steady(eng, cells, ckpt_every=seg,
                              ckpt_path=os.path.join(td, "ck"))
        # checkpoint-writer backpressure counters from the timed run
        # (DESIGN.md §17): queue high-watermark + total blocked ms show
        # whether the npz writes ever stalled the dispatch loop
        writer_stats = eng.runtime_stats().get("checkpoint_writer")
    leg_eng, leg_cells = _mk(rounds, donate_carry=False, async_pipeline=False)
    leg_s, leg_h = steady(leg_eng, leg_cells, ckpt_every=seg)
    with tempfile.TemporaryDirectory() as td:
        leg_ck_s, _ = steady(leg_eng, leg_cells, ckpt_every=seg,
                             ckpt_path=os.path.join(td, "ck"))

    # DESIGN §13: decisions bitwise across every runtime mode; the
    # pipelined and legacy segmented paths are bitwise EVERYWHERE
    decisions_ok = True
    for a, b, fields in ((fused_h, pipe_h, ("sel", "valid", "counts")),
                         (pipe_h, leg_h, ("sel", "valid", "counts", "gini",
                                          "count_var", "val_loss",
                                          "val_acc"))):
        for ha, hb in zip(a, b):
            for f in fields:
                decisions_ok &= bool(
                    np.array_equal(getattr(ha, f), getattr(hb, f),
                                   equal_nan=True))

    cell_rounds = B_CELLS * rounds
    rps = lambda s: round(cell_rounds / max(s, 1e-9), 1)   # noqa: E731
    row = {
        "table": "runtime_bench", "backend": jax.default_backend(),
        "backend_mode": pallas_backend_mode(),
        "n_clients": N_CLIENTS, "cells": B_CELLS, "rounds": rounds,
        "segment": seg,
        "cold_compile_s": round(cold_s, 3), "warm_compile_s": round(warm_s, 3),
        "warm_speedup_x": round(warm_speedup, 1),
        "fused_s": round(fused_s, 3),
        "pipelined_s": round(pipe_s, 3),
        "pipelined_ckpt_s": round(pipe_ck_s, 3),
        "legacy_s": round(leg_s, 3),
        "legacy_ckpt_s": round(leg_ck_s, 3),
        "fused_rounds_per_s": rps(fused_s),
        "pipelined_rounds_per_s": rps(pipe_s),
        "legacy_rounds_per_s": rps(leg_s),
        # the acceptance ratio: pipelined segmented vs fused steady state
        "pipelined_vs_fused": round(fused_s / max(pipe_s, 1e-9), 3),
        "pipelined_vs_legacy": round(leg_s / max(pipe_s, 1e-9), 3),
        "ckpt_overlap_x": round(leg_ck_s / max(pipe_ck_s, 1e-9), 3),
        "decisions_bitwise": decisions_ok,
        "checkpoint_writer": writer_stats,
    }
    print(f"[runtime_bench] steady: fused {fused_s:.2f}s, pipelined "
          f"{pipe_s:.2f}s ({row['pipelined_vs_fused']:.2f}x of fused), "
          f"legacy {leg_s:.2f}s; ckpt {pipe_ck_s:.2f}s vs legacy "
          f"{leg_ck_s:.2f}s", flush=True)

    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / "BENCH_runtime.json").write_text(json.dumps([row], indent=2))
    return [row]


def summarize(rows) -> list[str]:
    out = ["", "== runtime bench: persistent-cache warm start + "
           "donated/pipelined segments (results/BENCH_runtime.json) =="]
    for r in rows:
        out.append(f"  warm start : {r['cold_compile_s']:.2f}s -> "
                   f"{r['warm_compile_s']:.2f}s "
                   f"({r['warm_speedup_x']:.1f}x, persistent XLA cache)")
        out.append(f"  steady     : fused {r['fused_rounds_per_s']:.0f} "
                   f"rounds/s, pipelined {r['pipelined_rounds_per_s']:.0f} "
                   f"({r['pipelined_vs_fused']:.2f}x of fused), legacy "
                   f"segmented {r['legacy_rounds_per_s']:.0f}")
        out.append(f"  with ckpt  : pipelined {r['pipelined_ckpt_s']:.2f}s "
                   f"vs blocking {r['legacy_ckpt_s']:.2f}s "
                   f"({r['ckpt_overlap_x']:.2f}x)")
        out.append(f"  decisions bitwise across all modes: "
                   f"{r['decisions_bitwise']}")
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--child", default=None, metavar="CACHE_DIR",
                    help="internal: compile once in this process with the "
                         "persistent cache at CACHE_DIR, print JSON timing")
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--full", action="store_true")
    a = ap.parse_args()
    if a.child:
        _child_compile(a.child, a.rounds)
    else:
        for line in summarize(run(quick=not a.full)):
            print(line)

"""Paper Table 3: quality of privacy-preserving 3DG construction.

Clients train locally for one round; the server reconstructs the 3DG from the
uploaded models using (a) functional similarity (Eq. 12: cosine of output
embeddings on a shared Gaussian probe drawn from the validation moments) and
(b) cosine similarity of raw parameter updates (Eq. 11).  Edge-prediction
precision/recall/F1 are measured against the oracle label-distribution 3DG
(eps=0.1, sigma2=0.01), sweeping eps per method as in the paper.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import make_dataset, make_model
from repro.core import graph as G
from repro.fed.client import make_local_trainer

EPS_SWEEP = (0.0, 0.01, 0.05, 0.1, 0.5)


def _locally_trained_models(ds, model, *, local_steps=10, batch=32, lr=0.03,
                            seed=0):
    # E=10 local steps, as in the paper's training setup.
    key = jax.random.PRNGKey(seed)
    params = model.init(key)
    trainer = make_local_trainer(model.loss, local_steps=local_steps,
                                 batch_size=batch)
    n = ds.n_clients
    stacked = trainer(params, jnp.asarray(ds.x), jnp.asarray(ds.y),
                      jnp.asarray(ds.sizes), jnp.float32(lr),
                      jax.random.split(key, n))
    return params, stacked


def _flat_updates(global_params, stacked):
    g = np.concatenate([np.ravel(x) for x in jax.tree_util.tree_leaves(global_params)])
    outs = []
    leaves = jax.tree_util.tree_leaves(stacked)
    n = leaves[0].shape[0]
    for k in range(n):
        fk = np.concatenate([np.ravel(np.asarray(x[k])) for x in leaves])
        outs.append(fk - g)
    return np.stack(outs)


def _probe(ds, n_probe=128, seed=0):
    """Gaussian noise with the validation set's mean/covariance (paper §3.2)."""
    rng = np.random.default_rng(seed)
    xv = ds.x_val.reshape(len(ds.x_val), -1)
    mu = xv.mean(0)
    cov = np.cov(xv.T) + 1e-4 * np.eye(xv.shape[1])
    z = rng.multivariate_normal(mu, cov, n_probe).astype(np.float32)
    return z.reshape(n_probe, *ds.x_val.shape[1:])


def _best_f1(v_pred, r_true):
    best = {"eps": None, "precision": 0.0, "recall": 0.0, "f1": -1.0}
    for eps in EPS_SWEEP:
        r_pred = G.similarity_to_adjacency(G.normalize_01(v_pred), eps=eps,
                                           sigma2=0.01)
        p, r, f1 = G.edge_f1(r_pred, r_true)
        if f1 > best["f1"]:
            best = {"eps": eps, "precision": p, "recall": r, "f1": f1}
    return best


def run(quick: bool = True) -> list[dict]:
    rows = []
    for ds_name in ("cifar", "fashion"):
        ds = make_dataset(ds_name, quick)
        model = make_model(ds_name)
        _, r_true, _ = G.build_3dg(ds.label_dist, eps=0.1, sigma2=0.01)

        gp, stacked = _locally_trained_models(ds, model)
        probe = jnp.asarray(_probe(ds))
        emb = G.probe_embeddings(model.embed, stacked, probe)
        v_func = G.functional_similarity(emb)
        v_cos = G.update_cosine_similarity(_flat_updates(gp, stacked))

        for method, v in (("functional similarity", v_func),
                          ("cosine similarity", v_cos)):
            best = _best_f1(v, r_true)
            rows.append({"table": "table3", "dataset": ds_name,
                         "method": method, **best})
    return rows


def summarize(rows) -> list[str]:
    out = ["", "== Table 3: 3DG reconstruction quality (best eps per method) =="]
    out.append(f"{'dataset':10s} {'method':24s} {'prec':>7s} {'recall':>7s} {'F1':>7s} {'eps':>5s}")
    for r in rows:
        out.append(f"{r['dataset']:10s} {r['method']:24s} {r['precision']:7.4f} "
                   f"{r['recall']:7.4f} {r['f1']:7.4f} {r['eps']!s:>5s}")
    return out


if __name__ == "__main__":
    for line in summarize(run()):
        print(line)

"""Benchmark orchestrator — one section per paper table/figure, plus the
framework-scale extras (solver scaling, kernel micro-bench, roofline report).

  PYTHONPATH=src python -m benchmarks.run             # quick (CPU-budget) pass
  PYTHONPATH=src python -m benchmarks.run --quick     # same, explicit — one
                                                      # pass regenerates EVERY
                                                      # checked-in BENCH_*.json
  PYTHONPATH=src python -m benchmarks.run --full      # paper-scale settings
  PYTHONPATH=src python -m benchmarks.run --only table2,roofline

The quick pass rewrites all BENCH_*.json artifacts (availability, aggregator,
kernels, graph, sampler, shard) — commit them so the perf trajectory and the
CI perf gate (``benchmarks/perf_assert.py``) track the repo, not a laptop.
"""
from __future__ import annotations

import argparse
import csv
import io
import json
import pathlib
import time

RESULTS = pathlib.Path(__file__).resolve().parent / "results"

SECTIONS = ["table2", "fig4", "table3", "table4", "dynamic", "scaling",
            "engine", "shard", "runtime", "telemetry", "availability",
            "aggregator", "robustness", "kernels", "graph", "roofline",
            "variants"]


def _section(name: str, quick: bool):
    if name == "shard":
        # sharded-vs-single run_batch: run_shard re-execs itself with 8
        # forced CPU host devices when this process has fewer (XLA_FLAGS
        # only takes effect before jax initializes)
        from benchmarks import engine_bench as m
        rows = m.run_shard(quick=quick)
        return rows, m.summarize_shard(rows)
    if name == "table2":
        from benchmarks import table2_availability as m
    elif name == "fig4":
        from benchmarks import fig4_fairness as m
    elif name == "table3":
        from benchmarks import table3_graph as m
    elif name == "table4":
        from benchmarks import table4_constructed as m
    elif name == "dynamic":
        from benchmarks import ablation_dynamic as m
    elif name == "scaling":
        from benchmarks import sampler_scaling as m
    elif name == "engine":
        from benchmarks import engine_bench as m
    elif name == "runtime":
        from benchmarks import runtime_bench as m
    elif name == "telemetry":
        from benchmarks import telemetry_bench as m
    elif name == "availability":
        from benchmarks import availability_bench as m
    elif name == "aggregator":
        from benchmarks import aggregator_bench as m
    elif name == "robustness":
        from benchmarks import robustness_bench as m
    elif name == "kernels":
        from benchmarks import kernel_bench as m
    elif name == "graph":
        from benchmarks import graph_pipeline_bench as m
    elif name == "roofline":
        from benchmarks import roofline as m
    elif name == "variants":
        from benchmarks import variants_report as m
    else:
        raise ValueError(name)
    rows = m.run(quick=quick)
    return rows, m.summarize(rows)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale rounds/clients (hours on CPU)")
    ap.add_argument("--quick", action="store_true",
                    help="explicit quick pass (the default): regenerates "
                         "every BENCH_*.json artifact for commit")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of sections")
    args = ap.parse_args(argv)
    if args.quick and args.full:
        ap.error("--quick and --full are mutually exclusive")
    quick = not args.full
    sections = args.only.split(",") if args.only else SECTIONS

    RESULTS.mkdir(parents=True, exist_ok=True)
    all_rows = []
    for name in sections:
        t0 = time.time()
        print(f"\n######## {name} ########", flush=True)
        rows, summary = _section(name, quick)
        all_rows.extend(rows)
        for line in summary:
            print(line, flush=True)
        print(f"[{name}: {time.time() - t0:.1f}s]", flush=True)

    # machine-readable dump: one CSV per table
    by_table: dict[str, list] = {}
    for r in all_rows:
        by_table.setdefault(r.get("table", "misc"), []).append(r)
    for table, rows in by_table.items():
        keys = sorted({k for r in rows for k in r if k not in
                       ("counts", "loss_curve", "curve_rounds")})
        buf = io.StringIO()
        w = csv.DictWriter(buf, fieldnames=keys, extrasaction="ignore")
        w.writeheader()
        for r in rows:
            w.writerow(r)
        (RESULTS / f"{table}.csv").write_text(buf.getvalue())
    (RESULTS / "all_rows.json").write_text(json.dumps(all_rows, indent=1, default=str))
    print(f"\nwrote {len(all_rows)} rows across {len(by_table)} tables to {RESULTS}")


if __name__ == "__main__":
    main()

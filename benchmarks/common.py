"""Shared experiment runner for the paper-reproduction benchmarks.

Every benchmark sweeps (dataset x availability-mode x method) through the
federated round engine and records the History.  Results are cached in
benchmarks/results/paper/*.json so `python -m benchmarks.run` is restartable.
"""
from __future__ import annotations

import json
import pathlib
import time

import numpy as np

from repro.core.availability import make_mode
from repro.core.availability_device import ALL_SCENARIOS, make_process
from repro.core.sampler import FedGSSampler, make_sampler
from repro.fed.engine import FLConfig, FLEngine
from repro.fed.models import logistic_regression, small_cnn
from repro.fed.scan_engine import (
    ScanConfig, ScanEngine, oracle_h, precompute_masks,
)

RESULTS = pathlib.Path(__file__).resolve().parent / "results"
PAPER = RESULTS / "paper"


def pallas_backend_mode() -> str:
    """``"interpret"`` or ``"compiled"`` — how the Pallas kernels execute
    under the live jax backend.  On this CPU container every kernel runs
    under the Pallas interpreter (each grid step round-trips its carried
    output buffers, DESIGN.md §12), so wall-clock rows are correctness-grade
    only; on a real accelerator the Mosaic-lowered kernels time what ships.
    Every BENCH_*.json record carries this field so the perf trajectory
    never mixes the two regimes (DESIGN.md §14)."""
    import jax
    return "interpret" if jax.default_backend() == "cpu" else "compiled"

# per-process caches reused across batched sweep rows: datasets/models per
# (ds_name, quick), oracle graphs per (ds_name, quick), ScanEngine instances
# per (ds_name, quick, config) — jit caches live per engine, so the five
# FedGS(alpha) rows of a dataset share ONE compiled program
_DS_CACHE: dict = {}
_H_CACHE: dict = {}
_ENGINE_CACHE: dict = {}

# (mode name, beta) per dataset — the paper's Table 2 columns
MODES = {
    "synthetic": [("IDL", None), ("LN", 0.5), ("SLN", 0.5), ("LDF", 0.7), ("MDF", 0.7)],
    "cifar": [("IDL", None), ("LN", 0.5), ("SLN", 0.5), ("LDF", 0.7), ("MDF", 0.7)],
    "fashion": [("IDL", None), ("YMF", 0.9), ("YC", 0.9)],
}

# beyond-paper stateful scenario families (core/availability_device.py) —
# the extended availability axis of table2/availability_bench
SCENARIOS = list(ALL_SCENARIOS)          # GE, CLUSTER, DRIFT, DEADLINE

METHODS = ["UniformSample", "MDSample", "Power-of-Choice", "FedProx",
           "FedGS(0.0)", "FedGS(0.5)", "FedGS(1.0)", "FedGS(2.0)", "FedGS(5.0)"]


def make_dataset(name: str, quick: bool):
    if name == "synthetic":
        from repro.data.synthetic import make_synthetic
        return make_synthetic(n_clients=30, alpha=0.5, beta=0.5, seed=0)
    if name == "cifar":
        from repro.data.vision import make_cifar_like
        return make_cifar_like(n_clients=50 if quick else 100,
                               n_total=4000 if quick else 20000, seed=0)
    if name == "fashion":
        from repro.data.vision import make_fashion_like
        return make_fashion_like(n_clients=50 if quick else 100,
                                 n_total=4000 if quick else 20000, seed=0)
    raise ValueError(name)


def make_model(ds_name: str):
    if ds_name == "synthetic":
        return logistic_regression()
    shape = (8, 8, 3) if ds_name == "cifar" else (8, 8, 1)
    return small_cnn(shape=shape)


def fl_config(ds_name: str, quick: bool, seed: int) -> FLConfig:
    if ds_name == "synthetic":
        return FLConfig(rounds=60 if quick else 200, sample_frac=0.2,
                        local_steps=10, batch_size=10, lr=0.1,
                        eval_every=2, seed=seed)
    lr = 0.03 if ds_name == "cifar" else 0.1
    return FLConfig(rounds=40 if quick else 150, sample_frac=0.1,
                    local_steps=10, batch_size=32, lr=lr,
                    eval_every=2, seed=seed)


def make_method(name: str, prox_mu_default: float = 0.01):
    """Returns (sampler, prox_mu)."""
    if name.startswith("FedGS"):
        alpha = float(name.split("(")[1].rstrip(")"))
        return FedGSSampler(alpha=alpha, max_sweeps=32), 0.0
    if name == "UniformSample":
        return make_sampler("uniform"), 0.0
    if name == "MDSample":
        return make_sampler("md"), 0.0
    if name == "Power-of-Choice":
        return make_sampler("poc"), 0.0
    if name == "FedProx":
        return make_sampler("md"), prox_mu_default
    raise ValueError(name)


def scan_method(name: str, prox_mu_default: float = 0.01):
    """Method name -> (scan sampler kind, prox_mu, fedgs alpha).  Every
    Table-2 method — including Power-of-Choice, whose loss probe now runs
    in-scan — batches through ``run_row_batched``."""
    if name.startswith("FedGS"):
        return "fedgs", 0.0, float(name.split("(")[1].rstrip(")"))
    if name == "UniformSample":
        return "uniform", 0.0, 1.0
    if name == "MDSample":
        return "md", 0.0, 1.0
    if name == "Power-of-Choice":
        return "poc", 0.0, 1.0
    if name == "FedProx":
        return "md", prox_mu_default, 1.0
    raise ValueError(f"unknown method {name!r}")


def _scan_row_setup(ds_name: str, method: str, quick: bool, use_masks: bool):
    """The cached (dataset, engine, configs, H, alpha) of one batched sweep
    row — the ONE setup path ``run_row_batched`` (mask cells) and
    ``run_scenario_row_batched`` (process cells) share, so the two benchmark
    paths cannot drift apart.  Engines cache per (dataset, quick, config,
    use_masks); jit caches live per engine, so rows reuse compiled
    programs."""
    sampler_kind, prox, alpha = scan_method(method)
    dk = (ds_name, quick)
    if dk not in _DS_CACHE:
        _DS_CACHE[dk] = (make_dataset(ds_name, quick), make_model(ds_name))
    ds, model = _DS_CACHE[dk]
    fcfg = fl_config(ds_name, quick, 0)
    cfg = ScanConfig(rounds=fcfg.rounds,
                     m=max(1, int(round(fcfg.sample_frac * ds.n_clients))),
                     local_steps=fcfg.local_steps, batch_size=fcfg.batch_size,
                     lr=fcfg.lr, lr_decay=fcfg.lr_decay, prox_mu=prox,
                     eval_every=fcfg.eval_every, sampler=sampler_kind,
                     max_sweeps=32)
    ck = (ds_name, quick, cfg, use_masks)
    if ck not in _ENGINE_CACHE:
        _ENGINE_CACHE[ck] = ScanEngine(ds, model, cfg, use_masks=use_masks)
    eng = _ENGINE_CACHE[ck]
    h = None
    if sampler_kind == "fedgs":
        if dk not in _H_CACHE:
            feats = ds.opt_params if ds_name == "synthetic" else ds.label_dist
            _H_CACHE[dk] = oracle_h(np.asarray(feats))
        h = _H_CACHE[dk]
    return ds, eng, cfg, fcfg, h, alpha


def run_row_batched(ds_name: str, mode_list, method: str, seeds, *,
                    quick: bool = True, force: bool = False) -> list[dict]:
    """One whole Table-2 sweep row — every (availability mode x seed) cell of
    one (dataset, method) — as ONE jit-compiled scan-over-rounds /
    vmap-over-cells program (repro.fed.scan_engine).  Returns one record per
    cell with the run_setting schema subset; cached per row on disk."""
    PAPER.mkdir(parents=True, exist_ok=True)
    tag = "quick" if quick else "full"
    mtag = "-".join(f"{m}{'' if b is None else b}" for m, b in mode_list)
    key = f"scanrow__{ds_name}__{method}__{mtag}__s{'-'.join(map(str, seeds))}__{tag}"
    path = PAPER / (key.replace("(", "").replace(")", "").replace(".", "_") + ".json")
    if path.exists() and not force:
        return json.loads(path.read_text())

    ds, eng, cfg, fcfg, h, alpha = _scan_row_setup(ds_name, method, quick,
                                                   use_masks=True)
    cells, meta = [], []
    for mode_name, beta in mode_list:
        mode = make_mode(mode_name, n_clients=ds.n_clients,
                         data_sizes=ds.sizes, label_sets=ds.label_sets(),
                         num_labels=ds.num_classes, beta=beta, seed=99)
        # host-precomputed masks: every method sees the IDENTICAL
        # availability trace, the Appendix C invariant FLEngine.run implements
        masks = precompute_masks(mode, cfg.rounds, fcfg.avail_seed)
        for seed in seeds:
            cells.append(eng.cell(seed=seed, masks=masks, alpha=alpha, h=h))
            meta.append((mode_name, beta, seed))
    t0 = time.time()
    hists = eng.run_batch(cells)
    wall = round(time.time() - t0, 1)

    recs = _scan_records(meta, hists, ds_name, method, quick, cfg.rounds, wall)
    path.write_text(json.dumps(recs))
    print(f"[bench] {key}: {len(recs)} cells in one batched program ({wall}s)",
          flush=True)
    return recs


def _scan_records(meta, hists, ds_name, method, quick, rounds, wall):
    """Per-cell run_setting-schema records of one batched scan row."""
    from repro.core.fairness import count_variance, count_range, gini
    recs = []
    for (mode_name, beta, seed), hist in zip(meta, hists):
        recs.append({
            "dataset": ds_name, "mode": mode_name, "beta": beta,
            "method": method, "seed": seed, "quick": quick,
            "best_loss": hist.best_loss,
            "final_loss": float(hist.val_loss[hist.rounds[-1]]),
            "best_acc": float(np.nanmax(hist.val_acc)),
            "count_var": count_variance(hist.counts),
            "count_range": count_range(hist.counts),
            "gini": gini(hist.counts),
            "counts": hist.counts.tolist(),
            "rounds": rounds,
            "loss_curve": hist.val_loss[hist.rounds].tolist(),
            "curve_rounds": hist.rounds.tolist(),
            "wall_s": wall,                 # whole batched row, shared
            "engine": "scan",
        })
    return recs


def make_scenario(name: str, ds, *, rounds: int, seed: int = 0):
    """One stateful availability scenario (``SCENARIOS``) for a dataset —
    the scan-engine process counterpart of ``make_mode``.  DRIFT ramps from
    the dataset's MDF table to its LDF table over the run."""
    return make_process(name, n_clients=ds.n_clients, data_sizes=ds.sizes,
                        label_sets=ds.label_sets(), num_labels=ds.num_classes,
                        rounds=rounds, seed=seed)


def run_scenario_row_batched(ds_name: str, scenario_list, method: str, seeds,
                             *, quick: bool = True,
                             force: bool = False) -> list[dict]:
    """The scenario-axis analogue of ``run_row_batched``: every
    (scenario family x seed) cell of one (dataset, method) as ONE batched
    scan program.  Availability is drawn on-device by the stateful
    processes (no finite mask table exists for them), so cells use the
    ``use_masks=False`` engine; heterogeneous families batch through the
    same program (``availability_device.proc_step`` lax.switch)."""
    PAPER.mkdir(parents=True, exist_ok=True)
    tag = "quick" if quick else "full"
    stag = "-".join(scenario_list)
    key = f"scanscen__{ds_name}__{method}__{stag}__s{'-'.join(map(str, seeds))}__{tag}"
    path = PAPER / (key.replace("(", "").replace(")", "").replace(".", "_") + ".json")
    if path.exists() and not force:
        return json.loads(path.read_text())

    ds, eng, cfg, fcfg, h, alpha = _scan_row_setup(ds_name, method, quick,
                                                   use_masks=False)
    cells, meta = [], []
    for scen in scenario_list:
        process = make_scenario(scen, ds, rounds=cfg.rounds, seed=99)
        for seed in seeds:
            cells.append(eng.cell(seed=seed, process=process, alpha=alpha,
                                  h=h, avail_seed=fcfg.avail_seed))
            meta.append((scen, None, seed))
    t0 = time.time()
    hists = eng.run_batch(cells)
    wall = round(time.time() - t0, 1)

    recs = _scan_records(meta, hists, ds_name, method, quick, cfg.rounds, wall)
    path.write_text(json.dumps(recs))
    print(f"[bench] {key}: {len(recs)} scenario cells in one batched program "
          f"({wall}s)", flush=True)
    return recs


def run_setting(ds_name: str, mode_name: str, beta, method: str, *,
                quick: bool = True, seed: int = 0, graph_h=None,
                graph_tag: str = "g", force: bool = False) -> dict:
    """One (dataset, mode, method, seed) cell. Cached on disk."""
    PAPER.mkdir(parents=True, exist_ok=True)
    tag = "quick" if quick else "full"
    key = f"{ds_name}__{mode_name}{'' if beta is None else beta}__{method}__s{seed}__{tag}"
    if graph_h is not None:
        key += f"__{graph_tag}"
    path = PAPER / (key.replace("(", "").replace(")", "").replace(".", "_") + ".json")
    if path.exists() and not force:
        return json.loads(path.read_text())

    ds = make_dataset(ds_name, quick)
    model = make_model(ds_name)
    sampler, prox = make_method(method)
    cfg = fl_config(ds_name, quick, seed)
    cfg.prox_mu = prox
    mode = make_mode(mode_name, n_clients=ds.n_clients, data_sizes=ds.sizes,
                     label_sets=ds.label_sets(), num_labels=ds.num_classes,
                     beta=beta, seed=99)
    eng = FLEngine(ds, model, sampler, mode, cfg)
    if isinstance(sampler, FedGSSampler):
        if graph_h is not None:
            eng.install_graph_from_H(graph_h)
        elif ds_name == "synthetic":
            eng.install_oracle_graph(ds.opt_params)
        else:
            eng.install_oracle_graph()          # label-distribution features
    t0 = time.time()
    hist = eng.run()
    from repro.core.fairness import count_variance, count_range, gini
    rec = {
        "dataset": ds_name, "mode": mode_name, "beta": beta, "method": method,
        "seed": seed, "quick": quick,
        "best_loss": hist.best_loss,
        "final_loss": hist.val_loss[-1],
        "best_acc": float(np.max(hist.val_acc)),
        "count_var": count_variance(eng.counts),
        "count_range": count_range(eng.counts),
        "gini": gini(eng.counts),
        "counts": eng.counts.tolist(),
        "rounds": cfg.rounds,
        "loss_curve": hist.val_loss,
        "curve_rounds": hist.rounds,
        "wall_s": round(time.time() - t0, 1),
    }
    path.write_text(json.dumps(rec))
    print(f"[bench] {key}: best_loss={rec['best_loss']:.4f} "
          f"var={rec['count_var']:.2f} ({rec['wall_s']}s)", flush=True)
    return rec

"""Beyond-paper ablation: static oracle 3DG vs the dynamically refreshed
functional-similarity 3DG (engine.install_dynamic_graph) — the paper's
"dynamically built and polished round by round" future-work note, built."""
from __future__ import annotations

import numpy as np

from benchmarks.common import fl_config, make_dataset, make_model
from repro.core.availability import make_mode
from repro.core.fairness import count_variance
from repro.core.sampler import FedGSSampler
from repro.fed.engine import FLEngine


def _run(ds, graph: str, mode_name, beta, quick, seed=0, refresh=10):
    sampler = FedGSSampler(alpha=1.0, max_sweeps=32)
    cfg = fl_config("synthetic", quick, seed)
    mode = make_mode(mode_name, n_clients=ds.n_clients, data_sizes=ds.sizes,
                     label_sets=ds.label_sets(), num_labels=ds.num_classes,
                     beta=beta, seed=99)
    eng = FLEngine(ds, make_model("synthetic"), sampler, mode, cfg)
    if graph == "oracle":
        eng.install_oracle_graph(ds.opt_params)
    else:
        eng.install_dynamic_graph(refresh_every=refresh)
    hist = eng.run()
    return {"best_loss": hist.best_loss, "count_var": count_variance(eng.counts)}


def run(quick: bool = True) -> list[dict]:
    ds = make_dataset("synthetic", quick)
    rows = []
    for mode_name, beta in (("LN", 0.5), ("MDF", 0.7)):
        for graph in ("oracle", "dynamic"):
            r = _run(ds, graph, mode_name, beta, quick)
            rows.append({"table": "ablation_dynamic", "mode": mode_name,
                         "graph": graph, **r})
    return rows


def summarize(rows) -> list[str]:
    out = ["", "== Ablation: static oracle vs dynamic functional 3DG (Synthetic) =="]
    out.append(f"{'mode':6s} {'graph':8s} {'best_loss':>10s} {'Var(v)':>8s}")
    for r in rows:
        out.append(f"{r['mode']:6s} {r['graph']:8s} {r['best_loss']:10.4f} "
                   f"{r['count_var']:8.2f}")
    return out


if __name__ == "__main__":
    for line in summarize(run()):
        print(line)

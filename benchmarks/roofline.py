"""§Roofline report: reads the dry-run JSON records and formats the
per-(arch x shape x mesh) roofline table (compute / memory / collective terms,
dominant bottleneck, MODEL_FLOPS / HLO_FLOPs usefulness ratio)."""
from __future__ import annotations

import json
import pathlib

DRYRUN = pathlib.Path(__file__).resolve().parent / "results" / "dryrun"

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh: str = "pod1", variant: str = "baseline") -> list[dict]:
    rows = []
    for f in sorted(DRYRUN.glob("*.json")):
        r = json.loads(f.read_text())
        if (r.get("mesh") == mesh and r.get("variant", "baseline") == variant
                and r.get("shape") in SHAPE_ORDER):   # fedsim reported separately
            rows.append(r)
    rows.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])
                             if r["shape"] in SHAPE_ORDER else 9))
    return rows


def run(quick: bool = True) -> list[dict]:
    out = []
    for mesh in ("pod1", "pod2"):
        for r in load(mesh):
            out.append({
                "table": f"roofline_{mesh}", "arch": r["arch"],
                "shape": r["shape"], "ok": r["ok"],
                "compute_s": r.get("compute_term_s"),
                "memory_s": r.get("memory_term_s"),
                "collective_s": r.get("collective_term_s"),
                "dominant": r.get("dominant"),
                "useful_flop_ratio": r.get("useful_flop_ratio"),
                "temp_gb": round(r.get("mem", {}).get("temp_size_in_bytes", 0) / 1e9, 1),
                "error": r.get("error"),
            })
    return out


def summarize(rows) -> list[str]:
    out = []
    for mesh in ("pod1", "pod2"):
        sub = [r for r in rows if r["table"] == f"roofline_{mesh}"]
        if not sub:
            continue
        n_ok = sum(1 for r in sub if r["ok"])
        out.append("")
        out.append(f"== Roofline ({mesh}: "
                   f"{'16x16=256 chips' if mesh == 'pod1' else '2x16x16=512 chips'}; "
                   f"{n_ok}/{len(sub)} lower+compile OK) ==")
        out.append(f"{'arch':24s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s} "
                   f"{'coll_s':>10s} {'dominant':>10s} {'useful':>7s} {'tempGB':>7s}")
        for r in sub:
            if not r["ok"]:
                out.append(f"{r['arch']:24s} {r['shape']:12s} FAILED: {r['error']}")
                continue
            out.append(
                f"{r['arch']:24s} {r['shape']:12s} {r['compute_s']:10.3e} "
                f"{r['memory_s']:10.3e} {r['collective_s']:10.3e} "
                f"{r['dominant']:>10s} {r['useful_flop_ratio']:7.3f} "
                f"{r['temp_gb']:7.1f}")
    return out


if __name__ == "__main__":
    for line in summarize(run()):
        print(line)

"""Roofline report over the KERNEL bench records (DESIGN.md §14).

Reads ``results/BENCH_kernels.json`` (written by ``kernel_bench.py`` — the
``kernels`` section runs before this one in ``benchmarks/run.py``) and
places every kernel x tier row on the platform roofline: achieved GFLOP/s
and GB/s from the measured wall-clock plus the analytic FLOP/bytes models,
classified compute- vs memory-bound by arithmetic intensity against the
platform ridge point (AI* = peak_flops / peak_bw).

This replaced the dormant LM dry-run table: the repo's hot kernels are the
FedGS graph/solver/aggregator Pallas kernels, so the roofline now tracks
the records that ``perf_assert.py`` gates on.  The boundness classification
comes from the MODEL (AI vs ridge), so it is meaningful even for interpret
rows; the achieved-fraction columns are only meaningful for compiled rows
(interpret wall-clock measures the Pallas interpreter, not the kernel).

Platform ceilings (nominal, order-of-magnitude anchors):

  cpu   ~50 GFLOP/s f32, ~20 GB/s   (single-core container envelope)
  tpu   ~197 TFLOP/s bf16/f32-accum, ~1.2 TB/s HBM  (TPU v5p-class)
"""
from __future__ import annotations

import json
import pathlib

BENCH = pathlib.Path(__file__).resolve().parent / "results" / "BENCH_kernels.json"

# (peak GFLOP/s, peak GB/s) per jax.default_backend()
PEAKS = {
    "cpu": (50.0, 20.0),
    "tpu": (197000.0, 1200.0),
    "gpu": (60000.0, 2000.0),
}


def load() -> dict | None:
    if not BENCH.exists():
        return None
    return json.loads(BENCH.read_text())


def run(quick: bool = True) -> list[dict]:
    rec = load()
    if rec is None:
        return []
    peak_f, peak_b = PEAKS.get(rec.get("backend", "cpu"), PEAKS["cpu"])
    ridge = peak_f / peak_b
    out = []
    for r in rec["rows"]:
        out.append({
            "table": "roofline", "kernel": r["kernel"], "tier": r["tier"],
            "ai": r["ai"],
            "gflops": r["gflops"], "gbps": r["gbps"],
            "frac_peak_flops": round(r["gflops"] / peak_f, 4),
            "frac_peak_bw": round(r["gbps"] / peak_b, 4),
            "bound": "compute" if r["ai"] >= ridge else "memory",
            "backend_mode": r["backend_mode"],
            "ridge_ai": round(ridge, 2),
        })
    return out


def summarize(rows) -> list[str]:
    if not rows:
        return ["", "== Roofline (kernel records) ==",
                "  no results/BENCH_kernels.json — run the 'kernels' "
                "section first"]
    mode = rows[0]["backend_mode"]
    ridge = rows[0]["ridge_ai"]
    out = ["", f"== Roofline over BENCH_kernels.json (ridge AI* = {ridge}; "
               f"{mode} mode"
               + ("; achieved fractions are interpreter-bound, model "
                  "classification only)" if mode == "interpret" else ")")
           + " =="]
    out.append(f"{'kernel':18s} {'tier':16s} {'AI':>8s} {'GFLOP/s':>9s} "
               f"{'GB/s':>8s} {'%peakF':>7s} {'%peakB':>7s} {'bound':>8s}")
    for r in rows:
        out.append(f"{r['kernel']:18s} {r['tier']:16s} {r['ai']:8.2f} "
                   f"{r['gflops']:9.2f} {r['gbps']:8.2f} "
                   f"{100 * r['frac_peak_flops']:6.2f}% "
                   f"{100 * r['frac_peak_bw']:6.2f}% {r['bound']:>8s}")
    return out


if __name__ == "__main__":
    for line in summarize(run()):
        print(line)

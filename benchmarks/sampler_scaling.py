"""FedGS solver scaling: ref vs Pallas-tiled ``fedgs_solve`` toward
datacenter client counts.

The ref solver materializes a dense (N, N) swap-gain matrix per local-search
sweep; the pallas backend (``kernels/solver.py`` via ``kernels/ops.py``)
gathers only the (m, N) selected-row panel and reduces it tile by tile, so
the solve keeps scaling past N ≈ 1k.  Each row times one full Eq. 16 solve
(greedy + ``MAX_SWEEPS`` best-swap sweeps, m = N/10) on both backends from
identical (Q, A_t) inputs and asserts the selected sets are BIT-identical —
the same contract ``tests/test_sampler_device.py`` pins at small N.  A
fused-build column times ``fedgs_select`` (Q construction + solve) on the
pallas path.  The run is dumped to ``benchmarks/results/BENCH_sampler.json``
so the solver-scaling trajectory accumulates across PRs (CI runs the quick
pass; the acceptance bar is pallas faster at N >= 4096).

  PYTHONPATH=src python -m benchmarks.sampler_scaling [--full]   # adds 16384
"""
from __future__ import annotations

import json
import pathlib
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.sampler_device import _fedgs_select, _fedgs_solve

RESULTS = pathlib.Path(__file__).resolve().parent / "results"
BENCH_PATH = RESULTS / "BENCH_sampler.json"

MAX_SWEEPS = 32


def _time(fn, reps=2):
    fn()                                  # compile / warm up
    t0 = time.time()
    for _ in range(reps):
        fn()
    return (time.time() - t0) / reps


def _rand_problem(n: int, rng):
    """Random symmetric Q with a count-penalty diagonal + ~70% availability."""
    q = rng.random((n, n)).astype(np.float32)
    q = 0.5 * (q + q.T)
    q -= np.diag(rng.normal(size=n).astype(np.float32))
    avail = rng.random(n) < 0.7
    avail[0] = True
    return jnp.asarray(q), jnp.asarray(avail)


def run(quick: bool = True) -> list[dict]:
    rows = []
    sizes = (256, 1024, 4096) if quick else (256, 1024, 4096, 16384)
    rng = np.random.default_rng(0)
    for n in sizes:
        q, avail = _rand_problem(n, rng)
        m = min(max(2, n // 10), int(np.asarray(avail).sum()))
        reps = 2 if n <= 4096 else 1

        def solve(backend):
            return np.asarray(_fedgs_solve(q, avail, m=m,
                                           max_sweeps=MAX_SWEEPS,
                                           backend=backend))

        s_ref, s_pal = solve("ref"), solve("pallas")
        # the parity contract is load-bearing: CI must FAIL on a large-N
        # tie-break/padding regression, not bury sets_equal=false in the JSON
        assert np.array_equal(s_ref, s_pal), \
            f"ref/pallas selected sets diverge at N={n}"
        t_ref = _time(lambda: solve("ref"), reps=reps)
        t_pal = _time(lambda: solve("pallas"), reps=reps)

        # end-to-end select (what the scan engine / fedsim trace).  Since
        # PR 7 this path is Q-FREE — the solve runs on the factored
        # (H, z, alpha/N) and the fused swap kernel rebuilds Q tiles in
        # registers, so nothing (N, N) beyond H itself ever materializes
        # and the column runs at EVERY tier (the old Q-build kernel's
        # interpret-mode (N, N) output copies forced a skip past N=4096).
        h = jnp.asarray(0.5 * (lambda a: a + a.T)(
            rng.random((n, n)).astype(np.float32)))
        counts = jnp.asarray(rng.integers(0, 8, n), jnp.float32)
        t_sel = _time(lambda: np.asarray(_fedgs_select(
            h, counts, avail, jnp.float32(1.0), m=m,
            max_sweeps=MAX_SWEEPS, backend="pallas")), reps=reps)

        rows.append({"table": "sampler_scaling", "n_clients": n, "m": m,
                     "max_sweeps": MAX_SWEEPS,
                     "ref_s": round(t_ref, 4), "pallas_s": round(t_pal, 4),
                     "select_pallas_s": round(t_sel, 4)
                     if np.isfinite(t_sel) else None,
                     "speedup": round(t_ref / max(t_pal, 1e-9), 2),
                     "sets_equal": bool(np.array_equal(s_ref, s_pal))})
        print(f"[sampler_scaling] N={n:6d} m={m:5d}: ref {t_ref:7.3f}s  "
              f"pallas {t_pal:7.3f}s  ({rows[-1]['speedup']:5.2f}x, "
              f"sets_equal={rows[-1]['sets_equal']})", flush=True)

    RESULTS.mkdir(parents=True, exist_ok=True)
    from benchmarks.common import pallas_backend_mode
    record = {"bench": "sampler", "backend": jax.default_backend(),
              "backend_mode": pallas_backend_mode(),
              "pallas_interpret": jax.default_backend() == "cpu",
              "max_sweeps": MAX_SWEEPS, "rows": rows}
    BENCH_PATH.write_text(json.dumps(record, indent=1))
    return rows


def summarize(rows) -> list[str]:
    out = ["", "== FedGS Eq. 16 solver scaling: ref vs pallas-tiled =="]
    out.append(f"{'N':>7s} {'M':>6s} {'ref (s)':>9s} {'pallas (s)':>11s} "
               f"{'speedup':>8s} {'select+build (s)':>17s} {'bit-equal':>10s}")
    for r in rows:
        sel = r["select_pallas_s"]
        out.append(f"{r['n_clients']:7d} {r['m']:6d} {r['ref_s']:9.3f} "
                   f"{r['pallas_s']:11.3f} {r['speedup']:7.2f}x "
                   f"{sel if sel is not None else '—':>17} "
                   f"{str(r['sets_equal']):>10s}")
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="adds the N=16384 row (minutes on CPU)")
    args = ap.parse_args()
    for line in summarize(run(quick=not args.full)):
        print(line)

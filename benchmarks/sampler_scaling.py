"""Beyond-paper: FedGS solver scaling — wall time of the jit'd greedy+swap
QUBO local search and of the 3DG pipeline (similarity + Floyd-Warshall) as
the client count N grows toward datacenter scale, plus the amortized
per-cell cost when a whole sweep row of solves runs as one vmapped program
(the scan-engine formulation, repro.fed.scan_engine)."""
from __future__ import annotations

import time
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.graph import build_3dg
from repro.core.sampler import _fedgs_solve, fedgs_solve

BATCH = 8          # cells in the vmapped solve (seeds x modes slice)


def _time(fn, reps=3):
    fn()                                  # compile / warm up
    t0 = time.time()
    for _ in range(reps):
        fn()
    return (time.time() - t0) / reps


def run(quick: bool = True) -> list[dict]:
    rows = []
    sizes = (64, 128, 256) if quick else (64, 128, 256, 512, 1024)
    rng = np.random.default_rng(0)
    for n in sizes:
        feats = rng.random((n, 16)).astype(np.float32)
        t_graph = _time(lambda: build_3dg(feats, eps=0.1, sigma2=0.01), reps=1)
        q = rng.random((n, n)).astype(np.float32)
        q = 0.5 * (q + q.T)
        qj = jnp.asarray(q)
        avail = jnp.asarray(rng.random(n) < 0.7)
        m = max(2, n // 10)
        t_solve = _time(lambda: np.asarray(
            _fedgs_solve(qj, avail, m=m, max_sweeps=32)))

        # whole sweep row at once: vmap the pure solver over BATCH cells
        qb = jnp.asarray(0.5 * (lambda a: a + a.transpose(0, 2, 1))(
            rng.random((BATCH, n, n)).astype(np.float32)))
        ab = jnp.asarray(rng.random((BATCH, n)) < 0.7)
        solve_b = jax.jit(jax.vmap(
            partial(fedgs_solve, m=m, max_sweeps=32)))
        t_batched = _time(lambda: np.asarray(solve_b(qb, ab))) / BATCH
        rows.append({"table": "sampler_scaling", "n_clients": n, "m": m,
                     "graph_build_s": round(t_graph, 4),
                     "solve_s": round(t_solve, 4),
                     "solve_batched_percell_s": round(t_batched, 4),
                     "batch": BATCH})
    return rows


def summarize(rows) -> list[str]:
    out = ["", "== FedGS solver / 3DG scaling =="]
    out.append(f"{'N':>6s} {'M':>5s} {'3DG build (s)':>14s} {'solve (s)':>10s} "
               f"{'vmap x{}/cell (s)'.format(rows[0]['batch'] if rows else 0):>18s}")
    for r in rows:
        out.append(f"{r['n_clients']:6d} {r['m']:5d} {r['graph_build_s']:14.4f} "
                   f"{r['solve_s']:10.4f} {r['solve_batched_percell_s']:18.4f}")
    return out


if __name__ == "__main__":
    for line in summarize(run()):
        print(line)

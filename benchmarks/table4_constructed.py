"""Paper Table 4 (Appendix B): FedGS running on the CONSTRUCTED 3DG
(functional / cosine similarity of uploaded models) vs the oracle 3DG."""
from __future__ import annotations

import numpy as np

from benchmarks.common import make_dataset, make_model, run_setting
from benchmarks.table3_graph import (_flat_updates, _locally_trained_models,
                                     _probe)
from repro.core import graph as G

SETTINGS = {
    "cifar": [("IDL", None), ("LN", 0.5), ("MDF", 0.7)],
    "fashion": [("IDL", None), ("YMF", 0.9), ("YC", 0.9)],
}


def _constructed_graphs(ds_name: str, quick: bool):
    import jax.numpy as jnp
    ds = make_dataset(ds_name, quick)
    model = make_model(ds_name)
    gp, stacked = _locally_trained_models(ds, model)
    emb = G.probe_embeddings(model.embed, stacked, jnp.asarray(_probe(ds)))
    out = {}
    for name, v in (("func", G.functional_similarity(emb)),
                    ("cos", G.update_cosine_similarity(_flat_updates(gp, stacked)))):
        r = G.similarity_to_adjacency(G.normalize_01(v), eps=0.1, sigma2=0.01)
        out[name] = G.shortest_paths(r)
    return out


def run(quick: bool = True) -> list[dict]:
    rows = []
    for ds_name, modes in SETTINGS.items():
        graphs = _constructed_graphs(ds_name, quick)
        for mode, beta in modes:
            oracle = run_setting(ds_name, mode, beta, "FedGS(1.0)", quick=quick)
            rows.append({"table": "table4", "dataset": ds_name, "mode": mode,
                         "graph": "oracle", "best_loss": oracle["best_loss"]})
            for gname, h in graphs.items():
                rec = run_setting(ds_name, mode, beta, "FedGS(1.0)",
                                  quick=quick, graph_h=h, graph_tag=gname)
                rows.append({"table": "table4", "dataset": ds_name,
                             "mode": mode, "graph": gname,
                             "best_loss": rec["best_loss"]})
    return rows


def summarize(rows) -> list[str]:
    out = ["", "== Table 4: FedGS on oracle vs constructed 3DG (best loss) =="]
    out.append(f"{'dataset':10s} {'mode':6s} {'oracle':>8s} {'func':>8s} {'cos':>8s}")
    keys = sorted({(r["dataset"], r["mode"]) for r in rows})
    for ds, mode in keys:
        vals = {r["graph"]: r["best_loss"] for r in rows
                if r["dataset"] == ds and r["mode"] == mode}
        out.append(f"{ds:10s} {mode:6s} {vals.get('oracle', float('nan')):8.4f} "
                   f"{vals.get('func', float('nan')):8.4f} "
                   f"{vals.get('cos', float('nan')):8.4f}")
    return out


if __name__ == "__main__":
    for line in summarize(run()):
        print(line)

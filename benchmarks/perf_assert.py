"""CI perf gate: assert the committed kernel-bench records still show the
expected Pallas winners (DESIGN.md §14) AND the runtime-layer records still
show the warm-start / pipelining wins (DESIGN.md §15).

Loads ``results/BENCH_kernels.json`` (checked in — see ``.gitignore``'s
``!benchmarks/results/BENCH_*.json`` carve-out) and asserts every row the
bench marked ``winner_expected`` beats the jnp reference by >= 1.0x with a
20% run-to-run tolerance (>= 0.8x).  Which rows carry the flag is decided
at bench time from the recorded ``backend_mode``:

  * ``fedgs_select`` at production tier (N >= 1024) — enforced in BOTH
    modes: its win is algorithmic (the Q-free factored solve vs the ref's
    (N, N) Q materialization), so it must hold even under the Pallas
    interpreter on this CPU container.
  * every other kernel at production tier — enforced only on ``compiled``
    records (real accelerator): interpret wall-clock times the interpreter's
    carried-buffer copies, not the kernel (DESIGN.md §12).

Also asserts correctness invariants the records carry: ``fedgs_select``
rows are bit-identical to the ref, every ``max_err`` is finite and small.

``results/BENCH_runtime.json`` (benchmarks/runtime_bench.py) is gated with
run-to-run tolerance below the committed-record acceptance bars:

  * persistent-cache warm start: >= 3x compile-time reduction (committed
    record shows >= 5x);
  * pipelined segmented run_batch: >= 0.75x of the fused program's
    steady-state rounds/sec (committed record shows >= 0.9x);
  * ``decisions_bitwise`` must be true — the runtime layer may not change
    a single sampled set (DESIGN.md §13).

``results/BENCH_telemetry.json`` (benchmarks/telemetry_bench.py) gates
the observability layer (DESIGN.md §17): in-scan health channel <= 5%
steady-state overhead, ``bitwise_noninterference`` true (telemetry-on
history + checkpoints identical to telemetry-off, assumption log #24),
and JSONL sink throughput above the floor.

  PYTHONPATH=src python -m benchmarks.perf_assert            # exit 1 on fail
"""
from __future__ import annotations

import json
import pathlib
import sys

BENCH = pathlib.Path(__file__).resolve().parent / "results" / "BENCH_kernels.json"
BENCH_RUNTIME = BENCH.parent / "BENCH_runtime.json"
BENCH_ROBUST = BENCH.parent / "BENCH_robustness.json"
BENCH_TELEMETRY = BENCH.parent / "BENCH_telemetry.json"

TOLERANCE = 0.8        # >= 1.0x winner with 20% timing jitter allowance
MAX_ERR = 1e-4         # parity ceiling for non-bit-exact rows
WARM_SPEEDUP_MIN = 3.0       # committed record: >= 5x
PIPELINE_RATIO_MIN = 0.75    # committed record: >= 0.9x of fused
TELEMETRY_OVERHEAD_MAX = 5.0     # % steady-state, DESIGN.md §17
JSONL_EVENTS_PER_S_MIN = 10_000  # sink must absorb per-round emission


def check(record: dict) -> tuple[list[str], list[str]]:
    """-> (failures, report lines)."""
    fails, lines = [], []
    rows = record.get("rows", [])
    mode = record.get("backend_mode", "?")
    enforced = [r for r in rows if r.get("winner_expected")]
    lines.append(f"perf gate: {len(rows)} rows ({mode} mode), "
                 f"{len(enforced)} enforced winners, tol {TOLERANCE}x")
    if mode == "interpret":
        lines.append("  compiled-only winners skipped on this backend "
                     "(interpret wall-clock times the interpreter)")
    for r in enforced:
        ok = r["speedup"] >= TOLERANCE
        lines.append(f"  {'ok  ' if ok else 'FAIL'} {r['kernel']:18s} "
                     f"{r['tier']:16s} {r['speedup']:.2f}x")
        if not ok:
            fails.append(f"{r['kernel']} {r['tier']}: speedup "
                         f"{r['speedup']:.2f}x < {TOLERANCE}x")
    for r in rows:
        if r["kernel"] == "fedgs_select" and not r.get("selected_bit_equal"):
            fails.append(f"fedgs_select {r['tier']}: selected sets not "
                         f"bit-identical to ref")
        if not (r["max_err"] <= MAX_ERR):
            fails.append(f"{r['kernel']} {r['tier']}: max_err "
                         f"{r['max_err']:.2e} > {MAX_ERR}")
    return fails, lines


def check_runtime(rows: list) -> tuple[list[str], list[str]]:
    """Gate the runtime-layer record (DESIGN.md §15)."""
    fails, lines = [], []
    for r in rows:
        warm = r.get("warm_speedup_x", 0.0)
        ratio = r.get("pipelined_vs_fused", 0.0)
        lines.append(f"runtime gate: warm start {warm:.1f}x "
                     f"(floor {WARM_SPEEDUP_MIN}x), pipelined/fused "
                     f"{ratio:.2f}x (floor {PIPELINE_RATIO_MIN}x), "
                     f"decisions_bitwise={r.get('decisions_bitwise')}")
        if warm < WARM_SPEEDUP_MIN:
            fails.append(f"runtime: warm-start compile speedup {warm:.1f}x "
                         f"< {WARM_SPEEDUP_MIN}x (persistent cache broken?)")
        if ratio < PIPELINE_RATIO_MIN:
            fails.append(f"runtime: pipelined segmented run at {ratio:.2f}x "
                         f"of fused steady state < {PIPELINE_RATIO_MIN}x")
        if not r.get("decisions_bitwise"):
            fails.append("runtime: decisions not bitwise across runtime "
                         "modes — the pipeline changed results (DESIGN §13)")
    return fails, lines


def check_robustness(record: dict) -> tuple[list[str], list[str]]:
    """Gate the robustness record (DESIGN.md §16): Krum ref|pallas selected
    sets bit-identical, and the defenses actually defend — under 20%
    sign-flip, krum and trimmed-mean must beat fedavg's final val-acc."""
    fails, lines = [], []
    lines.append(f"robustness gate: krum_parity_ok="
                 f"{record.get('krum_parity_ok')}, "
                 f"robust_beats_fedavg_signflip="
                 f"{record.get('robust_beats_fedavg_signflip')}")
    if not record.get("krum_parity_ok"):
        fails.append("robustness: krum ref|pallas selected sets diverge "
                     "(kernel panel regression?)")
    if not record.get("robust_beats_fedavg_signflip"):
        fails.append("robustness: krum/trimmed-mean no longer beat fedavg "
                     "under 20% sign-flip — a defense regressed")
    return fails, lines


def check_telemetry(rows: list) -> tuple[list[str], list[str]]:
    """Gate the telemetry record (DESIGN.md §17): the in-scan health
    channel must stay <= 5% steady-state overhead, must be BITWISE
    non-interfering (history + checkpoints identical on-vs-off,
    assumption log #24), and the JSONL sink must sustain well above
    engine round rates."""
    fails, lines = [], []
    for r in rows:
        ov = r.get("overhead_pct", 1e9)
        ev = r.get("jsonl_events_per_s", 0.0)
        lines.append(f"telemetry gate: overhead {ov:+.1f}% "
                     f"(max {TELEMETRY_OVERHEAD_MAX}%), bitwise="
                     f"{r.get('bitwise_noninterference')}, sink "
                     f"{ev:,.0f} ev/s (floor {JSONL_EVENTS_PER_S_MIN:,})")
        if ov > TELEMETRY_OVERHEAD_MAX:
            fails.append(f"telemetry: {ov:+.1f}% steady-state overhead > "
                         f"{TELEMETRY_OVERHEAD_MAX}% — the health channel "
                         f"is no longer riding the existing transfer")
        if not r.get("bitwise_noninterference"):
            fails.append("telemetry: history/checkpoints differ on-vs-off "
                         "— the channel leaked into results (assumption "
                         "log #24 broken)")
        if ev < JSONL_EVENTS_PER_S_MIN:
            fails.append(f"telemetry: JSONL sink {ev:,.0f} events/s < "
                         f"{JSONL_EVENTS_PER_S_MIN:,}")
    return fails, lines


def main(argv=None) -> int:
    if not BENCH.exists():
        print(f"perf gate: {BENCH} missing — run "
              f"`python -m benchmarks.run --only kernels` and commit it")
        return 1
    fails, lines = check(json.loads(BENCH.read_text()))
    if not BENCH_RUNTIME.exists():
        fails.append(f"{BENCH_RUNTIME.name} missing — run "
                     f"`python -m benchmarks.run --only runtime` and commit")
    else:
        rfails, rlines = check_runtime(json.loads(BENCH_RUNTIME.read_text()))
        fails.extend(rfails)
        lines.extend(rlines)
    if not BENCH_ROBUST.exists():
        fails.append(f"{BENCH_ROBUST.name} missing — run "
                     f"`python -m benchmarks.run --only robustness` and "
                     f"commit")
    else:
        bfails, blines = check_robustness(
            json.loads(BENCH_ROBUST.read_text()))
        fails.extend(bfails)
        lines.extend(blines)
    if not BENCH_TELEMETRY.exists():
        fails.append(f"{BENCH_TELEMETRY.name} missing — run "
                     f"`python -m benchmarks.run --only telemetry` and "
                     f"commit")
    else:
        tfails, tlines = check_telemetry(
            json.loads(BENCH_TELEMETRY.read_text()))
        fails.extend(tfails)
        lines.extend(tlines)
    for ln in lines:
        print(ln)
    if fails:
        print("\nPERF GATE FAILED:")
        for f in fails:
            print(f"  - {f}")
        return 1
    print("perf gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())

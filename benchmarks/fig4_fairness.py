"""Paper Fig. 4: final client sampling counts (fairness) on
FashionMNIST-YMF-0.9 and CIFAR10-LN-0.5 — FedGS should yield near-uniform
counts while baselines skew toward highly-available clients."""
from __future__ import annotations

import numpy as np

from benchmarks.common import run_setting

SETTINGS = [("fashion", "YMF", 0.9), ("cifar", "LN", 0.5)]
METHODS = ["UniformSample", "MDSample", "Power-of-Choice", "FedGS(1.0)"]


def run(quick: bool = True) -> list[dict]:
    rows = []
    for ds, mode, beta in SETTINGS:
        for method in METHODS:
            rec = run_setting(ds, mode, beta, method, quick=quick)
            counts = np.asarray(rec["counts"])
            rows.append({
                "table": "fig4", "dataset": ds, "mode": f"{mode}-{beta}",
                "method": method,
                "count_var": rec["count_var"],
                "count_range": rec["count_range"],
                "gini": rec["gini"],
                "count_min": int(counts.min()),
                "count_max": int(counts.max()),
            })
    return rows


def summarize(rows) -> list[str]:
    out = ["", "== Fig. 4: client sampling-count fairness =="]
    out.append(f"{'setting':22s} {'method':18s} {'Var(v)':>8s} {'range':>6s} {'gini':>6s}")
    for r in rows:
        out.append(f"{r['dataset'] + '-' + r['mode']:22s} {r['method']:18s} "
                   f"{r['count_var']:8.2f} {r['count_range']:6d} {r['gini']:6.3f}")
    return out


if __name__ == "__main__":
    for line in summarize(run()):
        print(line)

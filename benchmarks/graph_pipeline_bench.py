"""Graph-pipeline bench: ref-vs-pallas ``build_h`` (the unified 3DG subsystem,
core/graph_device.py) at datacenter client counts.

On CPU the pallas backend runs in interpret mode — correctness-grade timing
only (the BlockSpec tiling targets TPU); the ref column is the compiled jnp
pipeline and is the CPU-meaningful number.  Since PR 7 the ``pallas`` column
IS the fused megakernel pipeline (``kernels/ops.build_3dg_fused``: one grid
for similarity -> min-max -> adjacency, feeding the blocked Floyd–Warshall
at the shared padded size); the ``staged_ms`` column keeps the old staged
pallas stages (separate similarity / adjacency / FW calls with HBM
round-trips between them) so the fusion win is measurable per tier.  Each
row records wall-clock per variant per N plus the cross-backend max abs
error, and the whole run is dumped to
``benchmarks/results/BENCH_graph_pipeline.json`` so the perf trajectory of
the graph path accumulates across PRs.

  PYTHONPATH=src python -m benchmarks.graph_pipeline_bench [--full]
"""
from __future__ import annotations

import json
import pathlib
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.graph_device import GraphConfig, build_3dg, build_h, \
    cap_and_normalize

RESULTS = pathlib.Path(__file__).resolve().parent / "results"


def _staged_h(u, cfg):
    """build_h semantics via the STAGED pallas stages (pre-PR7 routing)."""
    _, _, h = build_3dg(u, cfg, backend="pallas")
    return cap_and_normalize(h, scale=cfg.finite_cap_scale,
                             normalize=cfg.normalize)
BENCH_PATH = RESULTS / "BENCH_graph_pipeline.json"

NS_QUICK = (128, 512, 1024)
NS_FULL = (128, 512, 1024, 4096)     # 4096: O(N³) FW — minutes on CPU


def _time(fn, reps: int = 1):
    out = jax.block_until_ready(fn())        # compile + warm
    t0 = time.time()
    for _ in range(reps):
        out = jax.block_until_ready(fn())
    return (time.time() - t0) / reps, out


def run(quick: bool = True) -> list[dict]:
    rng = np.random.default_rng(0)
    cfg = GraphConfig()
    rows = []
    for n in NS_QUICK if quick else NS_FULL:
        d = 64
        feats = jnp.asarray(rng.random((n, d)) + 0.1, jnp.float32)
        fns = {b: jax.jit(lambda u, b=b: build_h(u, cfg, backend=b))
               for b in ("ref", "pallas")}
        # the pre-fusion staged pallas pipeline, kept as the parity oracle
        # (kernels/ops.build_3dg) — times the HBM round-trips fusion removed
        fns["staged"] = jax.jit(lambda u: _staged_h(u, cfg))
        outs = {}
        row = {"table": "graph_pipeline", "n": n, "d": d}
        for backend, fn in fns.items():
            s, outs[backend] = _time(lambda fn=fn: fn(feats))
            row[f"{backend}_ms"] = round(s * 1e3, 2)
        row["max_err"] = float(np.max(np.abs(
            np.asarray(outs["ref"]) - np.asarray(outs["pallas"]))))
        row["fused_vs_staged"] = round(row["staged_ms"] /
                                       max(row["pallas_ms"], 1e-9), 2)
        rows.append(row)
        print(f"[graph_pipeline] N={n}: ref {row['ref_ms']}ms  "
              f"fused {row['pallas_ms']}ms  staged {row['staged_ms']}ms  "
              f"err {row['max_err']:.2e}", flush=True)

    RESULTS.mkdir(parents=True, exist_ok=True)
    from benchmarks.common import pallas_backend_mode
    record = {"bench": "graph_pipeline",
              "backend": jax.default_backend(),
              "backend_mode": pallas_backend_mode(),
              "pallas_interpret": jax.default_backend() == "cpu",
              "rows": rows}
    BENCH_PATH.write_text(json.dumps(record, indent=1))
    return rows


def summarize(rows) -> list[str]:
    out = ["", "== build_h ref vs pallas-fused vs pallas-staged "
               "(wall-clock per N) =="]
    out.append(f"{'N':>6s} {'ref ms':>10s} {'fused ms':>10s} "
               f"{'staged ms':>10s} {'fused/stg':>9s} {'max err':>10s}")
    for r in rows:
        out.append(f"{r['n']:6d} {r['ref_ms']:10.2f} {r['pallas_ms']:10.2f} "
                   f"{r['staged_ms']:10.2f} {r['fused_vs_staged']:9.2f} "
                   f"{r['max_err']:10.2e}")
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="include N=4096 (minutes of CPU Floyd–Warshall)")
    args = ap.parse_args()
    for line in summarize(run(quick=not args.full)):
        print(line)

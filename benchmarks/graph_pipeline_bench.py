"""Graph-pipeline bench: ref-vs-pallas ``build_h`` (the unified 3DG subsystem,
core/graph_device.py) at datacenter client counts.

On CPU the pallas backend runs in interpret mode — correctness-grade timing
only (the BlockSpec tiling targets TPU); the ref column is the compiled jnp
pipeline and is the CPU-meaningful number.  Each row records wall-clock per
backend per N plus the cross-backend max abs error, and the whole run is
dumped to ``benchmarks/results/BENCH_graph_pipeline.json`` so the perf
trajectory of the graph path accumulates across PRs.

  PYTHONPATH=src python -m benchmarks.graph_pipeline_bench [--full]
"""
from __future__ import annotations

import json
import pathlib
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.graph_device import GraphConfig, build_h

RESULTS = pathlib.Path(__file__).resolve().parent / "results"
BENCH_PATH = RESULTS / "BENCH_graph_pipeline.json"

NS_QUICK = (128, 512, 1024)
NS_FULL = (128, 512, 1024, 4096)     # 4096: O(N³) FW — minutes on CPU


def _time(fn, reps: int = 1):
    out = jax.block_until_ready(fn())        # compile + warm
    t0 = time.time()
    for _ in range(reps):
        out = jax.block_until_ready(fn())
    return (time.time() - t0) / reps, out


def run(quick: bool = True) -> list[dict]:
    rng = np.random.default_rng(0)
    cfg = GraphConfig()
    rows = []
    for n in NS_QUICK if quick else NS_FULL:
        d = 64
        feats = jnp.asarray(rng.random((n, d)) + 0.1, jnp.float32)
        fns = {b: jax.jit(lambda u, b=b: build_h(u, cfg, backend=b))
               for b in ("ref", "pallas")}
        outs = {}
        row = {"table": "graph_pipeline", "n": n, "d": d}
        for backend, fn in fns.items():
            s, outs[backend] = _time(lambda fn=fn: fn(feats))
            row[f"{backend}_ms"] = round(s * 1e3, 2)
        row["max_err"] = float(np.max(np.abs(
            np.asarray(outs["ref"]) - np.asarray(outs["pallas"]))))
        rows.append(row)
        print(f"[graph_pipeline] N={n}: ref {row['ref_ms']}ms  "
              f"pallas {row['pallas_ms']}ms  err {row['max_err']:.2e}",
              flush=True)

    RESULTS.mkdir(parents=True, exist_ok=True)
    record = {"bench": "graph_pipeline",
              "backend": jax.default_backend(),
              "pallas_interpret": jax.default_backend() == "cpu",
              "rows": rows}
    BENCH_PATH.write_text(json.dumps(record, indent=1))
    return rows


def summarize(rows) -> list[str]:
    out = ["", "== build_h ref vs pallas (wall-clock per backend per N) =="]
    out.append(f"{'N':>6s} {'ref ms':>10s} {'pallas ms':>10s} {'max err':>10s}")
    for r in rows:
        out.append(f"{r['n']:6d} {r['ref_ms']:10.2f} {r['pallas_ms']:10.2f} "
                   f"{r['max_err']:10.2e}")
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="include N=4096 (minutes of CPU Floyd–Warshall)")
    args = ap.parse_args()
    for line in summarize(run(quick=not args.full)):
        print(line)

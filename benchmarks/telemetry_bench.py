"""Telemetry-layer bench (DESIGN.md §17): in-scan health-channel overhead
and bitwise noninterference, JSONL sink throughput.

Three claims, one ``results/BENCH_telemetry.json`` artifact (the CI perf
gate ``benchmarks/perf_assert.py`` enforces the first two):

overhead
    A mixed scenario x sampler x aggregator x fault ``run_batch`` with
    ``ScanConfig.telemetry=True`` vs the identical batch with telemetry
    off, steady state (second call — first call pays the compiles), best
    of 3 to absorb CPU-runner jitter.  Acceptance: <= 5% overhead — the
    metrics are pure in-scan reductions riding the trajectory transfer,
    not a second pass.

bitwise noninterference (assumption log #24)
    The telemetry-on run's ``ScanHistory`` fields AND its checkpoint
    bytes must be IDENTICAL to the telemetry-off run's — the health
    channel is output-only (no carry state, stripped before checkpoint).

sink throughput
    ``JSONLMetricsSink`` events/s and MB/s for round-sized payloads —
    the background-writer pattern must absorb per-round emission at far
    above engine round rates.

Artifacts for eyeballing land in ``results/telemetry/``: the run's
``metrics.jsonl`` and the host-span ``trace.json`` (chrome://tracing).

  PYTHONPATH=src python -m benchmarks.telemetry_bench
"""
from __future__ import annotations

import hashlib
import json
import os
import pathlib
import time

import numpy as np

RESULTS = pathlib.Path(__file__).resolve().parent / "results"
N_CLIENTS = 50
B_CELLS = 8


def _mk(rounds, telemetry: bool, **kw):
    """(engine, cells): the runtime_bench mixed-cell shape plus sampler
    variety and a fault cell, so every telemetry source is live —
    memory-panel staleness, fault corruption, FedGS dispersion."""
    from repro.core.availability_device import make_process
    from repro.core.sampler_device import make_sampler_process
    from repro.data.synthetic import make_synthetic
    from repro.fed.aggregator_device import make_aggregator_process
    from repro.fed.faults_device import make_fault_process
    from repro.fed.models import logistic_regression
    from repro.fed.scan_engine import ScanConfig, ScanEngine, oracle_h

    ds = make_synthetic(n_clients=N_CLIENTS, alpha=0.5, beta=0.5, seed=0)
    cfg = ScanConfig(rounds=rounds, m=5, local_steps=5, batch_size=8,
                     eval_every=5, sampler="uniform", aggregator="memory",
                     telemetry=telemetry, **kw)
    eng = ScanEngine(ds, logistic_regression(), cfg)
    h = oracle_h(ds.opt_params)
    scen = ("GE", "CLUSTER", "DRIFT", "DEADLINE")
    aggs = ("memory", "fedavgm", "fedadam", "fedavg")
    samplers = ("fedgs", "uniform", "md", "poc")
    cells = [eng.cell(
        seed=i, avail_seed=40 + i, h=h,
        process=make_process(scen[i % 4], n_clients=ds.n_clients,
                             data_sizes=ds.sizes, label_sets=ds.label_sets(),
                             num_labels=ds.num_classes, rounds=rounds,
                             seed=9 + i),
        sampler_process=make_sampler_process(samplers[i % 4], alpha=1.0),
        aggregator_process=make_aggregator_process(aggs[i % 4]),
        fault_process=make_fault_process("sign_flip", ds.n_clients,
                                         frac=0.2) if i == 3 else None)
        for i in range(B_CELLS)]
    return eng, cells


def _steady(eng, cells, reps: int = 3, **kw):
    """Best-of-``reps`` second-call wall-clock (first call compiles)."""
    hists = eng.run_batch(cells, **kw)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        hists = eng.run_batch(cells, **kw)
        best = min(best, time.perf_counter() - t0)
    return best, hists


def _md5(path: str) -> str:
    with open(path, "rb") as f:
        return hashlib.md5(f.read()).hexdigest()


def _history_bitwise(ha, hb) -> bool:
    ok = True
    for f in ("val_loss", "val_acc", "count_var", "gini", "sel", "valid",
              "counts"):
        ok &= bool(np.array_equal(np.asarray(getattr(ha, f)),
                                  np.asarray(getattr(hb, f)),
                                  equal_nan=True))
    return ok


def _sink_throughput(n_events: int = 20_000) -> tuple[float, float]:
    """(events/s, MB/s) for round-shaped JSONL payloads."""
    import tempfile

    from repro.obs import JSONLMetricsSink
    payload = {"cell": 3, "t": 17, "val_loss": 0.123, "val_acc": 0.9,
               "metrics": {"update_norm_mean": 0.5, "avail_rate": 0.8,
                           "staleness_hist": list(range(8))}}
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "m.jsonl")
        t0 = time.perf_counter()
        with JSONLMetricsSink(path, run="bench") as sink:
            for _ in range(n_events):
                sink.emit("round", payload)
            sink.flush()
            wall = time.perf_counter() - t0
            nbytes = sink.stats()["bytes"]
    return n_events / max(wall, 1e-9), nbytes / 1e6 / max(wall, 1e-9)


def run(quick: bool = True) -> list[dict]:
    import tempfile

    import jax

    from benchmarks.common import pallas_backend_mode
    from repro.fed.telemetry import Tracer
    from repro.obs import JSONLMetricsSink, read_metrics_jsonl

    rounds = 40 if quick else 120
    seg = 8

    # ------------- steady state: telemetry off vs on (fused program) ------
    eng_off, cells_off = _mk(rounds, telemetry=False)
    off_s, off_h = _steady(eng_off, cells_off)
    eng_on, cells_on = _mk(rounds, telemetry=True)
    on_s, on_h = _steady(eng_on, cells_on)
    overhead_pct = (on_s / max(off_s, 1e-9) - 1.0) * 100.0
    print(f"[telemetry_bench] steady: off {off_s:.2f}s, on {on_s:.2f}s "
          f"({overhead_pct:+.1f}%)", flush=True)

    # ------------- bitwise noninterference incl. checkpoints --------------
    hist_ok = all(_history_bitwise(a, b) for a, b in zip(off_h, on_h))
    with tempfile.TemporaryDirectory() as td:
        ck_off, ck_on = os.path.join(td, "off"), os.path.join(td, "on")
        eng_off.run_batch(cells_off, ckpt_path=ck_off, ckpt_every=seg)
        eng_on.run_batch(cells_on, ckpt_path=ck_on, ckpt_every=seg)
        ckpt_ok = _md5(ck_off + ".npz") == _md5(ck_on + ".npz")
    bitwise = bool(hist_ok and ckpt_ok)
    print(f"[telemetry_bench] bitwise: history={hist_ok} ckpt={ckpt_ok}",
          flush=True)

    # ------------- sink throughput ----------------------------------------
    ev_s, mb_s = _sink_throughput(5_000 if quick else 50_000)
    print(f"[telemetry_bench] sink: {ev_s:,.0f} events/s, {mb_s:.1f} MB/s",
          flush=True)

    # ------------- artifacts: metrics.jsonl + trace.json ------------------
    art = RESULTS / "telemetry"
    art.mkdir(parents=True, exist_ok=True)
    mpath = art / "metrics.jsonl"
    if mpath.exists():
        mpath.unlink()
    tracer = Tracer()
    with JSONLMetricsSink(str(mpath), run="telemetry_bench") as sink:
        eng_art, cells_art = _mk(rounds, telemetry=True)
        eng_art.tracer, eng_art.sink = tracer, sink
        eng_art.run_batch(cells_art, ckpt_every=seg)
    n_round_events = len(read_metrics_jsonl(str(mpath), kind="round"))
    tracer.export_chrome(str(art / "trace.json"))
    spans = {k: v["count"] for k, v in tracer.summary().items()}

    row = {
        "table": "telemetry_bench", "backend": jax.default_backend(),
        "backend_mode": pallas_backend_mode(),
        "n_clients": N_CLIENTS, "cells": B_CELLS, "rounds": rounds,
        "telemetry_off_s": round(off_s, 3),
        "telemetry_on_s": round(on_s, 3),
        "overhead_pct": round(overhead_pct, 2),
        "bitwise_noninterference": bitwise,
        "jsonl_events_per_s": round(ev_s, 1),
        "jsonl_mb_per_s": round(mb_s, 2),
        "round_events_streamed": n_round_events,
        "spans": spans,
    }
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / "BENCH_telemetry.json").write_text(json.dumps([row], indent=2))
    return [row]


def summarize(rows) -> list[str]:
    out = ["", "== telemetry bench: in-scan health channel overhead + "
           "sink throughput (results/BENCH_telemetry.json) =="]
    for r in rows:
        out.append(f"  steady     : off {r['telemetry_off_s']:.2f}s, on "
                   f"{r['telemetry_on_s']:.2f}s "
                   f"({r['overhead_pct']:+.1f}% overhead, gate <= 5%)")
        out.append(f"  bitwise    : history + checkpoints identical "
                   f"on-vs-off: {r['bitwise_noninterference']}")
        out.append(f"  sink       : {r['jsonl_events_per_s']:,.0f} "
                   f"events/s ({r['jsonl_mb_per_s']:.1f} MB/s JSONL)")
        out.append(f"  artifacts  : {r['round_events_streamed']} round "
                   f"events -> results/telemetry/metrics.jsonl, spans "
                   f"{r['spans']} -> results/telemetry/trace.json")
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true")
    a = ap.parse_args()
    for line in summarize(run(quick=not a.full)):
        print(line)

"""Kernel micro-bench: Pallas kernels (interpret mode — correctness-grade
timing only on CPU; the BlockSpec tiling targets TPU) vs the pure-jnp
references.  Reports us/call and the max abs error vs the oracle."""
from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def _time(fn, reps=2):
    out = fn()
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(reps):
        out = fn()
        jax.block_until_ready(out)
    return (time.time() - t0) / reps * 1e6, out


def run(quick: bool = True) -> list[dict]:
    rng = np.random.default_rng(0)
    rows = []

    # Floyd-Warshall
    n = 256
    r = (rng.random((n, n)) * 10).astype(np.float32)
    r[rng.random((n, n)) < 0.4] = np.inf
    np.fill_diagonal(r, 0)
    rj = jnp.asarray(r)
    us_k, out_k = _time(lambda: ops.floyd_warshall(rj))
    us_r, out_r = _time(lambda: ref.floyd_warshall_ref(rj))
    rows.append({"table": "kernels", "kernel": "floyd_warshall", "shape": f"{n}x{n}",
                 "pallas_us": round(us_k), "ref_us": round(us_r),
                 "max_err": float(np.nanmax(np.abs(np.asarray(out_k) - np.asarray(out_r))))})

    # pairwise similarity
    u = jnp.asarray(rng.random((256, 128)).astype(np.float32))
    us_k, out_k = _time(lambda: ops.pairwise_similarity(u))
    us_r, out_r = _time(lambda: ref.similarity_ref(u))
    rows.append({"table": "kernels", "kernel": "pairwise_similarity",
                 "shape": "256x128",
                 "pallas_us": round(us_k), "ref_us": round(us_r),
                 "max_err": float(np.max(np.abs(np.asarray(out_k) - np.asarray(out_r))))})

    # window attention
    b, s, h, d, w = 1, 512, 4, 64, 128
    q = jnp.asarray(rng.standard_normal((b, s, h, d)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((b, s, h, d)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((b, s, h, d)).astype(np.float32))
    us_k, out_k = _time(lambda: ops.window_attention(q, k, v, window=w), reps=1)
    us_r, out_r = _time(lambda: ref.window_attention_ref(q, k, v, window=w), reps=1)
    rows.append({"table": "kernels", "kernel": "window_attention",
                 "shape": f"b{b} s{s} h{h} d{d} w{w}",
                 "pallas_us": round(us_k), "ref_us": round(us_r),
                 "max_err": float(np.max(np.abs(np.asarray(out_k) - np.asarray(out_r))))})
    return rows


def summarize(rows) -> list[str]:
    out = ["", "== Pallas kernels (interpret mode) vs jnp oracle =="]
    out.append(f"{'kernel':22s} {'shape':18s} {'pallas us':>10s} {'ref us':>8s} {'max err':>10s}")
    for r in rows:
        out.append(f"{r['kernel']:22s} {r['shape']:18s} {r['pallas_us']:10d} "
                   f"{r['ref_us']:8d} {r['max_err']:10.2e}")
    return out


if __name__ == "__main__":
    for line in summarize(run()):
        print(line)

"""Kernel micro-bench with roofline instrumentation (DESIGN.md §14).

One row per kernel x shape tier: wall-clock (pallas vs pure-jnp ref), the
analytic FLOP and bytes-moved models next to it, and the derived
arithmetic intensity / achieved GFLOP/s / achieved GB/s that
``benchmarks/roofline.py`` plots against the platform ceilings.  Tiles
resolve through the autotuner table (``tile="auto"``), so the pallas
column times exactly what ships.

Cost models (per-kernel, algorithmic — documented in DESIGN.md §14):

  fused_3dg        flops = 4 N^2 d + 8 N^2        (two matmul phases + epilogue)
                   bytes = 4 (N d + N^2)          (stream U once, write R once)
  floyd_warshall   flops = 2 N^3                  (min-plus inner product)
                   bytes = 8 nb N^2               (read+write every tile per
                                                   pivot round, nb = N/tile)
  fedgs_select     flops ~= 6 S m N + 4 m N       (S sweeps of (m, N) swap
                   bytes ~= 4 (2 S m N + 2 m N)    gains + greedy row math)
  memory_aggregate flops = 2 N P                  (staleness reduction)
                   bytes = 4 (2 N P + m P + N)    (panel round-trip + updates)
  window_attention flops = 4 B H S W D            (qk + av, W-window)
                   bytes = 16 B S H D             (q, k, v, out)

``backend_mode`` is recorded per row (interpret on this CPU container,
compiled on a real accelerator): interpret timings are correctness-grade
only — the interpreter re-writes carried output buffers every grid step —
so the perf-gate (``benchmarks/perf_assert.py``) only enforces the
compiled-mode winners, plus ``fedgs_select`` which wins even under
interpret because the Q-free factorization beats the ref's (N, N) Q
materialization on algorithm, not codegen.

Dumped to ``benchmarks/results/BENCH_kernels.json``.

  PYTHONPATH=src python -m benchmarks.kernel_bench [--quick|--full]
"""
from __future__ import annotations

import json
import pathlib
import time

import numpy as np

import jax
import jax.numpy as jnp

RESULTS = pathlib.Path(__file__).resolve().parent / "results"
BENCH_PATH = RESULTS / "BENCH_kernels.json"

# production tier: the paper-scale client counts start here (ROADMAP.md)
PRODUCTION_N = 1024


def _time_ms(fn, reps=2):
    out = fn()
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best * 1e3, out


def _row(kernel, dims, ref_ms, pallas_ms, max_err, flops, bytes_moved, mode,
         tiles=None):
    n = dims.get("n", 0)
    production = n >= PRODUCTION_N
    # fedgs_select's win is algorithmic (Q-free vs (N, N) Q build), so it is
    # expected to win under the interpreter too; the pure-codegen kernels
    # only beat fused jnp/XLA once Mosaic-compiled
    winner_expected = production and (kernel == "fedgs_select"
                                      or mode == "compiled")
    ai = flops / bytes_moved if bytes_moved else 0.0
    sec = pallas_ms / 1e3
    return {
        "table": "kernels", "kernel": kernel, **dims,
        "tier": ",".join(f"{k}{v}" for k, v in sorted(dims.items())),
        "tiles": tiles or {},
        "ref_ms": round(ref_ms, 3), "pallas_ms": round(pallas_ms, 3),
        "speedup": round(ref_ms / pallas_ms, 3) if pallas_ms else 0.0,
        "max_err": float(max_err),
        "flops": int(flops), "bytes_moved": int(bytes_moved),
        "ai": round(ai, 3),
        "gflops": round(flops / sec / 1e9, 3) if sec else 0.0,
        "gbps": round(bytes_moved / sec / 1e9, 3) if sec else 0.0,
        "backend_mode": mode,
        "production_tier": production,
        "winner_expected": winner_expected,
    }


def _err(a, b):
    a, b = np.asarray(a), np.asarray(b)
    fin = np.isfinite(a) & np.isfinite(b)
    if not bool(np.all(np.isfinite(a) == np.isfinite(b))):
        return float("inf")
    return float(np.max(np.abs(a[fin] - b[fin]))) if fin.any() else 0.0


def _fused_rows(ns, mode, rng):
    from repro.core.graph_device import minmax01, to_adjacency
    from repro.kernels import ops
    from repro.kernels.autotune import resolve
    rows = []
    d = 16
    for n in ns:
        u = jnp.asarray(rng.standard_normal((n, d)).astype(np.float32))
        tiles = resolve("fused_3dg", {"tile": 128}, n=n)

        def _ref(u=u):
            v = u @ u.T
            return to_adjacency(minmax01(v), eps=0.1, sigma2=0.01)

        pal = jax.jit(lambda u: ops.fused_adjacency(u, eps=0.1, sigma2=0.01))
        ref = jax.jit(_ref)
        ms_p, out_p = _time_ms(lambda: pal(u))
        ms_r, out_r = _time_ms(lambda: ref(u))
        rows.append(_row("fused_3dg", {"n": n, "d": d}, ms_r, ms_p,
                         _err(out_p, out_r),
                         flops=4 * n * n * d + 8 * n * n,
                         bytes_moved=4 * (n * d + n * n),
                         mode=mode, tiles=tiles))
    return rows


def _fw_rows(ns, mode, rng):
    from repro.kernels import ops, ref
    from repro.kernels.autotune import resolve
    rows = []
    for n in ns:
        r = (rng.random((n, n)) * 10).astype(np.float32)
        r[rng.random((n, n)) < 0.4] = np.inf
        np.fill_diagonal(r, 0)
        rj = jnp.asarray(r)
        tiles = resolve("floyd_warshall", {"tile": 128}, n=n)
        nb = -(-n // tiles["tile"])
        ms_p, out_p = _time_ms(lambda: ops.floyd_warshall(rj), reps=1)
        ms_r, out_r = _time_ms(lambda: ref.floyd_warshall_ref(rj), reps=1)
        rows.append(_row("floyd_warshall", {"n": n}, ms_r, ms_p,
                         _err(out_p, out_r),
                         flops=2 * n ** 3,
                         bytes_moved=8 * nb * n * n,
                         mode=mode, tiles=tiles))
    return rows


def _select_rows(ns, mode, rng):
    from repro.core.sampler_device import fedgs_select
    rows = []
    sweeps = 2
    for n in ns:
        m = max(16, n // 16)
        h = rng.random((n, n)).astype(np.float32)
        h = (h + h.T) / 2
        np.fill_diagonal(h, 0)
        hj = jnp.asarray(h)
        counts = jnp.zeros((n,), jnp.float32)
        avail = jnp.asarray(rng.random(n) > 0.2)
        al = jnp.float32(1.0)
        sel = {}
        for backend in ("ref", "pallas"):
            fn = jax.jit(lambda h, c, a: fedgs_select(
                h, c, a, al, m=m, max_sweeps=sweeps, backend=backend))
            ms, out = _time_ms(lambda: fn(hj, counts, avail))
            sel[backend] = (ms, np.asarray(out[0]))
        bit_equal = bool(np.array_equal(sel["ref"][1], sel["pallas"][1]))
        row = _row("fedgs_select", {"n": n, "m": m}, sel["ref"][0],
                   sel["pallas"][0], 0.0 if bit_equal else float("inf"),
                   flops=6 * sweeps * m * n + 4 * m * n,
                   bytes_moved=4 * (2 * sweeps * m * n + 2 * m * n),
                   mode=mode)
        row["selected_bit_equal"] = bit_equal
        rows.append(row)
    return rows


def _agg_rows(sizes, mode, rng):
    from repro.fed.aggregator_device import memory_scatter_reduce_ref
    from repro.kernels import ops
    from repro.kernels.autotune import resolve
    rows = []
    for n, p in sizes:
        m = max(8, n // 8)
        mem = jnp.asarray(rng.standard_normal((n, p)).astype(np.float32))
        upd = jnp.asarray(rng.standard_normal((m, p)).astype(np.float32))
        sel = jnp.asarray(rng.permutation(n)[:m].astype(np.int32))
        valid = jnp.ones((m,), bool)
        w = jnp.asarray(rng.random(n).astype(np.float32) / n)
        tiles = resolve("memory_aggregate", {"tile_n": 128, "tile_p": 256},
                        n=n, p=p)
        pal = jax.jit(lambda *a: ops.memory_aggregate(*a))
        ref = jax.jit(memory_scatter_reduce_ref)
        ms_p, out_p = _time_ms(lambda: pal(mem, upd, sel, valid, w))
        ms_r, out_r = _time_ms(lambda: ref(mem, upd, sel, valid, w))
        rows.append(_row("memory_aggregate", {"n": n, "p": p}, ms_r, ms_p,
                         max(_err(out_p[0], out_r[0]),
                             _err(out_p[1], out_r[1])),
                         flops=2 * n * p,
                         bytes_moved=4 * (2 * n * p + m * p + n),
                         mode=mode, tiles=tiles))
    return rows


def _attn_rows(mode, rng):
    from repro.kernels import ops, ref
    b, s, h, d, w = 1, 512, 4, 64, 128
    q = jnp.asarray(rng.standard_normal((b, s, h, d)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((b, s, h, d)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((b, s, h, d)).astype(np.float32))
    ms_p, out_p = _time_ms(lambda: ops.window_attention(q, k, v, window=w),
                           reps=1)
    ms_r, out_r = _time_ms(lambda: ref.window_attention_ref(q, k, v, window=w),
                           reps=1)
    return [_row("window_attention", {"b": b, "d": d, "h": h, "s": s, "w": w},
                 ms_r, ms_p, _err(out_p, out_r),
                 flops=4 * b * h * s * w * d,
                 bytes_moved=16 * b * s * h * d, mode=mode)]


def run(quick: bool = True) -> list[dict]:
    from benchmarks.common import pallas_backend_mode
    mode = pallas_backend_mode()
    rng = np.random.default_rng(0)
    ns = [256, 1024] if quick else [256, 1024, 2048]
    rows = []
    rows += _fused_rows(ns + ([] if quick else [4096]), mode, rng)
    rows += _fw_rows(ns, mode, rng)
    rows += _select_rows(ns, mode, rng)
    rows += _agg_rows([(256, 1024), (1024, 2048)] if quick else
                      [(256, 1024), (1024, 2048), (4096, 4096)], mode, rng)
    rows += _attn_rows(mode, rng)

    RESULTS.mkdir(parents=True, exist_ok=True)
    record = {
        "bench": "kernels",
        "backend": jax.default_backend(),
        "backend_mode": mode,
        "quick": quick,
        "rows": rows,
    }
    BENCH_PATH.write_text(json.dumps(record, indent=1))
    return rows


def summarize(rows) -> list[str]:
    from benchmarks.common import pallas_backend_mode
    out = ["", f"== Pallas kernels vs jnp oracle "
               f"({pallas_backend_mode()} mode; AI = flops/byte) =="]
    out.append(f"{'kernel':18s} {'tier':16s} {'ref_ms':>9s} {'pallas_ms':>10s} "
               f"{'speedup':>8s} {'AI':>7s} {'GFLOP/s':>9s} {'max_err':>9s} "
               f"{'win?':>5s}")
    for r in rows:
        flag = "*" if r["winner_expected"] else ""
        out.append(f"{r['kernel']:18s} {r['tier']:16s} {r['ref_ms']:9.2f} "
                   f"{r['pallas_ms']:10.2f} {r['speedup']:8.2f} {r['ai']:7.2f} "
                   f"{r['gflops']:9.2f} {r['max_err']:9.2e} {flag:>5s}")
    out.append("   (* = production tier where the pallas path is the enforced "
               "winner — see benchmarks/perf_assert.py)")
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    for line in summarize(run(quick=not args.full)):
        print(line)

"""§Perf report: before/after of every recorded perf-variant dry-run vs its
baseline (the hypothesis→change→measure log lives in EXPERIMENTS.md §Perf)."""
from __future__ import annotations

import json
import pathlib

DRYRUN = pathlib.Path(__file__).resolve().parent / "results" / "dryrun"


def _dom(r):
    return max(r["compute_term_s"], r["memory_term_s"], r["collective_term_s"])


def run(quick: bool = True) -> list[dict]:
    rows = []
    for f in sorted(DRYRUN.glob("*.json")):
        r = json.loads(f.read_text())
        if r.get("variant", "baseline") == "baseline" or not r.get("ok"):
            continue
        base_f = DRYRUN / f"{r['arch']}__{r['shape']}__{r['mesh']}.json"
        if not base_f.exists():
            continue
        b = json.loads(base_f.read_text())
        if not b.get("ok"):
            continue
        rows.append({
            "table": "variants", "arch": r["arch"], "shape": r["shape"],
            "mesh": r["mesh"], "variant": r["variant"],
            "base_dominant_s": _dom(b), "variant_dominant_s": _dom(r),
            "speedup": _dom(b) / max(_dom(r), 1e-12),
            "base_temp_gb": round(b["mem"].get("temp_size_in_bytes", 0) / 1e9, 1),
            "variant_temp_gb": round(r["mem"].get("temp_size_in_bytes", 0) / 1e9, 1),
        })
    rows.sort(key=lambda x: -x["speedup"])
    return rows


def summarize(rows) -> list[str]:
    out = ["", "== Perf variants: dominant roofline term, baseline -> variant =="]
    out.append(f"{'arch/shape':42s} {'variant':24s} {'base_s':>9s} {'var_s':>9s} {'x':>6s}")
    for r in rows:
        out.append(f"{(r['arch'] + '/' + r['shape'])[:42]:42s} "
                   f"{r['variant']:24s} {r['base_dominant_s']:9.3g} "
                   f"{r['variant_dominant_s']:9.3g} {r['speedup']:6.2f}")
    return out


if __name__ == "__main__":
    for line in summarize(run()):
        print(line)

"""Feed-forward blocks: SwiGLU / squared-ReLU dense FFN, and token-choice MoE.

The MoE uses sort-based fixed-capacity dispatch (MegaBlocks-style grouped
matmul shape, TPU-friendly static shapes): tokens' top-k expert choices are
flattened, sorted by expert id, placed into an (E, C) capacity buffer (drop on
overflow), run through grouped einsum ``ecd,edf->ecf``, then combined back
weighted by router probabilities.  Experts shard over the ``model`` mesh axis
(expert parallelism).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


# ---------------------------------------------------------------- dense FFN
def init_ffn(key, d_model: int, d_ff: int, kind: str, dtype):
    ks = jax.random.split(key, 3)
    p = {"w_in": dense_init(ks[0], d_model, d_ff, dtype),
         "w_out": dense_init(ks[1], d_ff, d_model, dtype)}
    if kind == "swiglu":
        p["w_gate"] = dense_init(ks[2], d_model, d_ff, dtype)
    return p


def apply_ffn(p, x, kind: str):
    h = x @ p["w_in"]
    if kind == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * h
    elif kind == "squared_relu":
        h = jnp.square(jax.nn.relu(h))
    else:
        raise ValueError(kind)
    return h @ p["w_out"]


# ---------------------------------------------------------------- MoE
def init_moe(key, d_model: int, d_ff: int, n_experts: int, kind: str, dtype):
    ks = jax.random.split(key, 4)

    def fresh(key, n, a, b):
        return jax.vmap(lambda k: dense_init(k, a, b, dtype))(jax.random.split(key, n))

    p = {
        "router": dense_init(ks[0], d_model, n_experts, jnp.float32),
        "w_in": fresh(ks[1], n_experts, d_model, d_ff),
        "w_out": fresh(ks[2], n_experts, d_ff, d_model),
    }
    if kind == "swiglu":
        p["w_gate"] = fresh(ks[3], n_experts, d_model, d_ff)
    return p


def moe_capacity(n_tokens: int, n_experts: int, top_k: int, factor: float) -> int:
    c = int(n_tokens * top_k * factor / n_experts)
    return max(8, ((c + 7) // 8) * 8)


# Dispatch groups: 1 = the paper-simple global sort dispatch.  Set to the dp
# size (launch.variants `moe_grouped`) to keep the scatter/gather LOCAL per
# data shard — a global scatter into the (E,C,d) buffer otherwise lowers to
# partial-buffer + all-reduce under SPMD (3.9 TB/step for olmoe train_4k;
# EXPERIMENTS §Perf F).
MOE_GROUPS = 1                       # int, or -1 = auto (the mesh's dp size)


def _dispatch_group(xt, probs, gate, choice, p, *, cap: int, top_k: int,
                    kind: str):
    """Sort-based fixed-capacity dispatch for one token group.

    xt (T, d); probs (T, E); gate/choice (T, K).  Returns (out (T, d), aux).
    Called under vmap over the group axis; the expert-dim sharding
    constraints batch through (the group axis inherits the dp sharding of
    the operands).
    """
    from repro.sharding.ctx import shard_act
    t, d = xt.shape
    e = p["w_in"].shape[0]
    flat_expert = choice.reshape(-1)                            # (T*K,)
    flat_token = jnp.repeat(jnp.arange(t), top_k)               # (T*K,)
    flat_gate = gate.reshape(-1)
    order = jnp.argsort(flat_expert, stable=True)
    se, st, sg = flat_expert[order], flat_token[order], flat_gate[order]
    # position within expert segment via searchsorted on the sorted ids
    starts = jnp.searchsorted(se, jnp.arange(e), side="left")
    pos_in_e = jnp.arange(t * top_k) - starts[se]
    keep = pos_in_e < cap
    # overflow entries get an out-of-range slot and are dropped by the scatter
    slot = jnp.where(keep, se * cap + pos_in_e, e * cap)

    buf = jnp.zeros((e * cap, d), xt.dtype)
    buf = buf.at[slot].set(xt[st], mode="drop")
    buf = buf.reshape(e, cap, d)
    buf = shard_act(buf, "tp", None, None)          # experts over tp

    h = jnp.einsum("ecd,edf->ecf", buf, p["w_in"])
    if kind == "swiglu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])) * h
    else:
        h = jnp.square(jax.nn.relu(h))
    out_e = jnp.einsum("ecf,efd->ecd", h, p["w_out"])
    out_e = shard_act(out_e, "tp", None, None).reshape(e * cap, d)

    slot_c = jnp.minimum(slot, e * cap - 1)
    contrib = out_e[slot_c] * (sg * keep)[:, None].astype(xt.dtype)
    out = jnp.zeros((t, d), xt.dtype).at[st].add(contrib)

    # load-balance auxiliary loss (Switch-style)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(choice[:, 0], e), axis=0)
    aux = e * jnp.sum(me * ce)
    return out, aux


def apply_moe(p, x, *, top_k: int, capacity_factor: float, kind: str):
    """x: (B, S, d) -> (B, S, d), plus aux load-balance loss.

    Returns (out, aux_loss).  With MOE_GROUPS == G > 1 the dispatch runs
    independently on G contiguous token groups (aligned with the dp batch
    sharding), each with capacity/G — drops match the global dispatch in
    distribution, and exactly when capacity is ample.
    """
    from repro.sharding.ctx import current_ctx, shard_act
    b, s, d = x.shape
    t = b * s
    g = MOE_GROUPS
    if g == -1:                       # auto: one group per data shard
        ctx = current_ctx()
        g = ctx.dp_size if ctx is not None else 1
    if g < 1 or t % g != 0:
        g = 1
    e = p["w_in"].shape[0]
    cap = moe_capacity(t // g, e, top_k, capacity_factor)

    xt = x.reshape(g, t // g, d)
    xt = shard_act(xt, "dp", None, None)
    logits = (xt.astype(jnp.float32) @ p["router"])             # (G, Tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, choice = jax.lax.top_k(probs, top_k)                  # (G, Tg, K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    out, aux = jax.vmap(
        lambda xg, pg, gg, cg: _dispatch_group(
            xg, pg, gg, cg, p, cap=cap, top_k=top_k, kind=kind)
    )(xt, probs, gate, choice)
    out = shard_act(out, "dp", None, None)
    return out.reshape(b, s, d), jnp.mean(aux)

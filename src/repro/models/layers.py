"""Shared neural-net primitives (pure JAX, dict-pytree params)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dense_init(key, in_dim: int, out_dim: int, dtype) -> jax.Array:
    """Truncated-normal fan-in init (LLaMA-style 1/sqrt(in))."""
    scale = 1.0 / np.sqrt(in_dim)
    return (jax.random.truncated_normal(key, -3, 3, (in_dim, out_dim), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02).astype(dtype)


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32)).astype(dt)


def rope_angles(positions: jax.Array, head_dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """cos/sin tables for given integer positions. -> (..., head_dim//2)"""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, D); cos/sin: (S, D/2) or broadcastable (..., D/2)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    # broadcast cos/sin over batch and heads
    while cos.ndim < x1.ndim:
        cos = cos[None] if cos.ndim < x1.ndim - 1 else cos[..., None, :]
        sin = sin[None] if sin.ndim < x1.ndim - 1 else sin[..., None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(dt)


def softmax_cross_entropy(logits: jax.Array, labels: jax.Array, mask: jax.Array) -> jax.Array:
    """Mean masked token cross entropy.  logits (B,S,V) f32-accumulated."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)

"""The model zoo's unified language model.

One parameterized decoder (optionally with an encoder for enc-dec archs)
covering all six assigned families:

  dense   — GQA attention + (SwiGLU | squared-ReLU) FFN
  moe     — GQA attention + token-choice top-k MoE FFN
  ssm     — Mamba-2/SSD mixer, no FFN
  hybrid  — parallel attention + SSD heads, then FFN (Hymba)
  vlm     — dense/GQA decoder consuming [image-embeddings ; token-embeddings]
  audio   — enc-dec: bidirectional encoder over frame embeddings, causal
            decoder with cross-attention

Parameters are dict pytrees with per-layer leaves **stacked on a leading L
axis**; the forward is ``lax.scan`` over layers (+ ``jax.checkpoint`` remat in
training) so 96-layer models lower as fast as 1-layer models.

Three entry points (used by launch/, fed/, tests/):
  train_loss(params, cfg, batch)                -> scalar loss
  prefill(params, cfg, batch, cache_len)        -> (logits_last, cache)
  decode_step(params, cfg, token_batch, cache)  -> (logits, cache)
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import attention as attn_mod
from repro.models import ffn as ffn_mod
from repro.models import ssd as ssd_mod
from repro.models.layers import (
    dense_init, embed_init, rms_norm, rope_angles, apply_rope,
    softmax_cross_entropy,
)
from repro.sharding.ctx import shard_act


# ====================================================================== init
def _init_attn(key, cfg: ArchConfig, dtype):
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "norm": jnp.ones((d,), jnp.float32),
        "wq": dense_init(ks[0], d, hq * dh, dtype),
        "wk": dense_init(ks[1], d, hkv * dh, dtype),
        "wv": dense_init(ks[2], d, hkv * dh, dtype),
        "wo": dense_init(ks[3], hq * dh, d, dtype),
    }


def _init_block(key, cfg: ArchConfig, dtype, *, cross: bool = False):
    ks = jax.random.split(key, 5)
    p: dict[str, Any] = {}
    if cfg.attention != "none":
        p["attn"] = _init_attn(ks[0], cfg, dtype)
    if cfg.ssm is not None:
        p["ssm"] = {"norm": jnp.ones((cfg.d_model,), jnp.float32),
                    **ssd_mod.init_ssd(ks[1], cfg.d_model, cfg.ssm, dtype)}
    if cross:
        p["cross"] = _init_attn(ks[2], cfg, dtype)
    if cfg.d_ff > 0:
        if cfg.moe is not None:
            p["moe"] = {"norm": jnp.ones((cfg.d_model,), jnp.float32),
                        **ffn_mod.init_moe(ks[3], cfg.d_model, cfg.d_ff,
                                           cfg.moe.num_experts, cfg.ffn_kind, dtype)}
        else:
            p["ffn"] = {"norm": jnp.ones((cfg.d_model,), jnp.float32),
                        **ffn_mod.init_ffn(ks[4], cfg.d_model, cfg.d_ff,
                                           cfg.ffn_kind, dtype)}
    return p


def init_params(key, cfg: ArchConfig):
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 5)
    v, d = cfg.padded_vocab, cfg.d_model

    def stack_init(key, n, **kw):
        return jax.vmap(lambda k: _init_block(k, cfg, dtype, **kw))(jax.random.split(key, n))

    params = {
        "embed": embed_init(ks[0], v, d, dtype),
        "blocks": stack_init(ks[1], cfg.n_layers, cross=cfg.enc_dec),
        "final_norm": jnp.ones((d,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[2], d, v, dtype)
    if cfg.enc_dec:
        enc_cfg = cfg  # same dims for encoder blocks
        params["enc_blocks"] = jax.vmap(
            lambda k: _init_block(k, enc_cfg, dtype))(jax.random.split(ks[3], cfg.n_enc_layers))
        params["enc_norm"] = jnp.ones((d,), jnp.float32)
    return params


def embed_params_padded(params, cfg: ArchConfig, cfg_p: ArchConfig):
    """Exact embedding of a model's weights into the head-padded layout
    (configs.base.pad_heads): real q head j goes to slot (j//n0)*n1 + j%n0 so
    the uniform repeat_kv mapping keeps it attached to its original kv head;
    pad q slots get zero wq columns and zero wo rows (their attention output
    is exactly dropped); pad kv slots get zero wk/wv (attended only by pad q
    slots).  Returns params for cfg_p with identical function."""
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    hq_p, hkv_p = cfg_p.n_heads, cfg_p.n_kv_heads
    n0, n1 = hq // hkv, hq_p // hkv_p
    q_slot = np.array([(j // n0) * n1 + (j % n0) for j in range(hq)])

    def pad_attn(attn):
        out = dict(attn)
        L, d, _ = attn["wq"].shape
        wq = jnp.zeros((L, d, hq_p, dh), attn["wq"].dtype)
        wq = wq.at[:, :, q_slot].set(attn["wq"].reshape(L, d, hq, dh))
        out["wq"] = wq.reshape(L, d, hq_p * dh)
        wo = jnp.zeros((L, hq_p, dh, d), attn["wo"].dtype)
        wo = wo.at[:, q_slot].set(attn["wo"].reshape(L, hq, dh, d))
        out["wo"] = wo.reshape(L, hq_p * dh, d)
        for name in ("wk", "wv"):
            w = jnp.zeros((L, d, hkv_p, dh), attn[name].dtype)
            w = w.at[:, :, :hkv].set(attn[name].reshape(L, d, hkv, dh))
            out[name] = w.reshape(L, d, hkv_p * dh)
        return out

    new = dict(params)
    blocks = dict(params["blocks"])
    if "attn" in blocks:
        blocks["attn"] = pad_attn(blocks["attn"])
    if "cross" in blocks:
        blocks["cross"] = pad_attn(blocks["cross"])
    new["blocks"] = blocks
    if "enc_blocks" in params and "attn" in params["enc_blocks"]:
        enc = dict(params["enc_blocks"])
        enc["attn"] = pad_attn(enc["attn"])
        new["enc_blocks"] = enc
    return new


# ================================================================ block fwd
def _attn_fwd(p, x, cfg: ArchConfig, *, causal, window, positions,
              kv_override=None):
    """x (B,S,d). kv_override: (k, v) already-projected encoder memory (cross)."""
    b, s, d = x.shape
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    q = (h @ p["wq"]).reshape(b, s, hq, dh)
    q = shard_act(q, "dp", None, "tp", None)
    if kv_override is None:
        k = (h @ p["wk"]).reshape(b, s, hkv, dh)
        v = (h @ p["wv"]).reshape(b, s, hkv, dh)
        cos, sin = rope_angles(positions, dh, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    else:
        k, v = kv_override
    k = attn_mod._repeat_kv(k, hq // hkv)
    v = attn_mod._repeat_kv(v, hq // hkv)
    o = attn_mod.multihead_attention(q, k, v, causal=causal, window=window)
    o = o.reshape(b, s, hq * dh) @ p["wo"]
    return shard_act(o, "dp", None, None)


def _block_fwd(p, x, cfg: ArchConfig, *, causal=True, positions=None,
               enc_kv=None, decoder=True):
    """One transformer block (pre-norm, residual). Returns (x, aux)."""
    aux = jnp.zeros((), jnp.float32)
    window = cfg.window if cfg.attention == "sliding_window" else None
    has_attn = cfg.attention != "none" and "attn" in p
    has_ssm = cfg.ssm is not None and "ssm" in p

    if has_attn and has_ssm:          # hybrid: parallel branches, mean-fused
        a = _attn_fwd(p["attn"], x, cfg, causal=causal, window=window,
                      positions=positions)
        sp = {k: v for k, v in p["ssm"].items() if k != "norm"}
        m, _ = ssd_mod.apply_ssd(sp, rms_norm(x, p["ssm"]["norm"], cfg.norm_eps), cfg.ssm)
        x = x + 0.5 * (a + m)
    elif has_attn:
        x = x + _attn_fwd(p["attn"], x, cfg, causal=causal, window=window,
                          positions=positions)
    elif has_ssm:
        sp = {k: v for k, v in p["ssm"].items() if k != "norm"}
        m, _ = ssd_mod.apply_ssd(sp, rms_norm(x, p["ssm"]["norm"], cfg.norm_eps), cfg.ssm)
        x = x + m

    if enc_kv is not None and "cross" in p:
        x = x + _attn_fwd(p["cross"], x, cfg, causal=False, window=None,
                          positions=None, kv_override=enc_kv)

    if "moe" in p:
        h = rms_norm(x, p["moe"]["norm"], cfg.norm_eps)
        mp = {k: v for k, v in p["moe"].items() if k != "norm"}
        o, a = ffn_mod.apply_moe(mp, h, top_k=cfg.moe.top_k,
                                 capacity_factor=cfg.moe.capacity_factor,
                                 kind=cfg.ffn_kind)
        x = x + o
        aux = aux + a
    elif "ffn" in p:
        h = rms_norm(x, p["ffn"]["norm"], cfg.norm_eps)
        h = shard_act(h, "dp", None, None)
        fp = {k: v for k, v in p["ffn"].items() if k != "norm"}
        x = x + ffn_mod.apply_ffn(fp, h, cfg.ffn_kind)
    return x, aux


# --- perf-variant knobs (set by repro.launch.variants around a lowering) ---
# REMAT_POLICY: which intermediates the layer-scan checkpoint saves for bwd.
#   "dots"    — dots_with_no_batch_dims_saveable (default; saves FFN matmuls)
#   "nothing" — full recompute (smallest live set, ~+1 fwd of compute)
REMAT_POLICY = "dots"
# RING_CACHE: sliding-window decode keeps only a window-sized ring buffer
# instead of the full-sequence KV cache (long_500k collective fix).
RING_CACHE = False
# REMAT_GROUP: 2-level remat — scan over L/G checkpointed groups of G layers;
# only group inputs are saved (L/G + G transient instead of L live carries).
REMAT_GROUP = 1


def _remat(fn):
    if REMAT_POLICY == "nothing":
        return jax.checkpoint(fn)
    return jax.checkpoint(
        fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)


def _run_blocks(blocks, x, cfg: ArchConfig, *, causal, positions, enc_kv=None,
                remat=False):
    """lax.scan over stacked layer params."""

    def body(carry, layer_p):
        h, aux = carry
        h2, a = _block_fwd(layer_p, h, cfg, causal=causal, positions=positions,
                           enc_kv=None if enc_kv is None else enc_kv_proj(layer_p))
        return (h2, aux + a), None

    def enc_kv_proj(layer_p):
        # project encoder memory to this layer's cross K/V
        mem = enc_kv
        b, se, d = mem.shape
        k = (mem @ layer_p["cross"]["wk"]).reshape(b, se, cfg.n_kv_heads, cfg.head_dim)
        v = (mem @ layer_p["cross"]["wv"]).reshape(b, se, cfg.n_kv_heads, cfg.head_dim)
        return (k, v)

    fn = body
    g = REMAT_GROUP
    n_layers = jax.tree_util.tree_leaves(blocks)[0].shape[0]
    if remat and g > 1 and n_layers % g == 0:
        # 2-level remat: outer checkpointed scan over groups, inner unchecked
        # scan over the g layers of each group
        def group_body(carry, group_p):
            out, _ = jax.lax.scan(body, carry, group_p)
            return out, None

        grouped = jax.tree_util.tree_map(
            lambda x_: x_.reshape(n_layers // g, g, *x_.shape[1:]), blocks)
        (x, aux), _ = jax.lax.scan(jax.checkpoint(group_body),
                                   (x, jnp.zeros((), jnp.float32)), grouped)
        return x, aux
    if remat:
        fn = _remat(body)
    (x, aux), _ = jax.lax.scan(fn, (x, jnp.zeros((), jnp.float32)), blocks)
    return x, aux


# =============================================================== embeddings
def _embed_inputs(params, cfg: ArchConfig, batch):
    """Token (+ multimodal stub) embedding. Returns (x, positions, loss_mask)."""
    tokens = batch["tokens"]
    x = params["embed"][tokens]            # gather
    prefix = []
    if cfg.family == "vlm" and "image_emb" in batch:
        prefix.append(batch["image_emb"].astype(x.dtype))
    if prefix:
        x = jnp.concatenate(prefix + [x], axis=1)
    positions = jnp.arange(x.shape[1])
    return x, positions


def _lm_logits(params, cfg: ArchConfig, x):
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    return shard_act(logits, "dp", None, "tp")


LOSS_CHUNK = 512


def _chunked_cross_entropy(params, cfg: ArchConfig, x, labels, mask):
    """Sequence-chunked LM loss: the (B, chunk, V) logits tile is transient
    (recomputed in backward via jax.checkpoint), so the full (B, S, V) logits
    never materialize — essential for train_4k × 256k-vocab archs."""
    b, s, d = x.shape
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    chunk = LOSS_CHUNK if s % LOSS_CHUNK == 0 else s
    nc = s // chunk

    xc = x.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, nc, chunk).transpose(1, 0, 2)
    mc = mask.reshape(b, nc, chunk).transpose(1, 0, 2)

    def body(carry, inp):
        xs, ls, ms = inp
        logits = (xs @ head).astype(jnp.float32)
        logits = shard_act(logits, "dp", None, "tp")
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ls[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * ms.astype(jnp.float32)
        return (carry[0] + jnp.sum(nll), carry[1] + jnp.sum(ms)), None

    (tot, cnt), _ = jax.lax.scan(jax.checkpoint(body),
                                 (jnp.zeros((), jnp.float32),
                                  jnp.zeros((), jnp.float32)),
                                 (xc, lc, mc))
    return tot / jnp.maximum(cnt, 1.0)


def _encode(params, cfg: ArchConfig, frames):
    """Bidirectional encoder over precomputed frame embeddings (audio stub)."""
    x = frames.astype(jnp.dtype(cfg.dtype))
    pos = jnp.arange(x.shape[1])
    x, _ = _run_blocks(params["enc_blocks"], x, cfg, causal=False, positions=pos)
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


# ==================================================================== train
def train_loss(params, cfg: ArchConfig, batch, *, remat=True, aux_weight=0.01):
    """Next-token LM loss.  batch: tokens (B,S), labels (B,S), optional
    image_emb (B,Ni,d) / audio_frames (B,Nf,d)."""
    enc_kv = None
    if cfg.enc_dec:
        enc_mem = _encode(params, cfg, batch["audio_frames"])
        enc_kv = enc_mem
    x, positions = _embed_inputs(params, cfg, batch)
    x = shard_act(x, "dp", None, None)
    x, aux = _run_blocks(params["blocks"], x, cfg, causal=True,
                         positions=positions, enc_kv=enc_kv, remat=remat)
    labels = batch["labels"]
    if x.shape[1] != labels.shape[1]:          # vlm: image prefix positions
        pad = x.shape[1] - labels.shape[1]
        labels = jnp.concatenate(
            [jnp.full((labels.shape[0], pad), -1, labels.dtype), labels], axis=1)
    mask = (labels >= 0) & (labels < cfg.vocab_size)
    loss = _chunked_cross_entropy(params, cfg, x, jnp.maximum(labels, 0), mask)
    if cfg.moe is not None:
        loss = loss + aux_weight * aux / cfg.n_layers
    return loss


# ============================================================ prefill/decode
def init_decode_cache(cfg: ArchConfig, batch: int, max_len: int,
                      enc_len: int = 0, dtype=None):
    """Abstract-shape-compatible cache pytree (all zeros)."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    L = cfg.n_layers
    cache: dict[str, Any] = {"len": jnp.zeros((), jnp.int32)}
    if cfg.attention != "none":
        hkv, dh = cfg.n_kv_heads, cfg.head_dim
        cache["k"] = jnp.zeros((L, batch, max_len, hkv, dh), dtype)
        cache["v"] = jnp.zeros((L, batch, max_len, hkv, dh), dtype)
    if cfg.ssm is not None:
        s = cfg.ssm
        d_in = s.expand * cfg.d_model
        nh = d_in // s.head_dim
        cache["ssm"] = jnp.zeros((L, batch, nh, s.head_dim, s.d_state), jnp.float32)
        cache["conv"] = jnp.zeros((L, batch, s.d_conv - 1, d_in + 2 * s.d_state), dtype)
    if cfg.enc_dec:
        hkv, dh = cfg.n_kv_heads, cfg.head_dim
        cache["enc_k"] = jnp.zeros((L, batch, enc_len, hkv, dh), dtype)
        cache["enc_v"] = jnp.zeros((L, batch, enc_len, hkv, dh), dtype)
    return cache


def prefill(params, cfg: ArchConfig, batch):
    """Forward over a prompt; returns (last-position logits, populated cache)."""
    enc_kv = None
    enc_mem = None
    if cfg.enc_dec:
        enc_mem = _encode(params, cfg, batch["audio_frames"])
        enc_kv = enc_mem
    x, positions = _embed_inputs(params, cfg, batch)
    b, s, d = x.shape
    window = cfg.window if cfg.attention == "sliding_window" else None

    cache = init_decode_cache(cfg, b, s, enc_len=0 if enc_mem is None else enc_mem.shape[1])
    ks, vs, ssms, convs, eks, evs = [], [], [], [], [], []

    def body(carry, layer_p):
        h, aux = carry
        ys = {}
        # recompute K/V the same way _attn_fwd does, but also emit them
        if "attn" in layer_p:
            hn = rms_norm(h, layer_p["attn"]["norm"], cfg.norm_eps)
            k = (hn @ layer_p["attn"]["wk"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
            v = (hn @ layer_p["attn"]["wv"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
            cos, sin = rope_angles(positions, cfg.head_dim, cfg.rope_theta)
            ys["k"] = apply_rope(k, cos, sin)
            ys["v"] = v
        if "cross" in layer_p and enc_mem is not None:
            se = enc_mem.shape[1]
            ys["enc_k"] = (enc_mem @ layer_p["cross"]["wk"]).reshape(b, se, cfg.n_kv_heads, cfg.head_dim)
            ys["enc_v"] = (enc_mem @ layer_p["cross"]["wv"]).reshape(b, se, cfg.n_kv_heads, cfg.head_dim)
        if "ssm" in layer_p:
            sp = {kk: vv for kk, vv in layer_p["ssm"].items() if kk != "norm"}
            _, (st, cv) = ssd_mod.apply_ssd(
                sp, rms_norm(h, layer_p["ssm"]["norm"], cfg.norm_eps), cfg.ssm)
            ys["ssm"] = st
            ys["conv"] = cv
        h2, a = _block_fwd(
            layer_p, h, cfg, causal=True, positions=positions,
            enc_kv=None if enc_mem is None else (ys["enc_k"], ys["enc_v"]))
        return (h2, aux + a), ys

    (x, _), ys = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), params["blocks"])
    for name in ("k", "v", "ssm", "conv", "enc_k", "enc_v"):
        if name in ys:
            cache[name] = ys[name]
    cache["len"] = jnp.asarray(s, jnp.int32)
    logits = _lm_logits(params, cfg, x[:, -1:])
    return logits[:, 0], cache


def decode_step(params, cfg: ArchConfig, tokens, cache, *, audio_frames=None):
    """One-token decode.  tokens (B,) int32; cache from init_decode_cache/prefill.

    Returns (logits (B, V), new cache).
    """
    b = tokens.shape[0]
    x = params["embed"][tokens][:, None]                  # (B,1,d)
    pos = cache["len"][None, None] + jnp.zeros((b, 1), jnp.int32)
    window = cfg.window if cfg.attention == "sliding_window" else None
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim

    def body(carry, inp):
        h = carry
        layer_p, layer_c = inp
        new_c = {}
        if "attn" in layer_p:
            pa = layer_p["attn"]
            hn = rms_norm(h, pa["norm"], cfg.norm_eps)
            q = (hn @ pa["wq"]).reshape(b, 1, hq, dh)
            q = shard_act(q, "dp", None, "tp", None)
            k = (hn @ pa["wk"]).reshape(b, 1, hkv, dh)
            v = (hn @ pa["wv"]).reshape(b, 1, hkv, dh)
            cos, sin = rope_angles(pos, dh, cfg.rope_theta)
            q = apply_rope(q, cos, sin)
            k = apply_rope(k, cos, sin)
            if (RING_CACHE and window is not None
                    and layer_c["k"].shape[1] == window):
                # ring buffer: overwrite slot len % W; no sequence gather
                slot = jnp.mod(cache["len"], window)
                kc = jax.lax.dynamic_update_slice_in_dim(layer_c["k"], k, slot, axis=1)
                vc = jax.lax.dynamic_update_slice_in_dim(layer_c["v"], v, slot, axis=1)
                new_c["k"], new_c["v"] = kc, vc
                kr = attn_mod._repeat_kv(kc, hq // hkv)
                vr = attn_mod._repeat_kv(vc, hq // hkv)
                o = attn_mod.decode_attend_ring(q, kr, vr, cache["len"],
                                                window=window)
            else:
                kc = jax.lax.dynamic_update_slice_in_dim(layer_c["k"], k, cache["len"], axis=1)
                vc = jax.lax.dynamic_update_slice_in_dim(layer_c["v"], v, cache["len"], axis=1)
                new_c["k"], new_c["v"] = kc, vc
                kr = attn_mod._repeat_kv(kc, hq // hkv)
                vr = attn_mod._repeat_kv(vc, hq // hkv)
                o = attn_mod.decode_attend(q, kr, vr, cache["len"] + 1, window=window)
            attn_out = o.reshape(b, 1, hq * dh) @ pa["wo"]
        if "ssm" in layer_p:
            sp = {kk: vv for kk, vv in layer_p["ssm"].items() if kk != "norm"}
            m, (st, cv) = ssd_mod.ssd_decode_step(
                sp, rms_norm(h, layer_p["ssm"]["norm"], cfg.norm_eps), cfg.ssm,
                layer_c["ssm"], layer_c["conv"])
            new_c["ssm"], new_c["conv"] = st, cv
        if "attn" in layer_p and "ssm" in layer_p:
            h = h + 0.5 * (attn_out + m)
        elif "attn" in layer_p:
            h = h + attn_out
        elif "ssm" in layer_p:
            h = h + m
        if "cross" in layer_p:
            pc = layer_p["cross"]
            hn = rms_norm(h, pc["norm"], cfg.norm_eps)
            q = (hn @ pc["wq"]).reshape(b, 1, hq, dh)
            kr = attn_mod._repeat_kv(layer_c["enc_k"], hq // hkv)
            vr = attn_mod._repeat_kv(layer_c["enc_v"], hq // hkv)
            enc_len = jnp.asarray(layer_c["enc_k"].shape[1], jnp.int32)
            o = attn_mod.decode_attend(q, kr, vr, enc_len, window=None)
            h = h + o.reshape(b, 1, hq * dh) @ pc["wo"]
            # cross K/V are static during decode; pass through so the cache
            # pytree structure is stable
            new_c["enc_k"], new_c["enc_v"] = layer_c["enc_k"], layer_c["enc_v"]
        if "moe" in layer_p:
            hn = rms_norm(h, layer_p["moe"]["norm"], cfg.norm_eps)
            mp = {kk: vv for kk, vv in layer_p["moe"].items() if kk != "norm"}
            o, _ = ffn_mod.apply_moe(mp, hn, top_k=cfg.moe.top_k,
                                     capacity_factor=cfg.moe.capacity_factor,
                                     kind=cfg.ffn_kind)
            h = h + o
        elif "ffn" in layer_p:
            hn = rms_norm(h, layer_p["ffn"]["norm"], cfg.norm_eps)
            fp = {kk: vv for kk, vv in layer_p["ffn"].items() if kk != "norm"}
            h = h + ffn_mod.apply_ffn(fp, hn, cfg.ffn_kind)
        return h, new_c

    layer_caches = {k: v for k, v in cache.items() if k != "len"}
    x, new_caches = jax.lax.scan(body, x, (params["blocks"], layer_caches))
    logits = _lm_logits(params, cfg, x)[:, 0]
    new_cache = dict(new_caches)
    new_cache["len"] = cache["len"] + 1
    return logits, new_cache

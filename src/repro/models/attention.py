"""Attention: GQA with full-causal or sliding-window variants.

Three execution paths, all pure JAX (the Pallas sliding-window kernel in
``repro.kernels.window_attention`` is the TPU hot-spot version; these are the
portable references used for training/lowering):

* dense path (S <= DENSE_MAX): materialized (B,H,S,S) scores — fastest to
  compile, fine for smoke tests and short sequences.
* chunked path (full attention, long S): online-softmax ``lax.scan`` over KV
  chunks — memory O(S·chunk) instead of O(S²).
* windowed path (sliding window, long S): ``lax.scan`` over Q chunks, each
  attending to a static-size KV span — compute O(S·window), truly
  sub-quadratic in HLO FLOPs.

Decode: one query token against a KV cache; sliding-window decode slices the
last ``window`` cache entries (static size) so long_500k decode reads a
bounded span.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

DENSE_MAX = 8192
Q_CHUNK = 1024
KV_CHUNK = 1024

NEG_INF = -1e30

# perf-variant knob: dtype of the materialized (B,H,Sq,Sk) score/prob buffers
# in the dense path.  f32 is the numerically-safe default; bf16 halves the
# dominant HBM traffic at train_4k (max-subtracted softmax keeps exp bounded).
SCORE_DTYPE = "float32"


def _repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    """(B,S,Hkv,D) -> (B,S,Hkv*n_rep,D) repeating each kv head."""
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.repeat(k, n_rep, axis=2)


def attend_dense(q, k, v, *, causal: bool, window: int | None,
                 q_offset: int = 0) -> jax.Array:
    """Materialized attention. q (B,Sq,H,D), k/v (B,Sk,H,D) (kv already repeated)."""
    b, sq, h, d = q.shape
    sk = k.shape[1]
    scale = 1.0 / np.sqrt(d)
    sdt = jnp.dtype(SCORE_DTYPE)
    neg = jnp.asarray(-6e4 if sdt == jnp.bfloat16 else NEG_INF, sdt)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(sdt) * jnp.asarray(scale, sdt)
    qpos = jnp.arange(sq) + q_offset
    kpos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    scores = jnp.where(mask[None, None], scores, neg)
    # max-subtracted softmax: stable in bf16 because exp inputs are <= 0
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    probs = (p / jnp.sum(p, axis=-1, keepdims=True)).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def attend_chunked_full(q, k, v, *, causal: bool = True) -> jax.Array:
    """Online-softmax over KV chunks (flash pattern).  All-queries-at-once.

    Memory: O(B·H·Sq·KV_CHUNK) transient instead of O(Sq·Sk).
    """
    b, sq, h, d = q.shape
    sk = k.shape[1]
    assert sk % KV_CHUNK == 0, (sk, KV_CHUNK)
    n_kv = sk // KV_CHUNK
    scale = 1.0 / np.sqrt(d)
    qpos = jnp.arange(sq)

    kc = k.reshape(b, n_kv, KV_CHUNK, h, d)
    vc = v.reshape(b, n_kv, KV_CHUNK, h, d)

    def step(carry, inputs):
        acc, m, l = carry                       # (B,Sq,H,D) f32, (B,H,Sq), (B,H,Sq)
        kb, vb, kv_idx = inputs
        kp = kv_idx * KV_CHUNK + jnp.arange(KV_CHUNK)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kb).astype(jnp.float32) * scale
        if causal:
            msk = kp[None, :] <= qpos[:, None]
            s = jnp.where(msk[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr.transpose(0, 2, 1)[..., None] + jnp.einsum(
            "bhqk,bkhd->bqhd", p.astype(q.dtype), vb
        ).astype(jnp.float32)
        return (acc, m_new, l), None

    acc0 = jnp.zeros((b, sq, h, d), jnp.float32)
    m0 = jnp.full((b, h, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(
        step, (acc0, m0, l0),
        (kc.transpose(1, 0, 2, 3, 4), vc.transpose(1, 0, 2, 3, 4), jnp.arange(n_kv)),
    )
    out = acc / jnp.maximum(l, 1e-30).transpose(0, 2, 1)[..., None]
    return out.astype(q.dtype)


def attend_windowed(q, k, v, *, window: int) -> jax.Array:
    """Causal sliding-window attention via Q-chunk scan over static KV spans.

    Query chunk i (length C) attends to kv span of static length W+C ending at
    the chunk's last position — O(S·(W+C)) compute.
    """
    b, sq, h, d = q.shape
    sk = k.shape[1]
    c = min(Q_CHUNK, sq)
    assert sq % c == 0
    n_q = sq // c
    span = window + c

    # left-pad K/V so every span slice is in-bounds and static-size
    pad = span
    kp = jnp.pad(k, ((0, 0), (pad, 0), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (pad, 0), (0, 0), (0, 0)))

    qc = q.reshape(b, n_q, c, h, d).transpose(1, 0, 2, 3, 4)

    def step(_, inputs):
        qb, i = inputs
        end = (i + 1) * c + pad                 # exclusive end in padded coords
        start = end - span
        kb = jax.lax.dynamic_slice_in_dim(kp, start, span, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(vp, start, span, axis=1)
        qpos = i * c + jnp.arange(c)
        kpos = start - pad + jnp.arange(span)   # true positions (can be negative)
        s = jnp.einsum("bqhd,bkhd->bhqk", qb, kb).astype(jnp.float32) / np.sqrt(d)
        msk = (kpos[None, :] <= qpos[:, None]) & (kpos[None, :] > qpos[:, None] - window) & (kpos[None, :] >= 0)
        s = jnp.where(msk[None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
        return None, jnp.einsum("bhqk,bkhd->bqhd", p, vb)

    _, out = jax.lax.scan(step, None, (qc, jnp.arange(n_q)))
    return out.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, d)


def multihead_attention(q, k, v, *, causal: bool, window: int | None) -> jax.Array:
    """Dispatch on sequence length / window. kv heads already repeated to q heads."""
    sq, sk = q.shape[1], k.shape[1]
    if window is not None and sk > window + Q_CHUNK and sq == sk:
        return attend_windowed(q, k, v, window=window)
    if max(sq, sk) <= DENSE_MAX:
        return attend_dense(q, k, v, causal=causal, window=window)
    return attend_chunked_full(q, k, v, causal=causal)


def decode_attend_ring(q, k_ring, v_ring, cache_len, *, window: int) -> jax.Array:
    """One-token decode over a ring-buffer cache of exactly ``window`` slots.

    Slot j holds absolute position  pos_j = L - ((L % W - j) mod W)  where L is
    the position of the just-written token (= cache_len).  Slots with pos < 0
    (cold start) are masked.  No sequence gather: the ring is the window.
    """
    b, _, h, d = q.shape
    w = k_ring.shape[1]
    assert w == window, (w, window)
    slot = jnp.mod(cache_len, w)
    j = jnp.arange(w)
    pos = cache_len - jnp.mod(slot - j, w)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k_ring).astype(jnp.float32) / np.sqrt(d)
    s = jnp.where((pos >= 0)[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v_ring)


def decode_attend(q, k_cache, v_cache, cache_len, *, window: int | None) -> jax.Array:
    """One-token decode. q (B,1,H,D); caches (B,Smax,Hkv_rep,D); cache_len scalar.

    For windowed attention only the last ``window`` entries are read
    (static-size dynamic slice) — the long_500k path.
    """
    b, _, h, d = q.shape
    smax = k_cache.shape[1]
    if window is not None and smax > window:
        # slice [cache_len - window, cache_len) clamped; positions tracked for mask
        start = jnp.maximum(cache_len - window, 0)
        kb = jax.lax.dynamic_slice_in_dim(k_cache, start, window, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(v_cache, start, window, axis=1)
        kpos = start + jnp.arange(window)
    else:
        kb, vb = k_cache, v_cache
        kpos = jnp.arange(smax)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kb).astype(jnp.float32) / np.sqrt(d)
    msk = kpos < cache_len
    s = jnp.where(msk[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vb)

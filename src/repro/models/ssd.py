"""Mamba-2 / SSD (state-space duality) block, chunked parallel scan.

Follows arXiv:2405.21060 (Dao & Gu, "Transformers are SSMs"):
  h_t = exp(dt_t·A) h_{t-1} + dt_t · B_t ⊗ x_t        (per head, state N)
  y_t = C_t · h_t + D ⊙ x_t
Chunked form: within-chunk attention-like term + cross-chunk state recurrence
(``lax.scan`` over chunks).  Single B/C group (ngroups=1) as in mamba2-780m.

Params are separate projections (w_z/w_x/w_B/w_C/w_dt) instead of the fused
in_proj so tensor-parallel sharding can target head-aligned dims — see
DESIGN.md hardware-adaptation notes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import dense_init


def init_ssd(key, d_model: int, cfg, dtype):
    """cfg: SSMConfig."""
    d_in = cfg.expand * d_model
    nheads = d_in // cfg.head_dim
    ks = jax.random.split(key, 8)
    dt_init = jnp.log(jnp.expm1(jnp.exp(
        jax.random.uniform(ks[5], (nheads,), jnp.float32,
                           np.log(1e-3), np.log(1e-1)))))
    return {
        "w_z": dense_init(ks[0], d_model, d_in, dtype),
        "w_x": dense_init(ks[1], d_model, d_in, dtype),
        "w_B": dense_init(ks[2], d_model, cfg.d_state, dtype),
        "w_C": dense_init(ks[3], d_model, cfg.d_state, dtype),
        "w_dt": dense_init(ks[4], d_model, nheads, dtype),
        "dt_bias": dt_init.astype(jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nheads)).astype(jnp.float32),
        "D": jnp.ones((nheads,), jnp.float32),
        "conv_w": (jax.random.normal(ks[6], (cfg.d_conv, d_in + 2 * cfg.d_state), jnp.float32)
                   * 0.1).astype(dtype),
        "w_out": dense_init(ks[7], d_in, d_model, dtype),
    }


def _causal_conv(u: jax.Array, w: jax.Array, init_state: jax.Array | None = None):
    """Depthwise causal conv. u (B,S,C), w (K,C). Returns (y, last K-1 inputs)."""
    k = w.shape[0]
    if init_state is None:
        init_state = jnp.zeros((u.shape[0], k - 1, u.shape[2]), u.dtype)
    up = jnp.concatenate([init_state, u], axis=1)
    y = sum(up[:, i:i + u.shape[1]] * w[i][None, None] for i in range(k))
    return jax.nn.silu(y), up[:, -(k - 1):]


def _segsum(a: jax.Array) -> jax.Array:
    """a: (..., Q) -> (..., Q, Q) lower-triangular segment sums:
    out[i,j] = sum_{j<k<=i} a[k], -inf above diagonal."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, D, *, chunk: int, init_state=None):
    """SSD forward.

    x  (b, s, h, p)   dt (b, s, h)    A (h,) [negative]
    B  (b, s, n)      C (b, s, n)     D (h,)
    Returns y (b, s, h, p), final_state (b, h, p, n).
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc = B.reshape(b, nc, chunk, n)
    Cc = C.reshape(b, nc, chunk, n)

    a = dtc * A[None, None, None]                      # (b,nc,q,h) log-decay
    a_h = a.transpose(0, 1, 3, 2)                      # (b,nc,h,q)
    a_cum = jnp.cumsum(a_h, axis=-1)                   # within-chunk cumulative

    # ---- intra-chunk (diagonal blocks): attention-like with decay mask
    L = jnp.exp(_segsum(a_h))                          # (b,nc,h,q,q)
    scores = jnp.einsum("bcin,bcjn->bcij", Cc.astype(jnp.float32), Bc.astype(jnp.float32))
    Ydiag = jnp.einsum("bchij,bcij,bcjh,bcjhp->bcihp",
                       L, scores, dtc.astype(jnp.float32), xc.astype(jnp.float32))

    # ---- chunk states: state contributed by each chunk
    decay_to_end = jnp.exp(a_cum[..., -1:] - a_cum)    # (b,nc,h,q)
    states = jnp.einsum("bcqn,bchq,bcqh,bcqhp->bchpn",
                        Bc.astype(jnp.float32), decay_to_end, dtc.astype(jnp.float32),
                        xc.astype(jnp.float32))

    # ---- inter-chunk recurrence over chunk index
    chunk_decay = jnp.exp(a_cum[..., -1])              # (b,nc,h) total chunk decay
    if init_state is None:
        init_state = jnp.zeros((b, h, p, n), jnp.float32)

    def step(carry, inp):
        st_in = carry                                  # (b,h,p,n)
        dec, st_c = inp                                # (b,h), (b,h,p,n)
        new = st_in * dec[..., None, None] + st_c
        return new, st_in                              # emit state seen by chunk

    _, prev_states = jax.lax.scan(
        step, init_state,
        (chunk_decay.transpose(1, 0, 2), states.transpose(1, 0, 2, 3, 4)),
    )
    final_state = init_state * 0  # placeholder replaced below
    # recompute final state (scan emitted the *incoming* state of each chunk)
    last_in = prev_states[-1]
    final_state = last_in * chunk_decay[:, -1][..., None, None] + states[:, -1]
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (b,nc,h,p,n)

    # ---- inter-chunk output: decayed incoming state read by C
    in_decay = jnp.exp(a_cum)                          # decay from chunk start to q
    Yoff = jnp.einsum("bcqn,bchq,bchpn->bcqhp", Cc.astype(jnp.float32), in_decay, prev_states)

    y = Ydiag + Yoff + (x.astype(jnp.float32) * D[None, None, :, None]).reshape(b, nc, chunk, h, p)
    return y.reshape(b, s, h, p).astype(x.dtype), final_state


def apply_ssd(params, x, cfg, *, state=None, conv_state=None):
    """Full mamba2 mixer. x (b, s, d_model) -> (b, s, d_model).

    Returns (y, (ssm_state, conv_state)) for decode continuation.
    """
    d_model = x.shape[-1]
    d_in = cfg.expand * d_model
    h = d_in // cfg.head_dim
    n = cfg.d_state

    z = x @ params["w_z"]                               # gate
    xbc = jnp.concatenate(
        [x @ params["w_x"], x @ params["w_B"], x @ params["w_C"]], axis=-1)
    xbc, new_conv = _causal_conv(xbc, params["conv_w"], conv_state)
    xs, B, C = jnp.split(xbc, [d_in, d_in + n], axis=-1)

    dt = jax.nn.softplus((x @ params["w_dt"]).astype(jnp.float32)
                         + params["dt_bias"][None, None])
    A = -jnp.exp(params["A_log"])

    # pad sequence to a chunk multiple; dt=0 on padding makes padded steps
    # identity transitions (decay=1, zero contribution), so the final state is
    # exact for decode continuation.
    s_len = xs.shape[1]
    chunk = min(cfg.chunk, s_len)
    pad = (-s_len) % chunk
    if pad:
        xs_p = jnp.pad(xs, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    else:
        xs_p = xs

    xh = xs_p.reshape(*xs_p.shape[:-1], h, cfg.head_dim)
    y, new_state = ssd_chunked(xh, dt, A, B, C, params["D"],
                               chunk=chunk, init_state=state)
    y = y.reshape(xs_p.shape[0], xs_p.shape[1], d_in)[:, :s_len]
    y = y * jax.nn.silu(z)
    return y @ params["w_out"], (new_state, new_conv)


def ssd_decode_step(params, x, cfg, state, conv_state):
    """Single-token recurrent step. x (b, 1, d_model)."""
    d_model = x.shape[-1]
    d_in = cfg.expand * d_model
    h = d_in // cfg.head_dim
    n = cfg.d_state

    z = x @ params["w_z"]
    xbc = jnp.concatenate(
        [x @ params["w_x"], x @ params["w_B"], x @ params["w_C"]], axis=-1)
    xbc, new_conv = _causal_conv(xbc, params["conv_w"], conv_state)
    xs, B, C = jnp.split(xbc, [d_in, d_in + n], axis=-1)

    dt = jax.nn.softplus((x @ params["w_dt"]).astype(jnp.float32)
                         + params["dt_bias"][None, None])[:, 0]      # (b,h)
    A = -jnp.exp(params["A_log"])
    xh = xs[:, 0].reshape(-1, h, cfg.head_dim).astype(jnp.float32)   # (b,h,p)
    Bt = B[:, 0].astype(jnp.float32)                                 # (b,n)
    Ct = C[:, 0].astype(jnp.float32)

    decay = jnp.exp(dt * A[None])                                    # (b,h)
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt, xh, Bt)
    new_state = state * decay[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", new_state, Ct) + xh * params["D"][None, :, None]
    y = y.reshape(x.shape[0], 1, d_in).astype(x.dtype)
    y = y * jax.nn.silu(z)
    return y @ params["w_out"], (new_state, new_conv)

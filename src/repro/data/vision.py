"""Class-structured Gaussian surrogates for CIFAR10 / FashionMNIST.

The real datasets are not available offline (DESIGN.md §8); these surrogates
keep exactly what FedGS interacts with — label-skewed federated partitions
with controllable heterogeneity — while remaining learnable by the same small
CNNs.  Each class c has a random template mu_c; samples are mu_c + noise.
"""
from __future__ import annotations

import numpy as np

from repro.data.fed_dataset import FedDataset
from repro.data.partition import (
    dirichlet_label_partition, lognormal_sizes, two_label_partition,
)

NUM_CLASSES = 10


def _class_gaussian(n: int, shape: tuple[int, ...], rng, noise: float = 2.0):
    # noise 2.0 keeps the surrogate task non-trivial (val loss plateaus well
    # above zero) so sampler differences stay visible, matching the paper's
    # loss scale more closely than an easily-separable mixture would.
    templates = rng.normal(0, 1.0, (NUM_CLASSES, *shape)).astype(np.float32)
    y = rng.integers(0, NUM_CLASSES, n).astype(np.int32)
    x = templates[y] + rng.normal(0, noise, (n, *shape)).astype(np.float32)
    return x, y


def make_cifar_like(n_clients: int = 100, n_total: int = 20000,
                    dir_alpha: float = 1.75, seed: int = 0,
                    shape=(8, 8, 3), val_frac: float = 0.1,
                    noise: float = 2.0) -> FedDataset:
    """CIFAR10-style: lognormal sizes + Dir(alpha p*) label skew.

    (surrogate resolution 8x8x3 keeps CPU experiments fast; the partition
    statistics — the thing FedGS sees — match the paper's recipe.)"""
    rng = np.random.default_rng(seed)
    x, y = _class_gaussian(n_total, shape, rng, noise)
    n_val = int(n_total * val_frac)
    xv, yv = x[:n_val], y[:n_val]
    x, y = x[n_val:], y[n_val:]
    sizes = lognormal_sizes(len(y), n_clients, rng)
    parts = dirichlet_label_partition(y, n_clients, dir_alpha, rng, sizes)
    xs = [x[ix] for ix in parts]
    ys = [y[ix] for ix in parts]
    return FedDataset.from_lists(xs, ys, xv, yv, NUM_CLASSES)


def make_fashion_like(n_clients: int = 100, n_total: int = 20000,
                      seed: int = 0, shape=(8, 8, 1),
                      val_frac: float = 0.1) -> FedDataset:
    """FashionMNIST-style: equal sizes, two labels per client."""
    rng = np.random.default_rng(seed)
    x, y = _class_gaussian(n_total, shape, rng)
    n_val = int(n_total * val_frac)
    xv, yv = x[:n_val], y[:n_val]
    x, y = x[n_val:], y[n_val:]
    parts = two_label_partition(y, n_clients, rng)
    xs = [x[ix] for ix in parts]
    ys = [y[ix] for ix in parts]
    return FedDataset.from_lists(xs, ys, xv, yv, NUM_CLASSES)

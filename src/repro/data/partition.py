"""Federated partitioners (paper Appendix C)."""
from __future__ import annotations

import numpy as np


def lognormal_sizes(n_total: int, n_clients: int, rng) -> np.ndarray:
    """n_k ~ lognormal(log(n/N) - 0.5, 1), rescaled to sum to n_total."""
    mean = np.log(n_total / n_clients) - 0.5
    sizes = rng.lognormal(mean, 1.0, n_clients)
    sizes = np.maximum((sizes / sizes.sum() * n_total).astype(int), 8)
    return sizes


def dirichlet_label_partition(labels: np.ndarray, n_clients: int,
                              alpha: float, rng,
                              sizes: np.ndarray | None = None):
    """Per-client label distribution p_k ~ Dir(alpha * p*), matched to the
    allocated local sizes (paper loops re-drawing until feasible; we greedily
    cap draws by remaining per-class budget, same effect)."""
    classes = np.unique(labels)
    c = len(classes)
    p_star = np.array([(labels == cl).mean() for cl in classes])
    by_class = {cl: list(rng.permutation(np.flatnonzero(labels == cl))) for cl in classes}
    if sizes is None:
        sizes = np.full(n_clients, len(labels) // n_clients)

    client_idx = [[] for _ in range(n_clients)]
    for k in range(n_clients):
        pk = rng.dirichlet(alpha * p_star * c + 1e-9)
        want = rng.multinomial(sizes[k], pk)
        for ci, cl in enumerate(classes):
            take = min(want[ci], len(by_class[cl]))
            for _ in range(take):
                client_idx[k].append(by_class[cl].pop())
        # top up from whatever classes still have items
        while len(client_idx[k]) < sizes[k]:
            nonempty = [cl for cl in classes if by_class[cl]]
            if not nonempty:
                break
            cl = nonempty[int(rng.integers(len(nonempty)))]
            client_idx[k].append(by_class[cl].pop())
    return [np.array(ix, dtype=int) for ix in client_idx]


def two_label_partition(labels: np.ndarray, n_clients: int, rng):
    """McMahan-style pathological split: equal sizes, two labels per client."""
    classes = np.unique(labels)
    n_shards = 2 * n_clients
    # sort by label, split into shards, deal 2 shards per client
    order = np.argsort(labels, kind="stable")
    shards = np.array_split(order, n_shards)
    perm = rng.permutation(n_shards)
    return [np.concatenate([shards[perm[2 * k]], shards[perm[2 * k + 1]]])
            for k in range(n_clients)]

"""The paper's Synthetic(alpha, beta) dataset — exact recipe (Appendix C /
Li et al. 2020):

  W_k[i,j] ~ N(mu_k, 1), b_k[i] ~ N(mu_k, 1),  mu_k ~ N(0, alpha)
  v_k[i] ~ N(B_k, 1), B_k ~ N(0, beta),  x_{k,i} ~ N(v_k, Sigma),
  Sigma = diag(i^{-1.2}),  y = argmax softmax(W_k x + b_k)
  n_k ~ lognormal(4, 2)   (30 clients, alpha = beta = 0.5)
"""
from __future__ import annotations

import numpy as np

from repro.data.fed_dataset import FedDataset

DIM = 60
NUM_CLASSES = 10


def make_synthetic(alpha: float = 0.5, beta: float = 0.5, n_clients: int = 30,
                   seed: int = 0, val_frac: float = 0.2,
                   min_size: int = 20, max_size: int = 2000) -> FedDataset:
    rng = np.random.default_rng(seed)
    sigma = np.diag(np.arange(1, DIM + 1, dtype=np.float64) ** (-1.2))

    xs, ys = [], []
    opt_params = []     # the per-client local-optimal (W_k, b_k) — 3DG oracle features
    sizes = np.clip(rng.lognormal(4.0, 2.0, n_clients).astype(int), min_size, max_size)
    for k in range(n_clients):
        mu_k = rng.normal(0.0, np.sqrt(alpha))
        w_k = rng.normal(mu_k, 1.0, (NUM_CLASSES, DIM))
        b_k = rng.normal(mu_k, 1.0, NUM_CLASSES)
        bb_k = rng.normal(0.0, np.sqrt(beta))
        v_k = rng.normal(bb_k, 1.0, DIM)
        n_k = int(sizes[k])
        x = rng.multivariate_normal(v_k, sigma, n_k).astype(np.float32)
        logits = x @ w_k.T + b_k
        y = np.argmax(logits, axis=1).astype(np.int32)
        xs.append(x)
        ys.append(y)
        opt_params.append(np.concatenate([w_k.ravel(), b_k]))

    # shared validation set: held-out slice from every client
    xv, yv = [], []
    for k in range(n_clients):
        m = max(1, int(len(xs[k]) * val_frac))
        xv.append(xs[k][-m:]); yv.append(ys[k][-m:])
        xs[k] = xs[k][:-m]; ys[k] = ys[k][:-m]
    ds = FedDataset.from_lists(xs, ys, np.concatenate(xv), np.concatenate(yv),
                               NUM_CLASSES)
    ds.opt_params = np.stack(opt_params)    # oracle features for the 3DG
    return ds

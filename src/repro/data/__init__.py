from repro.data.fed_dataset import FedDataset
from repro.data.synthetic import make_synthetic
from repro.data.vision import make_cifar_like, make_fashion_like
from repro.data.partition import dirichlet_label_partition, two_label_partition, lognormal_sizes
from repro.data.lm_stream import token_batches

"""Federated dataset container: per-client data padded into stacked arrays so
client-local training can be a single vmap'd XLA program (DESIGN.md §3)."""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class FedDataset:
    """x: (N, n_max, ...) padded features; y: (N, n_max) labels;
    sizes: (N,) true local sizes; plus a shared validation split."""
    x: np.ndarray
    y: np.ndarray
    sizes: np.ndarray
    x_val: np.ndarray
    y_val: np.ndarray
    num_classes: int
    label_dist: np.ndarray = field(default=None)   # (N, C) true label histograms

    @property
    def n_clients(self) -> int:
        return len(self.sizes)

    def label_sets(self) -> list[set[int]]:
        return [set(np.unique(self.y[k][: self.sizes[k]]).tolist())
                for k in range(self.n_clients)]

    @staticmethod
    def from_lists(xs: list[np.ndarray], ys: list[np.ndarray], x_val, y_val,
                   num_classes: int) -> "FedDataset":
        n = len(xs)
        n_max = max(len(x) for x in xs)
        feat_shape = xs[0].shape[1:]
        x = np.zeros((n, n_max, *feat_shape), xs[0].dtype)
        y = np.zeros((n, n_max), np.int32)
        sizes = np.zeros(n, np.int64)
        dist = np.zeros((n, num_classes))
        for k, (xk, yk) in enumerate(zip(xs, ys)):
            m = len(xk)
            x[k, :m] = xk
            y[k, :m] = yk
            sizes[k] = m
            for c in range(num_classes):
                dist[k, c] = float(np.sum(yk == c))
        return FedDataset(x, y, sizes, np.asarray(x_val), np.asarray(y_val),
                          num_classes, dist)

"""Synthetic token streams for LM training examples (no corpora offline).

A per-client order-1 Markov chain over the vocabulary gives each federated
client a distinct, *learnable* token distribution — the LM analogue of label
skew, so FedGS's 3DG has real structure to discover.
"""
from __future__ import annotations

import numpy as np


def client_transition(vocab: int, n_modes: int, rng, concentration: float = 0.3):
    """Sparse-ish row-stochastic transition with ``n_modes`` preferred targets
    per token (cheap to sample from)."""
    prefer = rng.integers(0, vocab, (vocab, n_modes))
    return prefer


def sample_stream(prefer: np.ndarray, length: int, rng,
                  p_follow: float = 0.85) -> np.ndarray:
    vocab, n_modes = prefer.shape
    out = np.empty(length, np.int32)
    tok = int(rng.integers(vocab))
    for i in range(length):
        out[i] = tok
        if rng.random() < p_follow:
            tok = int(prefer[tok, rng.integers(n_modes)])
        else:
            tok = int(rng.integers(vocab))
    return out


def token_batches(vocab: int, n_clients: int, tokens_per_client: int,
                  seq_len: int, seed: int = 0):
    """Returns tokens (N, n_seq, S+1) int32 — per-client sequence pools.
    batch = {tokens: seq[:, :-1], labels: seq[:, 1:]}."""
    rng = np.random.default_rng(seed)
    n_seq = tokens_per_client // (seq_len + 1)
    out = np.empty((n_clients, n_seq, seq_len + 1), np.int32)
    for k in range(n_clients):
        prefer = client_transition(vocab, n_modes=3, rng=rng)
        stream = sample_stream(prefer, n_seq * (seq_len + 1), rng)
        out[k] = stream.reshape(n_seq, seq_len + 1)
    return out

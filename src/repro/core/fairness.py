"""Fairness / long-term-bias metrics (paper Eq. 6, Fig. 4)."""
from __future__ import annotations

import numpy as np


def count_variance(counts: np.ndarray) -> float:
    """Var(v^t) with the paper's 1/(N-1) normalization (Eq. 6)."""
    v = np.asarray(counts, np.float64)
    n = len(v)
    return float(np.sum((v - v.mean()) ** 2) / max(n - 1, 1))


def count_range(counts: np.ndarray) -> int:
    v = np.asarray(counts)
    return int(v.max() - v.min())


def gini(counts: np.ndarray) -> float:
    """Gini coefficient of the sampling counts (0 = perfectly fair)."""
    v = np.sort(np.asarray(counts, np.float64))
    n = len(v)
    if v.sum() == 0:
        return 0.0
    cum = np.cumsum(v)
    return float((n + 1 - 2 * np.sum(cum) / cum[-1]) / n)

"""Fairness / long-term-bias metrics (paper Eq. 6, Fig. 4).

Each metric has a host (numpy, float64) face and a device twin
(``*_device``, jnp float32, jit/vmap/scan-traceable) — the scan engine
emits the device versions per round (``ScanHistory.count_var`` /
``.gini``), the host engine and benchmarks use the numpy faces.  Parity is
pinned by ``tests/test_scan_engine.py`` on integer and zero-count inputs
(f32 vs f64 round-off only).
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp


def count_variance(counts: np.ndarray) -> float:
    """Var(v^t) with the paper's 1/(N-1) normalization (Eq. 6)."""
    v = np.asarray(counts, np.float64)
    n = len(v)
    return float(np.sum((v - v.mean()) ** 2) / max(n - 1, 1))


def count_range(counts: np.ndarray) -> int:
    v = np.asarray(counts)
    return int(v.max() - v.min())


def gini(counts: np.ndarray) -> float:
    """Gini coefficient of the sampling counts (0 = perfectly fair)."""
    v = np.sort(np.asarray(counts, np.float64))
    n = len(v)
    if v.sum() == 0:
        return 0.0
    cum = np.cumsum(v)
    return float((n + 1 - 2 * np.sum(cum) / cum[-1]) / n)


# -------------------------------------------------------------- device twins
def count_variance_device(counts) -> jnp.ndarray:
    """The jnp twin of :func:`count_variance` — the EXACT expression the
    scan engine used to inline (bit-identical count_var histories)."""
    v = jnp.asarray(counts)
    n = v.shape[-1]
    return jnp.sum((v - v.mean()) ** 2) / max(n - 1, 1)


def count_range_device(counts) -> jnp.ndarray:
    v = jnp.asarray(counts)
    return v.max() - v.min()


def gini_device(counts) -> jnp.ndarray:
    """The jnp twin of :func:`gini`; the zero-sum guard is a ``where`` over
    a 1e-12-floored denominator (branchless, scan-safe)."""
    v = jnp.sort(jnp.asarray(counts, jnp.float32))
    n = v.shape[-1]
    cum = jnp.cumsum(v)
    tot = cum[-1]
    g = (n + 1 - 2.0 * jnp.sum(cum) / jnp.maximum(tot, 1e-12)) / n
    return jnp.where(tot > 0, g, 0.0)

"""3DG — Data-Distribution-Dependency Graph construction (paper §3.2).

Pipeline: client feature vectors U -> similarity matrix V (normalized to
[0,1]) -> adjacency R via
    R_ij = 0                 if i == j
    R_ij = exp(-V_ij/sigma²) if V_ij >= eps     (similar => short edge)
    R_ij = inf               if V_ij <  eps     (no edge)
-> all-pairs shortest-path matrix H (Floyd–Warshall; the Pallas blocked
kernel in ``repro.kernels`` accelerates this at datacenter client counts).

Similarity sources:
  * ``oracle_similarity``      — true label-distribution / feature dot products
  * ``sspp_similarity``        — the same dot products computed through the
                                 secure-scalar-product protocol (core/sspp.py)
  * ``functional_similarity``  — Eq. 12: cosine of model outputs on a shared
                                 Gaussian probe batch
  * ``update_cosine_similarity`` — Eq. 11: cosine of raw model updates
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp


# ------------------------------------------------------------- similarities
def normalize_01(v: np.ndarray) -> np.ndarray:
    """Paper Appendix C: min-max normalize similarities to [0, 1]."""
    lo, hi = v.min(), v.max()
    if hi - lo < 1e-12:
        return np.zeros_like(v)
    return (v - lo) / (hi - lo)


def oracle_similarity(features: np.ndarray, *, kind: str = "dot") -> np.ndarray:
    """features (N, d): label-distribution vectors (or flat local-optimum params)."""
    u = np.asarray(features, np.float64)
    if kind == "cosine":
        u = u / np.maximum(np.linalg.norm(u, axis=1, keepdims=True), 1e-12)
    v = u @ u.T
    return normalize_01(v)


def update_cosine_similarity(updates: np.ndarray) -> np.ndarray:
    """Eq. 11: V_ij = max(cos(Δθ_i, Δθ_j), 0).  updates (N, P) flattened."""
    u = np.asarray(updates, np.float64)
    u = u / np.maximum(np.linalg.norm(u, axis=1, keepdims=True), 1e-12)
    return np.maximum(u @ u.T, 0.0)


def functional_similarity(embeddings: np.ndarray) -> np.ndarray:
    """Eq. 12: V_ij = max(cos(e_i, e_j), 0) where e_i = mean layer-l output of
    client i's model on the shared Gaussian probe batch."""
    return update_cosine_similarity(embeddings)


def probe_embeddings(apply_fn, client_params, probe: np.ndarray) -> np.ndarray:
    """Run each client model on the shared probe; mean output embedding.

    apply_fn(params, probe) -> (batch, dim) activations of the chosen layer
    (the output layer in the paper).  client_params: stacked pytree (N, ...).
    """
    outs = jax.vmap(lambda p: jnp.mean(apply_fn(p, probe), axis=0))(client_params)
    return np.asarray(outs)


# --------------------------------------------------------------- adjacency
def similarity_to_adjacency(v: np.ndarray, *, eps: float = 0.1,
                            sigma2: float = 0.01) -> np.ndarray:
    """V -> R per the paper (inf = no edge).  Diagonal is 0."""
    v = np.asarray(v, np.float64)
    r = np.where(v >= eps, np.exp(-v / sigma2), np.inf)
    np.fill_diagonal(r, 0.0)
    return r


def floyd_warshall_np(r: np.ndarray) -> np.ndarray:
    """Reference APSP (vectorized over k).  inf-safe."""
    h = np.array(r, np.float64, copy=True)
    n = h.shape[0]
    for k in range(n):
        np.minimum(h, h[:, k:k + 1] + h[k:k + 1, :], out=h)
    return h


def shortest_paths(r: np.ndarray, *, use_kernel: bool = False) -> np.ndarray:
    """APSP dispatch: numpy reference or the Pallas blocked kernel."""
    if use_kernel:
        from repro.kernels.ops import floyd_warshall
        return np.asarray(floyd_warshall(jnp.asarray(r, jnp.float32)))
    return floyd_warshall_np(r)


def finite_cap(h: np.ndarray, scale: float = 2.0) -> np.ndarray:
    """Replace inf distances (disconnected pairs) with scale x max finite
    distance so the QUBO objective stays finite while still strongly
    preferring disconnected (= maximally dissimilar) pairs."""
    finite = h[np.isfinite(h)]
    cap = (finite.max() if finite.size else 1.0) * scale
    out = np.where(np.isfinite(h), h, cap)
    np.fill_diagonal(out, 0.0)
    return out


def build_3dg(features: np.ndarray, *, eps: float = 0.1, sigma2: float = 0.01,
              sim_kind: str = "dot", use_kernel: bool = False):
    """features -> (V, R, H).  The one-call oracle-3DG constructor."""
    v = oracle_similarity(features, kind=sim_kind)
    r = similarity_to_adjacency(v, eps=eps, sigma2=sigma2)
    h = shortest_paths(r, use_kernel=use_kernel)
    return v, r, h


# --------------------------------------------------- graph-quality metrics
def edge_f1(r_pred: np.ndarray, r_true: np.ndarray) -> tuple[float, float, float]:
    """Precision/recall/F1 of predicted edges vs the oracle 3DG (Table 3)."""
    pred = np.isfinite(r_pred) & (~np.eye(len(r_pred), dtype=bool))
    true = np.isfinite(r_true) & (~np.eye(len(r_true), dtype=bool))
    tp = float(np.sum(pred & true))
    prec = tp / max(float(np.sum(pred)), 1e-12)
    rec = tp / max(float(np.sum(true)), 1e-12)
    f1 = 2 * prec * rec / max(prec + rec, 1e-12)
    return prec, rec, f1

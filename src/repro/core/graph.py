"""3DG — numpy-facing wrappers over the device-native pipeline.

The actual graph math (similarity -> adjacency -> Floyd–Warshall -> finite
cap / normalize) lives in ONE place: ``repro.core.graph_device`` (stages,
backend dispatch) backed by ``repro.kernels`` (Pallas) and
``repro.kernels.ref`` (jnp oracle).  This module keeps the host-side
conveniences: the similarity *sources* and numpy-in / numpy-out wrappers
for the host engine, the benchmarks, and the graph-quality metrics.

Similarity sources:
  * ``oracle_similarity``      — true label-distribution / feature dot products
  * ``sspp_similarity``        — the same dot products computed through the
                                 secure-scalar-product protocol (core/sspp.py)
  * ``functional_similarity``  — Eq. 12: cosine of model outputs on a shared
                                 Gaussian probe batch
  * ``update_cosine_similarity`` — Eq. 11: cosine of raw model updates
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import graph_device as gd


# ------------------------------------------------------------- similarities
def normalize_01(v: np.ndarray) -> np.ndarray:
    """Paper Appendix C: min-max normalize similarities to [0, 1]."""
    return np.asarray(gd.minmax01(jnp.asarray(v, jnp.float32)))


def oracle_similarity(features: np.ndarray, *, kind: str = "dot") -> np.ndarray:
    """features (N, d): label-distribution vectors (or flat local-optimum
    params) -> normalized similarity."""
    u = jnp.asarray(features, jnp.float32)
    v = gd.dot_sim(u) if kind == "dot" else gd.cosine_sim(u, clamp=False)
    return np.asarray(gd.minmax01(v))


def update_cosine_similarity(updates: np.ndarray) -> np.ndarray:
    """Eq. 11: V_ij = max(cos(Δθ_i, Δθ_j), 0).  updates (N, P) flattened."""
    return np.asarray(gd.cosine_sim(jnp.asarray(updates, jnp.float32)))


def functional_similarity(embeddings: np.ndarray) -> np.ndarray:
    """Eq. 12: V_ij = max(cos(e_i, e_j), 0) where e_i = mean layer-l output of
    client i's model on the shared Gaussian probe batch."""
    return update_cosine_similarity(embeddings)


def probe_embeddings(apply_fn, client_params, probe: np.ndarray) -> np.ndarray:
    """Run each client model on the shared probe; mean output embedding.

    apply_fn(params, probe) -> (batch, dim) activations of the chosen layer
    (the output layer in the paper).  client_params: stacked pytree (N, ...).
    """
    outs = jax.vmap(lambda p: jnp.mean(apply_fn(p, probe), axis=0))(client_params)
    return np.asarray(outs)


# --------------------------------------------------------------- adjacency
def similarity_to_adjacency(v: np.ndarray, *, eps: float = 0.1,
                            sigma2: float = 0.01) -> np.ndarray:
    """Normalized V -> R per the paper (inf = no edge).  Diagonal is 0."""
    return np.asarray(gd.to_adjacency(jnp.asarray(v, jnp.float32),
                                      eps=eps, sigma2=sigma2))


def shortest_paths(r: np.ndarray, *, backend: str = "ref") -> np.ndarray:
    """APSP: the jnp reference closure or the Pallas blocked kernel."""
    return np.asarray(gd.apsp(jnp.asarray(r, jnp.float32), backend=backend))


def finite_cap(h: np.ndarray, scale: float = 2.0) -> np.ndarray:
    """Replace inf distances (disconnected pairs) with scale x max finite
    distance so the QUBO objective stays finite while still strongly
    preferring disconnected (= maximally dissimilar) pairs."""
    return np.asarray(gd.cap_and_normalize(jnp.asarray(h, jnp.float32),
                                           scale=scale, normalize=False))


def build_3dg(features: np.ndarray, *, eps: float = 0.1, sigma2: float = 0.01,
              sim_kind: str = "dot", backend: str = "ref"):
    """features -> (V, R, H).  The one-call oracle-3DG constructor."""
    cfg = gd.GraphConfig(eps=eps, sigma2=sigma2, similarity=sim_kind)
    v, r, h = gd.build_3dg(jnp.asarray(features, jnp.float32), cfg,
                           backend=backend)
    return np.asarray(v), np.asarray(r), np.asarray(h)


# --------------------------------------------------- graph-quality metrics
def edge_f1(r_pred: np.ndarray, r_true: np.ndarray) -> tuple[float, float, float]:
    """Precision/recall/F1 of predicted edges vs the oracle 3DG (Table 3)."""
    pred = np.isfinite(r_pred) & (~np.eye(len(r_pred), dtype=bool))
    true = np.isfinite(r_true) & (~np.eye(len(r_true), dtype=bool))
    tp = float(np.sum(pred & true))
    prec = tp / max(float(np.sum(pred)), 1e-12)
    rec = tp / max(float(np.sum(true)), 1e-12)
    f1 = 2 * prec * rec / max(prec + rec, 1e-12)
    return prec, rec, f1

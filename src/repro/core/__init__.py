# The paper's primary contribution: FedGS — graph-based client sampling
# with arbitrary client availability (3DG + APSP + QUBO sampler + the
# seven availability modes + fairness metrics + SSPP graph construction).
from repro.core.availability import (
    make_mode, ALL_MODES, AvailabilityMode, ProcessMode, host_draw,
    host_trace,
)
from repro.core.availability_device import (
    ALL_SCENARIOS, AvailabilityProcess, TableProcess, GilbertElliott,
    ClusterOutage, DriftProcess, DeadlineProcess, make_process, proc_draw,
    proc_step, device_trace,
)
from repro.core.graph import (
    build_3dg, similarity_to_adjacency, shortest_paths,
    oracle_similarity, update_cosine_similarity, functional_similarity,
    finite_cap, edge_f1, normalize_01,
)
from repro.core.graph_device import (
    GraphConfig, build_h, cap_and_normalize, to_adjacency, minmax01, apsp,
)
from repro.core.sampler import (
    Sampler, UniformSampler, MDSampler, PowerOfChoiceSampler, FedGSSampler,
    make_sampler,
)
from repro.core.sampler_device import (
    SamplerProcess, UniformProcess, MDProcess, PoCProcess, FedGSProcess,
    make_sampler_process, make_sampler_step, fedgs_select, fedgs_solve,
    gumbel_topk_select, uniform_select, md_select,
)
from repro.core.fairness import count_variance, count_range, gini
from repro.core.sspp import secure_dot, secure_similarity_matrix

"""Device-native sampler subsystem.

The availability refactor's sampler twin (DESIGN.md §11): ONE pure,
jit/vmap/scan-traceable implementation of every client sampler — the paper's
FedGS Eq. 16 solver and the Table-2 baselines — that the scan engine carries
through ``lax.scan``, the host classes wrap in numpy (``core/sampler.py``),
and mixed-sampler sweep cells batch through a single ``run_batch`` program.

A :class:`SamplerProcess` is

    ``init(key) -> state``                                    (eager, host)
    ``select(state, key, inputs, avail, t) -> (s, state)``    (pure, traceable)

where ``inputs`` is the per-round context dict the engine assembles
(``{"h", "counts", "params", ...}``), ``s`` is the (N,) bool selection mask
with ``|s| = min(m, |A_t|)``, and every family compiles to ONE
``lax.switch`` branch index (:func:`make_sampler_step`) so cells of
DIFFERENT samplers vmap-batch together — previously sampler choice was a
per-cell Python branch and only availability heterogeneity batched.

Families (``FAMILIES`` — the switch order; it matches the scan engine's
``SAMPLERS`` knob):

  ======== ==================== ==========================================
  family   process              selection rule
  ======== ==================== ==========================================
  fedgs    FedGSProcess         Eq. 14/16: Q = sym(α/N·H) − diag(z), then
                                the greedy + best-swap p-dispersion solve
                                (α-variants batch via the per-cell alpha)
  uniform  UniformProcess       Gumbel top-m, equal weights (McMahan 2017)
  md       MDProcess            Gumbel top-m, weights ∝ data size (Li 2020)
  poc      PoCProcess           Gumbel top-d·m candidates by size, keep the
                                top-m by probed loss (Cho et al. 2020)
  ======== ==================== ==========================================

The FedGS solver itself dispatches ``backend="ref" | "pallas"`` exactly like
``core/graph_device.build_h``: ``ref`` is the pure-jnp greedy + best-swap
(dense (N, N) Q and delta per sweep); ``pallas`` is Q-FREE — the solve runs
on the factored (H, z, alpha/N) via ``kernels/solver.q_diag``/``q_row``
providers, the greedy blocked masked argmax, and the fused swap kernel that
rebuilds Q tiles in VREGs (``kernels/ops.swap_best_fused``) — neither Q nor
anything else N² is ever materialized, which is what lets the solve run at
N ∈ {4096, 16384} (``benchmarks/sampler_scaling.py``).
Both backends produce BIT-IDENTICAL selected sets (tie-breaks and the NaN
guard are pinned by ``tests/test_sampler_device.py``; DESIGN.md assumption
log #12/#13).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

import jax
import jax.numpy as jnp
from functools import partial

FAMILIES = ("fedgs", "uniform", "md", "poc")
BACKENDS = ("ref", "pallas")

# masked-entry sentinel (== kernels/solver.NEG).  A PYTHON float, never a
# module-level jnp constant: this module may first be imported inside an
# active jit trace (launch.fedsim defers its import), where jnp scalars
# materialize as tracers and would leak out of the trace.
NEG = -1e18
SWAP_TOL = 1e-9             # a swap must improve Eq. 16 by more than this


# ------------------------------------------------------------ shared helpers
def select_k(s: jax.Array, k: int):
    """Mask (N,) bool -> (sorted selected indices (k,), valid (k,)) — the
    static-shape gather order every layer shares: selected indices ascending,
    then pad slots (``valid`` False) ascending."""
    n = s.shape[0]
    order = jnp.argsort(jnp.where(s, jnp.arange(n), n + jnp.arange(n)))
    sel = order[:k]
    return sel, s[sel]


def log_size_weights(data_sizes) -> jax.Array:
    """The MD/PoC Gumbel log-weights with the degenerate-size guard: the
    ``maximum(·, 1e-12)`` floor turns all-zero data sizes into EQUAL finite
    weights (uniform sampling) instead of NaNs, and zero-size clients keep a
    finite score so they can still fill the mask when fewer than m
    positive-size clients are available."""
    return jnp.log(jnp.maximum(jnp.asarray(data_sizes).astype(jnp.float32),
                               1e-12))


# ------------------------------------------- device-side baseline sampling
def gumbel_topk_select(key: jax.Array, log_weights: jax.Array,
                       avail: jax.Array, m: int) -> jax.Array:
    """Weighted sampling WITHOUT replacement among available clients, fully
    on-device (Gumbel top-k): adding i.i.d. Gumbel noise to log-weights and
    taking the top-m reproduces successive draws without replacement with
    probabilities proportional to the weights.  With uniform weights this is
    ``UniformSampler``; with ``log(data_sizes)`` it is ``MDSampler`` — the
    jit-compatible counterparts used inside ``repro.fed.scan_engine``.

    Returns s (N,) bool with exactly min(m, |avail|) True entries.
    """
    g = jax.random.gumbel(key, log_weights.shape, dtype=jnp.float32)
    scores = jnp.where(avail, log_weights + g, -jnp.inf)
    _, idx = jax.lax.top_k(scores, m)
    valid = avail[idx]                      # fewer than m available -> drop pads
    s = jnp.zeros(log_weights.shape, bool)
    return s.at[idx].set(valid)


def uniform_select(key, avail, m: int):
    """Device-side UniformSampler: uniform without replacement among A_t."""
    return gumbel_topk_select(key, jnp.zeros(avail.shape, jnp.float32), avail, m)


def md_select(key, data_sizes, avail, m: int):
    """Device-side MDSampler: without replacement, P(k) ∝ n_k, among A_t
    (degenerate sizes handled by the :func:`log_size_weights` floor)."""
    return gumbel_topk_select(key, log_size_weights(data_sizes), avail, m)


# ------------------------------------------------------------- FedGS solver
def _solve_ref(q: jax.Array, avail: jax.Array, *, m: int, max_sweeps: int):
    """The pure-jnp oracle: greedy construction + dense best-swap sweeps."""
    n = q.shape[0]
    neg = jnp.float32(NEG)

    # ---------------- greedy construction --------------------------------
    def greedy_step(carry, _):
        s, r = carry                       # s: (N,) bool, r_k = sum_{i in S} Q_ik
        gain = q.diagonal() + 2.0 * r      # marginal gain of adding k
        gain = jnp.where(s | ~avail, neg, gain)
        gain = jnp.where(jnp.isnan(gain), neg, gain)   # NaN guard (log #13)
        k = jnp.argmax(gain)
        ok = gain[k] > neg / 2             # no addable client left => no-op
        s = s.at[k].set(ok | s[k])
        r = r + jnp.where(ok, q[k], 0.0)
        return (s, r), None

    s0 = jnp.zeros((n,), bool)
    r0 = jnp.zeros((n,), jnp.float32)
    (s, r), _ = jax.lax.scan(greedy_step, (s0, r0), None, length=m)

    # ---------------- best-swap local search -----------------------------
    diag = q.diagonal()

    def sweep(carry, _):
        s, r = carry
        # delta(i -> j) = -2 r_i + Q_ii + 2 (r_j - Q_ij) + Q_jj
        out_term = (-2.0 * r + diag)                          # (N,) for i in S
        in_term = (2.0 * r + diag)                            # (N,) for j notin S
        delta = out_term[:, None] + in_term[None, :] - 2.0 * q
        delta = jnp.where(s[:, None], delta, neg)             # i must be in S
        delta = jnp.where((~s & avail)[None, :], delta, neg)  # j must be addable
        delta = jnp.where(jnp.isnan(delta), neg, delta)       # NaN guard
        flat = jnp.argmax(delta)
        i, j = flat // n, flat % n
        best = delta[i, j]

        def do_swap(args):
            s, r = args
            s2 = s.at[i].set(False).at[j].set(True)
            r2 = r - q[i] + q[j]
            return s2, r2

        s, r = jax.lax.cond(best > SWAP_TOL, do_swap, lambda a: a, (s, r))
        return (s, r), best

    (s, r), _ = jax.lax.scan(sweep, (s, r), None, length=max_sweeps)
    return s


def _solve_pallas(diag: jax.Array, row_fn, swap_fn, avail: jax.Array, *,
                  m: int, max_sweeps: int, interpret: bool | None = None):
    """The tiled solve over a PROVIDED Q: same math, same tie-breaks, no
    dense (N, N) intermediates per sweep — and, on the factored path, no
    (N, N) Q at all.  Q enters through three providers:

    diag     (N,) = diag(Q), computed once.
    row_fn   ``row_fn(k) -> (N,)`` row k of Q (the greedy/swap ``r``
             accumulator updates — one row gather per step).
    swap_fn  ``swap_fn(sel, valid, a, b) -> (best, rank, j)`` the best-swap
             reduction over the |S| ≤ m selected rows (``sel`` ascending,
             clamped; ``valid`` marks real rows) — ``kernels/ops.swap_best``
             on a materialized Q panel or ``kernels/ops.swap_best_fused``
             rebuilding Q tiles in VREGs from (H, z, alpha/N).

    greedy   ``kernels/ops.greedy_argmax`` fuses gain + mask + argmax over
             lane blocks; only the selected row of Q is gathered per step.
    sweep    the delta matrix is restricted to the |S| ≤ m SELECTED rows
             (ascending index order keeps the ref path's row-major
             tie-break) — O(mN) traffic instead of O(N²) per sweep.
    """
    from repro.kernels.ops import greedy_argmax
    n = diag.shape[0]
    if m == 0:
        return jnp.zeros((n,), bool)
    neg = jnp.float32(NEG)
    iota = jnp.arange(n)

    def greedy_step(carry, _):
        s, r = carry
        val, k = greedy_argmax(diag, r, avail & ~s, interpret=interpret)
        ok = val > neg / 2
        s = s.at[k].set(ok | s[k])
        r = r + jnp.where(ok, row_fn(k), 0.0)
        return (s, r), None

    s0 = jnp.zeros((n,), bool)
    r0 = jnp.zeros((n,), jnp.float32)
    (s, r), _ = jax.lax.scan(greedy_step, (s0, r0), None, length=m)

    def sweep(carry, _):
        s, r = carry
        out_term = (-2.0 * r + diag)
        in_term = (2.0 * r + diag)
        sel = jnp.sort(jnp.where(s, iota, n))[:m]     # |S| rows, ascending
        valid = sel < n
        selc = jnp.minimum(sel, n - 1)
        a = jnp.where(valid, out_term[selc], neg)     # pad rows can't win
        b = jnp.where(~s & avail, in_term, neg)       # j must be addable
        best, rank, j = swap_fn(selc, valid, a, b)
        i = selc[jnp.minimum(rank, m - 1)]

        def do_swap(args):
            s, r = args
            s2 = s.at[i].set(False).at[j].set(True)
            r2 = r - row_fn(i) + row_fn(j)
            return s2, r2

        s, r = jax.lax.cond(best > SWAP_TOL, do_swap, lambda a_: a_, (s, r))
        return (s, r), best

    (s, r), _ = jax.lax.scan(sweep, (s, r), None, length=max_sweeps)
    return s


def fedgs_solve(q: jax.Array, avail: jax.Array, *, m: int, max_sweeps: int,
                backend: str = "ref", interpret: bool | None = None):
    """Greedy + best-swap local search on  max s^T Q s,  |s| = m,  s <= avail.

    Pure (unjitted) so it can be inlined into larger jit programs — the
    per-round host path wraps it as ``_fedgs_solve`` below; the scan engine
    (``repro.fed.scan_engine``) and the production dry-run
    (``repro.launch.fedsim.graph_pipeline``) call it directly inside their
    own jit scopes.  If fewer than ``m`` clients are available it selects all
    of them (|S| = min(m, |A|)).

    q: (N, N) symmetric with diagonal = -z (counts penalty).
    backend: ``ref`` (pure jnp) or ``pallas`` (tiled kernels; bit-identical
    selected sets, pinned by tests/test_sampler_device.py).
    Returns s (N,) bool.
    """
    if backend == "pallas":
        from repro.kernels.ops import swap_best

        def swap_fn(selc, valid, a, b):
            return swap_best(q[selc], a, b, interpret=interpret)

        return _solve_pallas(q.diagonal(), lambda k: q[k], swap_fn, avail,
                             m=m, max_sweeps=max_sweeps, interpret=interpret)
    if backend != "ref":
        raise ValueError(f"backend must be one of {BACKENDS}, not {backend!r}")
    return _solve_ref(q, avail, m=m, max_sweeps=max_sweeps)


# jit'd entry point for the per-round host path (FedGSSampler.sample).
_fedgs_solve = partial(jax.jit, static_argnames=(
    "m", "max_sweeps", "backend", "interpret"))(fedgs_solve)


def fedgs_select(h: jax.Array, counts: jax.Array, avail: jax.Array,
                 alpha: jax.Array, *, m: int, max_sweeps: int,
                 m_target: int | None = None, backend: str = "ref",
                 interpret: bool | None = None):
    """Eq. 14/16 end-to-end: build Q from (H, counts) and run the solver.

    Pure and float32 throughout — the ONE q-construction both the host
    sampler and the scan engine (repro.fed.scan_engine) trace, so greedy
    argmax near-ties resolve identically on both paths.  ``m`` is the solver
    budget (min(M, |A_t|) on the host path); ``m_target`` is the M used in
    the count-balance penalty z (defaults to ``m``).  The pallas backend is
    Q-FREE: Q never materializes at (N, N) — the solve runs on the factored
    (H, z, alpha/N) via ``kernels/solver.q_diag``/``q_row`` (ref-op-order
    row rebuilds for the greedy accumulator) and the fused swap kernel
    ``kernels/ops.swap_best_fused`` (Q tiles rebuilt in VREGs) —
    bit-identical selected sets by op-order design (pinned by
    tests/test_sampler_device.py).
    """
    n = h.shape[0]
    mt = m if m_target is None else m_target
    z = 2.0 * (counts - counts.mean() - mt / n) + 1.0
    if backend == "pallas":
        from repro.kernels.ops import swap_best_fused
        from repro.kernels.solver import q_diag, q_row
        hf = h.astype(jnp.float32)
        zf = z.astype(jnp.float32)
        al = jnp.float32(alpha / n)

        def swap_fn(selc, valid, a, b):
            return swap_best_fused(hf, zf, al, selc, valid, a, b,
                                   interpret=interpret)

        return _solve_pallas(q_diag(hf, zf, al).astype(jnp.float32),
                             lambda k: q_row(hf, zf, al, k), swap_fn,
                             avail, m=m, max_sweeps=max_sweeps,
                             interpret=interpret)
    if backend != "ref":
        raise ValueError(f"backend must be one of {BACKENDS}, not {backend!r}")
    q = (alpha / n) * h - jnp.diag(z)
    q = 0.5 * (q + q.T)                               # symmetrize (H should be)
    return fedgs_solve(q.astype(jnp.float32), avail, m=m,
                       max_sweeps=max_sweeps, backend=backend,
                       interpret=interpret)


_fedgs_select = partial(jax.jit, static_argnames=(
    "m", "max_sweeps", "m_target", "backend", "interpret"))(fedgs_select)


# ------------------------------------------------------- the switch step
def make_sampler_step(n: int, m: int, *, max_sweeps: int = 32,
                      d_cand: int | None = None, probe_losses=None,
                      solver_backend: str = "ref"):
    """Compile-time constructor of the ONE per-round sampler step

        ``step(sparams, state, key, inputs, avail, t) -> (s, state)``

    dispatching ``lax.switch`` on the cell's family index, so cells of
    DIFFERENT samplers batch through one vmapped program (under vmap the
    switch lowers to a select over all branches; the extra branches' cost is
    small next to local training — DESIGN.md §11).

    ``inputs`` carries the engine-supplied round context: ``h`` (N, N)
    normalized H and ``counts`` (N,) for FedGS, plus whatever
    ``probe_losses(inputs, cidx, keys) -> (d,)`` consumes for the PoC loss
    probe (the scan engine closes over the model and reads
    ``inputs["params"]``; the default reads a precomputed ``inputs
    ["losses"]`` (N,) vector).  ``key`` is the per-round sampler key —
    ``fold_in(sampler_key, t)`` in the scan stream; FedGS ignores it
    (deterministic given (H, counts, A_t)).
    """
    d = int(n if d_cand is None else d_cand)
    if probe_losses is None:
        probe_losses = lambda inputs, cidx, keys: inputs["losses"][cidx]

    def _fedgs(sp, state, key, inputs, avail, t):
        s = fedgs_select(inputs["h"], inputs["counts"], avail, sp["alpha"],
                         m=m, max_sweeps=max_sweeps, backend=solver_backend)
        return s, state

    def _uniform(sp, state, key, inputs, avail, t):
        return uniform_select(key, avail, m), state

    def _md(sp, state, key, inputs, avail, t):
        return gumbel_topk_select(key, sp["log_sizes"], avail, m), state

    def _poc(sp, state, key, inputs, avail, t):
        """Cho et al. 2020 on-device: d·m candidates by data size (Gumbel
        top-k), then keep the top-m highest-loss candidates.  Key layout:
        the candidate draw consumes ``key``, the probe ``fold_in(key, 1)``
        (bit-compatible with the PR-2 in-scan PoC stream)."""
        cand = gumbel_topk_select(key, sp["log_sizes"], avail, d)
        cidx, cvalid = select_k(cand, d)
        losses = probe_losses(
            inputs, cidx, jax.random.split(jax.random.fold_in(key, 1), d))
        _, kk = jax.lax.top_k(jnp.where(cvalid, losses, -jnp.inf), m)
        # cidx entries are distinct, so invalid slots never overwrite a
        # kept candidate
        return jnp.zeros((n,), bool).at[cidx[kk]].set(cvalid[kk]), state

    branches = {"fedgs": _fedgs, "uniform": _uniform, "md": _md, "poc": _poc}

    def step(sparams, state, key, inputs, avail, t):
        return jax.lax.switch(sparams["family"],
                              [branches[f] for f in FAMILIES],
                              sparams, state, key, inputs, avail, t)

    return step


# ------------------------------------------------------------ the processes
@dataclass
class SamplerProcess:
    """Base class.  ``params(data_sizes)``/``init(key)`` are eager host-side
    constructors of the per-cell runtime pytrees; :meth:`select` is the pure
    traceable entry point (single-process convenience over the switch step,
    guaranteed identical because it IS the switch path).  Every family fills
    the SAME params pytree (family index, alpha, log-size weights) so
    heterogeneous sampler cells stack along a vmap batch axis
    (``scan_engine.stack_cells``)."""

    family = "uniform"
    name = "process"

    def _alpha(self) -> float:
        return 0.0

    def params(self, *, data_sizes=None, n_clients: int | None = None) -> dict:
        """The cell-ready param pytree.  ``data_sizes`` defaults to all-ones
        — uniform MD/PoC weights — when only ``n_clients`` is known."""
        if data_sizes is None:
            assert n_clients is not None, "need data_sizes or n_clients"
            data_sizes = np.ones(n_clients)
        return {"family": jnp.int32(FAMILIES.index(self.family)),
                "alpha": jnp.float32(self._alpha()),
                "log_sizes": log_size_weights(data_sizes)}

    def init(self, key: jax.Array) -> dict:
        """Initial carried state — today's samplers are stateless per round,
        so this is the empty pytree (the protocol slot exists so stateful
        samplers ride the scan carry like availability processes do)."""
        return {}

    # -- traceable entry point --------------------------------------------
    def select(self, state, key, inputs, avail, t, *, m: int,
               data_sizes=None, max_sweeps: int = 32,
               d_cand: int | None = None, probe_losses=None,
               solver_backend: str = "ref"):
        """``data_sizes`` feeds the MD/PoC size weights — without it they
        fall back to all-ones (uniform), which is only right for samplers
        that ignore sizes."""
        n = avail.shape[-1]
        # every switch branch TRACES, so the round context must be complete
        # even for families this process never dispatches to — fill neutral
        # defaults for whatever the caller didn't supply
        inputs = {"h": jnp.zeros((n, n), jnp.float32),
                  "counts": jnp.zeros((n,), jnp.float32),
                  "losses": jnp.zeros((n,), jnp.float32),
                  "params": (), **inputs}
        step = make_sampler_step(n, m, max_sweeps=max_sweeps,
                                 d_cand=d_cand, probe_losses=probe_losses,
                                 solver_backend=solver_backend)
        return step(self.params(data_sizes=data_sizes, n_clients=n),
                    state, key, inputs, avail, t)


@dataclass
class UniformProcess(SamplerProcess):
    """McMahan et al. 2017: uniform without replacement among available."""
    name: str = "uniform"
    family = "uniform"


@dataclass
class MDProcess(SamplerProcess):
    """Li et al. 2020: without replacement, P(k) ∝ n_k, among available."""
    name: str = "md"
    family = "md"


@dataclass
class PoCProcess(SamplerProcess):
    """Cho et al. 2020 Power-of-Choice.  ``d_factor`` documents the intended
    candidate multiplier; the static candidate count itself is an engine
    compile-time knob (``ScanConfig.poc_d_factor`` / ``d_cand``)."""
    d_factor: int = 2
    name: str = "poc"
    family = "poc"


@dataclass
class FedGSProcess(SamplerProcess):
    """The paper's method; ``alpha`` weighs graph dispersion vs count
    balance and is a per-cell traced knob — α-variants batch together."""
    alpha: float = 1.0
    name: str = "fedgs"
    family = "fedgs"

    def __post_init__(self):
        self.name = f"fedgs(alpha={self.alpha})"

    def _alpha(self) -> float:
        return self.alpha


def make_sampler_process(name: str, *, alpha: float = 1.0,
                         d_factor: int = 2) -> SamplerProcess:
    """Family names (= ``scan_engine.SAMPLERS``) -> processes."""
    name = name.lower()
    if name in ("uniform", "uniformsample"):
        return UniformProcess()
    if name in ("md", "mdsample"):
        return MDProcess()
    if name in ("poc", "power-of-choice", "powerofchoice"):
        return PoCProcess(d_factor=d_factor)
    if name == "fedgs":
        return FedGSProcess(alpha=alpha)
    raise ValueError(f"unknown sampler family {name!r}")

"""Device-native availability-scenario subsystem.

The paper's central claim is robustness "under arbitrary client
availability", but the seven Table-1 modes (core/availability.py) are all
*stateless periodic* probability tables — the scenarios that actually stress
a sampler are stateful: Markov-correlated on/off churn (Rodio et al.),
non-stationary participation drift (Ribero et al.), regional outages,
deadline-dropped stragglers.  This module makes availability a first-class
process abstraction, mirroring the PR-2 graph unification
(core/graph_device.py): ONE pure, jit/vmap/scan-traceable implementation
that the scan engine carries through ``lax.scan``, the host engine wraps in
numpy (core/availability.py::ProcessMode), and the benchmarks sweep batched.

An :class:`AvailabilityProcess` is

    ``init(key) -> state``                                 (eager, host)
    ``draw(state, key, t) -> (avail bool (N,), state)``    (pure, traceable)

where ``draw`` = a per-family probability ``step`` (where any stateful
transition randomness is consumed) followed by the SHARED Bernoulli +
force-one-active draw (:func:`bernoulli_nonempty` — the one helper both the
host ``AvailabilityMode.sample`` and the scan engine use, DESIGN.md
assumption log #7/#10).

Scenario families (``FAMILIES`` — the ``lax.switch`` branch index every
process compiles to, so cells of DIFFERENT families batch through one
``ScanEngine.run_batch`` program):

  ======== ======================= ========================================
  family   class                   p_k(t)
  ======== ======================= ========================================
  table    TableProcess            table[t % P, k]            (the seven
                                   legacy Table-1 modes, stateless)
  markov   GilbertElliott          table[t % P, k] * (p_good if chain k on
                                   else p_bad); per-client 2-state Markov
                                   chain, mean sojourns = 1/p_fail, 1/p_rec
  cluster  ClusterOutage           table[t % P, k] * (1 if region c(k) up
                                   else floor); per-REGION 2-state chain —
                                   shared regional failures => correlated
                                   availability inside a cluster
  drift    DriftProcess            (1-w(t)) A[t % P, k] + w(t) B[t % P, k];
                                   w = ramp clip((t-t0)/(t1-t0), 0, 1) or
                                   regime switch (t // T_sw) % 2 — the
                                   non-stationary schedule, stateless
  deadline DeadlineProcess         table[t % P, k] * 1[l_k(t) <= deadline];
                                   l_k AR(1) log-latency state — available
                                   but straggling clients are dropped
  ======== ======================= ========================================

The runtime representation is a uniform *params* pytree (family index,
tables, packed ``theta`` knobs, per-client ``cluster``/``aux`` vectors) plus
a uniform *state* pytree (``onoff``, ``latency``), so heterogeneous
scenarios stack along a vmap batch axis (``scan_engine.stack_cells``).

Seed-stream convention (DESIGN.md assumption log #10): per round the caller
derives ``akey = fold_in(avail_key, t)``; the Bernoulli uses ``akey``
itself, force-one uses ``fold_in(akey, 1)`` (bit-compatible with the PR-1
scan stream for the table family), and stateful transitions use
``fold_in(akey, 2)``.  ``init`` consumes the raw ``avail_key`` — never a
``fold_in(·, t)`` key, so init and round draws cannot collide.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp

FAMILIES = ("table", "markov", "cluster", "drift", "deadline")
ALL_SCENARIOS = ("GE", "CLUSTER", "DRIFT", "DEADLINE")   # make_process names

THETA_DIM = 6          # packed per-family scalar knobs (see _step_* readers)
_STEP_SALT = 2         # fold_in salt of the state-transition key stream


# ----------------------------------------------------- shared draw helpers
def ensure_nonempty(avail: jax.Array, key: jax.Array) -> jax.Array:
    """Force >= 1 active client (device side): if the mask is empty, turn on
    one uniformly-drawn client.  The jit/vmap-traceable counterpart of
    :func:`ensure_nonempty_np` — the ONE force-one rule both paths share."""
    n = avail.shape[-1]
    forced = jax.random.randint(key, (), 0, n)
    return avail | ((jnp.arange(n) == forced) & ~avail.any())


def bernoulli_nonempty(key: jax.Array, p: jax.Array) -> jax.Array:
    """Bernoulli(p) availability mask with the force-one floor.  Key layout:
    the Bernoulli consumes ``key`` itself, the force draw ``fold_in(key, 1)``
    — bit-compatible with the scan engine's original table draw."""
    avail = jax.random.uniform(key, p.shape) < p
    return ensure_nonempty(avail, jax.random.fold_in(key, 1))


def ensure_nonempty_np(avail: np.ndarray, rng: np.random.Generator) -> np.ndarray:
    """Host-side force-one: same rule, numpy stream.  ``rng.integers`` is
    consumed ONLY when the mask is empty — bit-parity with the legacy
    ``AvailabilityMode.sample`` (and so with FLEngine traces)."""
    if not avail.any():
        avail = avail.copy()
        avail[int(rng.integers(len(avail)))] = True
    return avail


def sample_bernoulli_np(p: np.ndarray, rng: np.random.Generator) -> np.ndarray:
    """Host-side Bernoulli + force-one — the draw ``AvailabilityMode.sample``
    and ``ProcessMode.sample`` both delegate to."""
    return ensure_nonempty_np(rng.random(p.shape) < p, rng)


# ------------------------------------------------------- per-family steps
# Each branch: (params, state, key, t) -> (p (N,) f32, new state).  All
# branches return the SAME pytree structure so lax.switch can dispatch on a
# traced (per-cell, vmap-batched) family index.
def _base_row(params: dict, t: jax.Array) -> jax.Array:
    return params["table"][jnp.mod(t, params["period"])]


def _step_table(params, state, key, t):
    return _base_row(params, t), state


def _step_markov(params, state, key, t):
    p_fail, p_recover = params["theta"][0], params["theta"][1]
    p_good, p_bad = params["theta"][2], params["theta"][3]
    on = state["onoff"] > 0.5
    u = jax.random.uniform(key, on.shape)
    on = jnp.where(on, u >= p_fail, u < p_recover)
    p = _base_row(params, t) * jnp.where(on, p_good, p_bad)
    return p, {**state, "onoff": on.astype(jnp.float32)}


def _step_cluster(params, state, key, t):
    p_fail, p_recover, floor = (params["theta"][0], params["theta"][1],
                                params["theta"][2])
    up = state["onoff"] > 0.5                 # slot g = region g (pad unused)
    u = jax.random.uniform(key, up.shape)
    up = jnp.where(up, u >= p_fail, u < p_recover)
    gate = jnp.where(up[params["cluster"]], 1.0, floor)
    return _base_row(params, t) * gate, {**state,
                                         "onoff": up.astype(jnp.float32)}


def _step_drift(params, state, key, t):
    t0, t1, sw = params["theta"][0], params["theta"][1], params["theta"][2]
    tf = t.astype(jnp.float32)
    w_ramp = jnp.clip((tf - t0) / jnp.maximum(t1 - t0, 1.0), 0.0, 1.0)
    w_switch = jnp.mod(jnp.floor(tf / jnp.maximum(sw, 1.0)), 2.0)
    w = jnp.where(sw > 0, w_switch, w_ramp)
    row = jnp.mod(t, params["period"])
    p = (1.0 - w) * params["table"][row] + w * params["table_b"][row]
    return p, state


def _step_deadline(params, state, key, t):
    rho, sigma, deadline = (params["theta"][0], params["theta"][1],
                            params["theta"][2])
    mu = params["aux"]
    lat = rho * state["latency"] + (1.0 - rho) * mu \
        + sigma * jax.random.normal(key, mu.shape)
    p = _base_row(params, t) * (lat <= deadline)
    return p, {**state, "latency": lat}


_STEPS = (_step_table, _step_markov, _step_cluster, _step_drift,
          _step_deadline)


def proc_step(params: dict, state: dict, key: jax.Array, t: jax.Array):
    """Per-round availability probabilities of ANY family: ``lax.switch``
    on the cell's family index (under vmap this lowers to a select over all
    branches — availability math is negligible next to local training, so
    mixed-family batches cost nothing extra that matters).

    Returns ``(p (N,) float32, new state)``."""
    t = jnp.asarray(t, jnp.int32)
    return jax.lax.switch(params["family"],
                          [lambda s, k, tt, f=f: f(params, s, k, tt)
                           for f in _STEPS],
                          state, key, t)


def proc_draw(params: dict, state: dict, key: jax.Array, t: jax.Array):
    """The full per-round draw: family step (transition randomness on
    ``fold_in(key, 2)``) then the shared Bernoulli + force-one on ``key`` /
    ``fold_in(key, 1)``.  Returns ``(avail bool (N,), new state)``."""
    p, state = proc_step(params, state, jax.random.fold_in(key, _STEP_SALT), t)
    return bernoulli_nonempty(key, p), state


# ------------------------------------------------------------ the processes
def _ones_table(n: int) -> np.ndarray:
    return np.ones((1, n), np.float64)


def _as_table(table, n: Optional[int] = None) -> np.ndarray:
    t = np.atleast_2d(np.asarray(table, np.float64))
    if n is not None and t.shape[1] != n:
        raise ValueError(f"table has {t.shape[1]} clients, expected {n}")
    return t


@dataclass
class AvailabilityProcess:
    """Base class.  Subclasses set ``family`` and fill the params/state
    fields they use; everything else takes the neutral defaults so every
    process compiles to the SAME pytree shapes (the mixed-batch invariant).

    ``params()``/``init(key)`` are eager host-side constructors of the
    runtime pytrees; ``draw``/``step`` are the pure traceable entry points
    (single-process convenience over :func:`proc_draw`/:func:`proc_step`,
    guaranteed identical because they ARE the switch path)."""

    family = "table"
    name = "process"

    def __post_init__(self):
        self._params = None

    # -- runtime pytrees ---------------------------------------------------
    def _table(self) -> np.ndarray:
        raise NotImplementedError

    def _table_b(self) -> np.ndarray:
        return np.zeros_like(self._table())

    def _theta(self) -> np.ndarray:
        return np.zeros(THETA_DIM)

    def _cluster_ids(self) -> np.ndarray:
        return np.zeros(self.n_clients, np.int32)

    def _aux(self) -> np.ndarray:
        return np.zeros(self.n_clients)

    @property
    def n_clients(self) -> int:
        return self._table().shape[1]

    def params(self) -> dict:
        """The cell-ready param pytree (float32 on device, like every other
        cell array; the f64 source tables stay host-side for the numpy
        face's bit-parity — DESIGN.md assumption log #10)."""
        if self._params is None:
            table = self._table()
            theta = np.zeros(THETA_DIM, np.float32)
            th = np.asarray(self._theta(), np.float32)
            theta[:th.shape[0]] = th
            self._params = {
                "family": jnp.int32(FAMILIES.index(self.family)),
                "table": jnp.asarray(table, jnp.float32),
                "table_b": jnp.asarray(self._table_b(), jnp.float32),
                "period": jnp.int32(table.shape[0]),
                "theta": jnp.asarray(theta),
                "cluster": jnp.asarray(self._cluster_ids(), jnp.int32),
                "aux": jnp.asarray(self._aux(), jnp.float32),
            }
        return self._params

    def init(self, key: jax.Array) -> dict:
        """Initial carried state (stationary draw where one exists)."""
        n = self.n_clients
        return {"onoff": jnp.ones((n,), jnp.float32),
                "latency": jnp.zeros((n,), jnp.float32)}

    # -- traceable entry points -------------------------------------------
    def step(self, state, key, t):
        return proc_step(self.params(), state, key, t)

    def draw(self, state, key, t):
        return proc_draw(self.params(), state, key, t)

    # -- host face hook ----------------------------------------------------
    def host_probs(self, t: int) -> Optional[np.ndarray]:
        """Exact float64 probabilities for STATELESS families (the host
        face uses them for bit-parity with legacy traces); stateful families
        return None and the host face replays the device prob stream."""
        return None


@dataclass
class TableProcess(AvailabilityProcess):
    """The seven legacy Table-1 modes: a dense periodic ``(P, N)``
    probability table (``AvailabilityMode.probs_table()``), stateless."""
    table: np.ndarray
    name: str = "table"

    family = "table"

    def __post_init__(self):
        super().__post_init__()
        self.table = _as_table(self.table)

    def _table(self):
        return self.table

    def host_probs(self, t):
        return self.table[t % self.table.shape[0]]


@dataclass
class GilbertElliott(AvailabilityProcess):
    """Per-client Gilbert–Elliott on/off Markov chains (correlated-in-time
    availability, Rodio et al.): chain k flips on->off w.p. ``1/mean_on``
    and off->on w.p. ``1/mean_off`` each round; availability probability is
    ``base * p_good`` while on and ``base * p_bad`` while off.  Stationary
    participation = base * (pi_on p_good + (1-pi_on) p_bad) with
    pi_on = mean_on / (mean_on + mean_off)."""
    n: int
    mean_on: float = 8.0          # mean on-sojourn (rounds) = 1 / p_fail
    mean_off: float = 4.0         # mean off-sojourn (rounds) = 1 / p_recover
    p_good: float = 1.0
    p_bad: float = 0.0
    base_table: Optional[np.ndarray] = None
    name: str = "markov"

    family = "markov"

    def _table(self):
        return (_ones_table(self.n) if self.base_table is None
                else _as_table(self.base_table, self.n))

    def _theta(self):
        return np.array([1.0 / max(self.mean_on, 1.0),
                         1.0 / max(self.mean_off, 1.0),
                         self.p_good, self.p_bad])

    @property
    def pi_on(self) -> float:
        return self.mean_on / (self.mean_on + self.mean_off)

    def init(self, key):
        state = super().init(key)
        on = jax.random.uniform(key, (self.n,)) < self.pi_on
        return {**state, "onoff": on.astype(jnp.float32)}


@dataclass
class ClusterOutage(AvailabilityProcess):
    """Block-correlated outages: clients are grouped into regions, each
    region carries ONE up/down Markov chain (P(up->down) = p_fail,
    P(down->up) = p_recover); a down region multiplies its clients'
    availability by ``floor``.  Clients of one region fail together —
    the cross-client correlation structure no periodic table expresses."""
    n: int
    n_clusters: int = 4
    p_fail: float = 0.1
    p_recover: float = 0.3
    floor: float = 0.05
    cluster: Optional[np.ndarray] = None    # (N,) region ids; default rr
    base_table: Optional[np.ndarray] = None
    name: str = "cluster"

    family = "cluster"

    def _table(self):
        return (_ones_table(self.n) if self.base_table is None
                else _as_table(self.base_table, self.n))

    def _theta(self):
        return np.array([self.p_fail, self.p_recover, self.floor])

    def _cluster_ids(self):
        if self.cluster is not None:
            return np.asarray(self.cluster, np.int32)
        return (np.arange(self.n) % self.n_clusters).astype(np.int32)

    @property
    def pi_up(self) -> float:
        return self.p_recover / (self.p_fail + self.p_recover)

    def init(self, key):
        state = super().init(key)
        # region chains live in the first n_clusters slots of the (N,) state
        up = jax.random.uniform(key, (self.n,)) < self.pi_up
        return {**state, "onoff": up.astype(jnp.float32)}


@dataclass
class DriftProcess(AvailabilityProcess):
    """Non-stationary drift (Ribero et al.-style time-varying
    participation): interpolate between two periodic tables A and B —
    ``w(t) = clip((t - t0)/(t1 - t0), 0, 1)`` (ramp; t0 = t1 gives a hard
    shift) or, with ``switch_period > 0``, a regime switch
    ``w(t) = (t // T_sw) % 2``.  Stateless but aperiodic: NO finite
    ``(period, N)`` table represents it."""
    table_a: np.ndarray
    table_b: np.ndarray
    t0: float = 0.0
    t1: float = 100.0
    switch_period: int = 0
    name: str = "drift"

    family = "drift"

    def __post_init__(self):
        super().__post_init__()
        a, b = _as_table(self.table_a), _as_table(self.table_b)
        if a.shape[1] != b.shape[1]:
            raise ValueError("table_a / table_b client counts differ")
        # tile both to the common (lcm) period so one row index serves both
        p = int(np.lcm(a.shape[0], b.shape[0]))
        self.table_a = np.tile(a, (p // a.shape[0], 1))
        self.table_b = np.tile(b, (p // b.shape[0], 1))

    def _table(self):
        return self.table_a

    def _table_b(self):
        return self.table_b

    def _theta(self):
        return np.array([self.t0, self.t1, float(self.switch_period)])

    def weight(self, t: int) -> float:
        if self.switch_period > 0:
            return float((t // self.switch_period) % 2)
        return float(np.clip((t - self.t0) / max(self.t1 - self.t0, 1.0),
                             0.0, 1.0))

    def host_probs(self, t):
        w = self.weight(t)
        row = t % self.table_a.shape[0]
        return (1.0 - w) * self.table_a[row] + w * self.table_b[row]


@dataclass
class DeadlineProcess(AvailabilityProcess):
    """Deadline-constrained participation: client k carries an AR(1)
    latency state ``l' = rho l + (1 - rho) mu_k + sigma eps`` and is dropped
    (even when its base availability fires) whenever ``l' > deadline`` —
    available-but-straggling clients never make the round.  Stationarily
    ``l_k ~ N(mu_k, sigma^2 / (1 - rho^2))``, so the participation rate is
    ``base_k * Phi((deadline - mu_k) / sd)``."""
    n: int
    deadline: float = 1.0
    rho: float = 0.8
    sigma: float = 0.2
    mu: Optional[np.ndarray] = None      # (N,) mean latencies; default U[.5, 1.5]
    base_table: Optional[np.ndarray] = None
    mu_seed: int = 0
    name: str = "deadline"

    family = "deadline"

    def _table(self):
        return (_ones_table(self.n) if self.base_table is None
                else _as_table(self.base_table, self.n))

    def _theta(self):
        return np.array([self.rho, self.sigma, self.deadline])

    def _mu(self) -> np.ndarray:
        if self.mu is not None:
            return np.asarray(self.mu, np.float64)
        rng = np.random.default_rng(self.mu_seed)
        return rng.uniform(0.5, 1.5, self.n)

    def _aux(self):
        return self._mu()

    @property
    def stationary_sd(self) -> float:
        return self.sigma / np.sqrt(max(1.0 - self.rho ** 2, 1e-12))

    def stationary_rate(self) -> np.ndarray:
        """Analytic per-client participation probability (base x Phi)."""
        z = (self.deadline - self._mu()) / max(self.stationary_sd, 1e-12)
        phi = np.asarray(jax.scipy.stats.norm.cdf(jnp.asarray(z)))
        return self._table().mean(0) * phi

    def init(self, key):
        state = super().init(key)
        mu = jnp.asarray(self._mu(), jnp.float32)
        lat = mu + self.stationary_sd * jax.random.normal(key, mu.shape)
        return {**state, "latency": lat}


# ------------------------------------------------------------------ factory
def make_process(name: str, *, n_clients: int, data_sizes=None,
                 label_sets=None, num_labels: int = 10,
                 beta: Optional[float] = None, seed: int = 0,
                 period: int = 20, rounds: int = 100,
                 **kw) -> AvailabilityProcess:
    """Scenario names -> processes.  The seven legacy Table-1 mode names
    build a :class:`TableProcess` (via ``core.availability.make_mode``);
    the new families:

      GE        per-client Gilbert–Elliott chains (kw: mean_on, mean_off, …)
      CLUSTER   regional-outage chains           (kw: n_clusters, p_fail, …)
      DRIFT     MDF -> LDF ramp over ``rounds`` (falls back to a
                0.9 -> 0.25 flat ramp without data_sizes; kw override all)
      DEADLINE  AR(1) straggler latencies        (kw: deadline, rho, sigma)
    """
    uname = name.upper()
    if uname == "GE":
        return GilbertElliott(n_clients, **kw)
    if uname == "CLUSTER":
        kw.setdefault("n_clusters", max(2, n_clients // 10))
        return ClusterOutage(n_clients, **kw)
    if uname == "DRIFT":
        if "table_a" not in kw:
            from repro.core.availability import make_mode
            if data_sizes is not None:
                kw["table_a"] = make_mode(
                    "MDF", n_clients=n_clients,
                    data_sizes=data_sizes).probs_table()
                kw["table_b"] = make_mode(
                    "LDF", n_clients=n_clients,
                    data_sizes=data_sizes).probs_table()
            else:
                kw["table_a"] = np.full((1, n_clients), 0.9)
                kw["table_b"] = np.full((1, n_clients), 0.25)
        kw.setdefault("t0", 0.0)
        kw.setdefault("t1", float(rounds))
        return DriftProcess(**kw)
    if uname == "DEADLINE":
        kw.setdefault("mu_seed", seed)
        return DeadlineProcess(n_clients, **kw)
    from repro.core.availability import make_mode
    return make_mode(name, n_clients=n_clients, data_sizes=data_sizes,
                     label_sets=label_sets, num_labels=num_labels, beta=beta,
                     seed=seed, period=period).process()


# ------------------------------------------------------------- trace utility
def device_trace(process: AvailabilityProcess, rounds: int,
                 avail_seed: int = 1234) -> np.ndarray:
    """(rounds, N) bool availability trace drawn entirely on-device with the
    scan engine's key convention (init on the raw key, round draws on
    ``fold_in(key, t)``) — the device counterpart of
    ``availability.host_trace`` and the empirical-frequency test harness."""
    params = process.params()
    key = jax.random.PRNGKey(avail_seed)
    state0 = process.init(key)

    def step(state, t):
        avail, state = proc_draw(params, state, jax.random.fold_in(key, t), t)
        return state, avail

    _, trace = jax.lax.scan(step, state0, jnp.arange(rounds))
    return np.asarray(trace)

"""The 3DG pipeline — ONE device-native implementation (DESIGN.md §9).

Every layer that builds or normalizes a Data-Distribution-Dependency Graph
(paper §3.2, Eq. 11–13) goes through the composable stages below:

    features U (N, d)
       │  dot_sim / cosine_sim            similarity source (Eq. 11/12)
       ▼
    similarity V (N, N)
       │  minmax01                        Appendix C [0, 1] normalization
       ▼
    normalized similarity Vn
       │  to_adjacency(eps, sigma2)       R_ij = exp(-Vn/σ²) | inf, diag 0
       ▼
    adjacency R (inf = no edge)
       │  apsp(backend="ref"|"pallas")    Floyd–Warshall shortest paths
       ▼
    distance matrix H (inf = disconnected)
       │  cap_and_normalize(scale)        finite cap + [0, 1] scale (Eq. 16 prep)
       ▼
    normalized H — what FedGS's QUBO consumes

All stages are pure jnp and jit/vmap/scan-traceable, so the same code runs
in host numpy wrappers (``core/graph.py``), inside the scan engine's
``lax.scan`` body (``fed/scan_engine.py``), and in the production dry-run
(``launch/fedsim.py``).  ``backend="pallas"`` routes the similarity matmul
and the blocked Floyd–Warshall through the tiled TPU kernels in
``kernels/ops.py`` (whose wrappers pad to tile multiples in-trace);
``backend="ref"`` uses the pure-jnp oracles.  Math is float32 throughout —
the same precision the samplers trace (DESIGN.md assumption log #3/#8).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.kernels.ref import floyd_warshall_ref

BACKENDS = ("ref", "pallas")
# similarity sources: "dot" = U Uᵀ (oracle features), "cosine" = row-normalized
# dot (oracle kind="cosine"), "functional" = max(cos, 0) (Eq. 11/12, the
# dynamic-3DG probe path), "precomputed" = input already is V
SIMILARITIES = ("dot", "cosine", "functional", "precomputed")


@dataclass(frozen=True)
class GraphConfig:
    """Static (compile-time) 3DG build configuration — hashable, so it can be
    closed over by jit programs and used as a cache key."""
    eps: float = 0.1               # edge threshold on normalized similarity
    sigma2: float = 0.01           # paper's σ² in exp(-V/σ²)
    finite_cap_scale: float = 2.0  # disconnected pairs ↦ scale × max finite
    normalize: bool = True         # scale H to [0, 1] (DESIGN.md assumption #1)
    similarity: str = "dot"

    def __post_init__(self):
        if self.similarity not in SIMILARITIES:
            raise ValueError(f"similarity must be one of {SIMILARITIES}, "
                             f"not {self.similarity!r}")


# ------------------------------------------------------------------- stages
def dot_sim(u: jax.Array, *, backend: str = "ref",
            interpret: bool | None = None) -> jax.Array:
    """V = U Uᵀ.  The pallas backend runs the tiled MXU matmul."""
    if backend == "pallas":
        from repro.kernels.ops import pairwise_similarity
        return pairwise_similarity(u, interpret=interpret)
    return u @ u.T


def cosine_sim(u: jax.Array, *, clamp: bool = True, backend: str = "ref",
               interpret: bool | None = None) -> jax.Array:
    """Row-normalized similarity; ``clamp`` gives Eq. 11/12's max(cos, 0)."""
    un = u / jnp.maximum(jnp.linalg.norm(u, axis=-1, keepdims=True), 1e-12)
    v = dot_sim(un, backend=backend, interpret=interpret)
    return jnp.maximum(v, 0.0) if clamp else v


def minmax01(v: jax.Array) -> jax.Array:
    """Min-max normalize similarities to [0, 1] (paper Appendix C)."""
    lo, hi = jnp.min(v), jnp.max(v)
    return (v - lo) / jnp.maximum(hi - lo, 1e-12)


def to_adjacency(vn: jax.Array, *, eps: float = 0.1,
                 sigma2: float = 0.01) -> jax.Array:
    """Normalized similarity -> 3DG adjacency (inf = no edge, diag 0).

    The diagonal is masked with ``jnp.where(eye, 0, ...)`` — never by
    multiplying with ``1 - eye``, which turns an inf no-edge entry into
    ``inf·0 = NaN`` whenever a row's normalized self-similarity falls
    below eps (the hazard the regression tests pin).
    """
    eye = jnp.eye(vn.shape[-1], dtype=bool)
    r = jnp.where(vn >= eps, jnp.exp(-vn / sigma2), jnp.inf)
    return jnp.where(eye, 0.0, r)


def apsp(r: jax.Array, *, backend: str = "ref",
         interpret: bool | None = None) -> jax.Array:
    """All-pairs shortest paths of the (N, N) adjacency.

    ``ref``: the pure-jnp min-plus closure (kernels/ref.py).
    ``pallas``: the blocked VMEM-tiled kernel (kernels/ops.py), padded
    in-trace to the 128 tile multiple with isolated nodes.
    """
    if backend == "pallas":
        from repro.kernels.ops import floyd_warshall
        return floyd_warshall(r.astype(jnp.float32), interpret=interpret)
    if backend != "ref":
        raise ValueError(f"backend must be one of {BACKENDS}, not {backend!r}")
    return floyd_warshall_ref(r.astype(jnp.float32))


def cap_and_normalize(h: jax.Array, *, scale: float = 2.0,
                      normalize: bool = True) -> jax.Array:
    """Replace inf distances (disconnected pairs) with scale × max finite
    distance so the QUBO objective stays finite while still strongly
    preferring disconnected (= maximally dissimilar) pairs; then optionally
    scale to [0, 1] so alpha trades graph dispersion against count balance
    on comparable scales (DESIGN.md assumption log #1)."""
    finite = jnp.isfinite(h)
    mx = jnp.max(jnp.where(finite, h, -jnp.inf))
    cap = scale * jnp.where(jnp.isfinite(mx), mx, 1.0)
    eye = jnp.eye(h.shape[-1], dtype=bool)
    out = jnp.where(eye, 0.0, jnp.where(finite, h, cap))
    if normalize:
        # divide by the true max, however tiny (σ² = 0.01 puts edge weights
        # near 1e-18) — flooring the denominator would leave H ≈ 0 and
        # silently reduce FedGS to count balancing; all-zero H passes through
        hmax = jnp.max(out)
        out = out / jnp.where(hmax > 0, hmax, 1.0)
    return out


# ----------------------------------------------------------------- pipeline
def _similarity(u_or_v: jax.Array, cfg: GraphConfig, *, backend: str,
                interpret: bool | None) -> jax.Array:
    if cfg.similarity == "precomputed":
        return u_or_v
    if cfg.similarity == "dot":
        return dot_sim(u_or_v, backend=backend, interpret=interpret)
    clamp = cfg.similarity == "functional"
    return cosine_sim(u_or_v, clamp=clamp, backend=backend, interpret=interpret)


def build_3dg(u_or_v: jax.Array, cfg: GraphConfig = GraphConfig(), *,
              backend: str = "ref", interpret: bool | None = None):
    """Features (N, d) — or raw similarity (N, N) with
    ``similarity="precomputed"`` — to ``(Vn, R, H_raw)``: the normalized
    similarity, the adjacency, and the *uncapped* shortest-path matrix
    (inf = disconnected)."""
    if backend not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}, not {backend!r}")
    v = _similarity(u_or_v.astype(jnp.float32), cfg, backend=backend,
                    interpret=interpret)
    vn = minmax01(v)
    if backend == "pallas":
        # fused minmax -> threshold -> exp epilogue; lo/hi come from the raw
        # unpadded V, so the result matches the ref stages exactly
        from repro.kernels.ops import similarity_to_adjacency
        r = similarity_to_adjacency(v, eps=cfg.eps, sigma2=cfg.sigma2,
                                    interpret=interpret)
    else:
        r = to_adjacency(vn, eps=cfg.eps, sigma2=cfg.sigma2)
    h = apsp(r, backend=backend, interpret=interpret)
    return vn, r, h


def build_h(u_or_v: jax.Array, cfg: GraphConfig = GraphConfig(), *,
            backend: str = "ref", interpret: bool | None = None) -> jax.Array:
    """The one-call 3DG constructor: features (or similarity) -> finite,
    [0, 1]-normalized H, ready for ``fedgs_select``.  Traceable under
    jit / vmap / lax.scan on both backends.

    On ``backend="pallas"`` with a feature-based similarity the whole
    build routes through the fused megakernel pipeline
    (``kernels/ops.build_3dg_fused``): similarity, min-max stats, and the
    adjacency epilogue run tile-resident in ONE Pallas grid that feeds the
    blocked Floyd–Warshall at a shared padded size — V never exists in
    HBM and R round-trips it exactly once.  Bit-identical to the staged
    pallas stages (tests/test_kernels.py); ``similarity="precomputed"``
    (V given, no features) keeps the staged path."""
    if backend == "pallas" and cfg.similarity != "precomputed":
        from repro.kernels.ops import build_3dg_fused
        u = u_or_v.astype(jnp.float32)
        if cfg.similarity in ("cosine", "functional"):
            # same row normalization (and, via clamp, the same max(·, 0))
            # as cosine_sim — applied before the kernel so the fused matmul
            # consumes exactly the ref path's operand
            u = u / jnp.maximum(jnp.linalg.norm(u, axis=-1, keepdims=True),
                                1e-12)
        _, h = build_3dg_fused(u, eps=cfg.eps, sigma2=cfg.sigma2,
                               clamp=cfg.similarity == "functional",
                               interpret=interpret)
    else:
        _, _, h = build_3dg(u_or_v, cfg, backend=backend, interpret=interpret)
    return cap_and_normalize(h, scale=cfg.finite_cap_scale,
                             normalize=cfg.normalize)

"""Client samplers: FedGS (Eq. 16–17) + the paper's baselines.

FedGS solves, each round t:
    max_{s in {0,1}^|A_t|}  s^T ( alpha/N * H_A  -  diag(z_A) ) s
    s.t.  1^T s = m,   m = min(M, |A_t|)
with z_k = 2 (v_k^{t-1} - vbar^{t-1} - M/N) + 1  (long-term-bias penalty from
the count-variance objective, Eq. 7/14).

The problem is a p-dispersion variant (NP-hard).  The paper bounds solver
wall-clock; we use a deterministic, fully vectorized greedy + best-swap local
search with a fixed sweep budget (`max_sweeps`) — jit-compatible (static
shapes, masks for availability) and TPU-lowerable.  A local optimum "already
brings non-trivial improvement" (paper §3.3), which our experiments confirm.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

import jax
import jax.numpy as jnp
from functools import partial


# ----------------------------------------------------------------- baselines
class Sampler:
    """Stateless-per-round sampler interface. All samplers see only the
    available set A_t (immediate availability, as in the paper)."""
    name = "base"
    needs_losses = False

    def sample(self, *, avail: np.ndarray, m: int, rng: np.random.Generator,
               counts: np.ndarray | None = None, data_sizes=None,
               losses=None, t: int = 0) -> np.ndarray:
        raise NotImplementedError


class UniformSampler(Sampler):
    """McMahan et al. 2017: uniform without replacement among available."""
    name = "UniformSample"

    def sample(self, *, avail, m, rng, **_):
        idx = np.flatnonzero(avail)
        m = min(m, len(idx))
        return np.sort(rng.choice(idx, size=m, replace=False))


class MDSampler(Sampler):
    """Li et al. 2020: probability proportional to local data size (with
    replacement in theory; we draw without replacement by weight, the common
    implementation), among available clients."""
    name = "MDSample"

    def sample(self, *, avail, m, rng, data_sizes=None, **_):
        idx = np.flatnonzero(avail)
        m = min(m, len(idx))
        w = np.asarray(data_sizes, float)[idx]
        w = w / w.sum()
        return np.sort(rng.choice(idx, size=m, replace=False, p=w))


class PowerOfChoiceSampler(Sampler):
    """Cho et al. 2020: sample a candidate set by data size, then keep the
    top-m highest local loss."""
    name = "Power-of-Choice"
    needs_losses = True

    def __init__(self, d_factor: int = 2):
        self.d_factor = d_factor

    def sample(self, *, avail, m, rng, data_sizes=None, losses=None, **_):
        idx = np.flatnonzero(avail)
        m = min(m, len(idx))
        d = min(len(idx), max(m, self.d_factor * m))
        w = np.asarray(data_sizes, float)[idx]
        cand = rng.choice(idx, size=d, replace=False, p=w / w.sum())
        order = np.argsort(-np.asarray(losses)[cand])
        return np.sort(cand[order[:m]])


# -------------------------------------------------------------------- FedGS
@partial(jax.jit, static_argnames=("m", "max_sweeps"))
def _fedgs_solve(q: jax.Array, avail: jax.Array, *, m: int, max_sweeps: int):
    """Greedy + best-swap local search on  max s^T Q s,  |s| = m,  s <= avail.

    q: (N, N) symmetric with diagonal = -z (counts penalty).
    Returns s (N,) bool.
    """
    n = q.shape[0]
    neg = jnp.float32(-1e18)

    # ---------------- greedy construction --------------------------------
    def greedy_step(carry, _):
        s, r = carry                       # s: (N,) bool, r_k = sum_{i in S} Q_ik
        gain = q.diagonal() + 2.0 * r      # marginal gain of adding k
        gain = jnp.where(s | ~avail, neg, gain)
        k = jnp.argmax(gain)
        s = s.at[k].set(True)
        r = r + q[k]
        return (s, r), None

    s0 = jnp.zeros((n,), bool)
    r0 = jnp.zeros((n,), jnp.float32)
    (s, r), _ = jax.lax.scan(greedy_step, (s0, r0), None, length=m)

    # ---------------- best-swap local search -----------------------------
    diag = q.diagonal()

    def sweep(carry, _):
        s, r = carry
        # delta(i -> j) = -2 r_i + Q_ii + 2 (r_j - Q_ij) + Q_jj
        out_term = (-2.0 * r + diag)                          # (N,) for i in S
        in_term = (2.0 * r + diag)                            # (N,) for j notin S
        delta = out_term[:, None] + in_term[None, :] - 2.0 * q
        delta = jnp.where(s[:, None], delta, neg)             # i must be in S
        delta = jnp.where((~s & avail)[None, :], delta, neg)  # j must be addable
        flat = jnp.argmax(delta)
        i, j = flat // n, flat % n
        best = delta[i, j]

        def do_swap(args):
            s, r = args
            s2 = s.at[i].set(False).at[j].set(True)
            r2 = r - q[i] + q[j]
            return s2, r2

        s, r = jax.lax.cond(best > 1e-9, do_swap, lambda a: a, (s, r))
        return (s, r), best

    (s, r), _ = jax.lax.scan(sweep, (s, r), None, length=max_sweeps)
    return s


@dataclass
class FedGSSampler(Sampler):
    """The paper's method.  alpha weighs graph dispersion vs count balance."""
    alpha: float = 1.0
    max_sweeps: int = 64

    name = "FedGS"

    def __post_init__(self):
        self.name = f"FedGS(alpha={self.alpha})"
        self._h = None

    def set_graph(self, h: np.ndarray):
        """Install the (finite-capped) shortest-path matrix H.

        H is normalized to [0, 1] by its max finite entry.  The paper's Eq. 16
        uses raw H, but with its 3DG constants (sigma^2 = 0.01) the edge
        weights exp(-V/sigma^2) are O(1e-4) while the count-balance term z is
        O(1), which silently reduces FedGS to pure count balancing for any
        alpha in the paper's sweep.  Normalizing makes alpha trade the two
        objectives on comparable scales (DESIGN.md assumption log).
        """
        from repro.core.graph import finite_cap
        h = np.asarray(finite_cap(h), np.float64)
        hmax = h.max()
        if hmax > 0:
            h = h / hmax
        self._h = h.astype(np.float32)

    def sample(self, *, avail, m, rng, counts=None, **_):
        assert self._h is not None, "call set_graph(H) first"
        n = len(avail)
        m_eff = int(min(m, int(avail.sum())))
        v = np.asarray(counts, np.float64)
        z = 2.0 * (v - v.mean() - m / n) + 1.0
        q = (self.alpha / n) * self._h - np.diag(z)
        q = 0.5 * (q + q.T)                           # symmetrize (H should be)
        s = _fedgs_solve(jnp.asarray(q, jnp.float32), jnp.asarray(avail),
                         m=m_eff, max_sweeps=self.max_sweeps)
        return np.flatnonzero(np.asarray(s))


def make_sampler(name: str, **kw) -> Sampler:
    name = name.lower()
    if name in ("uniform", "uniformsample"):
        return UniformSampler()
    if name in ("md", "mdsample"):
        return MDSampler()
    if name in ("poc", "power-of-choice", "powerofchoice"):
        return PowerOfChoiceSampler()
    if name == "fedgs":
        return FedGSSampler(**kw)
    raise ValueError(f"unknown sampler {name!r}")

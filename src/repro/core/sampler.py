"""Client samplers: FedGS (Eq. 16–17) + the paper's baselines.

FedGS solves, each round t:
    max_{s in {0,1}^|A_t|}  s^T ( alpha/N * H_A  -  diag(z_A) ) s
    s.t.  1^T s = m,   m = min(M, |A_t|)
with z_k = 2 (v_k^{t-1} - vbar^{t-1} - M/N) + 1  (long-term-bias penalty from
the count-variance objective, Eq. 7/14).

The problem is a p-dispersion variant (NP-hard).  The paper bounds solver
wall-clock; we use a deterministic, fully vectorized greedy + best-swap local
search with a fixed sweep budget (`max_sweeps`) — jit-compatible (static
shapes, masks for availability) and TPU-lowerable.  A local optimum "already
brings non-trivial improvement" (paper §3.3), which our experiments confirm.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

import jax
import jax.numpy as jnp
from functools import partial


# ----------------------------------------------------------------- baselines
class Sampler:
    """Stateless-per-round sampler interface. All samplers see only the
    available set A_t (immediate availability, as in the paper)."""
    name = "base"
    needs_losses = False

    def sample(self, *, avail: np.ndarray, m: int, rng: np.random.Generator,
               counts: np.ndarray | None = None, data_sizes=None,
               losses=None, t: int = 0) -> np.ndarray:
        raise NotImplementedError


class UniformSampler(Sampler):
    """McMahan et al. 2017: uniform without replacement among available."""
    name = "UniformSample"

    def sample(self, *, avail, m, rng, **_):
        idx = np.flatnonzero(avail)
        m = min(m, len(idx))
        return np.sort(rng.choice(idx, size=m, replace=False))


def _size_weights(w: np.ndarray, k: int) -> np.ndarray | None:
    """Normalized data-size weights for a without-replacement draw of k, or
    None (= uniform fallback) when the weights are degenerate: all zero
    (``w / w.sum()`` would be NaN and ``rng.choice`` would raise) or with
    fewer than k nonzero entries (``rng.choice`` cannot fill k slots from a
    zero-mass support)."""
    s = w.sum()
    if s <= 0 or np.count_nonzero(w) < k:
        return None
    return w / s


class MDSampler(Sampler):
    """Li et al. 2020: probability proportional to local data size (with
    replacement in theory; we draw without replacement by weight, the common
    implementation), among available clients.  Degenerate all-zero data
    sizes fall back to uniform (``_size_weights``)."""
    name = "MDSample"

    def sample(self, *, avail, m, rng, data_sizes=None, **_):
        idx = np.flatnonzero(avail)
        m = min(m, len(idx))
        w = _size_weights(np.asarray(data_sizes, float)[idx], m)
        return np.sort(rng.choice(idx, size=m, replace=False, p=w))


class PowerOfChoiceSampler(Sampler):
    """Cho et al. 2020: sample a candidate set by data size, then keep the
    top-m highest local loss."""
    name = "Power-of-Choice"
    needs_losses = True

    def __init__(self, d_factor: int = 2):
        self.d_factor = d_factor

    def sample(self, *, avail, m, rng, data_sizes=None, losses=None, **_):
        idx = np.flatnonzero(avail)
        m = min(m, len(idx))
        d = min(len(idx), max(m, self.d_factor * m))
        w = _size_weights(np.asarray(data_sizes, float)[idx], d)
        cand = rng.choice(idx, size=d, replace=False, p=w)
        order = np.argsort(-np.asarray(losses)[cand])
        return np.sort(cand[order[:m]])


# -------------------------------------------------------------------- FedGS
def fedgs_solve(q: jax.Array, avail: jax.Array, *, m: int, max_sweeps: int):
    """Greedy + best-swap local search on  max s^T Q s,  |s| = m,  s <= avail.

    Pure (unjitted) so it can be inlined into larger jit programs — the
    per-round host path wraps it as ``_fedgs_solve`` below; the scan engine
    (``repro.fed.scan_engine``) and the production dry-run
    (``repro.launch.fedsim.graph_pipeline``) call it directly inside their
    own jit scopes.  If fewer than ``m`` clients are available it selects all
    of them (|S| = min(m, |A|)).

    q: (N, N) symmetric with diagonal = -z (counts penalty).
    Returns s (N,) bool.
    """
    n = q.shape[0]
    neg = jnp.float32(-1e18)

    # ---------------- greedy construction --------------------------------
    def greedy_step(carry, _):
        s, r = carry                       # s: (N,) bool, r_k = sum_{i in S} Q_ik
        gain = q.diagonal() + 2.0 * r      # marginal gain of adding k
        gain = jnp.where(s | ~avail, neg, gain)
        k = jnp.argmax(gain)
        ok = gain[k] > neg / 2             # no addable client left => no-op
        s = s.at[k].set(ok | s[k])
        r = r + jnp.where(ok, q[k], 0.0)
        return (s, r), None

    s0 = jnp.zeros((n,), bool)
    r0 = jnp.zeros((n,), jnp.float32)
    (s, r), _ = jax.lax.scan(greedy_step, (s0, r0), None, length=m)

    # ---------------- best-swap local search -----------------------------
    diag = q.diagonal()

    def sweep(carry, _):
        s, r = carry
        # delta(i -> j) = -2 r_i + Q_ii + 2 (r_j - Q_ij) + Q_jj
        out_term = (-2.0 * r + diag)                          # (N,) for i in S
        in_term = (2.0 * r + diag)                            # (N,) for j notin S
        delta = out_term[:, None] + in_term[None, :] - 2.0 * q
        delta = jnp.where(s[:, None], delta, neg)             # i must be in S
        delta = jnp.where((~s & avail)[None, :], delta, neg)  # j must be addable
        flat = jnp.argmax(delta)
        i, j = flat // n, flat % n
        best = delta[i, j]

        def do_swap(args):
            s, r = args
            s2 = s.at[i].set(False).at[j].set(True)
            r2 = r - q[i] + q[j]
            return s2, r2

        s, r = jax.lax.cond(best > 1e-9, do_swap, lambda a: a, (s, r))
        return (s, r), best

    (s, r), _ = jax.lax.scan(sweep, (s, r), None, length=max_sweeps)
    return s


# jit'd entry point for the per-round host path (FedGSSampler.sample).
_fedgs_solve = partial(jax.jit, static_argnames=("m", "max_sweeps"))(fedgs_solve)


def fedgs_select(h: jax.Array, counts: jax.Array, avail: jax.Array,
                 alpha: jax.Array, *, m: int, max_sweeps: int,
                 m_target: int | None = None):
    """Eq. 14/16 end-to-end: build Q from (H, counts) and run the solver.

    Pure and float32 throughout — the ONE q-construction both the host
    sampler and the scan engine (repro.fed.scan_engine) trace, so greedy
    argmax near-ties resolve identically on both paths.  ``m`` is the solver
    budget (min(M, |A_t|) on the host path); ``m_target`` is the M used in
    the count-balance penalty z (defaults to ``m``).
    """
    n = h.shape[0]
    mt = m if m_target is None else m_target
    z = 2.0 * (counts - counts.mean() - mt / n) + 1.0
    q = (alpha / n) * h - jnp.diag(z)
    q = 0.5 * (q + q.T)                               # symmetrize (H should be)
    return fedgs_solve(q.astype(jnp.float32), avail, m=m, max_sweeps=max_sweeps)


_fedgs_select = partial(jax.jit, static_argnames=("m", "max_sweeps",
                                                  "m_target"))(fedgs_select)


# ------------------------------------------- device-side baseline sampling
def gumbel_topk_select(key: jax.Array, log_weights: jax.Array,
                       avail: jax.Array, m: int) -> jax.Array:
    """Weighted sampling WITHOUT replacement among available clients, fully
    on-device (Gumbel top-k): adding i.i.d. Gumbel noise to log-weights and
    taking the top-m reproduces successive draws without replacement with
    probabilities proportional to the weights.  With uniform weights this is
    ``UniformSampler``; with ``log(data_sizes)`` it is ``MDSampler`` — the
    jit-compatible counterparts used inside ``repro.fed.scan_engine``.

    Returns s (N,) bool with exactly min(m, |avail|) True entries.
    """
    g = jax.random.gumbel(key, log_weights.shape, dtype=jnp.float32)
    scores = jnp.where(avail, log_weights + g, -jnp.inf)
    _, idx = jax.lax.top_k(scores, m)
    valid = avail[idx]                      # fewer than m available -> drop pads
    s = jnp.zeros(log_weights.shape, bool)
    return s.at[idx].set(valid)


def uniform_select(key, avail, m: int):
    """Device-side UniformSampler: uniform without replacement among A_t."""
    return gumbel_topk_select(key, jnp.zeros(avail.shape, jnp.float32), avail, m)


def md_select(key, data_sizes, avail, m: int):
    """Device-side MDSampler: without replacement, P(k) ∝ n_k, among A_t.

    The ``maximum(·, 1e-12)`` floor is the degenerate-weight guard: all-zero
    data sizes give EQUAL (finite) log-weights — uniform sampling — instead
    of the NaNs a ``w / w.sum()`` normalization would produce, and
    zero-size clients keep a finite score so they can still fill the mask
    when fewer than m positive-size clients are available (the host
    ``MDSampler``/Power-of-Choice guard is ``_size_weights``)."""
    w = jnp.log(jnp.maximum(data_sizes.astype(jnp.float32), 1e-12))
    return gumbel_topk_select(key, w, avail, m)


@dataclass
class FedGSSampler(Sampler):
    """The paper's method.  alpha weighs graph dispersion vs count balance."""
    alpha: float = 1.0
    max_sweeps: int = 64

    name = "FedGS"

    def __post_init__(self):
        self.name = f"FedGS(alpha={self.alpha})"
        self._h = None

    def set_graph(self, h: np.ndarray):
        """Install the (finite-capped) shortest-path matrix H.

        H is normalized to [0, 1] by its max finite entry.  The paper's Eq. 16
        uses raw H, but with its 3DG constants (sigma^2 = 0.01) the edge
        weights exp(-V/sigma^2) are O(1e-4) while the count-balance term z is
        O(1), which silently reduces FedGS to pure count balancing for any
        alpha in the paper's sweep.  Normalizing makes alpha trade the two
        objectives on comparable scales (DESIGN.md assumption log).
        """
        from repro.core.graph_device import cap_and_normalize
        self._h = np.asarray(cap_and_normalize(jnp.asarray(h, jnp.float32)))

    def sample(self, *, avail, m, rng, counts=None, **_):
        assert self._h is not None, "call set_graph(H) first"
        m_eff = int(min(m, int(avail.sum())))
        s = _fedgs_select(jnp.asarray(self._h),
                          jnp.asarray(counts, jnp.float32),
                          jnp.asarray(avail), jnp.float32(self.alpha),
                          m=m_eff, max_sweeps=self.max_sweeps, m_target=m)
        return np.flatnonzero(np.asarray(s))


def make_sampler(name: str, **kw) -> Sampler:
    name = name.lower()
    if name in ("uniform", "uniformsample"):
        return UniformSampler()
    if name in ("md", "mdsample"):
        return MDSampler()
    if name in ("poc", "power-of-choice", "powerofchoice"):
        return PowerOfChoiceSampler()
    if name == "fedgs":
        return FedGSSampler(**kw)
    raise ValueError(f"unknown sampler {name!r}")

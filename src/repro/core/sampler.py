"""Client samplers — the thin HOST face over the device-native sampler
subsystem (``core/sampler_device.py``, DESIGN.md §11).

FedGS solves, each round t:
    max_{s in {0,1}^|A_t|}  s^T ( alpha/N * H_A  -  diag(z_A) ) s
    s.t.  1^T s = m,   m = min(M, |A_t|)
with z_k = 2 (v_k^{t-1} - vbar^{t-1} - M/N) + 1  (long-term-bias penalty from
the count-variance objective, Eq. 7/14).

The problem is a p-dispersion variant (NP-hard); the deterministic greedy +
best-swap local search lives in ``sampler_device.fedgs_solve`` with a
``ref | pallas`` backend (tiled kernels for large N).  The baseline host
classes below no longer duplicate selection logic in numpy: each draws ONE
key from the caller's numpy stream (so per-round SeedSequence rngs keep
checkpoint-resume exactness) and delegates to the same device selects the
scan engine traces — ``uniform_select`` / ``md_select`` / the Gumbel
candidate draw.  All samplers see only the available set A_t (immediate
availability, as in the paper) and return SORTED selected indices; an empty
A_t returns an empty int array (previously ``rng.choice`` raised on the
empty support).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

import jax
import jax.numpy as jnp

# Back-compat re-exports: the device implementations moved to
# core/sampler_device.py; every pre-existing import path keeps working.
from repro.core.sampler_device import (      # noqa: F401
    BACKENDS, FAMILIES, SamplerProcess, UniformProcess, MDProcess,
    PoCProcess, FedGSProcess, fedgs_select, fedgs_solve, gumbel_topk_select,
    log_size_weights, make_sampler_process, make_sampler_step, md_select,
    select_k, uniform_select, _fedgs_select, _fedgs_solve,
)

_EMPTY = np.zeros(0, np.int64)


def _draw_key(rng: np.random.Generator) -> jax.Array:
    """One jax key per draw from the caller's numpy stream — deterministic
    given ``rng``, so FLEngine's per-round SeedSequence([seed, t]) rngs keep
    the run Markov in (params, counts, t) (checkpoint-resume exactness)."""
    return jax.random.PRNGKey(int(rng.integers(2 ** 31 - 1)))


# ----------------------------------------------------------------- baselines
class Sampler:
    """Stateless-per-round sampler interface. All samplers see only the
    available set A_t (immediate availability, as in the paper)."""
    name = "base"
    needs_losses = False

    def sample(self, *, avail: np.ndarray, m: int, rng: np.random.Generator,
               counts: np.ndarray | None = None, data_sizes=None,
               losses=None, t: int = 0) -> np.ndarray:
        raise NotImplementedError


class UniformSampler(Sampler):
    """McMahan et al. 2017: uniform without replacement among available —
    the host face of ``sampler_device.uniform_select``."""
    name = "UniformSample"

    def sample(self, *, avail, m, rng, **_):
        avail = np.asarray(avail, bool)
        if not avail.any():
            return _EMPTY
        m = int(min(m, avail.sum()))
        s = uniform_select(_draw_key(rng), jnp.asarray(avail), m)
        return np.flatnonzero(np.asarray(s))


class MDSampler(Sampler):
    """Li et al. 2020: probability proportional to local data size (with
    replacement in theory; we draw without replacement by weight, the common
    implementation), among available clients — the host face of
    ``sampler_device.md_select``, whose log-weight floor handles degenerate
    all-zero data sizes as a uniform draw."""
    name = "MDSample"

    def sample(self, *, avail, m, rng, data_sizes=None, **_):
        avail = np.asarray(avail, bool)
        if not avail.any():
            return _EMPTY
        m = int(min(m, avail.sum()))
        s = md_select(_draw_key(rng), jnp.asarray(data_sizes, jnp.float32),
                      jnp.asarray(avail), m)
        return np.flatnonzero(np.asarray(s))


class PowerOfChoiceSampler(Sampler):
    """Cho et al. 2020: sample a candidate set by data size (the shared
    Gumbel top-k draw), then keep the top-m highest local loss."""
    name = "Power-of-Choice"
    needs_losses = True

    def __init__(self, d_factor: int = 2):
        self.d_factor = d_factor

    def sample(self, *, avail, m, rng, data_sizes=None, losses=None, **_):
        avail = np.asarray(avail, bool)
        if not avail.any():
            return _EMPTY
        m = int(min(m, avail.sum()))
        d = int(min(avail.sum(), max(m, self.d_factor * m)))
        cand_mask = gumbel_topk_select(
            _draw_key(rng), log_size_weights(data_sizes),
            jnp.asarray(avail), d)
        cand = np.flatnonzero(np.asarray(cand_mask))
        order = np.argsort(-np.asarray(losses, float)[cand], kind="stable")
        return np.sort(cand[order[:m]])


@dataclass
class FedGSSampler(Sampler):
    """The paper's method.  alpha weighs graph dispersion vs count balance;
    ``solver_backend`` dispatches the Eq. 16 solve (``ref`` | ``pallas`` —
    bit-identical selected sets, tiled kernels for large N)."""
    alpha: float = 1.0
    max_sweeps: int = 64
    solver_backend: str = "ref"

    name = "FedGS"

    def __post_init__(self):
        self.name = f"FedGS(alpha={self.alpha})"
        self._h = None
        if self.solver_backend not in BACKENDS:
            raise ValueError(f"solver_backend must be one of {BACKENDS}, "
                             f"not {self.solver_backend!r}")

    def set_graph(self, h: np.ndarray):
        """Install the (finite-capped) shortest-path matrix H.

        H is normalized to [0, 1] by its max finite entry.  The paper's Eq. 16
        uses raw H, but with its 3DG constants (sigma^2 = 0.01) the edge
        weights exp(-V/sigma^2) are O(1e-4) while the count-balance term z is
        O(1), which silently reduces FedGS to pure count balancing for any
        alpha in the paper's sweep.  Normalizing makes alpha trade the two
        objectives on comparable scales (DESIGN.md assumption log).
        """
        from repro.core.graph_device import cap_and_normalize
        self._h = np.asarray(cap_and_normalize(jnp.asarray(h, jnp.float32)))

    def sample(self, *, avail, m, rng, counts=None, **_):
        assert self._h is not None, "call set_graph(H) first"
        m_eff = int(min(m, int(avail.sum())))
        s = _fedgs_select(jnp.asarray(self._h),
                          jnp.asarray(counts, jnp.float32),
                          jnp.asarray(avail), jnp.float32(self.alpha),
                          m=m_eff, max_sweeps=self.max_sweeps, m_target=m,
                          backend=self.solver_backend)
        return np.flatnonzero(np.asarray(s))


def make_sampler(name: str, **kw) -> Sampler:
    name = name.lower()
    if name in ("uniform", "uniformsample"):
        return UniformSampler()
    if name in ("md", "mdsample"):
        return MDSampler()
    if name in ("poc", "power-of-choice", "powerofchoice"):
        return PowerOfChoiceSampler()
    if name == "fedgs":
        return FedGSSampler(**kw)
    raise ValueError(f"unknown sampler {name!r}")

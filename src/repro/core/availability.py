"""The paper's seven client-availability modes (Table 1).

Each mode yields a per-client active probability ``p_k(t)``; each round the
active set is an independent Bernoulli draw with a *dedicated* seed stream
(independent of model-training randomness, as in Appendix C, so all methods
see identical availability traces).

Mode table (paper Table 1 rows -> formulas; beta defaults in parentheses):

  ====  =============================  ==========================================
  name  Table 1 row                    p_k(t)
  ====  =============================  ==========================================
  IDL   Ideal                          1
  MDF   More-Data-First (beta=0.7)     n_k^beta / max_i n_i^beta
  LDF   Less-Data-First (beta=0.7)     n_k^-beta / max_i n_i^-beta
  YMF   Y-Max-First (beta=0.9)         beta * min_i{y_ki} / max_{c,j}{y_cj}
                                         + (1 - beta)            (Gu et al. 2021)
  YC    Y-Cycle (beta=0.9, T_p=20)     beta * 1[exists y in Y_k:
                                         y/C <= phase(t) < (y+1)/C] + (1 - beta),
                                         phase(t) = (1 + t mod T_p) / T_p
  LN    Log-Normal (beta=0.5)          c_k / max_i c_i,
                                         c ~ LogNormal(0, ln 1/(1-beta))
  SLN   Sin-Log-Normal (beta=0.5;      clip(p_k^LN * (0.4 sin(2 pi
          T_p=20 via make_mode,          (1 + t mod T_p)/T_p) + 0.5), 0, 1)
          24 if built directly)
  ====  =============================  ==========================================

Every mode's probabilities are periodic in t (static modes have period 1), so
the whole schedule is a dense ``(period, N)`` table.  That table — exposed via
:meth:`AvailabilityMode.probs_table` — is the *source of truth*: it is a pure
array consumable from jit-compiled code as ``table[t % period]`` (this is how
``repro.fed.scan_engine`` draws availability on-device), while the numpy API
``probs(t)`` / ``sample(t, rng)`` is a thin host-side wrapper over the same
table.  See README.md "Availability modes" and DESIGN.md §5 for how the scan
engine batches these tables over sweep cells.
"""
from __future__ import annotations

import numpy as np


class AvailabilityMode:
    """Base class.  Subclasses implement ``_row(t)`` (the ``p_k(t)`` formula,
    which must only depend on ``t % period``) and set ``period``; the base
    class materializes the dense ``(period, N)`` probability table once and
    serves both the numpy and the jit-side APIs from it."""

    name = "base"
    period: int = 1

    def _row(self, t: int) -> np.ndarray:
        raise NotImplementedError

    def probs_table(self) -> np.ndarray:
        """The full periodic schedule as a pure ``(period, N)`` float array.

        Jittable availability: ``p(t) = probs_table()[t % period]`` — pass
        this array (plus ``period``) into device code; no host callback."""
        if not hasattr(self, "_table"):
            self._table = np.stack(
                [np.asarray(self._row(t), np.float64)
                 for t in range(self.period)])
        return self._table

    def probs(self, t: int) -> np.ndarray:
        """Per-client active probabilities for round t (numpy wrapper)."""
        return self.probs_table()[t % self.period]

    def sample(self, t: int, rng: np.random.Generator) -> np.ndarray:
        """Boolean active mask for round t."""
        p = self.probs(t)
        a = rng.random(p.shape) < p
        if not a.any():                     # guarantee at least one active client
            a[int(rng.integers(len(a)))] = True
        return a


class Ideal(AvailabilityMode):
    """Full client availability."""
    name = "IDL"

    def __init__(self, n_clients: int):
        self.n = n_clients

    def _row(self, t):
        return np.ones(self.n)


class MoreDataFirst(AvailabilityMode):
    """p_k = n_k^beta / max_i n_i^beta."""
    name = "MDF"

    def __init__(self, data_sizes, beta: float = 0.7):
        ns = np.asarray(data_sizes, float)
        self.p = ns ** beta / np.max(ns ** beta)

    def _row(self, t):
        return self.p


class LessDataFirst(AvailabilityMode):
    """p_k = n_k^-beta / max_i n_i^-beta."""
    name = "LDF"

    def __init__(self, data_sizes, beta: float = 0.7):
        ns = np.asarray(data_sizes, float)
        inv = ns ** (-beta)
        self.p = inv / np.max(inv)

    def _row(self, t):
        return self.p


class YMaxFirst(AvailabilityMode):
    """p_k = beta * min_i{y_ki} / max_{c,j}{y_cj} + (1 - beta).  (Gu et al. 2021)"""
    name = "YMF"

    def __init__(self, label_sets: list[set[int]], beta: float = 0.9):
        gmax = max(max(s) for s in label_sets)
        self.p = np.array([beta * min(s) / max(gmax, 1) + (1 - beta) for s in label_sets])

    def _row(self, t):
        return self.p


class YCycle(AvailabilityMode):
    """Periodic availability keyed on label values (ours/Table 1)."""
    name = "YC"

    def __init__(self, label_sets: list[set[int]], num_labels: int,
                 beta: float = 0.9, period: int = 20):
        self.label_sets = label_sets
        self.num_y = num_labels
        self.beta = beta
        self.tp = period
        self.period = period

    def _row(self, t):
        phase = (1 + (t % self.tp)) / self.tp
        out = np.empty(len(self.label_sets))
        for k, s in enumerate(self.label_sets):
            hit = any(y / self.num_y <= phase < (y + 1) / self.num_y for y in s)
            out[k] = self.beta * float(hit) + (1 - self.beta)
        return out


class LogNormal(AvailabilityMode):
    """Static availability c_k ~ lognormal(0, ln 1/(1-beta)); p = c/max c."""
    name = "LN"

    def __init__(self, n_clients: int, beta: float = 0.5, seed: int = 0):
        rng = np.random.default_rng(seed)
        sigma = np.log(1.0 / (1.0 - beta))
        c = rng.lognormal(0.0, sigma, n_clients)
        self.p = c / c.max()

    def _row(self, t):
        return self.p


class SinLogNormal(LogNormal):
    """Sin-modulated lognormal availability."""
    name = "SLN"

    def __init__(self, n_clients: int, beta: float = 0.5, seed: int = 0,
                 period: int = 24):
        super().__init__(n_clients, beta, seed)
        self.tp = period
        self.period = period

    def _row(self, t):
        mod = 0.4 * np.sin(2 * np.pi * (1 + (t % self.tp)) / self.tp) + 0.5
        return np.clip(self.p * mod, 0.0, 1.0)


def make_mode(name: str, *, n_clients: int, data_sizes=None, label_sets=None,
              num_labels: int = 10, beta: float | None = None,
              seed: int = 0, period: int = 20) -> AvailabilityMode:
    """Factory used by benchmarks/launchers: mode names as in the paper."""
    name = name.upper()
    if name == "IDL":
        return Ideal(n_clients)
    if name == "MDF":
        return MoreDataFirst(data_sizes, beta if beta is not None else 0.7)
    if name == "LDF":
        return LessDataFirst(data_sizes, beta if beta is not None else 0.7)
    if name == "YMF":
        return YMaxFirst(label_sets, beta if beta is not None else 0.9)
    if name == "YC":
        return YCycle(label_sets, num_labels, beta if beta is not None else 0.9, period)
    if name == "LN":
        return LogNormal(n_clients, beta if beta is not None else 0.5, seed)
    if name == "SLN":
        return SinLogNormal(n_clients, beta if beta is not None else 0.5, seed, period)
    raise ValueError(f"unknown availability mode {name!r}")


ALL_MODES = ("IDL", "MDF", "LDF", "YMF", "YC", "LN", "SLN")

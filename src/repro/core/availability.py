"""The paper's seven client-availability modes (Table 1).

Each mode yields a per-client active probability ``p_k(t)``; each round the
active set is an independent Bernoulli draw with a *dedicated* seed stream
(independent of model-training randomness, as in Appendix C, so all methods
see identical availability traces).

Mode table (paper Table 1 rows -> formulas; beta defaults in parentheses):

  ====  =============================  ==========================================
  name  Table 1 row                    p_k(t)
  ====  =============================  ==========================================
  IDL   Ideal                          1
  MDF   More-Data-First (beta=0.7)     n_k^beta / max_i n_i^beta
  LDF   Less-Data-First (beta=0.7)     n_k^-beta / max_i n_i^-beta
  YMF   Y-Max-First (beta=0.9)         beta * min_i{y_ki} / max_{c,j}{y_cj}
                                         + (1 - beta)            (Gu et al. 2021)
  YC    Y-Cycle (beta=0.9, T_p=20)     beta * 1[exists y in Y_k:
                                         y/C <= phase(t) < (y+1)/C] + (1 - beta),
                                         phase(t) = (1 + t mod T_p) / T_p
                                         (last band closed at phase = 1.0,
                                          hit at t = T_p - 1)
  LN    Log-Normal (beta=0.5)          c_k / max_i c_i,
                                         c ~ LogNormal(0, ln 1/(1-beta))
  SLN   Sin-Log-Normal (beta=0.5;      clip(p_k^LN * (0.4 sin(2 pi
          T_p=20 via make_mode,          (1 + t mod T_p)/T_p) + 0.5), 0, 1)
          24 if built directly)
  ====  =============================  ==========================================

Every mode's probabilities are periodic in t (static modes have period 1), so
the whole schedule is a dense ``(period, N)`` table — which makes each mode
one trivial instance of the device-native availability-scenario subsystem
(``repro.core.availability_device``): :meth:`AvailabilityMode.process`
wraps the table as a ``TableProcess``, the stateless member of the process
family the scan engine carries through ``lax.scan``.  This module is the
thin numpy FACE over that subsystem (mirroring ``core/graph.py`` over
``core/graph_device.py``): the mode classes construct the f64 tables from
host data (sizes, label sets), while the draw itself — Bernoulli + the
force-one-active floor — delegates to the SHARED helpers
(``sample_bernoulli_np`` here, ``bernoulli_nonempty`` in the scan), and
:func:`host_draw` / :func:`host_trace` are the ONE host wrapper both
``FLEngine.run`` and ``scan_engine.precompute_masks`` route through, so
host-vs-scan mask parity is structural.  Stateful scenario families
(Gilbert–Elliott churn, cluster outages, drift, deadlines) get the same
host face through :class:`ProcessMode`.  See README.md "Availability
scenarios" and DESIGN.md §5/§10.
"""
from __future__ import annotations

import numpy as np

import jax

from repro.core.availability_device import (
    _STEP_SALT, AvailabilityProcess, TableProcess, sample_bernoulli_np,
)


class AvailabilityMode:
    """Base class.  Subclasses implement ``_row(t)`` (the ``p_k(t)`` formula,
    which must only depend on ``t % period``) and set ``period``; the base
    class materializes the dense ``(period, N)`` probability table once and
    serves both the numpy and the jit-side APIs from it."""

    name = "base"
    period: int = 1

    def _row(self, t: int) -> np.ndarray:
        raise NotImplementedError

    def probs_table(self) -> np.ndarray:
        """The full periodic schedule as a pure ``(period, N)`` float array.

        Jittable availability: ``p(t) = probs_table()[t % period]`` — pass
        this array (plus ``period``) into device code; no host callback."""
        if not hasattr(self, "_table"):
            self._table = np.stack(
                [np.asarray(self._row(t), np.float64)
                 for t in range(self.period)])
        return self._table

    def probs(self, t: int) -> np.ndarray:
        """Per-client active probabilities for round t (numpy wrapper)."""
        return self.probs_table()[t % self.period]

    def sample(self, t: int, rng: np.random.Generator) -> np.ndarray:
        """Boolean active mask for round t — the shared Bernoulli +
        force-one-active draw (availability_device.sample_bernoulli_np)."""
        return sample_bernoulli_np(self.probs(t), rng)

    def process(self) -> TableProcess:
        """This mode as a device-native ``AvailabilityProcess`` (the f64
        table is kept on the process for the host face's bit-parity; the
        device params cast to float32)."""
        if not hasattr(self, "_process"):
            self._process = TableProcess(self.probs_table(), name=self.name)
        return self._process


class Ideal(AvailabilityMode):
    """Full client availability."""
    name = "IDL"

    def __init__(self, n_clients: int):
        self.n = n_clients

    def _row(self, t):
        return np.ones(self.n)


class MoreDataFirst(AvailabilityMode):
    """p_k = n_k^beta / max_i n_i^beta."""
    name = "MDF"

    def __init__(self, data_sizes, beta: float = 0.7):
        ns = np.asarray(data_sizes, float)
        self.p = ns ** beta / np.max(ns ** beta)

    def _row(self, t):
        return self.p


class LessDataFirst(AvailabilityMode):
    """p_k = n_k^-beta / max_i n_i^-beta."""
    name = "LDF"

    def __init__(self, data_sizes, beta: float = 0.7):
        ns = np.asarray(data_sizes, float)
        inv = ns ** (-beta)
        self.p = inv / np.max(inv)

    def _row(self, t):
        return self.p


class YMaxFirst(AvailabilityMode):
    """p_k = beta * min_i{y_ki} / max_{c,j}{y_cj} + (1 - beta).  (Gu et al. 2021)"""
    name = "YMF"

    def __init__(self, label_sets: list[set[int]], beta: float = 0.9):
        gmax = max(max(s) for s in label_sets)
        self.p = np.array([beta * min(s) / max(gmax, 1) + (1 - beta) for s in label_sets])

    def _row(self, t):
        return self.p


class YCycle(AvailabilityMode):
    """Periodic availability keyed on label values (ours/Table 1)."""
    name = "YC"

    def __init__(self, label_sets: list[set[int]], num_labels: int,
                 beta: float = 0.9, period: int = 20):
        self.label_sets = label_sets
        self.num_y = num_labels
        self.beta = beta
        self.tp = period
        self.period = period

    def _row(self, t):
        phase = (1 + (t % self.tp)) / self.tp
        out = np.empty(len(self.label_sets))
        for k, s in enumerate(self.label_sets):
            # label bands are half-open [y/C, (y+1)/C) except the LAST band,
            # which closes at 1.0: phase hits exactly 1.0 at t = T_p - 1, and
            # an all-open top band would match no label there, silently
            # dropping every client to the 1 - beta floor once per cycle
            hit = any(y / self.num_y <= phase
                      and (phase < (y + 1) / self.num_y or y + 1 == self.num_y)
                      for y in s)
            out[k] = self.beta * float(hit) + (1 - self.beta)
        return out


class LogNormal(AvailabilityMode):
    """Static availability c_k ~ lognormal(0, ln 1/(1-beta)); p = c/max c."""
    name = "LN"

    def __init__(self, n_clients: int, beta: float = 0.5, seed: int = 0):
        rng = np.random.default_rng(seed)
        sigma = np.log(1.0 / (1.0 - beta))
        c = rng.lognormal(0.0, sigma, n_clients)
        self.p = c / c.max()

    def _row(self, t):
        return self.p


class SinLogNormal(LogNormal):
    """Sin-modulated lognormal availability."""
    name = "SLN"

    def __init__(self, n_clients: int, beta: float = 0.5, seed: int = 0,
                 period: int = 24):
        super().__init__(n_clients, beta, seed)
        self.tp = period
        self.period = period

    def _row(self, t):
        mod = 0.4 * np.sin(2 * np.pi * (1 + (t % self.tp)) / self.tp) + 0.5
        return np.clip(self.p * mod, 0.0, 1.0)


def make_mode(name: str, *, n_clients: int, data_sizes=None, label_sets=None,
              num_labels: int = 10, beta: float | None = None,
              seed: int = 0, period: int = 20) -> AvailabilityMode:
    """Factory used by benchmarks/launchers: mode names as in the paper."""
    name = name.upper()
    if name == "IDL":
        return Ideal(n_clients)
    if name == "MDF":
        return MoreDataFirst(data_sizes, beta if beta is not None else 0.7)
    if name == "LDF":
        return LessDataFirst(data_sizes, beta if beta is not None else 0.7)
    if name == "YMF":
        return YMaxFirst(label_sets, beta if beta is not None else 0.9)
    if name == "YC":
        return YCycle(label_sets, num_labels, beta if beta is not None else 0.9, period)
    if name == "LN":
        return LogNormal(n_clients, beta if beta is not None else 0.5, seed)
    if name == "SLN":
        return SinLogNormal(n_clients, beta if beta is not None else 0.5, seed, period)
    raise ValueError(f"unknown availability mode {name!r}")


ALL_MODES = ("IDL", "MDF", "LDF", "YMF", "YC", "LN", "SLN")


# ----------------------------------------------------------- host face
class ProcessMode:
    """Numpy face over ANY ``AvailabilityProcess`` — duck-types the
    ``probs(t)`` / ``sample(t, rng)`` API that ``FLEngine`` and
    ``precompute_masks`` consume, so the stateful scenario families run on
    the host path too.

    Stateless families (table, drift) serve exact float64 probabilities via
    ``process.host_probs``; stateful families replay the DEVICE probability
    stream (same init/step keys as a scan cell with this ``avail_seed``, so
    the latent chain trajectory is identical host-vs-scan; only the
    Bernoulli backend differs — numpy here, threefry in-scan, the same split
    the seven legacy modes already have, DESIGN.md assumption log #7/#10).
    Rows are cached, so replay is deterministic and order-independent."""

    def __init__(self, process: AvailabilityProcess, avail_seed: int = 1234):
        self.process = process
        self.name = getattr(process, "name", process.family)
        self.avail_seed = avail_seed        # host_draw checks it matches
        self._key = jax.random.PRNGKey(avail_seed)
        self._state = process.init(self._key)
        self._rows: list[np.ndarray] = []

    def probs(self, t: int) -> np.ndarray:
        hp = self.process.host_probs(t)
        if hp is not None:
            return np.asarray(hp, np.float64)
        while len(self._rows) <= t:
            tt = len(self._rows)
            akey = jax.random.fold_in(self._key, tt)
            p, self._state = self.process.step(
                self._state, jax.random.fold_in(akey, _STEP_SALT), tt)
            self._rows.append(np.asarray(p, np.float64))
        return self._rows[t]

    def sample(self, t: int, rng: np.random.Generator) -> np.ndarray:
        return sample_bernoulli_np(self.probs(t), rng)


def host_round_rng(avail_seed: int, t: int) -> np.random.Generator:
    """The per-round numpy availability stream — ``SeedSequence([seed, t])``,
    independent of model-training randomness (Appendix C)."""
    return np.random.default_rng(np.random.SeedSequence([avail_seed, t]))


def host_draw(mode, t: int, avail_seed: int = 1234) -> np.ndarray:
    """ONE round's host-side availability mask.  The single wrapper BOTH
    ``FLEngine.run`` and ``scan_engine.precompute_masks`` call, so the masks
    the scan engine replays are bit-identical to the host engine's draws by
    construction.  ``mode`` is anything with ``sample(t, rng)`` — an
    ``AvailabilityMode`` or a ``ProcessMode``.

    A ``ProcessMode`` bakes its LATENT-stream seed at construction; drawing
    it under a different Bernoulli seed would produce a trace matching
    neither device run, so a mismatch is an error, not a silent skew."""
    mode_seed = getattr(mode, "avail_seed", None)
    if mode_seed is not None and mode_seed != avail_seed:
        raise ValueError(
            f"availability seed mismatch: the ProcessMode was built with "
            f"avail_seed={mode_seed} but host_draw was asked for "
            f"avail_seed={avail_seed}; the latent process stream and the "
            f"Bernoulli stream must share one seed for host<->scan parity")
    return mode.sample(t, host_round_rng(avail_seed, t))


def host_trace(mode, rounds: int, avail_seed: int = 1234) -> np.ndarray:
    """(rounds, N) bool availability trace via :func:`host_draw`."""
    return np.stack([host_draw(mode, t, avail_seed) for t in range(rounds)])

"""Secure Scalar Product Protocol (Du & Zhan 2002; paper Appendix D, Alg. 2).

Computes A·B between two clients' private feature vectors with the server as
the commodity/relay party.  The server never sees A or B — only masked
vectors and the blinded partial results v1, v2 whose sum is the product.

This is a faithful *simulation* of the message flow (all parties in-process);
the point is that the values visible to the server are exactly the protocol's
messages, which we assert leak nothing beyond the final dot product (see
tests/test_sspp.py for the reconstruction-infeasibility property check).
"""
from __future__ import annotations

import numpy as np


class _Client:
    def __init__(self, feature: np.ndarray):
        self._u = np.asarray(feature, np.float64)   # private

    # --- protocol steps (only masked data leaves the client) -------------
    def mask(self, r: np.ndarray) -> np.ndarray:
        return self._u + r

    def partial_b(self, a_hat: np.ndarray, r_b: float, rng) -> tuple[float, float]:
        v2 = float(rng.normal(scale=10.0))
        u = float(a_hat @ self._u) + r_b - v2
        return u, v2

    def partial_a(self, u: float, r_a: float, ra_vec: np.ndarray,
                  b_hat: np.ndarray) -> float:
        return u - float(ra_vec @ b_hat) + r_a


def secure_dot(feat_a: np.ndarray, feat_b: np.ndarray, *, seed: int = 0,
               transcript: list | None = None) -> float:
    """Run the protocol between two clients; returns A·B.

    ``transcript`` (if given) collects every value the *server* observes, for
    leakage analysis in tests.
    """
    rng = np.random.default_rng(seed)
    a, b = _Client(feat_a), _Client(feat_b)
    d = len(feat_a)

    # 1. server (commodity role) generates correlated randomness
    ra_vec = rng.normal(size=d)
    rb_vec = rng.normal(size=d)
    r_a = float(rng.normal())
    r_b = float(ra_vec @ rb_vec) - r_a

    # 2-3. clients mask and upload
    a_hat = a.mask(ra_vec)
    b_hat = b.mask(rb_vec)

    # 4-7. blinded partials relayed via the server
    u, v2 = b.partial_b(a_hat, r_b, rng)
    v1 = a.partial_a(u, r_a, ra_vec, b_hat)

    if transcript is not None:
        transcript.extend([a_hat.copy(), b_hat.copy(), u, v1, v2])

    # 8. server combines
    return v1 + v2


def secure_similarity_matrix(features: np.ndarray, *, seed: int = 0) -> np.ndarray:
    """All-pairs dot-product similarity via SSPP (upper triangle runs the
    protocol; result is exact up to float error)."""
    feats = np.asarray(features, np.float64)
    n = len(feats)
    v = np.zeros((n, n))
    for i in range(n):
        v[i, i] = float(feats[i] @ feats[i])    # self-similarity is local
        for j in range(i + 1, n):
            v[i, j] = v[j, i] = secure_dot(feats[i], feats[j],
                                           seed=seed * 1_000_003 + i * n + j)
    return v

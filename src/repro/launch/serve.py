"""Batched serving entry point: prefill a prompt batch, then decode tokens.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --reduced \
      --batch 8 --prompt-len 64 --gen 32

On a real accelerator mesh the same program runs sharded (the dry-run proves
the decode_32k / long_500k shardings lower); on CPU this drives the reduced
configs end-to-end and reports tokens/s.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.models import lm


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(args.seed)
    params = lm.init_params(key, cfg)

    rng = np.random.default_rng(args.seed)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32)}
    if cfg.family == "vlm" and cfg.n_image_tokens:
        batch["image_emb"] = jnp.asarray(
            rng.normal(0, 0.02, (args.batch, cfg.n_image_tokens, cfg.d_model)),
            jnp.dtype(cfg.dtype))
    if cfg.enc_dec:
        batch["audio_frames"] = jnp.asarray(
            rng.normal(0, 0.02, (args.batch, cfg.n_audio_frames, cfg.d_model)),
            jnp.dtype(cfg.dtype))

    # prefill builds a cache sized for prompt+gen
    total = args.prompt_len + (cfg.n_image_tokens if cfg.family == "vlm" else 0)
    max_len = total + args.gen

    prefill_j = jax.jit(lambda p, b: lm.prefill(p, cfg, b))
    decode_j = jax.jit(lambda p, t, c: lm.decode_step(p, cfg, t, c))

    t0 = time.time()
    logits, cache = prefill_j(params, batch)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    # grow the cache to max_len (prefill sized it to the prompt)
    def grow(x):
        if x.ndim == 5 and x.shape[2] == total:          # (L,B,S,H,D)
            pad = [(0, 0)] * 5
            pad[2] = (0, args.gen)
            return jnp.pad(x, pad)
        return x
    cache = {k: (grow(v) if k in ("k", "v") else v) for k, v in cache.items()}

    toks = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [np.asarray(toks)]
    t1 = time.time()
    for i in range(args.gen - 1):
        key, sub = jax.random.split(key)
        logits, cache = decode_j(params, toks, cache)
        if args.temperature > 0:
            toks = jax.random.categorical(sub, logits / args.temperature, -1).astype(jnp.int32)
        else:
            toks = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(np.asarray(toks))
    jax.block_until_ready(toks)
    t_decode = time.time() - t1

    gen = np.stack(out, 1)
    n_tok = gen.size
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} gen={args.gen}")
    print(f"prefill: {t_prefill:.3f}s  decode: {t_decode:.3f}s "
          f"({n_tok / max(t_decode, 1e-9):.1f} tok/s)")
    print("first sequence:", gen[0][:16].tolist())
    return gen


if __name__ == "__main__":
    main()

"""Serving entry points: the LM decode path and the federated-simulation
service (ROADMAP "simulation-as-a-service").

LM path — prefill a prompt batch, then decode tokens:

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --reduced \
      --batch 8 --prompt-len 64 --gen 32

Federated-simulation path — a long-lived ``SimService`` over ONE hot
``ScanEngine``: heterogeneous sweep-cell requests (mixed samplers /
availability scenarios / aggregators) batch into a single ``run_batch``
program, and per-round metrics stream back segment by segment through the
engine's donated/pipelined ``run_batch_stream`` (DESIGN.md §15) instead of
arriving post-scan.  With ``--compile-cache-dir`` a restarted service
re-loads its XLA programs from the persistent cache:

  PYTHONPATH=src python -m repro.launch.serve --fedsim --cells 4 \
      --rounds 24 --segment 8 --compile-cache-dir /tmp/jaxcache
"""
from __future__ import annotations

import argparse
import time
from dataclasses import dataclass

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.launch.obs_cli import (
    add_observability_args, finish_observability, make_observability,
)
from repro.models import lm


# ------------------------------------------------- simulation-as-a-service
@dataclass
class SegmentUpdate:
    """One streamed per-request slice of a scan segment."""
    request: int               # submit() ticket
    t0: int                    # first round of the segment
    rounds: int                # segment length
    val_loss: np.ndarray       # (rounds,) — NaN off the eval cadence
    val_acc: np.ndarray        # (rounds,)
    sel: np.ndarray            # (rounds, M) sampled sets (padded)
    valid: np.ndarray          # (rounds, M)
    metrics: dict | None = None   # per-round in-scan telemetry slice
                                  # (ScanConfig.telemetry cells only)


class SimService:
    """Queue sweep-cell requests, execute them as ONE batched scan program,
    stream per-segment metrics back incrementally.

    The service owns a single ``ScanEngine``: its ``ProgramCache`` keeps the
    compiled programs hot across ``drain()`` calls (same static shapes =
    zero recompiles), and ``ScanConfig.compile_cache_dir`` persists them
    across service restarts.  ``submit`` accepts everything
    ``ScanEngine.cell`` does — the ``lax.switch`` subsystems mean arbitrary
    sampler/availability/aggregator mixes still compile to one program.

    Observability (DESIGN.md §17): per-request queue latencies land in
    ``self.timings`` — ``first_segment_s`` (submit -> first streamed
    segment) and ``complete_s`` (submit -> reassembled history) — and on
    the returned ``ScanHistory`` as ``.request_timing``;
    ``metrics_text()`` renders service counters + the engine's runtime
    snapshot as a Prometheus text exposition."""

    def __init__(self, engine):
        self.engine = engine
        self._pending: list[tuple[int, dict]] = []
        self._next = 0
        self.histories: dict[int, object] = {}   # request -> ScanHistory
        self.timings: dict[int, dict] = {}       # request -> latency dict
        self._counters = {"requests_total": 0, "drains_total": 0,
                          "segments_streamed_total": 0,
                          "updates_streamed_total": 0,
                          "rounds_streamed_total": 0,
                          "drain_busy_seconds_total": 0.0}

    def submit(self, **cell_kwargs) -> int:
        """Queue one sweep-cell request; returns its ticket."""
        rid = self._next
        self._next += 1
        self._pending.append((rid, self.engine.cell(**cell_kwargs)))
        self.timings[rid] = {"submit_time": time.time()}
        self._counters["requests_total"] += 1
        return rid

    def _segment_metrics(self, t0: int, j: int) -> dict | None:
        """This segment's per-request telemetry slice, if the engine just
        stashed one (telemetry-off runs stream ``None``)."""
        parts = getattr(self.engine, "_tel_parts", None)
        if parts and parts[-1][0] == t0:
            return {k: v[j] for k, v in parts[-1][2].items()}
        return None

    def drain(self, *, segment: int = 0, ckpt_path=None, resume=False):
        """Run every pending request as one batched program, yielding a
        ``SegmentUpdate`` per (request, segment) as soon as that segment's
        trajectory lands on host — segment k+1 computes while k streams.
        ``segment=0`` runs the whole horizon as a single segment.  Final
        ``ScanHistory`` objects land in ``self.histories``."""
        if not self._pending:
            return
        ids = [rid for rid, _ in self._pending]
        cells = [c for _, c in self._pending]
        self._pending = []
        t_start = time.time()
        self._counters["drains_total"] += 1
        parts = []
        for t0, k, traj in self.engine.run_batch_stream(
                cells, ckpt_every=segment, ckpt_path=ckpt_path,
                resume=resume):
            parts.append(traj)
            self._counters["segments_streamed_total"] += 1
            self._counters["rounds_streamed_total"] += k * len(ids)
            now = time.time()
            for j, rid in enumerate(ids):
                self.timings[rid].setdefault(
                    "first_segment_s",
                    now - self.timings[rid]["submit_time"])
                self._counters["updates_streamed_total"] += 1
                yield SegmentUpdate(
                    request=rid, t0=t0, rounds=k,
                    val_loss=traj["val_loss"][j], val_acc=traj["val_acc"][j],
                    sel=traj["sel"][j], valid=traj["valid"][j],
                    metrics=self._segment_metrics(t0, j))
        full = jax.tree_util.tree_map(
            lambda *xs: np.concatenate(xs, axis=1), *parts)
        out = {**full, "counts": self.engine.final_counts}
        tel = self.engine._assemble_telemetry()
        done = time.time()
        self._counters["drain_busy_seconds_total"] += done - t_start
        for j, rid in enumerate(ids):
            self.timings[rid]["complete_s"] = \
                done - self.timings[rid]["submit_time"]
            hist = self.engine._to_history(out, j, telemetry=tel)
            hist.request_timing = dict(self.timings[rid])
            self.histories[rid] = hist
            if self.engine.sink is not None:
                self.engine.sink.emit(
                    "request", {"request": rid, **self.timings[rid]})

    def stats(self) -> dict:
        """Service counters merged over the engine's runtime snapshot
        (program-cache / checkpoint-writer / span counters)."""
        return {**self.engine.runtime_stats(), "service": dict(self._counters)}

    def metrics_text(self) -> str:
        """Prometheus text exposition (format 0.0.4) of the service
        counters, per-request queue latencies and the engine's runtime
        counters — scrape or dump, zero dependencies."""
        from repro.obs import render_prometheus
        eng = self.engine.runtime_stats()
        wall = max(self._counters["drain_busy_seconds_total"], 1e-9)
        fams = {
            "requests_total": {
                "type": "counter", "help": "Sweep-cell requests submitted.",
                "samples": [({}, self._counters["requests_total"])]},
            "segments_streamed_total": {
                "type": "counter", "help": "Scan segments streamed.",
                "samples": [({},
                             self._counters["segments_streamed_total"])]},
            "rounds_streamed_total": {
                "type": "counter",
                "help": "Cell-rounds streamed to clients.",
                "samples": [({}, self._counters["rounds_streamed_total"])]},
            "rounds_per_second": {
                "type": "gauge",
                "help": "Cell-rounds per busy drain second.",
                "samples": [({}, self._counters["rounds_streamed_total"]
                             / wall)]},
            "program_cache_hit_rate": {
                "type": "gauge",
                "help": "ProgramCache hits / (hits + misses).",
                "samples": [({}, eng["hits"] / max(
                    eng["hits"] + eng["misses"], 1))]},
            "compile_ms_total": {
                "type": "counter",
                "help": "Total XLA compile wall-clock (ms).",
                "samples": [({}, eng["compile_ms"])]},
            "request_queue_seconds": {
                "type": "gauge",
                "help": "submit -> first streamed segment latency.",
                "samples": [({"request": str(r)}, tm["first_segment_s"])
                            for r, tm in sorted(self.timings.items())
                            if "first_segment_s" in tm]},
            "request_complete_seconds": {
                "type": "gauge",
                "help": "submit -> reassembled history latency.",
                "samples": [({"request": str(r)}, tm["complete_s"])
                            for r, tm in sorted(self.timings.items())
                            if "complete_s" in tm]},
        }
        return render_prometheus(fams)


def _fedsim_main(args):
    from repro.core.availability_device import make_process
    from repro.data.synthetic import make_synthetic
    from repro.fed.models import logistic_regression
    from repro.fed.scan_engine import ScanConfig, ScanEngine

    ds = make_synthetic(n_clients=args.n_clients, alpha=0.5, beta=0.5,
                        seed=args.seed)
    cfg = ScanConfig(rounds=args.rounds, m=4, local_steps=2, batch_size=8,
                     eval_every=1, sampler="uniform",
                     compile_cache_dir=args.compile_cache_dir,
                     telemetry=bool(getattr(args, "telemetry", False)))
    tracer, sink = make_observability(args)
    svc = SimService(ScanEngine(ds, logistic_regression(), cfg,
                                tracer=tracer, sink=sink))
    scenarios = ("GE", "CLUSTER", "DRIFT", "DEADLINE")
    tickets = [svc.submit(
        seed=i, avail_seed=100 + i,
        process=make_process(scenarios[i % 4], n_clients=ds.n_clients,
                             data_sizes=ds.sizes,
                             label_sets=ds.label_sets(),
                             num_labels=ds.num_classes,
                             rounds=args.rounds, seed=7 + i))
        for i in range(args.cells)]
    t0 = time.time()
    n_updates = 0
    try:
        for upd in svc.drain(segment=args.segment):
            n_updates += 1
            loss = upd.val_loss[np.isfinite(upd.val_loss)]
            print(f"req {upd.request} rounds "
                  f"[{upd.t0}, {upd.t0 + upd.rounds}) "
                  f"loss {loss[-1]:.4f}" if loss.size else
                  f"req {upd.request} rounds "
                  f"[{upd.t0}, {upd.t0 + upd.rounds})")
        wall = time.time() - t0
        st = svc.stats()
        print(f"fedsim: {len(tickets)} cells x {args.rounds} rounds, "
              f"{n_updates} streamed updates in {wall:.2f}s "
              f"({len(tickets) * args.rounds / max(wall, 1e-9):.1f} "
              f"cell-rounds/s)")
        print(f"programs: {st['misses']} built ({st['compiles']} compiles, "
              f"{st['compile_ms']:.0f} ms), {st['hits']} cache hits")
        print(svc.metrics_text(), end="")
    finally:
        trace = finish_observability(tracer, sink, args)
        if trace:
            print(f"trace: {trace}")
    return [svc.histories[t] for t in tickets]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    # federated-simulation service mode (SimService over one hot ScanEngine)
    ap.add_argument("--fedsim", action="store_true",
                    help="serve federated sweep cells instead of LM decode")
    ap.add_argument("--cells", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=24)
    ap.add_argument("--segment", type=int, default=8,
                    help="streaming segment length (0 = one segment)")
    ap.add_argument("--n-clients", type=int, default=16)
    ap.add_argument("--compile-cache-dir", default=None,
                    help="persistent XLA compile cache directory")
    ap.add_argument("--telemetry", action="store_true",
                    help="enable the in-scan per-round health channel "
                         "(ScanConfig.telemetry)")
    add_observability_args(ap)
    args = ap.parse_args(argv)

    if args.fedsim:
        return _fedsim_main(args)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(args.seed)
    params = lm.init_params(key, cfg)

    rng = np.random.default_rng(args.seed)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32)}
    if cfg.family == "vlm" and cfg.n_image_tokens:
        batch["image_emb"] = jnp.asarray(
            rng.normal(0, 0.02, (args.batch, cfg.n_image_tokens, cfg.d_model)),
            jnp.dtype(cfg.dtype))
    if cfg.enc_dec:
        batch["audio_frames"] = jnp.asarray(
            rng.normal(0, 0.02, (args.batch, cfg.n_audio_frames, cfg.d_model)),
            jnp.dtype(cfg.dtype))

    # prefill builds a cache sized for prompt+gen
    total = args.prompt_len + (cfg.n_image_tokens if cfg.family == "vlm" else 0)
    max_len = total + args.gen

    prefill_j = jax.jit(lambda p, b: lm.prefill(p, cfg, b))
    decode_j = jax.jit(lambda p, t, c: lm.decode_step(p, cfg, t, c))

    t0 = time.time()
    logits, cache = prefill_j(params, batch)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    # grow the cache to max_len (prefill sized it to the prompt)
    def grow(x):
        if x.ndim == 5 and x.shape[2] == total:          # (L,B,S,H,D)
            pad = [(0, 0)] * 5
            pad[2] = (0, args.gen)
            return jnp.pad(x, pad)
        return x
    cache = {k: (grow(v) if k in ("k", "v") else v) for k, v in cache.items()}

    toks = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [np.asarray(toks)]
    t1 = time.time()
    for i in range(args.gen - 1):
        key, sub = jax.random.split(key)
        logits, cache = decode_j(params, toks, cache)
        if args.temperature > 0:
            toks = jax.random.categorical(sub, logits / args.temperature, -1).astype(jnp.int32)
        else:
            toks = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(np.asarray(toks))
    jax.block_until_ready(toks)
    t_decode = time.time() - t1

    gen = np.stack(out, 1)
    n_tok = gen.size
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} gen={args.gen}")
    print(f"prefill: {t_prefill:.3f}s  decode: {t_decode:.3f}s "
          f"({n_tok / max(t_decode, 1e-9):.1f} tok/s)")
    print("first sequence:", gen[0][:16].tolist())
    return gen


if __name__ == "__main__":
    main()

"""Serving entry points: the LM decode path and the federated-simulation
service (ROADMAP "simulation-as-a-service").

LM path — prefill a prompt batch, then decode tokens:

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --reduced \
      --batch 8 --prompt-len 64 --gen 32

Federated-simulation path — a long-lived ``SimService`` over ONE hot
``ScanEngine``: heterogeneous sweep-cell requests (mixed samplers /
availability scenarios / aggregators) batch into a single ``run_batch``
program, and per-round metrics stream back segment by segment through the
engine's donated/pipelined ``run_batch_stream`` (DESIGN.md §15) instead of
arriving post-scan.  With ``--compile-cache-dir`` a restarted service
re-loads its XLA programs from the persistent cache:

  PYTHONPATH=src python -m repro.launch.serve --fedsim --cells 4 \
      --rounds 24 --segment 8 --compile-cache-dir /tmp/jaxcache
"""
from __future__ import annotations

import argparse
import time
from dataclasses import dataclass

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.models import lm


# ------------------------------------------------- simulation-as-a-service
@dataclass
class SegmentUpdate:
    """One streamed per-request slice of a scan segment."""
    request: int               # submit() ticket
    t0: int                    # first round of the segment
    rounds: int                # segment length
    val_loss: np.ndarray       # (rounds,) — NaN off the eval cadence
    val_acc: np.ndarray        # (rounds,)
    sel: np.ndarray            # (rounds, M) sampled sets (padded)
    valid: np.ndarray          # (rounds, M)


class SimService:
    """Queue sweep-cell requests, execute them as ONE batched scan program,
    stream per-segment metrics back incrementally.

    The service owns a single ``ScanEngine``: its ``ProgramCache`` keeps the
    compiled programs hot across ``drain()`` calls (same static shapes =
    zero recompiles), and ``ScanConfig.compile_cache_dir`` persists them
    across service restarts.  ``submit`` accepts everything
    ``ScanEngine.cell`` does — the ``lax.switch`` subsystems mean arbitrary
    sampler/availability/aggregator mixes still compile to one program."""

    def __init__(self, engine):
        self.engine = engine
        self._pending: list[tuple[int, dict]] = []
        self._next = 0
        self.histories: dict[int, object] = {}   # request -> ScanHistory

    def submit(self, **cell_kwargs) -> int:
        """Queue one sweep-cell request; returns its ticket."""
        rid = self._next
        self._next += 1
        self._pending.append((rid, self.engine.cell(**cell_kwargs)))
        return rid

    def drain(self, *, segment: int = 0, ckpt_path=None, resume=False):
        """Run every pending request as one batched program, yielding a
        ``SegmentUpdate`` per (request, segment) as soon as that segment's
        trajectory lands on host — segment k+1 computes while k streams.
        ``segment=0`` runs the whole horizon as a single segment.  Final
        ``ScanHistory`` objects land in ``self.histories``."""
        if not self._pending:
            return
        ids = [rid for rid, _ in self._pending]
        cells = [c for _, c in self._pending]
        self._pending = []
        parts = []
        for t0, k, traj in self.engine.run_batch_stream(
                cells, ckpt_every=segment, ckpt_path=ckpt_path,
                resume=resume):
            parts.append(traj)
            for j, rid in enumerate(ids):
                yield SegmentUpdate(
                    request=rid, t0=t0, rounds=k,
                    val_loss=traj["val_loss"][j], val_acc=traj["val_acc"][j],
                    sel=traj["sel"][j], valid=traj["valid"][j])
        full = jax.tree_util.tree_map(
            lambda *xs: np.concatenate(xs, axis=1), *parts)
        out = {**full, "counts": self.engine.final_counts}
        for j, rid in enumerate(ids):
            self.histories[rid] = self.engine._to_history(out, j)

    def stats(self) -> dict:
        """The engine's program-cache counters (hits/misses/compile_ms)."""
        return self.engine.runtime_stats()


def _fedsim_main(args):
    from repro.core.availability_device import make_process
    from repro.data.synthetic import make_synthetic
    from repro.fed.models import logistic_regression
    from repro.fed.scan_engine import ScanConfig, ScanEngine

    ds = make_synthetic(n_clients=args.n_clients, alpha=0.5, beta=0.5,
                        seed=args.seed)
    cfg = ScanConfig(rounds=args.rounds, m=4, local_steps=2, batch_size=8,
                     eval_every=1, sampler="uniform",
                     compile_cache_dir=args.compile_cache_dir)
    svc = SimService(ScanEngine(ds, logistic_regression(), cfg))
    scenarios = ("GE", "CLUSTER", "DRIFT", "DEADLINE")
    tickets = [svc.submit(
        seed=i, avail_seed=100 + i,
        process=make_process(scenarios[i % 4], n_clients=ds.n_clients,
                             data_sizes=ds.sizes,
                             label_sets=ds.label_sets(),
                             num_labels=ds.num_classes,
                             rounds=args.rounds, seed=7 + i))
        for i in range(args.cells)]
    t0 = time.time()
    n_updates = 0
    for upd in svc.drain(segment=args.segment):
        n_updates += 1
        loss = upd.val_loss[np.isfinite(upd.val_loss)]
        print(f"req {upd.request} rounds [{upd.t0}, {upd.t0 + upd.rounds}) "
              f"loss {loss[-1]:.4f}" if loss.size else
              f"req {upd.request} rounds [{upd.t0}, {upd.t0 + upd.rounds})")
    wall = time.time() - t0
    st = svc.stats()
    print(f"fedsim: {len(tickets)} cells x {args.rounds} rounds, "
          f"{n_updates} streamed updates in {wall:.2f}s "
          f"({len(tickets) * args.rounds / max(wall, 1e-9):.1f} "
          f"cell-rounds/s)")
    print(f"programs: {st['misses']} built ({st['compiles']} compiles, "
          f"{st['compile_ms']:.0f} ms), {st['hits']} cache hits")
    return [svc.histories[t] for t in tickets]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    # federated-simulation service mode (SimService over one hot ScanEngine)
    ap.add_argument("--fedsim", action="store_true",
                    help="serve federated sweep cells instead of LM decode")
    ap.add_argument("--cells", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=24)
    ap.add_argument("--segment", type=int, default=8,
                    help="streaming segment length (0 = one segment)")
    ap.add_argument("--n-clients", type=int, default=16)
    ap.add_argument("--compile-cache-dir", default=None,
                    help="persistent XLA compile cache directory")
    args = ap.parse_args(argv)

    if args.fedsim:
        return _fedsim_main(args)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(args.seed)
    params = lm.init_params(key, cfg)

    rng = np.random.default_rng(args.seed)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32)}
    if cfg.family == "vlm" and cfg.n_image_tokens:
        batch["image_emb"] = jnp.asarray(
            rng.normal(0, 0.02, (args.batch, cfg.n_image_tokens, cfg.d_model)),
            jnp.dtype(cfg.dtype))
    if cfg.enc_dec:
        batch["audio_frames"] = jnp.asarray(
            rng.normal(0, 0.02, (args.batch, cfg.n_audio_frames, cfg.d_model)),
            jnp.dtype(cfg.dtype))

    # prefill builds a cache sized for prompt+gen
    total = args.prompt_len + (cfg.n_image_tokens if cfg.family == "vlm" else 0)
    max_len = total + args.gen

    prefill_j = jax.jit(lambda p, b: lm.prefill(p, cfg, b))
    decode_j = jax.jit(lambda p, t, c: lm.decode_step(p, cfg, t, c))

    t0 = time.time()
    logits, cache = prefill_j(params, batch)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    # grow the cache to max_len (prefill sized it to the prompt)
    def grow(x):
        if x.ndim == 5 and x.shape[2] == total:          # (L,B,S,H,D)
            pad = [(0, 0)] * 5
            pad[2] = (0, args.gen)
            return jnp.pad(x, pad)
        return x
    cache = {k: (grow(v) if k in ("k", "v") else v) for k, v in cache.items()}

    toks = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [np.asarray(toks)]
    t1 = time.time()
    for i in range(args.gen - 1):
        key, sub = jax.random.split(key)
        logits, cache = decode_j(params, toks, cache)
        if args.temperature > 0:
            toks = jax.random.categorical(sub, logits / args.temperature, -1).astype(jnp.int32)
        else:
            toks = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(np.asarray(toks))
    jax.block_until_ready(toks)
    t_decode = time.time() - t1

    gen = np.stack(out, 1)
    n_tok = gen.size
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} gen={args.gen}")
    print(f"prefill: {t_prefill:.3f}s  decode: {t_decode:.3f}s "
          f"({n_tok / max(t_decode, 1e-9):.1f} tok/s)")
    print("first sequence:", gen[0][:16].tolist())
    return gen


if __name__ == "__main__":
    main()

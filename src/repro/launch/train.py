"""Federated LM training entry point — the paper's Algorithm 1 driving the
assigned-architecture model zoo.

Each federated client owns a distinct Markov-chain token stream (the LM
analogue of label skew); FedGS builds the 3DG from client unigram statistics
(oracle) or functional similarity, samples clients under an availability
mode, clients run E local AdamW steps, and the server applies any
aggregator family (``--aggregator``: Eq. 18 FedAvg, server momentum,
FedAdam, proximal-weighted, or the memory-rectified reduction, with
``--agg-backend pallas`` routing the (N, P) panel through the fused
kernel).

  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --reduced \
      --rounds 20 --clients 16 --mode LN --sampler fedgs

``--reduced`` uses the 2-layer smoke variant (CPU-friendly); without it the
full config is built (requires a real accelerator mesh for the big archs).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import INPUT_SHAPES
from repro.configs.registry import get_config
from repro.core.availability import ProcessMode, make_mode
from repro.core.availability_device import ALL_SCENARIOS, make_process
from repro.core.sampler import make_sampler, FedGSSampler
from repro.core import graph as graph_mod
from repro.core.fairness import count_variance
from repro.data.lm_stream import token_batches
from repro.fed.aggregator_device import FAMILIES as AGGREGATORS
from repro.fed.aggregator_device import make_aggregator_process
from repro.fed.faults_device import FAMILIES as FAULTS
from repro.fed.faults_device import HostFaultInjector, make_fault_process
from repro.fed.server import ServerAggregator
from repro.launch.obs_cli import (
    add_observability_args, finish_observability, make_observability,
)
from repro.models import lm
from repro.optim.optimizers import adamw


def client_unigrams(tokens: np.ndarray, vocab: int) -> np.ndarray:
    """(N, n_seq, S+1) -> (N, vocab) normalized unigram histograms: the
    label-distribution analogue used as oracle 3DG features."""
    n = tokens.shape[0]
    out = np.zeros((n, vocab), np.float64)
    for k in range(n):
        out[k] = np.bincount(tokens[k].reshape(-1), minlength=vocab)
    return out / np.maximum(out.sum(1, keepdims=True), 1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--clients", type=int, default=16)
    ap.add_argument("--sample-frac", type=float, default=0.25)
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--mode", default="LN",
                    help="Table-1 availability mode (IDL/MDF/LDF/YMF/YC/LN/"
                         "SLN) or a stateful scenario family "
                         "(GE/CLUSTER/DRIFT/DEADLINE)")
    ap.add_argument("--sampler", default="fedgs")
    ap.add_argument("--alpha", type=float, default=1.0)
    ap.add_argument("--solver-backend", default="ref", choices=("ref", "pallas"),
                    help="FedGS Eq. 16 solve: pure-jnp ref or the tiled "
                         "Pallas kernels (large client counts)")
    ap.add_argument("--aggregator", default="fedavg", choices=AGGREGATORS,
                    help="server-update family (fed/aggregator_device.py): "
                         "Eq. 18 fedavg, server momentum, FedAdam, "
                         "proximal-weighted averaging, or the FedAR/MIFA-"
                         "style memory-rectified reduction")
    ap.add_argument("--agg-backend", default="ref", choices=("ref", "pallas"),
                    help="memory-family scatter+reduce: pure-jnp ref or "
                         "the fused Pallas panel kernel")
    ap.add_argument("--fault", default="none", choices=FAULTS,
                    help="Byzantine/straggler fault family injected between "
                         "local training and aggregation "
                         "(fed/faults_device.py); pair with a robust "
                         "--aggregator (median/trimmed_mean/krum)")
    ap.add_argument("--byzantine-frac", type=float, default=0.0,
                    help="fraction of clients made adversarial (ceil(frac*N) "
                         "by a seeded permutation; identity fixed per seed)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default=None,
                    help="checkpoint path: saves params+counts every 10 "
                         "rounds and resumes if present")
    add_observability_args(ap)
    args = ap.parse_args(argv)
    tracer, sink = make_observability(args, run=f"train-{args.arch}")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    n, m = args.clients, max(1, int(round(args.sample_frac * args.clients)))
    vocab = min(cfg.vocab_size, 512)

    # ---- per-client token pools + oracle 3DG ------------------------------
    pools = token_batches(vocab, n, tokens_per_client=args.batch * (args.seq + 1) * 8,
                          seq_len=args.seq, seed=args.seed)
    sizes = np.full(n, pools.shape[1], np.float64)
    feats = client_unigrams(pools, vocab)

    sampler = make_sampler(args.sampler, alpha=args.alpha,
                           solver_backend=args.solver_backend) \
        if args.sampler == "fedgs" else make_sampler(args.sampler)
    if isinstance(sampler, FedGSSampler):
        _, _, h = graph_mod.build_3dg(feats, eps=0.1, sigma2=0.01)
        sampler.set_graph(h)
    if args.mode.upper() in ALL_SCENARIOS:
        # stateful scenario families (GE / CLUSTER / DRIFT / DEADLINE) get
        # the same host face as the Table-1 modes via ProcessMode
        mode = ProcessMode(make_process(args.mode, n_clients=n,
                                        data_sizes=sizes, rounds=args.rounds,
                                        seed=args.seed),
                           avail_seed=args.seed + 1234)
    else:
        mode = make_mode(args.mode, n_clients=n, data_sizes=sizes,
                         label_sets=[set(np.argsort(-feats[k])[:3].tolist()) for k in range(n)],
                         num_labels=vocab)

    # ---- model + local trainer -------------------------------------------
    key = jax.random.PRNGKey(args.seed)
    params = lm.init_params(key, cfg)
    opt = adamw()

    @jax.jit
    def local_train(p, seqs, lr, key):
        """E local AdamW steps on one client's pool."""
        state = opt.init(p)

        def step(carry, k):
            p, s = carry
            idx = jax.random.randint(k, (args.batch,), 0, seqs.shape[0])
            b = {"tokens": seqs[idx][:, :-1], "labels": seqs[idx][:, 1:]}
            loss, g = jax.value_and_grad(
                lambda q: lm.train_loss(q, cfg, b, remat=False))(p)
            p, s = opt.update(g, s, p, lr)
            return (p, s), loss

        (p, _), losses = jax.lax.scan(step, (p, state),
                                      jax.random.split(key, args.local_steps))
        return p, losses.mean()

    @jax.jit
    def eval_loss(p, seqs):
        b = {"tokens": seqs[:, :-1], "labels": seqs[:, 1:]}
        return lm.train_loss(p, cfg, b, remat=False)

    val = jnp.asarray(pools[:, -1])        # one held-out sequence per client
    pools_j = jnp.asarray(pools[:, :-1])

    rng = np.random.default_rng(args.seed)
    avail_rng = np.random.default_rng(args.seed + 1234)
    counts = np.zeros(n)
    server = ServerAggregator(make_aggregator_process(args.aggregator),
                              n_clients=n, data_sizes=sizes,
                              backend=args.agg_backend, seed=args.seed)
    start = 0
    if args.ckpt:
        import os
        from repro.checkpoint.ckpt import load_checkpoint, save_checkpoint
        p = args.ckpt if args.ckpt.endswith(".npz") else args.ckpt + ".npz"
        if os.path.exists(p):
            state = load_checkpoint(args.ckpt, like={"params": params,
                                                     "counts": counts,
                                                     "round": np.zeros((), np.int64)})
            params = jax.tree_util.tree_map(jnp.asarray, state["params"])
            counts = np.asarray(state["counts"], np.float64)
            start = int(state["round"]) + 1
            print(f"resumed from {p} at round {start}")
    server.init(params)
    faults = None
    if args.fault != "none":
        faults = HostFaultInjector(
            make_fault_process(args.fault, n, frac=args.byzantine_frac),
            fault_seed=args.seed + 0xFA17)
        faults.init(params)
    t0 = time.time()
    try:
        for t in range(start, args.rounds):
            avail = mode.sample(t, avail_rng)
            sel = np.asarray(sampler.sample(avail=avail, m=m, rng=rng,
                                            counts=counts,
                                            data_sizes=sizes), int)
            if len(sel) == 0:
                # empty A_t (samplers return the empty array, PR-4): the
                # round is a params no-op — the zero-weight-guard story
                # end to end
                print(f"round {t:3d}  sel=[]  (no clients available; "
                      f"params kept)", flush=True)
                continue
            locals_, losses = [], []
            with tracer.span("local_train", t=t, m=len(sel)):
                for k in sel:
                    key, sub = jax.random.split(key)
                    pk, lk = local_train(params, pools_j[k],
                                         jnp.float32(args.lr), sub)
                    locals_.append(pk)
                    losses.append(float(lk))
            stacked = jax.tree_util.tree_map(lambda *x: jnp.stack(x),
                                             *locals_)
            if faults is not None:
                stacked = faults.inject(stacked, params, sel, avail, t)
            with tracer.span("aggregate", t=t):
                params = server.apply(stacked, sizes[sel].astype(np.float32),
                                      sel, avail, t)
            counts[sel] += 1
            with tracer.span("eval", t=t):
                vl = float(eval_loss(params, val))
            if sink is not None:
                sink.emit("round", {"engine": "train-lm", "t": t,
                                    "val_loss": vl,
                                    "train_loss": float(np.mean(losses)),
                                    "n_selected": int(len(sel)),
                                    "avail_rate": float(np.mean(avail)),
                                    "count_var":
                                    float(count_variance(counts))})
            print(f"round {t:3d}  sel={sel.tolist()}  "
                  f"train={np.mean(losses):.4f}  "
                  f"val={vl:.4f}  Var(v)={count_variance(counts):.3f}",
                  flush=True)
            if args.ckpt and (t + 1) % 10 == 0:
                from repro.checkpoint.ckpt import save_checkpoint
                with tracer.span("checkpoint_write", round=t):
                    save_checkpoint(
                        args.ckpt, {"params": params, "counts": counts,
                                    "round": np.asarray(t, np.int64)},
                        metadata={"round": t, "arch": cfg.name})
    finally:
        trace = finish_observability(tracer, sink, args)
        if trace:
            print(f"trace: {trace}")
    print(f"done in {time.time() - t0:.1f}s; final Var(v^t)={count_variance(counts):.3f}")
    return params, counts


if __name__ == "__main__":
    main()

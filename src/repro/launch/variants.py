"""Perf-iteration variants for the §Perf hillclimb.

A variant is a named, reversible patch of framework knobs (attention path
thresholds, loss chunking, remat policy, cache layout) applied around a
dry-run lowering.  The baseline is the paper-faithful/default configuration;
each variant is one hypothesis from EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import contextlib
from typing import Callable

import jax

from repro.models import attention as attn_mod
from repro.models import lm as lm_mod

# name -> (setup() -> undo_state, teardown(undo_state))
_REGISTRY: dict[str, tuple[Callable, Callable]] = {}


def _register(name):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


@_register("baseline")
def _baseline():
    yield


@_register("dense_max_2k")
def _dense_max_2k():
    """Force the chunked (flash-pattern) attention path at train_4k —
    hypothesis: removes the (B,H,S,S) f32 score buffer from temp memory."""
    old = attn_mod.DENSE_MAX
    attn_mod.DENSE_MAX = 2048
    try:
        yield
    finally:
        attn_mod.DENSE_MAX = old


@_register("loss_chunk_128")
def _loss_chunk_128():
    """Smaller LM-head loss chunks — hypothesis: shrinks the transient
    (B,chunk,V) logits tile (memory term) at the cost of more head matmuls."""
    old = lm_mod.LOSS_CHUNK
    lm_mod.LOSS_CHUNK = 128
    try:
        yield
    finally:
        lm_mod.LOSS_CHUNK = old


@_register("loss_chunk_1k")
def _loss_chunk_1k():
    old = lm_mod.LOSS_CHUNK
    lm_mod.LOSS_CHUNK = 1024
    try:
        yield
    finally:
        lm_mod.LOSS_CHUNK = old


@_register("kv_chunk_2k")
def _kv_chunk_2k():
    """Larger KV chunks in the online-softmax path — hypothesis: fewer scan
    steps / larger matmuls lower the memory term for prefill_32k."""
    old = attn_mod.KV_CHUNK
    attn_mod.KV_CHUNK = 2048
    try:
        yield
    finally:
        attn_mod.KV_CHUNK = old


@_register("no_remat")
def _no_remat():
    """Disable layer remat — hypothesis: compute term drops (no recompute) at
    the cost of the memory term; viable only for the small archs."""
    old = lm_mod.train_loss

    def patched(params, cfg, batch, **kw):
        kw["remat"] = False
        return old(params, cfg, batch, **kw)

    lm_mod.train_loss = patched
    try:
        yield
    finally:
        lm_mod.train_loss = old


@_register("minremat")
def _minremat():
    """Save-nothing remat — hypothesis: kills the scan-stacked saved-dot
    buffers (the big temp term) for ~+33% compute."""
    old = lm_mod.REMAT_POLICY
    lm_mod.REMAT_POLICY = "nothing"
    try:
        yield
    finally:
        lm_mod.REMAT_POLICY = old


def _micro(n):
    from repro.launch import steps as steps_mod
    old = steps_mod.MICROBATCHES
    steps_mod.MICROBATCHES = n
    try:
        yield
    finally:
        steps_mod.MICROBATCHES = old


@_register("micro8")
def _micro8():
    yield from _micro(8)


@_register("micro8_minremat")
def _micro8_minremat():
    old = lm_mod.REMAT_POLICY
    lm_mod.REMAT_POLICY = "nothing"
    try:
        yield from _micro(8)
    finally:
        lm_mod.REMAT_POLICY = old


@_register("micro16_minremat")
def _micro16_minremat():
    old = lm_mod.REMAT_POLICY
    lm_mod.REMAT_POLICY = "nothing"
    try:
        yield from _micro(16)
    finally:
        lm_mod.REMAT_POLICY = old


@_register("ring_cache")
def _ring_cache():
    """Window-sized ring-buffer KV cache for sliding-window decode —
    hypothesis: removes the seq-sharded-cache gather (the collective term)
    from long_500k entirely."""
    old = lm_mod.RING_CACHE
    lm_mod.RING_CACHE = True
    try:
        yield
    finally:
        lm_mod.RING_CACHE = old


@_register("chunked_attn")
def _chunked_attn():
    """Alias of dense_max_2k with the canonical name used in EXPERIMENTS."""
    old = attn_mod.DENSE_MAX
    attn_mod.DENSE_MAX = 2048
    try:
        yield
    finally:
        attn_mod.DENSE_MAX = old


@_register("chunked_attn_minremat")
def _chunked_attn_minremat():
    old_d = attn_mod.DENSE_MAX
    old_p = lm_mod.REMAT_POLICY
    attn_mod.DENSE_MAX = 2048
    lm_mod.REMAT_POLICY = "nothing"
    try:
        yield
    finally:
        attn_mod.DENSE_MAX = old_d
        lm_mod.REMAT_POLICY = old_p


@_register("micro8_chunked_minremat")
def _micro8_chunked_minremat():
    old_d = attn_mod.DENSE_MAX
    old_p = lm_mod.REMAT_POLICY
    attn_mod.DENSE_MAX = 2048
    lm_mod.REMAT_POLICY = "nothing"
    try:
        yield from _micro(8)
    finally:
        attn_mod.DENSE_MAX = old_d
        lm_mod.REMAT_POLICY = old_p


@_register("tp_only_weights")
def _tp_only_weights():
    """Replicate weights over the data axis (TP-only sharding) — hypothesis:
    decode stops all-gathering the FSDP-sharded weights every token, trading
    per-chip weight memory for the collective term."""
    from repro.sharding import rules as rules_mod
    old = rules_mod.FSDP_ENABLED
    rules_mod.FSDP_ENABLED = False
    try:
        yield
    finally:
        rules_mod.FSDP_ENABLED = old


@_register("tp_only_ring")
def _tp_only_ring():
    from repro.sharding import rules as rules_mod
    old_f = rules_mod.FSDP_ENABLED
    old_r = lm_mod.RING_CACHE
    rules_mod.FSDP_ENABLED = False
    lm_mod.RING_CACHE = True
    try:
        yield
    finally:
        rules_mod.FSDP_ENABLED = old_f
        lm_mod.RING_CACHE = old_r


@_register("bf16_scores")
def _bf16_scores():
    """bf16 (B,H,S,S) score/prob buffers in the dense attention path —
    hypothesis: halves the dominant S^2 HBM traffic of small-d archs."""
    old = attn_mod.SCORE_DTYPE
    attn_mod.SCORE_DTYPE = "bfloat16"
    try:
        yield
    finally:
        attn_mod.SCORE_DTYPE = old


def _set_many(micro=None, group=None, grad_dt=None, policy=None):
    from repro.launch import steps as steps_mod
    olds = (steps_mod.MICROBATCHES, lm_mod.REMAT_GROUP,
            steps_mod.GRAD_ACC_DTYPE, lm_mod.REMAT_POLICY)
    if micro is not None:
        steps_mod.MICROBATCHES = micro
    if group is not None:
        lm_mod.REMAT_GROUP = group
    if grad_dt is not None:
        steps_mod.GRAD_ACC_DTYPE = grad_dt
    if policy is not None:
        lm_mod.REMAT_POLICY = policy
    try:
        yield
    finally:
        (steps_mod.MICROBATCHES, lm_mod.REMAT_GROUP,
         steps_mod.GRAD_ACC_DTYPE, lm_mod.REMAT_POLICY) = olds


@_register("remat2_micro16")
def _remat2_micro16():
    """2-level remat (groups of 8 layers) + 16 microbatches — hypothesis:
    saved carries drop from L to L/G + G per microbatch, pushing the 340B
    train step's temp under HBM."""
    yield from _set_many(micro=16, group=8)


@_register("remat2_micro16_gradbf16")
def _remat2_micro16_gradbf16():
    yield from _set_many(micro=16, group=8, grad_dt="bfloat16")


@_register("remat2_micro8")
def _remat2_micro8():
    yield from _set_many(micro=8, group=8)


@_register("headaware")
def _headaware():
    """No-op alias: head-aware TP is the (post-fix) default; this name tags
    dry-run records produced after the fix, next to the legacy baselines."""
    yield


@_register("legacy_tp")
def _legacy_tp():
    """Pre-fix TP rules (head-unaware): shards attn projections whenever the
    flat dim divides, forcing attention-path regathers when the head count
    does not — kept to reproduce the recorded baseline."""
    from repro.sharding import rules as rules_mod
    old = rules_mod.HEAD_AWARE_TP
    rules_mod.HEAD_AWARE_TP = False
    try:
        yield
    finally:
        rules_mod.HEAD_AWARE_TP = old


@_register("padded_heads")
def _padded_heads():
    """Pad attention heads to the 16-way TP width (exact weight embedding,
    configs.base.pad_heads) — hypothesis: attention shards 16-way instead of
    replicating, cutting per-device attention compute/memory by ~16/flop-pad
    while keeping collectives head-aligned (no cache regathers)."""
    from repro.launch import specs as specs_mod
    old = specs_mod.PAD_HEADS_MULTIPLE
    specs_mod.PAD_HEADS_MULTIPLE = 16
    try:
        yield
    finally:
        specs_mod.PAD_HEADS_MULTIPLE = old


@_register("moe_grouped")
def _moe_grouped():
    """Group-local MoE dispatch (one group per dp shard) — hypothesis: the
    global scatter into the (E,C,d) buffer lowers to partial-buffer +
    all-reduce (3.9 TB/step on olmoe train_4k); per-shard dispatch keeps the
    scatter local and leaves only the expert-parallel collectives."""
    from repro.models import ffn as ffn_mod
    old = ffn_mod.MOE_GROUPS
    ffn_mod.MOE_GROUPS = -1            # auto: one group per data shard
    try:
        yield
    finally:
        ffn_mod.MOE_GROUPS = old


@_register("fsdp_over_pod")
def _fsdp_over_pod():
    """Shard weights/opt over (pod, data) = 32-way instead of data-only —
    hypothesis: halves the 340B per-chip state at the cost of cross-pod
    weight-gather traffic (only meaningful on the multi-pod mesh)."""
    from repro.launch import mesh as mesh_mod
    old = mesh_mod.FSDP_OVER_POD
    mesh_mod.FSDP_OVER_POD = True
    try:
        yield
    finally:
        mesh_mod.FSDP_OVER_POD = old


@_register("ring_padded")
def _ring_padded():
    """ring_cache + padded_heads stacked — the two winning long_500k levers."""
    from repro.launch import specs as specs_mod
    old_r, old_p = lm_mod.RING_CACHE, specs_mod.PAD_HEADS_MULTIPLE
    lm_mod.RING_CACHE = True
    specs_mod.PAD_HEADS_MULTIPLE = 16
    try:
        yield
    finally:
        lm_mod.RING_CACHE = old_r
        specs_mod.PAD_HEADS_MULTIPLE = old_p


VARIANTS = dict(_REGISTRY)


@contextlib.contextmanager
def apply_variant(name: str):
    gen = _REGISTRY[name]()
    next(gen)
    try:
        yield
    finally:
        try:
            next(gen)
        except StopIteration:
            pass

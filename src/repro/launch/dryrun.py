"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) and record
memory / cost / collective analyses (EXPERIMENTS.md §Dry-run, §Roofline).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all                 # 40 pairs, single-pod
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod     # 40 pairs, 2 pods
  ... --variant <name>   # perf-iteration variants (see repro.launch.variants)

Results are cached incrementally in benchmarks/results/dryrun/*.json.
"""
# The next two lines MUST run before any other import — jax locks the device
# count on first init, and the production mesh needs 512 host devices.
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import INPUT_SHAPES
from repro.configs.registry import get_config, list_archs
from repro.launch.mesh import make_production_mesh, make_shard_ctx
from repro.launch.specs import (abstract_params, input_specs, variant_for_shape)
from repro.launch.steps import (make_train_step, make_prefill_step,
                                make_serve_step, make_shardings)
from repro.launch.variants import apply_variant, VARIANTS
from repro.sharding.ctx import use_sharding
from repro.utils.hlo import analyze as hlo_analyze

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"

# TPU v5e roofline constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link


def _mem_dict(compiled):
    try:
        m = compiled.memory_analysis()
    except Exception:
        return {}
    if m is None:
        return {}
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes", "peak_memory_in_bytes")
    return {k: int(getattr(m, k)) for k in keys if hasattr(m, k)}


def _model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N_active·D tokens (training) / 2·N_active·D (inference)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch        # decode: one token per sequence


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            variant: str = "baseline", force: bool = False,
            results_dir: pathlib.Path = RESULTS_DIR) -> dict:
    mesh_tag = "pod2" if multi_pod else "pod1"
    key = f"{arch}__{shape_name}__{mesh_tag}"
    if variant != "baseline":
        key += f"__{variant}"
    results_dir.mkdir(parents=True, exist_ok=True)
    out_path = results_dir / f"{key}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    shape = INPUT_SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
           "variant": variant, "kind": shape.kind, "ok": False}
    t0 = time.time()
    try:
        with apply_variant(variant):
            # cfg derivation inside the variant scope: some variants transform
            # the config itself (e.g. padded_heads)
            cfg = variant_for_shape(get_config(arch), shape)
            rec.update(params=cfg.param_count(),
                       active_params=cfg.active_param_count(),
                       model_flops=_model_flops(cfg, shape))
            mesh = make_production_mesh(multi_pod=multi_pod)
            ctx = make_shard_ctx(mesh)
            params_abs = abstract_params(cfg)
            specs = input_specs(cfg, shape)

            with use_sharding(ctx):
                if shape.kind == "train":
                    step, optimizer = make_train_step(cfg)
                    opt_abs = jax.eval_shape(optimizer.init, params_abs)
                    sh = make_shardings(cfg, shape, ctx, params_abs,
                                        batch_abs=specs["batch"])
                    jitted = jax.jit(step,
                                     in_shardings=(sh["params"], sh["opt"],
                                                   sh["batch"], None),
                                     out_shardings=(sh["params"], sh["opt"], None),
                                     donate_argnums=(0, 1))
                    lowered = jitted.lower(
                        params_abs, opt_abs, specs["batch"],
                        jax.ShapeDtypeStruct((), jnp.float32))
                elif shape.kind == "prefill":
                    step = make_prefill_step(cfg)
                    sh = make_shardings(cfg, shape, ctx, params_abs,
                                        batch_abs=specs["batch"])
                    jitted = jax.jit(step, in_shardings=(sh["params"], sh["batch"]))
                    lowered = jitted.lower(params_abs, specs["batch"])
                else:  # decode
                    step = make_serve_step(cfg)
                    cache_abs = specs["cache"]
                    sh = make_shardings(cfg, shape, ctx, params_abs,
                                        cache_abs=cache_abs)
                    tok_sharding = None
                    jitted = jax.jit(step,
                                     in_shardings=(sh["params"], tok_sharding,
                                                   sh["cache"]),
                                     out_shardings=(None, sh["cache"]),
                                     donate_argnums=(2,))
                    lowered = jitted.lower(params_abs, specs["tokens"], cache_abs)

                rec["lower_s"] = round(time.time() - t0, 2)
                t1 = time.time()
                compiled = lowered.compile()
                rec["compile_s"] = round(time.time() - t1, 2)

        # the brief's required artifacts: memory_analysis proves the program
        # fits; cost_analysis feeds §Roofline (printed compactly, full record
        # goes to JSON)
        print(f"[dryrun] {key} memory_analysis: {_mem_dict(compiled)}")
        # raw XLA numbers (while bodies counted ONCE — kept for reference)
        cost = compiled.cost_analysis() or {}
        print(f"[dryrun] {key} cost_analysis: flops={cost.get('flops', 0):.4g} "
              f"bytes={cost.get('bytes accessed', 0):.4g} (raw; loop-aware "
              f"numbers in the record)")
        rec["xla_flops_raw"] = float(cost.get("flops", 0.0))
        rec["xla_bytes_raw"] = float(cost.get("bytes accessed", 0.0))
        rec["mem"] = _mem_dict(compiled)
        # loop-trip-aware walk of the compiled HLO (utils/hlo.py)
        hc = hlo_analyze(compiled.as_text())
        rec["flops_per_device"] = float(hc.flops)
        rec["bytes_per_device"] = float(hc.bytes)
        rec["collectives"] = hc.collectives
        rec["collective_bytes_per_device"] = int(hc.collective_bytes)

        # roofline terms (seconds); SPMD module stats are per-device, so
        # flops_pd/peak == HLO_FLOPs_global/(chips*peak)
        rec["compute_term_s"] = rec["flops_per_device"] / PEAK_FLOPS
        rec["memory_term_s"] = rec["bytes_per_device"] / HBM_BW
        rec["collective_term_s"] = rec["collective_bytes_per_device"] / ICI_BW
        terms = {"compute": rec["compute_term_s"], "memory": rec["memory_term_s"],
                 "collective": rec["collective_term_s"]}
        rec["dominant"] = max(terms, key=terms.get)
        chips = int(np.prod(mesh.devices.shape))
        rec["chips"] = chips
        rec["useful_flop_ratio"] = (rec["model_flops"] /
                                    max(rec["flops_per_device"] * chips, 1.0))
        rec["ok"] = True
    except Exception as e:  # record the failure for triage, don't hide it
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = round(time.time() - t0, 2)
    out_path.write_text(json.dumps(rec, indent=1))
    status = "ok" if rec["ok"] else f"FAIL ({rec.get('error', '?')[:120]})"
    print(f"[dryrun] {key}: {status}  ({rec['total_s']}s)", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=[*INPUT_SHAPES, None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", default="baseline", choices=sorted(VARIANTS))
    args = ap.parse_args()

    if args.all:
        combos = [(a, s) for a in list_archs() for s in INPUT_SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        combos = [(args.arch, args.shape)]

    n_fail = 0
    for arch, shape in combos:
        rec = run_one(arch, shape, multi_pod=args.multi_pod,
                      variant=args.variant, force=args.force)
        n_fail += 0 if rec["ok"] else 1
    print(f"[dryrun] done; {len(combos) - n_fail}/{len(combos)} ok")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()

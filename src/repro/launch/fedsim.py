"""Dry-run of the FEDERATED ROUND ITSELF on the production mesh.

The arch × shape dry-runs prove the model zoo lowers; this proves the
*paper's own program* — one FedGS communication round over thousands of
clients — lowers and compiles multi-pod:

  round_step(global_params, client_data, sel_weights, lr)
    -> vmap'd E-step local SGD over M sampled clients (clients sharded over
       the dp axes = the federated-silo axis, DESIGN.md §3)
    -> Eq. 18 weighted aggregation (an all-reduce over the client shards)

plus the server-side 3DG pipeline at datacenter client counts (similarity +
Floyd–Warshall + the QUBO solve for N clients), lowered as one jit program.

  PYTHONPATH=src python -m repro.launch.fedsim [--clients 4096] [--multi-pod]

Results: benchmarks/results/dryrun/fedsim__*.json (same record schema).
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.launch.dryrun import RESULTS_DIR, PEAK_FLOPS, HBM_BW, ICI_BW, _mem_dict
from repro.launch.mesh import make_production_mesh
from repro.utils.hlo import analyze as hlo_analyze

DIM, CLASSES = 60, 10          # the paper's Synthetic(0.5, 0.5) model


def round_step_factory(local_steps: int, batch: int):
    """One federated round: vmap'd local logreg training + Eq. 18 aggregate."""

    def local(global_params, x, y, n_k, lr, key):
        def loss(p, xb, yb):
            logits = xb @ p["w"] + p["b"]
            logp = jax.nn.log_softmax(logits)
            return -jnp.mean(jnp.take_along_axis(logp, yb[:, None], 1))

        def step(p, k):
            idx = jax.random.randint(k, (batch,), 0, jnp.maximum(n_k, 1))
            g = jax.grad(loss)(p, x[idx], y[idx])
            return jax.tree_util.tree_map(lambda a, b: a - lr * b, p, g), None

        p, _ = jax.lax.scan(step, global_params,
                            jax.random.split(key, local_steps))
        return p

    def round_step(global_params, xs, ys, sizes, lr, keys):
        from repro.fed.aggregator_device import fedavg_combine
        locals_ = jax.vmap(local, in_axes=(None, 0, 0, 0, None, 0))(
            global_params, xs, ys, sizes, lr, keys)
        # the shared Eq. 18 combine (zero-weight guard = params kept)
        return fedavg_combine(locals_, sizes.astype(jnp.float32),
                              global_params)

    return round_step


def graph_pipeline(feats, counts, avail, alpha, m_sel, max_sweeps: int = 32,
                   *, eps: float = 0.1, sigma2: float = 0.01,
                   backend: str = "ref", solver_backend: str = "ref"):
    """Server-side FedGS pipeline as ONE jit program: V -> R -> H -> solve.

    Pure composition of the shared device-native 3DG stages
    (``core.graph_device``) with the shared Q-construction + solver
    (``core.sampler_device.fedgs_select``) — NaN-safe by construction.
    ``backend`` routes the graph build, ``solver_backend`` the Eq. 16
    solve (fused Q build + tiled greedy/swap kernels at datacenter N).
    """
    from repro.core.graph_device import GraphConfig, build_h
    from repro.core.sampler_device import fedgs_select
    h = build_h(feats, GraphConfig(eps=eps, sigma2=sigma2), backend=backend)
    return fedgs_select(h, counts, avail, jnp.float32(alpha),
                        m=m_sel, max_sweeps=max_sweeps,
                        backend=solver_backend)


def aggregator_program(aggregator: str, n_clients: int, m_sel: int, *,
                       backend: str = "ref"):
    """The server-update apply as ONE jit-lowerable program at datacenter
    client counts: any ``fed.aggregator_device`` family over the logreg
    params (for ``memory``, the (N, P) panel scatter + rectified reduction
    with ``backend`` routing the fused Pallas kernel).  Returns the jitted
    fn and its abstract (state, upd, w, s, avail, t) argument specs."""
    from repro.fed.aggregator_device import (
        init_agg_state, make_aggregator_process, make_aggregator_step,
    )
    f32, b8 = jnp.float32, jnp.bool_
    gp = {"w": jax.ShapeDtypeStruct((DIM, CLASSES), f32),
          "b": jax.ShapeDtypeStruct((CLASSES,), f32)}
    proc = make_aggregator_process(aggregator)
    # lower the named family's branch with the state it actually reads —
    # non-memory families carry a 0-row panel spec, so the recorded
    # argument/memory stats are the family's own, not the union's
    step = make_aggregator_step(n_clients, m_sel, gp, backend=backend,
                                family=proc.family)
    aparams = proc.params()
    key = jax.random.PRNGKey(0)

    def apply(state, upd, wts, s, avail, t):
        return step(aparams, state, key, upd, wts, s, avail, t)

    rows = n_clients if proc.family == "memory" else 0
    state = jax.eval_shape(
        lambda p: init_agg_state(p, n_clients, memory_rows=rows), gp)
    args = (state,
            {"w": jax.ShapeDtypeStruct((m_sel, DIM, CLASSES), f32),
             "b": jax.ShapeDtypeStruct((m_sel, CLASSES), f32)},
            jax.ShapeDtypeStruct((m_sel,), f32),
            jax.ShapeDtypeStruct((n_clients,), b8),
            jax.ShapeDtypeStruct((n_clients,), b8),
            jax.ShapeDtypeStruct((), jnp.int32))
    return jax.jit(apply), args


def sweep_program(mesh_shape: tuple, *, n_clients: int = 32, rounds: int = 8,
                  aggregator: str = "memory"):
    """Lower the shard_map'd sweep engine (``fed.scan_engine.run_batch``
    under ``ScanConfig.mesh``, DESIGN.md §13) at dry-run scale: one cell
    per "cells"-axis shard, the silo axis row-sharding local training (and
    the memory panel via ``silo_reduce="psum"`` when it divides N).
    Returns the lowered-and-compiled program plus its HLO stats."""
    from repro.core.availability_device import make_process
    from repro.data.synthetic import make_synthetic
    from repro.fed.models import logistic_regression
    from repro.fed.scan_engine import ScanConfig, ScanEngine

    ds = make_synthetic(n_clients=n_clients, alpha=0.5, beta=0.5, seed=0)
    silo = mesh_shape[1] if len(mesh_shape) > 1 else 1
    cfg = ScanConfig(rounds=rounds, m=4, local_steps=2, batch_size=8,
                     sampler="uniform", aggregator=aggregator,
                     mesh=tuple(mesh_shape),
                     silo_reduce="psum" if silo > 1 and n_clients % silo == 0
                     else "gather")
    eng = ScanEngine(ds, logistic_regression(dim=ds.x.shape[-1]), cfg)
    cells = [eng.cell(seed=s, process=make_process(
        "GE", n_clients=n_clients, data_sizes=ds.sizes, rounds=rounds))
        for s in range(mesh_shape[0])]
    compiled = eng.lower_batch(cells).compile()
    return compiled, hlo_analyze(compiled.as_text())


def datacenter_cell_dryrun(n_clients: int = 100_000, mesh: tuple = (1, 8), *,
                           rounds: int = 2, m: int = 32,
                           aggregator: str = "memory",
                           samples_per_client: int = 4, dim: int = 8,
                           classes: int = 4):
    """Compile-only proof of the silo axis at datacenter N (the ROADMAP
    leftover from PR 6): lower ONE N=10^5 sweep cell on a (cells, silo)
    mesh with the psum-sharded memory panel — HLO only, never executed.
    The (N, N) graph H alone would be 40 GB at N=10^5, so the cell's H is
    a ``jax.ShapeDtypeStruct`` and the lowering runs fully abstract
    (``ScanEngine.lower_batch(abstract=True)``).

    Returns ``(lowered, carry_shapes)``: the jax ``Lowered`` program (call
    ``.as_text()`` for the HLO the CI dry-run step pins) and the abstract
    scan-carry pytree, whose memory-panel leaf must show (N / silo, P)
    rows — a carry-size regression (e.g. the panel silently going global
    again) surfaces as a shape change here."""
    from repro.core.availability_device import make_process
    from repro.data.fed_dataset import FedDataset
    from repro.fed.models import logistic_regression
    from repro.fed.scan_engine import ScanConfig, ScanEngine

    silo = mesh[1] if len(mesh) > 1 else 1
    if n_clients % max(silo, 1):
        raise ValueError(f"N={n_clients} must divide by silo={silo}")
    # tiny per-client payload — client COUNT is the thing under test
    s = samples_per_client
    ds = FedDataset(
        x=np.zeros((n_clients, s, dim), np.float32),
        y=np.zeros((n_clients, s), np.int32),
        sizes=np.full((n_clients,), s, np.int64),
        x_val=np.zeros((8, dim), np.float32),
        y_val=np.zeros((8,), np.int32),
        num_classes=classes,
        label_dist=np.zeros((n_clients, classes)))
    cfg = ScanConfig(rounds=rounds, m=m, local_steps=1, batch_size=2,
                     sampler="uniform", aggregator=aggregator,
                     mesh=tuple(mesh), silo_reduce="psum")
    eng = ScanEngine(ds, logistic_regression(dim=dim, classes=classes), cfg)
    cells = [eng.cell(
        seed=0,
        process=make_process("GE", n_clients=n_clients, data_sizes=ds.sizes,
                             rounds=rounds),
        h=jax.ShapeDtypeStruct((n_clients, n_clients), jnp.float32))
        for _ in range(mesh[0])]
    lowered = eng.lower_batch(cells, abstract=True)
    return lowered, eng.carry_shapes(cells)


def run(n_clients: int, *, multi_pod: bool, sample_frac: float = 0.1,
        n_max: int = 512, local_steps: int = 10, batch: int = 10,
        force: bool = False, solver_backend: str = "ref",
        aggregator: str = "fedavg", agg_backend: str = "ref",
        sweep_mesh: tuple | None = None, tracer=None,
        sink=None) -> dict:
    from repro.fed.telemetry import NULL_TRACER
    tracer = tracer if tracer is not None else NULL_TRACER
    mesh_tag = "pod2" if multi_pod else "pod1"
    key = f"fedsim__c{n_clients}__{mesh_tag}"
    if sweep_mesh:
        key += f"__sweep{'x'.join(str(s) for s in sweep_mesh)}"
    if solver_backend != "ref":
        key += f"__{solver_backend}"
    if aggregator != "fedavg":
        key += f"__{aggregator}"
        if agg_backend != "ref":
            key += f"__{agg_backend}"
    out_path = RESULTS_DIR / f"{key}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())
    rec = {"arch": f"fedsim-c{n_clients}", "shape": "fl_round",
           "mesh": mesh_tag, "variant": "baseline", "kind": "fl_round",
           "ok": False}
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        from jax.sharding import NamedSharding, PartitionSpec as P
        dp = ("pod", "data") if multi_pod else ("data",)
        client_sh = NamedSharding(mesh, P(dp))
        repl = NamedSharding(mesh, P())
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        dp_total = int(np.prod([sizes[a] for a in dp]))
        # pad the sampled-client count to the dp width (production pads the
        # cohort with zero-weight clients)
        m_sel = max(dp_total, int(round(sample_frac * n_clients)))
        m_sel = ((m_sel + dp_total - 1) // dp_total) * dp_total

        # ---- the round program: M sampled clients sharded over dp --------
        step = round_step_factory(local_steps, batch)
        gp = {"w": jax.ShapeDtypeStruct((DIM, CLASSES), jnp.float32),
              "b": jax.ShapeDtypeStruct((CLASSES,), jnp.float32)}
        args = (gp,
                jax.ShapeDtypeStruct((m_sel, n_max, DIM), jnp.float32),
                jax.ShapeDtypeStruct((m_sel, n_max), jnp.int32),
                jax.ShapeDtypeStruct((m_sel,), jnp.int32),
                jax.ShapeDtypeStruct((), jnp.float32),
                jax.ShapeDtypeStruct((m_sel, 2), jnp.uint32))
        jitted = jax.jit(step, in_shardings=(
            jax.tree_util.tree_map(lambda _: repl, gp),
            client_sh, client_sh, client_sh, None, client_sh),
            out_shardings=jax.tree_util.tree_map(lambda _: repl, gp))
        with tracer.span("lower", stage="round"):
            lowered = jitted.lower(*args)
        with tracer.span("compile", stage="round"):
            compiled = lowered.compile()
        hc = hlo_analyze(compiled.as_text())
        rec["round"] = {
            "m_sampled": m_sel,
            "flops_per_device": hc.flops, "bytes_per_device": hc.bytes,
            "collective_bytes_per_device": hc.collective_bytes,
            "mem": _mem_dict(compiled),
        }

        # ---- the server-side FedGS pipeline (N x N graph + solve) --------
        gargs = (jax.ShapeDtypeStruct((n_clients, CLASSES), jnp.float32),
                 jax.ShapeDtypeStruct((n_clients,), jnp.float32),
                 jax.ShapeDtypeStruct((n_clients,), jnp.bool_))
        gj = jax.jit(lambda f, c, a: graph_pipeline(
            f, c, a, 1.0, m_sel, solver_backend=solver_backend),
            in_shardings=(None, None, None))
        with mesh:
            with tracer.span("lower", stage="server_pipeline"):
                glow = gj.lower(*gargs)
            with tracer.span("compile", stage="server_pipeline"):
                gcomp = glow.compile()
        ghc = hlo_analyze(gcomp.as_text())
        rec["server_pipeline"] = {
            "n_clients": n_clients,
            "flops": ghc.flops, "bytes": ghc.bytes,
            "mem": _mem_dict(gcomp),
        }

        # ---- the server-update (aggregator) program ----------------------
        aj, aargs = aggregator_program(aggregator, n_clients, m_sel,
                                       backend=agg_backend)
        with tracer.span("compile", stage="aggregator"):
            acomp = aj.lower(*aargs).compile()
        ahc = hlo_analyze(acomp.as_text())
        rec["aggregator"] = {
            "family": aggregator, "backend": agg_backend,
            "n_clients": n_clients, "m_sampled": m_sel,
            "flops": ahc.flops, "bytes": ahc.bytes,
            "mem": _mem_dict(acomp),
        }
        # ---- the shard_map'd sweep engine on the ("cells","silo") mesh ---
        if sweep_mesh:
            scomp, shc = sweep_program(sweep_mesh)
            rec["sweep_engine"] = {
                "mesh": list(sweep_mesh),
                "flops_per_device": shc.flops, "bytes_per_device": shc.bytes,
                "collective_bytes_per_device": shc.collective_bytes,
                "mem": _mem_dict(scomp),
            }
        # roofline terms for the round program
        rec["compute_term_s"] = hc.flops / PEAK_FLOPS
        rec["memory_term_s"] = hc.bytes / HBM_BW
        rec["collective_term_s"] = hc.collective_bytes / ICI_BW
        terms = {"compute": rec["compute_term_s"],
                 "memory": rec["memory_term_s"],
                 "collective": rec["collective_term_s"]}
        rec["dominant"] = max(terms, key=terms.get)
        rec["ok"] = True
    except Exception as e:
        import traceback
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = round(time.time() - t0, 2)
    if sink is not None:
        sink.emit("dryrun", {"key": key, "ok": rec["ok"],
                             "total_s": rec["total_s"],
                             "spans": tracer.summary()})
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(rec, indent=1))
    print(f"[fedsim] {key}: {'ok' if rec['ok'] else 'FAIL ' + rec.get('error', '')[:120]} "
          f"({rec['total_s']}s)", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=4096)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--solver-backend", default="ref",
                    choices=("ref", "pallas"),
                    help="route the server-side Eq. 16 solve through the "
                         "tiled Pallas solver kernels")
    from repro.fed.aggregator_device import FAMILIES as _AGGS
    ap.add_argument("--aggregator", default="fedavg", choices=_AGGS,
                    help="server-update family to lower as the aggregator "
                         "program (fed/aggregator_device.py)")
    ap.add_argument("--agg-backend", default="ref", choices=("ref", "pallas"),
                    help="route the memory family's (N, P) panel "
                         "scatter+reduce through the fused Pallas kernel")
    ap.add_argument("--sweep-mesh", default=None, metavar="CxS",
                    help="also lower the shard_map'd sweep engine on a "
                         "(cells[, silo]) engine mesh, e.g. 8 or 4x2 "
                         "(fed/scan_engine.py, DESIGN.md §13)")
    from repro.launch.obs_cli import (
        add_observability_args, finish_observability, make_observability,
    )
    add_observability_args(ap)
    args = ap.parse_args()
    sweep = tuple(int(s) for s in args.sweep_mesh.split("x")) \
        if args.sweep_mesh else None
    tracer, sink = make_observability(args, run=f"fedsim-c{args.clients}")
    try:
        rec = run(args.clients, multi_pod=args.multi_pod, force=args.force,
                  solver_backend=args.solver_backend,
                  aggregator=args.aggregator, agg_backend=args.agg_backend,
                  sweep_mesh=sweep, tracer=tracer, sink=sink)
    finally:
        trace = finish_observability(tracer, sink, args)
        if trace:
            print(f"trace: {trace}")
    raise SystemExit(0 if rec["ok"] else 1)


if __name__ == "__main__":
    main()

"""Production mesh + logical-axis map construction.

All constructors are FUNCTIONS (no module-level jax device access) so
importing this module never locks the device count — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first init.
"""
from __future__ import annotations

import numpy as np

import jax

from repro.sharding.ctx import ShardCtx


def make_production_mesh(*, multi_pod: bool = False):
    """Target hardware: TPU v5e, 256 chips/pod.

    single pod : (16, 16)    axes ("data", "model")
    two pods   : (2, 16, 16) axes ("pod", "data", "model")
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for the production mesh, have {len(devices)} — "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "importing jax (launch/dryrun.py does this)")
    return jax.make_mesh(shape, axes, devices=devices)


def make_host_mesh():
    """Whatever this host actually has: (n_dev,) pure data-parallel mesh."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))


def make_engine_mesh(shape=(8, 1)):
    """The scan engine's ("cells", "silo") grid (DESIGN.md §13).

    ``shape`` is (cells,) or (cells, silo): sweep cells shard over the first
    axis (embarrassingly parallel — per-cell subsystem state stays
    device-local), and at large N the memory-aggregator panel / the vmap'd
    local-training client axis row-shard over the second.  CPU testing forces
    host devices via ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
    (must be set before jax first initializes).
    """
    shape = tuple(int(s) for s in shape)
    if len(shape) == 1:
        shape = shape + (1,)
    if len(shape) != 2 or any(s < 1 for s in shape):
        raise ValueError(f"engine mesh shape must be (cells,) or "
                         f"(cells, silo) with positive sizes, got {shape!r}")
    n = int(np.prod(shape))
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for the ({shape[0]}x{shape[1]}) engine mesh, "
            f"have {len(devices)} — set XLA_FLAGS=--xla_force_host_platform_"
            "device_count=8 before importing jax (tests/CI do this)")
    return jax.make_mesh(shape, ("cells", "silo"), devices=devices)


# When True (variant `fsdp_over_pod`), weights/optimizer shard over BOTH the
# pod and data axes (32-way ZeRO-style) instead of data only — halves
# per-chip weight+opt memory for the 340B archs at the price of cross-pod
# weight gathers on the slower inter-pod links.
FSDP_OVER_POD = False


def axis_map_for(mesh) -> dict[str, tuple[str, ...]]:
    """Logical -> physical axis map (DESIGN.md §3).

    dp    batch axis: ("pod","data") multi-pod, ("data",) single-pod
    fsdp  weight-sharding axis: ("data",)
    tp    tensor-parallel axis: ("model",)
    sp    sequence axis (long-context, batch=1): ("data",)
    """
    names = set(mesh.axis_names)
    amap: dict[str, tuple[str, ...]] = {}
    if "pod" in names and "data" in names:
        amap["dp"] = ("pod", "data")
    elif "data" in names:
        amap["dp"] = ("data",)
    if "data" in names:
        if FSDP_OVER_POD and "pod" in names:
            amap["fsdp"] = ("pod", "data")
        else:
            amap["fsdp"] = ("data",)
        amap["sp"] = ("data",)
    if "model" in names:
        amap["tp"] = ("model",)
    return amap


def make_shard_ctx(mesh) -> ShardCtx:
    amap = axis_map_for(mesh)
    sizes = {n: s for n, s in zip(mesh.axis_names, mesh.devices.shape)}
    tp = int(np.prod([sizes[a] for a in amap.get("tp", ())])) if amap.get("tp") else 1
    dp = int(np.prod([sizes[a] for a in amap.get("dp", ())])) if amap.get("dp") else 1
    return ShardCtx(axis_map=amap, mesh=mesh, tp_size=tp, dp_size=dp)

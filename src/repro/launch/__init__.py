# Launchers: production mesh construction, abstract input specs, the three
# lowered programs (train / prefill / serve), the multi-pod dry-run driver,
# and the real train/serve entry points.

"""Shared observability CLI knobs (DESIGN.md §17).

Every launch entry point (``train.py``, ``fedsim.py``, ``serve.py``)
exposes the same three flags through ``add_observability_args``::

    --trace-dir DIR      record host spans; Chrome trace.json lands in DIR
    --profile            also arm jax.profiler (XLA trace in DIR/xla)
    --metrics-jsonl F    stream schema-versioned metric events to F

``make_observability`` builds the (tracer, sink) pair from parsed args;
``finish_observability`` exports the Chrome trace, stops the profiler
and drains/closes the sink — call it in a ``finally``.
"""
from __future__ import annotations

import os
from typing import Optional

from repro.fed.telemetry import Tracer, make_tracer
from repro.obs import JSONLMetricsSink


def add_observability_args(ap):
    g = ap.add_argument_group("observability")
    g.add_argument("--trace-dir", default=None,
                   help="record host spans; writes trace.json here "
                        "(load in chrome://tracing / ui.perfetto.dev)")
    g.add_argument("--profile", action="store_true",
                   help="also record a jax.profiler XLA trace under "
                        "<trace-dir>/xla")
    g.add_argument("--metrics-jsonl", default=None,
                   help="stream schema-versioned metric events (JSONL) "
                        "to this file")
    return ap


def make_observability(args, *, run: Optional[str] = None):
    """(tracer, sink) from parsed args — NULL_TRACER / None when the
    flags are off, so call sites pass them through unconditionally."""
    trace_dir = getattr(args, "trace_dir", None)
    profile = bool(getattr(args, "profile", False))
    tracer = make_tracer(trace_dir, profile)
    if profile:
        tracer.start_profiler()
    metrics = getattr(args, "metrics_jsonl", None)
    sink = JSONLMetricsSink(metrics, run=run) if metrics else None
    return tracer, sink


def finish_observability(tracer: Tracer, sink, args) -> Optional[str]:
    """Export the Chrome trace (returns its path), stop the profiler,
    drain + close the sink.  Safe to call with observability off."""
    path = None
    tracer.stop_profiler()
    trace_dir = getattr(args, "trace_dir", None)
    if trace_dir and tracer.enabled:
        path = tracer.export_chrome(os.path.join(trace_dir, "trace.json"))
    if sink is not None:
        sink.close()
    return path

"""Abstract input/parameter specs for every (architecture × input shape).

``input_specs`` returns jax.ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, zero allocation) for the lowered program of the shape's kind:

  train    -> {tokens, labels (+ image_emb | audio_frames)}
  prefill  -> {tokens (+ image_emb | audio_frames)}
  decode   -> (tokens (B,), cache pytree with seq_len-entry KV/SSM state)

The modality frontends are stubs per the assignment: VLM patch embeddings and
audio frame embeddings arrive precomputed at the model's d_model width.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, InputShape, INPUT_SHAPES, pad_heads
from repro.models import lm

# perf-variant knob: pad attention heads to this multiple for TP alignment
# (exact weight embedding — see configs.base.pad_heads); None = off.
PAD_HEADS_MULTIPLE = None


def variant_for_shape(cfg: ArchConfig, shape: InputShape) -> ArchConfig:
    """long_500k needs sub-quadratic attention: full-attention archs switch to
    the sliding-window variant (window=cfg.window, default 4096) — the
    beyond-paper config flagged in DESIGN.md §Shape-applicability.  SSM /
    hybrid / already-windowed archs are unchanged."""
    if shape.name == "long_500k" and cfg.attention == "full":
        cfg = dataclasses.replace(cfg, attention="sliding_window")
    if PAD_HEADS_MULTIPLE and cfg.attention != "none":
        cfg = pad_heads(cfg, PAD_HEADS_MULTIPLE)
    return cfg


def abstract_params(cfg: ArchConfig):
    """ShapeDtypeStruct pytree of the model parameters (no allocation)."""
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return jax.eval_shape(lambda k: lm.init_params(k, cfg), key)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def input_specs(cfg: ArchConfig, shape: InputShape) -> dict[str, Any]:
    """Returns the kwargs pytree for the shape's lowered program."""
    b, s = shape.global_batch, shape.seq_len
    act_dtype = jnp.dtype(cfg.dtype)

    if shape.kind in ("train", "prefill"):
        batch: dict[str, Any] = {}
        s_text = s
        if cfg.family == "vlm" and cfg.n_image_tokens:
            s_text = s - cfg.n_image_tokens
            batch["image_emb"] = _sds((b, cfg.n_image_tokens, cfg.d_model), act_dtype)
        if cfg.enc_dec:
            batch["audio_frames"] = _sds((b, cfg.n_audio_frames, cfg.d_model), act_dtype)
        batch["tokens"] = _sds((b, s_text), jnp.int32)
        if shape.kind == "train":
            batch["labels"] = _sds((b, s_text), jnp.int32)
        return {"batch": batch}

    # ---- decode: one token against a seq_len cache -----------------------
    enc_len = cfg.n_audio_frames if cfg.enc_dec else 0
    max_len = s
    if lm.RING_CACHE and cfg.attention == "sliding_window":
        max_len = min(s, cfg.window)       # ring buffer: the window IS the cache
    cache = jax.eval_shape(
        partial(lm.init_decode_cache, cfg, b, max_len, enc_len))
    tokens = _sds((b,), jnp.int32)
    return {"tokens": tokens, "cache": cache}


def concrete_inputs(cfg: ArchConfig, shape: InputShape, key=None):
    """Materialize real (random) inputs matching ``input_specs`` — used by the
    smoke tests and CPU examples at reduced configs."""
    import numpy as np
    rng = np.random.default_rng(0)
    specs = input_specs(cfg, shape)

    def mk(x):
        if jnp.issubdtype(x.dtype, jnp.integer):
            return jnp.asarray(rng.integers(0, max(cfg.vocab_size - 1, 2), x.shape),
                               x.dtype)
        return jnp.asarray(rng.normal(0, 0.02, x.shape), x.dtype)

    return jax.tree_util.tree_map(mk, specs)

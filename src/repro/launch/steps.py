"""The three lowered programs and their sharding assignments.

  train_step   : fwd + bwd + AdamW update      (train_4k)
  prefill_step : prompt forward + cache build  (prefill_32k)
  serve_step   : ONE token against the cache   (decode_32k, long_500k)

``make_shardings`` derives NamedSharding pytrees for every argument from the
path-based rules in ``repro.sharding.rules`` — 2D weight sharding
(FSDP × TP), batch over dp, cache over dp (or over *sequence* when
global_batch == 1, the long_500k layout).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, InputShape
from repro.models import lm
from repro.optim.optimizers import adamw, Optimizer
from repro.sharding import rules
from repro.sharding.ctx import ShardCtx


# ------------------------------------------------------------------- steps
# Microbatch count for gradient accumulation (perf variant knob): the global
# batch is split into MICROBATCHES chunks scanned sequentially, dividing the
# live-activation footprint by the same factor at the cost of re-running the
# (FSDP weight-gather) collectives per chunk.
MICROBATCHES = 1
# dtype of the gradient accumulator in the microbatch scan (f32 default;
# bf16 halves the largest persistent temp buffer of the 340B train step)
GRAD_ACC_DTYPE = "float32"


def make_train_step(cfg: ArchConfig, optimizer: Optimizer | None = None):
    optimizer = optimizer or adamw(state_dtype=jnp.bfloat16)
    n_micro = MICROBATCHES

    def loss_fn(p, batch):
        return lm.train_loss(p, cfg, batch)

    def train_step(params, opt_state, batch, lr):
        if n_micro == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            # split every leaf's batch dim into (n_micro, b/n_micro, ...)
            def split(x):
                b = x.shape[0]
                return x.reshape(n_micro, b // n_micro, *x.shape[1:])

            micro = jax.tree_util.tree_map(split, batch)

            def acc_step(carry, mb):
                loss_acc, g_acc = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, x: a + x.astype(a.dtype), g_acc, g)
                return (loss_acc + l, g_acc), None

            acc_dt = jnp.dtype(GRAD_ACC_DTYPE)
            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, acc_dt), params)
            (loss, grads), _ = jax.lax.scan(
                acc_step, (jnp.zeros((), jnp.float32), g0), micro)
            loss = loss / n_micro
            grads = jax.tree_util.tree_map(lambda g: g / n_micro, grads)
        new_params, new_state = optimizer.update(grads, opt_state, params, lr)
        return new_params, new_state, loss

    return train_step, optimizer


def make_prefill_step(cfg: ArchConfig):
    def prefill_step(params, batch):
        return lm.prefill(params, cfg, batch)
    return prefill_step


def make_serve_step(cfg: ArchConfig):
    def serve_step(params, tokens, cache):
        return lm.decode_step(params, cfg, tokens, cache)
    return serve_step


# --------------------------------------------------------------- shardings
def _named(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def opt_state_specs(param_spec_tree):
    return {"m": param_spec_tree, "v": param_spec_tree, "t": P()}


def make_shardings(cfg: ArchConfig, shape: InputShape, ctx: ShardCtx,
                   params_abs, cache_abs=None, batch_abs=None):
    """Returns dict with NamedSharding pytrees: params, opt, batch, cache."""
    mesh = ctx.mesh
    if cfg.attention != "none" and rules.HEAD_AWARE_TP:
        ctx = dataclasses.replace(ctx, head_divisors={
            "wq": cfg.n_heads, "wo": cfg.n_heads,
            "wk": cfg.n_kv_heads, "wv": cfg.n_kv_heads})
    pspecs = rules.param_specs(params_abs, ctx)
    out: dict[str, Any] = {"params": _named(mesh, pspecs)}
    out["opt"] = _named(mesh, opt_state_specs(pspecs))
    if batch_abs is not None:
        out["batch"] = _named(mesh, rules.batch_specs(batch_abs, ctx))
    if cache_abs is not None:
        # batch=1 long-context: shard the cache over *sequence* — unless the
        # ring-cache variant already shrank it to one window (then replicate)
        seq_shard = shape.global_batch == 1 and not lm.RING_CACHE
        out["cache"] = _named(mesh, rules.cache_specs(cache_abs, ctx,
                                                      seq_shard=seq_shard))
    return out

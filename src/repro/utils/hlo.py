"""While-loop-aware cost analysis over compiled HLO text.

``compiled.cost_analysis()`` on the CPU backend counts ``while`` bodies ONCE
(verified: an 8-step scan reports 1/8 the flops of its unrolled twin), and the
same holds for collectives that live inside the layers scan — useless for a
roofline over scan-of-layers models.  This module re-derives the three
roofline inputs by walking the HLO call graph with loop-trip multiplicities:

  * flops            — 2·prod(out)·prod(contracting dims) per dot (incl. dots
                       inside fusion computations), × enclosing trip counts
  * bytes accessed   — operand + output bytes of top-level instructions
                       (fusion internals excluded, matching XLA's accounting),
                       × enclosing trip counts
  * collective bytes — result-shape bytes of all-gather / all-reduce /
                       reduce-scatter / all-to-all / collective-permute,
                       × enclosing trip counts

Trip counts are read from the while condition's integer constant (scans lower
to ``while (iv < L)``).  ``memory_analysis()`` needs no such correction —
buffer assignment already models loops — so callers keep using it directly.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_KNOWN_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
# shape text may be a tuple with /*index=N*/ comments; match lazily up to the
# first " opcode(" boundary
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([a-z0-9\-]+)\((.*)$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_TRIP_RE = re.compile(r"constant\((\d+)\)")

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")


def _shape_bytes(shape_text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_dims(shape_text: str) -> list[int]:
    """First array shape's dims in a (possibly tuple) shape string."""
    m = _SHAPE_RE.search(shape_text)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Instr:
    name: str
    shape: str
    op: str
    rest: str              # operand list + attrs (raw tail of the line)

    def operands(self) -> list[str]:
        # names in the parenthesized operand list; attrs follow "), " so the
        # cut keeps computation references (body=/calls=) out of the operand
        # byte count
        cut = self.rest.split("), ")[0]
        return _OPERAND_RE.findall(cut)

    def called(self) -> list[tuple[str, str]]:
        out = []
        for key in ("body=", "condition=", "calls=", "to_apply=",
                    "true_computation=", "false_computation="):
            for m in re.finditer(re.escape(key) + r"%?([\w.\-]+)", self.rest):
                out.append((key[:-1], m.group(1)))
        m = re.search(r"branch_computations=\{([^}]*)\}", self.rest)
        if m:
            for name in _OPERAND_RE.findall(m.group(1)):
                out.append(("branch", name))
        return out


@dataclass
class Computation:
    name: str
    instrs: list[Instr] = field(default_factory=list)
    shapes: dict[str, str] = field(default_factory=dict)
    is_entry: bool = False


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in text.splitlines():
        stripped = line.strip()
        if stripped.endswith("{") and "->" in stripped:
            m = _COMP_HEADER_RE.match(stripped)
            if m:
                cur = Computation(m.group(1),
                                  is_entry=stripped.startswith("ENTRY"))
                comps[cur.name] = cur
                continue
        if cur is None:
            continue
        if stripped == "}":
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            ins = Instr(m.group(1), m.group(2).strip(), m.group(3), m.group(4))
            cur.instrs.append(ins)
            cur.shapes[ins.name] = ins.shape
    return comps


def _dot_flops(ins: Instr, comp: Computation) -> float:
    out_elems = 1
    for d in _shape_dims(ins.shape):
        out_elems *= d
    ops = ins.operands()
    if not ops:
        return 0.0
    lhs_shape = _shape_dims(comp.shapes.get(ops[0], ""))
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.rest)
    contract = 1
    if m and lhs_shape:
        for idx in m.group(1).split(","):
            if idx and int(idx) < len(lhs_shape):
                contract *= lhs_shape[int(idx)]
    return 2.0 * out_elems * contract


def _conv_flops(ins: Instr, comp: Computation) -> float:
    # 2 * out_elems * (kernel spatial * in_channels); approximated from rhs
    ops = ins.operands()
    out_elems = 1
    for d in _shape_dims(ins.shape):
        out_elems *= d
    if len(ops) < 2:
        return 0.0
    rhs = _shape_dims(comp.shapes.get(ops[1], ""))
    k = 1
    for d in rhs[:-1]:           # all but the output-feature dim (approx)
        k *= d
    return 2.0 * out_elems * k


_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "opt-barrier", "partition-id", "replica-id",
}


def _trip_count(cond: Computation) -> int:
    best = 1
    for ins in cond.instrs:
        for m in _TRIP_RE.finditer(ins.rest):
            best = max(best, int(m.group(1)))
    return best


@dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collectives: dict = field(default_factory=dict)
    bytes_by_op: dict = field(default_factory=dict)   # opcode -> bytes (x trips)


def analyze(text: str, entry: str | None = None) -> HloCost:
    comps = parse_hlo(text)
    if not comps:
        return HloCost()
    if entry is None:
        entry = next((n for n, c in comps.items() if c.is_entry), None)
    if entry is None:
        # fallback: a computation nobody else calls
        called = set()
        for c in comps.values():
            for ins in c.instrs:
                for _, name in ins.called():
                    called.add(name)
        entries = [n for n in comps if n not in called]
        entry = entries[0] if entries else next(iter(comps))

    # ---- propagate multiplicities through the call graph -------------------
    mult: dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    fused: set[str] = set()
    order = [entry]
    seen = {entry}
    i = 0
    while i < len(order):
        cname = order[i]
        i += 1
        comp = comps.get(cname)
        if comp is None:
            continue
        m = mult[cname]
        for ins in comp.instrs:
            calls = ins.called()
            trip = 1
            if ins.op == "while":
                kt = _KNOWN_TRIP_RE.search(ins.rest)
                if kt:
                    trip = int(kt.group(1))
                else:
                    cond_name = next((n for k, n in calls if k == "condition"), None)
                    if cond_name and cond_name in comps:
                        trip = _trip_count(comps[cond_name])
            for kind, name in calls:
                if name not in comps:
                    continue
                child_mult = m * (trip if kind in ("body", "condition") else 1)
                if kind == "calls":            # fusion internals
                    fused.add(name)
                mult[name] += child_mult
                if name not in seen:
                    seen.add(name)
                    order.append(name)

    # ---- accumulate costs ---------------------------------------------------
    cost = HloCost()
    coll: dict[str, dict[str, float]] = defaultdict(lambda: {"count": 0.0, "bytes": 0.0})
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        in_fusion = cname in fused
        for ins in comp.instrs:
            if ins.op == "dot":
                cost.flops += m * _dot_flops(ins, comp)
            elif ins.op == "convolution":
                cost.flops += m * _conv_flops(ins, comp)
            if in_fusion:
                continue                        # bytes/collectives: top level only
            base = ins.op
            is_coll = False
            for kind in COLLECTIVE_KINDS:
                if base == kind or base == kind + "-start":
                    b = _shape_bytes(ins.shape)
                    coll[kind]["count"] += m
                    coll[kind]["bytes"] += m * b
                    cost.collective_bytes += m * b
                    is_coll = True
                    break
            if base in _SKIP_BYTES_OPS or base.endswith("-done"):
                continue
            b = _shape_bytes(ins.shape)
            for op_name in ins.operands():
                b += _shape_bytes(comp.shapes.get(op_name, ""))
            cost.bytes += m * b
            cost.bytes_by_op[base] = cost.bytes_by_op.get(base, 0.0) + m * b
    cost.collectives = {k: dict(v) for k, v in coll.items()}
    return cost


# ---------------------------------------------------------------- legacy API
def collective_breakdown(hlo_text: str) -> dict[str, dict[str, float]]:
    """Per-collective-kind {count, bytes}, loop-trip-aware (per-device)."""
    return analyze(hlo_text).collectives


def collective_bytes(hlo_text: str) -> int:
    return int(analyze(hlo_text).collective_bytes)

from repro.utils.tree import param_count, tree_bytes, map_with_path
from repro.utils.hlo import collective_bytes, collective_breakdown

"""Small pytree utilities used across the framework."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def param_count(tree) -> int:
    """Total number of scalar parameters in a pytree."""
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree) -> int:
    """Total bytes of a pytree of (possibly abstract) arrays."""
    total = 0
    for x in jax.tree_util.tree_leaves(tree):
        total += int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
    return total


def map_with_path(fn, tree):
    """tree_map that passes ('a','b',...) key-path tuples of strings to fn."""

    def _keystr(path):
        out = []
        for p in path:
            if hasattr(p, "key"):
                out.append(str(p.key))
            elif hasattr(p, "idx"):
                out.append(str(p.idx))
            else:
                out.append(str(p))
        return tuple(out)

    return jax.tree_util.tree_map_with_path(lambda p, x: fn(_keystr(p), x), tree)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(sum(leaves))


def tree_add(a, b, scale_b=1.0):
    return jax.tree_util.tree_map(lambda x, y: x + scale_b * y, a, b)


def tree_scale(a, s):
    return jax.tree_util.tree_map(lambda x: x * s, a)


def tree_zeros_like(a):
    return jax.tree_util.tree_map(jnp.zeros_like, a)

"""Minimal optax-style optimizers (pure functions over pytrees).

``adamw(state_dtype=jnp.bfloat16)`` keeps first/second moments in bf16 — the
memory plan for the >=15B dense archs (DESIGN.md §6).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable          # (grads, state, params, lr) -> (new_params, new_state)


def sgd(momentum: float = 0.0, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return ()
        return jax.tree_util.tree_map(jnp.zeros_like, params)

    def update(grads, state, params, lr):
        if weight_decay:
            grads = jax.tree_util.tree_map(lambda g, p: g + weight_decay * p, grads, params)
        if momentum == 0.0:
            new = jax.tree_util.tree_map(lambda p, g: p - lr * g.astype(p.dtype), params, grads)
            return new, ()
        state = jax.tree_util.tree_map(lambda m, g: momentum * m + g, state, grads)
        new = jax.tree_util.tree_map(lambda p, m: p - lr * m.astype(p.dtype), params, state)
        return new, state

    return Optimizer(init, update)


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0, state_dtype=None) -> Optimizer:
    def init(params):
        def z(p):
            dt = state_dtype or p.dtype
            return jnp.zeros(p.shape, dt)
        return {
            "m": jax.tree_util.tree_map(z, params),
            "v": jax.tree_util.tree_map(z, params),
            "t": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, lr):
        t = state["t"] + 1
        c1 = 1.0 - b1 ** t.astype(jnp.float32)
        c2 = 1.0 - b2 ** t.astype(jnp.float32)

        def upd(p, g, m, v):
            g32 = g.astype(jnp.float32)
            m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
            v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
            step = lr * (m32 / c1) / (jnp.sqrt(v32 / c2) + eps)
            if weight_decay:
                step = step + lr * weight_decay * p.astype(jnp.float32)
            return ((p.astype(jnp.float32) - step).astype(p.dtype),
                    m32.astype(m.dtype), v32.astype(v.dtype))

        out = jax.tree_util.tree_map(upd, params, grads, state["m"], state["v"])
        leaves, treedef = jax.tree_util.tree_flatten(out, is_leaf=lambda x: isinstance(x, tuple))
        new_p = treedef.unflatten([l[0] for l in leaves])
        new_m = treedef.unflatten([l[1] for l in leaves])
        new_v = treedef.unflatten([l[2] for l in leaves])
        return new_p, {"m": new_m, "v": new_v, "t": t}

    return Optimizer(init, update)

"""Learning-rate schedules."""
from __future__ import annotations

import numpy as np


def constant(lr: float):
    return lambda t: lr


def round_decay(lr: float, factor: float = 0.998):
    """The paper's per-round decay (x0.998 each communication round)."""
    return lambda t: lr * (factor ** t)


def cosine_warmup(peak: float, warmup: int, total: int, floor: float = 0.0):
    def f(t):
        if t < warmup:
            return peak * (t + 1) / warmup
        frac = (t - warmup) / max(total - warmup, 1)
        return floor + 0.5 * (peak - floor) * (1 + np.cos(np.pi * min(frac, 1.0)))
    return f

from repro.optim.optimizers import sgd, adamw, Optimizer
from repro.optim.schedules import constant, round_decay, cosine_warmup

"""Flat-npz pytree checkpointing (+ JSON metadata sidecar).

Stores any dict-pytree of arrays (model params, optimizer state, FedGS round
state: sampling counts v^t, the H matrix, rng key) with '/'-joined key paths.
"""
from __future__ import annotations

import json
import os

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        arr = np.asarray(tree)
        if arr.dtype.name == "bfloat16":       # npz has no bf16: store raw bits
            out[prefix[:-1] + "%bf16"] = arr.view(np.uint16)
        else:
            out[prefix[:-1]] = arr
    return out


def save_checkpoint(path: str, tree, metadata: dict | None = None):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    np.savez(path if path.endswith(".npz") else path + ".npz", **flat)
    if metadata is not None:
        with open(os.path.splitext(path)[0] + ".json", "w") as f:
            json.dump(metadata, f, indent=2, default=str)


def load_checkpoint(path: str, like=None):
    """Returns the nested dict; if ``like`` (a template pytree) is given, the
    result is reassembled to match its structure and dtypes."""
    p = path if path.endswith(".npz") else path + ".npz"
    with np.load(p) as z:
        flat = {}
        for k in z.files:
            if k.endswith("%bf16"):
                import ml_dtypes
                flat[k[:-5]] = z[k].view(ml_dtypes.bfloat16)
            else:
                flat[k] = z[k]
    nested: dict = {}
    for k, v in flat.items():
        cur = nested
        parts = k.split("/")
        for part in parts[:-1]:
            cur = cur.setdefault(part, {})
        cur[parts[-1]] = v
    if like is None:
        return nested

    def rebuild(template, node):
        if isinstance(template, dict):
            return {k: rebuild(template[k], node[k]) for k in template}
        if isinstance(template, (list, tuple)):
            vals = [rebuild(t, node[str(i)]) for i, t in enumerate(template)]
            return type(template)(vals)
        arr = np.asarray(node)
        return arr.astype(template.dtype) if hasattr(template, "dtype") else arr

    return rebuild(like, nested)

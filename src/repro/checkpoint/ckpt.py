"""Flat-npz pytree checkpointing (+ JSON metadata sidecar).

Stores any dict/list/tuple pytree of arrays (model params, optimizer state,
FedGS round state: sampling counts v^t, the H matrix, rng key, the scan
engine's FULL carry — aggregator slots, availability-chain state, sampler
state) with '/'-joined key paths.

Format notes (DESIGN.md §13):
  * bf16 leaves: npz has no bfloat16, so raw bits are stored as uint16 under
    a ``%bf16``-suffixed key and re-viewed on load.
  * EMPTY containers ({} / [] / ()): these carry pytree *structure* but no
    leaves (e.g. a stateless sampler's ``sampler_state`` is ``{}``), so a
    purely leaf-keyed flat file would silently drop them and a later
    ``load_checkpoint(..., like=...)`` rebuild would KeyError.  They are
    recorded under a ``%empty``-suffixed sentinel key whose int8 payload
    encodes the container kind (0=dict, 1=list, 2=tuple).
  * sharded jax arrays: ``np.asarray`` on a fully-addressable array gathers
    shards to one host buffer, so checkpoints written from a mesh-sharded
    run are device-layout-free and restorable on any device count.
  * leaf names themselves must not end in ``%bf16``/``%empty`` (reserved).
"""
from __future__ import annotations

import json
import os

import jax
import numpy as np

_EMPTY_KINDS = ({}, [], ())          # payload value indexes this tuple


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        if not tree:
            out[prefix[:-1] + "%empty"] = np.int8(0)
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        if not tree:
            out[prefix[:-1] + "%empty"] = np.int8(
                1 if isinstance(tree, list) else 2)
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        arr = np.asarray(tree)       # gathers sharded jax arrays to host
        if arr.dtype.name == "bfloat16":       # npz has no bf16: store raw bits
            out[prefix[:-1] + "%bf16"] = arr.view(np.uint16)
        else:
            out[prefix[:-1]] = arr
    return out


def save_checkpoint(path: str, tree, metadata: dict | None = None):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    # ONE device_get of the whole pytree: sharded jax leaves gather to host
    # in a single batched transfer (the per-leaf np.asarray in _flatten then
    # sees numpy and is a no-op) instead of one blocking copy per leaf
    tree = jax.device_get(tree)
    flat = _flatten(tree)
    np.savez(path if path.endswith(".npz") else path + ".npz", **flat)
    if metadata is not None:
        with open(os.path.splitext(path)[0] + ".json", "w") as f:
            json.dump(metadata, f, indent=2, default=str)


def load_checkpoint(path: str, like=None):
    """Returns the nested dict; if ``like`` (a template pytree) is given, the
    result is reassembled to match its structure and dtypes (missing keys
    raise KeyError — callers use that to detect older checkpoint formats).
    Without ``like``, empty dict subtrees come back as ``{}`` and numbered
    list/tuple subtrees as dicts keyed '0', '1', ... (the flat file does not
    record sequence kinds for non-empty containers)."""
    p = path if path.endswith(".npz") else path + ".npz"
    with np.load(p) as z:
        flat, empties = {}, {}
        for k in z.files:
            if k.endswith("%bf16"):
                import ml_dtypes
                flat[k[:-len("%bf16")]] = z[k].view(ml_dtypes.bfloat16)
            elif k.endswith("%empty"):
                empties[k[:-len("%empty")]] = int(z[k])
            else:
                flat[k] = z[k]
    if "" in empties:                # the whole tree is one empty container
        return type(_EMPTY_KINDS[empties[""]])()
    nested: dict = {}
    for k, v in flat.items():
        cur = nested
        parts = k.split("/")
        for part in parts[:-1]:
            cur = cur.setdefault(part, {})
        cur[parts[-1]] = v
    for k, kind in empties.items():
        cur = nested
        parts = k.split("/")
        for part in parts[:-1]:
            cur = cur.setdefault(part, {})
        cur[parts[-1]] = type(_EMPTY_KINDS[kind])()
    if like is None:
        return nested

    def rebuild(template, node):
        if isinstance(template, dict):
            return {k: rebuild(template[k], node[k]) for k in template}
        if isinstance(template, (list, tuple)):
            vals = [rebuild(t, node[str(i)]) for i, t in enumerate(template)]
            return type(template)(vals)
        arr = np.asarray(node)
        return arr.astype(template.dtype) if hasattr(template, "dtype") else arr

    return rebuild(like, nested)

"""JSONL metrics sink: schema-versioned, append-only, one writer thread.

Every event is one JSON line::

    {"schema": 1, "kind": "round", "wall_time": 1699.123, "run": "...",
     "seq": 17, ...payload...}

``kind`` partitions the stream — the engine emits ``run_start`` /
``round`` / ``segment`` / ``run_end`` events, the service adds
``request`` events — and ``schema`` versions the envelope so a consumer
can refuse a stream it does not understand (``read_metrics_jsonl``
round-trips and checks).

I/O happens on ONE background writer thread through the PR-8
``AsyncCheckpointWriter`` (bounded queue = backpressure instead of
unbounded host-memory growth; sticky errors re-raised on the caller
thread; strict submission order so ``seq`` is monotone in the file).
``emit`` itself only builds a small dict — JSON encoding AND the write
run on the writer thread, off the engine's dispatch loop.
"""
from __future__ import annotations

import json
import math
import os
import threading
import time
from typing import Optional

import numpy as np

from repro.fed.runtime import AsyncCheckpointWriter

METRICS_SCHEMA_VERSION = 1


def _jsonable(obj):
    """numpy/jax scalars and arrays -> plain JSON types (device arrays
    must already be on host — the engine emits from fetched segments)."""
    if isinstance(obj, (np.floating, np.integer, np.bool_)):
        return obj.item()
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    if hasattr(obj, "tolist"):                  # jax.Array already fetched
        return obj.tolist()
    raise TypeError(f"not JSON-serializable: {type(obj).__name__}")


def _definite(obj):
    """Recursively map non-finite floats to ``null`` — the stream must
    stay STANDARD JSON (python's default ``NaN`` token breaks every
    non-python consumer).  Only walked when a record actually carries a
    non-finite value; the common path never pays for it."""
    if isinstance(obj, float):
        return obj if math.isfinite(obj) else None
    if isinstance(obj, dict):
        return {k: _definite(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_definite(v) for v in obj]
    try:
        return _definite(_jsonable(obj))
    except TypeError:
        return obj


class JSONLMetricsSink:
    """Append metric events to ``path`` as JSON lines from a background
    writer thread.  Context-manager friendly; ``close()`` drains the
    queue and re-raises the first write error (never silent)."""

    def __init__(self, path: str, *, run: Optional[str] = None,
                 max_pending: int = 256):
        d = os.path.dirname(os.fspath(path))
        if d:
            os.makedirs(d, exist_ok=True)
        self.path = os.fspath(path)
        self.run = run
        self._seq = 0
        self._lock = threading.Lock()
        self._stats = {"events": 0, "bytes": 0}
        self._f = open(self.path, "a", encoding="utf-8")
        self._writer = AsyncCheckpointWriter(max_pending=max_pending)
        self._closed = False

    # ------------------------------------------------------------- emit
    def _write(self, rec: dict):
        try:
            line = json.dumps(rec, default=_jsonable,
                              separators=(",", ":"), allow_nan=False)
        except ValueError:          # a NaN/inf leaf: sanitize and retry
            line = json.dumps(_definite(rec), separators=(",", ":"),
                              allow_nan=False)
        self._f.write(line + "\n")
        self._stats["events"] += 1
        self._stats["bytes"] += len(line) + 1

    def emit(self, kind: str, payload: Optional[dict] = None, **fields):
        """Queue one event; returns its ``seq``.  ``payload``/``fields``
        must not use the envelope keys (schema/kind/seq/wall_time/run)."""
        if self._closed:
            raise RuntimeError("JSONLMetricsSink is closed")
        with self._lock:
            seq = self._seq
            self._seq += 1
        rec = {"schema": METRICS_SCHEMA_VERSION, "kind": kind, "seq": seq,
               "wall_time": round(time.time(), 6)}
        if self.run is not None:
            rec["run"] = self.run
        if payload:
            rec.update(payload)
        if fields:
            rec.update(fields)
        self._writer.submit(self._write, rec)
        return seq

    # ------------------------------------------------------------ admin
    def flush(self):
        self._writer.flush()
        self._f.flush()

    def close(self):
        if self._closed:
            return
        self._closed = True
        try:
            self._writer.close()
        finally:
            self._f.flush()
            self._f.close()

    def stats(self) -> dict:
        """events/bytes written plus the writer-thread backpressure
        counters (queue depth, high watermark, blocked ms)."""
        return {**self._stats, "writer": self._writer.stats()}

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        self.close()
        return False


def read_metrics_jsonl(path: str, *, kind: Optional[str] = None,
                       strict: bool = True) -> list[dict]:
    """Load a JSONL metrics stream back; optionally filter by ``kind``.
    ``strict=True`` refuses events from an unknown schema version;
    ``strict=False`` silently SKIPS them (a tolerant reader never
    misinterprets an envelope it does not understand)."""
    out = []
    with open(path, encoding="utf-8") as f:
        for ln in f:
            ln = ln.strip()
            if not ln:
                continue
            rec = json.loads(ln)
            if rec.get("schema") != METRICS_SCHEMA_VERSION:
                if strict:
                    raise ValueError(
                        f"unknown metrics schema {rec.get('schema')!r} "
                        f"(this reader understands "
                        f"{METRICS_SCHEMA_VERSION})")
                continue
            if kind is None or rec.get("kind") == kind:
                out.append(rec)
    return out

"""Prometheus text exposition (zero-dependency, exposition format 0.0.4).

``render_prometheus`` turns a metric-families dict into the plain-text
format a Prometheus scraper (or a human) reads::

    # HELP fedgs_rounds_streamed_total Rounds streamed to clients.
    # TYPE fedgs_rounds_streamed_total counter
    fedgs_rounds_streamed_total 192

Families are plain data so the service can build them from its counters
without a client library::

    families = {
        "rounds_streamed_total": {
            "type": "counter", "help": "Rounds streamed.",
            "samples": [({}, 192)],
        },
        "request_queue_seconds": {
            "type": "gauge", "help": "submit->drain queue latency.",
            "samples": [({"request": "3"}, 0.012)],
        },
    }

``prom_families`` is the one-liner builder for label-free gauges.
"""
from __future__ import annotations


def _escape(v: str) -> str:
    return str(v).replace("\\", r"\\").replace("\n", r"\n").replace(
        '"', r'\"')


def _fmt(value) -> str:
    v = float(value)
    if v != v:
        return "NaN"
    if v in (float("inf"), float("-inf")):
        return "+Inf" if v > 0 else "-Inf"
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(v)


def prom_families(metrics: dict, *, type_: str = "gauge",
                  help_texts: dict | None = None) -> dict:
    """Build label-free single-sample families from ``{name: value}``."""
    help_texts = help_texts or {}
    return {name: {"type": type_,
                   "help": help_texts.get(name, name.replace("_", " ")),
                   "samples": [({}, value)]}
            for name, value in metrics.items()}


def render_prometheus(families: dict, *, prefix: str = "fedgs_") -> str:
    """Render metric families (see module docstring) as exposition text.
    Sample values must be numbers; labels render sorted for a stable,
    diff-able exposition."""
    lines: list[str] = []
    for name in sorted(families):
        fam = families[name]
        full = prefix + name
        lines.append(f"# HELP {full} {_escape(fam.get('help', name))}")
        lines.append(f"# TYPE {full} {fam.get('type', 'gauge')}")
        for labels, value in fam.get("samples", []):
            if labels:
                lab = ",".join(f'{k}="{_escape(v)}"'
                               for k, v in sorted(labels.items()))
                lines.append(f"{full}{{{lab}}} {_fmt(value)}")
            else:
                lines.append(f"{full} {_fmt(value)}")
    return "\n".join(lines) + "\n"

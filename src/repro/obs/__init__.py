"""Streaming observability sinks (DESIGN.md §17).

``fed/telemetry.py`` produces the signals (in-scan metric pytrees, host
spans, runtime counters); this package STREAMS them out of the process:
a schema-versioned JSONL event log (``sinks.JSONLMetricsSink`` — one
background writer thread, the PR-8 ``AsyncCheckpointWriter`` pattern)
and a Prometheus-style text exposition (``prom.render_prometheus``) for
the ``SimService`` front-end."""
from repro.obs.prom import prom_families, render_prometheus
from repro.obs.sinks import (
    METRICS_SCHEMA_VERSION, JSONLMetricsSink, read_metrics_jsonl,
)

__all__ = [
    "JSONLMetricsSink", "METRICS_SCHEMA_VERSION", "read_metrics_jsonl",
    "prom_families", "render_prometheus",
]

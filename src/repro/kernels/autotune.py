"""Per-(N, P, m)-tier tile autotuner for the Pallas kernels (DESIGN.md §14).

A tile size that wins at one shape tier loses at another: small tiles keep
the grid busy at N = 128 but drown N = 4096 in grid-step overhead (and, in
interpret mode, in carried-buffer copies); big tiles amortize DMAs at scale
but waste VMEM and pad work at toy sizes.  Instead of the hand-rolled
``512 if n >= 512 else ...`` heuristics that used to live in
``kernels/ops.py``, this module

  1. enumerates tile candidates per kernel (powers of two, capped at the
     shape's pow2 ceiling so a candidate never more than doubles the work),
  2. times them under the LIVE backend (compiled on TPU, interpret on this
     CPU container — the mode is recorded per entry, never mixed),
  3. persists the winners to the checked-in ``kernels/tuned_tiles.json``
     keyed ``"<kernel>|<shape tier>|<platform>"``.

``resolve()`` is the read path every ``tile="auto"`` knob in
``kernels/ops.py`` goes through: tuned winner if the (kernel, tier,
platform) key exists, else the heuristic defaults the caller passes —
so an empty/stale table degrades to exactly the pre-autotuner behavior.
Shape tiers are pow2 ceilings (``n=1500 -> "n2048"``), matching how the
wrappers pad, so every padded shape in a tier shares one winner.  All of
this is host-side Python on static shapes: inside a jit trace the tile
still resolves at trace time and the engines pick tuned tiles per cell
tier with no code changes.

Determinism (pinned by tests): candidate order is fixed, ``pick_best`` is
min-time with first-candidate tie-break, and the JSON is written with
sorted keys — same timing table in, same tiles out, byte-identical file.
"""
from __future__ import annotations

import functools
import json
import time
from pathlib import Path

import numpy as np

import jax
import jax.numpy as jnp

TABLE_PATH = Path(__file__).with_name("tuned_tiles.json")

_RNG_SEED = 0


# ------------------------------------------------------------- tier / table
def _p2(v: int) -> int:
    """Power-of-two ceiling (>= 1)."""
    v = max(1, int(v))
    return 1 << (v - 1).bit_length()


def shape_tier(**dims) -> str:
    """Canonical tier string: pow2 ceiling per dim, keys sorted —
    ``shape_tier(n=1500) == "n2048"``, ``shape_tier(n=100, p=640) ==
    "n128,p1024"``."""
    return ",".join(f"{k}{_p2(v)}" for k, v in sorted(dims.items()))


def table_key(kernel: str, tier: str, platform: str) -> str:
    return f"{kernel}|{tier}|{platform}"


@functools.lru_cache(maxsize=None)
def _load(path_str: str) -> dict:
    p = Path(path_str)
    if not p.exists():
        return {}
    return json.loads(p.read_text())


def load_table(path=None) -> dict:
    return _load(str(path or TABLE_PATH))


def lookup(kernel: str, *, platform: str | None = None, path=None,
           **dims) -> dict | None:
    """Tuned tiles for (kernel, tier(dims), platform), or None."""
    platform = platform or jax.default_backend()
    entry = load_table(path).get(table_key(kernel, shape_tier(**dims),
                                           platform))
    return dict(entry["tiles"]) if entry else None


def resolve(kernel: str, defaults: dict, *, platform: str | None = None,
            path=None, **dims) -> dict:
    """The ``tile="auto"`` read path: tuned winner where the table has one,
    the caller's heuristic ``defaults`` otherwise.  Only keys present in
    ``defaults`` are taken from the table (a table row can never smuggle an
    unknown knob into a wrapper)."""
    out = dict(defaults)
    tuned = lookup(kernel, platform=platform, path=path, **dims)
    if tuned:
        out.update({k: int(v) for k, v in tuned.items() if k in out})
    return out


def pick_best(timed):
    """min time; ties keep the EARLIEST candidate (fixed enumeration order)
    so identical timing tables always produce identical winners."""
    best = None
    for tiles, ms in timed:
        if best is None or ms < best[1]:
            best = (tiles, ms)
    return best


# ----------------------------------------------------- per-kernel harnesses
# Each kernel registers (candidates, setup, run).  Candidates are capped at
# the shape's pow2 ceiling; invalid candidates on the live backend (e.g.
# VMEM overflow of the FW panels on TPU) simply fail and are skipped.
def _fw_setup(n):
    rng = np.random.default_rng(_RNG_SEED)
    h = (rng.random((n, n)) * 3.0).astype(np.float32)
    h = np.minimum(h, h.T)
    np.fill_diagonal(h, 0.0)
    return (jnp.asarray(h),)


def _fw_run(tiles, h):
    from repro.kernels import ops
    return ops.floyd_warshall(h, tile=tiles["tile"])


def _fused_setup(n):
    rng = np.random.default_rng(_RNG_SEED)
    return (jnp.asarray(rng.standard_normal((n, 16)).astype(np.float32)),)


def _fused_run(tiles, u):
    from repro.kernels import ops
    return ops.fused_adjacency(u, eps=0.1, sigma2=0.01, tile=tiles["tile"])


def _greedy_setup(n):
    rng = np.random.default_rng(_RNG_SEED)
    diag = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    r = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    mask = jnp.asarray(rng.random(n) > 0.3)
    return diag, r, mask


def _greedy_run(tiles, diag, r, mask):
    from repro.kernels import ops
    return ops.greedy_argmax(diag, r, mask, tile=tiles["tile"])


def _swap_setup(m, n):
    rng = np.random.default_rng(_RNG_SEED)
    qs = jnp.asarray(rng.standard_normal((m, n)).astype(np.float32))
    a = jnp.asarray(rng.standard_normal(m).astype(np.float32))
    b = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    return qs, a, b


def _swap_run(tiles, qs, a, b):
    from repro.kernels import ops
    return ops.swap_best(qs, a, b, tile_m=tiles["tile_m"],
                         tile_n=tiles["tile_n"])


def _agg_setup(n, p):
    rng = np.random.default_rng(_RNG_SEED)
    m = max(8, n // 8)
    mem = jnp.asarray(rng.standard_normal((n, p)).astype(np.float32))
    upd = jnp.asarray(rng.standard_normal((m, p)).astype(np.float32))
    sel = jnp.asarray(rng.permutation(n)[:m].astype(np.int32))
    valid = jnp.ones((m,), bool)
    w = jnp.asarray((rng.random(n).astype(np.float32)) / n)
    return mem, upd, sel, valid, w


def _agg_run(tiles, mem, upd, sel, valid, w):
    from repro.kernels import ops
    return ops.memory_aggregate(mem, upd, sel, valid, w,
                                tile_n=tiles["tile_n"],
                                tile_p=tiles["tile_p"])


def _krum_setup(m, p):
    rng = np.random.default_rng(_RNG_SEED)
    return (jnp.asarray(rng.standard_normal((m, p)).astype(np.float32)),)


def _krum_run(tiles, x):
    from repro.kernels import ops
    return ops.krum_distances(x, tile=tiles["tile"], tile_k=tiles["tile_k"])


KERNELS = {
    "floyd_warshall": dict(
        candidates=lambda n: [{"tile": t} for t in (128, 256, 512)
                              if t <= max(128, _p2(n))],
        setup=_fw_setup, run=_fw_run),
    "fused_3dg": dict(
        candidates=lambda n: [{"tile": t} for t in (128, 256, 512)
                              if t <= max(128, _p2(n))],
        setup=_fused_setup, run=_fused_run),
    "greedy_argmax": dict(
        candidates=lambda n: [{"tile": t} for t in (512, 1024, 2048, 4096)
                              if t <= max(512, _p2(n))],
        setup=_greedy_setup, run=_greedy_run),
    "swap_gain": dict(
        candidates=lambda m, n: [
            {"tile_m": tm, "tile_n": tn}
            for tm in (128, 512) if tm <= max(128, _p2(m))
            for tn in (1024, 2048, 4096) if tn <= max(1024, _p2(n))],
        setup=_swap_setup, run=_swap_run),
    "memory_aggregate": dict(
        candidates=lambda n, p: [
            {"tile_n": tn, "tile_p": tp}
            for tn in (128, 512) if tn <= max(128, _p2(n))
            for tp in (256, 1024, 2048) if tp <= max(256, _p2(p))],
        setup=_agg_setup, run=_agg_run),
    "krum_pairwise": dict(
        candidates=lambda m, p: [
            {"tile": tm, "tile_k": tk}
            for tm in (128, 256) if tm <= max(128, _p2(m))
            for tk in (128, 512, 2048) if tk <= max(128, _p2(p))],
        setup=_krum_setup, run=_krum_run),
}


def default_specs(max_n: int = 1024):
    """The tier sweep the checked-in table covers.  (N, N) kernels are
    interpret-timed up to ``max_n`` on CPU — beyond that the interpreter
    takes minutes per candidate; on real TPU raise ``--max-n``."""
    specs = []
    for n in (128, 256, 512, 1024, 2048, 4096):
        if n <= max_n:
            specs.append(("floyd_warshall", {"n": n}))
            specs.append(("fused_3dg", {"n": n}))
    for n in (1024, 4096, 16384):
        specs.append(("greedy_argmax", {"n": n}))
    for m, n in ((64, 1024), (128, 4096), (512, 16384)):
        specs.append(("swap_gain", {"m": m, "n": n}))
    for n, p in ((256, 1024), (1024, 2048), (4096, 4096)):
        if n * p <= max_n * 4096:
            specs.append(("memory_aggregate", {"n": n, "p": p}))
    for m, p in ((128, 1024), (256, 4096)):
        if m * p <= max_n * 4096:
            specs.append(("krum_pairwise", {"m": m, "p": p}))
    return specs


# ------------------------------------------------------------------ driver
def _time_ms(fn, *, reps: int = 3) -> float:
    jax.block_until_ready(fn())          # compile / first-trace warmup
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best * 1e3


def tune(specs=None, *, timer=None, platform: str | None = None,
         base_table: dict | None = None, verbose: bool = True) -> dict:
    """Time every candidate per (kernel, tier) spec and return the merged
    table.  ``timer`` is injectable (tests pass a stub for determinism);
    the default is best-of-3 wall clock under the live backend."""
    platform = platform or jax.default_backend()
    timer = timer or _time_ms
    mode = "interpret" if platform == "cpu" else "compiled"
    table = dict(base_table if base_table is not None else load_table())
    for kernel, dims in (specs if specs is not None else default_specs()):
        reg = KERNELS[kernel]
        cands = reg["candidates"](**dims)
        inputs = reg["setup"](**dims)
        timed = []
        for tiles in cands:
            try:
                ms = timer(functools.partial(reg["run"], tiles, *inputs))
            except Exception as e:           # candidate invalid on backend
                if verbose:
                    print(f"  skip {kernel} {dims} {tiles}: {e}")
                continue
            timed.append((tiles, ms))
        if not timed:
            continue
        tiles, ms = pick_best(timed)
        key = table_key(kernel, shape_tier(**dims), platform)
        table[key] = {"tiles": tiles, "ms": round(ms, 4), "mode": mode,
                      "candidates": [[t, round(v, 4)] for t, v in timed]}
        if verbose:
            print(f"{key}: {tiles} ({ms:.2f} ms over {len(timed)} candidates)")
    return table


def save_table(table: dict, path=None) -> Path:
    path = Path(path or TABLE_PATH)
    path.write_text(json.dumps(table, indent=2, sort_keys=True) + "\n")
    _load.cache_clear()
    return path


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--max-n", type=int, default=1024,
                    help="largest (N, N) tier to time (interpret mode is "
                         "O(N^3) per candidate)")
    ap.add_argument("--out", type=Path, default=TABLE_PATH)
    args = ap.parse_args()
    t0 = time.perf_counter()
    table = tune(default_specs(args.max_n))
    out = save_table(table, args.out)
    print(f"wrote {len(table)} entries -> {out} "
          f"({time.perf_counter() - t0:.1f}s)")

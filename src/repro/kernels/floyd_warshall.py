"""Blocked Floyd–Warshall APSP — Pallas TPU kernel, fused per-pivot round.

The classic cache-blocked FW (pivot / row panel / col panel / rest phases)
used to be FOUR ``pallas_call``s per pivot block, each re-streaming the
pivot panels from HBM.  This rewrite fuses one full pivot round into a
SINGLE call with a remapped grid: for pivot block ``kb`` the (nb, nb) grid
visits blocks at ``(ri, rj) = ((kb+i) % nb, (kb+j) % nb)``, so step (0,0)
is the pivot tile, row i=0 is the pivot row panel, column j=0 is the pivot
column panel, and everything else is the independent rank-T update.  The
updated pivot row/column panels are carried between steps in two RESIDENT
accumulator outputs (constant ``index_map`` — (T, N) and (N, T) buffers
that stay in VMEM for the whole round, double-buffered against the streamed
(T, T) tiles), so phase-3 steps read their panels via ``pl.ds`` dynamic
slices instead of HBM re-reads.  Every input block is read exactly once per
round and only its own block is rewritten (``input_output_aliases``), which
keeps the in/out pipelining race-free.

min-plus is not an MXU semiring, so the inner update is a VPU
broadcast-min-add.  VMEM per round ≈ 2·T·N·4 B of panels + 3 (T, T) tiles:
T=128 / N=8192 ≈ 8 MiB — inside the 16 MiB/core budget; for N=16384 use
T≤64 or shard the matrix first (DESIGN.md §14).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 128


def _fw_tile(tile, ka: jax.Array | None = None, kb_: jax.Array | None = None):
    """In-tile FW sweep: tile = min(tile, colsrc[:,k] + rowsrc[k,:]) for all k.

    ka (T,Tk) column source, kb_ (Tk,T) row source; None means the tile
    itself (phase-1 self-referential sweep must be sequential)."""
    t = tile.shape[0]

    def body(k, cur):
        col = jax.lax.dynamic_slice_in_dim(cur if ka is None else ka, k, 1, 1)   # (T,1)
        row = jax.lax.dynamic_slice_in_dim(cur if kb_ is None else kb_, k, 1, 0) # (1,T)
        return jnp.minimum(cur, col + row)

    tk = t if ka is None else ka.shape[1]
    return jax.lax.fori_loop(0, tk, body, tile)


def _fw_round_kernel(kb, nb, h_ref, out_ref, rowp_ref, colp_ref):
    """One full pivot round.  Grid (nb, nb); block (i, j) maps to matrix
    block ((kb+i) % nb, (kb+j) % nb).  rowp (T, N) / colp (N, T) are the
    resident pivot row/col panels, indexed by REAL block coordinates."""
    i, j = pl.program_id(0), pl.program_id(1)
    t = out_ref.shape[0]
    cur = h_ref[...]
    pivot_lo = kb * t  # static

    @pl.when((i == 0) & (j == 0))
    def _pivot():
        res = _fw_tile(cur)
        out_ref[...] = res
        rowp_ref[:, pl.ds(pivot_lo, t)] = res
        colp_ref[pl.ds(pivot_lo, t), :] = res

    @pl.when((i == 0) & (j > 0))
    def _row_panel():
        rj = (kb + j) % nb
        res = _fw_tile(cur, ka=rowp_ref[:, pl.ds(pivot_lo, t)], kb_=None)
        out_ref[...] = res
        rowp_ref[:, pl.ds(rj * t, t)] = res

    @pl.when((i > 0) & (j == 0))
    def _col_panel():
        ri = (kb + i) % nb
        res = _fw_tile(cur, ka=None, kb_=colp_ref[pl.ds(pivot_lo, t), :])
        out_ref[...] = res
        colp_ref[pl.ds(ri * t, t), :] = res

    @pl.when((i > 0) & (j > 0))
    def _rest():
        ri = (kb + i) % nb
        rj = (kb + j) % nb
        out_ref[...] = _fw_tile(cur,
                                ka=colp_ref[pl.ds(ri * t, t), :],
                                kb_=rowp_ref[:, pl.ds(rj * t, t)])


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def floyd_warshall_pallas(h: jax.Array, *, tile: int = TILE,
                          interpret: bool = False) -> jax.Array:
    """h (N, N) f32 adjacency (inf = no edge, 0 diag) -> shortest paths."""
    n = h.shape[0]
    assert n % tile == 0, f"pad N={n} to a multiple of {tile}"
    nb = n // tile
    t = tile

    for kb in range(nb):
        remap = lambda i, j, kb=kb: ((kb + i) % nb, (kb + j) % nb)
        h, _, _ = pl.pallas_call(
            functools.partial(_fw_round_kernel, kb, nb),
            grid=(nb, nb),
            in_specs=[pl.BlockSpec((t, t), remap)],
            out_specs=[pl.BlockSpec((t, t), remap),
                       pl.BlockSpec((t, n), lambda i, j: (0, 0)),
                       pl.BlockSpec((n, t), lambda i, j: (0, 0))],
            out_shape=[jax.ShapeDtypeStruct((n, n), jnp.float32),
                       jax.ShapeDtypeStruct((t, n), jnp.float32),
                       jax.ShapeDtypeStruct((n, t), jnp.float32)],
            input_output_aliases={0: 0},
            interpret=interpret,
        )(h)
    return h

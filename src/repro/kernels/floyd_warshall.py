"""Blocked Floyd–Warshall APSP — Pallas TPU kernel.

The classic cache-blocked FW re-tiled for VMEM (DESIGN.md hardware-adaptation
notes): for each pivot block kb (sequential on host),
  phase 1  pivot (kb,kb) block: full FW within the tile,
  phase 2  pivot row & column panels, using the updated pivot tile,
  phase 3  all remaining tiles via a min-plus rank-T update from their
           row/column panels.

min-plus is not an MXU semiring, so the inner update is a VPU
broadcast-min-add; tiles are (T, T) f32 with T=128 (128-lane aligned,
3 tiles live in VMEM during phase 3 ≈ 192 KiB — far under the 16 MiB/core
budget, leaving room for the pipeline's double buffering).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 128


def _fw_tile(tile, ka: jax.Array | None = None, kb_: jax.Array | None = None):
    """In-tile FW sweep: tile = min(tile, colsrc[:,k] + rowsrc[k,:]) for all k.

    ka (T,Tk) column source, kb_ (Tk,T) row source; None means the tile
    itself (phase-1 self-referential sweep must be sequential)."""
    t = tile.shape[0]

    def body(k, cur):
        col = jax.lax.dynamic_slice_in_dim(cur if ka is None else ka, k, 1, 1)   # (T,1)
        row = jax.lax.dynamic_slice_in_dim(cur if kb_ is None else kb_, k, 1, 0) # (1,T)
        return jnp.minimum(cur, col + row)

    tk = t if ka is None else ka.shape[1]
    return jax.lax.fori_loop(0, tk, body, tile)


# --------------------------------------------------------------- kernels
def _phase1_kernel(h_ref, out_ref):
    out_ref[...] = _fw_tile(h_ref[...])


def _phase2_row_kernel(pivot_ref, h_ref, out_ref):
    # row panel: block (kb, j).  col source = pivot, row source = self
    out_ref[...] = _fw_tile(h_ref[...], ka=pivot_ref[...], kb_=None)


def _phase2_col_kernel(pivot_ref, h_ref, out_ref):
    # col panel: block (i, kb). col source = self, row source = pivot
    out_ref[...] = _fw_tile(h_ref[...], ka=None, kb_=pivot_ref[...])


def _phase3_kernel(col_ref, row_ref, h_ref, out_ref):
    # independent rank-T min-plus update
    out_ref[...] = _fw_tile(h_ref[...], ka=col_ref[...], kb_=row_ref[...])


def _call(kernel, n_in, grid, in_specs, out_spec, shape, interpret):
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct(shape, jnp.float32),
        interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def floyd_warshall_pallas(h: jax.Array, *, tile: int = TILE,
                          interpret: bool = False) -> jax.Array:
    """h (N, N) f32 adjacency (inf = no edge, 0 diag) -> shortest paths."""
    n = h.shape[0]
    assert n % tile == 0, f"pad N={n} to a multiple of {tile}"
    nb = n // tile
    t = tile

    spec_pivot = lambda kb: pl.BlockSpec((t, t), lambda *_: (kb, kb))

    for kb in range(nb):
        # ---- phase 1: pivot tile
        h = pl.pallas_call(
            _phase1_kernel,
            grid=(1,),
            in_specs=[pl.BlockSpec((t, t), lambda g, kb=kb: (kb, kb))],
            out_specs=pl.BlockSpec((t, t), lambda g, kb=kb: (kb, kb)),
            out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
            input_output_aliases={0: 0},
            interpret=interpret,
        )(h)
        # ---- phase 2: row panel (kb, j) for all j
        h = pl.pallas_call(
            _phase2_row_kernel,
            grid=(nb,),
            in_specs=[pl.BlockSpec((t, t), lambda j, kb=kb: (kb, kb)),
                      pl.BlockSpec((t, t), lambda j, kb=kb: (kb, j))],
            out_specs=pl.BlockSpec((t, t), lambda j, kb=kb: (kb, j)),
            out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
            input_output_aliases={1: 0},
            interpret=interpret,
        )(h, h)
        # ---- phase 2: col panel (i, kb) for all i
        h = pl.pallas_call(
            _phase2_col_kernel,
            grid=(nb,),
            in_specs=[pl.BlockSpec((t, t), lambda i, kb=kb: (kb, kb)),
                      pl.BlockSpec((t, t), lambda i, kb=kb: (i, kb))],
            out_specs=pl.BlockSpec((t, t), lambda i, kb=kb: (i, kb)),
            out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
            input_output_aliases={1: 0},
            interpret=interpret,
        )(h, h)
        # ---- phase 3: the rest
        h = pl.pallas_call(
            _phase3_kernel,
            grid=(nb, nb),
            in_specs=[pl.BlockSpec((t, t), lambda i, j, kb=kb: (i, kb)),
                      pl.BlockSpec((t, t), lambda i, j, kb=kb: (kb, j)),
                      pl.BlockSpec((t, t), lambda i, j: (i, j))],
            out_specs=pl.BlockSpec((t, t), lambda i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
            input_output_aliases={2: 0},
            interpret=interpret,
        )(h, h, h)
    return h

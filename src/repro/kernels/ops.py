"""jit'd public wrappers around the Pallas kernels.

On CPU (this container) the kernels execute in ``interpret=True`` mode — the
kernel body runs in Python, which validates correctness; on TPU they compile
natively.  Wrappers handle padding to tile multiples and unpadding in-trace,
so the callers (core/graph_device.py's ``backend="pallas"`` dispatch,
models/attention.py) see clean shapes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.aggregate import AGG_TM, AGG_TN, AGG_TP, memagg_pallas
from repro.kernels.floyd_warshall import floyd_warshall_pallas, TILE
from repro.kernels.pairwise_similarity import (
    similarity_pallas, adjacency_pallas, TILE_N, TILE_K,
)
from repro.kernels.solver import (
    NEG, SWAP_TM, SWAP_TN, TILE_Q, TILE_V,
    masked_argmax_pallas, qbuild_pallas, swap_gain_pallas,
)
from repro.kernels.window_attention import window_attention_pallas


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _pad_to(x: np.ndarray | jax.Array, mult: int, axes: tuple[int, ...],
            value: float = 0.0):
    pads = [(0, 0)] * x.ndim
    for ax in axes:
        pads[ax] = (0, (-x.shape[ax]) % mult)
    if all(p == (0, 0) for p in pads):
        return x
    return jnp.pad(x, pads, constant_values=value)


# ------------------------------------------------------------------- APSP
def floyd_warshall(h: jax.Array, *, tile: int = TILE,
                   interpret: bool | None = None) -> jax.Array:
    """All-pairs shortest paths of an (N, N) f32 adjacency (inf = no edge).

    Pads to the tile multiple with inf off-diagonal / 0 diagonal (pad nodes
    are isolated, so true distances are unchanged), runs the blocked Pallas
    FW, and unpads.
    """
    if interpret is None:
        interpret = _on_cpu()
    n = h.shape[0]
    m = ((n + tile - 1) // tile) * tile
    if m != n:
        hp = jnp.full((m, m), jnp.inf, jnp.float32)
        hp = hp.at[:n, :n].set(h.astype(jnp.float32))
        hp = hp.at[jnp.arange(m), jnp.arange(m)].set(0.0)
    else:
        hp = h.astype(jnp.float32)
    out = floyd_warshall_pallas(hp, tile=tile, interpret=interpret)
    return out[:n, :n]


# ------------------------------------------------- similarity -> adjacency
def pairwise_similarity(u: jax.Array, *, interpret: bool | None = None) -> jax.Array:
    """V = U Uᵀ for (N, d) features, tiled on the MXU. Returns (N, N) f32."""
    if interpret is None:
        interpret = _on_cpu()
    n, d = u.shape
    up = _pad_to(u.astype(jnp.float32), TILE_N, (0,))
    up = _pad_to(up, TILE_K, (1,))
    v = similarity_pallas(up, interpret=interpret)
    return v[:n, :n]


def similarity_to_adjacency(v: jax.Array, *, eps: float, sigma2: float,
                            interpret: bool | None = None) -> jax.Array:
    """Fused min-max-normalize -> threshold -> exp(-V/σ²) epilogue.

    lo/hi are reduced from the raw UNPADDED v before padding, so zero-filled
    pad tiles never skew the normalization; pad rows/cols are sliced off
    before returning.
    """
    if interpret is None:
        interpret = _on_cpu()
    n = v.shape[0]
    lo = jnp.min(v)
    hi = jnp.max(v)
    vp = _pad_to(v.astype(jnp.float32), TILE_N, (0, 1))
    scal = jnp.stack([lo, hi, jnp.float32(eps), jnp.float32(sigma2)]).reshape(1, 4)
    r = adjacency_pallas(vp, scal, interpret=interpret)
    return r[:n, :n]


def build_3dg_kernel(u: jax.Array, *, eps: float = 0.1, sigma2: float = 0.01,
                     interpret: bool | None = None):
    """Full fused path: features -> V -> R -> H, all on-kernel. Returns (V, R, H)."""
    v = pairwise_similarity(u, interpret=interpret)
    r = similarity_to_adjacency(v, eps=eps, sigma2=sigma2, interpret=interpret)
    h = floyd_warshall(r, interpret=interpret)
    return v, r, h


# ------------------------------------------------------------ FedGS solver
def solver_q_build(h: jax.Array, z: jax.Array, scale: jax.Array, *,
                   interpret: bool | None = None) -> jax.Array:
    """Fused Eq. 14/16 Q construction: ``sym(scale · H) − diag(z)`` for
    (N, N) H and (N,) z, tiled so the symmetrization temporaries never
    materialize.  Zero padding is exact (pad Q entries are 0, sliced off)."""
    if interpret is None:
        interpret = _on_cpu()
    n = h.shape[0]
    hp = _pad_to(h.astype(jnp.float32), TILE_Q, (0, 1))
    zp = _pad_to(z.astype(jnp.float32).reshape(1, n), TILE_Q, (1,))
    scal = jnp.asarray(scale, jnp.float32).reshape(1, 1)
    q = qbuild_pallas(hp, zp, scal, interpret=interpret)
    return q[:n, :n]


def greedy_argmax(diag: jax.Array, r: jax.Array, mask: jax.Array, *,
                  interpret: bool | None = None):
    """Blocked masked argmax of the greedy gain ``diag + 2r`` over (N,)
    vectors (mask True = addable).  Pads with mask False, so pad lanes carry
    the −1e18 sentinel and can only win when EVERY entry is masked — in
    which case the ref path's argmax also returns 0.  Returns scalar
    (best gain, index)."""
    if interpret is None:
        interpret = _on_cpu()
    n = diag.shape[0]
    d = _pad_to(diag.astype(jnp.float32).reshape(1, n), TILE_V, (1,))
    rr = _pad_to(r.astype(jnp.float32).reshape(1, n), TILE_V, (1,))
    mk = _pad_to(mask.astype(jnp.float32).reshape(1, n), TILE_V, (1,))
    val, idx = masked_argmax_pallas(d, rr, mk, interpret=interpret)
    return val[0, 0], idx[0, 0]


def swap_best(qs: jax.Array, a: jax.Array, b: jax.Array, *,
              interpret: bool | None = None):
    """Best-swap gain over the (M, N) selected-row panel.

    qs = gathered selected rows of Q, a (M,) out-gain terms, b (N,) in-gain
    terms (both already carry the −1e18 sentinel on invalid entries).  Pads
    a/b with the sentinel and qs with 0, so pad cells sit at ≈ −2e18 and
    never beat a real candidate.  Tile sizes scale with the panel — up to
    (512, 4096) = 8 MiB f32, still under the VMEM budget — so the grid
    stays small at datacenter N (every grid step re-touches the carried
    panel in interpret mode, and on TPU fewer/larger DMAs pipeline
    better); the reduction is tile-size-invariant (global-flat-index
    tie-break), so this never changes the selected swap.  Returns scalar
    (best delta, panel rank, column j)."""
    if interpret is None:
        interpret = _on_cpu()
    m, n = qs.shape
    tm = 512 if m >= 512 else SWAP_TM
    tn = 4096 if n >= 4096 else SWAP_TN
    qp = _pad_to(qs.astype(jnp.float32), tm, (0,))
    qp = _pad_to(qp, tn, (1,))
    ap = _pad_to(a.astype(jnp.float32).reshape(m, 1), tm, (0,), value=NEG)
    bp = _pad_to(b.astype(jnp.float32).reshape(1, n), tn, (1,), value=NEG)
    val, flat = swap_gain_pallas(qp, ap, bp, tile_m=tm, tile_n=tn,
                                 interpret=interpret)
    npad = qp.shape[1]
    return val[0, 0], flat[0, 0] // npad, flat[0, 0] % npad


# ------------------------------------------------- memory-rectified reduce
def memory_aggregate(mem: jax.Array, upd: jax.Array, sel: jax.Array,
                     valid: jax.Array, w: jax.Array, *,
                     interpret: bool | None = None):
    """Fused masked scatter + staleness-weighted reduction over the (N, P)
    update-memory panel (the ``memory`` aggregator family's hot path).

    mem (N, P) panel, upd (M, P) flattened sampled updates, sel (M,) int
    target rows with ``valid`` (M,) masking pad slots, w (N,) reduction
    weights (already normalized by the caller).  Pads: invalid slots become
    the −1 sentinel row id (matches no row), the panel pads to tile
    multiples with zero rows/cols and w pads with 0, so pad rows never
    contribute to the reduction and pad cols are sliced off.  Panel tiles
    scale up to (512, 2048) and the update matrix is chunked at 256 rows
    (m scales with N — an untiled (M, Tp) block would blow VMEM at
    datacenter m; worst case ≈ 10.5 MiB, see kernels/aggregate.py) while
    keeping the grid SMALL (each interpret grid step re-writes the carried
    (N, P) output, and on TPU fewer/larger DMAs pipeline better).  Returns
    ``(new_mem (N, P), reduced (P,))``; new_mem is bit-identical to the jnp
    scatter, reduced is numerically equal to the ref tensordot (tile-order
    partial sums)."""
    if interpret is None:
        interpret = _on_cpu()
    n, p = mem.shape
    m = upd.shape[0]
    tn = 512 if n >= 512 else AGG_TN
    tp = 2048 if p >= 2048 else AGG_TP
    memp = _pad_to(mem.astype(jnp.float32), tn, (0,))
    memp = _pad_to(memp, tp, (1,))
    # update chunking: one sub-tile chunk for small m, AGG_TM rows at scale
    tm = max(8, ((min(m, AGG_TM) + 7) // 8) * 8)
    mp = ((max(m, 1) + tm - 1) // tm) * tm
    updp = jnp.zeros((mp, memp.shape[1]), jnp.float32)
    if m:
        updp = updp.at[:m, :p].set(upd.astype(jnp.float32))
    selp = jnp.full((1, mp), -1.0, jnp.float32)
    if m:
        selp = selp.at[0, :m].set(
            jnp.where(valid, sel.astype(jnp.float32), -1.0))
    wp = _pad_to(w.astype(jnp.float32).reshape(1, n), tn, (1,))
    newmem, red = memagg_pallas(memp, updp, selp, wp, tile_n=tn, tile_p=tp,
                                tile_m=tm, interpret=interpret)
    return newmem[:n, :p], red[0, :p]


# -------------------------------------------------------- window attention
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    interpret: bool | None = None) -> jax.Array:
    """Full-causal flash attention: the sliding-window kernel with
    window = S covers every past position, so the same VMEM-tiled online
    softmax serves the train-side hot spot (EXPERIMENTS §Perf C)."""
    return window_attention(q, k, v, window=q.shape[1], interpret=interpret)


def window_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                     window: int, interpret: bool | None = None) -> jax.Array:
    """Flash sliding-window attention (B, S, H, D). S padded to 128 internally."""
    if interpret is None:
        interpret = _on_cpu()
    b, s, h, d = q.shape
    bq = min(128, s) if s % 128 else 128
    sp = ((s + bq - 1) // bq) * bq
    if sp != s:
        qp = _pad_to(q, bq, (1,))
        kp = _pad_to(k, bq, (1,))
        vp = _pad_to(v, bq, (1,))
    else:
        qp, kp, vp = q, k, v
    out = window_attention_pallas(qp, kp, vp, window=window, bq=bq,
                                  bk=bq, interpret=interpret)
    return out[:, :s]

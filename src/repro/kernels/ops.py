"""jit'd public wrappers around the Pallas kernels.

On CPU (this container) the kernels execute in ``interpret=True`` mode — the
kernel body runs in Python, which validates correctness; on TPU they compile
natively.  Wrappers handle padding to tile multiples and unpadding in-trace,
so the callers (core/graph_device.py's ``backend="pallas"`` dispatch,
models/attention.py) see clean shapes.

Every wrapper takes ``tile="auto"`` (the default): tiles resolve through
``kernels/autotune.resolve`` — the tuned winner for the (kernel, pow2
shape tier, platform) key in the checked-in ``kernels/tuned_tiles.json``
if present, else the per-kernel heuristic default.  Shapes are static at
trace time, so engines tracing cells of different N automatically pick the
tuned tiles of each cell's tier.  Pass an int to pin a tile explicitly
(the autotuner itself does, when timing candidates).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.aggregate import AGG_TM, AGG_TN, AGG_TP, memagg_pallas
from repro.kernels.autotune import resolve
from repro.kernels.floyd_warshall import floyd_warshall_pallas, TILE
from repro.kernels.graph_fused import fused_adjacency_pallas, FUSED_TILE
from repro.kernels.krum import krum_pallas, KRUM_TM, KRUM_TK
from repro.kernels.pairwise_similarity import (
    similarity_pallas, adjacency_pallas, TILE_N, TILE_K,
)
from repro.kernels.solver import (
    NEG, SWAP_TM, SWAP_TN, TILE_V,
    masked_argmax_pallas, swap_gain_fused_pallas, swap_gain_pallas,
)
from repro.kernels.window_attention import window_attention_pallas


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _pad_to(x: np.ndarray | jax.Array, mult: int, axes: tuple[int, ...],
            value: float = 0.0):
    pads = [(0, 0)] * x.ndim
    for ax in axes:
        pads[ax] = (0, (-x.shape[ax]) % mult)
    if all(p == (0, 0) for p in pads):
        return x
    return jnp.pad(x, pads, constant_values=value)


def _tiles(kernel: str, defaults: dict, overrides: dict, **dims) -> dict:
    """``tile="auto"`` resolution: tuned table -> heuristic defaults, then
    explicit int overrides win unconditionally."""
    res = resolve(kernel, defaults, **dims)
    for k, v in overrides.items():
        if v is not None and v != "auto":
            res[k] = int(v)
    return res


# ------------------------------------------------------------------- APSP
def floyd_warshall(h: jax.Array, *, tile: int | str = "auto",
                   interpret: bool | None = None) -> jax.Array:
    """All-pairs shortest paths of an (N, N) f32 adjacency (inf = no edge).

    Pads to the tile multiple with inf off-diagonal / 0 diagonal (pad nodes
    are isolated, so true distances are unchanged), runs the blocked Pallas
    FW, and unpads.
    """
    if interpret is None:
        interpret = _on_cpu()
    n = h.shape[0]
    t = _tiles("floyd_warshall", {"tile": TILE}, {"tile": tile}, n=n)["tile"]
    m = ((n + t - 1) // t) * t
    if m != n:
        hp = jnp.full((m, m), jnp.inf, jnp.float32)
        hp = hp.at[:n, :n].set(h.astype(jnp.float32))
        hp = hp.at[jnp.arange(m), jnp.arange(m)].set(0.0)
    else:
        hp = h.astype(jnp.float32)
    out = floyd_warshall_pallas(hp, tile=t, interpret=interpret)
    return out[:n, :n]


# ------------------------------------------------- similarity -> adjacency
def pairwise_similarity(u: jax.Array, *, tile: int | str = "auto",
                        interpret: bool | None = None) -> jax.Array:
    """V = U Uᵀ for (N, d) features, tiled on the MXU. Returns (N, N) f32."""
    if interpret is None:
        interpret = _on_cpu()
    n, d = u.shape
    t = _tiles("pairwise_similarity", {"tile": TILE_N}, {"tile": tile},
               n=n)["tile"]
    up = _pad_to(u.astype(jnp.float32), t, (0,))
    up = _pad_to(up, TILE_K, (1,))
    v = similarity_pallas(up, tile_n=t, interpret=interpret)
    return v[:n, :n]


def similarity_to_adjacency(v: jax.Array, *, eps: float, sigma2: float,
                            tile: int | str = "auto",
                            interpret: bool | None = None) -> jax.Array:
    """Fused min-max-normalize -> threshold -> exp(-V/σ²) epilogue.

    lo/hi are reduced from the raw UNPADDED v before padding, so zero-filled
    pad tiles never skew the normalization; pad rows/cols are sliced off
    before returning.
    """
    if interpret is None:
        interpret = _on_cpu()
    n = v.shape[0]
    t = _tiles("pairwise_similarity", {"tile": TILE_N}, {"tile": tile},
               n=n)["tile"]
    lo = jnp.min(v)
    hi = jnp.max(v)
    vp = _pad_to(v.astype(jnp.float32), t, (0, 1))
    scal = jnp.stack([lo, hi, jnp.float32(eps), jnp.float32(sigma2)]).reshape(1, 4)
    r = adjacency_pallas(vp, scal, tile_n=t, interpret=interpret)
    return r[:n, :n]


def build_3dg_kernel(u: jax.Array, *, eps: float = 0.1, sigma2: float = 0.01,
                     interpret: bool | None = None):
    """STAGED kernel path: features -> V -> R -> H, one pallas call per
    stage (V and R round-trip HBM — kept as the parity oracle for the fused
    megakernel below and for callers that need V).  Returns (V, R, H)."""
    v = pairwise_similarity(u, interpret=interpret)
    r = similarity_to_adjacency(v, eps=eps, sigma2=sigma2, interpret=interpret)
    h = floyd_warshall(r, interpret=interpret)
    return v, r, h


def fused_adjacency(u: jax.Array, *, eps: float, sigma2: float,
                    clamp: bool = False, tile: int | str = "auto",
                    pad_mult: int | None = None,
                    interpret: bool | None = None,
                    keep_pad: bool = False) -> jax.Array:
    """Fused 3DG megakernel: similarity -> min-max stats -> adjacency in ONE
    Pallas grid (``kernels/graph_fused.py``) — V never exists in HBM.

    u (N, d) features (row-normalize beforehand for cosine; ``clamp`` adds
    the Eq. 11/12 ``max(·, 0)``).  With ``keep_pad`` the padded FW-ready
    (M, M) adjacency is returned (pad nodes isolated: 0 diagonal, inf
    off-diagonal) — ``pad_mult`` forces M to a multiple of a downstream
    tile so the APSP consumes it with no unpad/re-pad round-trip."""
    if interpret is None:
        interpret = _on_cpu()
    n, d = u.shape
    t = _tiles("fused_3dg", {"tile": FUSED_TILE}, {"tile": tile}, n=n)["tile"]
    mult = t if pad_mult is None else max(t, pad_mult)   # both pow2
    up = _pad_to(u.astype(jnp.float32), mult, (0,))
    up = _pad_to(up, 128, (1,))
    scal = jnp.asarray([eps, sigma2], jnp.float32).reshape(1, 2)
    r, _ = fused_adjacency_pallas(up, scal, n=n, clamp=clamp, tile_n=t,
                                  interpret=interpret)
    return r if keep_pad else r[:n, :n]


def build_3dg_fused(u: jax.Array, *, eps: float = 0.1, sigma2: float = 0.01,
                    clamp: bool = False, tile: int | str = "auto",
                    fw_tile: int | str = "auto",
                    interpret: bool | None = None):
    """FUSED 3DG pipeline: the similarity→normalize→adjacency megakernel
    chained straight into the blocked Floyd–Warshall at a shared padded
    size — R round-trips HBM exactly once between the two kernels and the
    staged path's unpad/re-pad disappears.  Returns (R (N, N), H_raw
    (N, N)); finite entries are bit-identical to the staged pallas path
    (pinned by tests/test_kernels.py)."""
    if interpret is None:
        interpret = _on_cpu()
    n = u.shape[0]
    ft = _tiles("floyd_warshall", {"tile": TILE}, {"tile": fw_tile},
                n=n)["tile"]
    rp = fused_adjacency(u, eps=eps, sigma2=sigma2, clamp=clamp, tile=tile,
                         pad_mult=ft, interpret=interpret, keep_pad=True)
    hp = floyd_warshall_pallas(rp, tile=ft, interpret=interpret)
    return rp[:n, :n], hp[:n, :n]


# ------------------------------------------------------------ FedGS solver
def greedy_argmax(diag: jax.Array, r: jax.Array, mask: jax.Array, *,
                  tile: int | str = "auto", interpret: bool | None = None):
    """Blocked masked argmax of the greedy gain ``diag + 2r`` over (N,)
    vectors (mask True = addable).  Pads with mask False, so pad lanes carry
    the −1e18 sentinel and can only win when EVERY entry is masked — in
    which case the ref path's argmax also returns 0.  Returns scalar
    (best gain, index)."""
    if interpret is None:
        interpret = _on_cpu()
    n = diag.shape[0]
    t = _tiles("greedy_argmax", {"tile": TILE_V}, {"tile": tile}, n=n)["tile"]
    d = _pad_to(diag.astype(jnp.float32).reshape(1, n), t, (1,))
    rr = _pad_to(r.astype(jnp.float32).reshape(1, n), t, (1,))
    mk = _pad_to(mask.astype(jnp.float32).reshape(1, n), t, (1,))
    val, idx = masked_argmax_pallas(d, rr, mk, tile=t, interpret=interpret)
    return val[0, 0], idx[0, 0]


def _swap_tiles(m: int, n: int, tile_m, tile_n) -> tuple[int, int]:
    # heuristic fallback: tiles scale with the panel — up to (512, 4096) =
    # 8 MiB f32, still under the VMEM budget — so the grid stays small at
    # datacenter N (every grid step re-touches the carried accumulators in
    # interpret mode, and on TPU fewer/larger DMAs pipeline better); the
    # reduction is tile-size-invariant (global-flat-index tie-break), so
    # tile choice never changes the selected swap.
    t = _tiles("swap_gain",
               {"tile_m": 512 if m >= 512 else SWAP_TM,
                "tile_n": 4096 if n >= 4096 else SWAP_TN},
               {"tile_m": tile_m, "tile_n": tile_n}, m=m, n=n)
    return t["tile_m"], t["tile_n"]


def swap_best(qs: jax.Array, a: jax.Array, b: jax.Array, *,
              tile_m: int | str = "auto", tile_n: int | str = "auto",
              interpret: bool | None = None):
    """Best-swap gain over a MATERIALIZED (M, N) selected-row panel.

    qs = gathered selected rows of Q, a (M,) out-gain terms, b (N,) in-gain
    terms (both already carry the −1e18 sentinel on invalid entries).  Pads
    a/b with the sentinel and qs with 0, so pad cells sit at ≈ −2e18 and
    never beat a real candidate.  Returns scalar (best delta, panel rank,
    column j)."""
    if interpret is None:
        interpret = _on_cpu()
    m, n = qs.shape
    tm, tn = _swap_tiles(m, n, tile_m, tile_n)
    qp = _pad_to(qs.astype(jnp.float32), tm, (0,))
    qp = _pad_to(qp, tn, (1,))
    ap = _pad_to(a.astype(jnp.float32).reshape(m, 1), tm, (0,), value=NEG)
    bp = _pad_to(b.astype(jnp.float32).reshape(1, n), tn, (1,), value=NEG)
    val, flat = swap_gain_pallas(qp, ap, bp, tile_m=tm, tile_n=tn,
                                 interpret=interpret)
    npad = qp.shape[1]
    return val[0, 0], flat[0, 0] // npad, flat[0, 0] % npad


def swap_best_fused(h: jax.Array, z: jax.Array, scale: jax.Array,
                    sel: jax.Array, valid: jax.Array, a: jax.Array,
                    b: jax.Array, *, tile_m: int | str = "auto",
                    tile_n: int | str = "auto",
                    interpret: bool | None = None):
    """Q-FREE best-swap: the kernel rebuilds Q tiles in VREGs from the H
    panels of the selected rows (``kernels/solver.swap_gain_fused_pallas``)
    — neither an (N, N) Q nor an (M, N) Q panel ever exists in HBM.

    h (N, N), z (N,), scale = alpha/N, sel (M,) global row indices already
    clamped into range, valid (M,) marking real (non-pad) rows, a (M,) /
    b (N,) out/in-gain terms carrying the −1e18 sentinel on invalid
    entries.  Bit-identical winners vs :func:`swap_best` on a materialized
    panel (same op order in-kernel; pinned by tests).  Returns scalar
    (best delta, panel rank, column j)."""
    if interpret is None:
        interpret = _on_cpu()
    n = h.shape[0]
    m = sel.shape[0]
    tm, tn = _swap_tiles(m, n, tile_m, tile_n)
    hs = jnp.take(h, sel, axis=0).astype(jnp.float32)        # (M, N)
    hts = jnp.take(h, sel, axis=1).T.astype(jnp.float32)     # (M, N)
    zsel = jnp.where(valid, z[sel], 0.0).astype(jnp.float32)
    selcol = jnp.where(valid, sel, -1).astype(jnp.int32)     # -1: no δ match
    hsp = _pad_to(hs, tm, (0,))
    hsp = _pad_to(hsp, tn, (1,))
    htsp = _pad_to(hts, tm, (0,))
    htsp = _pad_to(htsp, tn, (1,))
    ap = _pad_to(a.astype(jnp.float32).reshape(m, 1), tm, (0,), value=NEG)
    bp = _pad_to(b.astype(jnp.float32).reshape(1, n), tn, (1,), value=NEG)
    selp = _pad_to(selcol.reshape(m, 1), tm, (0,), value=-1)
    zp = _pad_to(zsel.reshape(m, 1), tm, (0,))
    scal = jnp.asarray(scale, jnp.float32).reshape(1, 1)
    val, flat = swap_gain_fused_pallas(hsp, htsp, ap, bp, selp, zp, scal,
                                       tile_m=tm, tile_n=tn,
                                       interpret=interpret)
    npad = hsp.shape[1]
    return val[0, 0], flat[0, 0] // npad, flat[0, 0] % npad


# -------------------------------------------------- Krum pairwise distances
def krum_distances(x: jax.Array, *, tile: int | str = "auto",
                   tile_k: int | str = "auto",
                   interpret: bool | None = None) -> jax.Array:
    """Pairwise squared-distance panel D[i, j] = ||x_i − x_j||² over the
    (m, P) flattened update matrix — the Krum score's hot inner loop
    (``kernels/krum.py``).  Zero-pads m and P to tile multiples (zero P
    columns contribute 0 to every distance; pad-row entries are sliced
    off), so callers see clean (m, m).  The expansion can go slightly
    negative / asymmetric at f32 roundoff for near-identical rows — the
    aggregator's shared post-process clamps at 0, both backends alike."""
    if interpret is None:
        interpret = _on_cpu()
    m, p = x.shape
    t = _tiles("krum_pairwise", {"tile": KRUM_TM, "tile_k": KRUM_TK},
               {"tile": tile, "tile_k": tile_k}, m=m, p=p)
    xp = _pad_to(x.astype(jnp.float32), t["tile"], (0,))
    xp = _pad_to(xp, t["tile_k"], (1,))
    d = krum_pallas(xp, tile_m=t["tile"], tile_k=t["tile_k"],
                    interpret=interpret)
    return d[:m, :m]


# ------------------------------------------------- memory-rectified reduce
def memory_aggregate(mem: jax.Array, upd: jax.Array, sel: jax.Array,
                     valid: jax.Array, w: jax.Array, *,
                     tile_n: int | str = "auto", tile_p: int | str = "auto",
                     tile_m: int | str = "auto",
                     interpret: bool | None = None):
    """Fused masked scatter + staleness-weighted reduction over the (N, P)
    update-memory panel (the ``memory`` aggregator family's hot path).

    mem (N, P) panel, upd (M, P) flattened sampled updates, sel (M,) int
    target rows with ``valid`` (M,) masking pad slots, w (N,) reduction
    weights (already normalized by the caller).  Pads: invalid slots become
    the −1 sentinel row id (matches no row), the panel pads to tile
    multiples with zero rows/cols and w pads with 0, so pad rows never
    contribute to the reduction and pad cols are sliced off.  Heuristic
    panel tiles scale up to (512, 2048) and the update matrix is chunked at
    256 rows (m scales with N — an untiled (M, Tp) block would blow VMEM at
    datacenter m; worst case ≈ 10.5 MiB, see kernels/aggregate.py) while
    keeping the grid SMALL (each interpret grid step re-writes the carried
    (N, P) output, and on TPU fewer/larger DMAs pipeline better).  Returns
    ``(new_mem (N, P), reduced (P,))``; new_mem is bit-identical to the jnp
    scatter, reduced is numerically equal to the ref tensordot (tile-order
    partial sums)."""
    if interpret is None:
        interpret = _on_cpu()
    n, p = mem.shape
    m = upd.shape[0]
    t = _tiles("memory_aggregate",
               {"tile_n": 512 if n >= 512 else AGG_TN,
                "tile_p": 2048 if p >= 2048 else AGG_TP},
               {"tile_n": tile_n, "tile_p": tile_p}, n=n, p=p)
    tn, tp = t["tile_n"], t["tile_p"]
    memp = _pad_to(mem.astype(jnp.float32), tn, (0,))
    memp = _pad_to(memp, tp, (1,))
    # update chunking: one sub-tile chunk for small m, AGG_TM rows at scale
    if tile_m == "auto" or tile_m is None:
        tm = max(8, ((min(m, AGG_TM) + 7) // 8) * 8)
    else:
        tm = int(tile_m)
    mp = ((max(m, 1) + tm - 1) // tm) * tm
    updp = jnp.zeros((mp, memp.shape[1]), jnp.float32)
    if m:
        updp = updp.at[:m, :p].set(upd.astype(jnp.float32))
    selp = jnp.full((1, mp), -1.0, jnp.float32)
    if m:
        selp = selp.at[0, :m].set(
            jnp.where(valid, sel.astype(jnp.float32), -1.0))
    wp = _pad_to(w.astype(jnp.float32).reshape(1, n), tn, (1,))
    newmem, red = memagg_pallas(memp, updp, selp, wp, tile_n=tn, tile_p=tp,
                                tile_m=tm, interpret=interpret)
    return newmem[:n, :p], red[0, :p]


# -------------------------------------------------------- window attention
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    interpret: bool | None = None) -> jax.Array:
    """Full-causal flash attention: the sliding-window kernel with
    window = S covers every past position, so the same VMEM-tiled online
    softmax serves the train-side hot spot (EXPERIMENTS §Perf C)."""
    return window_attention(q, k, v, window=q.shape[1], interpret=interpret)


def window_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                     window: int, tile: int | str = "auto",
                     interpret: bool | None = None) -> jax.Array:
    """Flash sliding-window attention (B, S, H, D). S padded to the query
    block internally."""
    if interpret is None:
        interpret = _on_cpu()
    b, s, h, d = q.shape
    bq = _tiles("window_attention",
                {"bq": min(128, s) if s % 128 else 128},
                {"bq": tile}, s=s)["bq"]
    sp = ((s + bq - 1) // bq) * bq
    if sp != s:
        qp = _pad_to(q, bq, (1,))
        kp = _pad_to(k, bq, (1,))
        vp = _pad_to(v, bq, (1,))
    else:
        qp, kp, vp = q, k, v
    out = window_attention_pallas(qp, kp, vp, window=window, bq=bq,
                                  bk=bq, interpret=interpret)
    return out[:, :s]

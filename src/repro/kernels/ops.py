"""jit'd public wrappers around the Pallas kernels.

On CPU (this container) the kernels execute in ``interpret=True`` mode — the
kernel body runs in Python, which validates correctness; on TPU they compile
natively.  Wrappers handle padding to tile multiples and unpadding in-trace,
so the callers (core/graph_device.py's ``backend="pallas"`` dispatch,
models/attention.py) see clean shapes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.floyd_warshall import floyd_warshall_pallas, TILE
from repro.kernels.pairwise_similarity import (
    similarity_pallas, adjacency_pallas, TILE_N, TILE_K,
)
from repro.kernels.window_attention import window_attention_pallas


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _pad_to(x: np.ndarray | jax.Array, mult: int, axes: tuple[int, ...],
            value: float = 0.0):
    pads = [(0, 0)] * x.ndim
    for ax in axes:
        pads[ax] = (0, (-x.shape[ax]) % mult)
    if all(p == (0, 0) for p in pads):
        return x
    return jnp.pad(x, pads, constant_values=value)


# ------------------------------------------------------------------- APSP
def floyd_warshall(h: jax.Array, *, tile: int = TILE,
                   interpret: bool | None = None) -> jax.Array:
    """All-pairs shortest paths of an (N, N) f32 adjacency (inf = no edge).

    Pads to the tile multiple with inf off-diagonal / 0 diagonal (pad nodes
    are isolated, so true distances are unchanged), runs the blocked Pallas
    FW, and unpads.
    """
    if interpret is None:
        interpret = _on_cpu()
    n = h.shape[0]
    m = ((n + tile - 1) // tile) * tile
    if m != n:
        hp = jnp.full((m, m), jnp.inf, jnp.float32)
        hp = hp.at[:n, :n].set(h.astype(jnp.float32))
        hp = hp.at[jnp.arange(m), jnp.arange(m)].set(0.0)
    else:
        hp = h.astype(jnp.float32)
    out = floyd_warshall_pallas(hp, tile=tile, interpret=interpret)
    return out[:n, :n]


# ------------------------------------------------- similarity -> adjacency
def pairwise_similarity(u: jax.Array, *, interpret: bool | None = None) -> jax.Array:
    """V = U Uᵀ for (N, d) features, tiled on the MXU. Returns (N, N) f32."""
    if interpret is None:
        interpret = _on_cpu()
    n, d = u.shape
    up = _pad_to(u.astype(jnp.float32), TILE_N, (0,))
    up = _pad_to(up, TILE_K, (1,))
    v = similarity_pallas(up, interpret=interpret)
    return v[:n, :n]


def similarity_to_adjacency(v: jax.Array, *, eps: float, sigma2: float,
                            interpret: bool | None = None) -> jax.Array:
    """Fused min-max-normalize -> threshold -> exp(-V/σ²) epilogue.

    lo/hi are reduced from the raw UNPADDED v before padding, so zero-filled
    pad tiles never skew the normalization; pad rows/cols are sliced off
    before returning.
    """
    if interpret is None:
        interpret = _on_cpu()
    n = v.shape[0]
    lo = jnp.min(v)
    hi = jnp.max(v)
    vp = _pad_to(v.astype(jnp.float32), TILE_N, (0, 1))
    scal = jnp.stack([lo, hi, jnp.float32(eps), jnp.float32(sigma2)]).reshape(1, 4)
    r = adjacency_pallas(vp, scal, interpret=interpret)
    return r[:n, :n]


def build_3dg_kernel(u: jax.Array, *, eps: float = 0.1, sigma2: float = 0.01,
                     interpret: bool | None = None):
    """Full fused path: features -> V -> R -> H, all on-kernel. Returns (V, R, H)."""
    v = pairwise_similarity(u, interpret=interpret)
    r = similarity_to_adjacency(v, eps=eps, sigma2=sigma2, interpret=interpret)
    h = floyd_warshall(r, interpret=interpret)
    return v, r, h


# -------------------------------------------------------- window attention
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    interpret: bool | None = None) -> jax.Array:
    """Full-causal flash attention: the sliding-window kernel with
    window = S covers every past position, so the same VMEM-tiled online
    softmax serves the train-side hot spot (EXPERIMENTS §Perf C)."""
    return window_attention(q, k, v, window=q.shape[1], interpret=interpret)


def window_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                     window: int, interpret: bool | None = None) -> jax.Array:
    """Flash sliding-window attention (B, S, H, D). S padded to 128 internally."""
    if interpret is None:
        interpret = _on_cpu()
    b, s, h, d = q.shape
    bq = min(128, s) if s % 128 else 128
    sp = ((s + bq - 1) // bq) * bq
    if sp != s:
        qp = _pad_to(q, bq, (1,))
        kp = _pad_to(k, bq, (1,))
        vp = _pad_to(v, bq, (1,))
    else:
        qp, kp, vp = q, k, v
    out = window_attention_pallas(qp, kp, vp, window=window, bq=bq,
                                  bk=bq, interpret=interpret)
    return out[:, :s]

"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def floyd_warshall_ref(h: jax.Array) -> jax.Array:
    """APSP min-plus closure. h (N, N) f32, inf = no edge, diag 0."""
    n = h.shape[0]

    def body(k, h):
        col = jax.lax.dynamic_slice_in_dim(h, k, 1, axis=1)   # (N, 1)
        row = jax.lax.dynamic_slice_in_dim(h, k, 1, axis=0)   # (1, N)
        return jnp.minimum(h, col + row)

    return jax.lax.fori_loop(0, n, body, h)


def similarity_ref(u: jax.Array) -> jax.Array:
    """Raw dot-product similarity V = U U^T.  u (N, d) f32."""
    return u @ u.T


# The adjacency oracle lives in ``core.graph_device`` (``minmax01`` +
# ``to_adjacency``) — the ONE normalize/threshold/exp implementation every
# layer shares; keeping a second copy here caused the inf·0 -> NaN diagonal
# hazard the graph_device regression tests pin.


def window_attention_ref(q, k, v, *, window: int) -> jax.Array:
    """Causal sliding-window attention. q/k/v (B, S, H, D); fp32 softmax."""
    b, s, h, d = q.shape
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) / np.sqrt(d)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = (kpos <= qpos) & (kpos > qpos - window)
    scores = jnp.where(mask[None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)

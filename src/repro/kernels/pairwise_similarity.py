"""Fused pairwise-similarity -> 3DG-adjacency Pallas kernels.

``similarity``: tiled U·Uᵀ on the MXU (f32 accumulate), grid (N/T, N/T, d/Tk)
with a revisiting accumulator — the standard TPU matmul pattern.

``adjacency``: elementwise epilogue V -> R (min-max normalize with
host-provided lo/hi scalars, threshold eps, exp(-V/sigma2), inf for no-edge,
zero diagonal) fused in VREGs so V never round-trips HBM twice.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_N = 128
TILE_K = 128


def _sim_kernel(u_ref, ut_ref, out_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jax.lax.dot_general(
        u_ref[...], ut_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("tile_n", "tile_k", "interpret"))
def similarity_pallas(u: jax.Array, *, tile_n: int = TILE_N,
                      tile_k: int = TILE_K, interpret: bool = False) -> jax.Array:
    """u (N, d) f32 -> V = U U^T (N, N) f32. N, d padded to tile multiples."""
    n, d = u.shape
    assert n % tile_n == 0 and d % tile_k == 0, (n, d)
    ut = u.T.copy()
    grid = (n // tile_n, n // tile_n, d // tile_k)
    return pl.pallas_call(
        _sim_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((tile_n, tile_k), lambda i, j, k: (i, k)),
                  pl.BlockSpec((tile_k, tile_n), lambda i, j, k: (k, j))],
        out_specs=pl.BlockSpec((tile_n, tile_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=interpret,
    )(u, ut)


def _adj_kernel(v_ref, scal_ref, out_ref):
    lo, hi, eps, sigma2 = (scal_ref[0, 0], scal_ref[0, 1],
                           scal_ref[0, 2], scal_ref[0, 3])
    v = (v_ref[...] - lo) / jnp.maximum(hi - lo, 1e-12)
    r = jnp.where(v >= eps, jnp.exp(-v / sigma2), jnp.inf)
    i, j = pl.program_id(0), pl.program_id(1)
    t = out_ref.shape[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (t, t), 0) + i * t
    cols = jax.lax.broadcasted_iota(jnp.int32, (t, t), 1) + j * t
    out_ref[...] = jnp.where(rows == cols, 0.0, r)


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def adjacency_pallas(v: jax.Array, scalars: jax.Array, *, tile_n: int = TILE_N,
                     interpret: bool = False) -> jax.Array:
    """v (N,N) raw similarity; scalars = [lo, hi, eps, sigma2] f32 (shape (1,4))."""
    n = v.shape[0]
    assert n % tile_n == 0
    grid = (n // tile_n, n // tile_n)
    scalars = scalars.reshape(1, 4).astype(jnp.float32)
    return pl.pallas_call(
        _adj_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((tile_n, tile_n), lambda i, j: (i, j)),
                  pl.BlockSpec((1, 4), lambda i, j: (0, 0))],
        out_specs=pl.BlockSpec((tile_n, tile_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=interpret,
    )(v, scalars)

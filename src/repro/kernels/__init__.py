# Pallas TPU kernels for the framework's compute hot-spots
# (validated in interpret mode on CPU against the pure-jnp oracles in ref.py):
#   floyd_warshall       — blocked min-plus APSP over the 3DG
#   pairwise_similarity  — fused U·Uᵀ -> 3DG adjacency epilogue
#   window_attention     — flash sliding-window attention (long_500k path)
from repro.kernels import ops
from repro.kernels import ref

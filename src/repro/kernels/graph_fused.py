"""Fused 3DG megakernel: similarity -> min-max stats -> adjacency in ONE grid.

The staged pallas path (``pairwise_similarity`` + ``adjacency_pallas``)
round-trips the (N, N) similarity matrix V through HBM three times: the
matmul writes it, the host-side ``jnp.min``/``jnp.max`` normalization stats
read it, and the adjacency epilogue reads it again.  This kernel keeps V
tile-resident: a two-phase sequential grid ``(phase, N/T, N/T)`` where

  phase 0  computes each V tile from the (T, d) feature row panels
           (MXU dot, optional max(·, 0) clamp for the Eq. 11/12 functional
           similarity) and folds its min/max into a RESIDENT (1, 2) stats
           accumulator (constant ``index_map`` — the same revisiting
           pattern as ``kernels/solver.py``'s running argmax).  min/max
           are exactly associative, so the tiled reduction is bit-identical
           to ``jnp.min``/``jnp.max`` over the unpadded V.
  phase 1  RE-computes the V tile (features stay in VMEM; for the small
           feature dims of the 3DG build the extra FLOPs are far cheaper
           than an HBM round-trip of the (N, N) matrix) and applies the
           fused epilogue in VREGs: min-max normalize with the phase-0
           stats, threshold at eps, ``exp(-Vn/sigma2)``, inf for no-edge,
           0 diagonal.

V never exists in HBM.  Pad lanes (rows/cols >= n) are excluded from the
stats and written as isolated nodes (inf off-diagonal, 0 diagonal), so the
output is directly Floyd–Warshall-ready at the padded size — the unpad/
re-pad round-trip between the staged adjacency and APSP wrappers disappears
too.  Epilogue op order matches ``core/graph_device.minmax01`` +
``to_adjacency`` exactly, so finite entries are bit-identical to the ref
stages given bit-identical V (pinned by ``tests/test_kernels.py``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

FUSED_TILE = 128


def _fused_kernel(n, clamp, u_ref, ut_ref, scal_ref, out_ref, stat_ref):
    phase, i, j = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    t = out_ref.shape[0]
    v = jax.lax.dot_general(u_ref[...], ut_ref[...], (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    if clamp:
        v = jnp.maximum(v, 0.0)
    rows = jax.lax.broadcasted_iota(jnp.int32, (t, t), 0) + i * t
    cols = jax.lax.broadcasted_iota(jnp.int32, (t, t), 1) + j * t
    valid = (rows < n) & (cols < n)

    @pl.when((phase == 0) & (i == 0) & (j == 0))
    def _init():
        stat_ref[0, 0] = jnp.inf
        stat_ref[0, 1] = -jnp.inf

    @pl.when(phase == 0)
    def _stats():
        stat_ref[0, 0] = jnp.minimum(stat_ref[0, 0],
                                     jnp.min(jnp.where(valid, v, jnp.inf)))
        stat_ref[0, 1] = jnp.maximum(stat_ref[0, 1],
                                     jnp.max(jnp.where(valid, v, -jnp.inf)))
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(phase == 1)
    def _epilogue():
        lo, hi = stat_ref[0, 0], stat_ref[0, 1]
        eps, sigma2 = scal_ref[0, 0], scal_ref[0, 1]
        vn = (v - lo) / jnp.maximum(hi - lo, 1e-12)
        r = jnp.where(vn >= eps, jnp.exp(-vn / sigma2), jnp.inf)
        # pad rows/cols become isolated nodes: the output is FW-ready at the
        # padded size (diagonal 0 INCLUDING pads, inf elsewhere off-region)
        out_ref[...] = jnp.where(rows == cols, 0.0,
                                 jnp.where(valid, r, jnp.inf))


@functools.partial(jax.jit,
                   static_argnames=("n", "clamp", "tile_n", "interpret"))
def fused_adjacency_pallas(u: jax.Array, scal: jax.Array, *, n: int,
                           clamp: bool = False, tile_n: int = FUSED_TILE,
                           interpret: bool = False):
    """u (M, d) f32 feature rows padded to tile multiples (zero pad rows),
    scal (1, 2) = [eps, sigma2], ``n`` the true (unpadded) client count.
    Returns (R (M, M) FW-ready padded adjacency, stats (1, 2) = [lo, hi])."""
    m, d = u.shape
    assert m % tile_n == 0 and d % 128 == 0, (u.shape, tile_n)
    grid = (2, m // tile_n, m // tile_n)
    ut = u.T.copy()
    return pl.pallas_call(
        functools.partial(_fused_kernel, n, clamp),
        grid=grid,
        in_specs=[pl.BlockSpec((tile_n, d), lambda p, i, j: (i, 0)),
                  pl.BlockSpec((d, tile_n), lambda p, i, j: (0, j)),
                  pl.BlockSpec((1, 2), lambda p, i, j: (0, 0))],
        out_specs=[pl.BlockSpec((tile_n, tile_n), lambda p, i, j: (i, j)),
                   pl.BlockSpec((1, 2), lambda p, i, j: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((m, m), jnp.float32),
                   jax.ShapeDtypeStruct((1, 2), jnp.float32)],
        interpret=interpret,
    )(u, ut, scal)

"""Sliding-window flash attention — Pallas TPU kernel.

The long_500k hot-spot (DESIGN.md): causal attention where each query attends
only to the previous ``window`` positions.  Flash-style online softmax over KV
blocks, but the KV block range is *statically bounded* per query block —
compute and VMEM traffic are O(S·window), never O(S²).

Tiling: grid = (B·H, S/BQ, NKB) with NKB = ceil(window+BQ over BK)+1 KV blocks
per query block; the KV block offset is derived from the query block index in
the BlockSpec index_map, so the pipeline only streams the window span from
HBM.  Scores/softmax accumulate in f32 VMEM scratch ((BQ,BK) scores tile,
(BQ,D) accumulator); inputs can be bf16 or f32.  Default BQ=BK=128, D<=256:
working set ≈ 128·128·4 + 3·128·256·4 ≈ 460 KiB — well inside VMEM with
double buffering.

K/V are left-padded by PAD = NKB·BK so every index_map block is in-bounds for
every query block; padded keys are masked by their (negative) true position.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _wa_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
               window: int, bq: int, bk: int, pad: int, seq: int):
    iq = pl.program_id(1)
    jk = pl.program_id(2)
    nkb = pl.num_programs(2)

    @pl.when(jk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)                 # (BQ, D)
    k = k_ref[0].astype(jnp.float32)                 # (BK, D)
    d = q.shape[-1]

    # true positions of this query / kv block
    q0 = iq * bq
    kb0 = (q0 - window + 1 + pad) // bk              # first kv block (padded coords)
    qpos = q0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = (kb0 + jk) * bk - pad + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s * (1.0 / np.sqrt(d))
    mask = (kpos <= qpos) & (kpos > qpos - window) & (kpos >= 0) & (kpos < seq)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                              # (BQ, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)                           # (BQ, BK)
    corr = jnp.exp(m_prev - m_new)                   # (BQ, 1)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(jk == nkb - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "bq", "bk", "interpret"))
def window_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                            window: int, bq: int = 128, bk: int = 128,
                            interpret: bool = False) -> jax.Array:
    """Causal sliding-window attention.  q/k/v (B, S, H, D) -> (B, S, H, D).

    S must be a multiple of bq; kv heads must already be repeated to q heads.
    """
    b, s, h, d = q.shape
    assert s % bq == 0, (s, bq)
    nq = s // bq
    # KV blocks per query block: cover [q0-window+1, q0+BQ-1]
    nkb = (window + bq - 2) // bk + 2
    pad = nkb * bk                                    # left pad; >= window+bq

    def flat(x):
        return x.transpose(0, 2, 1, 3).reshape(b * h, s, d)

    qf = flat(q)
    kf = jnp.pad(flat(k), ((0, 0), (pad, 0), (0, 0)))
    vf = jnp.pad(flat(v), ((0, 0), (pad, 0), (0, 0)))

    def kv_index(bh, iq, jk):
        kb0 = (iq * bq - window + 1 + pad) // bk
        return (bh, kb0 + jk, 0)

    from jax.experimental.pallas import tpu as pltpu

    out = pl.pallas_call(
        functools.partial(_wa_kernel, window=window, bq=bq, bk=bk, pad=pad,
                          seq=s),
        grid=(b * h, nq, nkb),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, iq, jk: (bh, iq, 0)),
            pl.BlockSpec((1, bk, d), kv_index),
            pl.BlockSpec((1, bk, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, iq, jk: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),        # acc
            pltpu.VMEM((bq, 1), jnp.float32),        # running max
            pltpu.VMEM((bq, 1), jnp.float32),        # running denom
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, s, d).transpose(0, 2, 1, 3)

"""Pallas kernels for the FedGS Eq. 16 p-dispersion solver.

The reference solver (``core/sampler_device.fedgs_solve``) materializes a
dense (N, N) swap-gain matrix every local-search sweep and re-scans it with
a flat argmax — O(N²) HBM traffic per sweep that dominates the solve past
N ≈ 1k.  These kernels tile the three hot stages so nothing bigger than a
VMEM tile is ever materialized:

``qbuild``      fused Q construction: ``Q = sym(alpha/N · H) − diag(z)``
                built tile-by-tile from H and its transpose panel — the
                (N, N) symmetrization temporaries of the ref path never
                exist.  Grid (N/T, N/T), elementwise VPU work.

``masked_argmax``  the greedy step: gain ``diag + 2r`` is computed, masked
                (unavailable / already-selected / NaN ↦ −1e18) and arg-maxed
                in one pass over (1, T) lane blocks, carrying the running
                (best, index) pair across the sequential grid.  Strict ``>``
                combining + first-position-within-block reproduces
                ``jnp.argmax``'s first-max tie-break bit for bit.

``swap_gain``   the best-swap sweep over the (m, N) PANEL of selected rows
                only (the caller gathers the |S| ≤ m rows of Q): the tile
                computes ``delta = a_i + b_j − 2 Q_ij`` in VREGs and reduces
                to a running (best, flat index).  Ties combine on the GLOBAL
                flat index (not grid order), matching the ref path's
                row-major flat argmax exactly.

All tiles are f32; min tile (8, 128) per the TPU tiling constraints — the
(1, T) argmax rows and (1, 1) accumulator outputs are sub-tile but legal
(the compiler pads sublanes).  The running-reduction outputs use a constant
``index_map`` so the accumulator tile stays resident across the sequential
grid (the same revisiting-accumulator pattern as ``pairwise_similarity``).
On CPU the kernels run under ``interpret=True`` (see ``kernels/ops.py``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_Q = 512        # qbuild tile (T, T)
TILE_V = 2048       # masked-argmax lane-block width (1, T)
SWAP_TM = 128       # swap panel tile rows (selected-client ranks)
SWAP_TN = 2048      # swap panel tile cols (incoming candidates)

NEG = -1e18         # the solver's masked-entry sentinel (== sampler_device)


# ------------------------------------------------------------------ qbuild
def _qbuild_kernel(h_ref, ht_ref, z_ref, scal_ref, out_ref):
    # Q_ij = 0.5 * ((a·H_ij − δ_ij z_i) + (a·H_ji − δ_ij z_j)) — the exact
    # op order of the ref `q = a·H − diag(z); q = 0.5 (q + qᵀ)`, so the
    # fused build is bit-identical to the ref path.
    a = scal_ref[0, 0]
    t = out_ref.shape[0]
    bi, bj = pl.program_id(0), pl.program_id(1)
    rows = jax.lax.broadcasted_iota(jnp.int32, (t, t), 0) + bi * t
    cols = jax.lax.broadcasted_iota(jnp.int32, (t, t), 1) + bj * t
    zd = jnp.where(rows == cols, z_ref[...], 0.0)     # z block is col-aligned
    t1 = a * h_ref[...] - zd
    t2 = a * ht_ref[...].T - zd                       # ht block = H[bj, bi]
    out_ref[...] = 0.5 * (t1 + t2)


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def qbuild_pallas(h: jax.Array, z: jax.Array, scal: jax.Array, *,
                  tile: int = TILE_Q, interpret: bool = False) -> jax.Array:
    """h (N, N) f32, z (1, N) f32, scal (1, 1) = [alpha/N] -> Q (N, N) f32."""
    n = h.shape[0]
    assert n % tile == 0 and z.shape == (1, n), (h.shape, z.shape)
    grid = (n // tile, n // tile)
    return pl.pallas_call(
        _qbuild_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((tile, tile), lambda i, j: (i, j)),
                  pl.BlockSpec((tile, tile), lambda i, j: (j, i)),
                  pl.BlockSpec((1, tile), lambda i, j: (0, j)),
                  pl.BlockSpec((1, 1), lambda i, j: (0, 0))],
        out_specs=pl.BlockSpec((tile, tile), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=interpret,
    )(h, h, z, scal)


# ----------------------------------------------------------- masked argmax
def _masked_argmax_kernel(diag_ref, r_ref, mask_ref, val_ref, idx_ref):
    b = pl.program_id(0)
    t = diag_ref.shape[1]
    gain = diag_ref[...] + 2.0 * r_ref[...]           # (1, T)
    gain = jnp.where(mask_ref[...] > 0.5, gain, NEG)
    gain = jnp.where(jnp.isnan(gain), NEG, gain)      # NaN guard (== ref)

    @pl.when(b == 0)
    def _init():
        val_ref[0, 0] = -jnp.inf
        idx_ref[0, 0] = 0

    mx = jnp.max(gain)
    cols = jax.lax.broadcasted_iota(jnp.int32, (1, t), 1)
    pos = jnp.min(jnp.where(gain == mx, cols, t))     # first max in block
    # strict > + left-to-right grid order == jnp.argmax first-max tie-break
    better = mx > val_ref[0, 0]
    idx_ref[0, 0] = jnp.where(better, b * t + pos, idx_ref[0, 0])
    val_ref[0, 0] = jnp.where(better, mx, val_ref[0, 0])


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def masked_argmax_pallas(diag: jax.Array, r: jax.Array, mask: jax.Array, *,
                         tile: int = TILE_V, interpret: bool = False):
    """Fused greedy gain + blocked masked argmax.

    diag, r, mask: (1, N) f32 (mask 1.0 = addable).  Returns the running
    ((1, 1) best gain, (1, 1) flat index) pair.
    """
    n = diag.shape[1]
    assert n % tile == 0 and r.shape == diag.shape == mask.shape
    return pl.pallas_call(
        _masked_argmax_kernel,
        grid=(n // tile,),
        in_specs=[pl.BlockSpec((1, tile), lambda b: (0, b)),
                  pl.BlockSpec((1, tile), lambda b: (0, b)),
                  pl.BlockSpec((1, tile), lambda b: (0, b))],
        out_specs=[pl.BlockSpec((1, 1), lambda b: (0, 0)),
                   pl.BlockSpec((1, 1), lambda b: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((1, 1), jnp.float32),
                   jax.ShapeDtypeStruct((1, 1), jnp.int32)],
        interpret=interpret,
    )(diag, r, mask)


# -------------------------------------------------------------- swap sweep
def _swap_gain_kernel(a_ref, b_ref, q_ref, val_ref, flat_ref):
    bi, bj = pl.program_id(0), pl.program_id(1)
    tm, tn = q_ref.shape
    np_cols = pl.num_programs(1) * tn
    delta = (a_ref[...] + b_ref[...]) - 2.0 * q_ref[...]   # (tm,1)+(1,tn)
    delta = jnp.where(jnp.isnan(delta), NEG, delta)        # NaN guard (== ref)

    @pl.when((bi == 0) & (bj == 0))
    def _init():
        val_ref[0, 0] = -jnp.inf
        flat_ref[0, 0] = 0

    mx = jnp.max(delta)
    rows = jax.lax.broadcasted_iota(jnp.int32, (tm, tn), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (tm, tn), 1)
    # global flat index over the (M, N) panel: tie-breaks must compare in
    # panel-row-major order, NOT grid order — a later column tile can hold
    # an earlier PANEL row than a tile already visited.
    flat = (rows + bi * tm) * np_cols + (cols + bj * tn)
    pos = jnp.min(jnp.where(delta == mx, flat, jnp.int32(2 ** 31 - 1)))
    cur_v, cur_f = val_ref[0, 0], flat_ref[0, 0]
    better = (mx > cur_v) | ((mx == cur_v) & (pos < cur_f))
    flat_ref[0, 0] = jnp.where(better, pos, cur_f)
    val_ref[0, 0] = jnp.where(better, mx, cur_v)


@functools.partial(jax.jit, static_argnames=("tile_m", "tile_n", "interpret"))
def swap_gain_pallas(qs: jax.Array, a: jax.Array, b: jax.Array, *,
                     tile_m: int = SWAP_TM, tile_n: int = SWAP_TN,
                     interpret: bool = False):
    """Best swap over the selected-row panel.

    qs (M, N) f32 = gathered selected rows of Q; a (M, 1) out-gain terms
    (−1e18 on invalid/pad rows); b (1, N) in-gain terms (−1e18 on
    non-addable/pad cols).  Returns ((1, 1) best delta, (1, 1) flat index
    into the (M, N) panel).
    """
    m, n = qs.shape
    assert m % tile_m == 0 and n % tile_n == 0, (qs.shape, tile_m, tile_n)
    assert a.shape == (m, 1) and b.shape == (1, n)
    grid = (m // tile_m, n // tile_n)
    return pl.pallas_call(
        _swap_gain_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((tile_m, 1), lambda i, j: (i, 0)),
                  pl.BlockSpec((1, tile_n), lambda i, j: (0, j)),
                  pl.BlockSpec((tile_m, tile_n), lambda i, j: (i, j))],
        out_specs=[pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
                   pl.BlockSpec((1, 1), lambda i, j: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((1, 1), jnp.float32),
                   jax.ShapeDtypeStruct((1, 1), jnp.int32)],
        interpret=interpret,
    )(a, b, qs)

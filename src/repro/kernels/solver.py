"""Pallas kernels for the FedGS Eq. 16 p-dispersion solver.

The reference solver (``core/sampler_device.fedgs_solve``) materializes a
dense (N, N) swap-gain matrix every local-search sweep and re-scans it with
a flat argmax — O(N²) HBM traffic per sweep that dominates the solve past
N ≈ 1k.  These kernels tile the three hot stages so nothing bigger than a
VMEM tile is ever materialized — including Q itself: since PR 7 the pallas
path never builds the (N, N) ``Q = sym(alpha/N · H) − diag(z)`` at all.
The factored form (H, z, alpha/N) is carried instead and Q entries are
reconstructed exactly where they are consumed:

``q_diag / q_row``  host-side jnp helpers reconstructing the diagonal and
                single rows (for the greedy ``r`` accumulator) with the ref
                path's exact op order ``0.5·((a·H_ij − δz) + (a·H_ji − δz))``
                — bit-identical to gathering from a materialized Q.

``masked_argmax``  the greedy step: gain ``diag + 2r`` is computed, masked
                (unavailable / already-selected / NaN ↦ −1e18) and arg-maxed
                in one pass over (1, T) lane blocks, carrying the running
                (best, index) pair across the sequential grid.  Strict ``>``
                combining + first-position-within-block reproduces
                ``jnp.argmax``'s first-max tie-break bit for bit.

``swap_gain_fused``  the best-swap sweep fused end-to-end: the kernel takes
                the (m, N) H row/column panels of the SELECTED clients plus
                (z[sel], alpha/N), rebuilds the Q tile in VREGs, and reduces
                ``delta = a_i + b_j − 2 Q_ij`` to a running (best, flat
                index) — solve→select→swap with no (N, N) and not even an
                (m, N) Q panel in HBM.  Ties combine on the GLOBAL flat
                index (not grid order), matching the ref path's row-major
                flat argmax exactly.

``swap_gain``   the same sweep for callers that already hold a Q panel
                (``fedgs_solve``'s public (N, N)-Q API).

All tiles are f32; min tile (8, 128) per the TPU tiling constraints — the
(1, T) argmax rows and (1, 1) accumulator outputs are sub-tile but legal
(the compiler pads sublanes).  The running-reduction outputs use a constant
``index_map`` so the accumulator tile stays resident across the sequential
grid (the same revisiting-accumulator pattern as ``pairwise_similarity``).
On CPU the kernels run under ``interpret=True`` (see ``kernels/ops.py``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_Q = 512        # legacy q-panel tile (kept for the dense-Q swap path)
TILE_V = 2048       # masked-argmax lane-block width (1, T)
SWAP_TM = 128       # swap panel tile rows (selected-client ranks)
SWAP_TN = 2048      # swap panel tile cols (incoming candidates)

NEG = -1e18         # the solver's masked-entry sentinel (== sampler_device)


# ----------------------------------------------------- factored-Q providers
def q_diag(h: jax.Array, z: jax.Array, a) -> jax.Array:
    """diag(Q) for Q = sym(a·H) − diag(z), without building Q.

    Ref op order: ``Q_kk = 0.5·((a·H_kk − z_k) + (a·H_kk − z_k))`` — both
    addends are the same float, so this is bit-identical to the ref build's
    diagonal (0.5·(t+t) is exact)."""
    t = a * jnp.diagonal(h) - z
    return 0.5 * (t + t)


def q_row(h: jax.Array, z: jax.Array, a, k) -> jax.Array:
    """Row k of Q = sym(a·H) − diag(z) (the greedy ``r`` update), rebuilt
    with the ref op order so it is bit-identical to ``Q[k]`` of the
    materialized build: the δ-term subtracts z_k at column k in BOTH the
    H-row and H-column addends (z_i = z_j = z_k on the diagonal)."""
    n = h.shape[0]
    zc = jnp.where(jnp.arange(n) == k, z[k], 0.0)
    t1 = a * h[k, :] - zc
    t2 = a * h[:, k] - zc
    return 0.5 * (t1 + t2)


# ----------------------------------------------------------- masked argmax
def _masked_argmax_kernel(diag_ref, r_ref, mask_ref, val_ref, idx_ref):
    b = pl.program_id(0)
    t = diag_ref.shape[1]
    gain = diag_ref[...] + 2.0 * r_ref[...]           # (1, T)
    gain = jnp.where(mask_ref[...] > 0.5, gain, NEG)
    gain = jnp.where(jnp.isnan(gain), NEG, gain)      # NaN guard (== ref)

    @pl.when(b == 0)
    def _init():
        val_ref[0, 0] = -jnp.inf
        idx_ref[0, 0] = 0

    mx = jnp.max(gain)
    cols = jax.lax.broadcasted_iota(jnp.int32, (1, t), 1)
    pos = jnp.min(jnp.where(gain == mx, cols, t))     # first max in block
    # strict > + left-to-right grid order == jnp.argmax first-max tie-break
    better = mx > val_ref[0, 0]
    idx_ref[0, 0] = jnp.where(better, b * t + pos, idx_ref[0, 0])
    val_ref[0, 0] = jnp.where(better, mx, val_ref[0, 0])


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def masked_argmax_pallas(diag: jax.Array, r: jax.Array, mask: jax.Array, *,
                         tile: int = TILE_V, interpret: bool = False):
    """Fused greedy gain + blocked masked argmax.

    diag, r, mask: (1, N) f32 (mask 1.0 = addable).  Returns the running
    ((1, 1) best gain, (1, 1) flat index) pair.
    """
    n = diag.shape[1]
    assert n % tile == 0 and r.shape == diag.shape == mask.shape
    return pl.pallas_call(
        _masked_argmax_kernel,
        grid=(n // tile,),
        in_specs=[pl.BlockSpec((1, tile), lambda b: (0, b)),
                  pl.BlockSpec((1, tile), lambda b: (0, b)),
                  pl.BlockSpec((1, tile), lambda b: (0, b))],
        out_specs=[pl.BlockSpec((1, 1), lambda b: (0, 0)),
                   pl.BlockSpec((1, 1), lambda b: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((1, 1), jnp.float32),
                   jax.ShapeDtypeStruct((1, 1), jnp.int32)],
        interpret=interpret,
    )(diag, r, mask)


# -------------------------------------------------------------- swap sweep
def _best_swap_update(delta, bi, bj, np_cols, val_ref, flat_ref):
    """Shared running reduction: fold a (tm, tn) delta tile into the
    resident ((1,1) best, (1,1) flat-index) accumulators.  Ties compare on
    the GLOBAL flat index over the (M, N) panel, NOT grid order — a later
    column tile can hold an earlier PANEL row than a tile already visited —
    matching the ref path's row-major flat argmax exactly."""
    tm, tn = delta.shape

    @pl.when((bi == 0) & (bj == 0))
    def _init():
        val_ref[0, 0] = -jnp.inf
        flat_ref[0, 0] = 0

    mx = jnp.max(delta)
    rows = jax.lax.broadcasted_iota(jnp.int32, (tm, tn), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (tm, tn), 1)
    flat = (rows + bi * tm) * np_cols + (cols + bj * tn)
    pos = jnp.min(jnp.where(delta == mx, flat, jnp.int32(2 ** 31 - 1)))
    cur_v, cur_f = val_ref[0, 0], flat_ref[0, 0]
    better = (mx > cur_v) | ((mx == cur_v) & (pos < cur_f))
    flat_ref[0, 0] = jnp.where(better, pos, cur_f)
    val_ref[0, 0] = jnp.where(better, mx, cur_v)


def _swap_gain_kernel(a_ref, b_ref, q_ref, val_ref, flat_ref):
    bi, bj = pl.program_id(0), pl.program_id(1)
    tn = q_ref.shape[1]
    delta = (a_ref[...] + b_ref[...]) - 2.0 * q_ref[...]   # (tm,1)+(1,tn)
    delta = jnp.where(jnp.isnan(delta), NEG, delta)        # NaN guard (== ref)
    _best_swap_update(delta, bi, bj, pl.num_programs(1) * tn,
                      val_ref, flat_ref)


@functools.partial(jax.jit, static_argnames=("tile_m", "tile_n", "interpret"))
def swap_gain_pallas(qs: jax.Array, a: jax.Array, b: jax.Array, *,
                     tile_m: int = SWAP_TM, tile_n: int = SWAP_TN,
                     interpret: bool = False):
    """Best swap over a MATERIALIZED selected-row panel.

    qs (M, N) f32 = gathered selected rows of Q; a (M, 1) out-gain terms
    (−1e18 on invalid/pad rows); b (1, N) in-gain terms (−1e18 on
    non-addable/pad cols).  Returns ((1, 1) best delta, (1, 1) flat index
    into the (M, N) panel).
    """
    m, n = qs.shape
    assert m % tile_m == 0 and n % tile_n == 0, (qs.shape, tile_m, tile_n)
    assert a.shape == (m, 1) and b.shape == (1, n)
    grid = (m // tile_m, n // tile_n)
    return pl.pallas_call(
        _swap_gain_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((tile_m, 1), lambda i, j: (i, 0)),
                  pl.BlockSpec((1, tile_n), lambda i, j: (0, j)),
                  pl.BlockSpec((tile_m, tile_n), lambda i, j: (i, j))],
        out_specs=[pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
                   pl.BlockSpec((1, 1), lambda i, j: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((1, 1), jnp.float32),
                   jax.ShapeDtypeStruct((1, 1), jnp.int32)],
        interpret=interpret,
    )(a, b, qs)


def _swap_fused_kernel(a_ref, b_ref, hs_ref, hts_ref, sel_ref, zsel_ref,
                       scal_ref, val_ref, flat_ref):
    """Q-free best swap: rebuild the Q tile in VREGs from the H panels.

    Q_sr,j = 0.5·((a·H[sr,j] − δ z[sr]) + (a·H[j,sr] − δ z[sr])) with
    δ = (j == sel_r) — the exact ref op order (z_j = z_sr on the diagonal),
    so ``delta = (a_i + b_j) − 2 Q`` is bit-identical to the dense-panel
    kernel fed by a materialized Q."""
    bi, bj = pl.program_id(0), pl.program_id(1)
    tm, tn = hs_ref.shape
    al = scal_ref[0, 0]
    cols = jax.lax.broadcasted_iota(jnp.int32, (tm, tn), 1) + bj * tn
    zc = jnp.where(sel_ref[...] == cols, zsel_ref[...], 0.0)
    t1 = al * hs_ref[...] - zc
    t2 = al * hts_ref[...] - zc
    q = 0.5 * (t1 + t2)
    delta = (a_ref[...] + b_ref[...]) - 2.0 * q
    delta = jnp.where(jnp.isnan(delta), NEG, delta)        # NaN guard (== ref)
    _best_swap_update(delta, bi, bj, pl.num_programs(1) * tn,
                      val_ref, flat_ref)


@functools.partial(jax.jit, static_argnames=("tile_m", "tile_n", "interpret"))
def swap_gain_fused_pallas(hs: jax.Array, hts: jax.Array, a: jax.Array,
                           b: jax.Array, sel: jax.Array, zsel: jax.Array,
                           scal: jax.Array, *, tile_m: int = SWAP_TM,
                           tile_n: int = SWAP_TN, interpret: bool = False):
    """Fused best swap over the factored Q.

    hs (M, N) = H[sel, :], hts (M, N) = H[:, sel]ᵀ, a (M, 1) out-gain,
    b (1, N) in-gain (both −1e18-masked), sel (M, 1) int32 global indices
    of the panel rows (−1 on pad rows — matches no column), zsel (M, 1) =
    z[sel], scal (1, 1) = [alpha/N].  Returns ((1, 1) best delta, (1, 1)
    flat index into the (M, N) panel)."""
    m, n = hs.shape
    assert m % tile_m == 0 and n % tile_n == 0, (hs.shape, tile_m, tile_n)
    assert hts.shape == (m, n) and a.shape == (m, 1) and b.shape == (1, n)
    assert sel.shape == (m, 1) and zsel.shape == (m, 1)
    grid = (m // tile_m, n // tile_n)
    return pl.pallas_call(
        _swap_fused_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((tile_m, 1), lambda i, j: (i, 0)),
                  pl.BlockSpec((1, tile_n), lambda i, j: (0, j)),
                  pl.BlockSpec((tile_m, tile_n), lambda i, j: (i, j)),
                  pl.BlockSpec((tile_m, tile_n), lambda i, j: (i, j)),
                  pl.BlockSpec((tile_m, 1), lambda i, j: (i, 0)),
                  pl.BlockSpec((tile_m, 1), lambda i, j: (i, 0)),
                  pl.BlockSpec((1, 1), lambda i, j: (0, 0))],
        out_specs=[pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
                   pl.BlockSpec((1, 1), lambda i, j: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((1, 1), jnp.float32),
                   jax.ShapeDtypeStruct((1, 1), jnp.int32)],
        interpret=interpret,
    )(a, b, hs, hts, sel, zsel, scal)

"""Tiled pairwise squared-distance panel for the Krum aggregator.

Krum's score (Blanchard et al., NeurIPS 2017) is, per sampled update i,
the sum of its n − f − 2 smallest squared distances ||θ_i − θ_j||².  The
hot part is the (m, m) distance panel over the (m, P) flattened update
matrix: this kernel computes it as the classic expansion

    D[i, j] = ||x_i||² + ||x_j||² − 2 x_i · x_jᵀ

tiled exactly like ``pairwise_similarity`` — grid (m/T, m/T, P/Tk) with a
revisiting accumulator, the cross term on the MXU via ``dot_general`` with
f32 accumulation, and the row/col squared norms reduced per P-tile in
VREGs so each (T, Tk) panel of x is touched ONCE per grid step (no
separate norm pass over HBM).  The per-tile partials ``ri + rj − 2 x xᵀ``
accumulate over k, which reassociates the f32 sums vs the ref's
full-norm-then-subtract order — the panel agrees to f32 roundoff, and the
SELECTION (sorted score ranks) is pinned bit-identical in tests (Krum's
decision margin dwarfs the reassociation noise).

Zero-padding is safe end-to-end: padded P columns contribute 0 to every
term, and padded rows only add distance entries that the caller slices
off (``kernels/ops.krum_distances``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

KRUM_TM = 128       # (m, m) panel tile — min f32 sublane/lane tile is (8, 128)
KRUM_TK = 128       # P reduction tile


def _krum_kernel(x_ref, xt_ref, out_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    xi = x_ref[...]                                   # (T, Tk) rows i
    xtj = xt_ref[...]                                 # (Tk, T) rows j, transposed
    ri = jnp.sum(xi * xi, axis=1, keepdims=True)      # (T, 1) partial ||x_i||²
    rj = jnp.sum(xtj * xtj, axis=0, keepdims=True)    # (1, T) partial ||x_j||²
    out_ref[...] += (ri + rj) - 2.0 * jax.lax.dot_general(
        xi, xtj, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("tile_m", "tile_k", "interpret"))
def krum_pallas(x: jax.Array, *, tile_m: int = KRUM_TM,
                tile_k: int = KRUM_TK, interpret: bool = False) -> jax.Array:
    """x (m, P) f32 -> D (m, m) f32 squared distances. m, P tile multiples."""
    m, p = x.shape
    assert m % tile_m == 0 and p % tile_k == 0, (m, p)
    xt = x.T.copy()
    grid = (m // tile_m, m // tile_m, p // tile_k)
    return pl.pallas_call(
        _krum_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((tile_m, tile_k), lambda i, j, k: (i, k)),
                  pl.BlockSpec((tile_k, tile_m), lambda i, j, k: (k, j))],
        out_specs=pl.BlockSpec((tile_m, tile_m), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, m), jnp.float32),
        interpret=interpret,
    )(x, xt)

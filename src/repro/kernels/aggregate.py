"""Pallas kernel for the memory-rectified server aggregation.

The memory aggregator (``fed/aggregator_device.py``, family ``memory``)
keeps an (N, P) panel of every client's last flattened update and, each
round, (a) scatters the m sampled clients' fresh updates into their rows
and (b) reduces the panel with staleness-discounted weights into the new
global params.  Done naively per params leaf this is the heaviest per-round
data movement in the simulation — an (N, leaf)-shaped gather/scatter and
reduction for every leaf.  This kernel fuses both stages over the ONE flat
panel:

``memagg``  grid (P/Tp, N/Tn, M/Tm) — update chunks innermost, so the
            (Tn, Tp) output tile is REVISITED across the Tm-chunks of the
            sampled-update matrix (m scales with N, so the (M, Tp) block
            must be tiled too or it alone would blow VMEM at datacenter
            m).  Chunk step k: the scatter is a one-hot MXU matmul —
            ``onehot (Tn, Tm) @ upd_k (Tm, Tp)`` with ``onehot[r, c] =
            (row r == sel_k[c])`` — overwriting exactly the hit rows of
            the carried tile (the one-hot products are 1·x + 0·…, so the
            scattered panel is BIT-identical to the jnp ``.at[sel].set``
            reference; sel chunks are disjoint so chunk order cannot
            conflict).  On the LAST chunk the finished tile feeds the
            weighted row reduction ``w (1, Tn) @ tile (Tn, Tp)``
            accumulated into a revisited (1, Tp) output block (the same
            running-accumulator pattern as ``kernels/solver.py``) — the
            post-scatter panel is reduced where it is produced and never
            re-read from HBM.

Per-round HBM traffic: the O(mP) update rows + one tiled O(NP) panel
read/write + the O(P) reduction — nothing (N, P)-sized is ever
materialized per params leaf (the pytree is raveled to one flat axis by
the caller).  The reduction's tile-order partial sums differ from the ref
path's single (N,)·(N, P) tensordot, so reduction parity is NUMERICAL
(allclose, pinned by ``tests/test_aggregator_device.py``), while the
scattered panel is bit-identical.

Invalid/pad scatter slots are encoded as ``sel = -1`` (never equal to a
row id); pad rows of the panel carry zero weight, pad columns are sliced
off by the ``kernels/ops.py`` wrapper.  Tiles are f32; the (1, Tp)
accumulator and (1, Tm) sel row are sub-tile but legal (the compiler pads
sublanes).  Worst-case VMEM at the (512, 2048) panel tile with Tm = 256
update chunks: mem + newmem 8 MiB + upd 2 MiB + one-hot 0.5 MiB ≈ 10.5
MiB, under the 16 MiB/core budget.  On CPU the kernel runs under
``interpret=True`` — tiles scale up at large panels to keep the grid
small (every interpret grid step re-writes the (N, P) output; see the
perf note in ``kernels/ops.py``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

AGG_TN = 256        # memory-panel tile rows (clients)
AGG_TP = 512        # memory-panel tile cols (flat params)
AGG_TM = 256        # sampled-update chunk rows


def _memagg_kernel(sel_ref, w_ref, upd_ref, mem_ref, newmem_ref, red_ref):
    i, k = pl.program_id(1), pl.program_id(2)      # row tile, update chunk
    nk = pl.num_programs(2)
    tn, tp = newmem_ref.shape
    tm = upd_ref.shape[0]

    @pl.when(k == 0)
    def _load():
        newmem_ref[...] = mem_ref[...]

    # one-hot scatter of this update chunk: row ids are exact in f32
    # (N < 2^24), sel = -1 for invalid/pad slots never matches.  The
    # matmul must not see non-finite update entries — 0 · NaN = NaN would
    # leak one diverged client's NaN into every other scattered row of the
    # chunk — so they are zeroed for the dot and restored as NaN through a
    # second one-hot dot on the non-finite mask (DESIGN.md §12: finite
    # panels are bit-identical; a client's non-finite entries land as NaN
    # in that client's row only, as a NaN-poisoned row marks itself).
    rows = jax.lax.broadcasted_iota(jnp.float32, (tn, tm), 0) + i * tn
    onehot = (rows == sel_ref[...]).astype(jnp.float32)
    u = upd_ref[...]
    finite = jnp.isfinite(u)
    scat = jnp.dot(onehot, jnp.where(finite, u, 0.0),
                   preferred_element_type=jnp.float32)
    bad = jnp.dot(onehot, 1.0 - finite.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    scat = jnp.where(bad > 0.0, jnp.float32(jnp.nan), scat)
    hit = jnp.sum(onehot, axis=1, keepdims=True) > 0.5
    newmem_ref[...] = jnp.where(hit, scat, newmem_ref[...])

    @pl.when((i == 0) & (k == 0))
    def _init():
        red_ref[...] = jnp.zeros_like(red_ref)

    @pl.when(k == nk - 1)                          # tile fully scattered
    def _reduce():
        red_ref[...] += jnp.dot(w_ref[...], newmem_ref[...],
                                preferred_element_type=jnp.float32)


@functools.partial(jax.jit,
                   static_argnames=("tile_n", "tile_p", "tile_m", "interpret"))
def memagg_pallas(mem: jax.Array, upd: jax.Array, sel: jax.Array,
                  w: jax.Array, *, tile_n: int = AGG_TN,
                  tile_p: int = AGG_TP, tile_m: int = AGG_TM,
                  interpret: bool = False):
    """mem (N, P) f32 panel, upd (M, P) f32 sampled updates, sel (1, M) f32
    target rows (−1 = invalid), w (1, N) f32 reduction weights ->
    (new_mem (N, P), red (1, P))."""
    n, p = mem.shape
    mm = upd.shape[0]
    assert n % tile_n == 0 and p % tile_p == 0 and mm % tile_m == 0, \
        (mem.shape, upd.shape, tile_n, tile_p, tile_m)
    assert upd.shape == (mm, p) and sel.shape == (1, mm) and w.shape == (1, n)
    grid = (p // tile_p, n // tile_n, mm // tile_m)   # chunks innermost
    return pl.pallas_call(
        _memagg_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, tile_m), lambda j, i, k: (0, k)),
                  pl.BlockSpec((1, tile_n), lambda j, i, k: (0, i)),
                  pl.BlockSpec((tile_m, tile_p), lambda j, i, k: (k, j)),
                  pl.BlockSpec((tile_n, tile_p), lambda j, i, k: (i, j))],
        out_specs=[pl.BlockSpec((tile_n, tile_p), lambda j, i, k: (i, j)),
                   pl.BlockSpec((1, tile_p), lambda j, i, k: (0, j))],
        out_shape=[jax.ShapeDtypeStruct((n, p), jnp.float32),
                   jax.ShapeDtypeStruct((1, p), jnp.float32)],
        interpret=interpret,
    )(sel, w, upd, mem)

"""Fully jit-compiled federated simulation: scan-over-rounds, vmap-over-cells.

``FLEngine.run`` (fed/engine.py) is a per-round Python loop: every round pays
a dispatch + host<->device sync for the sampler, the trainer and the eval, and
a sweep like Table 2 (samplers x availability modes x seeds) runs each cell
serially.  This module moves the *entire* round loop onto the device:

  one ``lax.scan`` step = availability draw -> sampler -> vmap'd local
  training (E SGD steps) -> server update (aggregator switch; Eq. 18
  default) -> count update -> eval,

all with static shapes, and the scanned program is then ``vmap``-ed over a
batch axis of *cells* — (seed, availability mode, FedGS alpha) triples — so a
whole sweep row executes as ONE XLA program (DESIGN.md §5).

Static-shape formulation
  The sampler emits a boolean mask s (N,) with |s| = min(M, |A_t|); the M
  sorted selected indices (padded with zero-weight slots when |A_t| < M) are
  gathered so local training always runs on exactly M stacked clients, and
  Eq. 18 weights ``n_k * valid_k`` zero out the pads.

Seed streams (parity with FLEngine)
  The training stream replicates FLEngine.run exactly: ``key_t = fold_in(
  PRNGKey(seed), t)``, then ``_, sub = split(key_t)`` and per-client keys
  ``split(sub, M)`` — so with the same sampled sets the parameter trajectory
  matches the host engine to float32 round-off, PROVIDED every round has
  |A_t| >= M: FLEngine splits ``split(sub, |S_t|)`` and threefry key prefixes
  depend on the split count, so rounds where fewer than M clients are
  available draw different local-training batches (still a valid simulation,
  just not bit-parity — the parity tests assert the precondition).  Availability either comes
  from host-precomputed masks (``precompute_masks`` = the shared host
  wrapper ``availability.host_trace``, bit-identical to FLEngine's numpy
  SeedSequence([avail_seed, t]) stream — the parity-test path) or is drawn
  on-device by an ``AvailabilityProcess``
  (``core.availability_device``): the cell carries the process params +
  carried state, the scan body calls the one shared ``proc_draw`` (family
  step -> Bernoulli -> force-one), and because every family compiles to the
  same ``lax.switch`` program, cells of DIFFERENT scenario families —
  legacy periodic tables, Gilbert–Elliott churn, cluster outages, drift,
  deadlines — vmap-batch through one ``run_batch`` program.  The SAMPLER is
  the same kind of per-cell switch (``core.sampler_device``): each cell
  carries a ``SamplerProcess`` params pytree + in-scan state, and the one
  ``make_sampler_step`` program dispatches Uniform / MD (Gumbel top-k),
  Power-of-Choice (d·m Gumbel candidates + in-scan loss probe + top-m
  keep) and FedGS (the deterministic ``fedgs_solve``, so FedGS cells match
  the host engine's sampled sets exactly; ``ScanConfig.solver_backend``
  routes the Eq. 16 solve through the tiled Pallas kernels) — so
  MIXED-SAMPLER cell batches execute as one XLA program too.  The SERVER
  UPDATE is the third per-cell switch (``fed.aggregator_device``): each
  cell carries an ``AggregatorProcess`` params pytree and the in-scan
  aggregator state (previous params — which double as the param carry —
  momentum/Adam moments, the (N, P) update-memory panel), and the one
  ``make_aggregator_step`` program dispatches FedAvg (bit-parity with the
  legacy Eq. 18 path), FedAvgM, FedAdam, proximal-weighted averaging and
  the FedAR/MIFA-style memory-rectified reduction
  (``ScanConfig.agg_backend`` routes the memory scatter+reduce through the
  tiled Pallas kernel) — so MIXED-AGGREGATOR cell batches are one XLA
  program as well.

Dynamic 3DG
  With ``graph_refresh_every > 0`` the 3DG is maintained *inside* the scan:
  participants' post-training probe embeddings update a carried (N, C)
  embedding table and every K rounds ``core.graph_device.build_h`` (the one
  shared functional-similarity -> adjacency -> Floyd–Warshall -> finite-cap
  pipeline) rebuilds the carried H under ``lax.cond``.
  ``ScanConfig.graph_backend="pallas"`` routes the rebuild's similarity
  matmul and APSP through the tiled kernels for large-N sweeps.

Typical use::

    eng = ScanEngine(ds, model, ScanConfig(rounds=60, m=6, sampler="fedgs"))
    cells = [eng.cell(seed=s, mode=mode, alpha=1.0, h=h) for s in (0, 1, 2)]
    hists = eng.run_batch(cells)          # one compiled program, B cells
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.availability import AvailabilityMode, host_trace
from repro.core.availability_device import AvailabilityProcess, proc_draw
from repro.core.graph_device import (
    BACKENDS, GraphConfig, build_h, cap_and_normalize,
)
from repro.core.sampler_device import (
    FAMILIES, SamplerProcess, make_sampler_process, make_sampler_step,
    select_k,
)
from repro.core.fairness import count_variance_device, gini_device
from repro.data.fed_dataset import FedDataset
from repro.fed.aggregator_device import (
    AggregatorProcess, init_agg_state, make_aggregator_process,
    make_aggregator_step,
)
from repro.fed.aggregator_device import FAMILIES as AGG_FAMILIES
from repro.fed.client import make_local_trainer
from repro.fed.models import FedModel

SAMPLERS = FAMILIES            # ("fedgs", "uniform", "md", "poc")
AGGREGATORS = AGG_FAMILIES     # ("fedavg", "fedavgm", "fedadam",
                               #  "fedprox_w", "memory")


@dataclass(frozen=True)
class ScanConfig:
    """Static (compile-time) configuration of the scanned program."""
    rounds: int = 200
    m: int = 3                     # sampled clients per round (static shape M)
    local_steps: int = 10          # E
    batch_size: int = 10
    lr: float = 0.1
    lr_decay: float = 0.998
    prox_mu: float = 0.0
    eval_every: int = 1            # in-scan eval cadence (NaN on off rounds)
    sampler: str = "fedgs"         # fedgs | uniform | md | poc
    max_sweeps: int = 32           # FedGS local-search budget
    # Power-of-Choice: d·m candidates by data size, in-scan loss probe
    poc_d_factor: int = 2
    poc_probe: int = 64            # loss-probe batch per candidate
    # dynamic 3DG: rebuild H in-scan from participants' probe embeddings
    # every K rounds (0 = static graph installed via the cell's ``h``)
    graph_refresh_every: int = 0
    graph_eps: float = 0.1
    graph_sigma2: float = 0.01
    graph_backend: str = "ref"     # ref | pallas (dynamic-3DG rebuild path)
    solver_backend: str = "ref"    # ref | pallas (FedGS Eq. 16 solve)
    aggregator: str = "fedavg"     # fedavg | fedavgm | fedadam | fedprox_w
                                   # | memory (per-cell overridable)
    agg_backend: str = "ref"       # ref | pallas (memory scatter+reduce)
    probe_size: int = 64
    probe_seed: int = 777

    def __post_init__(self):
        if self.sampler not in SAMPLERS:
            raise ValueError(f"scan engine supports {SAMPLERS}, "
                             f"not {self.sampler!r}")
        if self.aggregator not in AGGREGATORS:
            raise ValueError(f"scan engine supports {AGGREGATORS}, "
                             f"not {self.aggregator!r}")
        for knob in ("graph_backend", "solver_backend", "agg_backend"):
            if getattr(self, knob) not in BACKENDS:
                raise ValueError(f"{knob} must be one of {BACKENDS}, "
                                 f"not {getattr(self, knob)!r}")


# --------------------------------------------------------------- host helpers
def precompute_masks(mode, rounds: int, avail_seed: int = 1234) -> np.ndarray:
    """(rounds, N) bool availability trace, bit-identical to the stream
    FLEngine.run draws — both route through the ONE host wrapper
    ``availability.host_draw`` / ``host_trace``.  ``mode`` is anything with
    ``sample(t, rng)``: an ``AvailabilityMode`` or a ``ProcessMode`` over a
    stateful scenario family."""
    return host_trace(mode, rounds, avail_seed)


def normalized_h(h: np.ndarray) -> np.ndarray:
    """Finite-cap + [0, 1]-normalize a shortest-path matrix — the SAME
    ``graph_device.cap_and_normalize`` stage FedGSSampler.set_graph runs
    (DESIGN.md assumption log)."""
    return np.asarray(cap_and_normalize(jnp.asarray(h, jnp.float32)))


def oracle_h(features: np.ndarray, *, eps: float = 0.1, sigma2: float = 0.01,
             backend: str = "ref") -> np.ndarray:
    """Oracle 3DG -> normalized H (the scan-engine analogue of
    FLEngine.install_oracle_graph)."""
    cfg = GraphConfig(eps=eps, sigma2=sigma2, similarity="dot")
    return np.asarray(build_h(jnp.asarray(features, jnp.float32), cfg,
                              backend=backend))


def stack_cells(cells: list[dict]) -> dict:
    """Stack per-cell pytrees along a new leading batch axis, zero-padding
    the availability-process tables to a common period (rows beyond a
    cell's own period are never indexed because lookups are
    ``table[t % period]``) — this is what lets cells of different scenario
    families, with different table periods, batch into ONE program."""
    if "proc" in cells[0]:
        pmax = max(int(c["proc"]["table"].shape[0]) for c in cells)
        cells = [dict(c, proc=dict(c["proc"])) for c in cells]
        for c in cells:
            for k in ("table", "table_b"):
                tab = c["proc"][k]
                p = int(tab.shape[0])
                if p < pmax:
                    c["proc"][k] = jnp.concatenate(
                        [tab, jnp.zeros((pmax - p,) + tab.shape[1:],
                                        tab.dtype)])
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *cells)


# ------------------------------------------------------------------ histories
@dataclass
class ScanHistory:
    """Device-side trajectory of one cell (full-round resolution; eval
    entries are NaN on rounds skipped by ``eval_every``)."""
    val_loss: np.ndarray       # (T,)
    val_acc: np.ndarray        # (T,)
    count_var: np.ndarray      # (T,)
    gini: np.ndarray           # (T,) Gini coefficient of the counts
    sel: np.ndarray            # (T, M) sorted selected indices (padded)
    valid: np.ndarray          # (T, M) pad mask (False = zero-weight slot)
    counts: np.ndarray         # (N,) final participation counts

    @property
    def best_loss(self) -> float:
        return float(np.nanmin(self.val_loss))

    @property
    def rounds(self) -> np.ndarray:
        """Rounds with recorded eval."""
        return np.flatnonzero(np.isfinite(self.val_loss))

    def sampled(self, t: int) -> np.ndarray:
        """The round-t sampled set (pads stripped)."""
        return self.sel[t][self.valid[t]]


# ---------------------------------------------------------------- the program
def _build_simulate(ds: FedDataset, model: FedModel, cfg: ScanConfig,
                    use_masks: bool, with_memory: bool = False):
    """Closure-captures the (cell-shared) dataset and returns the pure
    ``simulate(cell) -> traj`` program to be jit'd / vmap'd.

    ``with_memory`` statically sizes the aggregator state's (N, P)
    update-memory panel: the engine compiles the panel-carrying variant
    only when a memory-family cell is actually in play (the common
    fedavg sweep keeps the pre-subsystem carry: params + counts + H)."""
    n = int(ds.n_clients)
    m = int(cfg.m)
    xs = jnp.asarray(ds.x)
    ys = jnp.asarray(ds.y)
    sizes_i = jnp.asarray(ds.sizes)
    sizes_f = jnp.asarray(ds.sizes, jnp.float32)
    xv = jnp.asarray(ds.x_val)
    yv = jnp.asarray(ds.y_val)
    # host-side f64 schedule cast to f32: bit-identical to FLEngine's
    # per-round ``jnp.float32(lr * decay ** t)``
    lrs = jnp.asarray([np.float32(cfg.lr * cfg.lr_decay ** t)
                       for t in range(cfg.rounds)])
    trainer = make_local_trainer(model.loss, local_steps=cfg.local_steps,
                                 batch_size=cfg.batch_size,
                                 prox_mu=cfg.prox_mu)
    dynamic = cfg.graph_refresh_every > 0
    if dynamic:
        # shared Gaussian probe batch (Eq. 12), engine-level constant —
        # FLEngine re-draws it per run seed; the scan engine fixes probe_seed
        # so one compiled program serves every cell (DESIGN.md §5)
        rng = np.random.default_rng(cfg.probe_seed)
        flat = np.asarray(ds.x_val, np.float64).reshape(len(ds.x_val), -1)
        mu, cov = flat.mean(0), np.cov(flat.T) + 1e-4 * np.eye(flat.shape[1])
        probe = rng.multivariate_normal(mu, cov, cfg.probe_size)
        probe = jnp.asarray(
            probe.reshape(cfg.probe_size, *ds.x_val.shape[1:]), jnp.float32)

    # the shared device-native 3DG pipeline (core/graph_device.py) — the same
    # stages engine._rebuild_dynamic_graph / fedsim.graph_pipeline compose
    gcfg = GraphConfig(eps=cfg.graph_eps, sigma2=cfg.graph_sigma2,
                       similarity="functional")

    def rebuild_h(emb):
        return build_h(emb, gcfg, backend=cfg.graph_backend)

    def embed_mean(stacked):
        return jax.vmap(lambda p: jnp.mean(model.embed(p, probe), 0))(stacked)

    def select(s):
        return select_k(s, m)

    d_cand = int(min(n, max(m, cfg.poc_d_factor * m)))

    def probe_losses(inputs, idx, keys):
        """Global-model loss on a probe batch of each candidate's local
        data — the in-scan analogue of fed.client.make_loss_prober (the
        PoC branch of the sampler switch calls this)."""
        params = inputs["params"]

        def one(x, y, n_k, key):
            b = jax.random.randint(key, (cfg.poc_probe,), 0,
                                   jnp.maximum(n_k, 1))
            return model.loss(params, x[b], y[b])
        return jax.vmap(one)(xs[idx], ys[idx], sizes_i[idx], keys)

    # the ONE sampler step — lax.switch over the cell's family index, so
    # cells of DIFFERENT samplers batch through one run_batch program
    # (core/sampler_device.make_sampler_step)
    sampler_step = make_sampler_step(
        n, m, max_sweeps=cfg.max_sweeps, d_cand=d_cand,
        probe_losses=probe_losses, solver_backend=cfg.solver_backend)

    # ... and the ONE aggregator step (fed/aggregator_device): the server
    # update is a per-cell lax.switch too, so mixed-aggregator cells batch;
    # the aggregator state's ``prev`` slot doubles as the param carry
    agg_step = make_aggregator_step(
        n, m, jax.eval_shape(model.init, jax.random.PRNGKey(0)),
        data_sizes=ds.sizes, backend=cfg.agg_backend,
        memory_enabled=with_memory)

    def simulate(cell):
        key0 = cell["key"]
        params0 = model.init(key0)
        counts0 = jnp.zeros((n,), jnp.float32)

        if dynamic:
            # init: one all-clients probe round from a fresh model (the
            # paper's everyone-available-at-init assumption), as in
            # FLEngine.install_dynamic_graph
            ikey = cell["init_key"]
            stacked = trainer(model.init(ikey), xs, ys, sizes_i,
                              jnp.float32(cfg.lr), jax.random.split(ikey, n))
            emb0 = embed_mean(stacked)
            h0 = rebuild_h(emb0)
        else:
            emb0 = jnp.zeros((1, 1), jnp.float32)
            h0 = cell["h"]

        def step(carry, sx):
            astate, counts, h, emb, pstate, sstate = carry
            params = astate["prev"]        # the aggregator state IS the
            t, lr = sx["t"], sx["lr"]      # global-params carry
            key = jax.random.fold_in(key0, t)

            # 1. availability A_t — the shared device-native process draw
            # (core/availability_device.proc_draw: family step -> Bernoulli
            # -> force-one); the process state rides the scan carry
            if use_masks:
                avail = sx["mask"]
            else:
                avail, pstate = proc_draw(
                    cell["proc"], pstate,
                    jax.random.fold_in(cell["avail_key"], t), t)

            # 2. sampler: S_t subset of A_t, |S_t| = min(M, |A_t|) — the
            # switch step dispatches on the cell's family; the sampler
            # state rides the scan carry like the availability state
            skey = jax.random.fold_in(cell["sampler_key"], t)
            s, sstate = sampler_step(
                cell["sampler"], sstate, skey,
                {"h": h, "counts": counts, "params": params}, avail, t)
            sel, valid = select(s)

            # 3. vmap'd local training on the M gathered clients
            key, sub = jax.random.split(key)
            local = trainer(params, xs[sel], ys[sel], sizes_i[sel], lr,
                            jax.random.split(sub, m))

            # 4. server update — the aggregator switch step dispatches on
            # the cell's family (Eq. 18 weights: pads carry zero weight;
            # the fedavg branch is bit-identical to the legacy aggregate())
            params, astate = agg_step(
                cell["agg"], astate, jax.random.fold_in(cell["agg_key"], t),
                local, sizes_f[sel] * valid, s, avail, t, sel, valid)

            # 5. count update v^{t+1}
            counts = counts + s.astype(jnp.float32)

            # dynamic 3DG: refresh participants' embeddings; rebuild every K
            if dynamic:
                e_sel = embed_mean(local)
                emb = emb.at[sel].set(
                    jnp.where(valid[:, None], e_sel, emb[sel]))
                h = jax.lax.cond(
                    (t + 1) % cfg.graph_refresh_every == 0,
                    rebuild_h, lambda e: h, emb)

            # 6. eval (cond-gated to the eval_every cadence)
            def do_eval(_):
                return model.loss(params, xv, yv), model.accuracy(params, xv, yv)

            if cfg.eval_every == 1:
                vl, va = do_eval(None)
            else:
                vl, va = jax.lax.cond(
                    (jnp.mod(t, cfg.eval_every) == 0) | (t == cfg.rounds - 1),
                    do_eval,
                    lambda _: (jnp.float32(jnp.nan), jnp.float32(jnp.nan)),
                    None)
            # fairness metrics — the shared device twins (core/fairness.py)
            cvar = count_variance_device(counts)
            gini = gini_device(counts)
            out = {"val_loss": vl, "val_acc": va, "count_var": cvar,
                   "gini": gini, "sel": sel.astype(jnp.int32), "valid": valid}
            return (astate, counts, h, emb, pstate, sstate), out

        sxs = {"t": jnp.arange(cfg.rounds), "lr": lrs}
        if use_masks:
            sxs["mask"] = cell["masks"]
        pstate0 = cell.get("proc_state", {})
        sstate0 = cell.get("sampler_state", {})
        astate0 = init_agg_state(params0, n,
                                 memory_rows=n if with_memory else 0)
        (astate, counts, _, _, _, _), traj = jax.lax.scan(
            step, (astate0, counts0, h0, emb0, pstate0, sstate0), sxs)
        return {"params": astate["prev"], "counts": counts, **traj}

    return simulate


# ------------------------------------------------------------------- engine
class ScanEngine:
    """Host-facing wrapper: builds cells, compiles the scanned program once,
    and runs single cells or whole batched sweeps."""

    def __init__(self, ds: FedDataset, model: FedModel, cfg: ScanConfig, *,
                 use_masks: bool = False):
        self.ds, self.model, self.cfg = ds, model, cfg
        self.n = ds.n_clients
        self.use_masks = use_masks
        self._sims: dict = {}         # with_memory -> simulate closure
        self._jits: dict = {}         # (with_memory, batched) -> jit'd fn

    def _program(self, cells: list[dict], batched: bool):
        """The compiled program variant for these cells: the (N, P)
        update-memory panel rides the scan carry ONLY when a memory-family
        cell (or the engine default) asks for it — the common fedavg sweep
        keeps the lean carry."""
        midx = AGGREGATORS.index("memory")
        wm = self.cfg.aggregator == "memory" or any(
            int(np.asarray(c["agg"]["family"])) == midx for c in cells)
        key = (wm, batched)
        if key not in self._jits:
            if wm not in self._sims:
                self._sims[wm] = _build_simulate(
                    self.ds, self.model, self.cfg, self.use_masks,
                    with_memory=wm)
            fn = self._sims[wm]
            self._jits[key] = jax.jit(jax.vmap(fn) if batched else fn)
        return self._jits[key]

    # ------------------------------------------------------------- cells
    def cell(self, *, seed: int = 0, mode: Optional[AvailabilityMode] = None,
             process: Optional[AvailabilityProcess] = None,
             masks: Optional[np.ndarray] = None, alpha: float = 1.0,
             h: Optional[np.ndarray] = None, avail_seed: int = 1234,
             sampler_seed: Optional[int] = None,
             sampler_process: Optional[SamplerProcess] = None,
             aggregator_process: Optional[AggregatorProcess] = None) -> dict:
        """One sweep cell = (seed, availability, sampler params) pytree.

        Mask path (``use_masks=True``): pass ``masks`` (rounds, N), e.g. from
        ``precompute_masks`` for bit-exact FLEngine availability.  Device
        path: pass ``process`` (any ``AvailabilityProcess`` scenario family)
        or ``mode`` (a legacy Table-1 mode, wrapped as its ``TableProcess``);
        the cell carries the process params + initial state
        (``init(PRNGKey(avail_seed))``) and per-round draws use the
        ``fold_in(avail_seed, t)`` jax stream.  Cells of different scenario
        families batch together in ``run_batch``.

        The SAMPLER is a per-cell choice too: ``sampler_process`` (any
        ``core.sampler_device.SamplerProcess``; defaults to the engine-level
        ``cfg.sampler`` family with this cell's ``alpha``) compiles to a
        ``lax.switch`` index, so cells of different samplers batch through
        one ``run_batch`` program.  Because every branch traces, EVERY cell
        carries the full (N, N) ``h`` (zeros when no FedGS cell needs it).

        The AGGREGATOR is a per-cell choice the same way:
        ``aggregator_process`` (any ``fed.aggregator_device
        .AggregatorProcess``; defaults to the engine-level
        ``cfg.aggregator`` family) compiles to a ``lax.switch`` index, so
        cells of different server-update rules batch through one
        ``run_batch`` program; the aggregator state is built in-scan from
        the cell's own ``params0``, and its (N, P) update-memory panel is
        carried only by the program variant that actually has a
        memory-family cell (``_program``).
        """
        c: dict = {"key": jax.random.PRNGKey(seed)}
        if self.use_masks:
            assert masks is not None and masks.shape == (self.cfg.rounds, self.n)
            c["masks"] = jnp.asarray(masks, bool)
        else:
            if process is None:
                assert mode is not None, \
                    "device-side availability needs a process or a mode"
                process = mode.process()
            c["avail_key"] = jax.random.PRNGKey(avail_seed)
            c["proc"] = process.params()
            c["proc_state"] = process.init(c["avail_key"])
        sproc = sampler_process if sampler_process is not None else \
            make_sampler_process(self.cfg.sampler, alpha=alpha,
                                 d_factor=self.cfg.poc_d_factor)
        c["sampler"] = sproc.params(data_sizes=self.ds.sizes)
        c["sampler_key"] = jax.random.PRNGKey(
            seed + 0x5E1EC7 if sampler_seed is None else sampler_seed)
        c["sampler_state"] = sproc.init(c["sampler_key"])
        aproc = aggregator_process if aggregator_process is not None else \
            make_aggregator_process(self.cfg.aggregator)
        c["agg"] = aproc.params()
        c["agg_key"] = jax.random.PRNGKey(seed + 0xA66)
        if self.cfg.graph_refresh_every > 0:
            c["init_key"] = jax.random.PRNGKey(seed + 778)
        elif h is not None:
            c["h"] = jnp.asarray(h, jnp.float32)
        else:
            assert sproc.family != "fedgs", \
                "static FedGS cell needs a normalized H"
            c["h"] = jnp.zeros((self.n, self.n), jnp.float32)
        return c

    # -------------------------------------------------------------- runs
    def _to_history(self, out, i: Optional[int] = None) -> ScanHistory:
        pick = (lambda x: np.asarray(x)) if i is None else \
               (lambda x: np.asarray(x[i]))
        return ScanHistory(val_loss=pick(out["val_loss"]),
                           val_acc=pick(out["val_acc"]),
                           count_var=pick(out["count_var"]),
                           gini=pick(out["gini"]),
                           sel=pick(out["sel"]), valid=pick(out["valid"]),
                           counts=pick(out["counts"]))

    def run(self, cell: dict) -> ScanHistory:
        """Execute one cell; the whole trajectory is a single device program."""
        out = jax.block_until_ready(self._program([cell], False)(cell))
        self.params = out["params"]
        return self._to_history(out)

    def run_batch(self, cells: list[dict]) -> list[ScanHistory]:
        """Execute B cells as ONE vmapped-and-scanned XLA program."""
        fn = self._program(cells, True)
        out = jax.block_until_ready(fn(stack_cells(cells)))
        self.params = out["params"]           # (B, ...) stacked
        return [self._to_history(out, i) for i in range(len(cells))]

    def lower_batch(self, cells: list[dict]):
        """Lower (without running) — for compile-time measurement."""
        return self._program(cells, True).lower(stack_cells(cells))

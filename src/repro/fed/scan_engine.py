"""Fully jit-compiled federated simulation: scan-over-rounds, vmap-over-cells.

``FLEngine.run`` (fed/engine.py) is a per-round Python loop: every round pays
a dispatch + host<->device sync for the sampler, the trainer and the eval, and
a sweep like Table 2 (samplers x availability modes x seeds) runs each cell
serially.  This module moves the *entire* round loop onto the device:

  one ``lax.scan`` step = availability draw -> sampler -> vmap'd local
  training (E SGD steps) -> server update (aggregator switch; Eq. 18
  default) -> count update -> eval,

all with static shapes, and the scanned program is then ``vmap``-ed over a
batch axis of *cells* — (seed, availability mode, FedGS alpha) triples — so a
whole sweep row executes as ONE XLA program (DESIGN.md §5).

Static-shape formulation
  The sampler emits a boolean mask s (N,) with |s| = min(M, |A_t|); the M
  sorted selected indices (padded with zero-weight slots when |A_t| < M) are
  gathered so local training always runs on exactly M stacked clients, and
  Eq. 18 weights ``n_k * valid_k`` zero out the pads.

Seed streams (parity with FLEngine)
  The training stream replicates FLEngine.run exactly: ``key_t = fold_in(
  PRNGKey(seed), t)``, then ``_, sub = split(key_t)`` and per-client keys
  ``split(sub, M)`` — so with the same sampled sets the parameter trajectory
  matches the host engine to float32 round-off, PROVIDED every round has
  |A_t| >= M: FLEngine splits ``split(sub, |S_t|)`` and threefry key prefixes
  depend on the split count, so rounds where fewer than M clients are
  available draw different local-training batches (still a valid simulation,
  just not bit-parity — the parity tests assert the precondition).  Availability either comes
  from host-precomputed masks (``precompute_masks`` = the shared host
  wrapper ``availability.host_trace``, bit-identical to FLEngine's numpy
  SeedSequence([avail_seed, t]) stream — the parity-test path) or is drawn
  on-device by an ``AvailabilityProcess``
  (``core.availability_device``): the cell carries the process params +
  carried state, the scan body calls the one shared ``proc_draw`` (family
  step -> Bernoulli -> force-one), and because every family compiles to the
  same ``lax.switch`` program, cells of DIFFERENT scenario families —
  legacy periodic tables, Gilbert–Elliott churn, cluster outages, drift,
  deadlines — vmap-batch through one ``run_batch`` program.  The SAMPLER is
  the same kind of per-cell switch (``core.sampler_device``): each cell
  carries a ``SamplerProcess`` params pytree + in-scan state, and the one
  ``make_sampler_step`` program dispatches Uniform / MD (Gumbel top-k),
  Power-of-Choice (d·m Gumbel candidates + in-scan loss probe + top-m
  keep) and FedGS (the deterministic ``fedgs_solve``, so FedGS cells match
  the host engine's sampled sets exactly; ``ScanConfig.solver_backend``
  routes the Eq. 16 solve through the tiled Pallas kernels) — so
  MIXED-SAMPLER cell batches execute as one XLA program too.  The SERVER
  UPDATE is the third per-cell switch (``fed.aggregator_device``): each
  cell carries an ``AggregatorProcess`` params pytree and the in-scan
  aggregator state (previous params — which double as the param carry —
  momentum/Adam moments, the (N, P) update-memory panel), and the one
  ``make_aggregator_step`` program dispatches FedAvg (bit-parity with the
  legacy Eq. 18 path), FedAvgM, FedAdam, proximal-weighted averaging and
  the FedAR/MIFA-style memory-rectified reduction
  (``ScanConfig.agg_backend`` routes the memory scatter+reduce through the
  tiled Pallas kernel) — so MIXED-AGGREGATOR cell batches are one XLA
  program as well.

Dynamic 3DG
  With ``graph_refresh_every > 0`` the 3DG is maintained *inside* the scan:
  participants' post-training probe embeddings update a carried (N, C)
  embedding table and every K rounds ``core.graph_device.build_h`` (the one
  shared functional-similarity -> adjacency -> Floyd–Warshall -> finite-cap
  pipeline) rebuilds the carried H under ``lax.cond``.
  ``ScanConfig.graph_backend="pallas"`` routes the rebuild's similarity
  matmul and APSP through the tiled kernels for large-N sweeps.

Mesh scale-out (DESIGN.md §13)
  ``ScanConfig.mesh=(cells,)`` or ``(cells, silo)`` runs ``run_batch``
  under ``jax.experimental.shard_map`` on ``launch.mesh.make_engine_mesh``:
  sweep cells shard over the "cells" axis (embarrassingly parallel —
  per-cell subsystem state stays device-local; uneven batches are padded by
  repeating the last cell and the pad trajectories dropped), and the "silo"
  axis row-shards the vmap'd local-training client axis (each silo trains
  its M/s chunk and ``all_gather``s the stacked updates — bitwise equal to
  the single-device program by construction).  ``silo_reduce="psum"``
  additionally row-shards the memory aggregator's (N, P) panel, turning the
  staleness reduction into partial tensordots + a ``psum`` (numerically
  equal, not bitwise — same contract as the Pallas backend's tile-order
  partial sums).

Exact-resume checkpointing (DESIGN.md §13)
  ``run_batch(cells, ckpt_path=..., ckpt_every=k, resume=...)`` executes
  the scan in k-round segments (``lax.scan`` over a ``t0 + arange(k)``
  window — every per-round stream is keyed ``fold_in(key, t)`` with NO
  cross-round rng state, so a resume replays the identical per-round
  computation) and checkpoints the FULL carry — aggregator slots incl.
  momentum/Adam/memory panel, availability-chain state, sampler state,
  counts, H, embeddings — plus the accumulated trajectory and round index
  through ``checkpoint.ckpt``.  A same-mesh same-cadence resume is bitwise
  equal to the uninterrupted segmented run.  Saving gathers shards to host
  npz (device-layout-free), so a run may resume on a DIFFERENT device
  count / mesh (the loaded carry is resharded to the target program's
  specs); cross-device-count runs are bitwise at ``ckpt_every=1`` — XLA
  fuses a multi-round scan's while-body differently per SPMD partition
  count and scan length (ulp-level eval drift, decisions unaffected), but
  one-round segments compile identically everywhere and chain exactly.

Typical use::

    eng = ScanEngine(ds, model, ScanConfig(rounds=60, m=6, sampler="fedgs"))
    cells = [eng.cell(seed=s, mode=mode, alpha=1.0, h=h) for s in (0, 1, 2)]
    hists = eng.run_batch(cells)          # one compiled program, B cells
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.checkpoint.ckpt import load_checkpoint, save_checkpoint
from repro.fed.runtime import (
    AsyncCheckpointWriter, CarryHandle, ProgramCache, enable_compile_cache,
)
from repro.fed.telemetry import (
    NULL_TRACER, fault_corruption_norm, round_telemetry, runtime_snapshot,
)
from repro.core.availability import AvailabilityMode, host_trace
from repro.core.availability_device import AvailabilityProcess, proc_draw
from repro.core.graph_device import (
    BACKENDS, GraphConfig, build_h, cap_and_normalize,
)
from repro.core.sampler_device import (
    FAMILIES, SamplerProcess, make_sampler_process, make_sampler_step,
    select_k,
)
from repro.core.fairness import count_variance_device, gini_device
from repro.data.fed_dataset import FedDataset
from repro.fed.aggregator_device import (
    AggregatorProcess, _flat_template, init_agg_state,
    make_aggregator_process, make_aggregator_step,
)
from repro.fed.aggregator_device import FAMILIES as AGG_FAMILIES
from repro.fed.faults_device import (
    FaultProcess, init_fault_state, make_fault_process, make_fault_step,
)
from repro.fed.faults_device import FAMILIES as FAULT_FAMILIES
from repro.fed.client import make_local_trainer
from repro.fed.models import FedModel
from repro.launch.mesh import make_engine_mesh
from repro.sharding.rules import (
    ENGINE_SILO_AXIS, engine_batch_spec, engine_carry_specs,
)

SAMPLERS = FAMILIES            # ("fedgs", "uniform", "md", "poc")
AGGREGATORS = AGG_FAMILIES     # ("fedavg", "fedavgm", "fedadam",
                               #  "fedprox_w", "memory", "median",
                               #  "trimmed_mean", "krum")
FAULTS = FAULT_FAMILIES        # ("none", "sign_flip", "gaussian_noise",
                               #  "scaled", "straggler_stale")
SILO_REDUCES = ("gather", "psum")


@dataclass(frozen=True)
class ScanConfig:
    """Static (compile-time) configuration of the scanned program."""
    rounds: int = 200
    m: int = 3                     # sampled clients per round (static shape M)
    local_steps: int = 10          # E
    batch_size: int = 10
    lr: float = 0.1
    lr_decay: float = 0.998
    prox_mu: float = 0.0
    eval_every: int = 1            # in-scan eval cadence (NaN on off rounds)
    sampler: str = "fedgs"         # fedgs | uniform | md | poc
    max_sweeps: int = 32           # FedGS local-search budget
    # Power-of-Choice: d·m candidates by data size, in-scan loss probe
    poc_d_factor: int = 2
    poc_probe: int = 64            # loss-probe batch per candidate
    # dynamic 3DG: rebuild H in-scan from participants' probe embeddings
    # every K rounds (0 = static graph installed via the cell's ``h``)
    graph_refresh_every: int = 0
    graph_eps: float = 0.1
    graph_sigma2: float = 0.01
    graph_backend: str = "ref"     # ref | pallas (dynamic-3DG rebuild path)
    solver_backend: str = "ref"    # ref | pallas (FedGS Eq. 16 solve)
    aggregator: str = "fedavg"     # fedavg | fedavgm | fedadam | fedprox_w
                                   # | memory | median | trimmed_mean | krum
                                   # (per-cell overridable)
    agg_backend: str = "ref"       # ref | pallas (memory scatter+reduce and
                                   # krum distance panel)
    # fault injection (fed/faults_device): engine-level default family +
    # Byzantine fraction, per-cell overridable via cell(fault_process=...)
    fault: str = "none"            # none | sign_flip | gaussian_noise |
                                   # scaled | straggler_stale
    fault_frac: float = 0.0        # adversarial client fraction (ceil(f*N))
    probe_size: int = 64
    probe_seed: int = 777
    # mesh scale-out (DESIGN.md §13): (cells,) or (cells, silo) device grid
    # for shard_map'd run_batch; None = single-device (the default)
    mesh: Optional[tuple] = None
    cell_sharding: bool = True     # shard the cell-batch axis over "cells"
    silo_reduce: str = "gather"    # gather (bitwise) | psum (panel-sharded)
    # runtime layer (DESIGN.md §15): donate the scan carry into each
    # segment program (in-place HBM reuse; use-after-donation raises via
    # CarryHandle), overlap device compute with host traj fetch + async
    # checkpoint writes, persist XLA compiles across processes, and bound
    # the in-process program cache
    donate_carry: bool = True
    async_pipeline: bool = True
    compile_cache_dir: Optional[str] = None
    program_cache_size: int = 32
    # in-scan telemetry channel (DESIGN.md §17): opt-in per-round stage
    # health metrics (update norms / NaN fraction / clip rate, sampler
    # dispersion, availability rate, weight entropy, staleness histogram,
    # fault magnitude) captured alongside the ScanHistory trajectory.
    # Gated like the fault carry: telemetry=False programs, outputs and
    # checkpoints are bitwise untouched (assumption log #24)
    telemetry: bool = False
    telemetry_clip_thresh: float = 10.0   # client-update-norm clip probe

    def __post_init__(self):
        if self.sampler not in SAMPLERS:
            raise ValueError(f"scan engine supports {SAMPLERS}, "
                             f"not {self.sampler!r}")
        if self.aggregator not in AGGREGATORS:
            raise ValueError(f"scan engine supports {AGGREGATORS}, "
                             f"not {self.aggregator!r}")
        for knob in ("graph_backend", "solver_backend", "agg_backend"):
            if getattr(self, knob) not in BACKENDS:
                raise ValueError(f"{knob} must be one of {BACKENDS}, "
                                 f"not {getattr(self, knob)!r}")
        if self.silo_reduce not in SILO_REDUCES:
            raise ValueError(f"silo_reduce must be one of {SILO_REDUCES}, "
                             f"not {self.silo_reduce!r}")
        if self.fault not in FAULTS:
            raise ValueError(f"scan engine supports faults {FAULTS}, "
                             f"not {self.fault!r}")
        if not 0.0 <= self.fault_frac <= 1.0:
            raise ValueError(f"fault_frac must be in [0, 1], "
                             f"not {self.fault_frac!r}")
        if self.program_cache_size < 1:
            raise ValueError(f"program_cache_size must be >= 1, "
                             f"not {self.program_cache_size!r}")
        if self.mesh is not None:
            shape = tuple(int(s) for s in self.mesh)
            if len(shape) not in (1, 2) or any(s < 1 for s in shape):
                raise ValueError(f"mesh must be (cells,) or (cells, silo) "
                                 f"with positive sizes, not {self.mesh!r}")
            object.__setattr__(self, "mesh",
                               shape if len(shape) == 2 else shape + (1,))


# --------------------------------------------------------------- host helpers
def precompute_masks(mode, rounds: int, avail_seed: int = 1234) -> np.ndarray:
    """(rounds, N) bool availability trace, bit-identical to the stream
    FLEngine.run draws — both route through the ONE host wrapper
    ``availability.host_draw`` / ``host_trace``.  ``mode`` is anything with
    ``sample(t, rng)``: an ``AvailabilityMode`` or a ``ProcessMode`` over a
    stateful scenario family."""
    return host_trace(mode, rounds, avail_seed)


def normalized_h(h: np.ndarray) -> np.ndarray:
    """Finite-cap + [0, 1]-normalize a shortest-path matrix — the SAME
    ``graph_device.cap_and_normalize`` stage FedGSSampler.set_graph runs
    (DESIGN.md assumption log)."""
    return np.asarray(cap_and_normalize(jnp.asarray(h, jnp.float32)))


def oracle_h(features: np.ndarray, *, eps: float = 0.1, sigma2: float = 0.01,
             backend: str = "ref") -> np.ndarray:
    """Oracle 3DG -> normalized H (the scan-engine analogue of
    FLEngine.install_oracle_graph)."""
    cfg = GraphConfig(eps=eps, sigma2=sigma2, similarity="dot")
    return np.asarray(build_h(jnp.asarray(features, jnp.float32), cfg,
                              backend=backend))


def stack_cells(cells: list[dict]) -> dict:
    """Stack per-cell pytrees along a new leading batch axis, zero-padding
    the availability-process tables to a common period (rows beyond a
    cell's own period are never indexed because lookups are
    ``table[t % period]``) — this is what lets cells of different scenario
    families, with different table periods, batch into ONE program."""
    if "proc" in cells[0]:
        pmax = max(int(c["proc"]["table"].shape[0]) for c in cells)
        cells = [dict(c, proc=dict(c["proc"])) for c in cells]
        for c in cells:
            for k in ("table", "table_b"):
                tab = c["proc"][k]
                p = int(tab.shape[0])
                if p < pmax:
                    c["proc"][k] = jnp.concatenate(
                        [tab, jnp.zeros((pmax - p,) + tab.shape[1:],
                                        tab.dtype)])
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *cells)


# ------------------------------------------------------------------ histories
@dataclass
class ScanHistory:
    """Device-side trajectory of one cell (full-round resolution; eval
    entries are NaN on rounds skipped by ``eval_every``)."""
    val_loss: np.ndarray       # (T,)
    val_acc: np.ndarray        # (T,)
    count_var: np.ndarray      # (T,)
    gini: np.ndarray           # (T,) Gini coefficient of the counts
    sel: np.ndarray            # (T, M) sorted selected indices (padded)
    valid: np.ndarray          # (T, M) pad mask (False = zero-weight slot)
    counts: np.ndarray         # (N,) final participation counts
    # opt-in per-round stage-health metrics (ScanConfig.telemetry;
    # DESIGN.md §17): {name: (T,) or (T, bins) array} — None when the
    # telemetry channel is off.  Rounds before a resume point are NaN
    # (telemetry is observability, not state: it is NOT checkpointed)
    telemetry: Optional[dict] = None

    @property
    def best_loss(self) -> float:
        return float(np.nanmin(self.val_loss))

    @property
    def rounds(self) -> np.ndarray:
        """Rounds with recorded eval."""
        return np.flatnonzero(np.isfinite(self.val_loss))

    def sampled(self, t: int) -> np.ndarray:
        """The round-t sampled set (pads stripped)."""
        return self.sel[t][self.valid[t]]


# ---------------------------------------------------------------- the program
def _build_simulate(ds: FedDataset, model: FedModel, cfg: ScanConfig,
                    use_masks: bool, with_memory: bool = False, *,
                    with_fault: bool = False, with_stale: bool = False,
                    with_telemetry: bool = False,
                    silo: int = 1, panel_axis: Optional[str] = None):
    """Closure-captures the (cell-shared) dataset and returns the pure
    per-cell closures the engine jit/vmap/shard_maps:

      ``init(cell) -> carry``            the full scan carry (dict pytree:
                                         aggregator state incl. params,
                                         counts, H, embeddings, availability
                                         + sampler state)
      ``segment(seg_len)(cell, carry, t0) -> (carry, traj)``
                                         ``seg_len`` rounds starting at
                                         ``t0`` — the checkpoint/resume unit
      ``simulate(cell) -> out``          init + one full-run segment

    Segmenting is exact because every per-round stream is ``fold_in(key,
    t)``-keyed off the round index alone (no cross-round rng carry), and
    the lr schedule / mask table are indexed by the global ``t`` inside the
    step body — a ``(k)+(T-k)`` split replays the identical per-round
    computation.

    ``with_memory`` statically sizes the aggregator state's (N, P)
    update-memory panel: the engine compiles the panel-carrying variant
    only when a memory-family cell is actually in play (the common
    fedavg sweep keeps the pre-subsystem carry: params + counts + H).
    ``with_fault`` / ``with_stale`` gate the fault-injection seam the same
    way: only a batch with an actual fault cell carries the fault state
    (and only a straggler cell carries the (N, P) stale-update panel), so
    the benign default program — and its checkpoints — are unchanged.
    ``with_telemetry`` gates the in-scan health channel identically
    (``ScanConfig.telemetry``): the step emits an extra per-round metrics
    pytree under ``out["telemetry"]`` — pure reductions over
    intermediates the step already materializes, NO new carry state — so
    a telemetry-off program, its history fields and its checkpoints are
    bitwise untouched (DESIGN.md §17, assumption log #24).

    ``silo > 1`` chunks the vmap'd local-training client axis over the
    shard_map "silo" mesh axis (each silo trains ceil(M/s) clients with the
    SAME per-client fold_in keys, then ``all_gather``s the stacked updates
    — bitwise equal to the unsharded program); ``panel_axis`` additionally
    row-shards the memory panel (see ``make_aggregator_step``)."""
    n = int(ds.n_clients)
    m = int(cfg.m)
    xs = jnp.asarray(ds.x)
    ys = jnp.asarray(ds.y)
    sizes_i = jnp.asarray(ds.sizes)
    sizes_f = jnp.asarray(ds.sizes, jnp.float32)
    xv = jnp.asarray(ds.x_val)
    yv = jnp.asarray(ds.y_val)
    # host-side f64 schedule cast to f32: bit-identical to FLEngine's
    # per-round ``jnp.float32(lr * decay ** t)``
    lrs = jnp.asarray([np.float32(cfg.lr * cfg.lr_decay ** t)
                       for t in range(cfg.rounds)])
    trainer = make_local_trainer(model.loss, local_steps=cfg.local_steps,
                                 batch_size=cfg.batch_size,
                                 prox_mu=cfg.prox_mu)
    dynamic = cfg.graph_refresh_every > 0
    if dynamic:
        # shared Gaussian probe batch (Eq. 12), engine-level constant —
        # FLEngine re-draws it per run seed; the scan engine fixes probe_seed
        # so one compiled program serves every cell (DESIGN.md §5)
        rng = np.random.default_rng(cfg.probe_seed)
        flat = np.asarray(ds.x_val, np.float64).reshape(len(ds.x_val), -1)
        mu, cov = flat.mean(0), np.cov(flat.T) + 1e-4 * np.eye(flat.shape[1])
        probe = rng.multivariate_normal(mu, cov, cfg.probe_size)
        probe = jnp.asarray(
            probe.reshape(cfg.probe_size, *ds.x_val.shape[1:]), jnp.float32)

    # the shared device-native 3DG pipeline (core/graph_device.py) — the same
    # stages engine._rebuild_dynamic_graph / fedsim.graph_pipeline compose
    gcfg = GraphConfig(eps=cfg.graph_eps, sigma2=cfg.graph_sigma2,
                       similarity="functional")

    def rebuild_h(emb):
        return build_h(emb, gcfg, backend=cfg.graph_backend)

    def embed_mean(stacked):
        return jax.vmap(lambda p: jnp.mean(model.embed(p, probe), 0))(stacked)

    def select(s):
        return select_k(s, m)

    d_cand = int(min(n, max(m, cfg.poc_d_factor * m)))

    def probe_losses(inputs, idx, keys):
        """Global-model loss on a probe batch of each candidate's local
        data — the in-scan analogue of fed.client.make_loss_prober (the
        PoC branch of the sampler switch calls this)."""
        params = inputs["params"]

        def one(x, y, n_k, key):
            b = jax.random.randint(key, (cfg.poc_probe,), 0,
                                   jnp.maximum(n_k, 1))
            return model.loss(params, x[b], y[b])
        return jax.vmap(one)(xs[idx], ys[idx], sizes_i[idx], keys)

    # the ONE sampler step — lax.switch over the cell's family index, so
    # cells of DIFFERENT samplers batch through one run_batch program
    # (core/sampler_device.make_sampler_step)
    sampler_step = make_sampler_step(
        n, m, max_sweeps=cfg.max_sweeps, d_cand=d_cand,
        probe_losses=probe_losses, solver_backend=cfg.solver_backend)

    # ... and the ONE aggregator step (fed/aggregator_device): the server
    # update is a per-cell lax.switch too, so mixed-aggregator cells batch;
    # the aggregator state's ``prev`` slot doubles as the param carry
    agg_step = make_aggregator_step(
        n, m, jax.eval_shape(model.init, jax.random.PRNGKey(0)),
        data_sizes=ds.sizes, backend=cfg.agg_backend,
        memory_enabled=with_memory, panel_axis=panel_axis)

    # ... and the fault-injection seam (fed/faults_device) BETWEEN local
    # training and aggregation: per-cell lax.switch over the fault family,
    # operating on the flat (M, P) update panel.  The ravel->unravel
    # round-trip is a bitwise identity, so benign cells inside a faulted
    # batch match their no-fault program bitwise.
    if with_fault:
        fault_step = make_fault_step(n, m, stale_enabled=with_stale)
        fravel, funravel, _ = _flat_template(
            jax.eval_shape(model.init, jax.random.PRNGKey(0)))
    if panel_axis is not None and n % silo:
        raise ValueError(f"silo_reduce='psum' row-shards the (N, P) memory "
                         f"panel: N={n} must divide by silo={silo}")
    mem_rows = (n // silo if panel_axis is not None else n) \
        if with_memory else 0
    chunk = -(-m // silo)              # per-silo local-training clients

    def init(cell):
        """The FULL scan carry — everything a bitwise-exact resume needs
        (plus the round index and rng cell keys, which live in the cell /
        checkpoint metadata)."""
        key0 = cell["key"]
        params0 = model.init(key0)

        if dynamic:
            # init: one all-clients probe round from a fresh model (the
            # paper's everyone-available-at-init assumption), as in
            # FLEngine.install_dynamic_graph
            ikey = cell["init_key"]
            stacked = trainer(model.init(ikey), xs, ys, sizes_i,
                              jnp.float32(cfg.lr), jax.random.split(ikey, n))
            emb0 = embed_mean(stacked)
            h0 = rebuild_h(emb0)
        else:
            emb0 = jnp.zeros((1, 1), jnp.float32)
            h0 = cell["h"]
        astate0 = init_agg_state(params0, n, memory_rows=mem_rows,
                                 tau_rows=n if with_memory else 0)
        carry0 = {"agg": astate0,
                  "counts": jnp.zeros((n,), jnp.float32),
                  "h": h0, "emb": emb0,
                  "proc": cell.get("proc_state", {}),
                  "sampler": cell.get("sampler_state", {})}
        if with_fault:
            # latency chain from the cell's eager init; the (rows, P)
            # stale-update panel is sized here because P is model-dependent
            carry0["fault"] = init_fault_state(
                cell["fault_state"], params0, n if with_stale else 0)
        return carry0

    def step(cell, carry, t):
        astate, counts = carry["agg"], carry["counts"]
        h, emb = carry["h"], carry["emb"]
        pstate, sstate = carry["proc"], carry["sampler"]
        params = astate["prev"]        # the aggregator state IS the
        lr = lrs[t]                    # global-params carry
        key = jax.random.fold_in(cell["key"], t)

        # 1. availability A_t — the shared device-native process draw
        # (core/availability_device.proc_draw: family step -> Bernoulli
        # -> force-one); the process state rides the scan carry
        if use_masks:
            avail = cell["masks"][t]
        else:
            avail, pstate = proc_draw(
                cell["proc"], pstate,
                jax.random.fold_in(cell["avail_key"], t), t)

        # 2. sampler: S_t subset of A_t, |S_t| = min(M, |A_t|) — the
        # switch step dispatches on the cell's family; the sampler
        # state rides the scan carry like the availability state
        skey = jax.random.fold_in(cell["sampler_key"], t)
        s, sstate = sampler_step(
            cell["sampler"], sstate, skey,
            {"h": h, "counts": counts, "params": params}, avail, t)
        sel, valid = select(s)

        # 3. vmap'd local training on the M gathered clients — under a
        # silo'd mesh each shard trains its ceil(M/s) chunk (same
        # per-client keys) and all_gathers the stacked updates
        key, sub = jax.random.split(key)
        keys_m = jax.random.split(sub, m)
        if silo > 1:
            pad = chunk * silo - m
            sel_p = jnp.concatenate([sel, sel[-1:].repeat(pad, 0)]) \
                if pad else sel
            keys_p = jnp.concatenate([keys_m, keys_m[-1:].repeat(pad, 0)]) \
                if pad else keys_m
            i0 = jax.lax.axis_index(ENGINE_SILO_AXIS) * chunk
            sel_l = jax.lax.dynamic_slice_in_dim(sel_p, i0, chunk)
            keys_l = jax.lax.dynamic_slice_in_dim(keys_p, i0, chunk)
            local_l = trainer(params, xs[sel_l], ys[sel_l], sizes_i[sel_l],
                              lr, keys_l)
            local = jax.tree_util.tree_map(
                lambda a: jax.lax.all_gather(
                    a, ENGINE_SILO_AXIS, axis=0, tiled=True)[:m], local_l)
        else:
            local = trainer(params, xs[sel], ys[sel], sizes_i[sel], lr,
                            keys_m)

        # 3b. fault injection — the per-cell fault switch corrupts the
        # byz slots of the flat (M, P) update panel BETWEEN training and
        # aggregation (sign flips, noise, boosting, stale straggler
        # replays); benign cells pass through the identity branch
        fault_mag = None
        if with_fault:
            fstate = carry["fault"]
            cleanf = jax.vmap(fravel)(local)
            updf, fstate = fault_step(
                cell["fault"], fstate,
                jax.random.fold_in(cell["fault_key"], t),
                cleanf, fravel(params), avail, t, sel,
                valid)
            local = jax.vmap(funravel)(updf)
            if with_telemetry:
                # corruption magnitude at the seam, where the clean flat
                # panel is still in scope (DESIGN.md §17)
                fault_mag = fault_corruption_norm(updf, cleanf, valid)

        # 4. server update — the aggregator switch step dispatches on
        # the cell's family (Eq. 18 weights: pads carry zero weight;
        # the fedavg branch is bit-identical to the legacy aggregate())
        prev_params = params
        agg_w = sizes_f[sel] * valid
        params, astate = agg_step(
            cell["agg"], astate, jax.random.fold_in(cell["agg_key"], t),
            local, agg_w, s, avail, t, sel, valid)

        # 5. count update v^{t+1}
        counts = counts + s.astype(jnp.float32)

        # dynamic 3DG: refresh participants' embeddings; rebuild every K
        if dynamic:
            e_sel = embed_mean(local)
            emb = emb.at[sel].set(
                jnp.where(valid[:, None], e_sel, emb[sel]))
            h = jax.lax.cond(
                (t + 1) % cfg.graph_refresh_every == 0,
                rebuild_h, lambda e: h, emb)

        # 6. eval (cond-gated to the eval_every cadence)
        def do_eval(_):
            return model.loss(params, xv, yv), model.accuracy(params, xv, yv)

        if cfg.eval_every == 1:
            vl, va = do_eval(None)
        else:
            vl, va = jax.lax.cond(
                (jnp.mod(t, cfg.eval_every) == 0) | (t == cfg.rounds - 1),
                do_eval,
                lambda _: (jnp.float32(jnp.nan), jnp.float32(jnp.nan)),
                None)
        # fairness metrics — the shared device twins (core/fairness.py)
        cvar = count_variance_device(counts)
        gini = gini_device(counts)
        out = {"val_loss": vl, "val_acc": va, "count_var": cvar,
               "gini": gini, "sel": sel.astype(jnp.int32), "valid": valid}
        if with_telemetry:
            # the in-scan health channel (DESIGN.md §17): pure reductions
            # over this step's intermediates — consumers only, nothing
            # feeds back into the carry or the history fields above
            out["telemetry"] = round_telemetry(
                avail=avail, valid=valid, sel=sel, local=local,
                params_prev=prev_params, params_new=params, weights=agg_w,
                h=h, clip_thresh=cfg.telemetry_clip_thresh,
                tau=astate["tau"] if with_memory else None, t=t,
                fault_mag=fault_mag)
        carry1 = {"agg": astate, "counts": counts, "h": h, "emb": emb,
                  "proc": pstate, "sampler": sstate}
        if with_fault:
            carry1["fault"] = fstate
        return carry1, out

    def segment(seg_len: int):
        def run_segment(cell, carry, t0):
            return jax.lax.scan(lambda c, t: step(cell, c, t), carry,
                                t0 + jnp.arange(seg_len))
        return run_segment

    def simulate(cell):
        carry, traj = segment(cfg.rounds)(cell, init(cell), jnp.int32(0))
        return {"params": carry["agg"]["prev"], "counts": carry["counts"],
                **traj}

    return {"init": init, "segment": segment, "simulate": simulate}


# ------------------------------------------------------------------- engine
class ScanEngine:
    """Host-facing wrapper: builds cells, compiles the scanned program once,
    and runs single cells or whole batched sweeps — optionally shard_map'd
    over a ("cells", "silo") mesh and/or segmented for exact-resume
    checkpointing (DESIGN.md §13)."""

    def __init__(self, ds: FedDataset, model: FedModel, cfg: ScanConfig, *,
                 use_masks: bool = False, tracer=None, sink=None):
        self.ds, self.model, self.cfg = ds, model, cfg
        self.n = ds.n_clients
        self.use_masks = use_masks
        self._sims: dict = {}   # ((wm, wf, ws, wt), silo, panel) -> closures
        # program key -> jit'd fn: bounded LRU with hit/miss/compile-ms
        # counters (DESIGN.md §15) — the old unbounded dict leaked one
        # program per (seg_len, variant) across heterogeneous sweeps
        self._programs = ProgramCache(maxsize=cfg.program_cache_size)
        self._cspecs: dict = {}       # (flags, silo, panel) -> carry specs
        self._mesh_obj = None
        # observability spine (DESIGN.md §17): host span tracer + streaming
        # metrics sink — both default to no-ops, both hot-swappable
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.sink = sink
        self._tel_parts: list = []    # [(t0, k, telemetry_host)] per run
        self._writer_stats: Optional[dict] = None
        if cfg.compile_cache_dir is not None:
            enable_compile_cache(cfg.compile_cache_dir)

    def runtime_stats(self) -> dict:
        """The unified telemetry snapshot (DESIGN.md §17): program-cache
        counters FLAT at the top level (hits, misses, evictions, compiles,
        compile_ms, size — the pre-telemetry shape benchmarks read), plus
        the last run's checkpoint-writer backpressure counters and the
        tracer's per-span aggregates."""
        return runtime_snapshot(programs=self._programs,
                                writer=self._writer_stats,
                                tracer=self.tracer)

    def attach_sink(self, sink):
        """Install (or clear, with ``None``) the streaming metrics sink —
        per-segment round metrics flow through ``run_batch_stream``."""
        self.sink = sink

    # ----------------------------------------------------------- programs
    def _mesh(self):
        if self.cfg.mesh is None:
            return None
        if self._mesh_obj is None:
            self._mesh_obj = make_engine_mesh(self.cfg.mesh)
        return self._mesh_obj

    def _flags(self, cells: list[dict]) -> tuple:
        """Static program-variant flags for this batch: ``(wm, wf, ws,
        wt)`` — does any cell need the (N, P) update-memory panel / the
        fault seam / the straggler stale panel, and is the in-scan
        telemetry channel on?  Each flag widens the carry or the traced
        step only for batches that actually use the feature, so the
        benign default program is unchanged."""
        midx = AGGREGATORS.index("memory")
        wm = self.cfg.aggregator == "memory" or any(
            int(np.asarray(c["agg"]["family"])) == midx for c in cells)
        nidx = FAULTS.index("none")
        sidx = FAULTS.index("straggler_stale")
        fams = [int(np.asarray(c["fault"]["family"]))
                for c in cells if "fault" in c]
        wf = self.cfg.fault != "none" or any(f != nidx for f in fams)
        ws = self.cfg.fault == "straggler_stale" or any(
            f == sidx for f in fams)
        return wm, wf, ws, bool(self.cfg.telemetry)

    def _variant(self, batched: bool):
        """(mesh, silo, panel_axis-factory) for this run shape."""
        mesh = self._mesh() if batched else None
        silo = int(mesh.devices.shape[1]) if mesh is not None else 1

        def panel(wm: bool):
            return ENGINE_SILO_AXIS if (
                silo > 1 and self.cfg.silo_reduce == "psum" and wm) else None
        return mesh, silo, panel

    def _closures(self, flags: tuple, silo: int, panel: Optional[str]):
        wm, wf, ws, wt = flags
        key = (flags, silo, panel)
        if key not in self._sims:
            self._sims[key] = _build_simulate(
                self.ds, self.model, self.cfg, self.use_masks,
                with_memory=wm, with_fault=wf, with_stale=ws,
                with_telemetry=wt, silo=silo, panel_axis=panel)
        return self._sims[key]

    def _program(self, cells: list[dict], batched: bool):
        """The compiled full-run program variant for these cells: the (N, P)
        update-memory panel — and likewise the fault seam and its stale
        panel — ride the scan carry ONLY when a cell (or the engine
        default) asks for them — the common fedavg sweep keeps the lean
        carry.  With a mesh, the batched program is shard_map'd over
        ("cells", "silo")."""
        flags = self._flags(cells)
        mesh, silo, panelf = self._variant(batched)
        panel = panelf(flags[0])

        def build():
            fn = self._closures(flags, silo, panel)["simulate"]
            if batched:
                fn = jax.vmap(fn)
            if mesh is not None:
                spec = engine_batch_spec(self.cfg.cell_sharding)
                fn = shard_map(fn, mesh=mesh, in_specs=(spec,),
                               out_specs=spec, check_rep=False)
            return jax.jit(fn)
        return self._programs.get((flags, batched, silo, panel), build)

    def _carry_specs(self, stacked: dict, flags: tuple, silo: int,
                     panel: Optional[str], init_fn):
        """PartitionSpec tree for the carry (structure from an abstract
        eval — shapes themselves are not consulted beyond rank)."""
        key = (flags, silo, panel)
        if key not in self._cspecs:
            shapes = jax.eval_shape(init_fn, stacked)
            self._cspecs[key] = engine_carry_specs(
                shapes, cell_sharding=self.cfg.cell_sharding,
                panel_sharded=panel is not None)
        return self._cspecs[key]

    def _init_program(self, stacked: dict, flags: tuple):
        mesh, silo, panelf = self._variant(True)
        panel = panelf(flags[0])

        # NOT donated: the stacked cells stay live across every subsequent
        # segment call (donating them here would invalidate the whole run —
        # the donation-safety audit of DESIGN.md §15 rejects it)
        def build():
            fn = jax.vmap(self._closures(flags, silo, panel)["init"])
            if mesh is not None:
                cspecs = self._carry_specs(stacked, flags, silo, panel, fn)
                spec = engine_batch_spec(self.cfg.cell_sharding)
                fn = shard_map(fn, mesh=mesh, in_specs=(spec,),
                               out_specs=cspecs, check_rep=False)
            return jax.jit(fn)
        return self._programs.get((flags, "init", silo, panel), build)

    def _segment_program(self, stacked: dict, flags: tuple, seg_len: int):
        mesh, silo, panelf = self._variant(True)
        panel = panelf(flags[0])
        donate = bool(self.cfg.donate_carry)

        def build():
            cl = self._closures(flags, silo, panel)
            fn = jax.vmap(cl["segment"](seg_len), in_axes=(0, 0, None))
            if mesh is not None:
                cspecs = self._carry_specs(stacked, flags, silo, panel,
                                           jax.vmap(cl["init"]))
                spec = engine_batch_spec(self.cfg.cell_sharding)
                fn = shard_map(fn, mesh=mesh, in_specs=(spec, cspecs, P()),
                               out_specs=(cspecs, spec), check_rep=False)
            # donate the carry (arg 1): the (params, moments, (N, P) memory
            # panel, chain + sampler state) buffers are reused in place
            # across segments instead of fresh HBM allocations per segment;
            # callers interact through CarryHandle, whose consume-once
            # semantics turn use-after-donation into a loud error
            return jax.jit(fn, donate_argnums=(1,) if donate else ())
        return self._programs.get((flags, "seg", seg_len, silo, panel,
                                   donate), build)

    def _pad_cells(self, cells: list[dict]) -> list[dict]:
        """Pad an uneven batch to a multiple of the "cells" axis size by
        repeating the last cell (pad trajectories are dropped on return)."""
        mesh = self._mesh()
        if mesh is None or not self.cfg.cell_sharding:
            return list(cells)
        c = int(mesh.devices.shape[0])
        r = len(cells) % c
        return list(cells) + [cells[-1]] * ((c - r) % c)

    # ------------------------------------------------------------- cells
    def cell(self, *, seed: int = 0, mode: Optional[AvailabilityMode] = None,
             process: Optional[AvailabilityProcess] = None,
             masks: Optional[np.ndarray] = None, alpha: float = 1.0,
             h: Optional[np.ndarray] = None, avail_seed: int = 1234,
             sampler_seed: Optional[int] = None,
             sampler_process: Optional[SamplerProcess] = None,
             aggregator_process: Optional[AggregatorProcess] = None,
             fault_process: Optional[FaultProcess] = None,
             fault_seed: Optional[int] = None) -> dict:
        """One sweep cell = (seed, availability, sampler params) pytree.

        Mask path (``use_masks=True``): pass ``masks`` (rounds, N), e.g. from
        ``precompute_masks`` for bit-exact FLEngine availability.  Device
        path: pass ``process`` (any ``AvailabilityProcess`` scenario family)
        or ``mode`` (a legacy Table-1 mode, wrapped as its ``TableProcess``);
        the cell carries the process params + initial state
        (``init(PRNGKey(avail_seed))``) and per-round draws use the
        ``fold_in(avail_seed, t)`` jax stream.  Cells of different scenario
        families batch together in ``run_batch``.

        The SAMPLER is a per-cell choice too: ``sampler_process`` (any
        ``core.sampler_device.SamplerProcess``; defaults to the engine-level
        ``cfg.sampler`` family with this cell's ``alpha``) compiles to a
        ``lax.switch`` index, so cells of different samplers batch through
        one ``run_batch`` program.  Because every branch traces, EVERY cell
        carries the full (N, N) ``h`` (zeros when no FedGS cell needs it).

        The AGGREGATOR is a per-cell choice the same way:
        ``aggregator_process`` (any ``fed.aggregator_device
        .AggregatorProcess``; defaults to the engine-level
        ``cfg.aggregator`` family) compiles to a ``lax.switch`` index, so
        cells of different server-update rules batch through one
        ``run_batch`` program; the aggregator state is built in-scan from
        the cell's own ``params0``, and its (N, P) update-memory panel is
        carried only by the program variant that actually has a
        memory-family cell (``_program``).

        FAULT INJECTION is per-cell as well: ``fault_process`` (any
        ``fed.faults_device.FaultProcess``; defaults to the engine-level
        ``cfg.fault``/``cfg.fault_frac`` family — ``none`` by default)
        compiles to a ``lax.switch`` index, so benign and adversarial
        cells batch through one ``run_batch`` program; every cell carries
        the (small) fault params + latency state for stacking uniformity,
        but the scan carries fault state only in program variants with an
        actual fault cell (``_flags``).
        """
        c: dict = {"key": jax.random.PRNGKey(seed)}
        if self.use_masks:
            assert masks is not None and masks.shape == (self.cfg.rounds, self.n)
            c["masks"] = jnp.asarray(masks, bool)
        else:
            if process is None:
                assert mode is not None, \
                    "device-side availability needs a process or a mode"
                process = mode.process()
            c["avail_key"] = jax.random.PRNGKey(avail_seed)
            c["proc"] = process.params()
            c["proc_state"] = process.init(c["avail_key"])
        sproc = sampler_process if sampler_process is not None else \
            make_sampler_process(self.cfg.sampler, alpha=alpha,
                                 d_factor=self.cfg.poc_d_factor)
        c["sampler"] = sproc.params(data_sizes=self.ds.sizes)
        c["sampler_key"] = jax.random.PRNGKey(
            seed + 0x5E1EC7 if sampler_seed is None else sampler_seed)
        c["sampler_state"] = sproc.init(c["sampler_key"])
        aproc = aggregator_process if aggregator_process is not None else \
            make_aggregator_process(self.cfg.aggregator)
        c["agg"] = aproc.params()
        c["agg_key"] = jax.random.PRNGKey(seed + 0xA66)
        fproc = fault_process if fault_process is not None else \
            make_fault_process(self.cfg.fault, self.n,
                               frac=self.cfg.fault_frac)
        c["fault"] = fproc.params()
        c["fault_key"] = jax.random.PRNGKey(
            seed + 0xFA17 if fault_seed is None else fault_seed)
        c["fault_state"] = fproc.init(c["fault_key"])
        if self.cfg.graph_refresh_every > 0:
            c["init_key"] = jax.random.PRNGKey(seed + 778)
        elif isinstance(h, jax.ShapeDtypeStruct):
            # abstract H for compile-only dry-runs (lower_batch(abstract=
            # True)): a datacenter-N (N, N) matrix lowers without ever
            # materializing on this host
            c["h"] = h
        elif h is not None:
            c["h"] = jnp.asarray(h, jnp.float32)
        else:
            assert sproc.family != "fedgs", \
                "static FedGS cell needs a normalized H"
            c["h"] = jnp.zeros((self.n, self.n), jnp.float32)
        return c

    # -------------------------------------------------------------- runs
    def _to_history(self, out, i: Optional[int] = None,
                    telemetry: Optional[dict] = None) -> ScanHistory:
        pick = (lambda x: np.asarray(x)) if i is None else \
               (lambda x: np.asarray(x[i]))
        return ScanHistory(val_loss=pick(out["val_loss"]),
                           val_acc=pick(out["val_acc"]),
                           count_var=pick(out["count_var"]),
                           gini=pick(out["gini"]),
                           sel=pick(out["sel"]), valid=pick(out["valid"]),
                           counts=pick(out["counts"]),
                           telemetry=None if telemetry is None else
                           jax.tree_util.tree_map(pick, telemetry))

    # --------------------------------------------------- telemetry plumbing
    def _emit_segment_metrics(self, b: int, t0: int, k: int, traj_h: dict,
                              tel_h: Optional[dict]):
        """Stream one fetched segment's per-round rows to the metrics sink
        (DESIGN.md §17) — called per segment as it lands on host, so a
        service front-end sees metrics while later segments still
        compute.  Pad cells (mesh batch padding) are not emitted."""
        if self.sink is None:
            return
        with self.tracer.span("metrics_emit", t0=t0, rounds=k):
            for j in range(b):
                for r in range(k):
                    row = {"cell": j, "t": t0 + r,
                           "n_valid": int(np.sum(traj_h["valid"][j][r]))}
                    for f in ("val_loss", "val_acc", "count_var", "gini"):
                        row[f] = float(traj_h[f][j][r])
                    if tel_h is not None:
                        row["metrics"] = {
                            kk: np.asarray(v[j][r])
                            for kk, v in tel_h.items()}
                    self.sink.emit("round", row)
            self.sink.emit("segment",
                           {"t0": t0, "rounds": k, "cells": b,
                            "programs": self._programs.stats()})

    def _fetch_segment(self, t0: int, k: int, traj_dev, b: int) -> dict:
        """ONE whole-pytree ``jax.device_get`` of a segment trajectory;
        the telemetry subtree is split off (stashed for the final
        histories + streamed to the sink) so the trajectory that flows
        into checkpoints and stream consumers is bitwise the
        telemetry-off one (assumption log #24)."""
        with self.tracer.span("device_get", t0=t0, rounds=k):
            traj_h = jax.device_get(traj_dev)
        tel_h = traj_h.pop("telemetry", None)
        if tel_h is not None:
            self._tel_parts.append((t0, k, tel_h))
        self._emit_segment_metrics(b, t0, k, traj_h, tel_h)
        return traj_h

    def _assemble_telemetry(self) -> Optional[dict]:
        """Concat the stashed per-segment telemetry into (B, T, ...)
        arrays; a resumed run's pre-resume prefix (telemetry is not
        checkpointed) is NaN-filled so round indices stay aligned."""
        if not self._tel_parts:
            return None
        parts, t_next = [], 0
        for t0, k, tel in self._tel_parts:
            if t0 > t_next:
                gap = t0 - t_next
                parts.append(jax.tree_util.tree_map(
                    lambda x, g=gap: np.full(
                        x.shape[:1] + (g,) + x.shape[2:], np.nan,
                        x.dtype), tel))
            parts.append(tel)
            t_next = t0 + k
        return jax.tree_util.tree_map(
            lambda *xs: np.concatenate(xs, axis=1), *parts)

    def run(self, cell: dict) -> ScanHistory:
        """Execute one cell; the whole trajectory is a single device program
        (always single-device — the mesh applies to ``run_batch``).  The
        output pytree comes back in ONE ``jax.device_get`` transfer (which
        also synchronizes), not one ``np.asarray`` per history field."""
        with self.tracer.span("run_cell"):
            out = jax.device_get(self._program([cell], False)(cell))
        tel = out.pop("telemetry", None)
        self.params = out["params"]
        return self._to_history(out, telemetry=tel)

    # ------------------------------------------------- segmented runtime
    def init_carry(self, cells: list[dict]) -> CarryHandle:
        """Build the full scan carry for these cells and wrap it in a
        donation-safe handle (DESIGN.md §15): ``run_segment`` consumes the
        handle and returns a fresh one; touching a consumed handle raises."""
        cells_p = self._pad_cells(cells)
        flags = self._flags(cells_p)
        stacked = stack_cells(cells_p)
        return CarryHandle(self._init_program(stacked, flags)(stacked))

    def run_segment(self, cells: list[dict], carry: CarryHandle,
                    t0: int, seg_len: int):
        """Dispatch one ``seg_len``-round segment starting at round ``t0``
        (asynchronously — nothing blocks until the outputs are consumed).
        The carry handle is CONSUMED: with ``cfg.donate_carry`` its device
        buffers are donated to the segment program and reused in place.
        Returns ``(new_handle, traj_device)``."""
        cells_p = self._pad_cells(cells)
        flags = self._flags(cells_p)
        return self._run_segment(stack_cells(cells_p), flags, carry, t0,
                                 seg_len)

    def _run_segment(self, stacked: dict, flags: tuple, carry: CarryHandle,
                     t0: int, seg_len: int):
        with self.tracer.span("program_get", seg_len=seg_len):
            fn = self._segment_program(stacked, flags, seg_len)
        # dispatch is async: this span covers trace/lower/compile on a
        # cache miss and ~µs enqueue steady-state (assumption log #25)
        with self.tracer.span("dispatch_segment", t0=t0, rounds=seg_len):
            new_carry, traj = fn(stacked, carry.consume(), jnp.int32(t0))
        return CarryHandle(new_carry), traj

    def run_batch_stream(self, cells: list[dict], *,
                         ckpt_path: Optional[str] = None,
                         ckpt_every: int = 0, resume: bool = False):
        """Generator driving the segmented scan as an async pipeline:
        yields ``(t_start, seg_len, traj_host)`` per segment IN ORDER,
        where ``traj_host`` leaves are (B_padded, seg_len, ...) numpy
        arrays — incremental history streaming for a service front-end
        (``launch/serve.py``) instead of one post-scan gather.

        Pipelining (``cfg.async_pipeline``): segment k+1 is dispatched
        before segment k's trajectory is fetched, so the device→host
        transfer (one ``jax.device_get`` per segment) and the npz
        checkpoint write (a background ``AsyncCheckpointWriter`` thread)
        overlap segment k+1's device compute.  On checkpoint boundaries
        the carry is gathered to host BEFORE the next (donating) dispatch
        — the one mandatory sync of the loop.  With
        ``cfg.async_pipeline=False`` every segment blocks and writes
        inline (the pre-runtime-layer PR 6 behavior); either way the
        dispatched per-round programs are identical, so results are
        bitwise equal (assumption log #19).

        After exhaustion ``self.params`` / ``self.final_counts`` hold the
        final state (host copies, pad cells included)."""
        cfg = self.cfg
        b = len(cells)
        cells_p = self._pad_cells(cells)
        flags = self._flags(cells_p)
        stacked = stack_cells(cells_p)
        rounds = cfg.rounds
        every = int(ckpt_every) if ckpt_every else rounds
        concat = lambda parts: jax.tree_util.tree_map(        # noqa: E731
            lambda *xs: np.concatenate(xs, axis=1), *parts)
        self._tel_parts = []
        self._writer_stats = None
        if self.sink is not None:
            self.sink.emit("run_start",
                           {"cells": b, "rounds": rounds, "mesh": cfg.mesh,
                            "telemetry": bool(cfg.telemetry),
                            "ckpt_every": int(ckpt_every)})
        t0, parts, carry = 0, [], None
        if resume and ckpt_path is not None:
            p = ckpt_path if ckpt_path.endswith(".npz") else ckpt_path + ".npz"
            if os.path.exists(p):
                state = load_checkpoint(ckpt_path)
                t0 = int(np.asarray(state["round"]))
                carry = jax.tree_util.tree_map(jnp.asarray, state["carry"])
                parts.append(state["traj"])
                yield 0, t0, state["traj"]
        if carry is None:
            with self.tracer.span("init_carry", cells=len(cells_p)):
                carry = self._init_program(stacked, flags)(stacked)
        handle = CarryHandle(carry)
        writer = AsyncCheckpointWriter() \
            if (ckpt_path is not None and cfg.async_pipeline) else None
        pending = None                      # (t_start, seg_len, traj_device)

        def meta_of(t_next):
            return {"round": t_next, "rounds": rounds, "b": b,
                    "cells": len(cells_p), "mesh": cfg.mesh}
        try:
            while t0 < rounds:
                k = min(every, rounds - t0)
                handle, traj_dev = self._run_segment(stacked, flags, handle,
                                                     t0, k)
                t1 = t0 + k
                need_ckpt = ckpt_path is not None and t1 < rounds
                if not cfg.async_pipeline:
                    # PR 6 semantics: block, fetch, write inline
                    traj_h = self._fetch_segment(t0, k, traj_dev, b)
                    parts.append(traj_h)
                    if need_ckpt:
                        with self.tracer.span("checkpoint_write", round=t1):
                            save_checkpoint(
                                ckpt_path,
                                {"carry": jax.device_get(handle.tree),
                                 "round": np.int64(t1),
                                 "traj": concat(parts)},
                                metadata=meta_of(t1))
                    yield t0, k, traj_h
                elif need_ckpt:
                    # the checkpoint needs the cumulative trajectory AND
                    # the post-segment carry on host; the carry gather
                    # must land before the next donating dispatch.  The
                    # concat + npz write run on the writer thread,
                    # overlapping the next segment's compute.
                    if pending is not None:
                        ph = self._fetch_segment(pending[0], pending[1],
                                                 pending[2], b)
                        parts.append(ph)
                        yield pending[0], pending[1], ph
                        pending = None
                    traj_h = self._fetch_segment(t0, k, traj_dev, b)
                    parts.append(traj_h)
                    carry_h = jax.device_get(handle.tree)
                    snapshot = list(parts)

                    def _write(ch=carry_h, sn=snapshot, tn=t1):
                        with self.tracer.span("checkpoint_write", round=tn):
                            save_checkpoint(
                                ckpt_path,
                                {"carry": ch, "round": np.int64(tn),
                                 "traj": concat(sn)},
                                metadata=meta_of(tn))
                    writer.submit(_write)
                    yield t0, k, traj_h
                else:
                    # free-running: fetch the PREVIOUS segment while this
                    # one computes
                    if pending is not None:
                        ph = self._fetch_segment(pending[0], pending[1],
                                                 pending[2], b)
                        parts.append(ph)
                        yield pending[0], pending[1], ph
                    pending = (t0, k, traj_dev)
                t0 = t1
            if pending is not None:
                ph = self._fetch_segment(pending[0], pending[1],
                                         pending[2], b)
                parts.append(ph)
                yield pending[0], pending[1], ph
            final = jax.device_get({"params": handle.tree["agg"]["prev"],
                                    "counts": handle.tree["counts"]})
            self.params = jax.tree_util.tree_map(lambda x: x[:b],
                                                 final["params"])
            self.final_counts = final["counts"][:b]
        finally:
            if writer is not None:
                try:
                    writer.close()
                finally:
                    self._writer_stats = writer.stats()
            if self.sink is not None:
                self.sink.emit("run_end", {"runtime": self.runtime_stats()})

    def run_batch(self, cells: list[dict], *,
                  ckpt_path: Optional[str] = None, ckpt_every: int = 0,
                  resume: bool = False) -> list[ScanHistory]:
        """Execute B cells as ONE vmapped-and-scanned XLA program
        (shard_map'd over the mesh when ``cfg.mesh`` is set).

        Checkpointing (DESIGN.md §13): with ``ckpt_path`` the scan runs in
        ``ckpt_every``-round segments and after each non-final segment the
        FULL carry + accumulated trajectory + next round index are saved
        (gathered to host npz — device-layout-free).  ``resume=True`` picks
        up from ``ckpt_path`` if it exists (else starts fresh); at the same
        mesh + cadence the tail recomputes bitwise-identically to the
        uninterrupted run.  Resume on a DIFFERENT device count / mesh
        reshards the loaded carry to the target program's specs and is
        bitwise at ``ckpt_every=1`` (one-round segments compile identically
        on every device count; longer scans pick up ulp-level eval drift
        from SPMD-/length-dependent while-body fusion).

        Runtime layer (DESIGN.md §15): the segmented path runs donated +
        pipelined through ``run_batch_stream`` (bitwise-identical results —
        the compiled per-round programs are unchanged); ``ckpt_every``
        WITHOUT a ``ckpt_path`` now streams the scan in segments too
        (previously it silently ran fused).
        """
        b = len(cells)
        cells_p = self._pad_cells(cells)
        if ckpt_path is None and not resume and not ckpt_every:
            fn = self._program(cells_p, True)
            # ONE device_get of the whole output pytree (one transfer +
            # sync), not one np.asarray round-trip per history field
            with self.tracer.span("device_get", t0=0,
                                  rounds=self.cfg.rounds):
                out = jax.device_get(fn(stack_cells(cells_p)))
            tel = out.pop("telemetry", None)
            self._emit_segment_metrics(b, 0, self.cfg.rounds, out, tel)
            self.params = jax.tree_util.tree_map(lambda x: x[:b],
                                                 out["params"])
            return [self._to_history(out, i, telemetry=tel)
                    for i in range(b)]

        parts = [traj for _, _, traj in self.run_batch_stream(
            cells, ckpt_path=ckpt_path, ckpt_every=ckpt_every,
            resume=resume)]
        traj = jax.tree_util.tree_map(lambda *xs: np.concatenate(xs, axis=1),
                                      *parts)
        tel = self._assemble_telemetry()
        # the stream already set self.params / self.final_counts (B-sliced)
        out = {**traj, "counts": self.final_counts}
        return [self._to_history(out, i, telemetry=tel) for i in range(b)]

    def lower_batch(self, cells: list[dict], *, abstract: bool = False):
        """Lower (without running) — for compile-time measurement.

        ``abstract=True`` lowers against ``ShapeDtypeStruct``s instead of
        device arrays (the stacked-cell structure comes from
        ``jax.eval_shape`` over ``stack_cells``), so datacenter-N cells —
        whose (N, N) ``h`` could never materialize on this host — still
        produce HLO (the compile-only silo-axis dry-run,
        ``launch/fedsim.py::datacenter_cell_dryrun``)."""
        cells_p = self._pad_cells(cells)
        stacked = jax.eval_shape(stack_cells, cells_p) if abstract \
            else stack_cells(cells_p)
        return self._program(cells_p, True).lower(stacked)

    def carry_shapes(self, cells: list[dict]):
        """Abstract (per-device local) carry pytree for these cells —
        what one device holds per scan step.  Used by the compile-only
        dry-run to pin the carry footprint (a silo-sharded memory panel
        must show its (N/silo, P) rows here)."""
        cells_p = self._pad_cells(cells)
        flags = self._flags(cells_p)
        _, silo, panelf = self._variant(True)
        stacked = jax.eval_shape(stack_cells, cells_p)
        return jax.eval_shape(
            jax.vmap(self._closures(flags, silo, panelf(flags[0]))["init"]),
            stacked)

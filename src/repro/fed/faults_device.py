"""Device-native Byzantine/straggler fault-injection subsystem.

Every scenario the engines simulated before this module was *benign*:
clients disappear (core/availability_device.py), but the updates that do
arrive are always honest.  The robustness literature the ROADMAP's
scenario-diversity item points at (Blanchard et al.'s Krum, trimmed-mean /
coordinate-median breakdown analyses, straggler-staleness models) needs the
opposite: clients that LIE.  This module makes the lie a first-class
process abstraction, mirroring ``AvailabilityProcess`` exactly — ONE pure,
jit/vmap/scan-traceable implementation that the scan engine carries through
``lax.scan`` between local training and aggregation, the host engine wraps
eagerly (:class:`HostFaultInjector`), and mixed benign/adversarial sweep
cells batch through a single ``run_batch`` program.

A :class:`FaultProcess` is

    ``init(key) -> state``                                    (eager, host)
    ``corrupt(state, key, updf, prevf, avail, t, sel, valid)
        -> (updf, state)``                              (pure, traceable)

where ``updf`` is the (M, P) FLAT panel of locally-trained client params
(the ``aggregator_device._flat_template`` convention — the engines ravel
the stacked pytree once, corrupt, and unravel), ``prevf`` the flat previous
global params, and ``sel``/``valid`` the round's gathered client slots.
Every family compiles to ONE ``lax.switch`` branch index
(:func:`make_fault_step`), so cells of DIFFERENT fault families — and
benign cells, whose ``none`` branch is a bitwise identity — vmap-batch
together.

Families (``FAMILIES`` — the switch order):

  =============== ===================== ==================================
  family          class                 corrupted update of a byz slot
  =============== ===================== ==================================
  none            NoFault               identity (the benign default)
  sign_flip       SignFlipFault         ``prev - scale (theta_k - prev)``
                                        — the update delta reversed
  gaussian_noise  GaussianNoiseFault    ``theta_k + sigma eps``, eps ~
                                        N(0, I) per coordinate
  scaled          ScaledFault           ``prev + boost (theta_k - prev)``
                                        — model-replacement boosting
                                        (Bagdasaryan et al.)
  straggler_stale StragglerStaleFault   the client's LAST on-time update
                                        (a tau-round-old row of a carried
                                        (N, P) stale panel); lateness is
                                        the AR(1) latency chain of the
                                        PR-3 deadline machinery
  =============== ===================== ==================================

Which clients are adversarial is a fixed host-side mask (``byz``):
``ceil(frac * N)`` clients drawn by a seeded permutation, so the attacker
identity is deterministic per (frac, byz_seed) and identical across the
paired cells of a bench row.  Corruption applies to a sampled slot iff its
client is in the mask AND the slot is valid (pads stay untouched).

The runtime representation is a uniform *params* pytree (family index,
packed ``theta`` knobs, the (N,) ``byz`` mask, per-client ``aux`` mean
latencies) plus a uniform *state* pytree (``latency`` (N,) AR(1) chain;
the engines merge in the flat (rows, P) ``stale`` panel via
:func:`init_fault_state` because P is only known once the model is —
exactly how the aggregator's memory panel is sized).  ``stale_enabled=
False`` aliases the straggler branch to ``none`` so a no-straggler program
carries a 0-row panel without tracing the scatter (the ``memory_enabled``
pattern of ``make_aggregator_step``).

Seed-stream convention (matches availability, DESIGN.md assumption log
#10): per round the engines derive ``fkey = fold_in(fault_key, t)``; the
noise draw uses ``fkey`` itself, the AR(1) latency transition uses
``fold_in(fkey, 2)`` (``_STEP_SALT``), and ``init`` consumes the raw
``fault_key`` — init and round draws cannot collide, and a segmented
resume replays the identical per-round stream (no cross-round rng carry).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.fed.aggregator_device import _flat_template

FAMILIES = ("none", "sign_flip", "gaussian_noise", "scaled",
            "straggler_stale")

THETA_DIM = 6          # packed per-family scalar knobs (see the branch readers)
_STEP_SALT = 2         # fold_in salt of the AR(1) latency-transition stream


# ------------------------------------------------------------ state helpers
def init_fault_state(state: dict, params0, stale_rows: int) -> dict:
    """Merge the flat (rows, P) stale-update panel into a process's carried
    state.  Every row starts as flat(params0) — a straggler's first late
    round ships the INITIAL model, the same round-0 pseudo-update
    convention the memory aggregator uses (DESIGN.md assumption log #15).
    ``stale_rows=0`` keeps the uniform pytree structure with an empty
    panel (the no-straggler program variants)."""
    ravel, _, _ = _flat_template(params0)
    flat0 = ravel(params0)
    return {**state, "stale": jnp.tile(flat0[None, :], (stale_rows, 1))}


# ------------------------------------------------------- per-family branches
# Each branch: (fparams, state, key, updf (M, P), prevf (P,), avail, t,
# sel, valid, byzm) -> (updf, new state).  All branches return the SAME
# pytree structure so lax.switch can dispatch on a traced (per-cell,
# vmap-batched) family index; ``byzm`` (M,) is the precomputed
# byz-and-valid slot mask.
def _corrupt_none(fp, state, key, updf, prevf, avail, t, sel, valid, byzm):
    return updf, state


def _corrupt_sign_flip(fp, state, key, updf, prevf, avail, t, sel, valid,
                       byzm):
    """Reverse (and optionally amplify) the update delta: the byz slot
    ships ``prev - scale (theta_k - prev)`` — at scale 1 exactly the
    mirror image of the honest update through the previous model."""
    scale = fp["theta"][0]
    flipped = prevf[None, :] - scale * (updf - prevf[None, :])
    return jnp.where(byzm[:, None], flipped, updf), state


def _corrupt_gaussian(fp, state, key, updf, prevf, avail, t, sel, valid,
                      byzm):
    """Additive N(0, sigma^2 I) noise on the byz slots' params."""
    sigma = fp["theta"][0]
    noise = sigma * jax.random.normal(key, updf.shape)
    return jnp.where(byzm[:, None], updf + noise, updf), state


def _corrupt_scaled(fp, state, key, updf, prevf, avail, t, sel, valid,
                    byzm):
    """Model-replacement boosting: the byz slot ships
    ``prev + boost (theta_k - prev)`` — after Eq. 18's 1/M dilution the
    attacker's delta survives at full strength when boost ~ M."""
    boost = fp["theta"][0]
    boosted = prevf[None, :] + boost * (updf - prevf[None, :])
    return jnp.where(byzm[:, None], boosted, updf), state


def _corrupt_straggler(fp, state, key, updf, prevf, avail, t, sel, valid,
                       byzm):
    """Staleness, not malice: byz ("slow") clients carry the PR-3 AR(1)
    latency chain ``l' = rho l + (1 - rho) mu_k + sigma eps`` and, whenever
    sampled while ``l' > deadline``, ship the row of the carried (N, P)
    stale panel — their last ON-TIME update (tau rounds old).  On-time
    sampled slots (honest ones always) refresh their panel row with the
    fresh update, so staleness compounds only across consecutive late
    draws."""
    rho, sigma, deadline = fp["theta"][0], fp["theta"][1], fp["theta"][2]
    mu = fp["aux"]
    lat = rho * state["latency"] + (1.0 - rho) * mu \
        + sigma * jax.random.normal(jax.random.fold_in(key, _STEP_SALT),
                                    mu.shape)
    late = byzm & (lat[sel] > deadline)
    stale_rows = state["stale"][sel]                      # pre-refresh read
    out = jnp.where(late[:, None], stale_rows, updf)
    refresh = valid & ~late
    stale = state["stale"].at[sel].set(
        jnp.where(refresh[:, None], updf, stale_rows))
    return out, {**state, "latency": lat, "stale": stale}


_BRANCHES = {"none": _corrupt_none, "sign_flip": _corrupt_sign_flip,
             "gaussian_noise": _corrupt_gaussian, "scaled": _corrupt_scaled,
             "straggler_stale": _corrupt_straggler}


def make_fault_step(n: int, m: int, *, stale_enabled: bool = False,
                    family: Optional[str] = None):
    """Compile-time constructor of the ONE per-round corruption step

        ``corrupt(fparams, state, key, updf, prevf, avail, t, sel, valid)
            -> (updf, state)``

    dispatching ``lax.switch`` on the cell's family index, so cells of
    DIFFERENT fault families (and benign cells) batch through one vmapped
    program.  ``stale_enabled=False`` aliases the straggler branch to the
    identity so a no-straggler program can carry a 0-row stale panel
    without tracing the gather/scatter (callers — ``ScanEngine`` — must
    dispatch straggler cells to a stale-enabled program; the
    ``memory_enabled`` convention of ``make_aggregator_step``).
    ``family`` names a single branch for the eager host path — SAME branch
    code, identical numerics, but nothing else traces."""
    if family is not None and family not in FAMILIES:
        raise ValueError(f"family must be one of {FAMILIES}, not {family!r}")
    if family == "straggler_stale" and not stale_enabled:
        raise ValueError("family='straggler_stale' requires "
                         "stale_enabled=True")
    branches = dict(_BRANCHES)
    if not stale_enabled:
        branches["straggler_stale"] = _corrupt_none

    def corrupt(fparams, state, key, updf, prevf, avail, t, sel=None,
                valid=None):
        t = jnp.asarray(t, jnp.int32)
        byzm = fparams["byz"][sel] & valid
        if family is not None:
            return branches[family](fparams, state, key, updf, prevf,
                                    avail, t, sel, valid, byzm)
        return jax.lax.switch(fparams["family"],
                              [branches[f] for f in FAMILIES],
                              fparams, state, key, updf, prevf, avail, t,
                              sel, valid, byzm)

    return corrupt


# ------------------------------------------------------------ the processes
@dataclass
class FaultProcess:
    """Base class.  ``params()``/``init(key)`` are eager host-side
    constructors of the per-cell runtime pytrees; :meth:`corrupt` is the
    pure traceable entry point (single-process convenience over
    :func:`make_fault_step`, guaranteed identical because it IS the switch
    path).  Every family fills the SAME params pytree so heterogeneous
    fault cells stack along a vmap batch axis
    (``scan_engine.stack_cells``)."""
    n: int
    frac: float = 0.0
    byz_seed: int = 0
    name: str = "none"

    family = "none"

    def _theta(self) -> np.ndarray:
        return np.zeros(0)

    def _aux(self) -> np.ndarray:
        return np.zeros(self.n)

    def byz_mask(self) -> np.ndarray:
        """(N,) bool: the ``ceil(frac * N)`` adversarial clients, drawn by
        a seeded permutation — deterministic attacker identity per
        (frac, byz_seed), shared across the paired cells of a sweep."""
        mask = np.zeros(self.n, bool)
        k = int(np.ceil(self.frac * self.n)) if self.frac > 0 else 0
        if k:
            rng = np.random.default_rng(self.byz_seed)
            mask[rng.permutation(self.n)[:k]] = True
        return mask

    def params(self) -> dict:
        theta = np.zeros(THETA_DIM, np.float32)
        th = np.asarray(self._theta(), np.float32)
        theta[:th.shape[0]] = th
        return {"family": jnp.int32(FAMILIES.index(self.family)),
                "theta": jnp.asarray(theta),
                "byz": jnp.asarray(self.byz_mask()),
                "aux": jnp.asarray(self._aux(), jnp.float32)}

    def init(self, key: jax.Array) -> dict:
        """Initial carried state (stationary AR(1) draw where one exists).
        The stale panel is merged in by the engine via
        :func:`init_fault_state` (P is model-dependent)."""
        return {"latency": jnp.zeros((self.n,), jnp.float32)}

    # -- traceable entry point --------------------------------------------
    def corrupt(self, state, key, updf, prevf, avail, t, sel, valid):
        step = make_fault_step(
            self.n, int(updf.shape[0]),
            stale_enabled=self.family == "straggler_stale",
            family=self.family)
        return step(self.params(), state, key, updf, prevf, avail, t, sel,
                    valid)


@dataclass
class NoFault(FaultProcess):
    """The benign identity (every slot honest)."""
    name: str = "none"
    family = "none"


@dataclass
class SignFlipFault(FaultProcess):
    """Reversed update delta, optionally amplified (``scale`` > 1)."""
    frac: float = 0.2
    scale: float = 1.0
    name: str = "sign_flip"
    family = "sign_flip"

    def _theta(self):
        return np.array([self.scale])


@dataclass
class GaussianNoiseFault(FaultProcess):
    """Additive per-coordinate N(0, sigma^2) noise on byz updates."""
    frac: float = 0.2
    sigma: float = 1.0
    name: str = "gaussian_noise"
    family = "gaussian_noise"

    def _theta(self):
        return np.array([self.sigma])


@dataclass
class ScaledFault(FaultProcess):
    """Model-replacement boosting: the delta amplified ``boost``-fold."""
    frac: float = 0.2
    boost: float = 10.0
    name: str = "scaled"
    family = "scaled"

    def _theta(self):
        return np.array([self.boost])


@dataclass
class StragglerStaleFault(FaultProcess):
    """AR(1)-latency stragglers shipping their last on-time update.  The
    latency chain is EXACTLY the PR-3 ``DeadlineProcess`` machinery
    (``l' = rho l + (1 - rho) mu_k + sigma eps``, stationary init
    ``N(mu_k, sigma^2 / (1 - rho^2))``) — but instead of dropping the
    late client, the round keeps it and its update is stale."""
    frac: float = 0.3
    rho: float = 0.8
    sigma: float = 0.2
    deadline: float = 1.0
    mu: Optional[np.ndarray] = None      # (N,) mean latencies; default U[.5, 1.5]
    mu_seed: int = 0
    name: str = "straggler_stale"
    family = "straggler_stale"

    def _theta(self):
        return np.array([self.rho, self.sigma, self.deadline])

    def _mu(self) -> np.ndarray:
        if self.mu is not None:
            return np.asarray(self.mu, np.float64)
        rng = np.random.default_rng(self.mu_seed)
        return rng.uniform(0.5, 1.5, self.n)

    def _aux(self):
        return self._mu()

    @property
    def stationary_sd(self) -> float:
        return self.sigma / np.sqrt(max(1.0 - self.rho ** 2, 1e-12))

    def init(self, key):
        mu = jnp.asarray(self._mu(), jnp.float32)
        lat = mu + self.stationary_sd * jax.random.normal(key, mu.shape)
        return {"latency": lat}


def make_fault_process(name: str, n_clients: int, *, frac: float = 0.2,
                       byz_seed: int = 0, **kw) -> FaultProcess:
    """Family names (= ``scan_engine.FAULTS``) -> processes.  ``frac`` is
    the adversarial fraction (ignored by ``none``); extra kwargs reach the
    family constructor (scale / sigma / boost / rho / deadline / ...)."""
    name = name.lower()
    if name == "none":
        return NoFault(n_clients)
    if name == "sign_flip":
        return SignFlipFault(n_clients, frac=frac, byz_seed=byz_seed, **kw)
    if name == "gaussian_noise":
        return GaussianNoiseFault(n_clients, frac=frac, byz_seed=byz_seed,
                                  **kw)
    if name == "scaled":
        return ScaledFault(n_clients, frac=frac, byz_seed=byz_seed, **kw)
    if name in ("straggler_stale", "straggler"):
        return StragglerStaleFault(n_clients, frac=frac, byz_seed=byz_seed,
                                   **kw)
    raise ValueError(f"unknown fault family {name!r}")


# ---------------------------------------------------------------- host face
class HostFaultInjector:
    """Thin eager host face over the device switch step — the
    ``ServerAggregator`` pattern: ``FLEngine`` / ``launch/train.py`` call
    :meth:`inject` between local training and ``server.apply``, the state
    (latency chain + stale panel) carries across rounds, and because it is
    the SAME branch code on the SAME ``fold_in(PRNGKey(fault_seed), t)``
    stream, a scan cell with matching seeds replays the host corruption
    bit-exactly (precondition: every round samples the full M, as for
    trainer-key parity — DESIGN.md §5)."""

    def __init__(self, process: FaultProcess, *, fault_seed: int = 0):
        self.process = process
        self.n = int(process.n)
        self._key = jax.random.PRNGKey(fault_seed)
        self._steps: dict[int, object] = {}
        self._ravel = None
        self._unravel = None
        self.state = None

    def init(self, params0):
        self._ravel, self._unravel, _ = _flat_template(params0)
        rows = self.n if self.process.family == "straggler_stale" else 0
        self.state = init_fault_state(self.process.init(self._key), params0,
                                      rows)
        return self.state

    def _step(self, m: int):
        if m not in self._steps:
            step = make_fault_step(
                self.n, m,
                stale_enabled=self.process.family == "straggler_stale",
                family=self.process.family)
            self._steps[m] = jax.jit(step)
        return self._steps[m]

    def inject(self, stacked_updates, prev_params, sel, avail, t: int):
        assert self.state is not None, "call init(params0) first"
        sel = np.asarray(sel, int)
        updf = jax.vmap(self._ravel)(stacked_updates)
        updf, self.state = self._step(len(sel))(
            self.process.params(), self.state,
            jax.random.fold_in(self._key, t), updf,
            self._ravel(prev_params), jnp.asarray(avail, bool),
            jnp.int32(t), jnp.asarray(sel, jnp.int32),
            jnp.ones(len(sel), bool))
        return jax.vmap(self._unravel)(updf)

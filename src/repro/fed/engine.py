"""The federated round engine (Algorithm 1).

Per round t:
  1. availability mode draws A_t            (independent seed stream)
  2. sampler picks S_t ⊆ A_t, |S_t| ≤ M     (FedGS solves Eq. 16)
  3. broadcast θ^t; vmap'd local training (E steps SGD, optional prox)
  4. server update: any ``AggregatorProcess`` family via the shared device
     apply (``fed/server.py::ServerAggregator``; default = Eq. 18 FedAvg,
     bit-parity with the legacy ``aggregate``)
  5. update counts v^{t+1}
Evaluation on the shared validation split; history records loss/acc/fairness.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.availability import AvailabilityMode, host_draw
from repro.core.sampler import Sampler, FedGSSampler
from repro.core import graph as graph_mod
from repro.data.fed_dataset import FedDataset
from repro.fed.client import make_local_trainer, make_loss_prober
from repro.fed.faults_device import HostFaultInjector, make_fault_process
from repro.fed.models import FedModel
from repro.fed.runtime import (
    AsyncCheckpointWriter, ProgramCache, enable_compile_cache,
)
from repro.fed.server import ServerAggregator
from repro.fed.telemetry import NULL_TRACER, runtime_snapshot


@dataclass
class FLConfig:
    rounds: int = 200
    sample_frac: float = 0.1          # M = frac * N (paper: 0.1 / 0.2)
    local_steps: int = 10             # E
    batch_size: int = 10
    lr: float = 0.1
    lr_decay: float = 0.998
    prox_mu: float = 0.0
    eval_every: int = 5
    seed: int = 0
    avail_seed: int = 1234            # independent availability stream
    # dynamic 3DG: rebuild the graph from participants' uploaded models every
    # K rounds (0 = static graph; paper §3.2 "dynamically built and polished
    # round by round")
    graph_refresh_every: int = 0
    # persistent XLA compile cache (DESIGN.md §15): a re-launched run pays
    # compile once per (program, topology); None = in-process cache only
    compile_cache_dir: Optional[str] = None


@dataclass
class History:
    rounds: list = field(default_factory=list)
    val_loss: list = field(default_factory=list)
    val_acc: list = field(default_factory=list)
    count_var: list = field(default_factory=list)
    sampled: list = field(default_factory=list)

    @property
    def best_loss(self) -> float:
        return float(np.min(self.val_loss)) if self.val_loss else float("inf")

    @property
    def final_counts_var(self) -> float:
        return self.count_var[-1] if self.count_var else 0.0


class FLEngine:
    def __init__(self, ds: FedDataset, model: FedModel, sampler: Sampler,
                 mode: AvailabilityMode, cfg: FLConfig, *,
                 aggregator=None, agg_backend: str = "ref",
                 fault=None, fault_frac: float = 0.0,
                 fault_seed: Optional[int] = None,
                 tracer=None, sink=None):
        """``aggregator`` is any ``fed.aggregator_device.AggregatorProcess``
        (default FedAvg — bit-parity with the legacy Eq. 18 path);
        ``agg_backend`` routes the memory family's scatter+reduction.
        ``fault`` is a ``fed.faults_device.FaultProcess`` (or a family name
        string, built with ``fault_frac`` adversarial clients) — corruption
        is injected between local training and ``server.apply`` through
        ``HostFaultInjector``, the same branch code and
        ``fold_in(PRNGKey(fault_seed), t)`` stream the scan engine traces,
        so a matching scan cell replays the host run bit-exactly.
        ``fault_seed`` defaults to ``cfg.seed + 0xFA17`` (the scan cell
        convention)."""
        self.ds, self.model, self.sampler, self.mode, self.cfg = ds, model, sampler, mode, cfg
        self.n = ds.n_clients
        self.m = max(1, int(round(cfg.sample_frac * self.n)))
        self._server = ServerAggregator(aggregator, n_clients=self.n,
                                        data_sizes=ds.sizes,
                                        backend=agg_backend, seed=cfg.seed)
        if isinstance(fault, str):
            fault = make_fault_process(fault, self.n, frac=fault_frac)
        if fault is not None and fault.family != "none":
            self._faults = HostFaultInjector(
                fault, fault_seed=cfg.seed + 0xFA17
                if fault_seed is None else fault_seed)
        else:
            self._faults = None
        # observability spine (DESIGN.md §17): the host engine's jitted
        # programs route through the same ProgramCache as the scan engine,
        # so runtime_stats() reports hit/miss/compile counters with one
        # shared snapshot shape (runtime_snapshot); tracer spans + metric
        # sink are optional and default to no-ops
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.sink = sink
        self._programs = ProgramCache(maxsize=8)
        self._writer_stats: Optional[dict] = None
        self._trainer = self._programs.get(
            "trainer", lambda: make_local_trainer(
                model.loss, local_steps=cfg.local_steps,
                batch_size=cfg.batch_size, prox_mu=cfg.prox_mu))
        self._prober = self._programs.get(
            "prober", lambda: make_loss_prober(model.loss)) \
            if sampler.needs_losses else None
        self._eval = self._programs.get(
            "eval", lambda: jax.jit(lambda p, x, y: (
                model.loss(p, x, y), model.accuracy(p, x, y))))
        self.counts = np.zeros(self.n)
        if cfg.compile_cache_dir is not None:
            enable_compile_cache(cfg.compile_cache_dir)

    def runtime_stats(self) -> dict:
        """The shared telemetry snapshot (same shape as
        ``ScanEngine.runtime_stats``): flat ProgramCache counters, the
        last run's checkpoint-writer backpressure counters and the
        tracer's per-span aggregates."""
        return runtime_snapshot(programs=self._programs,
                                writer=self._writer_stats,
                                tracer=self.tracer)

    # ------------------------------------------------------------- 3DG setup
    def install_oracle_graph(self, features: Optional[np.ndarray] = None,
                             eps: float = 0.1, sigma2: float = 0.01,
                             backend: str = "ref"):
        """Build the oracle 3DG (label-distribution features by default,
        Appendix C) and hand H to a FedGS sampler."""
        if not isinstance(self.sampler, FedGSSampler):
            return None
        if features is None:
            features = self.ds.label_dist
        _, r, h = graph_mod.build_3dg(np.asarray(features), eps=eps,
                                      sigma2=sigma2, backend=backend)
        self.sampler.set_graph(h)
        return r

    def install_graph_from_H(self, h: np.ndarray):
        if isinstance(self.sampler, FedGSSampler):
            self.sampler.set_graph(h)

    # ------------------------------------------------------- dynamic 3DG
    def install_dynamic_graph(self, refresh_every: int = 10, eps: float = 0.1,
                              sigma2: float = 0.01, probe_size: int = 64):
        """Functional-similarity 3DG maintained online (paper §3.2): the
        initial graph comes from one all-clients local-training probe round
        (the paper's everyone-available-at-init assumption); afterwards the
        server re-embeds only the clients that participate and rebuilds
        V -> R -> H every ``refresh_every`` rounds."""
        if not isinstance(self.sampler, FedGSSampler):
            return
        self.cfg.graph_refresh_every = refresh_every
        self._graph_eps, self._graph_sigma2 = eps, sigma2
        rng = np.random.default_rng(self.cfg.seed + 777)
        xv = np.asarray(self.ds.x_val, np.float64).reshape(len(self.ds.x_val), -1)
        mu, cov = xv.mean(0), np.cov(xv.T) + 1e-4 * np.eye(xv.shape[1])
        probe = rng.multivariate_normal(mu, cov, probe_size).astype(np.float32)
        self._probe = jnp.asarray(probe.reshape(probe_size, *self.ds.x_val.shape[1:]))

        # init: probe round over ALL clients from a fresh global model
        key = jax.random.PRNGKey(self.cfg.seed + 778)
        params = self.model.init(key)
        stacked = self._trainer(params, jnp.asarray(self.ds.x),
                                jnp.asarray(self.ds.y),
                                jnp.asarray(self.ds.sizes),
                                jnp.float32(self.cfg.lr),
                                jax.random.split(key, self.n))
        self._emb = np.array(graph_mod.probe_embeddings(
            self.model.embed, stacked, self._probe), copy=True)
        self._rebuild_dynamic_graph()

    def _rebuild_dynamic_graph(self):
        from repro.core.graph_device import GraphConfig, build_3dg
        cfg = GraphConfig(eps=self._graph_eps, sigma2=self._graph_sigma2,
                          similarity="functional")
        _, _, h = build_3dg(jnp.asarray(self._emb, jnp.float32), cfg)
        self.sampler.set_graph(np.asarray(h))

    def _update_dynamic_embeddings(self, sel, local_stacked):
        emb = np.asarray(graph_mod.probe_embeddings(
            self.model.embed, local_stacked, self._probe))
        self._emb[sel] = emb

    # ---------------------------------------------------------------- round
    def run(self, progress: Callable | None = None, *,
            ckpt_path: str | None = None, ckpt_every: int = 0,
            resume: bool = False) -> History:
        """Run the federated rounds.  Randomness is derived per round from
        (seed, t) SeedSequences, so the process is Markov in
        (params, counts, t) and a checkpoint resume is exact; the
        availability stream stays independent of training randomness and
        identical across methods (Appendix C)."""
        cfg = self.cfg
        key0 = jax.random.PRNGKey(cfg.seed)
        params = self.model.init(key0)
        hist = History()
        start_round = 0
        # server-update state (momentum / Adam moments / update memory):
        # built from the initial params, then OVERWRITTEN wholesale by the
        # checkpoint on resume — stateful aggregators (fedavgm / fedadam /
        # fedprox_w / memory) resume bitwise-exactly (DESIGN.md §13; the
        # pre-§13 format dropped this state, pinned fixed by
        # tests/test_checkpoint_resume.py)
        self._server.init(params)
        # fault-injector state (AR(1) latency chain + stale panel) follows
        # the same init-then-overwrite-on-resume protocol as server state
        if self._faults is not None:
            self._faults.init(params)
        if resume and ckpt_path:
            import os
            from repro.checkpoint.ckpt import load_checkpoint
            if os.path.exists(ckpt_path if ckpt_path.endswith(".npz")
                              else ckpt_path + ".npz"):
                like = {"params": params, "counts": self.counts,
                        "round": np.zeros((), np.int64),
                        "server": self._server.state}
                if self._faults is not None:
                    like["faults"] = self._faults.state
                try:
                    state = load_checkpoint(ckpt_path, like=like)
                    self._server.state = jax.tree_util.tree_map(
                        jnp.asarray, state["server"])
                except KeyError:      # older checkpoint: missing server or
                    like.pop("server")      # fault state — those restart
                    like.pop("faults", None)
                    state = load_checkpoint(ckpt_path, like=like)
                params = jax.tree_util.tree_map(jnp.asarray, state["params"])
                self.counts = np.asarray(state["counts"], np.float64)
                start_round = int(state["round"]) + 1
                if "server" not in state:
                    self._server.init(params)
                if self._faults is not None:
                    if "faults" in state:
                        self._faults.state = jax.tree_util.tree_map(
                            jnp.asarray, state["faults"])
                    else:
                        self._faults.init(params)

        xs = jnp.asarray(self.ds.x)
        ys = jnp.asarray(self.ds.y)
        sizes = jnp.asarray(self.ds.sizes)
        xv = jnp.asarray(self.ds.x_val)
        yv = jnp.asarray(self.ds.y_val)

        # periodic saves go through the background writer so npz
        # serialization + disk I/O overlap the next round's device compute;
        # close() before returning drains the queue and re-raises any write
        # error (DESIGN.md §15)
        writer = AsyncCheckpointWriter() \
            if (ckpt_path and ckpt_every) else None
        self._writer_stats = None
        if self.sink is not None:
            self.sink.emit("run_start",
                           {"engine": "host", "rounds": cfg.rounds,
                            "start_round": start_round,
                            "sampler": self.sampler.name})
        try:
            self._run_rounds(hist, params, start_round, xs, ys, sizes, xv,
                             yv, progress, ckpt_path, ckpt_every, writer)
        finally:
            if writer is not None:
                try:
                    writer.close()
                finally:
                    self._writer_stats = writer.stats()
            if self.sink is not None:
                self.sink.emit("run_end",
                               {"engine": "host",
                                "runtime": self.runtime_stats()})
        return hist

    def _run_rounds(self, hist, params, start_round, xs, ys, sizes, xv, yv,
                    progress, ckpt_path, ckpt_every, writer):
        cfg = self.cfg
        key0 = jax.random.PRNGKey(cfg.seed)
        for t in range(start_round, cfg.rounds):
            rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, t]))
            key = jax.random.fold_in(key0, t)
            # the ONE shared host availability wrapper — the same call
            # precompute_masks stacks, so scan-engine mask cells replay this
            # engine's availability bit-exactly (works for AvailabilityMode
            # and ProcessMode scenario families alike)
            avail = host_draw(self.mode, t, cfg.avail_seed)
            losses = None
            if self._prober is not None:
                key, sub = jax.random.split(key)
                losses = jax.device_get(self._prober(
                    params, xs, ys, sizes, jax.random.split(sub, self.n)))
            sel = self.sampler.sample(
                avail=avail, m=self.m, rng=rng, counts=self.counts,
                data_sizes=self.ds.sizes, losses=losses, t=t)
            sel = np.asarray(sel, dtype=int)

            lr = cfg.lr * (cfg.lr_decay ** t)
            key, sub = jax.random.split(key)
            with self.tracer.span("local_train", t=t, m=len(sel)):
                local = self._trainer(params, xs[sel], ys[sel], sizes[sel],
                                      jnp.float32(lr),
                                      jax.random.split(sub, len(sel)))
            if self._faults is not None:
                local = self._faults.inject(local, params, sel, avail, t)
            with self.tracer.span("aggregate", t=t):
                params = self._server.apply(
                    local, self.ds.sizes[sel].astype(np.float32), sel,
                    avail, t)
            self.counts[sel] += 1

            if cfg.graph_refresh_every > 0 and hasattr(self, "_emb"):
                self._update_dynamic_embeddings(sel, local)
                if (t + 1) % cfg.graph_refresh_every == 0:
                    self._rebuild_dynamic_graph()

            if t % cfg.eval_every == 0 or t == cfg.rounds - 1:
                with self.tracer.span("eval", t=t):
                    vl, va = self._eval(params, xv, yv)
                from repro.core.fairness import count_variance
                hist.rounds.append(t)
                hist.val_loss.append(float(vl))
                hist.val_acc.append(float(va))
                hist.count_var.append(count_variance(self.counts))
                hist.sampled.append(sel.tolist())
                if self.sink is not None:
                    self.sink.emit("round",
                                   {"engine": "host", "t": t,
                                    "val_loss": float(vl),
                                    "val_acc": float(va),
                                    "count_var": hist.count_var[-1],
                                    "n_selected": int(len(sel)),
                                    "avail_rate":
                                    float(np.mean(avail))})
                if progress:
                    progress(t, float(vl), float(va))
            if writer is not None and (t + 1) % ckpt_every == 0:
                from repro.checkpoint.ckpt import save_checkpoint
                # snapshot on the main thread: params / server.state are
                # rebound functionally each round (the old trees stay
                # valid), but self.counts mutates in place — copy it
                snap = {"params": params, "counts": self.counts.copy(),
                        "round": np.asarray(t, np.int64),
                        "server": self._server.state}
                if self._faults is not None:
                    snap["faults"] = self._faults.state

                def _write(snap=snap, tn=t):
                    with self.tracer.span("checkpoint_write", round=tn):
                        save_checkpoint(
                            ckpt_path, snap,
                            metadata={"round": tn,
                                      "sampler": self.sampler.name,
                                      "aggregator":
                                      self._server.process.name})
                writer.submit(_write)
        self.params = params

"""Server-side aggregation — the thin HOST face over the device-native
aggregator subsystem (``fed/aggregator_device.py``, DESIGN.md §12).

``aggregate`` is the paper's Eq. 18 (kept as the one-call entry every
legacy caller imports), now with the zero-weight guard: passing the
previous global params makes a forced all-unavailable round (all weights
zero) a no-op instead of the all-zero pytree ``0 / 1e-12`` used to return.
:class:`ServerAggregator` is the per-round eager applier ``FLEngine`` and
``launch/train.py`` use — it carries the aggregator state (momentum, Adam
moments, the (N, P) update memory) across rounds and delegates every
update to the SAME device ``apply`` the scan engine traces, so host and
scan runs share one implementation per family.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.fed.aggregator_device import (
    AggregatorProcess, FedAvgProcess, fedavg_combine, init_agg_state,
    make_aggregator_step,
)


@jax.jit
def aggregate(stacked_params, weights, prev_params=None):
    """theta^{t+1} = sum_k w_k theta_k,  w_k = n_k / sum n  (Eq. 18).

    stacked_params: pytree with leading client axis (M, ...); weights (M,).
    With ``prev_params`` the all-weights-zero round returns the previous
    params unchanged (the zero-weight guard); without it the legacy
    unguarded average is kept (bit-identical op order — the guard is a
    post-hoc select)."""
    return fedavg_combine(stacked_params, weights, prev_params)


class ServerAggregator:
    """Host face: eager per-round application of an
    :class:`AggregatorProcess` (defaults to Eq. 18 FedAvg).

    ``init(params0)`` builds the carried state; ``apply`` takes the stacked
    local params, the Eq. 18 weights, the selected indices and the round's
    availability mask, and returns the new global params.  Steps are
    compiled per sampled-set size (the host path has no static M), as the
    process's SINGLE branch — same branch code as the scan switch (same
    numerics), but non-memory families never materialize the (N, P)
    update-memory panel (at LM scale that panel is N × |params| — the
    scan path carries it because mixed-family cells share one program;
    the eager host path knows its family up front).  The aggregator state
    IS checkpointed by the host engine (``FLEngine`` saves/restores
    ``ServerAggregator.state`` wholesale), so a resume is bitwise-exact for
    every family — stateless fedavg and the stateful momentum/Adam/memory
    ones alike (DESIGN.md §13; pinned by tests/test_checkpoint_resume.py)."""

    def __init__(self, process: AggregatorProcess | None = None, *,
                 n_clients: int, data_sizes=None, backend: str = "ref",
                 seed: int = 0):
        self.process = process if process is not None else FedAvgProcess()
        self.n = int(n_clients)
        self.data_sizes = None if data_sizes is None else np.asarray(data_sizes)
        self.backend = backend
        self._key = jax.random.PRNGKey(seed)
        self._steps: dict[int, object] = {}
        self.state = None

    def init(self, params0):
        rows = self.n if self.process.family == "memory" else 0
        self.state = init_agg_state(params0, self.n, memory_rows=rows)
        return self.state

    def _step(self, m: int):
        if m not in self._steps:
            step = make_aggregator_step(self.n, m, self.state["prev"],
                                        data_sizes=self.data_sizes,
                                        backend=self.backend,
                                        family=self.process.family)
            self._steps[m] = jax.jit(step)
        return self._steps[m]

    def apply(self, stacked_updates, weights, sel, avail, t: int):
        assert self.state is not None, "call init(params0) first"
        sel = np.asarray(sel, int)
        weights = np.asarray(weights, np.float32)
        if np.any(np.diff(sel) < 0):
            # the device gather convention is ascending sel; permute the
            # stacked rows/weights alongside so update k still lands in
            # client sel[k]'s memory row (in-repo samplers return sorted
            # indices, so this path never fires for them)
            order = np.argsort(sel, kind="stable")
            sel, weights = sel[order], weights[order]
            stacked_updates = jax.tree_util.tree_map(
                lambda x: jnp.asarray(x)[jnp.asarray(order)],
                stacked_updates)
        s = np.zeros(self.n, bool)
        s[sel] = True
        params, self.state = self._step(len(sel))(
            self.process.params(), self.state,
            jax.random.fold_in(self._key, t), stacked_updates,
            jnp.asarray(weights), jnp.asarray(s),
            jnp.asarray(avail, bool), t,
            jnp.asarray(sel, jnp.int32),               # host sel is the
            jnp.ones(len(sel), bool))                  # gather: all valid
        return params

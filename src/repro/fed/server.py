"""Server-side aggregation (paper Eq. 18)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


@jax.jit
def aggregate(stacked_params, weights):
    """theta^{t+1} = sum_k w_k theta_k,  w_k = n_k / sum n  (Eq. 18).

    stacked_params: pytree with leading client axis (M, ...); weights (M,)."""
    w = weights / jnp.maximum(jnp.sum(weights), 1e-12)

    def wsum(p):
        return jnp.tensordot(w.astype(p.dtype), p, axes=(0, 0))

    return jax.tree_util.tree_map(wsum, stacked_params)

"""Device-native aggregator subsystem.

FedGS fights long-term bias on the *sampling* side (Eq. 6 -> Eq. 16); under
arbitrary availability the server update is the other bias lever: FedAR
(Jiang et al., 2024) and MIFA-style memory aggregation keep and rectify the
last update of EVERY client — including unavailable ones — which directly
reduces the participation bias FedGS targets.  Until this module the server
side was one hard-coded FedAvg ``aggregate()`` (Eq. 18) — the last per-round
step that was not a subsystem.  This is the graph/availability/sampler
unification applied to aggregation (DESIGN.md §12): ONE pure,
jit/vmap/scan-traceable implementation of every server-update rule that the
scan engine carries through ``lax.scan``, the host engine wraps eagerly
(``fed/server.py::ServerAggregator``), and mixed-aggregator sweep cells
batch through a single ``run_batch`` program.

An :class:`AggregatorProcess` is

    ``init(params0, n_clients) -> state``                      (eager, host)
    ``apply(state, key, stacked_updates, weights, s, avail, t)
        -> (params, state)``                              (pure, traceable)

where ``stacked_updates`` is the (M, ...) pytree of locally-trained client
params, ``weights`` the (M,) Eq. 18 weights (``n_k * valid_k`` — pads carry
zero), ``s``/``avail`` the (N,) selection/availability masks, and every
family compiles to ONE ``lax.switch`` branch index
(:func:`make_aggregator_step`) so cells of DIFFERENT aggregators vmap-batch
together — previously the aggregation rule was not even a knob.

Families (``FAMILIES`` — the switch order; == ``scan_engine.AGGREGATORS``):

  ========= ================== ===========================================
  family    process            server update
  ========= ================== ===========================================
  fedavg    FedAvgProcess      Eq. 18 ``theta = sum w_k theta_k / sum w``
                               (bit-parity with the legacy ``aggregate()``
                               pinned), zero-weight guard -> params kept
  fedavgm   FedAvgMProcess     server momentum (Hsu et al. 2019):
                               ``mom = beta mom + (prev - avg)``,
                               ``theta = prev - lr_s mom``
  fedadam   FedAdamProcess     adaptive server step (Reddi et al. 2021,
                               no bias correction, per the paper):
                               ``m = b1 m + (1-b1) d``, ``v = b2 v +
                               (1-b2) d^2``, ``theta = prev + lr_s m /
                               (sqrt(v) + eps)`` with ``d = avg - prev``
  fedprox_w FedProxWProcess    proximal-weighted averaging: Eq. 18 with
                               ``w_k / (1 + mu ||theta_k - prev||^2)`` —
                               far-drifted clients are down-weighted
  memory    MemoryProcess      FedAR/MIFA-style rectification: a per-client
                               (N, P) last-update table; participants
                               overwrite their row, then ``theta = sum_k
                               w_k mem_k`` over ALL N clients with
                               staleness-discounted weights
                               ``w_k ∝ n_k gamma^(t - tau_k)``
  median    MedianProcess      coordinate-wise lower median of the valid
                               updates — 1/2 breakdown point per
                               coordinate (robust-statistics classic)
  trimmed_  TrimmedMeanProcess per-coordinate beta-trimmed mean: drop the
  mean                         k = floor(beta v) smallest and largest
                               entries, average the rest (Yin et al. 2018)
  krum      KrumProcess        Krum / multi-Krum (Blanchard et al. 2017):
                               score_i = sum of the v − f − 2 smallest
                               squared distances to the other updates;
                               keep the k lowest-scoring updates and
                               average them uniformly.  The (m, m)
                               distance panel dispatches ``ref | pallas``
                               (``kernels/krum.py``)
  ========= ================== ===========================================

The three robust families are the fault-tolerance counterpart of the
``fed/faults_device.py`` injection seam: they deliberately IGNORE the
Eq. 18 size weights (a data-rich Byzantine client must not buy itself
extra mass) and map NaN-poisoned coordinates to +inf before sorting, so
the PR-5 NaN-containment semantics hold for them too — a poisoned update
is an extreme order statistic, trimmed/out-voted like any other outlier.

The runtime representation is a uniform *params* pytree (family index,
packed ``theta`` knobs) plus a uniform *state* pytree (``prev`` global
params, two params-shaped moment slots ``m1``/``m2``, the flat ``mem``
(N, P) update-memory panel and its ``tau`` (N,) last-participation
vector), so heterogeneous aggregators stack along a vmap batch axis
(``scan_engine.stack_cells``).  ``prev`` doubles as the global-parameter
scan carry: the engines read ``state["prev"]`` instead of carrying params
twice.

The memory family dispatches ``backend="ref" | "pallas"`` exactly like
``fedgs_solve``: ``ref`` is the pure-jnp O(mP) row scatter + one (N,) @
(N, P) reduction; ``pallas`` routes both through
``kernels/ops.memory_aggregate`` (``kernels/aggregate.py``) — the masked
scatter of the m sampled rows is fused in-tile (one-hot MXU matmul) with
the staleness-weighted row reduction, so the post-scatter panel is
consumed where it is produced and nothing (N, P)-sized is materialized per
params leaf (the pytree is raveled to ONE flat (P,) axis).  The scattered
panel is BIT-identical across backends; the reduction is numerically equal
(tile-order partial sums — asserted in tests and BENCH_aggregator.json).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

import jax
import jax.numpy as jnp
from jax import flatten_util

from repro.core.sampler_device import select_k

FAMILIES = ("fedavg", "fedavgm", "fedadam", "fedprox_w", "memory",
            "median", "trimmed_mean", "krum")
BACKENDS = ("ref", "pallas")

THETA_DIM = 6          # packed per-family scalar knobs (see the branch readers)


# ------------------------------------------------------------ shared helpers
def _flat_template(params_like):
    """(ravel, unravel, P) for a params pytree of arrays OR ShapeDtypeStructs
    — the one flattening convention every memory-panel consumer shares."""
    zeros = jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, x.dtype), params_like)
    flat0, unravel = flatten_util.ravel_pytree(zeros)

    def ravel(pt):
        return flatten_util.ravel_pytree(pt)[0].astype(jnp.float32)

    return ravel, unravel, int(flat0.shape[0])


def guard_zero_weight(avg, prev, total):
    """The ONE zero-weight guard (assumption log #15): keep ``avg`` when
    any weight fired, fall back to the previous params on an all-zero
    round — shared by ``fedavg_combine`` and the memory branch so the
    guard semantics cannot diverge between families."""
    return jax.tree_util.tree_map(
        lambda a, p0: jnp.where(total > 0, a, p0.astype(a.dtype)),
        avg, prev)


def fedavg_combine(stacked_params, weights, prev_params=None):
    """Eq. 18: ``theta = sum_k w_k theta_k, w_k = n_k / sum n`` — the EXACT
    legacy ``fed/server.aggregate`` op order (bit-parity pinned by
    ``tests/test_aggregator_device.py``), plus the zero-weight guard: with
    ``prev_params`` given and all weights zero (a forced all-unavailable
    round), the previous global params are returned instead of the all-zero
    pytree ``0 / 1e-12`` used to produce.  ``prev_params=None`` keeps the
    unguarded legacy behaviour for callers without a previous model."""
    total = jnp.sum(weights)
    w = weights / jnp.maximum(total, 1e-12)

    def wsum(p):
        return jnp.tensordot(w.astype(p.dtype), p, axes=(0, 0))

    avg = jax.tree_util.tree_map(wsum, stacked_params)
    if prev_params is None:
        return avg
    return guard_zero_weight(avg, prev_params, total)


def init_agg_state(params0, n_clients: int,
                   memory_rows: int | None = None,
                   tau_rows: int | None = None) -> dict:
    """The uniform carried state every family shares (family-INDEPENDENT, so
    the engines build it without knowing the cell's aggregator):

      ``prev``  the global params (this slot IS the engines' param carry)
      ``m1``    momentum / Adam first moment        (zeros)
      ``m2``    Adam second moment                  (zeros)
      ``mem``   (N, P) per-client last-update panel, every row initialized
                to flat(params0) — a never-seen client contributes the
                INITIAL model, discounted by its staleness (DESIGN.md
                assumption log #15)
      ``tau``   (N,) last participation round, init 0 (the memory rows are
                treated as a round-0 pseudo-update)

    ``memory_rows`` overrides the panel row count: the eager host path
    passes 0 for non-memory families so a big-model FedAvg run never
    materializes the (N, P) panel (the pytree KEYS stay — uniformity is
    about structure; the scan path keeps the full panel because cells of
    any family share one switch program).  ``tau_rows`` decouples the
    ``tau`` vector length from the panel rows: the shard_map'd engine's
    psum mode keeps ``tau`` global (N,) while each silo shard holds only
    its (N/s, P) panel slice (DESIGN.md §13).
    """
    rows = n_clients if memory_rows is None else memory_rows
    trows = rows if tau_rows is None else tau_rows
    ravel, _, _ = _flat_template(params0)
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params0)
    flat0 = ravel(params0)
    return {"prev": params0,
            "m1": zeros,
            "m2": zeros,
            "mem": jnp.tile(flat0[None, :], (rows, 1)),
            "tau": jnp.zeros((trows,), jnp.float32)}


def memory_scatter_reduce_ref(mem, upd, sel, valid, w):
    """The memory family's REF backend, shared by the switch branch, the
    benchmark and the parity tests (so 'ref vs pallas' always compares the
    shipped path): O(mP) masked row scatter + one (N,)·(N, P) tensordot."""
    mem2 = mem.at[sel].set(jnp.where(valid[:, None], upd, mem[sel]))
    return mem2, jnp.tensordot(w, mem2, axes=(0, 0))


# ----------------------------------------------------- robust combine rules
# Shared by the switch branches, the numpy-oracle tests and the robustness
# bench, so "branch vs oracle" always pins the shipped math.  All three
# operate on the flat (M, P) update panel with a (M,) valid mask, map
# NaN-poisoned coordinates and pad rows to +inf before sorting (one mapping
# buys both NaN containment and the Byzantine breakdown bound), and ignore
# the Eq. 18 size weights (see the module docstring).
def coordinate_median(updf, valid):
    """Coordinate-wise LOWER median — sorted index ``(v − 1) // 2`` of the
    v valid entries per coordinate.  With f < v/2 arbitrarily corrupted
    rows (±inf included) the median index always lands on an honest order
    statistic: at most f entries sort below it and at most f above.
    Returns ``(median (P,), v)``."""
    v = jnp.sum(valid.astype(jnp.int32))
    x = jnp.where(jnp.isnan(updf), jnp.inf, updf)
    x = jnp.where(valid[:, None], x, jnp.inf)
    srt = jnp.sort(x, axis=0)
    return srt[jnp.maximum((v - 1) // 2, 0)], v


def trimmed_mean_combine(updf, valid, beta):
    """Per-coordinate beta-trimmed mean (Yin et al. 2018): sort the v valid
    entries, drop the ``k = min(floor(beta v), (v − 1) // 2)`` smallest and
    largest, average the rest — op order is sum-then-divide over the kept
    window (assumption log #21; the oracle mirrors it).  ``k >= f`` removes
    every one-sided corruption; the f32 product ``beta * v`` floors exactly
    like the numpy-f32 oracle.  Returns ``(mean (P,), v)``."""
    v = jnp.sum(valid.astype(jnp.int32))
    x = jnp.where(jnp.isnan(updf), jnp.inf, updf)
    x = jnp.where(valid[:, None], x, jnp.inf)
    srt = jnp.sort(x, axis=0)
    k = jnp.maximum(jnp.minimum(
        jnp.floor(beta * v.astype(jnp.float32)).astype(jnp.int32),
        (v - 1) // 2), 0)
    ii = jnp.arange(updf.shape[0])[:, None]
    keep = (ii >= k) & (ii < v - k)
    kept = jnp.sum(jnp.where(keep, srt, 0.0), axis=0)
    return kept / jnp.maximum(v - 2 * k, 1).astype(jnp.float32), v


def krum_pairwise_ref(updf):
    """REF backend of the Krum squared-distance panel, shared by the switch
    branch, the bench and the ref-vs-pallas parity tests:
    ``D = ||x_i||² + ||x_j||² − 2 X Xᵀ``."""
    x = updf.astype(jnp.float32)
    n2 = jnp.sum(x * x, axis=1)
    return n2[:, None] + n2[None, :] - 2.0 * (x @ x.T)


def krum_select(updf, valid, f_byz, multi, *, backend: str = "ref",
                interpret: bool | None = None):
    """Krum / multi-Krum selection (Blanchard et al., NeurIPS 2017) over
    the valid rows of the flat (M, P) panel.

    score_i = sum of the ``nn = clip(v − f − 2, 1, m − 1)`` smallest
    squared distances from row i to the other valid rows; the ``k =
    clip(multi, 1, v)`` lowest-scoring rows win.  Rank ties break by row
    index (double STABLE argsort — ``jnp.argsort`` is stable, matching the
    ``np.argsort(kind="stable")`` oracle bit-for-bit).  Distance hygiene:
    the expansion is clamped at 0, NaN entries (inf − inf of ±inf-poisoned
    pairs, or NaN-poisoned rows) map to +inf, and diagonal / invalid pairs
    are +inf — so a poisoned row's score is +inf and it can only be chosen
    when k exceeds the finite-score rows (``chosen`` is additionally
    masked by ``valid`` so pad rows NEVER win a tie against a real row).
    ``v < f + 3`` (outside Blanchard's m >= 2f + 3 regime) degrades
    gracefully to nearest-neighbor scoring via the nn clamp.  Returns
    ``(chosen (M,) bool, scores (M,) f32)``."""
    m = updf.shape[0]
    if backend == "pallas":
        from repro.kernels.ops import krum_distances
        d = krum_distances(updf.astype(jnp.float32), interpret=interpret)
    else:
        d = krum_pairwise_ref(updf)
    d = jnp.maximum(d, 0.0)
    d = jnp.where(jnp.isnan(d), jnp.inf, d)
    pair_ok = valid[:, None] & valid[None, :] & ~jnp.eye(m, dtype=bool)
    d = jnp.where(pair_ok, d, jnp.inf)
    v = jnp.sum(valid.astype(jnp.int32))
    nn = jnp.clip(v - f_byz - 2, 1, max(m - 1, 1))
    ds = jnp.sort(d, axis=1)
    take = jnp.arange(m)[None, :] < nn
    scores = jnp.sum(jnp.where(take, ds, 0.0), axis=1)
    scores = jnp.where(valid, scores, jnp.inf)
    kk = jnp.clip(multi, 1, jnp.maximum(v, 1))
    rank = jnp.argsort(jnp.argsort(scores))
    chosen = (rank < kk) & valid
    return chosen, scores


def krum_combine(updf, valid, f_byz, multi, *, backend: str = "ref",
                 interpret: bool | None = None):
    """:func:`krum_select` + the UNWEIGHTED mean of the chosen rows
    (multi-Krum averages uniformly).  Returns ``(combined (P,), chosen,
    scores)``."""
    chosen, scores = krum_select(updf, valid, f_byz, multi,
                                 backend=backend, interpret=interpret)
    cnt = jnp.sum(chosen.astype(jnp.float32))
    out = jnp.sum(jnp.where(chosen[:, None], updf.astype(jnp.float32), 0.0),
                  axis=0) / jnp.maximum(cnt, 1.0)
    return out, chosen, scores


# ------------------------------------------------------- the switch step
def make_aggregator_step(n: int, m: int, params_like, *, data_sizes=None,
                         backend: str = "ref",
                         interpret: bool | None = None,
                         family: str | None = None,
                         memory_enabled: bool = True,
                         panel_axis: str | None = None):
    """Compile-time constructor of the ONE per-round aggregator step

        ``step(aparams, state, key, stacked_updates, weights, s, avail, t)
            -> (params, state)``

    dispatching ``lax.switch`` on the cell's family index, so cells of
    DIFFERENT aggregators batch through one vmapped program (under vmap the
    switch lowers to a select over all branches; the extra branches' cost is
    small next to local training — DESIGN.md §12).

    ``params_like`` is a template pytree (arrays or ShapeDtypeStructs) that
    fixes the flat memory-panel layout; ``data_sizes`` the (N,) per-client
    sizes the memory family's rectified weights use (all-ones when omitted);
    ``backend`` routes the memory scatter+reduction (``ref`` | ``pallas``).
    ``key`` is the per-round aggregator key — reserved for stochastic
    families; none of the current five consumes it.

    ``family=None`` builds the full switch (the scan path); naming a
    family builds that single branch directly — SAME branch code, so
    numerics are identical, but the other branches never trace, which is
    what lets the eager host path (``fed/server.ServerAggregator``) skip
    the (N, P) memory panel for non-memory families.  ``memory_enabled=
    False`` aliases the switch's memory slot to the fedavg branch so a
    NO-memory-cell scan program can carry a 0-row panel
    (``init_agg_state(memory_rows=0)``) without tracing the scatter —
    callers (``ScanEngine``) must dispatch memory cells to a
    memory-enabled program.

    ``panel_axis`` names a shard_map mesh axis over which the (N, P)
    memory panel is ROW-sharded (the scan engine's "silo" axis, DESIGN.md
    §13): the step then sees only the local (N/s, P) slice in
    ``state["mem"]`` (``tau`` stays global (N,)), scatters the sampled
    rows that land in its slice (out-of-range indices drop, XLA scatter
    semantics), reduces its partial staleness-weighted sum and ``psum``s
    across the axis — the per-tile locality of the fused kernel turned
    into a collective.  Only meaningful inside ``shard_map``.
    """
    if backend not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}, not {backend!r}")
    if family is not None and family not in FAMILIES:
        raise ValueError(f"family must be one of {FAMILIES}, not {family!r}")
    if family == "memory" and not memory_enabled:
        raise ValueError("family='memory' requires memory_enabled=True")
    ravel, unravel, _ = _flat_template(params_like)
    sizes = (jnp.ones((n,), jnp.float32) if data_sizes is None
             else jnp.asarray(data_sizes, jnp.float32))

    def _fedavg(ap, state, key, upd, w, s, avail, t, sel, valid):
        new = fedavg_combine(upd, w, state["prev"])
        return new, {**state, "prev": new}

    def _fedavgm(ap, state, key, upd, w, s, avail, t, sel, valid):
        """Server momentum on the pseudo-gradient ``prev - avg`` (a
        zero-weight round contributes a zero pseudo-gradient: the momentum
        keeps decaying, the params keep drifting along it)."""
        lr_s, beta = ap["theta"][0], ap["theta"][1]
        avg = fedavg_combine(upd, w, state["prev"])
        m1 = jax.tree_util.tree_map(
            lambda mo, p0, a: beta * mo + (p0 - a), state["m1"],
            state["prev"], avg)
        new = jax.tree_util.tree_map(
            lambda p0, mo: p0 - lr_s * mo, state["prev"], m1)
        return new, {**state, "prev": new, "m1": m1}

    def _fedadam(ap, state, key, upd, w, s, avail, t, sel, valid):
        """Reddi et al. 2021 FedAdam (no bias correction, per the paper)."""
        lr_s, b1, b2 = ap["theta"][0], ap["theta"][1], ap["theta"][2]
        eps = ap["theta"][3]
        avg = fedavg_combine(upd, w, state["prev"])
        delta = jax.tree_util.tree_map(
            lambda a, p0: a - p0, avg, state["prev"])
        m1 = jax.tree_util.tree_map(
            lambda mo, d: b1 * mo + (1.0 - b1) * d, state["m1"], delta)
        m2 = jax.tree_util.tree_map(
            lambda vo, d: b2 * vo + (1.0 - b2) * d * d, state["m2"], delta)
        new = jax.tree_util.tree_map(
            lambda p0, mo, vo: p0 + lr_s * mo / (jnp.sqrt(vo) + eps),
            state["prev"], m1, m2)
        return new, {**state, "prev": new, "m1": m1, "m2": m2}

    def _fedprox_w(ap, state, key, upd, w, s, avail, t, sel, valid):
        """Eq. 18 with each weight damped by the client's squared drift from
        the previous global model — far-drifted (non-iid-shocked) updates
        pull less.  Pads keep zero weight (0 / (1 + mu·drift) = 0)."""
        mu = ap["theta"][0]
        prevf = ravel(state["prev"])
        updf = jax.vmap(ravel)(upd)                       # (M, P)
        drift = jnp.sum((updf - prevf[None, :]) ** 2, axis=1)
        w2 = w / (1.0 + mu * drift)
        new = fedavg_combine(upd, w2, state["prev"])
        return new, {**state, "prev": new}

    def _memory(ap, state, key, upd, w, s, avail, t, sel, valid):
        """FedAR/MIFA-style memory rectification over ALL N clients: the m
        sampled rows are scattered into the (N, P) panel, then the new
        params are the staleness-discounted, size-weighted row reduction
        ``sum_k n_k gamma^(t - tau_k) mem_k / Z`` — unavailable clients'
        last updates keep pulling the average, which is the bias
        correction (DESIGN.md assumption log #14).  gamma -> 0 recovers
        FedAvg over the sampled set; gamma = 1 is full MIFA memory."""
        gamma = ap["theta"][0]
        updf = jax.vmap(ravel)(upd)                       # (M, P)
        tf = t.astype(jnp.float32)
        tau = jnp.where(s, tf, state["tau"])
        age = jnp.maximum(tf - tau, 0.0)
        wmem = sizes * gamma ** age                       # (N,)
        total = jnp.sum(wmem)
        wn = wmem / jnp.maximum(total, 1e-12)
        if panel_axis is not None:
            # row-sharded panel: scatter the sampled rows that fall in this
            # shard's slice (out-of-range indices drop), partial-reduce the
            # local rows, psum the (P,) partials across the silo axis
            rows = state["mem"].shape[0]
            off = jax.lax.axis_index(panel_axis) * rows
            lsel = sel - off
            hit = valid & (lsel >= 0) & (lsel < rows)
            mem = state["mem"].at[jnp.where(hit, lsel, rows)].set(updf)
            wn_l = jax.lax.dynamic_slice_in_dim(wn, off, rows)
            red = jax.lax.psum(jnp.tensordot(wn_l, mem, axes=(0, 0)),
                               panel_axis)
        elif backend == "pallas":
            from repro.kernels.ops import memory_aggregate
            mem, red = memory_aggregate(state["mem"], updf, sel, valid, wn,
                                        interpret=interpret)
        else:
            mem, red = memory_scatter_reduce_ref(state["mem"], updf, sel,
                                                 valid, wn)
        new = guard_zero_weight(unravel(red), state["prev"], total)
        return new, {**state, "prev": new, "mem": mem, "tau": tau}

    def _median(ap, state, key, upd, w, s, avail, t, sel, valid):
        """Coordinate-wise lower median of the valid updates (weights
        ignored — see :func:`coordinate_median`)."""
        med, v = coordinate_median(jax.vmap(ravel)(upd), valid)
        new = guard_zero_weight(unravel(med), state["prev"], v)
        return new, {**state, "prev": new}

    def _trimmed_mean(ap, state, key, upd, w, s, avail, t, sel, valid):
        """Per-coordinate beta-trimmed mean, ``beta = theta[0]``."""
        beta = ap["theta"][0]
        tm, v = trimmed_mean_combine(jax.vmap(ravel)(upd), valid, beta)
        new = guard_zero_weight(unravel(tm), state["prev"], v)
        return new, {**state, "prev": new}

    def _krum(ap, state, key, upd, w, s, avail, t, sel, valid):
        """Krum / multi-Krum, ``f = theta[0]``, ``k = theta[1]``; the
        distance panel routes through the module ``backend`` knob (the
        same ``agg_backend`` that routes the memory scatter)."""
        f_byz = jnp.round(ap["theta"][0]).astype(jnp.int32)
        multi = jnp.round(ap["theta"][1]).astype(jnp.int32)
        out, chosen, _ = krum_combine(jax.vmap(ravel)(upd), valid, f_byz,
                                      multi, backend=backend,
                                      interpret=interpret)
        new = guard_zero_weight(unravel(out), state["prev"],
                                jnp.sum(chosen.astype(jnp.int32)))
        return new, {**state, "prev": new}

    branches = {"fedavg": _fedavg, "fedavgm": _fedavgm, "fedadam": _fedadam,
                "fedprox_w": _fedprox_w,
                "memory": _memory if memory_enabled else _fedavg,
                "median": _median, "trimmed_mean": _trimmed_mean,
                "krum": _krum}

    def step(aparams, state, key, stacked_updates, weights, s, avail, t,
             sel=None, valid=None):
        """``sel``/``valid`` (the ``select_k(s, m)`` gather of the engines)
        can be passed when the caller already computed them — otherwise
        they are derived here (same helper, same order)."""
        t = jnp.asarray(t, jnp.int32)
        if sel is None:
            sel, valid = select_k(s, m)
        if family is not None:
            return branches[family](aparams, state, key, stacked_updates,
                                    weights, s, avail, t, sel, valid)
        return jax.lax.switch(aparams["family"],
                              [branches[f] for f in FAMILIES],
                              aparams, state, key, stacked_updates,
                              weights, s, avail, t, sel, valid)

    return step


# ------------------------------------------------------------ the processes
@dataclass
class AggregatorProcess:
    """Base class.  ``params()``/``init(params0, n)`` are eager host-side
    constructors of the per-cell runtime pytrees; :meth:`apply` is the pure
    traceable entry point (single-process convenience over the switch step,
    guaranteed identical because it IS the switch path).  Every family fills
    the SAME params pytree (family index, packed theta) so heterogeneous
    aggregator cells stack along a vmap batch axis
    (``scan_engine.stack_cells``)."""

    family = "fedavg"
    name = "process"

    def _theta(self) -> np.ndarray:
        return np.zeros(0)

    def params(self) -> dict:
        theta = np.zeros(THETA_DIM, np.float32)
        th = np.asarray(self._theta(), np.float32)
        theta[:th.shape[0]] = th
        return {"family": jnp.int32(FAMILIES.index(self.family)),
                "theta": jnp.asarray(theta)}

    def init(self, params0, n_clients: int) -> dict:
        """Initial carried state — family-independent (the uniform pytree
        of :func:`init_agg_state`), so the engines can build it without
        inspecting the process."""
        return init_agg_state(params0, n_clients)

    # -- traceable entry point --------------------------------------------
    def apply(self, state, key, stacked_updates, weights, s, avail, t, *,
              data_sizes=None, backend: str = "ref",
              interpret: bool | None = None):
        """Single-shot convenience; ``m`` is read off the stacked leading
        axis.  ``data_sizes`` feeds the memory family's rectified weights —
        without it they fall back to all-ones."""
        n = s.shape[-1]
        m = int(jax.tree_util.tree_leaves(stacked_updates)[0].shape[0])
        step = make_aggregator_step(n, m, state["prev"],
                                    data_sizes=data_sizes, backend=backend,
                                    interpret=interpret)
        return step(self.params(), state, key, stacked_updates, weights,
                    s, avail, t)


@dataclass
class FedAvgProcess(AggregatorProcess):
    """Eq. 18, bit-parity with the legacy ``aggregate()`` (plus the
    zero-weight guard)."""
    name: str = "fedavg"
    family = "fedavg"


@dataclass
class FedAvgMProcess(AggregatorProcess):
    """Hsu et al. 2019 server momentum."""
    server_lr: float = 1.0
    beta: float = 0.9
    name: str = "fedavgm"
    family = "fedavgm"

    def _theta(self):
        return np.array([self.server_lr, self.beta])


@dataclass
class FedAdamProcess(AggregatorProcess):
    """Reddi et al. 2021 adaptive federated optimization (FedAdam)."""
    server_lr: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.99
    eps: float = 1e-3
    name: str = "fedadam"
    family = "fedadam"

    def _theta(self):
        return np.array([self.server_lr, self.beta1, self.beta2, self.eps])


@dataclass
class FedProxWProcess(AggregatorProcess):
    """Proximal-weighted averaging: ``w_k <- w_k / (1 + mu ||d_k||^2)``."""
    mu: float = 0.1
    name: str = "fedprox_w"
    family = "fedprox_w"

    def _theta(self):
        return np.array([self.mu])


@dataclass
class MemoryProcess(AggregatorProcess):
    """FedAR/MIFA-style per-client update memory with staleness-discounted
    rectification; ``gamma`` is the per-round staleness discount (per-cell
    traced, so gamma-variants batch together)."""
    gamma: float = 0.9
    name: str = "memory"
    family = "memory"

    def __post_init__(self):
        self.name = f"memory(gamma={self.gamma})"

    def _theta(self):
        return np.array([max(self.gamma, 1e-6)])


@dataclass
class MedianProcess(AggregatorProcess):
    """Coordinate-wise lower median (1/2 breakdown per coordinate)."""
    name: str = "median"
    family = "median"


@dataclass
class TrimmedMeanProcess(AggregatorProcess):
    """Per-coordinate beta-trimmed mean (Yin et al. 2018); ``beta`` is the
    per-side trim fraction (per-cell traced, so beta-variants batch)."""
    beta: float = 0.2
    name: str = "trimmed_mean"
    family = "trimmed_mean"

    def __post_init__(self):
        self.name = f"trimmed_mean(beta={self.beta})"

    def _theta(self):
        return np.array([self.beta])


@dataclass
class KrumProcess(AggregatorProcess):
    """Krum / multi-Krum (Blanchard et al. 2017): ``f`` is the Byzantine
    budget the score defends against, ``multi`` the number of selected
    updates averaged (1 = classic Krum)."""
    f: int = 1
    multi: int = 1
    name: str = "krum"
    family = "krum"

    def __post_init__(self):
        self.name = (f"krum(f={self.f})" if self.multi <= 1
                     else f"multikrum(f={self.f},k={self.multi})")

    def _theta(self):
        return np.array([float(self.f), float(self.multi)])


def make_aggregator_process(name: str, *, server_lr: float | None = None,
                            beta: float = 0.9, mu: float = 0.1,
                            gamma: float = 0.9, beta_trim: float = 0.2,
                            krum_f: int = 1,
                            krum_multi: int = 1) -> AggregatorProcess:
    """Family names (= ``scan_engine.AGGREGATORS``) -> processes."""
    name = name.lower()
    if name == "fedavg":
        return FedAvgProcess()
    if name == "fedavgm":
        return FedAvgMProcess(server_lr=1.0 if server_lr is None
                              else server_lr, beta=beta)
    if name == "fedadam":
        return FedAdamProcess(server_lr=0.1 if server_lr is None
                              else server_lr)
    if name in ("fedprox_w", "fedproxw"):
        return FedProxWProcess(mu=mu)
    if name == "memory":
        return MemoryProcess(gamma=gamma)
    if name == "median":
        return MedianProcess()
    if name in ("trimmed_mean", "trimmedmean"):
        return TrimmedMeanProcess(beta=beta_trim)
    if name in ("krum", "multikrum"):
        return KrumProcess(f=krum_f,
                           multi=krum_multi if name == "krum" else
                           max(krum_multi, 2))
    raise ValueError(f"unknown aggregator family {name!r}")

"""Zero-copy engine runtime: donated carries, persistent compile cache,
async checkpoint/transfer pipelining (DESIGN.md §15).

PRs 6–7 made the per-round *math* fast; this module makes the runtime
around the compiled programs hot-path too.  Four pieces, shared by both
engines (``fed/scan_engine.py``, ``fed/engine.py``) and the service
front-end (``launch/serve.py``):

``enable_compile_cache(dir)``
    Wires ``jax``'s persistent compilation cache
    (``jax.experimental.compilation_cache``) so a re-launched sweep or a
    second service process pays XLA compile once per (program, device
    topology) — entries are keyed by XLA on the optimized HLO + compile
    options + backend, so heterogeneous programs never collide.  Thresholds
    are dropped to cache-everything: the engine's programs are few and
    re-compiled from scratch they dominate warm-start latency.

``ProgramCache``
    A bounded LRU over the engines' jitted programs (the old ``_jits``
    dict grew unboundedly across heterogeneous sweeps) with hit / miss /
    eviction / compile-event counters.  Compile time is measured per call:
    a call that grows the underlying jit's executable cache is a compile
    event and its wall-clock (trace + lower + XLA or persistent-cache
    load; dispatch is async so steady-state calls return in ~µs) is
    recorded as ``compile_ms`` — this is what splits first-call compile
    from steady-state run in the benches.

``CarryHandle``
    The donation-safety audit.  ``jax.jit(..., donate_argnums=...)`` frees
    the scan carry's input buffers for in-place reuse; a caller that still
    holds the old carry would read garbage (or, on backends that implement
    donation, trip a late "Array has been deleted").  Every carry the
    engine hands out is wrapped in a handle that is invalidated the moment
    a donated program consumes it — use-after-donation is a LOUD,
    immediate ``RuntimeError`` on every backend, not a heisenbug.

``AsyncCheckpointWriter``
    One background thread, bounded queue, strict submission order: npz
    checkpoint serialization + disk write overlap the next segment's
    device compute instead of blocking the dispatch loop.  ``close()``
    drains the queue and re-raises the first worker error so failures are
    never silent.
"""
from __future__ import annotations

import os
import queue
import threading
import time
import warnings
from collections import OrderedDict
from typing import Callable, Optional

import jax

# Backends that cannot honor a donation simply keep the copy and warn;
# the engine's semantics (CarryHandle consume-once) are identical either
# way, so the warning is noise — donation is best-effort by design.
warnings.filterwarnings(
    "ignore", message="Some donated buffers were not usable")


# ------------------------------------------------------- persistent cache
def enable_compile_cache(cache_dir: str | os.PathLike) -> str:
    """Point jax's persistent compilation cache at ``cache_dir`` (created
    if missing) and drop the size/time thresholds so every engine program
    is cached.  Idempotent; returns the directory.  Keying (trust the
    cache): XLA fingerprints the optimized HLO module + compile options +
    backend/topology, so a program compiled for one device count never
    serves another."""
    cache_dir = os.fspath(cache_dir)
    os.makedirs(cache_dir, exist_ok=True)
    if jax.config.jax_compilation_cache_dir != cache_dir:
        # the persistent-cache layer initializes ONCE per process, at the
        # first compile — if that happened before this call (or with a
        # different dir), the config update alone is a silent no-op; reset
        # so the next compile re-initializes against cache_dir
        from jax.experimental.compilation_cache import (
            compilation_cache as _cc,
        )
        _cc.reset_cache()
    jax.config.update("jax_compilation_cache_dir", cache_dir)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    return cache_dir


# ----------------------------------------------------------- program LRU
class _TimedProgram:
    """Wraps one jitted callable; detects compile events by watching the
    jit executable-cache size across calls (dispatch is async, so a timed
    call that did NOT compile returns in dispatch time, while a compile
    call pays trace + lower + XLA / persistent-cache load)."""

    def __init__(self, fn, stats: dict):
        self._fn = fn
        self._stats = stats

    def __call__(self, *args, **kwargs):
        probe = getattr(self._fn, "_cache_size", None)
        before = probe() if probe is not None else -1
        t0 = time.perf_counter()
        out = self._fn(*args, **kwargs)
        if probe is not None and probe() > before:
            self._stats["compiles"] += 1
            self._stats["compile_ms"] += (time.perf_counter() - t0) * 1e3
        return out

    def __getattr__(self, name):          # .lower(...) etc. pass through
        return getattr(self._fn, name)


class ProgramCache:
    """Bounded LRU of compiled programs keyed on static config, with
    hit / miss / eviction / compile counters — the replacement for the
    engines' unbounded ``_jits`` dicts."""

    def __init__(self, maxsize: int = 32):
        if maxsize < 1:
            raise ValueError(f"ProgramCache needs maxsize >= 1, "
                             f"got {maxsize}")
        self.maxsize = int(maxsize)
        self._programs: OrderedDict = OrderedDict()
        self._stats = {"hits": 0, "misses": 0, "evictions": 0,
                       "compiles": 0, "compile_ms": 0.0}

    def __len__(self) -> int:
        return len(self._programs)

    def __contains__(self, key) -> bool:
        return key in self._programs

    def get(self, key, build: Callable[[], Callable]):
        """The program for ``key``, building (and possibly evicting the
        least-recently-used entry) on miss."""
        if key in self._programs:
            self._stats["hits"] += 1
            self._programs.move_to_end(key)
            return self._programs[key]
        self._stats["misses"] += 1
        prog = _TimedProgram(build(), self._stats)
        self._programs[key] = prog
        while len(self._programs) > self.maxsize:
            self._programs.popitem(last=False)
            self._stats["evictions"] += 1
        return prog

    def stats(self) -> dict:
        """Counters snapshot: hits, misses, evictions, compiles,
        compile_ms (sum over compile events), size."""
        return {**self._stats, "size": len(self._programs)}


# ------------------------------------------------------- donated carries
class CarryHandle:
    """Ownership token for a (possibly donated) device carry pytree.

    ``tree`` reads without consuming (host gathers for checkpoints);
    ``consume()`` surrenders the buffers to a donated program and
    invalidates the handle.  Any later access raises immediately —
    the loud-error half of the donation contract (DESIGN.md §15)."""

    __slots__ = ("_tree", "_alive", "_label")

    def __init__(self, tree, label: str = "scan carry"):
        self._tree = tree
        self._alive = True
        self._label = label

    @property
    def alive(self) -> bool:
        return self._alive

    @property
    def tree(self):
        if not self._alive:
            raise RuntimeError(
                f"use-after-donation: this {self._label} handle was "
                f"consumed by a donated program (jit donate_argnums) and "
                f"its buffers now belong to that program's output. Use the "
                f"handle RETURNED by run_segment / the stream, not the one "
                f"you passed in.")
        return self._tree

    def consume(self):
        """Surrender the carry to a donated call: returns the pytree and
        invalidates the handle."""
        tree = self.tree
        self._alive = False
        self._tree = None
        return tree


# -------------------------------------------------- async checkpoint I/O
class AsyncCheckpointWriter:
    """Single worker thread executing submitted thunks in order, so npz
    serialization + disk writes overlap device compute.  The queue is
    bounded (backpressure: a sweep that outruns the disk blocks on submit
    instead of accumulating whole trajectories in host memory).  Errors
    are sticky: the first worker exception is re-raised on the next
    ``submit``/``flush``/``close``."""

    def __init__(self, max_pending: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=max_pending)
        self._err: Optional[BaseException] = None
        # backpressure visibility (DESIGN.md §17): queue high-watermark,
        # total time submit() spent BLOCKED on a full queue, and worker
        # write time — surfaced through the engines' runtime_stats()
        self._stats = {"submitted": 0, "completed": 0, "max_pending":
                       int(max_pending), "queue_high_watermark": 0,
                       "blocked_ms": 0.0, "write_ms": 0.0}
        self._thread = threading.Thread(
            target=self._loop, name="ckpt-writer", daemon=True)
        self._thread.start()

    def _loop(self):
        while True:
            item = self._q.get()
            try:
                if item is None:
                    return
                if self._err is None:     # fail-fast: skip after first error
                    fn, args, kwargs = item
                    t0 = time.perf_counter()
                    fn(*args, **kwargs)
                    self._stats["write_ms"] += \
                        (time.perf_counter() - t0) * 1e3
                    self._stats["completed"] += 1
            except BaseException as e:    # noqa: BLE001 — re-raised on host
                self._err = e
            finally:
                self._q.task_done()

    def _raise_pending(self):
        if self._err is not None:
            err, self._err = self._err, None
            raise RuntimeError("async checkpoint write failed") from err

    def submit(self, fn: Callable, *args, **kwargs):
        self._raise_pending()
        item = (fn, args, kwargs)
        try:
            self._q.put_nowait(item)
        except queue.Full:
            # the backpressure path: the producer outran the disk — time
            # the stall so it shows up in runtime_stats / BENCH rows
            t0 = time.perf_counter()
            self._q.put(item)
            self._stats["blocked_ms"] += (time.perf_counter() - t0) * 1e3
        self._stats["submitted"] += 1
        self._stats["queue_high_watermark"] = max(
            self._stats["queue_high_watermark"], self._q.qsize())

    def stats(self) -> dict:
        """Counters snapshot + instantaneous queue depth."""
        return {**self._stats, "queue_depth": self._q.qsize(),
                "blocked_ms": round(self._stats["blocked_ms"], 3),
                "write_ms": round(self._stats["write_ms"], 3)}

    def flush(self):
        """Block until everything submitted so far has been written."""
        self._q.join()
        self._raise_pending()

    def close(self):
        """Drain, stop the worker, and surface any write error."""
        self._q.join()
        self._q.put(None)
        self._thread.join()
        self._raise_pending()

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        # drain on clean exit; on error, still stop the thread but prefer
        # the caller's exception over a secondary writer error
        try:
            self.close()
        except RuntimeError:
            if exc_type is None:
                raise
        return False

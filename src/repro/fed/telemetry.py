"""Unified telemetry layer (DESIGN.md §17): in-scan stage health metrics,
host-side span tracing with profiler hooks, and the shared
``runtime_stats`` snapshot.

The engine runs a whole FedGS round — 3DG rebuild, availability draw,
sampling, (possibly faulted) local training, aggregation (PAPER.md
Alg. 1) — as ONE opaque ``lax.scan`` program, so by default the only
per-round signals that come back are the end-of-round ``ScanHistory``
eval fields.  Diagnosing long-term sampling bias under non-stationary
availability (Rodio et al. 2023; Ribero et al. 2022), a diverging sweep
cell, or a regressed Pallas kernel needs per-stage, per-round health
signals.  Three pieces:

in-scan health channel (``round_telemetry``)
    A pure, scan-traceable metrics pytree computed INSIDE the step body
    from intermediates the step already materializes: per-stage
    update-norm / NaN-fraction / clip-rate on the (M, P) update panel,
    sampler dispersion (the mean pairwise H-distance of the selected set
    — the quantity the paper's Eq. 16 objective maximizes), availability
    rate, aggregator weight entropy, global param-delta norm, and —
    gated exactly like the PR-9 fault carry — the memory panel's
    staleness histogram and the fault seam's corruption magnitude.
    Every metric is a CONSUMER of values the benign program already
    computes (reductions only — nothing feeds back), so a telemetry-off
    program, its outputs and its checkpoints are bitwise untouched
    (assumption log #24).

host-side span tracer (``Tracer``)
    Zero-dependency nested spans around the host runtime — build /
    lower / compile / dispatch / device_get / checkpoint-write — each
    span also entering ``jax.named_scope`` so the operations traced
    under it carry the span name into HLO and (with ``--profile``)
    ``jax.profiler`` XLA traces line up with the host spans.  Exports
    Chrome/Perfetto ``trace.json``.  Span durations are HOST wall-clock
    around ASYNC dispatch (assumption log #25): a "dispatch" span times
    enqueue, not device compute — device time comes from the profiler
    hook, and compile time from the ``ProgramCache`` executable-cache
    probe (DESIGN.md §15).

``runtime_snapshot``
    One merged counters snapshot shared by ``ScanEngine``, ``FLEngine``
    and ``SimService``: the ``ProgramCache`` hit/miss/compile counters
    (flat, for backward compatibility), the ``AsyncCheckpointWriter``
    queue-depth/backpressure counters, and the tracer's per-span
    aggregates.
"""
from __future__ import annotations

import json
import os
import threading
import time
from contextlib import contextmanager
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp

TELEMETRY_SCHEMA_VERSION = 1

# staleness-age histogram bin edges (rounds since a client's last
# participation): ages land in [0,1), [1,2), [2,4), ... [64, inf) —
# static so the (N_STALE_BINS,) vector is scan-traceable
STALE_BIN_EDGES = (1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0)
N_STALE_BINS = len(STALE_BIN_EDGES) + 1


# --------------------------------------------------- in-scan health metrics
def _sq_norms_vs_base(stacked, base):
    """(M,) per-client squared L2 norm of ``stacked_k - base`` without
    materializing a flat (M, P) panel: per-leaf reductions summed."""
    def leaf(s, b):
        d = s - b[None]
        return jnp.sum(jnp.square(d).reshape(d.shape[0], -1), axis=1)
    parts = jax.tree_util.tree_map(leaf, stacked, base)
    return sum(jax.tree_util.tree_leaves(parts))


def _nonfinite_fracs(stacked):
    """(M,) fraction of non-finite entries per client across all leaves."""
    def bad(s):
        return jnp.sum((~jnp.isfinite(s)).reshape(s.shape[0], -1)
                       .astype(jnp.float32), axis=1)

    def size(s):
        return np.prod(s.shape[1:], dtype=np.float64)
    bads = sum(jax.tree_util.tree_leaves(
        jax.tree_util.tree_map(bad, stacked)))
    total = sum(size(s) for s in jax.tree_util.tree_leaves(stacked))
    return bads / jnp.float32(max(total, 1.0))


def selection_dispersion(h, sel, valid):
    """Mean pairwise H-distance of the selected set — the per-round value
    of the paper's Eq. 16 dispersion objective.  ``sel`` (M,) padded
    indices, ``valid`` (M,) pad mask; invalid slots contribute nothing.
    0 when fewer than two clients were selected."""
    vf = valid.astype(jnp.float32)
    pair = vf[:, None] * vf[None, :]
    pair = pair * (1.0 - jnp.eye(sel.shape[0], dtype=jnp.float32))
    hs = h[sel][:, sel]
    n_pairs = jnp.sum(pair)
    return jnp.where(n_pairs > 0, jnp.sum(hs * pair) / jnp.maximum(
        n_pairs, 1.0), jnp.float32(0.0))


def weight_entropy(weights):
    """Shannon entropy (nats) of the normalized aggregation weights — a
    collapse-to-one-client round shows up as entropy -> 0."""
    w = jnp.maximum(weights.astype(jnp.float32), 0.0)
    z = jnp.sum(w)
    p = w / jnp.maximum(z, 1e-12)
    ent = -jnp.sum(jnp.where(p > 0, p * jnp.log(p), 0.0))
    return jnp.where(z > 0, ent, jnp.float32(0.0))


def staleness_histogram(age):
    """(N_STALE_BINS,) counts of per-client staleness ages (rounds since
    last participation) over the static ``STALE_BIN_EDGES`` buckets."""
    edges = jnp.asarray(STALE_BIN_EDGES, jnp.float32)
    idx = jnp.searchsorted(edges, age.astype(jnp.float32), side="right")
    return jnp.sum(jax.nn.one_hot(idx, N_STALE_BINS, dtype=jnp.float32),
                   axis=0)


def round_telemetry(*, avail, valid, sel, local, params_prev, params_new,
                    weights, h, clip_thresh: float = 10.0,
                    tau=None, t=None, fault_mag=None) -> dict:
    """The per-round in-scan metrics pytree (all jnp, scan-traceable).

    Pure CONSUMER of the step's intermediates: ``avail`` (N,) bool,
    ``sel``/``valid`` (M,) the padded selected set, ``local`` the stacked
    (M, ...) post-training client params, ``params_prev``/``params_new``
    the global params around the server update, ``weights`` (M,) the
    Eq. 18 aggregation weights (pads already zeroed), ``h`` the (N, N)
    normalized 3DG distance panel.  ``tau`` (+ ``t``) adds the memory
    aggregator's staleness histogram; ``fault_mag`` threads the fault
    seam's corruption magnitude through (computed at the seam, where the
    clean panel is still in scope).  Keys are the JSONL sink's metric
    names (schema v1)."""
    vf = valid.astype(jnp.float32)
    n_sel = jnp.sum(vf)
    sq = _sq_norms_vs_base(local, params_prev)
    norms = jnp.sqrt(jnp.maximum(sq, 0.0))
    nmask = jnp.where(valid, norms, 0.0)
    mean_norm = jnp.sum(nmask) / jnp.maximum(n_sel, 1.0)
    max_norm = jnp.max(jnp.where(valid, norms, -jnp.inf))
    max_norm = jnp.where(n_sel > 0, max_norm, jnp.float32(0.0))
    clip = jnp.sum((nmask > clip_thresh).astype(jnp.float32)) \
        / jnp.maximum(n_sel, 1.0)
    nan_frac = jnp.sum(jnp.where(valid, _nonfinite_fracs(local), 0.0)) \
        / jnp.maximum(n_sel, 1.0)
    delta_sq = sum(jax.tree_util.tree_leaves(jax.tree_util.tree_map(
        lambda a, b: jnp.sum(jnp.square(a - b)), params_new, params_prev)))
    tel = {
        "avail_rate": jnp.mean(avail.astype(jnp.float32)),
        "n_selected": n_sel,
        "update_norm_mean": mean_norm,
        "update_norm_max": max_norm,
        "update_clip_rate": clip,
        "update_nan_frac": nan_frac,
        "sampler_dispersion": selection_dispersion(h, sel, valid),
        "weight_entropy": weight_entropy(weights),
        "param_delta_norm": jnp.sqrt(jnp.maximum(delta_sq, 0.0)),
    }
    if tau is not None:
        age = jnp.maximum(jnp.asarray(t, jnp.float32) - tau, 0.0)
        tel["staleness_hist"] = staleness_histogram(age)
    if fault_mag is not None:
        tel["fault_corruption_norm"] = fault_mag
    return tel


def fault_corruption_norm(updf, cleanf, valid):
    """Mean L2 distance between the corrupted and clean flat (M, P)
    update panels over the valid slots — the fault seam's magnitude
    probe (0 for benign cells: the none branch is a bitwise identity)."""
    vf = valid.astype(jnp.float32)
    d = jnp.sqrt(jnp.maximum(
        jnp.sum(jnp.square(updf - cleanf), axis=1), 0.0))
    return jnp.sum(d * vf) / jnp.maximum(jnp.sum(vf), 1.0)


# ------------------------------------------------------- host span tracer
class Tracer:
    """Zero-dependency nested span tracer with Chrome-trace export and
    ``jax`` profiler hooks.

    ``span(name)`` is a context manager: it enters ``jax.named_scope``
    (so device ops traced inside carry the span name into HLO / XLA
    profiles) and, when the tracer is enabled, records a Chrome
    complete-event with host wall-clock start/duration, thread id and
    nesting depth.  Thread-safe — checkpoint-writer spans record from
    the writer thread and show up on their own trace row.

    ``profile_dir`` arms the ``jax.profiler.trace`` hook:
    ``start_profiler()`` / ``stop_profiler()`` bracket a run so the XLA
    device trace lands next to the host spans' ``trace.json``.

    A disabled tracer (``enabled=False``) still enters
    ``jax.named_scope`` but records nothing — the engines default to a
    shared module-level ``NULL_TRACER``."""

    def __init__(self, *, enabled: bool = True,
                 profile_dir: Optional[str] = None):
        self.enabled = enabled
        self.profile_dir = profile_dir
        self._events: list[dict] = []
        self._lock = threading.Lock()
        self._local = threading.local()
        self._epoch = time.perf_counter()
        self._profiling = False

    # ------------------------------------------------------------ spans
    def _depth(self) -> int:
        return getattr(self._local, "depth", 0)

    @contextmanager
    def span(self, name: str, **attrs):
        with jax.named_scope(name):
            if not self.enabled:
                yield self
                return
            self._local.depth = self._depth() + 1
            t0 = time.perf_counter()
            try:
                yield self
            finally:
                dur = time.perf_counter() - t0
                self._local.depth -= 1
                ev = {"name": name,
                      "ts": (t0 - self._epoch) * 1e6,       # us
                      "dur": dur * 1e6,
                      "tid": threading.get_ident(),
                      "depth": self._local.depth}
                if attrs:
                    ev["args"] = {k: (v if isinstance(v, (int, float, str,
                                                          bool, type(None)))
                                      else repr(v))
                                  for k, v in attrs.items()}
                with self._lock:
                    self._events.append(ev)

    # --------------------------------------------------------- profiler
    def start_profiler(self):
        """Arm ``jax.profiler.trace`` (XLA device trace) into
        ``profile_dir`` — no-op without a directory."""
        if self.profile_dir and not self._profiling:
            os.makedirs(self.profile_dir, exist_ok=True)
            jax.profiler.start_trace(self.profile_dir)
            self._profiling = True

    def stop_profiler(self):
        if self._profiling:
            jax.profiler.stop_trace()
            self._profiling = False

    # ----------------------------------------------------------- export
    def events(self) -> list[dict]:
        with self._lock:
            return list(self._events)

    def clear(self):
        with self._lock:
            self._events.clear()

    def summary(self) -> dict:
        """Per-span-name aggregates: count / total_ms / max_ms."""
        out: dict[str, dict] = {}
        for ev in self.events():
            s = out.setdefault(ev["name"],
                               {"count": 0, "total_ms": 0.0, "max_ms": 0.0})
            ms = ev["dur"] / 1e3
            s["count"] += 1
            s["total_ms"] += ms
            s["max_ms"] = max(s["max_ms"], ms)
        for s in out.values():
            s["total_ms"] = round(s["total_ms"], 3)
            s["max_ms"] = round(s["max_ms"], 3)
        return out

    def export_chrome(self, path: str) -> str:
        """Write the recorded spans as a Chrome/Perfetto-loadable
        ``trace.json`` (complete "X" events, microsecond timestamps) and
        return the path.  Load via chrome://tracing or ui.perfetto.dev;
        with the profiler hook armed, the XLA trace written into
        ``profile_dir`` covers the same wall-clock window."""
        pid = os.getpid()
        evs = [{"name": ev["name"], "ph": "X", "pid": pid,
                "tid": ev["tid"], "ts": round(ev["ts"], 3),
                "dur": round(ev["dur"], 3),
                "args": ev.get("args", {"depth": ev["depth"]})}
               for ev in self.events()]
        doc = {"traceEvents": evs, "displayTimeUnit": "ms",
               "otherData": {"schema": TELEMETRY_SCHEMA_VERSION,
                             "tool": "repro.fed.telemetry.Tracer"}}
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(doc, f)
        return path


NULL_TRACER = Tracer(enabled=False)


def make_tracer(trace_dir: Optional[str] = None,
                profile: bool = False) -> Tracer:
    """CLI-knob constructor: ``--trace-dir`` enables span recording (the
    chrome export lands there), ``--profile`` additionally arms the
    ``jax.profiler`` hook into ``<trace_dir>/xla``."""
    if not trace_dir and not profile:
        return NULL_TRACER
    pdir = os.path.join(trace_dir or ".", "xla") if profile else None
    return Tracer(enabled=True, profile_dir=pdir)


# ------------------------------------------------------ unified snapshot
def runtime_snapshot(*, programs=None, writer: Optional[dict] = None,
                     tracer: Optional[Tracer] = None,
                     extra: Optional[dict] = None) -> dict:
    """The ONE ``runtime_stats()`` shape shared by both engines and the
    service: the ``ProgramCache`` counters stay FLAT at the top level
    (``hits`` / ``misses`` / ``compiles`` / ``compile_ms`` / ``size`` —
    the pre-telemetry consumers in the benches read them there), with
    the checkpoint-writer and span sections nested beside them."""
    snap: dict = {"telemetry_schema": TELEMETRY_SCHEMA_VERSION}
    if programs is not None:
        snap.update(programs.stats())
    if writer is not None:
        snap["checkpoint_writer"] = dict(writer)
    if tracer is not None and tracer.enabled:
        snap["spans"] = tracer.summary()
    if extra:
        snap.update(extra)
    return snap

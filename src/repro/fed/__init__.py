from repro.fed.models import logistic_regression, small_cnn, FedModel
from repro.fed.client import make_local_trainer, make_loss_prober
from repro.fed.server import aggregate
from repro.fed.engine import FLConfig, FLEngine
from repro.fed.scan_engine import (
    ScanConfig, ScanEngine, ScanHistory, oracle_h, precompute_masks,
)

from repro.fed.models import logistic_regression, small_cnn, FedModel
from repro.fed.client import make_local_trainer, make_loss_prober
from repro.fed.server import ServerAggregator, aggregate
from repro.fed.aggregator_device import (
    AggregatorProcess, FedAvgProcess, FedAvgMProcess, FedAdamProcess,
    FedProxWProcess, MemoryProcess, make_aggregator_process,
)
from repro.fed.engine import FLConfig, FLEngine
from repro.fed.scan_engine import (
    ScanConfig, ScanEngine, ScanHistory, oracle_h, precompute_masks,
)

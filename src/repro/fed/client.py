"""Client-side local training — one vmap'd XLA program over sampled clients.

This replaces the paper's sequential PyTorch client loop with a single
client-batched program (the TPU-native formulation, DESIGN.md §3): all
sampled clients' padded data is stacked and E local SGD steps run under
``vmap`` with per-client batch draws.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.utils.tree import global_norm


def make_local_trainer(model_loss, *, local_steps: int, batch_size: int,
                       prox_mu: float = 0.0):
    """Returns jit'd fn(global_params, x (M,n_max,...), y (M,n_max), sizes (M,),
    lr, rng) -> stacked local params (M, ...)."""

    def one_client(global_params, x, y, n_k, lr, rng):
        def loss_fn(p, xb, yb):
            l = model_loss(p, xb, yb)
            if prox_mu > 0.0:
                sq = sum(jnp.sum(jnp.square(a - b)) for a, b in zip(
                    jax.tree_util.tree_leaves(p),
                    jax.tree_util.tree_leaves(global_params)))
                l = l + 0.5 * prox_mu * sq
            return l

        def step(params, key):
            idx = jax.random.randint(key, (batch_size,), 0, jnp.maximum(n_k, 1))
            g = jax.grad(loss_fn)(params, x[idx], y[idx])
            params = jax.tree_util.tree_map(lambda p, gg: p - lr * gg, params, g)
            return params, None

        params, _ = jax.lax.scan(step, global_params,
                                 jax.random.split(rng, local_steps))
        return params

    batched = jax.vmap(one_client, in_axes=(None, 0, 0, 0, None, 0))
    return jax.jit(batched)


def make_loss_prober(model_loss, *, probe_size: int = 64):
    """jit'd fn(params, x (N,n_max,...), y, sizes, rng) -> per-client loss (N,)
    of the *global* model on each client's local data (Power-of-Choice)."""

    def one(params, x, y, n_k, rng):
        idx = jax.random.randint(rng, (probe_size,), 0, jnp.maximum(n_k, 1))
        return model_loss(params, x[idx], y[idx])

    batched = jax.vmap(one, in_axes=(None, 0, 0, 0, 0))
    return jax.jit(batched)

"""Paper-scale federated models: logistic regression (Synthetic) and the
McMahan-style small CNNs (vision surrogates) — pure JAX.

``embed`` exposes the output-layer activations used by the functional-
similarity 3DG construction (Eq. 12, l = output layer).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class FedModel:
    init: Callable          # rng -> params
    loss: Callable          # (params, x, y) -> scalar
    accuracy: Callable      # (params, x, y) -> scalar
    embed: Callable         # (params, x) -> (B, dim) output-layer embedding


def _xent(logits, y):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def logistic_regression(dim: int = 60, classes: int = 10) -> FedModel:
    def init(rng):
        k1, _ = jax.random.split(rng)
        return {"w": jax.random.normal(k1, (dim, classes)) * 0.01,
                "b": jnp.zeros((classes,))}

    def logits(p, x):
        return x @ p["w"] + p["b"]

    return FedModel(
        init=init,
        loss=lambda p, x, y: _xent(logits(p, x), y),
        accuracy=lambda p, x, y: jnp.mean(jnp.argmax(logits(p, x), 1) == y),
        embed=lambda p, x: logits(p, x),
    )


def small_cnn(shape=(8, 8, 3), classes: int = 10, width: int = 16) -> FedModel:
    """Two conv + pool stages, one hidden dense — the McMahan CNN scaled to
    the surrogate resolution."""
    h, w, c = shape

    def init(rng):
        ks = jax.random.split(rng, 4)
        def conv_init(k, kh, kw, cin, cout):
            fan = kh * kw * cin
            return jax.random.normal(k, (kh, kw, cin, cout)) / np.sqrt(fan)
        flat = (h // 4) * (w // 4) * (2 * width)
        return {
            "c1": conv_init(ks[0], 3, 3, c, width),
            "c2": conv_init(ks[1], 3, 3, width, 2 * width),
            "d1": jax.random.normal(ks[2], (flat, 64)) / np.sqrt(flat),
            "b1": jnp.zeros((64,)),
            "d2": jax.random.normal(ks[3], (64, classes)) / np.sqrt(64),
            "b2": jnp.zeros((classes,)),
        }

    def conv(x, w):
        return jax.lax.conv_general_dilated(
            x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))

    def pool(x):
        return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                     (1, 2, 2, 1), (1, 2, 2, 1), "VALID")

    def logits(p, x):
        x = pool(jax.nn.relu(conv(x, p["c1"])))
        x = pool(jax.nn.relu(conv(x, p["c2"])))
        x = x.reshape(x.shape[0], -1)
        x = jax.nn.relu(x @ p["d1"] + p["b1"])
        return x @ p["d2"] + p["b2"]

    return FedModel(
        init=init,
        loss=lambda p, x, y: _xent(logits(p, x), y),
        accuracy=lambda p, x, y: jnp.mean(jnp.argmax(logits(p, x), 1) == y),
        embed=lambda p, x: logits(p, x),
    )

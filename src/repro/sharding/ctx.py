"""Logical-axis activation-sharding context.

Model code annotates activations with *logical* axis names
(``shard_act(x, "dp", None, "tp")``); the launcher installs a ``ShardCtx``
mapping logical names to physical mesh axes.  Outside a context the calls are
no-ops, so the same model code runs in CPU smoke tests (1 device, no mesh) and
in the 512-device dry-run.

Logical names:
  dp    batch/data-parallel axis    -> ("pod","data") multi-pod, ("data",) single
  tp    tensor-parallel axis        -> ("model",)
  fsdp  parameter-sharding axis     -> ("data",)  (2D weight sharding with tp)
  sp    sequence axis (long-context decode, batch=1) -> ("data",)
"""
from __future__ import annotations

import contextlib
import contextvars
from dataclasses import dataclass, field

import jax
from jax.sharding import PartitionSpec as P


@dataclass(frozen=True)
class ShardCtx:
    axis_map: dict = field(default_factory=dict)   # logical -> tuple of mesh axes
    mesh: object = None
    # sizes of the physical tp axis, for divisibility checks
    tp_size: int = 1
    dp_size: int = 1
    # head-aware TP: leaf name -> semantic unit count (e.g. {"wq": n_heads}).
    # A projection whose flat dim is divisible by tp but whose HEAD count is
    # not must stay replicated, or the (B,S,H,dh) reshape forces XLA to
    # regather the whole attention path (incl. the KV cache) every step.
    head_divisors: dict = field(default_factory=dict)

    def resolve(self, *logical) -> P:
        phys = []
        for name in logical:
            if name is None:
                phys.append(None)
            else:
                axes = self.axis_map.get(name)
                if not axes:
                    phys.append(None)
                elif len(axes) == 1:
                    phys.append(axes[0])
                else:
                    phys.append(tuple(axes))
        return P(*phys)


_ctx: contextvars.ContextVar[ShardCtx | None] = contextvars.ContextVar("shard_ctx", default=None)


def current_ctx() -> ShardCtx | None:
    return _ctx.get()


@contextlib.contextmanager
def use_sharding(ctx: ShardCtx):
    token = _ctx.set(ctx)
    try:
        yield ctx
    finally:
        _ctx.reset(token)


def shard_act(x: jax.Array, *logical, dim_sizes_ok: bool = True):
    """Apply a with_sharding_constraint if a ShardCtx is installed.

    A logical axis is silently dropped (-> replicated) when the corresponding
    array dim is not divisible by the product of physical axis sizes — the
    divisibility-aware fallback from DESIGN.md §4.
    """
    ctx = _ctx.get()
    if ctx is None or ctx.mesh is None:
        return x
    sizes = {n: s for n, s in zip(ctx.mesh.axis_names, ctx.mesh.devices.shape)}
    checked = []
    for dim, name in enumerate(logical):
        if name is None:
            checked.append(None)
            continue
        axes = ctx.axis_map.get(name) or ()
        total = 1
        for a in axes:
            total *= sizes.get(a, 1)
        if total > 1 and x.shape[dim] % total == 0:
            checked.append(name)
        else:
            checked.append(None)
    spec = ctx.resolve(*checked)
    from jax.sharding import NamedSharding
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))

"""Parameter / input / cache PartitionSpec derivation.

Weights get 2D sharding (FSDP over ``data`` × TP over ``model``) following the
path-based rules below; any dim not divisible by its axis size falls back to
replication on that axis (DESIGN.md §4).
"""
from __future__ import annotations

import numpy as np
from jax.sharding import PartitionSpec as P

from repro.utils.tree import map_with_path

# When False, the "fsdp" logical axis maps to replication — TP-only weight
# sharding, the standard serving layout (decode would otherwise all-gather
# the full FSDP-sharded weights every token; see EXPERIMENTS.md §Perf).
FSDP_ENABLED = True

# Head-aware TP (default on): see ShardCtx.head_divisors.  The `legacy_tp`
# variant disables it to reproduce the pre-fix baseline numbers.
HEAD_AWARE_TP = True

# (path-suffix match, (dim -> logical axis)) — first match wins.
# logical: "tp" tensor-parallel, "fsdp" data-axis weight sharding
_RULES: list[tuple[tuple[str, ...], tuple[str | None, ...]]] = [
    (("embed",), ("tp", "fsdp")),              # (V, d)
    (("lm_head",), ("fsdp", "tp")),            # (d, V)
    (("wq",), ("fsdp", "tp")),
    (("wk",), ("fsdp", "tp")),
    (("wv",), ("fsdp", "tp")),
    (("wo",), ("tp", "fsdp")),
    (("router",), ("fsdp", None)),
    (("w_gate",), ("fsdp", "tp")),
    (("w_in",), ("fsdp", "tp")),
    (("w_out",), ("tp", "fsdp")),
    (("w_z",), ("fsdp", "tp")),
    (("w_x",), ("fsdp", "tp")),
    (("w_B",), ("fsdp", None)),
    (("w_C",), ("fsdp", None)),
    (("w_dt",), ("fsdp", None)),
]


def _axes_for(path: tuple[str, ...], shape: tuple[int, ...]):
    name = path[-1]
    moe = "moe" in path
    axes = None
    for (suffix, rule_axes) in _RULES:
        if name == suffix[0]:
            if moe and name in ("w_in", "w_out", "w_gate"):
                # (E, a, b): experts over tp, FSDP on the larger inner dim
                axes = ("tp", "fsdp", None)
            else:
                axes = rule_axes
            break
    if axes is not None and not FSDP_ENABLED:
        axes = tuple(None if a == "fsdp" else a for a in axes)
    return axes  # None -> replicate (norms, scalars, biases, conv)


def param_specs(params, ctx):
    """Pytree of PartitionSpec matching ``params``; divisibility-checked."""
    sizes = {n: s for n, s in zip(ctx.mesh.axis_names, ctx.mesh.devices.shape)}

    tp_axes = ctx.axis_map.get("tp") or ()
    tp_total = int(np.prod([sizes.get(a, 1) for a in tp_axes])) if tp_axes else 1

    def spec_of(path, x):
        axes = _axes_for(path, x.shape)
        if axes is None:
            return P()
        # head-aware TP (see ShardCtx.head_divisors)
        unit = ctx.head_divisors.get(path[-1])
        if unit is not None and tp_total > 1 and unit % tp_total != 0:
            axes = tuple(None if a == "tp" else a for a in axes)
        # stacked-per-layer leaves carry a leading L dim: right-align the rule
        axes = (None,) * max(0, x.ndim - len(axes)) + tuple(axes[: x.ndim])
        phys = []
        for dim, logical in enumerate(axes):
            if logical is None:
                phys.append(None)
                continue
            mesh_axes = ctx.axis_map.get(logical) or ()
            total = int(np.prod([sizes.get(a, 1) for a in mesh_axes])) if mesh_axes else 1
            if total > 1 and x.shape[dim] % total == 0:
                phys.append(mesh_axes[0] if len(mesh_axes) == 1 else tuple(mesh_axes))
            else:
                phys.append(None)
        return P(*phys)

    return map_with_path(spec_of, params)


# ---------------------------------------------------------------- scan engine
# Mesh axes of launch.mesh.make_engine_mesh (DESIGN.md §13): sweep cells over
# "cells", the memory panel's client-row dim over "silo".
ENGINE_CELL_AXIS = "cells"
ENGINE_SILO_AXIS = "silo"


def engine_batch_spec(cell_sharding: bool = True) -> P:
    """Prefix PartitionSpec for the engine's cell-stacked pytrees (cells,
    carries, trajectories): dim 0 is the cell-batch axis.  With
    ``cell_sharding=False`` the batch is replicated (every device sees all
    cells — only useful with a size-1 "cells" axis)."""
    return P(ENGINE_CELL_AXIS) if cell_sharding else P()


def engine_carry_specs(carry_shapes, *, cell_sharding: bool = True,
                       panel_sharded: bool = False):
    """Per-leaf PartitionSpec tree for the scan carry.  All leaves follow
    ``engine_batch_spec``; in psum mode (``panel_sharded``) the aggregator's
    (B, rows, P) update-memory panel additionally row-shards over "silo" —
    the spec the shard_map'd segment program uses for its carry in/out, so a
    checkpoint gather sees rows reassembled in global client order."""
    cells = ENGINE_CELL_AXIS if cell_sharding else None

    def spec_of(path, x):
        if (panel_sharded and path and path[-1] == "mem"
                and len(x.shape) >= 3):
            return P(cells, ENGINE_SILO_AXIS)
        return P(cells)

    return map_with_path(spec_of, carry_shapes)


def batch_specs(batch, ctx):
    """Shard dim-0 (batch) of every input over the dp axes when divisible."""
    sizes = {n: s for n, s in zip(ctx.mesh.axis_names, ctx.mesh.devices.shape)}
    dp = ctx.axis_map.get("dp") or ()
    total = int(np.prod([sizes.get(a, 1) for a in dp])) if dp else 1

    def spec_of(path, x):
        if x.ndim >= 1 and total > 1 and x.shape[0] % total == 0:
            first = dp[0] if len(dp) == 1 else tuple(dp)
            return P(first, *([None] * (x.ndim - 1)))
        return P(*([None] * x.ndim))

    return map_with_path(spec_of, batch)


def cache_specs(cache, ctx, *, seq_shard: bool):
    """KV/SSM cache specs.  Layout: kv (L, B, S, H, D), ssm (L, B, H, P, N).

    ``seq_shard=True`` (batch=1 long-context): shard the cache *sequence* dim
    over the dp axes instead of batch.
    """
    sizes = {n: s for n, s in zip(ctx.mesh.axis_names, ctx.mesh.devices.shape)}
    dp = ctx.axis_map.get("dp") or ()
    tp = ctx.axis_map.get("tp") or ()
    dp_total = int(np.prod([sizes.get(a, 1) for a in dp])) if dp else 1
    tp_total = int(np.prod([sizes.get(a, 1) for a in tp])) if tp else 1
    dp_phys = None if not dp else (dp[0] if len(dp) == 1 else tuple(dp))
    tp_phys = None if not tp else (tp[0] if len(tp) == 1 else tuple(tp))

    def spec_of(path, x):
        name = path[-1]
        if x.ndim == 0:
            return P()
        spec = [None] * x.ndim
        if name in ("k", "v") and x.ndim == 5:          # (L,B,S,Hkv,D)
            if not seq_shard and dp_total > 1 and x.shape[1] % dp_total == 0:
                spec[1] = dp_phys
            if seq_shard and dp_total > 1 and x.shape[2] % dp_total == 0:
                spec[2] = dp_phys
            if tp_total > 1 and x.shape[3] % tp_total == 0:
                spec[3] = tp_phys
        elif name == "ssm" and x.ndim == 5:             # (L,B,H,P,N)
            if dp_total > 1 and x.shape[1] % dp_total == 0:
                spec[1] = dp_phys
            if tp_total > 1 and x.shape[2] % tp_total == 0:
                spec[2] = tp_phys
        elif name == "conv" and x.ndim == 4:            # (L,B,K-1,C)
            if dp_total > 1 and x.shape[1] % dp_total == 0:
                spec[1] = dp_phys
            if tp_total > 1 and x.shape[3] % tp_total == 0:
                spec[3] = tp_phys
        elif name in ("enc_k", "enc_v") and x.ndim == 5:
            if not seq_shard and dp_total > 1 and x.shape[1] % dp_total == 0:
                spec[1] = dp_phys
            if tp_total > 1 and x.shape[3] % tp_total == 0:
                spec[3] = tp_phys
        return P(*spec)

    return map_with_path(spec_of, cache)

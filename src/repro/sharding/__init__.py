from repro.sharding.ctx import ShardCtx, use_sharding, shard_act, current_ctx
from repro.sharding.rules import param_specs, batch_specs, cache_specs

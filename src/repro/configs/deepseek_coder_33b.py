"""DeepSeek-Coder-33B — llama-arch GQA.  [arXiv:2401.14196]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-coder-33b",
    family="dense",
    source="arXiv:2401.14196",
    n_layers=62,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,              # 7168 / 56
    d_ff=19200,
    vocab_size=32256,
    ffn_kind="swiglu",
    attention="full",
    rope_theta=100000.0,
)

"""Nemotron-4-340B — GQA, squared-ReLU (non-gated) FFN.  [arXiv:2402.16819]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-340b",
    family="dense",
    source="arXiv:2402.16819",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv_heads=8,
    head_dim=192,              # 18432 / 96
    d_ff=73728,
    vocab_size=256000,
    ffn_kind="squared_relu",
    attention="full",
)

"""Registry of assigned architectures (+ paper-scale federated models).

Every entry is selectable via ``--arch <id>`` in the launchers.
"""
from __future__ import annotations

from repro.configs.base import ArchConfig

from repro.configs.llava_next_mistral_7b import CONFIG as _llava
from repro.configs.seamless_m4t_large_v2 import CONFIG as _seamless
from repro.configs.olmoe_1b_7b import CONFIG as _olmoe
from repro.configs.nemotron_4_340b import CONFIG as _nem340
from repro.configs.nemotron_4_15b import CONFIG as _nem15
from repro.configs.smollm_135m import CONFIG as _smollm
from repro.configs.mamba2_780m import CONFIG as _mamba2
from repro.configs.granite_moe_1b_a400m import CONFIG as _granite
from repro.configs.deepseek_coder_33b import CONFIG as _dsc
from repro.configs.hymba_1_5b import CONFIG as _hymba

REGISTRY: dict[str, ArchConfig] = {
    c.name: c
    for c in [
        _llava, _seamless, _olmoe, _nem340, _nem15,
        _smollm, _mamba2, _granite, _dsc, _hymba,
    ]
}


def get_config(name: str) -> ArchConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(REGISTRY)}")
    return REGISTRY[name]


def list_archs() -> list[str]:
    return sorted(REGISTRY)

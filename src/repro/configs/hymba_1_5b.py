"""Hymba-1.5B — hybrid: parallel attention + Mamba heads per block.
[arXiv:2411.13676]"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    source="arXiv:2411.13676",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,               # 25 * 64 = 1600
    d_ff=5504,
    vocab_size=32001,
    ffn_kind="swiglu",
    attention="full",          # hybrid block runs attention + SSM in parallel
    ssm=SSMConfig(d_state=16, head_dim=64, expand=2, chunk=256, d_conv=4),
)

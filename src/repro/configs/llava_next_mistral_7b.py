"""LLaVA-NeXT (v1.6) Mistral-7B backbone — anyres tiling VLM.

[hf:llava-hf/llava-v1.6-mistral-7b-hf]
Backbone only per assignment: the SigLIP/CLIP-ViT vision tower + projector is a
stub; ``input_specs()`` feeds precomputed anyres patch embeddings.  Mistral uses
sliding-window attention natively (window 4096), GQA with 8 kv heads.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    ffn_kind="swiglu",
    attention="sliding_window",
    window=4096,
    # anyres tiling: base 336px tile -> 576 patch tokens; up to 4 tiles + base
    # = 2880 image tokens max; we provision 2880 for shape purposes.
    n_image_tokens=2880,
    rope_theta=1000000.0,
)

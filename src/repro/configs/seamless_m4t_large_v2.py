"""SeamlessM4T-large v2 text/speech translation backbone — enc-dec, multimodal.

[arXiv:2308.11596]
Backbone only: the w2v-BERT speech frontend (mel + conv feature extractor) is a
stub; ``input_specs()`` feeds precomputed frame embeddings to the encoder.
24 encoder + 24 decoder layers, d_model 1024, 16 heads (kv=16 -> MHA), ffn 8192.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    source="arXiv:2308.11596",
    n_layers=24,               # decoder layers
    n_enc_layers=24,
    enc_dec=True,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    ffn_kind="swiglu",
    attention="full",
    n_audio_frames=1024,       # encoder-side precomputed frames for specs
)

"""Architecture + input-shape configuration for the repro framework.

Every assigned architecture gets one ``<id>.py`` in this package holding an
``ArchConfig`` with the exact dimensions from the assignment table (source
citation in the ``source`` field).  ``reduced()`` derives the CPU-smoke-test
variant (2 layers, d_model<=512, <=4 experts) of the same family.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional


def pad_vocab(v: int, multiple: int = 128, shards: int = 16) -> int:
    """Round vocab up so it is both MXU-aligned and divisible by the tp axis."""
    import math
    step = multiple * shards // math.gcd(multiple, shards)
    return ((v + step - 1) // step) * step


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    # capacity factor used for fixed-shape token dispatch (TPU-friendly).
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 / SSD block configuration."""
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    chunk: int = 256
    d_conv: int = 4


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    source: str                      # citation from the assignment table
    n_layers: int
    d_model: int
    n_heads: int                     # query heads (0 for attention-free)
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    # --- activation/ffn style ---
    ffn_kind: str = "swiglu"         # swiglu | squared_relu
    # --- attention style ---
    attention: str = "full"          # full | sliding_window | none
    window: int = 4096               # used when attention == sliding_window
    rope_theta: float = 10000.0
    # --- optional sub-configs ---
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # --- enc-dec (audio) ---
    enc_dec: bool = False
    n_enc_layers: int = 0
    # --- multimodal stub frontends ---
    n_image_tokens: int = 0          # vlm: precomputed patch embeddings per sample
    n_audio_frames: int = 0          # audio: precomputed frame embeddings (encoder input)
    # --- numerics / training ---
    dtype: str = "bfloat16"
    tie_embeddings: bool = False
    norm_eps: float = 1e-5

    # ---------- derived ----------
    @property
    def padded_vocab(self) -> int:
        return pad_vocab(self.vocab_size)

    @property
    def attn_heads_or_zero(self) -> int:
        return 0 if self.attention == "none" else self.n_heads

    @property
    def d_head_total(self) -> int:
        return self.n_heads * self.head_dim

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, L, V = self.d_model, self.n_layers, self.padded_vocab
        total = V * d                      # embedding
        if not self.tie_embeddings:
            total += V * d                 # lm head
        total += d                         # final norm
        per_layer = 0
        if self.attention != "none":
            qd = self.n_heads * self.head_dim
            kvd = self.n_kv_heads * self.head_dim
            per_layer += d * qd + 2 * d * kvd + qd * d + d  # qkv,o + norm
        if self.ssm is not None:
            s = self.ssm
            d_in = s.expand * d
            nheads = d_in // s.head_dim
            # in_proj produces [z, x, B, C, dt]
            zxbcdt = 2 * d_in + 2 * s.d_state + nheads
            per_layer += d * zxbcdt + (d_in + 2 * s.d_state) * s.d_conv
            per_layer += nheads * 2 + d_in  # A_log, D, dt_bias? (approx) + norm-ish
            per_layer += d_in * d + d       # out proj + norm
        if self.d_ff > 0:
            n_mats = 3 if self.ffn_kind == "swiglu" else 2
            ff = n_mats * d * self.d_ff
            if self.moe is not None:
                per_layer += self.moe.num_experts * ff + d * self.moe.num_experts
            else:
                per_layer += ff
            per_layer += d                  # ffn norm
        total += L * per_layer
        if self.enc_dec:
            # encoder layers: self-attn + ffn; decoder adds cross-attn (count in L above
            # via cross flag at model build; approximate here)
            enc_per = 0
            qd = self.n_heads * self.head_dim
            kvd = self.n_kv_heads * self.head_dim
            enc_per += d * qd + 2 * d * kvd + qd * d + d
            n_mats = 3 if self.ffn_kind == "swiglu" else 2
            enc_per += n_mats * d * self.d_ff + d
            total += self.n_enc_layers * enc_per
            # decoder cross-attention (one per decoder layer)
            total += L * (d * qd + 2 * d * kvd + qd * d + d)
        return int(total)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top_k experts active)."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        n_mats = 3 if self.ffn_kind == "swiglu" else 2
        ff = n_mats * self.d_model * self.d_ff
        inactive = self.n_layers * (self.moe.num_experts - self.moe.top_k) * ff
        return int(full - inactive)

    def reduced(self) -> "ArchConfig":
        """CPU smoke-test variant of the same family (2L, d_model<=512, <=4 experts)."""
        d = min(self.d_model, 256)
        hd = 32
        nh = max(2, min(self.n_heads, 4)) if self.attention != "none" else 0
        nkv = max(1, min(self.n_kv_heads, 2)) if self.attention != "none" else 0
        moe = None
        if self.moe is not None:
            moe = MoEConfig(num_experts=4, top_k=2, capacity_factor=self.moe.capacity_factor)
        ssm = None
        if self.ssm is not None:
            ssm = SSMConfig(d_state=16, head_dim=32, expand=2, chunk=32, d_conv=4)
        return dataclasses.replace(
            self,
            n_layers=2,
            d_model=d,
            n_heads=nh,
            n_kv_heads=nkv,
            head_dim=hd,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            moe=moe,
            ssm=ssm,
            n_enc_layers=2 if self.enc_dec else 0,
            n_image_tokens=min(self.n_image_tokens, 16),
            n_audio_frames=min(self.n_audio_frames, 16),
            window=min(self.window, 64),
            dtype="float32",
        )


def pad_heads(cfg: "ArchConfig", multiple: int = 16) -> "ArchConfig":
    """TP head alignment: pad query heads up to ``multiple`` and kv heads to
    the smallest count that (a) divides the padded q count and (b) is >= the
    real kv count.  There is an exact weight embedding of the original model
    into the padded one (zero wq columns / wo rows for pad q-heads, with the
    real q heads laid out so slot//(Hq'/Hkv') == original kv group — see
    models/lm.embed_params_padded and tests/test_head_padding.py), so this is
    a layout change, not an approximation.  Cost: (Hq'-Hq)/Hq extra attention
    FLOPs; benefit: attention shards ``multiple``-way instead of replicating.
    """
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    if hq == 0 or hq % multiple == 0:
        return cfg
    n0 = hq // hkv                       # original q-per-kv group size
    # smallest padded (hq', hkv') with hq' a multiple of `multiple`,
    # hkv' | hq', hkv' >= hkv, and group size hq'/hkv' >= n0 (so every real
    # q head fits in its original kv group under the uniform repeat mapping)
    hq_p = ((hq + multiple - 1) // multiple) * multiple
    while True:
        cands = [k for k in range(hkv, hq_p + 1)
                 if hq_p % k == 0 and hq_p // k >= n0]
        if cands:
            return dataclasses.replace(cfg, n_heads=hq_p, n_kv_heads=cands[0])
        hq_p += multiple


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}

"""Nemotron-4-15B — GQA, squared-ReLU FFN.  [arXiv:2402.16819]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-15b",
    family="dense",
    source="arXiv:2402.16819",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,              # 6144 / 48
    d_ff=24576,
    vocab_size=256000,
    ffn_kind="squared_relu",
    attention="full",
)

from repro.configs.base import ArchConfig, InputShape, INPUT_SHAPES
from repro.configs.registry import get_config, list_archs, REGISTRY

"""OLMoE-1B-7B — 64-expert top-8 MoE.  [arXiv:2409.02060]"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    source="arXiv:2409.02060",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1024,                 # per-expert FFN width
    vocab_size=50304,
    ffn_kind="swiglu",
    attention="full",
    moe=MoEConfig(num_experts=64, top_k=8),
)

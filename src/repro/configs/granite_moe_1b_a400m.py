"""Granite-3.0-1B-A400M — 32-expert top-8 MoE.
[hf:ibm-granite/granite-3.0-1b-a400m-base]"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=64,               # 1024 / 16
    d_ff=512,                  # per-expert width
    vocab_size=49155,
    ffn_kind="swiglu",
    attention="full",
    moe=MoEConfig(num_experts=32, top_k=8),
)

"""SmolLM-135M — llama-arch small.  [hf:HuggingFaceTB/SmolLM-135M]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="smollm-135m",
    family="dense",
    source="hf:HuggingFaceTB/SmolLM-135M",
    n_layers=30,
    d_model=576,
    n_heads=9,
    n_kv_heads=3,
    head_dim=64,               # 576 / 9
    d_ff=1536,
    vocab_size=49152,
    ffn_kind="swiglu",
    attention="full",
    tie_embeddings=True,
)

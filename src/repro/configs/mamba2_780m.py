"""Mamba2-780M — SSD (state-space duality), attention-free.  [arXiv:2405.21060]"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-780m",
    family="ssm",
    source="arXiv:2405.21060",
    n_layers=48,
    d_model=1536,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,                    # mamba2 blocks only, no separate FFN
    vocab_size=50280,
    attention="none",
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, chunk=256, d_conv=4),
)

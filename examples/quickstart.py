"""Quickstart: FedGS vs UniformSample on the paper's Synthetic(0.5, 0.5)
dataset under skewed (LogNormal) client availability.

  PYTHONPATH=src python examples/quickstart.py

Runs in ~1 minute on CPU and prints the two methods' loss curves and final
sampling-count fairness — the paper's core claim in miniature.
"""
import numpy as np

from repro.core.availability import make_mode
from repro.core.fairness import count_variance, gini
from repro.core.sampler import FedGSSampler, UniformSampler
from repro.data.synthetic import make_synthetic
from repro.fed.engine import FLConfig, FLEngine
from repro.fed.models import logistic_regression


def run(sampler, ds, label):
    mode = make_mode("LN", n_clients=ds.n_clients, beta=0.5, seed=99)
    cfg = FLConfig(rounds=40, sample_frac=0.2, local_steps=10, batch_size=10,
                   lr=0.1, eval_every=4, seed=0)
    eng = FLEngine(ds, logistic_regression(), sampler, mode, cfg)
    if isinstance(sampler, FedGSSampler):
        eng.install_oracle_graph(ds.opt_params)      # 3DG from local optima
    hist = eng.run(progress=lambda t, l, a: print(
        f"  [{label}] round {t:3d}  val_loss={l:.4f}  val_acc={a:.3f}"))
    return hist, eng.counts


def main():
    ds = make_synthetic(n_clients=30, alpha=0.5, beta=0.5, seed=0)
    print(f"Synthetic(0.5, 0.5): {ds.n_clients} clients, "
          f"sizes {ds.sizes.min()}..{ds.sizes.max()}")

    print("\n-- UniformSample (McMahan et al. 2017) --")
    h_u, c_u = run(UniformSampler(), ds, "uniform")
    print("\n-- FedGS (this paper, alpha=1) --")
    h_g, c_g = run(FedGSSampler(alpha=1.0), ds, "fedgs")

    print("\n== summary under LogNormal(0.5) availability ==")
    print(f"{'method':15s} {'best loss':>10s} {'Var(v^T)':>10s} {'gini':>6s}")
    print(f"{'UniformSample':15s} {h_u.best_loss:10.4f} "
          f"{count_variance(c_u):10.2f} {gini(c_u):6.3f}")
    print(f"{'FedGS':15s} {h_g.best_loss:10.4f} "
          f"{count_variance(c_g):10.2f} {gini(c_g):6.3f}")
    assert np.isfinite(h_g.best_loss)


if __name__ == "__main__":
    main()

"""End-to-end serving driver: batched request serving of an assigned
architecture (reduced variant on CPU; the dry-run proves the full configs
shard on the production mesh).

  PYTHONPATH=src python examples/serve_llm.py --arch smollm-135m --batch 8
"""
import sys

from repro.launch import serve


def main():
    argv = sys.argv[1:]
    if "--reduced" not in argv:
        argv = argv + ["--reduced"]
    serve.main(argv)


if __name__ == "__main__":
    main()

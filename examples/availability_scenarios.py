"""Availability scenarios: FedGS under STATEFUL client availability the
paper's Table-1 modes cannot express — Gilbert–Elliott churn, regional
cluster outages, non-stationary drift, deadline stragglers — swept together
with a legacy mode in ONE batched scan program.

  PYTHONPATH=src python examples/availability_scenarios.py

~1 min on CPU.  Every cell is a different ``AvailabilityProcess`` family
(core/availability_device.py); because all families compile to the same
``lax.switch`` step, the whole heterogeneous sweep is a single XLA program
(``ScanEngine.run_batch``).  Printed per scenario: best validation loss,
mean participation rate, and the sampling-count fairness gap FedGS
balances.
"""
import numpy as np

from repro.core.availability import make_mode
from repro.core.availability_device import (
    ClusterOutage, DeadlineProcess, DriftProcess, GilbertElliott,
)
from repro.core.fairness import count_variance, gini
from repro.data.synthetic import make_synthetic
from repro.fed.models import logistic_regression
from repro.fed.scan_engine import ScanConfig, ScanEngine, oracle_h


def main():
    ds = make_synthetic(n_clients=30, alpha=0.5, beta=0.5, seed=0)
    rounds = 40
    n = ds.n_clients
    mdf = make_mode("MDF", n_clients=n, data_sizes=ds.sizes).probs_table()
    ldf = make_mode("LDF", n_clients=n, data_sizes=ds.sizes).probs_table()
    scenarios = {
        "LN (legacy)": make_mode("LN", n_clients=n, beta=0.5,
                                 seed=99).process(),
        "GE churn": GilbertElliott(n, mean_on=8, mean_off=4),
        "cluster outage": ClusterOutage(n, n_clusters=4, p_fail=0.1,
                                        p_recover=0.3, floor=0.05),
        "MDF->LDF drift": DriftProcess(mdf, ldf, t0=5, t1=rounds - 5),
        "deadline": DeadlineProcess(n, deadline=1.0, rho=0.8, sigma=0.2),
    }

    eng = ScanEngine(ds, logistic_regression(),
                     ScanConfig(rounds=rounds, m=6, sampler="fedgs",
                                local_steps=10, batch_size=10, lr=0.1,
                                eval_every=4, max_sweeps=32))
    h = oracle_h(ds.opt_params)
    cells = [eng.cell(seed=0, process=proc, alpha=1.0, h=h,
                      avail_seed=1234 + i)
             for i, proc in enumerate(scenarios.values())]
    print(f"running {len(cells)} scenario families as ONE batched program "
          f"({rounds} rounds, FedGS alpha=1) ...")
    hists = eng.run_batch(cells)

    print(f"\n{'scenario':16s} {'best loss':>10s} {'cohort fill':>11s} "
          f"{'Var(v^T)':>9s} {'gini':>6s}")
    for (label, _), sh in zip(scenarios.items(), hists):
        # participation proxy: how full the M-slot cohort ran on average
        fill = sh.counts.sum() / (rounds * eng.cfg.m)
        print(f"{label:16s} {sh.best_loss:10.4f} {fill:11.3f} "
              f"{count_variance(sh.counts):9.2f} {gini(sh.counts):6.3f}")
    assert all(np.isfinite(sh.best_loss) for sh in hists)


if __name__ == "__main__":
    main()

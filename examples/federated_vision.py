"""Availability-mode study on the CIFAR10-like federated vision surrogate:
run one method under several availability modes and watch the degradation —
then run FedGS and watch it hold (paper Table 2's phenomenon).

  PYTHONPATH=src python examples/federated_vision.py [--rounds 30]
"""
import argparse

from repro.core.availability import make_mode
from repro.core.fairness import count_variance
from repro.core.sampler import FedGSSampler, UniformSampler
from repro.data.vision import make_cifar_like
from repro.fed.engine import FLConfig, FLEngine
from repro.fed.models import small_cnn


def run_one(ds, sampler_fn, mode_name, beta, rounds):
    sampler = sampler_fn()
    mode = make_mode(mode_name, n_clients=ds.n_clients, data_sizes=ds.sizes,
                     label_sets=ds.label_sets(), num_labels=ds.num_classes,
                     beta=beta, seed=99)
    cfg = FLConfig(rounds=rounds, sample_frac=0.1, local_steps=10,
                   batch_size=32, lr=0.03, eval_every=5, seed=0)
    eng = FLEngine(ds, small_cnn(shape=(8, 8, 3)), sampler, mode, cfg)
    if isinstance(sampler, FedGSSampler):
        eng.install_oracle_graph()          # label-distribution 3DG
    hist = eng.run()
    return hist.best_loss, count_variance(eng.counts)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--clients", type=int, default=50)
    args = ap.parse_args()

    ds = make_cifar_like(n_clients=args.clients, n_total=4000, seed=0)
    modes = [("IDL", None), ("LN", 0.5), ("MDF", 0.7), ("LDF", 0.7)]
    methods = [("UniformSample", UniformSampler),
               ("FedGS(a=1)", lambda: FedGSSampler(alpha=1.0))]

    print(f"{'method':16s} " + " ".join(f"{m}{'' if b is None else b:}".rjust(10)
                                        for m, b in modes))
    for name, fn in methods:
        cells = []
        for mode_name, beta in modes:
            loss, cv = run_one(ds, fn, mode_name, beta, args.rounds)
            cells.append(f"{loss:7.4f}/{cv:4.0f}".rjust(10))
        print(f"{name:16s} " + " ".join(cells))
    print("(cells: best val loss / final count variance)")


if __name__ == "__main__":
    main()

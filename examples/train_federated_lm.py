"""End-to-end training driver: federated LM training (Algorithm 1) over the
assigned-architecture model zoo with FedGS sampling — clients own distinct
Markov token streams, the 3DG is built from client unigram statistics.

Default: ~200 federated training steps (50 rounds x 4 local steps) of the
reduced smollm-135m on CPU.  On an accelerator, drop --reduced and raise
--seq/--batch; the production mesh path is exercised by launch/dryrun.py.

  PYTHONPATH=src python examples/train_federated_lm.py --rounds 50
"""
import sys

from repro.launch import train


def main():
    argv = sys.argv[1:]
    defaults = ["--reduced", "--rounds", "50", "--clients", "16",
                "--sampler", "fedgs", "--mode", "SLN"]
    # user-provided flags win; defaults fill the gaps
    have = {a for a in argv if a.startswith("--")}
    out = list(argv)
    i = 0
    while i < len(defaults):
        flag = defaults[i]
        has_val = i + 1 < len(defaults) and not defaults[i + 1].startswith("--")
        if flag not in have:
            out.append(flag)
            if has_val:
                out.append(defaults[i + 1])
        i += 2 if has_val else 1
    train.main(out)


if __name__ == "__main__":
    main()

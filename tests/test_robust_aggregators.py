"""The robust aggregator families (fed/aggregator_device.py: median /
trimmed_mean / krum):

* numpy oracles — every combine rule pinned against a plain-numpy
  implementation, including exact ties, f = 0, all-adversarial and
  NaN-poisoned panels (the PR-5 NaN-containment story holds: a minority of
  poisoned rows can NEVER leak NaN/inf into the combined params);
* Krum per Blanchard et al. (NeurIPS 2017) — the selected index sets are
  bit-identical to a float64 numpy oracle, tie-break by row index (stable
  argsort), and ref|pallas backends select identically;
* switch integration — the robust branches through ``make_aggregator_step``
  match the direct combine + zero-weight guard, and a MIXED robust-family
  ``run_batch`` equals the per-cell runs bitwise.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.availability import make_mode
from repro.fed.aggregator_device import (
    KrumProcess, MedianProcess, TrimmedMeanProcess, coordinate_median,
    init_agg_state, krum_combine, krum_pairwise_ref, krum_select,
    make_aggregator_process, make_aggregator_step, trimmed_mean_combine,
)
from repro.fed.models import logistic_regression
from repro.fed.scan_engine import ScanConfig, ScanEngine

M, P = 7, 24


def _panel(rng, m=M, p=P):
    return jnp.asarray(rng.normal(size=(m, p)).astype(np.float32))


# -------------------------------------------------------------- numpy oracles
def _median_oracle(x, valid):
    x = np.where(np.isnan(x), np.inf, np.asarray(x, np.float32))
    x = np.where(np.asarray(valid)[:, None], x, np.inf)
    v = int(np.sum(valid))
    return np.sort(x, axis=0)[max((v - 1) // 2, 0)], v


def _trimmed_oracle(x, valid, beta):
    x = np.where(np.isnan(x), np.inf, np.asarray(x, np.float32))
    x = np.where(np.asarray(valid)[:, None], x, np.inf)
    v = int(np.sum(valid))
    # f32 product, matching the XLA op order (DESIGN.md assumption log #21)
    k = max(min(int(np.floor(np.float32(beta) * np.float32(v))),
                (v - 1) // 2), 0)
    srt = np.sort(x, axis=0)
    keep = (np.arange(x.shape[0])[:, None] >= k) \
        & (np.arange(x.shape[0])[:, None] < v - k)
    return (np.sum(np.where(keep, srt, np.float32(0)), axis=0,
                   dtype=np.float32)
            / np.float32(max(v - 2 * k, 1))), v


def _krum_oracle(x, valid, f, multi):
    """Blanchard et al. in float64: exact ||xi - xj||^2, nn smallest
    distances summed, k lowest scores win, ties by row index (stable)."""
    x = np.asarray(x, np.float64)
    m = x.shape[0]
    valid = np.asarray(valid)
    d = np.sum((x[:, None, :] - x[None, :, :]) ** 2, axis=-1)
    d[np.isnan(d)] = np.inf
    pair_ok = valid[:, None] & valid[None, :] & ~np.eye(m, dtype=bool)
    d[~pair_ok] = np.inf
    v = int(valid.sum())
    nn = int(np.clip(v - f - 2, 1, max(m - 1, 1)))
    ds = np.sort(d, axis=1)
    scores = np.where(np.isfinite(ds[:, :nn]), ds[:, :nn], 0).sum(1) \
        + np.where(np.isinf(ds[:, :nn]), np.inf, 0).sum(1)
    scores[~valid] = np.inf
    kk = int(np.clip(multi, 1, max(v, 1)))
    rank = np.argsort(np.argsort(scores, kind="stable"), kind="stable")
    return (rank < kk) & valid, scores


@pytest.mark.parametrize("mask", ["all", "some", "one"])
def test_median_oracle(rng, mask):
    x = _panel(rng)
    valid = {"all": np.ones(M, bool),
             "some": rng.random(M) < 0.6,
             "one": np.eye(M, dtype=bool)[2]}[mask]
    if not valid.any():
        valid[0] = True
    med, v = coordinate_median(x, jnp.asarray(valid))
    om, ov = _median_oracle(x, valid)
    assert int(v) == ov
    np.testing.assert_array_equal(np.asarray(med), om)


def test_median_exact_ties(rng):
    """Duplicate rows: the lower median is an exact copy of a tied value."""
    row = rng.normal(size=P).astype(np.float32)
    x = jnp.asarray(np.stack([row] * 4 + [row + 5, row - 5, row + 9]))
    med, _ = coordinate_median(x, jnp.ones(7, bool))
    np.testing.assert_array_equal(np.asarray(med), row)


@pytest.mark.parametrize("beta", [0.0, 0.1, 0.25, 0.49, 0.9])
def test_trimmed_mean_oracle(rng, beta):
    x = _panel(rng)
    valid = jnp.asarray(rng.random(M) < 0.8)
    if not bool(valid.any()):
        valid = valid.at[0].set(True)
    tm, v = trimmed_mean_combine(x, valid, jnp.float32(beta))
    ot, ov = _trimmed_oracle(x, np.asarray(valid), beta)
    assert int(v) == ov
    np.testing.assert_allclose(np.asarray(tm), ot, atol=1e-6)


def test_trimmed_beta_zero_is_plain_mean(rng):
    x = _panel(rng)
    tm, _ = trimmed_mean_combine(x, jnp.ones(M, bool), jnp.float32(0.0))
    np.testing.assert_allclose(np.asarray(tm),
                               np.asarray(x).mean(0), atol=1e-6)


@pytest.mark.parametrize("f,multi", [(0, 1), (1, 1), (2, 3), (1, 7)])
def test_krum_oracle_blanchard(rng, f, multi):
    x = _panel(rng)
    valid = jnp.asarray(rng.random(M) < 0.85)
    if not bool(valid.any()):
        valid = valid.at[0].set(True)
    chosen, scores = krum_select(x, valid, f, multi)
    ochosen, oscores = _krum_oracle(x, np.asarray(valid), f, multi)
    np.testing.assert_array_equal(np.asarray(chosen), ochosen)
    fin = np.isfinite(oscores)
    np.testing.assert_allclose(np.asarray(scores)[fin], oscores[fin],
                               rtol=2e-4)


def test_krum_exact_tie_breaks_by_row_index(rng):
    """Identical rows have identical scores; the stable double argsort
    picks the LOWEST indices — bit-reproducible tie-breaking."""
    row = rng.normal(size=P).astype(np.float32)
    x = jnp.asarray(np.stack([row] * 5 + [row + 100]))
    chosen, _ = krum_select(x, jnp.ones(6, bool), 1, 2)
    np.testing.assert_array_equal(np.asarray(chosen),
                                  [True, True, False, False, False, False])


def test_krum_all_adversarial_scores_inf(rng):
    """Every row NaN-poisoned: all scores +inf, but the selection still
    returns exactly k valid rows (stable order) — breakdown exceeded is
    a documented degradation, not a crash."""
    x = jnp.full((5, P), jnp.nan)
    chosen, scores = krum_select(x, jnp.ones(5, bool), 1, 2)
    assert bool(jnp.isinf(scores).all())
    np.testing.assert_array_equal(np.asarray(chosen),
                                  [True, True, False, False, False])


def test_nan_containment_minority_poison(rng):
    """f < m/2 NaN-poisoned rows: median / trimmed-mean / krum outputs are
    finite and ignore the poison (the PR-5 NaN-containment invariant now
    extends to the robust families)."""
    x = np.array(_panel(rng))
    x[1] = np.nan
    x[4] = np.nan
    xj, valid = jnp.asarray(x), jnp.ones(M, bool)
    med, _ = coordinate_median(xj, valid)
    tm, _ = trimmed_mean_combine(xj, valid, jnp.float32(0.3))
    out, chosen, _ = krum_combine(xj, valid, 2, 3)
    honest = np.delete(x, [1, 4], axis=0)
    for got in (med, tm, out):
        got = np.asarray(got)
        assert np.isfinite(got).all()
        assert (got >= honest.min(0) - 1e-5).all()
        assert (got <= honest.max(0) + 1e-5).all()
    assert not bool(chosen[1]) and not bool(chosen[4])


# --------------------------------------------------------- ref vs pallas
@pytest.mark.parametrize("m,p", [(5, 7), (16, 64), (33, 130), (64, 256)])
def test_krum_selection_ref_pallas_bit_identical(rng, m, p):
    """The load-bearing kernel contract: ref and pallas distance panels
    agree to f32 roundoff, and the SELECTED sets are bit-identical —
    at non-tile shapes (zero-padding) and under jit."""
    from repro.kernels.ops import krum_distances
    x = jnp.asarray(rng.normal(size=(m, p)).astype(np.float32) * 3)
    valid = jnp.asarray(rng.random(m) < 0.9)
    if not bool(valid.any()):
        valid = valid.at[0].set(True)
    d_ref = np.asarray(krum_pairwise_ref(x))
    d_pal = np.asarray(krum_distances(x))
    np.testing.assert_allclose(np.maximum(d_ref, 0), np.maximum(d_pal, 0),
                               atol=1e-2, rtol=1e-4)
    f = max(1, m // 5)
    sel_ref, _ = jax.jit(
        lambda a, b: krum_select(a, b, f, 3, backend="ref"))(x, valid)
    sel_pal, _ = jax.jit(
        lambda a, b: krum_select(a, b, f, 3, backend="pallas"))(x, valid)
    np.testing.assert_array_equal(np.asarray(sel_ref), np.asarray(sel_pal))


# ------------------------------------------------------- switch integration
def _tree_params(rng, dim=4, classes=3):
    return {"w": jnp.asarray(rng.normal(size=(dim, classes)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(classes,)), jnp.float32)}


def _tree_stacked(rng, m, dim=4, classes=3):
    return {"w": jnp.asarray(rng.normal(size=(m, dim, classes)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(m, classes)), jnp.float32)}


@pytest.mark.parametrize("proc", [MedianProcess(), TrimmedMeanProcess(0.25),
                                  KrumProcess(f=1, multi=2)])
def test_switch_matches_process_apply(rng, proc):
    """make_aggregator_step's lax.switch dispatch == the process's own
    apply for every robust family, params and state bitwise."""
    n, m = 10, 4
    prev = _tree_params(rng)
    state = init_agg_state(prev, n)
    upd = _tree_stacked(rng, m)
    w = jnp.asarray(rng.random(m) + 0.5, jnp.float32)
    sel = np.sort(rng.choice(n, size=m, replace=False))
    s = np.zeros(n, bool)
    s[sel] = True
    key = jax.random.PRNGKey(0)
    avail = jnp.ones(n, bool)
    p1, st1 = proc.apply(state, key, upd, w, jnp.asarray(s), avail, 3)
    step = make_aggregator_step(n, m, prev)
    p2, st2 = step(proc.params(), state, key, upd, w, jnp.asarray(s),
                   avail, 3)
    for a, b in zip(jax.tree_util.tree_leaves((p1, st1)),
                    jax.tree_util.tree_leaves((p2, st2))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_factory_names_and_knobs():
    assert make_aggregator_process("median").name == "median"
    tm = make_aggregator_process("trimmed_mean", beta_trim=0.3)
    assert "0.3" in tm.name
    k = make_aggregator_process("krum", krum_f=2)
    assert k.f == 2 and k.multi == 1
    mk = make_aggregator_process("multikrum", krum_f=1, krum_multi=4)
    assert mk.multi == 4


def test_mixed_robust_batch_equals_per_cell():
    """fedavg + median + trimmed_mean + krum cells as ONE run_batch == the
    per-cell runs bitwise — including the Krum cell's sampled sets (the
    switch shares a program across aggregator families)."""
    from repro.data.synthetic import make_synthetic
    ds = make_synthetic(n_clients=12, alpha=0.5, beta=0.5, seed=0)
    eng = ScanEngine(ds, logistic_regression(),
                     ScanConfig(rounds=5, m=4, local_steps=2, batch_size=8,
                                sampler="uniform"))
    mode = make_mode("IDL", n_clients=ds.n_clients, data_sizes=ds.sizes,
                     label_sets=ds.label_sets(), num_labels=ds.num_classes,
                     seed=7)
    cells = [eng.cell(seed=0, mode=mode,
                      aggregator_process=make_aggregator_process(a))
             for a in ("fedavg", "median", "trimmed_mean", "krum")]
    batch = eng.run_batch(cells)
    for i, c in enumerate(cells):
        solo = eng.run(c)
        np.testing.assert_array_equal(batch[i].val_loss, solo.val_loss,
                                      err_msg=f"cell {i}")
        np.testing.assert_array_equal(batch[i].sel, solo.sel,
                                      err_msg=f"cell {i}")

"""The seven client-availability modes (paper Table 1)."""
import numpy as np
import pytest

from repro.core.availability import (ALL_MODES, Ideal, LessDataFirst,
                                     LogNormal, MoreDataFirst, SinLogNormal,
                                     YCycle, YMaxFirst, make_mode)


@pytest.fixture
def sizes(rng):
    return rng.integers(10, 1000, 40).astype(float)


@pytest.fixture
def label_sets(rng):
    return [set(rng.choice(10, 2, replace=False).tolist()) for _ in range(40)]


def test_all_modes_constructible(sizes, label_sets):
    for name in ALL_MODES:
        m = make_mode(name, n_clients=40, data_sizes=sizes,
                      label_sets=label_sets, num_labels=10)
        p = m.probs(3)
        assert p.shape == (40,)
        assert np.all(p >= 0) and np.all(p <= 1)


def test_ideal_always_full():
    m = Ideal(7)
    assert np.all(m.probs(0) == 1)
    a = m.sample(0, np.random.default_rng(0))
    assert a.all()


def test_mdf_monotone_in_size(sizes):
    m = MoreDataFirst(sizes, beta=0.7)
    order = np.argsort(sizes)
    p = m.probs(0)
    assert np.all(np.diff(p[order]) >= -1e-12)
    assert p.max() == pytest.approx(1.0)      # largest client fully available


def test_ldf_monotone_inverse(sizes):
    m = LessDataFirst(sizes, beta=0.7)
    order = np.argsort(sizes)
    p = m.probs(0)
    assert np.all(np.diff(p[order]) <= 1e-12)


def test_ymf_formula(label_sets):
    beta = 0.9
    m = YMaxFirst(label_sets, beta=beta)
    gmax = max(max(s) for s in label_sets)
    want = np.array([beta * min(s) / gmax + (1 - beta) for s in label_sets])
    assert np.allclose(m.probs(5), want)
    # time-independent
    assert np.allclose(m.probs(0), m.probs(99))


def test_ycycle_periodic(label_sets):
    m = YCycle(label_sets, num_labels=10, beta=0.9, period=20)
    assert np.allclose(m.probs(3), m.probs(23))
    # floor (1-beta) for inactive clients
    assert m.probs(0).min() >= 0.1 - 1e-12


def test_ycycle_last_round_phase_boundary():
    """Regression: at t = T_p - 1 the phase is exactly 1.0; the last label
    band must be closed there (an all-open band matched no label, silently
    dropping EVERY client to the 1 - beta floor once per cycle)."""
    num_y, beta, tp = 10, 0.9, 20
    label_sets = [{num_y - 1}, {0}, {3, num_y - 1}]
    m = YCycle(label_sets, num_labels=num_y, beta=beta, period=tp)
    p = m.probs(tp - 1)
    # clients holding the top label are active at phase 1.0 ...
    assert p[0] == pytest.approx(beta + (1 - beta))
    assert p[2] == pytest.approx(beta + (1 - beta))
    # ... clients without it stay on the floor
    assert p[1] == pytest.approx(1 - beta)
    # with every label held by some client, every round activates its
    # band's clients — before the fix t = T_p - 1 collapsed the WHOLE
    # population to the floor
    full = YCycle([{y} for y in range(num_y)], num_labels=num_y,
                  beta=beta, period=tp)
    for t in range(tp):
        assert full.probs(t).max() == pytest.approx(1.0), f"t={t}"


def test_ycycle_interior_bands_stay_half_open():
    """The fix only touches the top band: an interior boundary phase
    activates the band it OPENS (y/C <= phase), not the one it closes."""
    num_y, beta, tp = 10, 0.9, 20
    m = YCycle([{4}, {5}], num_labels=num_y, beta=beta, period=tp)
    # phase(t=9) = 10/20 = 0.5 = 5/10: band 5 opens, band 4 closed
    p = m.probs(9)
    assert p[0] == pytest.approx(1 - beta)
    assert p[1] == pytest.approx(1.0)


def test_lognormal_static_and_seeded():
    a = LogNormal(30, beta=0.5, seed=7)
    b = LogNormal(30, beta=0.5, seed=7)
    assert np.allclose(a.probs(0), b.probs(1))
    assert a.probs(0).max() == pytest.approx(1.0)


def test_sln_modulation():
    m = SinLogNormal(30, beta=0.5, seed=7, period=24)
    probs = np.stack([m.probs(t) for t in range(24)])
    assert np.allclose(probs[0], m.probs(24))        # periodic
    assert probs.max() <= 0.9 + 1e-9                  # 0.4 sin + 0.5 ceiling


def test_sample_never_empty():
    m = LogNormal(10, beta=0.99, seed=0)              # near-zero availability
    rng = np.random.default_rng(0)
    for t in range(50):
        assert m.sample(t, rng).any()


def test_availability_trace_reproducible(sizes):
    m = MoreDataFirst(sizes, beta=0.7)
    t1 = [m.sample(t, np.random.default_rng(42)) for t in range(5)]
    t2 = [m.sample(t, np.random.default_rng(42)) for t in range(5)]
    for a, b in zip(t1, t2):
        assert np.array_equal(a, b)

"""The seven client-availability modes (paper Table 1)."""
import numpy as np
import pytest

from repro.core.availability import (ALL_MODES, Ideal, LessDataFirst,
                                     LogNormal, MoreDataFirst, SinLogNormal,
                                     YCycle, YMaxFirst, make_mode)


@pytest.fixture
def sizes(rng):
    return rng.integers(10, 1000, 40).astype(float)


@pytest.fixture
def label_sets(rng):
    return [set(rng.choice(10, 2, replace=False).tolist()) for _ in range(40)]


def test_all_modes_constructible(sizes, label_sets):
    for name in ALL_MODES:
        m = make_mode(name, n_clients=40, data_sizes=sizes,
                      label_sets=label_sets, num_labels=10)
        p = m.probs(3)
        assert p.shape == (40,)
        assert np.all(p >= 0) and np.all(p <= 1)


def test_ideal_always_full():
    m = Ideal(7)
    assert np.all(m.probs(0) == 1)
    a = m.sample(0, np.random.default_rng(0))
    assert a.all()


def test_mdf_monotone_in_size(sizes):
    m = MoreDataFirst(sizes, beta=0.7)
    order = np.argsort(sizes)
    p = m.probs(0)
    assert np.all(np.diff(p[order]) >= -1e-12)
    assert p.max() == pytest.approx(1.0)      # largest client fully available


def test_ldf_monotone_inverse(sizes):
    m = LessDataFirst(sizes, beta=0.7)
    order = np.argsort(sizes)
    p = m.probs(0)
    assert np.all(np.diff(p[order]) <= 1e-12)


def test_ymf_formula(label_sets):
    beta = 0.9
    m = YMaxFirst(label_sets, beta=beta)
    gmax = max(max(s) for s in label_sets)
    want = np.array([beta * min(s) / gmax + (1 - beta) for s in label_sets])
    assert np.allclose(m.probs(5), want)
    # time-independent
    assert np.allclose(m.probs(0), m.probs(99))


def test_ycycle_periodic(label_sets):
    m = YCycle(label_sets, num_labels=10, beta=0.9, period=20)
    assert np.allclose(m.probs(3), m.probs(23))
    # floor (1-beta) for inactive clients
    assert m.probs(0).min() >= 0.1 - 1e-12


def test_lognormal_static_and_seeded():
    a = LogNormal(30, beta=0.5, seed=7)
    b = LogNormal(30, beta=0.5, seed=7)
    assert np.allclose(a.probs(0), b.probs(1))
    assert a.probs(0).max() == pytest.approx(1.0)


def test_sln_modulation():
    m = SinLogNormal(30, beta=0.5, seed=7, period=24)
    probs = np.stack([m.probs(t) for t in range(24)])
    assert np.allclose(probs[0], m.probs(24))        # periodic
    assert probs.max() <= 0.9 + 1e-9                  # 0.4 sin + 0.5 ceiling


def test_sample_never_empty():
    m = LogNormal(10, beta=0.99, seed=0)              # near-zero availability
    rng = np.random.default_rng(0)
    for t in range(50):
        assert m.sample(t, rng).any()


def test_availability_trace_reproducible(sizes):
    m = MoreDataFirst(sizes, beta=0.7)
    t1 = [m.sample(t, np.random.default_rng(42)) for t in range(5)]
    t2 = [m.sample(t, np.random.default_rng(42)) for t in range(5)]
    for a, b in zip(t1, t2):
        assert np.array_equal(a, b)

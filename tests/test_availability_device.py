"""The device-native availability-scenario subsystem
(core/availability_device.py):

* legacy parity — the seven Table-1 modes reproduce BIT-IDENTICAL traces
  through the new process path: ``precompute_masks`` (= the shared host
  wrapper) vs an inline re-implementation of the seed's numpy draw, and the
  ``ProcessMode(TableProcess)`` face vs the mode itself;
* shared force-one helper — jax and numpy implementations agree;
* empirical frequencies — each stateful family matches its stationary /
  scheduled distribution (Gilbert–Elliott, cluster outage incl. the
  within-region correlation no periodic table expresses, drift schedule,
  deadline stragglers);
* mixed-family ``run_batch`` — one vmapped program sweeps cells of ALL
  scenario families at once and equals the per-cell runs.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.availability import (
    ALL_MODES, ProcessMode, host_trace, make_mode,
)
from repro.core.availability_device import (
    ClusterOutage, DeadlineProcess, DriftProcess, GilbertElliott,
    TableProcess, bernoulli_nonempty, device_trace, ensure_nonempty,
    ensure_nonempty_np, make_process, proc_draw, proc_step,
)
from repro.fed.models import logistic_regression
from repro.fed.scan_engine import ScanConfig, ScanEngine, precompute_masks


def _mode(name, ds, seed=7):
    return make_mode(name, n_clients=ds.n_clients, data_sizes=ds.sizes,
                     label_sets=ds.label_sets(), num_labels=ds.num_classes,
                     seed=seed)


# ------------------------------------------------------------ legacy parity
def _legacy_trace(mode, rounds, avail_seed):
    """The seed repo's precompute_masks / AvailabilityMode.sample, inlined
    as an independent oracle: numpy SeedSequence([avail_seed, t]) stream,
    f64 table Bernoulli, force-one via rng.integers only when empty."""
    rows = []
    for t in range(rounds):
        rng = np.random.default_rng(np.random.SeedSequence([avail_seed, t]))
        p = mode.probs_table()[t % mode.period]
        a = rng.random(p.shape) < p
        if not a.any():
            a[int(rng.integers(len(a)))] = True
        rows.append(a)
    return np.stack(rows)


@pytest.mark.parametrize("name", ALL_MODES)
def test_legacy_modes_bit_identical(synthetic_ds, name):
    """Shared-wrapper path AND ProcessMode(TableProcess) path both reproduce
    the legacy availability stream bit for bit."""
    mode = _mode(name, synthetic_ds)
    want = _legacy_trace(mode, 40, avail_seed=1234)
    np.testing.assert_array_equal(precompute_masks(mode, 40, 1234), want)
    pm = ProcessMode(mode.process(), avail_seed=1234)
    np.testing.assert_array_equal(precompute_masks(pm, 40, 1234), want)


def test_table_process_device_probs_match_table(synthetic_ds):
    """The device-side table family serves exactly probs_table (f32 cast)."""
    mode = _mode("SLN", synthetic_ds)
    proc = mode.process()
    params, state = proc.params(), proc.init(jax.random.PRNGKey(0))
    for t in (0, 3, 25, 100):
        p, state = proc_step(params, state, jax.random.PRNGKey(t), t)
        np.testing.assert_array_equal(
            np.asarray(p), mode.probs(t).astype(np.float32))


# ------------------------------------------------------- force-one helper
def test_ensure_nonempty_parity():
    """The jax and numpy force-one helpers implement the SAME rule: empty
    mask -> exactly one client on; non-empty mask -> untouched."""
    n = 11
    some = np.zeros(n, bool)
    some[4] = True
    # non-empty: identity on both paths (and numpy consumes NO rng draws)
    rng = np.random.default_rng(0)
    s0 = rng.bit_generator.state
    np.testing.assert_array_equal(ensure_nonempty_np(some, rng), some)
    assert rng.bit_generator.state == s0
    np.testing.assert_array_equal(
        np.asarray(ensure_nonempty(jnp.asarray(some), jax.random.PRNGKey(0))),
        some)
    # empty: exactly one forced, uniformly across clients on both paths
    hits_np = np.zeros(n)
    hits_j = np.zeros(n)
    for i in range(200):
        a = ensure_nonempty_np(np.zeros(n, bool), np.random.default_rng(i))
        assert a.sum() == 1
        hits_np[np.flatnonzero(a)[0]] += 1
        b = np.asarray(ensure_nonempty(jnp.zeros(n, bool),
                                       jax.random.PRNGKey(i)))
        assert b.sum() == 1
        hits_j[np.flatnonzero(b)[0]] += 1
    assert hits_np.min() > 0 and hits_j.min() > 0


def test_bernoulli_nonempty_never_empty():
    p = jnp.zeros(9)
    for i in range(20):
        a = np.asarray(bernoulli_nonempty(jax.random.PRNGKey(i), p))
        assert a.sum() == 1


# ------------------------------------------- stationary / scheduled freqs
def test_gilbert_elliott_stationary_and_sojourn():
    ge = GilbertElliott(80, mean_on=8.0, mean_off=4.0)
    tr = device_trace(ge, 800, avail_seed=3)
    # stationary participation = pi_on (p_good=1, p_bad=0, base=1)
    assert abs(tr.mean() - ge.pi_on) < 0.04
    # mean on-sojourn ~ mean_on: count run lengths of the on-state
    runs = []
    for k in range(tr.shape[1]):
        col = tr[:, k].astype(int)
        edges = np.flatnonzero(np.diff(col))
        lengths = np.diff(np.concatenate([[0], edges + 1, [len(col)]]))
        vals = np.concatenate([[col[0]], col[edges + 1]])
        runs.extend(lengths[vals == 1].tolist())
    assert abs(np.mean(runs) - ge.mean_on) / ge.mean_on < 0.3


def test_cluster_outage_correlated_within_region():
    cl = ClusterOutage(60, n_clusters=4, p_fail=0.1, p_recover=0.3, floor=0.0)
    tr = device_trace(cl, 600, avail_seed=5)
    assert abs(tr.mean() - cl.pi_up) < 0.05
    ids = np.asarray(cl._cluster_ids())
    c = np.corrcoef(tr.T.astype(float))
    n = tr.shape[1]
    same = np.mean([c[i, j] for i in range(n) for j in range(i + 1, n)
                    if ids[i] == ids[j]])
    diff = np.mean([c[i, j] for i in range(n) for j in range(i + 1, n)
                    if ids[i] != ids[j]])
    # a region fails as a block: within-region correlation ~1, across ~0
    assert same > 0.9
    assert abs(diff) < 0.2


def test_drift_ramp_schedule():
    n = 50
    dr = DriftProcess(np.full((1, n), 0.9), np.full((1, n), 0.2),
                      t0=100, t1=400)
    tr = device_trace(dr, 500, avail_seed=7)
    assert abs(tr[:100].mean() - 0.9) < 0.05       # pre-ramp: table A
    assert abs(tr[450:].mean() - 0.2) < 0.05       # post-ramp: table B
    mid = tr[240:260].mean()                       # halfway: interpolated
    assert 0.4 < mid < 0.7
    # exact scheduled probabilities through the host face (f64, stateless)
    pm = ProcessMode(dr)
    np.testing.assert_allclose(pm.probs(250), np.full(n, 0.55))
    np.testing.assert_allclose(pm.probs(0), np.full(n, 0.9))


def test_drift_regime_switch():
    n = 40
    dr = DriftProcess(np.full((1, n), 0.9), np.full((1, n), 0.1),
                      switch_period=25)
    tr = device_trace(dr, 100, avail_seed=9)
    assert tr[:25].mean() > 0.8                    # regime A
    assert tr[25:50].mean() < 0.2                  # regime B
    assert tr[50:75].mean() > 0.8                  # back to A


def test_deadline_stationary_rate():
    dl = DeadlineProcess(80, deadline=1.0, rho=0.8, sigma=0.2, mu_seed=1)
    tr = device_trace(dl, 800, avail_seed=11)
    want = dl.stationary_rate()
    # population mean matches the analytic base * Phi((D - mu)/sd)
    assert abs(tr.mean() - want.mean()) < 0.04
    # per-client: clients with mu far below the deadline ~always make it,
    # far above ~never
    emp = tr.mean(0)
    mu = dl._mu()
    assert emp[mu < 0.6].mean() > 0.9
    assert emp[mu > 1.4].mean() < 0.1
    # tighter deadline -> strictly fewer participants
    tight = DeadlineProcess(80, deadline=0.7, rho=0.8, sigma=0.2, mu_seed=1)
    assert device_trace(tight, 800, avail_seed=11).mean() < tr.mean()


def test_stateful_families_stay_in_range(synthetic_ds):
    """Every factory scenario emits probabilities in [0, 1]."""
    ds = synthetic_ds
    for name in ("GE", "CLUSTER", "DRIFT", "DEADLINE"):
        proc = make_process(name, n_clients=ds.n_clients,
                            data_sizes=ds.sizes, rounds=50, seed=3)
        params = proc.params()
        state = proc.init(jax.random.PRNGKey(0))
        for t in range(30):
            p, state = proc_step(params, state, jax.random.PRNGKey(t), t)
            p = np.asarray(p)
            assert np.all(p >= 0) and np.all(p <= 1), name


def test_host_face_matches_device_latent_stream():
    """ProcessMode replays the SAME latent chain trajectory a scan cell
    draws: the probability rows agree (the Bernoulli backends differ by
    design — numpy vs threefry, DESIGN.md assumption log #10)."""
    ge = GilbertElliott(20, mean_on=5, mean_off=5)
    pm = ProcessMode(ge, avail_seed=77)
    params = ge.params()
    key = jax.random.PRNGKey(77)
    state = ge.init(key)
    from repro.core.availability_device import _STEP_SALT
    for t in range(15):
        p, state = proc_step(
            params, state,
            jax.random.fold_in(jax.random.fold_in(key, t), _STEP_SALT), t)
        np.testing.assert_allclose(pm.probs(t), np.asarray(p), atol=1e-7)


# ------------------------------------------------------ mixed-family batch
def test_mixed_families_run_batch(synthetic_ds):
    """ONE vmapped scan program sweeps cells of every scenario family, and
    equals the per-cell runs (sel, counts, losses)."""
    ds = synthetic_ds
    eng = ScanEngine(ds, logistic_regression(),
                     ScanConfig(rounds=8, m=6, local_steps=5, batch_size=10,
                                lr=0.1, eval_every=1, sampler="uniform",
                                max_sweeps=16))
    procs = [
        _mode("LN", ds).process(),
        GilbertElliott(ds.n_clients, mean_on=6, mean_off=3),
        ClusterOutage(ds.n_clients, n_clusters=3, floor=0.1),
        make_process("DRIFT", n_clients=ds.n_clients, data_sizes=ds.sizes,
                     rounds=8),
        DeadlineProcess(ds.n_clients, deadline=1.2),
    ]
    cells = [eng.cell(seed=i, process=p, avail_seed=60 + i)
             for i, p in enumerate(procs)]
    batch = eng.run_batch(cells)
    assert all(np.isfinite(h.val_loss).all() for h in batch)
    for cell, b in zip(cells, batch):
        single = eng.run(cell)
        np.testing.assert_array_equal(b.sel, single.sel)
        np.testing.assert_array_equal(b.counts, single.counts)
        np.testing.assert_allclose(b.val_loss, single.val_loss, atol=2e-6)


def test_mixed_families_with_fedgs(synthetic_ds):
    """FedGS sweeps the scenario axis too (the paper's sampler under the
    stateful availability the paper could not express)."""
    from repro.fed.scan_engine import oracle_h
    ds = synthetic_ds
    h = oracle_h(ds.opt_params)
    eng = ScanEngine(ds, logistic_regression(),
                     ScanConfig(rounds=8, m=6, local_steps=5, batch_size=10,
                                lr=0.1, eval_every=1, sampler="fedgs",
                                max_sweeps=16))
    cells = [eng.cell(seed=i, process=p, h=h, alpha=1.0, avail_seed=80 + i)
             for i, p in enumerate(
                 [GilbertElliott(ds.n_clients, mean_on=6, mean_off=3),
                  DeadlineProcess(ds.n_clients, deadline=1.0)])]
    hists = eng.run_batch(cells)
    for sh in hists:
        assert np.isfinite(sh.val_loss).all()
        assert sh.counts.sum() > 0


def test_host_draw_rejects_mismatched_process_seed():
    """A ProcessMode bakes its latent-stream seed; drawing it under a
    DIFFERENT Bernoulli seed would yield a trace matching neither device
    run — host_draw refuses instead of silently skewing."""
    pm = ProcessMode(GilbertElliott(10, mean_on=4, mean_off=4), avail_seed=7)
    with pytest.raises(ValueError, match="seed mismatch"):
        precompute_masks(pm, 5, avail_seed=8)
    assert precompute_masks(pm, 5, avail_seed=7).shape == (5, 10)


def test_flengine_runs_a_stateful_scenario(synthetic_ds):
    """The host engine accepts a ProcessMode scenario and its masks replay
    bit-exactly through precompute_masks (the shared host wrapper)."""
    from repro.core.sampler import UniformSampler
    from repro.fed.engine import FLConfig, FLEngine
    ds = synthetic_ds
    proc = GilbertElliott(ds.n_clients, mean_on=6, mean_off=3)
    cfg = FLConfig(rounds=6, sample_frac=0.2, local_steps=2, batch_size=5,
                   lr=0.1, eval_every=2, seed=1)
    eng = FLEngine(ds, logistic_regression(), UniformSampler(),
                   ProcessMode(proc, avail_seed=cfg.avail_seed), cfg)
    hist = eng.run()
    assert np.isfinite(hist.val_loss).all()
    masks = precompute_masks(ProcessMode(proc, avail_seed=cfg.avail_seed),
                             cfg.rounds, cfg.avail_seed)
    # counts consistency: each round FLEngine selected within those masks
    for t, sel in zip(hist.rounds, hist.sampled):
        assert set(sel) <= set(np.flatnonzero(masks[t]))

"""The unified telemetry layer (DESIGN.md §17).

Pinned claims:

* BITWISE NONINTERFERENCE (assumption log #24): a ``telemetry=True`` run's
  ``ScanHistory`` fields AND its checkpoint bytes are identical to the
  ``telemetry=False`` run's, across a stateful aggregator x availability x
  fault cell mix — the health channel is output-only (no carry state,
  stripped before checkpoint);
* the per-round metrics themselves are sane: ``(T,)``/``(T, bins)``
  float32 leaves, avail_rate in [0, 1], n_selected <= m, staleness
  histogram rows sum to the panel mass, the fault cell's corruption norm
  is positive while clean cells read 0;
* a resumed run's pre-resume telemetry prefix is NaN (telemetry is
  observability, not state — it is NOT checkpointed);
* the host-side ``Tracer`` nests spans, summarizes per-name, and exports
  a loadable Chrome/Perfetto ``trace.json``; the NULL_TRACER records
  nothing but still enters ``jax.named_scope``;
* ``JSONLMetricsSink`` round-trips schema-versioned events in order and
  ``read_metrics_jsonl`` rejects unknown schema versions;
* ``render_prometheus`` emits valid exposition text (TYPE/HELP + labeled
  samples);
* both engines share one ``runtime_stats()`` snapshot shape: flat
  program-cache counters plus nested checkpoint-writer and span blocks;
* ``SimService`` stamps submit -> first-segment and submit -> complete
  latency per request and serves them through ``metrics_text()``.
"""
import json
import math

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.availability_device import make_process
from repro.fed.aggregator_device import make_aggregator_process
from repro.fed.faults_device import make_fault_process
from repro.fed.models import logistic_regression
from repro.fed.scan_engine import ScanConfig, ScanEngine, oracle_h
from repro.fed.telemetry import (
    N_STALE_BINS, NULL_TRACER, TELEMETRY_SCHEMA_VERSION, Tracer,
    fault_corruption_norm, make_tracer, round_telemetry, runtime_snapshot,
    selection_dispersion, staleness_histogram, weight_entropy,
)
from repro.obs import (
    JSONLMetricsSink, prom_families, read_metrics_jsonl, render_prometheus,
)

HIST_FIELDS = ("sel", "valid", "counts", "gini", "count_var", "val_loss",
               "val_acc")
COMBOS = [("memory", "GE"), ("fedavgm", "CLUSTER"), ("fedadam", "DRIFT")]


@pytest.fixture(scope="module")
def ds16():
    from repro.data.synthetic import make_synthetic
    return make_synthetic(n_clients=16, alpha=0.5, beta=0.5, seed=0)


def _proc(name, ds, rounds, seed=7):
    return make_process(name, n_clients=ds.n_clients, data_sizes=ds.sizes,
                        label_sets=ds.label_sets(),
                        num_labels=ds.num_classes, rounds=rounds, seed=seed)


def _cfg(rounds, **kw):
    return ScanConfig(rounds=rounds, m=4, local_steps=2, batch_size=8,
                      lr=0.1, eval_every=1, sampler="uniform", **kw)


def _cells(eng, ds, rounds, agg, scenario, b=2, fault_cell=None):
    return [eng.cell(
        seed=s, process=_proc(scenario, ds, rounds, 3 + s),
        avail_seed=70 + s, h=oracle_h(ds.opt_params),
        aggregator_process=make_aggregator_process(agg),
        fault_process=(make_fault_process("sign_flip", ds.n_clients,
                                          frac=0.25)
                       if s == fault_cell else None))
        for s in range(b)]


# ------------------------------------------------------- metric reductions
class TestMetricReductions:
    def test_selection_dispersion_matches_hand_mean(self):
        h = jnp.asarray(np.random.default_rng(0).uniform(size=(6, 6)),
                        jnp.float32)
        sel = jnp.asarray([0, 2, 5, 0])
        valid = jnp.asarray([1.0, 1.0, 1.0, 0.0])
        got = float(selection_dispersion(h, sel, valid))
        idx = [0, 2, 5]
        hs = np.asarray(h)[np.ix_(idx, idx)]
        want = (hs.sum() - np.trace(hs)) / (3 * 2)
        assert got == pytest.approx(want, rel=1e-6)

    def test_dispersion_degenerate_selection_is_zero(self):
        h = jnp.ones((4, 4), jnp.float32)
        sel = jnp.asarray([1, 0, 0, 0])
        valid = jnp.asarray([1.0, 0.0, 0.0, 0.0])   # < 2 valid -> no pairs
        assert float(selection_dispersion(h, sel, valid)) == 0.0

    def test_weight_entropy_bounds(self):
        u = jnp.ones(5, jnp.float32)
        assert float(weight_entropy(u)) == pytest.approx(math.log(5),
                                                         rel=1e-5)
        spike = jnp.asarray([1.0, 0.0, 0.0], jnp.float32)
        assert float(weight_entropy(spike)) == pytest.approx(0.0, abs=1e-6)

    def test_staleness_histogram_bins_and_mass(self):
        age = jnp.asarray([0.0, 1.0, 3.0, 100.0], jnp.float32)
        hist = np.asarray(staleness_histogram(age))
        assert hist.shape == (N_STALE_BINS,) and hist.dtype == np.float32
        assert hist.sum() == pytest.approx(4.0)
        assert hist[0] == 1.0 and hist[-1] == 1.0   # 0 -> first, 100 -> last

    def test_fault_corruption_norm_zero_when_clean(self):
        f = jnp.ones((3, 7), jnp.float32)
        valid = jnp.ones(3, jnp.float32)
        assert float(fault_corruption_norm(f, f, valid)) == 0.0
        assert float(fault_corruption_norm(-f, f, valid)) > 0.0

    def test_round_telemetry_leaves_all_float32(self):
        """Every leaf float32 so resumed runs can NaN-pad the prefix."""
        n, m, p = 8, 3, 5
        params = {"w": jnp.zeros(p, jnp.float32)}
        local = {"w": jnp.ones((m, p), jnp.float32)}
        tel = round_telemetry(
            avail=jnp.ones(n, jnp.float32),
            valid=jnp.ones(m, jnp.float32),
            sel=jnp.asarray([0, 1, 2]),
            local=local, params_prev=params,
            params_new={"w": jnp.full(p, 0.1, jnp.float32)},
            weights=jnp.ones(m, jnp.float32),
            h=jnp.ones((n, n), jnp.float32),
            tau=jnp.zeros(n, jnp.float32), t=jnp.asarray(4, jnp.int32),
            fault_mag=jnp.asarray(0.5, jnp.float32))
        assert {"avail_rate", "n_selected", "update_norm_mean",
                "sampler_dispersion", "weight_entropy", "staleness_hist",
                "fault_corruption_norm"} <= set(tel)
        for k, v in tel.items():
            assert v.dtype == jnp.float32, k


# ------------------------------------------------ bitwise noninterference
@pytest.mark.parametrize("agg,scenario", COMBOS)
def test_telemetry_bitwise_noninterference(ds16, tmp_path, agg, scenario):
    """Assumption log #24: history fields and checkpoint bytes identical
    on-vs-off, with a sign-flip fault cell in the mix."""
    ds = ds16
    rounds = 6
    off = ScanEngine(ds, logistic_regression(), _cfg(rounds))
    on = ScanEngine(ds, logistic_regression(),
                    _cfg(rounds, telemetry=True))
    kw = dict(fault_cell=1)
    h_off = off.run_batch(_cells(off, ds, rounds, agg, scenario, **kw),
                          ckpt_path=str(tmp_path / "off"), ckpt_every=3)
    h_on = on.run_batch(_cells(on, ds, rounds, agg, scenario, **kw),
                        ckpt_path=str(tmp_path / "on"), ckpt_every=3)
    for i in range(2):
        for f in HIST_FIELDS:
            np.testing.assert_array_equal(
                getattr(h_on[i], f), getattr(h_off[i], f),
                err_msg=f"{agg}/{scenario} cell {i}: {f}")
    assert (tmp_path / "off.npz").read_bytes() == \
        (tmp_path / "on.npz").read_bytes(), "checkpoint bytes differ"
    assert h_off[0].telemetry is None
    assert h_on[0].telemetry is not None


def test_telemetry_content_sane(ds16):
    ds = ds16
    rounds = 5
    eng = ScanEngine(ds, logistic_regression(),
                     _cfg(rounds, telemetry=True))
    hists = eng.run_batch(_cells(eng, ds, rounds, "memory", "GE",
                                 fault_cell=1))
    clean, faulty = hists[0].telemetry, hists[1].telemetry
    assert clean["avail_rate"].shape == (rounds,)
    assert clean["staleness_hist"].shape == (rounds, N_STALE_BINS)
    assert np.all((clean["avail_rate"] >= 0) & (clean["avail_rate"] <= 1))
    assert np.all(clean["n_selected"] <= eng.cfg.m)
    assert np.all(clean["sampler_dispersion"] >= 0)
    assert np.all(clean["update_nan_frac"] == 0.0)
    # memory panel: every round's histogram carries the full N-client mass
    assert np.allclose(clean["staleness_hist"].sum(axis=1), ds.n_clients)
    # the sign-flip cell shows corruption; the clean cell reads zero
    assert np.all(clean["fault_corruption_norm"] == 0.0)
    assert faulty["fault_corruption_norm"].max() > 0.0


def test_telemetry_resume_prefix_nan(ds16, tmp_path):
    """Telemetry is NOT checkpointed: resuming from a mid-run save leaves
    the pre-resume rounds NaN while the tail is real — and the history
    fields still match the uninterrupted run bitwise."""
    ds = ds16
    rounds = 6
    ck = str(tmp_path / "ck")
    eng = ScanEngine(ds, logistic_regression(),
                     _cfg(rounds, telemetry=True))
    full = eng.run_batch(_cells(eng, ds, rounds, "memory", "GE"),
                         ckpt_path=ck, ckpt_every=3)
    # rewind the on-disk state to the mid-run save: stream and stop after
    # the first segment's checkpoint lands
    eng2 = ScanEngine(ds, logistic_regression(),
                      _cfg(rounds, telemetry=True))
    for _t0, _k, _traj in eng2.run_batch_stream(
            _cells(eng2, ds, rounds, "memory", "GE"),
            ckpt_path=ck, ckpt_every=3):
        break
    eng3 = ScanEngine(ds, logistic_regression(),
                      _cfg(rounds, telemetry=True))
    res = eng3.run_batch(_cells(eng3, ds, rounds, "memory", "GE"),
                         ckpt_path=ck, resume=True, ckpt_every=3)
    for i in range(2):
        for f in HIST_FIELDS:
            np.testing.assert_array_equal(getattr(res[i], f),
                                          getattr(full[i], f), err_msg=f)
        tel = res[i].telemetry
        assert np.all(np.isnan(tel["avail_rate"][:3]))
        assert np.all(np.isfinite(tel["avail_rate"][3:]))


def test_telemetry_streams_round_events(ds16, tmp_path):
    """The engine's sink feed: run_start / per-round round events with the
    metrics dict / segment / run_end, all loadable via read_metrics_jsonl."""
    ds = ds16
    rounds = 4
    path = str(tmp_path / "m.jsonl")
    eng = ScanEngine(ds, logistic_regression(),
                     _cfg(rounds, telemetry=True))
    eng.tracer = Tracer()
    with JSONLMetricsSink(path, run="test") as sink:
        eng.sink = sink
        eng.run_batch(_cells(eng, ds, rounds, "memory", "GE"),
                      ckpt_every=2)
    evs = read_metrics_jsonl(path)
    kinds = [e["kind"] for e in evs]
    assert kinds[0] == "run_start" and kinds[-1] == "run_end"
    rounds_evs = read_metrics_jsonl(path, kind="round")
    assert len(rounds_evs) == 2 * rounds          # per cell per round
    ev = rounds_evs[0]
    assert {"cell", "t", "metrics", "run", "seq", "wall_time"} <= set(ev)
    assert "avail_rate" in ev["metrics"]
    # spans covered the streamed run
    names = set(eng.tracer.summary())
    assert {"program_get", "dispatch_segment", "device_get",
            "metrics_emit"} <= names


# ------------------------------------------------------------------ Tracer
class TestTracer:
    def test_nested_spans_and_summary(self):
        tr = Tracer()
        with tr.span("outer", tag="x"):
            with tr.span("inner"):
                pass
            with tr.span("inner"):
                pass
        evs = tr.events()
        assert [e["name"] for e in evs] == ["inner", "inner", "outer"]
        depths = {e["name"]: e["depth"] for e in evs}
        assert depths == {"inner": 1, "outer": 0}
        s = tr.summary()
        assert s["inner"]["count"] == 2 and s["outer"]["count"] == 1
        assert s["outer"]["total_ms"] >= s["inner"]["total_ms"]
        assert evs[-1]["args"]["tag"] == "x"

    def test_export_chrome_loads(self, tmp_path):
        tr = Tracer()
        with tr.span("a"):
            pass
        p = tr.export_chrome(str(tmp_path / "trace.json"))
        doc = json.loads(open(p).read())
        (ev,) = doc["traceEvents"]
        assert ev["ph"] == "X" and ev["name"] == "a"
        assert ev["dur"] >= 0 and "ts" in ev
        assert doc["displayTimeUnit"] == "ms"

    def test_null_tracer_records_nothing(self):
        with NULL_TRACER.span("x"):
            pass
        assert NULL_TRACER.events() == [] and NULL_TRACER.summary() == {}

    def test_span_exception_still_recorded(self):
        tr = Tracer()
        with pytest.raises(ValueError):
            with tr.span("boom"):
                raise ValueError("x")
        assert tr.summary()["boom"]["count"] == 1

    def test_make_tracer_gating(self, tmp_path):
        assert make_tracer(None, False) is NULL_TRACER
        tr = make_tracer(str(tmp_path), False)
        assert tr.enabled and tr is not NULL_TRACER


# ------------------------------------------------------------------- sinks
class TestJSONLSink:
    def test_round_trip_ordered_and_schema_stamped(self, tmp_path):
        path = str(tmp_path / "m.jsonl")
        with JSONLMetricsSink(path, run="r1") as sink:
            for i in range(20):
                sink.emit("round", {"t": i})
            sink.flush()
            st = sink.stats()
        assert st["events"] == 20 and st["bytes"] > 0
        evs = read_metrics_jsonl(path)
        assert [e["payload"]["t"] if "payload" in e else e["t"]
                for e in evs] == list(range(20))
        assert all(e["schema"] == TELEMETRY_SCHEMA_VERSION for e in evs)
        assert [e["seq"] for e in evs] == list(range(20))
        assert all(e["run"] == "r1" for e in evs)

    def test_unknown_schema_rejected(self, tmp_path):
        path = tmp_path / "bad.jsonl"
        path.write_text(json.dumps({"schema": 999, "kind": "round",
                                    "seq": 0}) + "\n")
        with pytest.raises(ValueError, match="schema"):
            read_metrics_jsonl(str(path))
        assert read_metrics_jsonl(str(path), strict=False) == []

    def test_numpy_payloads_jsonable(self, tmp_path):
        path = str(tmp_path / "m.jsonl")
        with JSONLMetricsSink(path) as sink:
            sink.emit("round", {"x": np.float32(1.5),
                                "hist": np.arange(3, dtype=np.int32),
                                "nan": float("nan")})
        (ev,) = read_metrics_jsonl(path)
        assert ev["x"] == 1.5 and ev["hist"] == [0, 1, 2]
        assert ev["nan"] is None    # JSONL stays standard-parseable


class TestPrometheus:
    def test_render_exposition_format(self):
        fams = {
            "requests_total": {"type": "counter", "help": "reqs",
                               "samples": [({}, 3)]},
            "queue_seconds": {"type": "gauge", "help": "q",
                              "samples": [({"request": "0"}, 0.25),
                                          ({"request": "1"}, 0.5)]},
        }
        text = render_prometheus(fams)
        assert "# TYPE fedgs_requests_total counter" in text
        assert "fedgs_requests_total 3" in text
        assert 'fedgs_queue_seconds{request="0"} 0.25' in text
        assert text.endswith("\n")

    def test_prom_families_helper(self):
        fams = prom_families({"hits": 4, "misses": 1}, type_="counter")
        text = render_prometheus(fams, prefix="x_")
        assert "x_hits 4" in text and "# TYPE x_misses counter" in text


# ----------------------------------------------- shared runtime snapshot
def test_runtime_snapshot_shape():
    snap = runtime_snapshot(
        programs=None, writer={"submitted": 2},
        tracer=Tracer(), extra={"foo": 1})
    assert snap["telemetry_schema"] == TELEMETRY_SCHEMA_VERSION
    assert snap["checkpoint_writer"] == {"submitted": 2}
    assert snap["foo"] == 1 and "spans" in snap


def test_scan_engine_runtime_stats_nested_blocks(ds16, tmp_path):
    ds = ds16
    rounds = 4
    eng = ScanEngine(ds, logistic_regression(), _cfg(rounds))
    eng.run_batch(_cells(eng, ds, rounds, "fedavg", "GE"),
                  ckpt_path=str(tmp_path / "ck"), ckpt_every=2)
    st = eng.runtime_stats()
    # flat program-cache counters (pre-§17 shape) preserved
    assert st["misses"] >= 1 and st["compiles"] >= 1 and "size" in st
    w = st["checkpoint_writer"]
    assert w["submitted"] == w["completed"] >= 1
    assert w["queue_high_watermark"] >= 1
    assert w["blocked_ms"] >= 0 and w["write_ms"] > 0


def test_flengine_runtime_stats(ds16):
    from repro.core.availability import make_mode
    from repro.core.sampler import UniformSampler
    from repro.fed.engine import FLConfig, FLEngine
    ds = ds16
    mode = make_mode("IDL", n_clients=ds.n_clients, data_sizes=ds.sizes,
                     label_sets=ds.label_sets(),
                     num_labels=ds.num_classes, seed=7)
    cfg = FLConfig(rounds=3, sample_frac=0.25, local_steps=2,
                   batch_size=8, lr=0.1, eval_every=1, seed=0)
    eng = FLEngine(ds, logistic_regression(), UniformSampler(), mode, cfg)
    eng.run()
    st = eng.runtime_stats()
    assert st["telemetry_schema"] == TELEMETRY_SCHEMA_VERSION
    assert st["misses"] >= 2          # trainer + eval programs
    assert st["compiles"] >= 2 and st["compile_ms"] > 0
    # no checkpoint writer ran and the tracer is the NULL_TRACER, so the
    # nested blocks are absent — the flat shape stays minimal
    assert "spans" not in st and "checkpoint_writer" not in st
    eng.tracer = Tracer()
    with eng.tracer.span("probe"):
        pass
    assert eng.runtime_stats()["spans"]["probe"]["count"] == 1


# --------------------------------------------------------------- SimService
def test_sim_service_request_latency_and_metrics_text(ds16):
    from repro.launch.serve import SimService
    ds = ds16
    rounds = 4
    svc = SimService(ScanEngine(ds, logistic_regression(),
                                _cfg(rounds, telemetry=True)))
    kw = lambda i: dict(                                      # noqa: E731
        seed=i, avail_seed=70 + i, process=_proc("GE", ds, rounds, 3 + i),
        aggregator_process=make_aggregator_process("memory"))
    tickets = [svc.submit(**kw(i)) for i in range(2)]
    updates = list(svc.drain(segment=2))
    assert len(updates) == 4
    for t in tickets:
        tm = svc.histories[t].request_timing
        assert 0 <= tm["first_segment_s"] <= tm["complete_s"]
        assert svc.histories[t].telemetry is not None
    st = svc.stats()
    assert st["service"]["requests_total"] == 2
    assert st["service"]["segments_streamed_total"] == 2
    assert st["service"]["rounds_streamed_total"] == rounds * 2
    text = svc.metrics_text()
    assert "# TYPE fedgs_requests_total counter" in text
    assert "fedgs_requests_total 2" in text
    assert 'fedgs_request_queue_seconds{request="0"}' in text
    assert "fedgs_rounds_per_second" in text
    assert "fedgs_program_cache_hit_rate" in text


def test_fedsim_cli_with_observability(tmp_path, capsys):
    """serve --fedsim end-to-end with every observability knob on: JSONL
    metrics + chrome trace land on disk, prometheus text prints."""
    from repro.launch import serve
    mpath = tmp_path / "m.jsonl"
    tdir = tmp_path / "traces"
    hists = serve.main(["--fedsim", "--cells", "2", "--rounds", "4",
                       "--segment", "2", "--n-clients", "12",
                        "--telemetry", "--metrics-jsonl", str(mpath),
                        "--trace-dir", str(tdir)])
    assert len(hists) == 2 and hists[0].telemetry is not None
    evs = read_metrics_jsonl(str(mpath))
    assert {"run_start", "round", "request", "run_end"} <= \
        {e["kind"] for e in evs}
    trace = json.loads((tdir / "trace.json").read_text())
    assert any(e["name"] == "dispatch_segment"
               for e in trace["traceEvents"])
    out = capsys.readouterr().out
    assert "fedgs_requests_total" in out

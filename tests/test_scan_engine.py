"""The jit-compiled scan engine (scan-over-rounds, vmap-over-cells):

* parity harness — with identical seed streams and bit-identical availability
  masks, the scan engine reproduces FLEngine's sampled sets exactly and its
  val-loss trajectory to float32 round-off (the ISSUE acceptance bar is 1e-4);
* vmap-batch — a batched run equals the per-cell runs stacked;
* device-side Gumbel top-k sampling invariants;
* in-scan dynamic 3DG refresh.
"""
import numpy as np
import pytest

from repro.core.availability import make_mode
from repro.core.sampler import FedGSSampler
from repro.fed.engine import FLConfig, FLEngine
from repro.fed.models import logistic_regression
from repro.fed.scan_engine import (
    ScanConfig, ScanEngine, oracle_h, precompute_masks, stack_cells,
)


def _mode(name, ds, seed=7):
    return make_mode(name, n_clients=ds.n_clients, data_sizes=ds.sizes,
                     label_sets=ds.label_sets(), num_labels=ds.num_classes,
                     seed=seed)


def _host_run(ds, mode, rounds, seed, frac):
    sampler = FedGSSampler(alpha=1.0, max_sweeps=16)
    cfg = FLConfig(rounds=rounds, sample_frac=frac, local_steps=5,
                   batch_size=10, lr=0.1, eval_every=1, seed=seed)
    eng = FLEngine(ds, logistic_regression(), sampler, mode, cfg)
    eng.install_oracle_graph(ds.opt_params)
    return eng, eng.run()


def _scan_cfg(rounds, m, **kw):
    return ScanConfig(rounds=rounds, m=m, local_steps=5, batch_size=10,
                      lr=0.1, eval_every=1, max_sweeps=16, **kw)


@pytest.mark.parametrize("mode_name,frac,rounds", [("IDL", 0.2, 10),
                                                   ("LN", 0.1, 20)])
def test_parity_with_host_engine(synthetic_ds, mode_name, frac, rounds):
    """Same seeds -> same sampled sets, val-loss within 1e-4 (Alg. 1 parity)."""
    ds = synthetic_ds
    mode = _mode(mode_name, ds)
    eng, hist = _host_run(ds, mode, rounds, seed=3, frac=frac)
    masks = precompute_masks(mode, rounds, eng.cfg.avail_seed)
    # parity precondition: the static-shape program always selects M clients
    assert masks.sum(1).min() >= eng.m

    seng = ScanEngine(ds, logistic_regression(),
                      _scan_cfg(rounds, eng.m, sampler="fedgs"),
                      use_masks=True)
    sh = seng.run(seng.cell(seed=3, masks=masks, alpha=1.0,
                            h=eng.sampler._h))
    for i, t in enumerate(hist.rounds):
        assert hist.sampled[i] == sh.sampled(t).tolist(), f"round {t}"
    np.testing.assert_allclose(
        sh.val_loss[np.asarray(hist.rounds)], np.asarray(hist.val_loss),
        atol=1e-4)
    np.testing.assert_array_equal(eng.counts, sh.counts)


def test_vmap_batch_equals_per_cell_runs(synthetic_ds):
    """One vmapped program over B cells == the B single-cell programs."""
    ds = synthetic_ds
    h = oracle_h(ds.opt_params)
    eng = ScanEngine(ds, logistic_regression(),
                     _scan_cfg(8, 6, sampler="fedgs"))
    modes = [_mode(n, ds) for n in ("IDL", "LN", "SLN")]
    cells = [eng.cell(seed=s, mode=m, alpha=a, h=h, avail_seed=40 + s)
             for s, (m, a) in enumerate(zip(modes, (0.5, 1.0, 2.0)))]
    batch = eng.run_batch(cells)
    for cell, b in zip(cells, batch):
        single = eng.run(cell)
        np.testing.assert_array_equal(b.sel, single.sel)
        np.testing.assert_array_equal(b.counts, single.counts)
        np.testing.assert_allclose(b.val_loss, single.val_loss, atol=2e-6)


def test_stack_cells_pads_tables(synthetic_ds):
    """Cells whose availability tables have different periods batch fine."""
    ds = synthetic_ds
    eng = ScanEngine(ds, logistic_regression(),
                     _scan_cfg(6, 4, sampler="uniform"))
    cells = [eng.cell(seed=0, mode=_mode("LN", ds)),       # period 1
             eng.cell(seed=1, mode=_mode("YC", ds))]       # period 20
    stacked = stack_cells(cells)
    assert stacked["proc"]["table"].shape[:2] == (2, 20)
    assert stacked["proc"]["table_b"].shape[:2] == (2, 20)
    hists = eng.run_batch(cells)
    assert all(np.isfinite(h.val_loss).all() for h in hists)


def test_gumbel_selection_invariants(synthetic_ds):
    """S_t subset of A_t and |S_t| = min(M, |A_t|) for uniform and MD."""
    ds = synthetic_ds
    rounds, m = 12, 6
    mode = _mode("LN", ds)
    masks = precompute_masks(mode, rounds, avail_seed=5)
    for sampler in ("uniform", "md"):
        eng = ScanEngine(ds, logistic_regression(),
                         _scan_cfg(rounds, m, sampler=sampler),
                         use_masks=True)
        sh = eng.run(eng.cell(seed=0, masks=masks))
        for t in range(rounds):
            sel = sh.sampled(t)
            avail = np.flatnonzero(masks[t])
            assert set(sel) <= set(avail)
            assert len(sel) == min(m, len(avail))
        # counts track the selections
        assert sh.counts.sum() == sum(min(m, int(masks[t].sum()))
                                      for t in range(rounds))


def test_scan_uniform_learns_device_availability(synthetic_ds):
    """Device-side Bernoulli availability + Gumbel sampling: still learns."""
    ds = synthetic_ds
    eng = ScanEngine(ds, logistic_regression(),
                     _scan_cfg(16, 6, sampler="uniform"))
    sh = eng.run(eng.cell(seed=0, mode=_mode("LN", ds)))
    assert sh.val_loss[-1] < sh.val_loss[0]
    assert np.isfinite(sh.val_loss).all()


def test_dynamic_3dg_refresh_in_scan(synthetic_ds):
    """The carried (emb, H) dynamic-3DG state rebuilds in-scan and learns."""
    ds = synthetic_ds
    eng = ScanEngine(ds, logistic_regression(),
                     _scan_cfg(12, 6, sampler="fedgs",
                               graph_refresh_every=4))
    sh = eng.run(eng.cell(seed=0, mode=_mode("LN", ds)))
    assert np.isfinite(sh.val_loss).all()
    assert sh.val_loss[-1] < sh.val_loss[0]


def test_poc_selection_invariants(synthetic_ds):
    """In-scan Power-of-Choice: S_t subset of A_t, |S_t| = min(M, |A_t|),
    counts track the selections (the host-loop fallback is gone)."""
    ds = synthetic_ds
    rounds, m = 12, 6
    mode = _mode("LN", ds)
    masks = precompute_masks(mode, rounds, avail_seed=5)
    eng = ScanEngine(ds, logistic_regression(),
                     _scan_cfg(rounds, m, sampler="poc"), use_masks=True)
    sh = eng.run(eng.cell(seed=0, masks=masks))
    for t in range(rounds):
        sel = sh.sampled(t)
        avail = np.flatnonzero(masks[t])
        assert set(sel) <= set(avail)
        assert len(sel) == min(m, len(avail))
    assert sh.counts.sum() == sum(min(m, int(masks[t].sum()))
                                  for t in range(rounds))
    assert np.isfinite(sh.val_loss).all()


def test_poc_learns(synthetic_ds):
    """Sanity: the in-scan PoC trajectory decreases validation loss."""
    ds = synthetic_ds
    eng = ScanEngine(ds, logistic_regression(),
                     _scan_cfg(16, 6, sampler="poc"))
    sh = eng.run(eng.cell(seed=0, mode=_mode("IDL", ds)))
    assert sh.val_loss[-1] < sh.val_loss[0]


def test_poc_keeps_top_m_loss_candidates(synthetic_ds):
    """Round-0 exact replication of the device PoC path: the kept set must
    equal the top-m probed-loss subset of the Gumbel candidate draw (an
    inverted top-k — keeping the LOWEST-loss candidates — would still learn,
    so this pins the selection rule itself)."""
    import jax
    import jax.numpy as jnp
    from repro.core.sampler import gumbel_topk_select

    ds = synthetic_ds
    n, m = ds.n_clients, 6
    cfg = _scan_cfg(1, m, sampler="poc")
    eng = ScanEngine(ds, logistic_regression(), cfg, use_masks=True)
    cell = eng.cell(seed=4, masks=np.ones((1, n), bool))
    sh = eng.run(cell)

    # replicate the in-scan draw + probe with the same key streams
    model = logistic_regression()
    params = model.init(cell["key"])
    d = min(n, max(m, cfg.poc_d_factor * m))
    skey = jax.random.fold_in(cell["sampler_key"], 0)
    logw = jnp.log(jnp.maximum(jnp.asarray(ds.sizes, jnp.float32), 1e-12))
    cand = np.asarray(gumbel_topk_select(skey, logw,
                                         jnp.ones((n,), bool), d))
    cidx = np.argsort(np.where(cand, np.arange(n), n + np.arange(n)))[:d]
    keys = jax.random.split(jax.random.fold_in(skey, 1), d)
    xs, ys = jnp.asarray(ds.x), jnp.asarray(ds.y)
    losses = []
    for i, k in zip(cidx, keys):
        b = jax.random.randint(k, (cfg.poc_probe,), 0,
                               max(int(ds.sizes[i]), 1))
        losses.append(float(model.loss(params, xs[i][b], ys[i][b])))
    want = np.sort(cidx[np.argsort(-np.asarray(losses), kind="stable")[:m]])
    np.testing.assert_array_equal(sh.sampled(0), want)


def test_poc_batches_in_run_batch(synthetic_ds):
    """PoC cells vmap-batch like every other sampler (Table 2 acceptance)."""
    ds = synthetic_ds
    eng = ScanEngine(ds, logistic_regression(),
                     _scan_cfg(8, 6, sampler="poc"))
    cells = [eng.cell(seed=s, mode=_mode("LN", ds), avail_seed=50 + s)
             for s in range(2)]
    batch = eng.run_batch(cells)
    for cell, b in zip(cells, batch):
        single = eng.run(cell)
        np.testing.assert_array_equal(b.sel, single.sel)
        np.testing.assert_allclose(b.val_loss, single.val_loss, atol=2e-6)


def test_mixed_sampler_batch_equals_per_cell(synthetic_ds):
    """THE sampler-subsystem acceptance: one vmapped program running a
    Uniform + MD + PoC + FedGS cell batch (four different sampler families
    behind the one lax.switch step) equals the four per-cell runs."""
    from repro.core.sampler_device import make_sampler_process

    ds = synthetic_ds
    h = oracle_h(ds.opt_params)
    eng = ScanEngine(ds, logistic_regression(),
                     _scan_cfg(8, 6, sampler="fedgs"))
    procs = [make_sampler_process(f, alpha=1.0)
             for f in ("uniform", "md", "poc", "fedgs")]
    cells = [eng.cell(seed=i, mode=_mode("LN", ds), sampler_process=p,
                      h=h, avail_seed=60 + i)
             for i, p in enumerate(procs)]
    batch = eng.run_batch(cells)
    for proc, cell, b in zip(procs, cells, batch):
        single = eng.run(cell)
        np.testing.assert_array_equal(b.sel, single.sel,
                                      err_msg=proc.family)
        np.testing.assert_array_equal(b.counts, single.counts)
        np.testing.assert_allclose(b.val_loss, single.val_loss, atol=2e-6)
        # every family respects the cardinality contract in-scan
        assert np.all(b.valid.sum(1) <= eng.cfg.m)
        assert np.isfinite(b.val_loss).all()


def test_mixed_sampler_cells_match_per_family_engines(synthetic_ds):
    """A cell's sampler_process overrides the engine default and reproduces
    the run a cfg.sampler=<family> engine produces (same streams, same
    program semantics) — the per-cell switch is pure dispatch."""
    ds = synthetic_ds
    h = oracle_h(ds.opt_params)
    mode = _mode("LN", ds)
    from repro.core.sampler_device import make_sampler_process
    eng_mixed = ScanEngine(ds, logistic_regression(),
                           _scan_cfg(6, 6, sampler="fedgs"))
    # md (the weighted Gumbel stream) and poc (the probe-key stream) are the
    # two branches with sampler randomness; uniform is md with equal weights
    for family in ("md", "poc"):
        eng_single = ScanEngine(ds, logistic_regression(),
                                _scan_cfg(6, 6, sampler=family))
        a = eng_mixed.run(eng_mixed.cell(
            seed=2, mode=mode, h=h,
            sampler_process=make_sampler_process(family)))
        b = eng_single.run(eng_single.cell(seed=2, mode=mode, h=h))
        np.testing.assert_array_equal(a.sel, b.sel, err_msg=family)
        np.testing.assert_allclose(a.val_loss, b.val_loss, atol=2e-6)


def test_scan_solver_backend_pallas_matches_ref(synthetic_ds):
    """ScanConfig.solver_backend="pallas" routes the in-scan Eq. 16 solve
    through the tiled solver kernels and reproduces the ref backend's
    sampled sets bit for bit (the solver-parity contract composed into the
    full scanned program)."""
    ds = synthetic_ds
    h = oracle_h(ds.opt_params)
    mode = _mode("LN", ds)
    hists = {}
    for backend in ("ref", "pallas"):
        eng = ScanEngine(ds, logistic_regression(),
                         _scan_cfg(6, 6, sampler="fedgs",
                                   solver_backend=backend))
        hists[backend] = eng.run(eng.cell(seed=0, mode=mode, alpha=1.0, h=h))
    np.testing.assert_array_equal(hists["ref"].sel, hists["pallas"].sel)
    np.testing.assert_array_equal(hists["ref"].counts,
                                  hists["pallas"].counts)
    np.testing.assert_allclose(hists["ref"].val_loss,
                               hists["pallas"].val_loss, atol=1e-6)


def test_dynamic_3dg_pallas_backend(synthetic_ds):
    """ScanConfig.graph_backend="pallas" routes the in-scan rebuild through
    the tiled kernels (interpret mode on CPU) and matches the ref backend."""
    ds = synthetic_ds
    hists = {}
    for backend in ("ref", "pallas"):
        eng = ScanEngine(ds, logistic_regression(),
                         _scan_cfg(6, 6, sampler="fedgs",
                                   graph_refresh_every=3,
                                   graph_backend=backend))
        hists[backend] = eng.run(eng.cell(seed=0, mode=_mode("LN", ds)))
    np.testing.assert_array_equal(hists["ref"].sel, hists["pallas"].sel)
    np.testing.assert_allclose(hists["ref"].val_loss,
                               hists["pallas"].val_loss, atol=1e-5)


def test_eval_every_cadence(synthetic_ds):
    """eval_every > 1 leaves NaN on off rounds, records the last round."""
    ds = synthetic_ds
    cfg = ScanConfig(rounds=7, m=6, local_steps=5, batch_size=10, lr=0.1,
                     eval_every=3, sampler="uniform", max_sweeps=16)
    eng = ScanEngine(ds, logistic_regression(), cfg)
    sh = eng.run(eng.cell(seed=0, mode=_mode("IDL", ds)))
    assert sh.rounds.tolist() == [0, 3, 6]
    assert np.isnan(sh.val_loss[1])
    assert np.isfinite(sh.best_loss)


def test_fairness_host_device_parity():
    """The jnp fairness twins (core/fairness.py) match the numpy faces on
    integer and zero-count inputs (f32 vs f64 round-off only)."""
    from repro.core.fairness import (
        count_range, count_range_device, count_variance,
        count_variance_device, gini, gini_device,
    )
    rng = np.random.default_rng(3)
    cases = [rng.integers(0, 12, 30).astype(float),
             np.zeros(17),                       # zero-sum gini guard
             np.ones(9) * 4,                     # uniform -> gini 0
             rng.random(50) * 100]
    for v in cases:
        assert float(count_variance_device(v)) == pytest.approx(
            count_variance(v), rel=1e-5, abs=1e-5)
        assert float(count_range_device(v)) == pytest.approx(
            count_range(v.astype(int)) if np.all(v == v.astype(int))
            else float(v.max() - v.min()), rel=1e-5, abs=1e-5)
        assert float(gini_device(v)) == pytest.approx(gini(v), abs=1e-5)


def test_scan_history_emits_gini(synthetic_ds):
    """ScanHistory.gini tracks the device gini of the running counts at
    every round (cross-checked against the host gini of the replayed
    selections)."""
    from repro.core.fairness import gini as gini_host
    ds = synthetic_ds
    rounds = 10
    eng = ScanEngine(ds, logistic_regression(),
                     _scan_cfg(rounds, 6, sampler="uniform"))
    sh = eng.run(eng.cell(seed=0, mode=_mode("LN", ds)))
    assert sh.gini.shape == (rounds,)
    counts = np.zeros(ds.n_clients)
    for t in range(rounds):
        counts[sh.sampled(t)] += 1
        assert sh.gini[t] == pytest.approx(gini_host(counts), abs=1e-5), t
    assert sh.gini[-1] == pytest.approx(gini_host(sh.counts), abs=1e-5)


def test_probs_table_matches_numpy_api(synthetic_ds):
    """AvailabilityMode.probs_table is the source of truth the numpy API
    wraps: table[t % period] == probs(t) for every mode."""
    ds = synthetic_ds
    for name in ("IDL", "MDF", "LDF", "YMF", "YC", "LN", "SLN"):
        mode = _mode(name, ds)
        table = mode.probs_table()
        assert table.shape == (mode.period, ds.n_clients)
        for t in (0, 3, 25, 100):
            np.testing.assert_array_equal(table[t % mode.period],
                                          mode.probs(t))

"""Integration: the full federated round engine (Algorithm 1) on the paper's
exact Synthetic(0.5, 0.5) dataset."""
import numpy as np
import pytest

from repro.core.availability import make_mode
from repro.core.sampler import FedGSSampler, UniformSampler
from repro.fed.engine import FLConfig, FLEngine
from repro.fed.models import logistic_regression


def _engine(ds, sampler, mode_name="IDL", rounds=12, seed=0):
    mode = make_mode(mode_name, n_clients=ds.n_clients, data_sizes=ds.sizes,
                     label_sets=ds.label_sets(), num_labels=ds.num_classes,
                     seed=7)
    cfg = FLConfig(rounds=rounds, sample_frac=0.2, local_steps=5,
                   batch_size=10, lr=0.1, eval_every=2, seed=seed)
    return FLEngine(ds, logistic_regression(), sampler, mode, cfg)


def test_fedavg_uniform_learns(synthetic_ds):
    eng = _engine(synthetic_ds, UniformSampler(), rounds=16)
    hist = eng.run()
    assert hist.val_loss[-1] < hist.val_loss[0]
    assert hist.val_acc[-1] > 0.3          # 10-class problem, random = 0.1


def test_fedgs_learns_and_tracks_counts(synthetic_ds):
    sampler = FedGSSampler(alpha=1.0, max_sweeps=16)
    eng = _engine(synthetic_ds, sampler)
    eng.install_oracle_graph(synthetic_ds.opt_params)
    hist = eng.run()
    assert hist.val_loss[-1] < hist.val_loss[0]
    assert eng.counts.sum() == eng.m * eng.cfg.rounds


def test_fedgs_fairer_than_uniform_under_skewed_availability(synthetic_ds):
    """Fig. 4's claim at miniature scale: under skewed (LN) availability the
    FedGS sampling counts are more uniform than UniformSample's."""
    rounds = 30
    u_eng = _engine(synthetic_ds, UniformSampler(), "LN", rounds=rounds)
    u_eng.run()
    g = FedGSSampler(alpha=1.0, max_sweeps=16)
    g_eng = _engine(synthetic_ds, g, "LN", rounds=rounds)
    g_eng.install_oracle_graph(synthetic_ds.opt_params)
    g_eng.run()
    from repro.core.fairness import count_variance
    assert count_variance(g_eng.counts) < count_variance(u_eng.counts)


def test_fedprox_runs(synthetic_ds):
    eng = _engine(synthetic_ds, UniformSampler(), rounds=6)
    eng.cfg.prox_mu = 0.01
    eng._trainer = None
    from repro.fed.client import make_local_trainer
    eng._trainer = make_local_trainer(eng.model.loss, local_steps=5,
                                      batch_size=10, prox_mu=0.01)
    hist = eng.run()
    assert np.isfinite(hist.val_loss[-1])


def test_availability_trace_identical_across_methods(synthetic_ds):
    """Appendix C: the active states are controlled by an independent seed, so
    different methods see identical availability traces."""
    import numpy as np
    mode = make_mode("LN", n_clients=synthetic_ds.n_clients, seed=7)
    rng1 = np.random.default_rng(1234)
    rng2 = np.random.default_rng(1234)
    a1 = [mode.sample(t, rng1) for t in range(10)]
    a2 = [mode.sample(t, rng2) for t in range(10)]
    for x, y in zip(a1, a2):
        assert np.array_equal(x, y)


def test_aggregate_eq18():
    """theta = sum n_k / sum(n) theta_k."""
    import jax.numpy as jnp
    from repro.fed.server import aggregate
    stacked = {"w": jnp.asarray([[2.0], [6.0]])}
    out = aggregate(stacked, jnp.asarray([1.0, 3.0]))
    assert float(out["w"][0]) == pytest.approx((1 * 2 + 3 * 6) / 4)


def test_dynamic_3dg_refresh(synthetic_ds):
    """The online functional-similarity 3DG (paper: 'dynamically built and
    polished round by round') runs end-to-end and still learns."""
    sampler = FedGSSampler(alpha=1.0, max_sweeps=16)
    eng = _engine(synthetic_ds, sampler, "LN", rounds=12)
    eng.install_dynamic_graph(refresh_every=4)
    assert sampler._h is not None
    h0 = sampler._h.copy()
    hist = eng.run()
    assert hist.val_loss[-1] < hist.val_loss[0]
    # the graph was rebuilt with fresh embeddings at least once
    assert not np.allclose(sampler._h, h0)


def test_checkpoint_resume_exact(synthetic_ds, tmp_path):
    """Resuming from a round-10 checkpoint reproduces the uninterrupted run
    exactly (per-round seed derivation makes the process Markov)."""
    ck = str(tmp_path / "fl_ckpt")

    eng1 = _engine(synthetic_ds, UniformSampler(), rounds=14, seed=3)
    h1 = eng1.run()

    eng2 = _engine(synthetic_ds, UniformSampler(), rounds=14, seed=3)
    eng2.cfg.rounds = 10
    eng2.run(ckpt_path=ck, ckpt_every=5)
    eng3 = _engine(synthetic_ds, UniformSampler(), rounds=14, seed=3)
    h3 = eng3.run(ckpt_path=ck, resume=True)

    assert np.array_equal(eng1.counts, eng3.counts)
    assert h1.val_loss[-1] == pytest.approx(h3.val_loss[-1], rel=1e-5)

"""Perf-variant correctness: the optimized paths must be numerically
equivalent to the baselines they replace."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.launch.variants import VARIANTS, apply_variant
from repro.models import lm


@pytest.fixture(scope="module")
def swa_model():
    cfg = dataclasses.replace(get_config("smollm-135m").reduced(),
                              attention="sliding_window", window=8)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_ring_cache_matches_full_cache(swa_model, rng):
    cfg, params = swa_model
    T = 20
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, T)), jnp.int32)

    def rollout(ring: bool):
        old = lm.RING_CACHE
        lm.RING_CACHE = ring
        try:
            c = lm.init_decode_cache(cfg, 2, cfg.window if ring else T)
            outs = []
            for t in range(T):
                lg, c = lm.decode_step(params, cfg, toks[:, t], c)
                outs.append(np.asarray(lg, np.float32))
            return outs
        finally:
            lm.RING_CACHE = old

    full, ring = rollout(False), rollout(True)
    for a, b in zip(full, ring):
        np.testing.assert_allclose(a, b, atol=2e-3, rtol=1e-3)


def test_minremat_same_loss_and_grads(rng):
    cfg = get_config("smollm-135m").reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jnp.asarray(rng.integers(0, 100, (2, 16)), jnp.int32),
             "labels": jnp.asarray(rng.integers(0, 100, (2, 16)), jnp.int32)}

    def lg():
        return jax.value_and_grad(
            lambda p: lm.train_loss(p, cfg, batch, remat=True))(params)

    l0, g0 = lg()
    with apply_variant("minremat"):
        l1, g1 = lg()
    assert float(l0) == pytest.approx(float(l1), rel=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(g0), jax.tree_util.tree_leaves(g1)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-4)


def test_microbatch_grads_match_full_batch(rng):
    from repro.launch import steps as steps_mod
    from repro.launch.steps import make_train_step
    from repro.optim.optimizers import sgd
    cfg = get_config("smollm-135m").reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jnp.asarray(rng.integers(0, 100, (4, 16)), jnp.int32),
             "labels": jnp.asarray(rng.integers(0, 100, (4, 16)), jnp.int32)}
    opt = sgd()

    step1, _ = make_train_step(cfg, opt)
    p1, _, l1 = step1(params, opt.init(params), batch, jnp.float32(0.1))

    old = steps_mod.MICROBATCHES
    steps_mod.MICROBATCHES = 2
    try:
        step2, _ = make_train_step(cfg, opt)
        p2, _, l2 = step2(params, opt.init(params), batch, jnp.float32(0.1))
    finally:
        steps_mod.MICROBATCHES = old

    # each microbatch is half the tokens; mean-of-means == full mean here
    # because the masks are all-ones (labels in-range), so grads must match
    assert float(l1) == pytest.approx(float(l2), rel=1e-4)
    for a, b in zip(jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=3e-3)


def test_all_variants_enter_and_exit_cleanly():
    from repro.models import attention as attn_mod
    base = (attn_mod.DENSE_MAX, lm.REMAT_POLICY, lm.RING_CACHE, lm.LOSS_CHUNK)
    for name in VARIANTS:
        with apply_variant(name):
            pass
        assert (attn_mod.DENSE_MAX, lm.REMAT_POLICY, lm.RING_CACHE,
                lm.LOSS_CHUNK) == base, name


def test_remat_group_same_loss(rng):
    cfg = get_config("smollm-135m").reduced()   # 2 layers
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jnp.asarray(rng.integers(0, 100, (2, 16)), jnp.int32),
             "labels": jnp.asarray(rng.integers(0, 100, (2, 16)), jnp.int32)}
    l0 = float(lm.train_loss(params, cfg, batch, remat=True))
    old = lm.REMAT_GROUP
    lm.REMAT_GROUP = 2
    try:
        l1 = float(lm.train_loss(params, cfg, batch, remat=True))
        g1 = jax.grad(lambda p: lm.train_loss(p, cfg, batch, remat=True))(params)
    finally:
        lm.REMAT_GROUP = old
    assert l0 == pytest.approx(l1, rel=1e-6)
    assert all(np.all(np.isfinite(np.asarray(g, np.float32)))
               for g in jax.tree_util.tree_leaves(g1))

"""The unified device-native 3DG pipeline (core/graph_device.py):

* backend parity — ``build_h(backend="pallas")`` ≡ ``build_h(backend="ref")``
  ≡ the legacy float64 numpy pipeline (pinned verbatim below) at
  non-tile-multiple N, including disconnected graphs and the all-equal
  degenerate V;
* the ``inf·0 -> NaN`` diagonal-hazard regression (ISSUE 2 satellite): the
  shared ``to_adjacency`` must stay NaN-free when a row's normalized
  self-similarity falls below eps;
* traceability — the pipeline composes under jit, and the production
  ``fedsim.graph_pipeline`` built on it returns a valid selection.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import graph_device as gd


def _legacy_h(feats, eps=0.1, sigma2=0.01, scale=2.0):
    """The pre-refactor core/graph.py float64 pipeline (dot similarity ->
    minmax -> adjacency -> FW -> finite cap -> [0,1]), kept here verbatim as
    the numerics oracle the unified f32 pipeline must match to 1e-5."""
    u = np.asarray(feats, np.float64)
    v = u @ u.T
    lo, hi = v.min(), v.max()
    vn = np.zeros_like(v) if hi - lo < 1e-12 else (v - lo) / (hi - lo)
    r = np.where(vn >= eps, np.exp(-vn / sigma2), np.inf)
    np.fill_diagonal(r, 0.0)
    h = r.copy()
    for k in range(len(h)):
        np.minimum(h, h[:, k:k + 1] + h[k:k + 1, :], out=h)
    finite = h[np.isfinite(h)]
    cap = (finite.max() if finite.size else 1.0) * scale
    h = np.where(np.isfinite(h), h, cap)
    np.fill_diagonal(h, 0.0)
    hmax = h.max()
    return h / hmax if hmax > 0 else h


def _clustered_feats(rng, n, d=6):
    """Two orthogonal nonneg clusters -> disconnected cross-cluster pairs
    (inf distances), exercising the finite-cap path."""
    u = np.abs(rng.normal(size=(n, d))) + 0.3
    u[: n // 2, d // 2:] = 0.0
    u[n // 2:, : d // 2] = 0.0
    return u


# ---------------------------------------------------------- backend parity
@pytest.mark.parametrize("n", [7, 100, 130])
def test_backend_parity_dense(rng, n):
    """pallas ≡ ref ≡ legacy numpy at non-tile-multiple N (1e-5)."""
    feats = rng.random((n, 5)) + 0.1
    want = _legacy_h(feats)
    for backend in gd.BACKENDS:
        got = np.asarray(gd.build_h(jnp.asarray(feats, jnp.float32),
                                    backend=backend))
        np.testing.assert_allclose(got, want, atol=1e-5,
                                   err_msg=f"backend={backend}")


@pytest.mark.parametrize("n", [7, 100, 130])
def test_backend_parity_disconnected(rng, n):
    """Disconnected graphs (inf distances): cap path agrees across backends
    and with the legacy oracle; edge patterns match exactly."""
    feats = _clustered_feats(rng, n)
    want = _legacy_h(feats)
    _, r_ref, h_ref = gd.build_3dg(jnp.asarray(feats, jnp.float32))
    assert np.isinf(np.asarray(h_ref)).any(), "fixture must disconnect"
    for backend in gd.BACKENDS:
        _, r, _ = gd.build_3dg(jnp.asarray(feats, jnp.float32),
                               backend=backend)
        np.testing.assert_array_equal(np.isinf(np.asarray(r)),
                                      np.isinf(np.asarray(r_ref)))
        got = np.asarray(gd.build_h(jnp.asarray(feats, jnp.float32),
                                    backend=backend))
        assert np.isfinite(got).all()
        np.testing.assert_allclose(got, want, atol=1e-5,
                                   err_msg=f"backend={backend}")


@pytest.mark.parametrize("n", [7, 130])
def test_backend_parity_degenerate_v(n):
    """All-equal similarity V: minmax collapses to 0, no edges survive, and
    every backend returns the all-zero H (the legacy zeros contract)."""
    v = jnp.full((n, n), 3.0, jnp.float32)
    cfg = gd.GraphConfig(similarity="precomputed")
    for backend in gd.BACKENDS:
        h = np.asarray(gd.build_h(v, cfg, backend=backend))
        assert not np.isnan(h).any()
        np.testing.assert_array_equal(h, np.zeros((n, n), np.float32))


def test_legacy_numpy_wrapper_matches_device(rng):
    """core.graph.build_3dg is the same pipeline behind a numpy face."""
    from repro.core.graph import build_3dg
    feats = rng.random((23, 4))
    v_np, r_np, h_np = build_3dg(feats)
    v_d, r_d, h_d = gd.build_3dg(jnp.asarray(feats, jnp.float32))
    np.testing.assert_array_equal(v_np, np.asarray(v_d))
    np.testing.assert_array_equal(r_np, np.asarray(r_d))
    np.testing.assert_array_equal(h_np, np.asarray(h_d))


# ------------------------------------------------------ NaN-hazard regression
def test_to_adjacency_diag_below_eps_no_nan():
    """Regression for the ``r * (1 - eye)`` pattern: when a row's normalized
    self-similarity falls below eps the no-edge entry is inf, and inf·0 on
    the diagonal is NaN — the shared stage must mask with where(eye, 0, ·)."""
    vn = np.array([[0.02, 0.9, 0.0],
                   [0.9, 1.0, 0.0],
                   [0.0, 0.0, 0.03]])          # rows 0/2: self-sim < eps
    # the hazard pattern really does NaN on this input
    with np.errstate(invalid="ignore"):
        hazard = np.where(vn >= 0.1, np.exp(-vn / 0.01), np.inf) * (1 - np.eye(3))
    assert np.isnan(np.diag(hazard)).any()
    r = np.asarray(gd.to_adjacency(jnp.asarray(vn, jnp.float32)))
    assert not np.isnan(r).any()
    np.testing.assert_array_equal(np.diag(r), np.zeros(3))
    assert np.isinf(r[0, 2]) and np.isfinite(r[0, 1])


def test_build_h_low_self_similarity_features_no_nan(rng):
    """End to end: nonneg features with a near-zero row push that row's
    normalized self-similarity below eps; H must stay NaN-free on both
    backends (previously fedsim.graph_pipeline produced NaN here)."""
    feats = np.abs(rng.normal(size=(12, 4))) + 0.5
    feats[3] = 1e-3
    for backend in gd.BACKENDS:
        vn, r, h_raw = gd.build_3dg(jnp.asarray(feats, jnp.float32),
                                    backend=backend)
        assert float(vn[3, 3]) < 0.1, "fixture must trip the hazard"
        h = gd.cap_and_normalize(h_raw)
        for arr in (r, h_raw, h):
            assert not np.isnan(np.asarray(arr)).any()


# ------------------------------------------------------------- traceability
def test_stages_compose_under_jit(rng):
    feats = jnp.asarray(rng.random((9, 5)), jnp.float32)
    cfg = gd.GraphConfig(eps=0.2, sigma2=0.05, finite_cap_scale=3.0)
    eager = gd.build_h(feats, cfg)
    jitted = jax.jit(lambda u: gd.build_h(u, cfg))(feats)
    # XLA fusion may reorder the matmul/exp pipeline by an ulp
    np.testing.assert_allclose(np.asarray(eager), np.asarray(jitted),
                               atol=1e-6)


def test_cap_and_normalize_matches_sampler_set_graph(rng):
    """FedGSSampler.set_graph and scan_engine.normalized_h are the SAME
    stage: one cap/normalize implementation serves both layers."""
    from repro.core.sampler import FedGSSampler
    from repro.fed.scan_engine import normalized_h
    h = rng.random((15, 15)) * 4
    h[h > 3.2] = np.inf
    np.fill_diagonal(h, 0.0)
    s = FedGSSampler(alpha=1.0)
    s.set_graph(h)
    np.testing.assert_array_equal(s._h, normalized_h(h))


def test_fedsim_graph_pipeline_selects_m(rng):
    """The production dry-run pipeline (shared stages + shared solver) jits
    and returns a valid |S| = m selection with no NaN-poisoned scores."""
    from repro.launch.fedsim import graph_pipeline
    n, m = 16, 4
    feats = jnp.asarray(np.abs(rng.normal(size=(n, 6))) + 0.2, jnp.float32)
    counts = jnp.zeros((n,), jnp.float32)
    avail = jnp.ones((n,), bool)
    s = np.asarray(jax.jit(
        lambda f, c, a: graph_pipeline(f, c, a, 1.0, m, 8))(feats, counts, avail))
    assert s.sum() == m

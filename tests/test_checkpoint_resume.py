"""Exact-resume checkpointing for BOTH engines (DESIGN.md §13).

The claims pinned here:

* ScanEngine.run_batch: fused == segmented == resumed-from-mid-run, every
  trajectory leaf bitwise, for every stateful aggregator family (momentum,
  Adam moments, the (N, P) update memory) crossed with every stateful
  availability scenario family (Markov chains, cluster outages, drift,
  deadlines) — the FULL carry round-trips through the flat-npz checkpoint;
* FLEngine.run: the host engine checkpoints ``ServerAggregator.state``
  wholesale, so stateful aggregators resume bitwise too (the pre-§13
  format silently dropped that state and momentum restarted from zero —
  the regression test below pins both the fix and the old-format
  fallback);
* resume across DEVICE COUNTS (8 -> 1 and 1 -> 8, CPU host devices forced
  by ``XLA_FLAGS=--xla_force_host_platform_device_count=8``): with
  ckpt_every=1 the head run is a chain of one-round segments, which
  compile identically on every device count — so the resumed trajectory
  is bitwise equal to the uninterrupted single-device run (the multi-round
  fused program does NOT have this property: XLA fuses the scan while-body
  differently per SPMD partition count; see test_shard_engine.py).
"""
import numpy as np
import pytest

import jax

from repro.core.availability import ProcessMode
from repro.core.availability_device import make_process
from repro.core.sampler import make_sampler
from repro.fed.aggregator_device import make_aggregator_process
from repro.fed.engine import FLConfig, FLEngine
from repro.fed.models import logistic_regression
from repro.fed.scan_engine import ScanConfig, ScanEngine

needs8 = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs 8 devices: export "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8 before jax "
           "initializes (the CI shard job does)")

# one stateful aggregator family x one stateful scenario family per case —
# together the four cases cover every slot of the checkpointed state
COMBOS = [("fedavgm", "GE"), ("fedadam", "CLUSTER"),
          ("fedprox_w", "DRIFT"), ("memory", "DEADLINE")]


@pytest.fixture(scope="module")
def ds16():
    from repro.data.synthetic import make_synthetic
    return make_synthetic(n_clients=16, alpha=0.5, beta=0.5, seed=0)


def _proc(name, ds, rounds, seed=7):
    return make_process(name, n_clients=ds.n_clients, data_sizes=ds.sizes,
                        label_sets=ds.label_sets(),
                        num_labels=ds.num_classes, rounds=rounds, seed=seed)


def _assert_hist_bitwise(a, b, msg=""):
    for f in ("sel", "valid", "counts", "gini", "count_var", "val_loss",
              "val_acc"):
        np.testing.assert_array_equal(getattr(a, f), getattr(b, f),
                                      err_msg=f"{msg}: {f}")


# ------------------------------------------------------------- ScanEngine
def _scan_cfg(rounds, **kw):
    return ScanConfig(rounds=rounds, m=4, local_steps=2, batch_size=8,
                      lr=0.1, eval_every=1, sampler="uniform", **kw)


@pytest.mark.parametrize("agg,scenario", COMBOS)
def test_scan_resume_bitwise(ds16, tmp_path, agg, scenario):
    """Mid-run save at round 3, resume in a FRESH engine: the 6-round
    trajectory is bitwise equal to the uninterrupted run at the same
    checkpoint cadence — the whole carry (aggregator slots incl.
    momentum/moments/memory panel, availability-chain state, sampler
    state, counts) survives the npz.  Against the FUSED (no-checkpoint)
    program the decisions are still bitwise and the float evals agree to
    2e-6 (XLA fuses the scan while-body differently per scan length —
    the same ulp-drift precedent as run() vs run_batch)."""
    ds = ds16
    rounds = 6
    cells_of = lambda eng: [eng.cell(        # noqa: E731
        seed=s, process=_proc(scenario, ds, rounds, seed=3 + s),
        avail_seed=70 + s,
        aggregator_process=make_aggregator_process(agg))
        for s in range(2)]
    eng = ScanEngine(ds, logistic_regression(), _scan_cfg(rounds))
    fused = eng.run_batch(cells_of(eng))
    ck = str(tmp_path / "ck")
    seg = eng.run_batch(cells_of(eng), ckpt_path=ck, ckpt_every=3)
    eng2 = ScanEngine(ds, logistic_regression(), _scan_cfg(rounds))
    res = eng2.run_batch(cells_of(eng2), ckpt_path=ck, resume=True,
                         ckpt_every=3)
    for i in range(2):
        _assert_hist_bitwise(seg[i], res[i], f"{agg}/{scenario} res {i}")
        for f in ("sel", "valid", "counts"):
            np.testing.assert_array_equal(
                getattr(fused[i], f), getattr(seg[i], f),
                err_msg=f"{agg}/{scenario} fused {i}: {f}")
        np.testing.assert_allclose(seg[i].val_loss, fused[i].val_loss,
                                   atol=2e-6)


def test_scan_resume_without_checkpoint_starts_fresh(ds16, tmp_path):
    """resume=True with no file on disk is a cold start, not an error."""
    ds = ds16
    eng = ScanEngine(ds, logistic_regression(), _scan_cfg(4))
    cells = [eng.cell(seed=0, process=_proc("GE", ds, 4))]
    got = eng.run_batch(cells, ckpt_path=str(tmp_path / "missing"),
                        resume=True)
    ref = eng.run_batch(cells)
    _assert_hist_bitwise(ref[0], got[0])


# ------------------------------------------------- cross-device-count resume
@needs8
@pytest.mark.parametrize("direction", ["8to1", "1to8"])
def test_scan_resume_across_device_counts_bitwise(ds16, tmp_path, direction):
    """Save on one device count, resume on another (8 -> 1 and 1 -> 8):
    checkpoints gather shards to host npz (device-layout-free) and the
    resuming program reshards to its own mesh.  One-round segments compile
    identically on EVERY device count (unlike multi-round scans, whose
    while-body XLA fuses differently per SPMD partition count and scan
    length), so with ckpt_every=1 the stitched cross-device trajectory is
    bitwise equal to the uninterrupted single-device run at the same
    cadence — and decisions-bitwise / evals-to-2e-6 vs the fused run."""
    ds = ds16
    rounds, head_rounds = 8, 5
    mesh = (8,)
    ref_eng = ScanEngine(ds, logistic_regression(), _scan_cfg(rounds))
    cells = [ref_eng.cell(
        seed=s, process=_proc(("GE", "CLUSTER", "DRIFT", "DEADLINE")[s % 4],
                              ds, rounds, seed=3 + s),
        avail_seed=80 + s,
        aggregator_process=make_aggregator_process(
            ("fedavgm", "fedadam", "memory", "fedavg")[s % 4]))
        for s in range(8)]
    # the uninterrupted single-device reference at the SAME k=1 cadence
    ref = ref_eng.run_batch(cells, ckpt_path=str(tmp_path / "ref"),
                            ckpt_every=1)
    fused = ref_eng.run_batch(cells)

    head_mesh, tail_mesh = (mesh, None) if direction == "8to1" else \
        (None, mesh)
    # the head engine stops after head_rounds (its lr table is the
    # length-5 prefix of the full schedule — per-round host floats), and
    # its last mid-run save (t0=4) is what the tail resumes from
    head = ScanEngine(ds, logistic_regression(),
                      _scan_cfg(head_rounds, mesh=head_mesh))
    ck = str(tmp_path / "ck")
    head.run_batch(cells, ckpt_path=ck, ckpt_every=1)
    tail = ScanEngine(ds, logistic_regression(),
                      _scan_cfg(rounds, mesh=tail_mesh))
    got = tail.run_batch(cells, ckpt_path=ck, resume=True, ckpt_every=1)
    for i in range(8):
        _assert_hist_bitwise(ref[i], got[i], f"{direction} cell {i}")
        for f in ("sel", "valid", "counts"):
            np.testing.assert_array_equal(
                getattr(fused[i], f), getattr(got[i], f),
                err_msg=f"{direction} fused {i}: {f}")
        np.testing.assert_allclose(got[i].val_loss, fused[i].val_loss,
                                   atol=2e-6)


# --------------------------------------------------------------- FLEngine
def _fl_build(ds, agg, scenario, rounds):
    proc = _proc(scenario, ds, rounds)
    cfg = FLConfig(rounds=rounds, sample_frac=0.25, local_steps=2,
                   batch_size=8, eval_every=1, seed=0, avail_seed=1234)
    return FLEngine(ds, logistic_regression(), make_sampler("uniform"),
                    ProcessMode(proc, avail_seed=1234), cfg,
                    aggregator=make_aggregator_process(agg))


def _leaf_max_diff(a, b):
    return max(float(np.max(np.abs(np.asarray(x) - np.asarray(y))))
               if np.asarray(x).size else 0.0
               for x, y in zip(jax.tree_util.tree_leaves(a),
                               jax.tree_util.tree_leaves(b)))


@pytest.mark.parametrize("agg,scenario", COMBOS)
def test_flengine_resume_bitwise(ds16, tmp_path, agg, scenario):
    """Save at round 3, resume in a fresh engine: tail history and final
    params bitwise equal to the uninterrupted run — the server aggregator's
    momentum / Adam moments / update memory now ride the checkpoint."""
    ds = ds16
    rounds, split = 8, 4
    full = _fl_build(ds, agg, scenario, rounds)
    h_full = full.run()

    ck = str(tmp_path / "ck")
    head = _fl_build(ds, agg, scenario, rounds)
    head.cfg.rounds = split
    head.run(ckpt_path=ck, ckpt_every=split)
    res = _fl_build(ds, agg, scenario, rounds)
    h_res = res.run(ckpt_path=ck, resume=True)

    assert h_res.rounds == list(range(split, rounds))
    assert h_full.val_loss[split:] == h_res.val_loss
    assert h_full.sampled[split:] == h_res.sampled
    assert _leaf_max_diff(full.params, res.params) == 0.0


def test_flengine_checkpoint_carries_server_state(ds16, tmp_path):
    """Regression pin for the resume gap: the saved npz contains the
    ``server`` subtree, and a legacy checkpoint WITHOUT it still resumes
    (falling back to a re-initialized aggregator) — but that fallback
    demonstrably diverges from the uninterrupted momentum trajectory,
    which is exactly the drift the new format eliminates."""
    ds = ds16
    rounds, split = 8, 4
    ck = str(tmp_path / "ck")
    head = _fl_build(ds, "fedavgm", "GE", rounds)
    head.cfg.rounds = split
    head.run(ckpt_path=ck, ckpt_every=split)

    with np.load(ck + ".npz") as z:
        server_keys = [k for k in z.files if k.startswith("server/")]
        assert any(k.startswith("server/m1/") for k in server_keys)
        legacy = {k: z[k] for k in z.files if not k.startswith("server/")}
    full = _fl_build(ds, "fedavgm", "GE", rounds)
    h_full = full.run()

    # strip the server subtree -> the pre-§13 format
    old_ck = str(tmp_path / "old_ck")
    np.savez(old_ck + ".npz", **legacy)
    res = _fl_build(ds, "fedavgm", "GE", rounds)
    h_old = res.run(ckpt_path=old_ck, resume=True)
    assert h_old.rounds == list(range(split, rounds))
    assert np.all(np.isfinite(h_old.val_loss))
    # momentum restarted from zero: the old format's tail drifts
    assert h_old.val_loss != h_full.val_loss[split:]


# ------------------------------------------------- fault-injection carry
def test_scan_resume_bitwise_fault_krum(ds16, tmp_path):
    """The PR-9 combo: krum x sign_flip x GE (plus a straggler x
    trimmed-mean cell so the (N, P) stale panel rides the carry too) —
    fused == segmented == fresh-engine-resumed, decisions bitwise and the
    FaultProcess state (AR(1) latency chain, stale panel) round-tripping
    through the npz exactly like the aggregator slots."""
    from repro.fed.faults_device import make_fault_process
    ds = ds16
    rounds = 6
    cells_of = lambda eng: [                 # noqa: E731
        eng.cell(seed=0, process=_proc("GE", ds, rounds, seed=3),
                 avail_seed=70,
                 fault_process=make_fault_process("sign_flip",
                                                  ds.n_clients, frac=0.25),
                 aggregator_process=make_aggregator_process(
                     "krum", krum_f=1)),
        eng.cell(seed=1, process=_proc("GE", ds, rounds, seed=4),
                 avail_seed=71,
                 fault_process=make_fault_process("straggler_stale",
                                                  ds.n_clients, frac=0.5),
                 aggregator_process=make_aggregator_process(
                     "trimmed_mean", beta_trim=0.25)),
    ]
    eng = ScanEngine(ds, logistic_regression(), _scan_cfg(rounds))
    fused = eng.run_batch(cells_of(eng))
    ck = str(tmp_path / "ck")
    seg = eng.run_batch(cells_of(eng), ckpt_path=ck, ckpt_every=3)
    eng2 = ScanEngine(ds, logistic_regression(), _scan_cfg(rounds))
    res = eng2.run_batch(cells_of(eng2), ckpt_path=ck, resume=True,
                         ckpt_every=3)
    for i in range(2):
        _assert_hist_bitwise(seg[i], res[i], f"fault res {i}")
        for f in ("sel", "valid", "counts"):
            np.testing.assert_array_equal(
                getattr(fused[i], f), getattr(seg[i], f),
                err_msg=f"fault fused {i}: {f}")
        np.testing.assert_allclose(seg[i].val_loss, fused[i].val_loss,
                                   atol=2e-6)


def test_flengine_fault_resume_bitwise(ds16, tmp_path):
    """FLEngine checkpoints now carry the ``faults`` subtree: a
    straggler-stale run saved at round 4 resumes bitwise (stale panel +
    latency chain restored), and the npz actually contains the keys."""
    h_full = _fl_build_fault(ds16, 8).run()
    ck = str(tmp_path / "ck")
    head = _fl_build_fault(ds16, 8)
    head.cfg.rounds = 4
    head.run(ckpt_path=ck, ckpt_every=4)
    with np.load(ck + ".npz") as z:
        assert any(k.startswith("faults/") for k in z.files)
    res = _fl_build_fault(ds16, 8)
    h_res = res.run(ckpt_path=ck, resume=True)
    assert h_res.rounds == list(range(4, 8))
    assert h_full.val_loss[4:] == h_res.val_loss
    assert h_full.sampled[4:] == h_res.sampled


def _fl_build_fault(ds, rounds):
    from repro.fed.faults_device import make_fault_process
    proc = _proc("GE", ds, rounds)
    cfg = FLConfig(rounds=rounds, sample_frac=0.25, local_steps=2,
                   batch_size=8, eval_every=1, seed=0, avail_seed=1234)
    return FLEngine(ds, logistic_regression(), make_sampler("uniform"),
                    ProcessMode(proc, avail_seed=1234), cfg,
                    fault=make_fault_process("straggler_stale",
                                             ds.n_clients, frac=0.5),
                    aggregator=make_aggregator_process("trimmed_mean",
                                                       beta_trim=0.25))

"""shard_map'd ScanEngine.run_batch on the ("cells", "silo") mesh
(DESIGN.md §13).

Parity contract proven here (all on CPU host devices forced by
``XLA_FLAGS=--xla_force_host_platform_device_count=8``, set BEFORE jax
initializes — the CI shard job exports it; locally the module skips):

* every DECISION — sampled sets, pad masks, participation counts, the
  fairness metrics derived from them — is bitwise identical between the
  sharded and the single-device program, for a mixed scenario x sampler x
  aggregator cell batch;
* the float EVAL leaves (val_loss) agree to 2e-6: XLA fuses the multi-round
  scan's while-body differently per SPMD partition count / vmap width, so
  full multi-round trajectories pick up ulp-level drift (same precedent and
  tolerance as the run() vs run_batch tests in test_scan_engine.py);
* ONE-round segments compile identically everywhere: a sharded run chained
  from k=1 segments is FULLY bitwise vs the single-device k=1 chain — the
  foundation of cross-device-count resume (test_checkpoint_resume.py);
* same-mesh same-cadence resume is fully bitwise at any segment length;
* uneven batches pad by repeating the last cell, pads dropped on return;
* silo_reduce="psum" row-shards the memory panel (numerically equal,
  not bitwise — the partial-tensordot + psum reduction-order contract).
"""
import numpy as np
import pytest

import jax

from repro.core.availability_device import make_process
from repro.core.sampler_device import make_sampler_process
from repro.fed.aggregator_device import make_aggregator_process
from repro.fed.models import logistic_regression
from repro.fed.scan_engine import ScanConfig, ScanEngine, oracle_h

pytestmark = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs 8 devices: export "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8 before jax "
           "initializes (the CI shard job does)")

SCENARIOS = ("GE", "CLUSTER", "DRIFT", "DEADLINE")
SAMPLER_FAMILIES = ("uniform", "md", "fedgs", "poc")
AGG_FAMILIES = ("fedavg", "fedavgm", "fedadam", "memory")


def _cfg(rounds=6, m=4, **kw):
    return ScanConfig(rounds=rounds, m=m, local_steps=2, batch_size=8,
                      lr=0.1, eval_every=1, max_sweeps=8, sampler="uniform",
                      **kw)


def _mixed_cells(eng, ds, h, rounds, k=8, samplers=SAMPLER_FAMILIES):
    """k cells cycling through scenario x sampler x aggregator families —
    the one-program-many-subsystems batch the mesh must reproduce."""
    cells = []
    for i in range(k):
        proc = make_process(SCENARIOS[i % 4], n_clients=ds.n_clients,
                            data_sizes=ds.sizes,
                            label_sets=ds.label_sets(),
                            num_labels=ds.num_classes, rounds=rounds,
                            seed=7 + i)
        cells.append(eng.cell(
            seed=i, process=proc, h=h, avail_seed=40 + i,
            sampler_process=make_sampler_process(
                samplers[(i + i // 4) % len(samplers)], alpha=1.0),
            aggregator_process=make_aggregator_process(
                AGG_FAMILIES[(i // 2) % 4])))
    return cells


def _assert_decisions_equal(a, b, msg=""):
    """The bitwise tier: selections, pad masks, counts and the count-derived
    fairness metrics (and val_acc, which empirically never flips)."""
    np.testing.assert_array_equal(a.sel, b.sel, err_msg=msg)
    np.testing.assert_array_equal(a.valid, b.valid, err_msg=msg)
    np.testing.assert_array_equal(a.counts, b.counts, err_msg=msg)
    np.testing.assert_array_equal(a.gini, b.gini, err_msg=msg)
    np.testing.assert_array_equal(a.count_var, b.count_var, err_msg=msg)
    np.testing.assert_array_equal(a.val_acc, b.val_acc, err_msg=msg)


def _assert_bitwise(a, b, msg=""):
    _assert_decisions_equal(a, b, msg)
    np.testing.assert_array_equal(a.val_loss, b.val_loss, err_msg=msg)


def test_sharded_mixed_batch_matches_single_device(synthetic_ds):
    """(8,) cells-axis mesh, 8 mixed-family cells: decisions bitwise,
    val_loss to 2e-6 vs the single-device batched program."""
    ds = synthetic_ds
    h = oracle_h(ds.opt_params)
    rounds = 6
    single = ScanEngine(ds, logistic_regression(), _cfg(rounds))
    shard = ScanEngine(ds, logistic_regression(), _cfg(rounds, mesh=(8,)))
    cells = _mixed_cells(single, ds, h, rounds)
    ref = single.run_batch(cells)
    got = shard.run_batch(cells)
    assert len(got) == len(ref) == 8
    for i, (r, g) in enumerate(zip(ref, got)):
        _assert_decisions_equal(r, g, msg=f"cell {i}")
        np.testing.assert_allclose(g.val_loss, r.val_loss, atol=2e-6)


def test_sharded_single_round_segments_fully_bitwise(synthetic_ds, tmp_path):
    """ckpt_every=1 on the mesh == ckpt_every=1 single-device, EVERY leaf
    bitwise: one-round scan segments compile identically across device
    counts (multi-round scans do NOT — XLA fuses the while-body per SPMD
    partition count and scan length), and the per-round fold_in(key, t)
    streams make them chain exactly — the property that makes
    cross-device-count resume exact (test_checkpoint_resume.py).  The
    heavyweight in-step sampler programs (PoC's d-candidate loss probe,
    FedGS's Eq. 16 solve) can tip SPMD fusion even inside a one-round
    program (decisions still bitwise, evals to 2e-6 — covered by the
    mixed-batch test above), so the full-bitwise claim is asserted over
    the Gumbel-only sampler families x ALL aggregator/scenario families —
    the domain the cross-device resume contract targets."""
    ds = synthetic_ds
    h = oracle_h(ds.opt_params)
    rounds = 5
    single = ScanEngine(ds, logistic_regression(), _cfg(rounds))
    shard = ScanEngine(ds, logistic_regression(), _cfg(rounds, mesh=(8,)))
    cells = _mixed_cells(single, ds, h, rounds,
                         samplers=("uniform", "md"))
    ref = single.run_batch(cells, ckpt_path=str(tmp_path / "ref"),
                           ckpt_every=1)
    got = shard.run_batch(cells, ckpt_path=str(tmp_path / "ck"),
                          ckpt_every=1)
    for i, (r, g) in enumerate(zip(ref, got)):
        _assert_bitwise(r, g, msg=f"cell {i}")


def test_cells_by_silo_mesh_matches_single_device(synthetic_ds):
    """(2, 4) mesh: the silo axis chunks the vmap'd local-training client
    axis (each silo trains ceil(M/4) clients, all_gather reassembles —
    incl. the M=6 % 4 != 0 pad path); decisions stay bitwise."""
    ds = synthetic_ds
    rounds, m = 6, 6
    single = ScanEngine(ds, logistic_regression(), _cfg(rounds, m))
    shard = ScanEngine(ds, logistic_regression(),
                       _cfg(rounds, m, mesh=(2, 4)))
    cells = [single.cell(
        seed=s, process=make_process("GE", n_clients=ds.n_clients,
                                     data_sizes=ds.sizes, rounds=rounds,
                                     seed=3 + s),
        avail_seed=50 + s,
        aggregator_process=make_aggregator_process(
            ("fedavgm", "memory")[s % 2]))
        for s in range(2)]
    ref = single.run_batch(cells)
    got = shard.run_batch(cells)
    for i, (r, g) in enumerate(zip(ref, got)):
        _assert_decisions_equal(r, g, msg=f"cell {i}")
        np.testing.assert_allclose(g.val_loss, r.val_loss, atol=2e-6)


def test_psum_panel_sharding_matches_gather():
    """silo_reduce="psum" row-shards the (N, P) update-memory panel over
    the silo axis and turns the staleness reduction into partial
    tensordots + psum — numerically equal to the replicated-panel gather
    program (reduction order differs, so allclose not bitwise), with
    identical sampled sets."""
    from repro.data.synthetic import make_synthetic
    ds = make_synthetic(n_clients=16, alpha=0.5, beta=0.5, seed=0)
    rounds = 6
    cells_of = lambda eng: [eng.cell(        # noqa: E731
        seed=s, process=make_process("GE", n_clients=16,
                                     data_sizes=ds.sizes, rounds=rounds,
                                     seed=5 + s),
        avail_seed=60 + s,
        aggregator_process=make_aggregator_process("memory"))
        for s in range(2)]
    ref_eng = ScanEngine(ds, logistic_regression(),
                         _cfg(rounds, mesh=(2, 4), silo_reduce="gather"))
    psum_eng = ScanEngine(ds, logistic_regression(),
                          _cfg(rounds, mesh=(2, 4), silo_reduce="psum"))
    ref = ref_eng.run_batch(cells_of(ref_eng))
    got = psum_eng.run_batch(cells_of(psum_eng))
    for i, (r, g) in enumerate(zip(ref, got)):
        np.testing.assert_array_equal(g.sel, r.sel, err_msg=f"cell {i}")
        np.testing.assert_array_equal(g.counts, r.counts)
        np.testing.assert_allclose(g.val_loss, r.val_loss, atol=1e-5)


def test_psum_requires_divisible_clients(synthetic_ds):
    """N=30 does not divide silo=4: the psum variant refuses loudly."""
    ds = synthetic_ds
    eng = ScanEngine(ds, logistic_regression(),
                     _cfg(4, mesh=(2, 4), silo_reduce="psum"))
    cells = [eng.cell(seed=0,
                      process=make_process("GE", n_clients=ds.n_clients,
                                           data_sizes=ds.sizes, rounds=4),
                      aggregator_process=make_aggregator_process("memory"))
             for _ in range(2)]
    with pytest.raises(ValueError, match="divide"):
        eng.run_batch(cells)


def test_uneven_cell_batch_pads_and_drops(synthetic_ds):
    """5 cells on an 8-wide cells axis: the batch pads by repeating the
    last cell; exactly the 5 real trajectories come back, decision-bitwise
    with the single-device run of the same 5 cells."""
    ds = synthetic_ds
    h = oracle_h(ds.opt_params)
    rounds = 5
    single = ScanEngine(ds, logistic_regression(), _cfg(rounds))
    shard = ScanEngine(ds, logistic_regression(), _cfg(rounds, mesh=(8,)))
    cells = _mixed_cells(single, ds, h, rounds, k=5)
    ref = single.run_batch(cells)
    got = shard.run_batch(cells)
    assert len(got) == 5
    for i, (r, g) in enumerate(zip(ref, got)):
        _assert_decisions_equal(r, g, msg=f"cell {i}")
        np.testing.assert_allclose(g.val_loss, r.val_loss, atol=2e-6)


def test_same_mesh_segment_and_resume_bitwise(synthetic_ds, tmp_path):
    """On ONE mesh, a mid-run resume replays the identical per-round
    programs (same segment lengths, same shards): every leaf bitwise vs
    the uninterrupted segmented run; decisions bitwise and evals to 2e-6
    vs the fused (no-checkpoint) program."""
    ds = synthetic_ds
    h = oracle_h(ds.opt_params)
    rounds = 6
    eng = ScanEngine(ds, logistic_regression(), _cfg(rounds, mesh=(8,)))
    cells = _mixed_cells(eng, ds, h, rounds)
    fused = eng.run_batch(cells)
    ck = str(tmp_path / "ck")
    seg = eng.run_batch(cells, ckpt_path=ck, ckpt_every=3)
    # the file on disk is the mid-run (t0=3) checkpoint — resume replays
    # the tail on the same mesh at the same cadence
    res = eng.run_batch(cells, ckpt_path=ck, resume=True, ckpt_every=3)
    for i in range(len(cells)):
        _assert_bitwise(seg[i], res[i], msg=f"resume cell {i}")
        np.testing.assert_array_equal(fused[i].sel, seg[i].sel,
                                      err_msg=f"fused cell {i}")
        np.testing.assert_array_equal(fused[i].counts, seg[i].counts)
        np.testing.assert_allclose(seg[i].val_loss, fused[i].val_loss,
                                   atol=2e-6)

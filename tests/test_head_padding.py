"""Head padding for TP alignment (configs.base.pad_heads) must be an EXACT
function-preserving weight embedding."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, pad_heads
from repro.configs.registry import get_config
from repro.models import lm


def _cfg(hq, hkv):
    return ArchConfig(
        name="t", family="dense", source="test", n_layers=2, d_model=64,
        n_heads=hq, n_kv_heads=hkv, head_dim=16, d_ff=96, vocab_size=128,
        dtype="float32")


@pytest.mark.parametrize("hq,hkv,mult", [(3, 1, 4), (9, 3, 16), (5, 5, 8),
                                         (25, 5, 16)])
def test_padded_model_exact(rng, hq, hkv, mult):
    cfg = _cfg(hq, hkv)
    cfg_p = pad_heads(cfg, mult)
    assert cfg_p.n_heads % mult == 0
    assert cfg_p.n_heads % cfg_p.n_kv_heads == 0
    assert cfg_p.n_heads // cfg_p.n_kv_heads >= hq // hkv

    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    params_p = lm.embed_params_padded(params, cfg, cfg_p)

    batch = {"tokens": jnp.asarray(rng.integers(0, 128, (2, 12)), jnp.int32),
             "labels": jnp.asarray(rng.integers(0, 128, (2, 12)), jnp.int32)}
    l0 = float(lm.train_loss(params, cfg, batch, remat=False))
    l1 = float(lm.train_loss(params_p, cfg_p, batch, remat=False))
    assert l0 == pytest.approx(l1, rel=1e-5)

    lg0, _ = lm.prefill(params, cfg, {"tokens": batch["tokens"]})
    lg1, _ = lm.prefill(params_p, cfg_p, {"tokens": batch["tokens"]})
    np.testing.assert_allclose(np.asarray(lg0), np.asarray(lg1),
                               atol=1e-4, rtol=1e-4)


def test_pad_heads_noop_when_aligned():
    cfg = get_config("olmoe-1b-7b")        # 16 heads, kv 16
    assert pad_heads(cfg, 16) is cfg

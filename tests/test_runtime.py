"""The zero-copy engine runtime (DESIGN.md §15).

Pinned claims:

* donated + pipelined segmented ``run_batch`` is bitwise equal to the
  legacy blocking path (``donate_carry=False, async_pipeline=False``) at
  ``ckpt_every=1``, across every stateful aggregator x availability
  family — and decisions-bitwise vs the fused single program (§13);
* use-after-donation is a LOUD error: a consumed ``CarryHandle`` raises on
  any access, at both the unit level and through ``ScanEngine.run_segment``;
* the ``ProgramCache`` LRU counts hits/misses/evictions/compiles and
  bounds the program set (the old ``_jits`` dict grew unboundedly);
* the ``AsyncCheckpointWriter`` preserves submission order and re-raises
  worker errors instead of dropping them;
* ``ScanConfig.compile_cache_dir`` populates a persistent XLA cache and
  changes no results;
* ``run_batch_stream`` yields segments incrementally, and the
  ``SimService`` front-end streams per-request updates that reassemble to
  the exact ``run_batch`` histories;
* (slow, 8 devices) the N=10^5 datacenter cell LOWERS on a (1, 8) silo
  mesh with the memory panel sharded to N/8 rows — compile-only, the
  (N, N) graph never materializes (the PR 6 ROADMAP leftover).
"""
import os

import numpy as np
import pytest

import jax

from repro.core.availability_device import make_process
from repro.fed.aggregator_device import make_aggregator_process
from repro.fed.models import logistic_regression
from repro.fed.runtime import (
    AsyncCheckpointWriter, CarryHandle, ProgramCache,
)
from repro.fed.scan_engine import ScanConfig, ScanEngine

needs8 = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs 8 devices: export "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8 before jax "
           "initializes (the CI shard job does)")

HIST_FIELDS = ("sel", "valid", "counts", "gini", "count_var", "val_loss",
               "val_acc")
COMBOS = [("fedavgm", "GE"), ("fedadam", "CLUSTER"),
          ("fedprox_w", "DRIFT"), ("memory", "DEADLINE")]


@pytest.fixture(scope="module")
def ds16():
    from repro.data.synthetic import make_synthetic
    return make_synthetic(n_clients=16, alpha=0.5, beta=0.5, seed=0)


def _proc(name, ds, rounds, seed=7):
    return make_process(name, n_clients=ds.n_clients, data_sizes=ds.sizes,
                        label_sets=ds.label_sets(),
                        num_labels=ds.num_classes, rounds=rounds, seed=seed)


def _cfg(rounds, **kw):
    return ScanConfig(rounds=rounds, m=4, local_steps=2, batch_size=8,
                      lr=0.1, eval_every=1, sampler="uniform", **kw)


def _cells(eng, ds, rounds, agg, scenario, b=2):
    return [eng.cell(seed=s, process=_proc(scenario, ds, rounds, 3 + s),
                     avail_seed=70 + s,
                     aggregator_process=make_aggregator_process(agg))
            for s in range(b)]


# ------------------------------------------------------------ ProgramCache
class TestProgramCache:
    def test_lru_eviction_and_counters(self):
        pc = ProgramCache(maxsize=2)
        built = []

        def mk(tag):
            def build():
                built.append(tag)
                return lambda: tag
            return build

        assert pc.get("a", mk("a"))() == "a"
        assert pc.get("b", mk("b"))() == "b"
        assert pc.get("a", mk("a"))() == "a"       # hit, refreshes a
        assert pc.get("c", mk("c"))() == "c"       # evicts b (LRU)
        assert "b" not in pc and "a" in pc and "c" in pc
        pc.get("b", mk("b"))                        # rebuild b
        st = pc.stats()
        assert built == ["a", "b", "c", "b"]
        assert (st["hits"], st["misses"], st["evictions"]) == (1, 4, 2)
        assert st["size"] == len(pc) == 2

    def test_maxsize_validated(self):
        with pytest.raises(ValueError):
            ProgramCache(maxsize=0)
        with pytest.raises(ValueError):
            ScanConfig(program_cache_size=0)

    def test_compile_counter_on_jitted_fn(self):
        pc = ProgramCache()
        f = pc.get("k", lambda: jax.jit(lambda x: x * 2))
        assert pc.stats()["compiles"] == 0
        f(np.float32(3.0))                          # first call compiles
        assert pc.stats()["compiles"] == 1
        assert pc.stats()["compile_ms"] > 0
        f(np.float32(4.0))                          # steady state
        assert pc.stats()["compiles"] == 1


# ------------------------------------------------------------- CarryHandle
class TestCarryHandle:
    def test_consume_once(self):
        h = CarryHandle({"x": 1})
        assert h.alive and h.tree == {"x": 1}
        assert h.consume() == {"x": 1}
        assert not h.alive
        with pytest.raises(RuntimeError, match="use-after-donation"):
            _ = h.tree
        with pytest.raises(RuntimeError, match="use-after-donation"):
            h.consume()


# --------------------------------------------------- AsyncCheckpointWriter
class TestAsyncCheckpointWriter:
    def test_ordered_writes(self):
        seen = []
        with AsyncCheckpointWriter() as w:
            for i in range(5):
                w.submit(seen.append, i)
            w.flush()
        assert seen == [0, 1, 2, 3, 4]

    def test_error_surfaces_on_close(self):
        w = AsyncCheckpointWriter()
        w.submit(lambda: 1 / 0)
        with pytest.raises(RuntimeError, match="checkpoint write failed"):
            w.close()

    def test_error_is_fail_fast(self):
        seen = []
        w = AsyncCheckpointWriter()
        w.submit(lambda: 1 / 0)
        with pytest.raises(RuntimeError, match="checkpoint write failed"):
            w.flush()
        # after the first error the worker is still alive for close()
        w.submit(seen.append, 1)
        w.close()
        assert seen == [1]


# -------------------------------------------------- engine runtime surface
def test_run_segment_use_after_donation(ds16):
    rounds = 4
    eng = ScanEngine(ds16, logistic_regression(), _cfg(rounds))
    cells = _cells(eng, ds16, rounds, "memory", "GE")
    h0 = eng.init_carry(cells)
    h1, traj = eng.run_segment(cells, h0, 0, 2)
    assert not h0.alive and h1.alive
    with pytest.raises(RuntimeError, match="use-after-donation"):
        eng.run_segment(cells, h0, 2, 2)
    # the returned handle chains on fine
    h2, _ = eng.run_segment(cells, h1, 2, 2)
    assert h2.alive
    # jax-level donation backs the handle: on backends that implement
    # donation the consumed device buffers really are gone
    if eng.cfg.donate_carry:
        assert not h1.alive


def test_runtime_stats_counters(ds16):
    rounds = 4
    eng = ScanEngine(ds16, logistic_regression(), _cfg(rounds))
    cells = _cells(eng, ds16, rounds, "fedavg", "GE")
    eng.run_batch(cells)
    st = eng.runtime_stats()
    assert st["misses"] == st["size"] == 1 and st["compiles"] == 1
    assert st["compile_ms"] > 0
    eng.run_batch(cells)                       # cache hit, no new compile
    st = eng.runtime_stats()
    assert st["hits"] == 1 and st["compiles"] == 1


@pytest.mark.parametrize("agg,scenario", COMBOS)
def test_donated_pipelined_bitwise_vs_legacy(ds16, tmp_path, agg, scenario):
    """The tentpole parity claim: donated + pipelined segmented run_batch
    at ckpt_every=1 is bitwise equal to the legacy blocking non-donated
    path, for every stateful aggregator x availability family — and
    decisions-bitwise (evals to 2e-6) vs the fused single program."""
    ds = ds16
    rounds = 5
    new = ScanEngine(ds, logistic_regression(), _cfg(rounds))
    assert new.cfg.donate_carry and new.cfg.async_pipeline   # the defaults
    legacy = ScanEngine(ds, logistic_regression(),
                        _cfg(rounds, donate_carry=False,
                             async_pipeline=False))
    fused = new.run_batch(_cells(new, ds, rounds, agg, scenario))
    got = new.run_batch(_cells(new, ds, rounds, agg, scenario),
                        ckpt_path=str(tmp_path / "a"), ckpt_every=1)
    ref = legacy.run_batch(_cells(legacy, ds, rounds, agg, scenario),
                           ckpt_path=str(tmp_path / "b"), ckpt_every=1)
    for i in range(2):
        for f in HIST_FIELDS:
            np.testing.assert_array_equal(
                getattr(got[i], f), getattr(ref[i], f),
                err_msg=f"{agg}/{scenario} cell {i}: {f}")
        for f in ("sel", "valid", "counts"):
            np.testing.assert_array_equal(
                getattr(got[i], f), getattr(fused[i], f),
                err_msg=f"{agg}/{scenario} fused cell {i}: {f}")
        np.testing.assert_allclose(got[i].val_loss, fused[i].val_loss,
                                   atol=2e-6)


def test_stream_yields_segments_incrementally(ds16):
    ds = ds16
    rounds = 6
    eng = ScanEngine(ds, logistic_regression(), _cfg(rounds))
    cells = _cells(eng, ds, rounds, "fedavgm", "GE")
    segs = list(eng.run_batch_stream(cells, ckpt_every=4))
    assert [(t0, k) for t0, k, _ in segs] == [(0, 4), (4, 2)]
    for _, k, traj in segs:
        assert traj["sel"].shape[:2] == (len(cells), k)
        assert isinstance(traj["sel"], np.ndarray)
    # stitched stream == plain segmented run, and final state is exposed
    assert eng.params is not None and eng.final_counts.shape == (
        len(cells), ds.n_clients)
    whole = eng.run_batch(cells, ckpt_every=4)
    sel = np.concatenate([t["sel"] for _, _, t in segs], axis=1)
    np.testing.assert_array_equal(sel[0], whole[0].sel)


def test_ckpt_every_without_path_segments(ds16):
    """ckpt_every with NO ckpt_path streams in segments (it used to run
    fused silently) — decisions stay bitwise vs fused."""
    ds = ds16
    rounds = 6
    eng = ScanEngine(ds, logistic_regression(), _cfg(rounds))
    fused = eng.run_batch(_cells(eng, ds, rounds, "fedadam", "CLUSTER"))
    seg = eng.run_batch(_cells(eng, ds, rounds, "fedadam", "CLUSTER"),
                        ckpt_every=2)
    for f in ("sel", "valid", "counts"):
        np.testing.assert_array_equal(getattr(seg[0], f),
                                      getattr(fused[0], f), err_msg=f)


def test_compile_cache_dir_populates_and_preserves_results(ds16, tmp_path):
    ds = ds16
    rounds = 4
    cache = str(tmp_path / "xla-cache")
    plain = ScanEngine(ds, logistic_regression(), _cfg(rounds))
    cached = ScanEngine(ds, logistic_regression(),
                        _cfg(rounds, compile_cache_dir=cache))
    a = plain.run_batch(_cells(plain, ds, rounds, "fedavg", "GE"))
    b = cached.run_batch(_cells(cached, ds, rounds, "fedavg", "GE"))
    for f in HIST_FIELDS:
        np.testing.assert_array_equal(getattr(a[0], f), getattr(b[0], f),
                                      err_msg=f)
    assert os.path.isdir(cache) and os.listdir(cache), \
        "persistent compile cache left empty"


# --------------------------------------------------------------- SimService
def test_sim_service_streams_and_matches_run_batch(ds16):
    from repro.launch.serve import SimService
    ds = ds16
    rounds = 6
    svc = SimService(ScanEngine(ds, logistic_regression(), _cfg(rounds)))
    ref_eng = ScanEngine(ds, logistic_regression(), _cfg(rounds))
    kw = lambda i: dict(                                      # noqa: E731
        seed=i, avail_seed=70 + i,
        process=_proc(("GE", "DEADLINE")[i % 2], ds, rounds, 3 + i),
        aggregator_process=make_aggregator_process(
            ("memory", "fedavgm")[i % 2]))
    tickets = [svc.submit(**kw(i)) for i in range(2)]
    updates = list(svc.drain(segment=3))
    # one update per (request, segment), tagged with the right windows
    assert [(u.request, u.t0, u.rounds) for u in updates] == \
        [(0, 0, 3), (1, 0, 3), (0, 3, 3), (1, 3, 3)]
    ref = ref_eng.run_batch([ref_eng.cell(**kw(i)) for i in range(2)],
                            ckpt_every=3)
    for i, t in enumerate(tickets):
        hist = svc.histories[t]
        for f in HIST_FIELDS:
            np.testing.assert_array_equal(getattr(hist, f),
                                          getattr(ref[i], f), err_msg=f)
        # streamed slices reassemble to the final history
        vl = np.concatenate([u.val_loss for u in updates
                             if u.request == t])
        np.testing.assert_array_equal(vl, hist.val_loss)


def test_serve_fedsim_entry_runs(capsys):
    from repro.launch import serve
    hists = serve.main(["--fedsim", "--cells", "2", "--rounds", "4",
                        "--segment", "2", "--n-clients", "12"])
    assert len(hists) == 2 and hists[0].val_loss.shape == (4,)
    out = capsys.readouterr().out
    assert "fedsim: 2 cells x 4 rounds" in out


# -------------------------------------------- datacenter compile-only dry-run
@pytest.mark.slow
@needs8
def test_datacenter_cell_dryrun_lowering():
    """The N=10^5 silo-axis proof (compile-only): the cell lowers fully
    abstract — the (N, N) graph H (40 GB) never materializes — and the
    scan carry stays silo-sharded: memory panel (N/8, P) rows per device,
    total per-cell carry (excl H) under 4 MB.  A regression that grows the
    carry (e.g. the panel going global again) fails these pins."""
    import math

    from repro.launch.fedsim import datacenter_cell_dryrun

    n = 100_000
    lowered, carry = datacenter_cell_dryrun(n_clients=n, mesh=(1, 8))
    assert carry["agg"]["mem"].shape == (1, n // 8, 36)     # silo-sharded
    assert carry["agg"]["tau"].shape == (1, n)              # tau stays global
    assert carry["h"].shape == (1, n, n)                    # abstract only
    leaves = jax.tree_util.tree_leaves(carry)
    bytes_excl_h = sum(math.prod(x.shape) * x.dtype.itemsize
                       for x in leaves) - n * n * 4
    assert bytes_excl_h < 4_000_000, f"carry grew: {bytes_excl_h} bytes"
    hlo = lowered.as_text()
    assert len(hlo) > 0 and "100000" in hlo

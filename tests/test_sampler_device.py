"""The device-native sampler subsystem (core/sampler_device.py):

* solver backend parity — ``fedgs_solve(backend="pallas")`` selects the
  BIT-identical set as the ref path at N ∈ {7, 100, 130, 1024}, including
  the all-unavailable, |A| < m, exact-tie and NaN-poisoned edge cases, and
  the fused pallas Q build inside ``fedgs_select`` preserves that parity;
* host face — ``FedGSSampler.sample`` equals the device ``fedgs_select``
  given the identical (normalized) H on BOTH backends, and the baseline
  host classes return the device selects' sets;
* the sampler switch — ``make_sampler_step`` reproduces each family's
  direct select bit for bit from the same key, and ``SamplerProcess``
  params/state follow the protocol;
* distributional — ``gumbel_topk_select`` inclusion frequencies match the
  MD without-replacement weights and ``uniform_select`` is uniform
  (χ² tolerance), keys drawn from a ``fold_in`` stream per the DESIGN
  assumption-log seed rules;
* empty availability through the device path returns the empty selection.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.sampler_device import (
    FAMILIES, FedGSProcess, MDProcess, PoCProcess, SamplerProcess,
    UniformProcess, _fedgs_select, _fedgs_solve, fedgs_select,
    gumbel_topk_select, log_size_weights, make_sampler_process,
    make_sampler_step, md_select, select_k, uniform_select,
)


def _rand_q(rng, n):
    q = rng.random((n, n)).astype(np.float32)
    q = 0.5 * (q + q.T)
    q -= np.diag(rng.normal(size=n).astype(np.float32))
    return q


def _solve(q, avail, m, backend, sweeps=16):
    return np.asarray(_fedgs_solve(jnp.asarray(q, jnp.float32),
                                   jnp.asarray(avail), m=m,
                                   max_sweeps=sweeps, backend=backend))


# ------------------------------------------------------ solver backend parity
@pytest.mark.parametrize("n", [7, 100, 130,
                               pytest.param(1024, marks=pytest.mark.slow)])
def test_solver_backend_parity_random(rng, n):
    """pallas ≡ ref selected sets, bit for bit, at non-tile-multiple N."""
    q = _rand_q(rng, n)
    avail = rng.random(n) < 0.7
    avail[0] = True
    m = min(max(2, n // 8), int(avail.sum()))
    s_ref = _solve(q, avail, m, "ref")
    s_pal = _solve(q, avail, m, "pallas")
    np.testing.assert_array_equal(s_ref, s_pal)
    sel = np.flatnonzero(s_pal)
    assert len(sel) == m and np.all(avail[sel])


def test_solver_parity_all_unavailable(rng):
    """Empty A_t: both backends return the empty selection (greedy adds
    nothing, the sweep never fires)."""
    q = _rand_q(rng, 33)
    avail = np.zeros(33, bool)
    for m in (0, 4):
        s_ref = _solve(q, avail, m, "ref")
        s_pal = _solve(q, avail, m, "pallas")
        np.testing.assert_array_equal(s_ref, s_pal)
        assert s_pal.sum() == 0


def test_solver_parity_fewer_available_than_m(rng):
    """|A| < m: both backends select exactly A."""
    n = 40
    q = _rand_q(rng, n)
    avail = np.zeros(n, bool)
    avail[[3, 17, 29]] = True
    m = min(7, int(avail.sum()))          # the solver budget min(M, |A|)
    s_ref = _solve(q, avail, m, "ref")
    s_pal = _solve(q, avail, m, "pallas")
    np.testing.assert_array_equal(s_ref, s_pal)
    assert set(np.flatnonzero(s_pal)) == {3, 17, 29}


def test_solver_parity_tied_gains(rng):
    """Integer-valued Q forces EXACT float ties in both the greedy argmax
    and the swap sweep — the blocked kernels must reproduce jnp.argmax's
    first-max tie-break (panel-row-major flat order)."""
    n = 52
    q = rng.integers(0, 3, (n, n)).astype(np.float32)
    q = 0.5 * (q + q.T)
    avail = np.ones(n, bool)
    for m in (3, 9):
        np.testing.assert_array_equal(_solve(q, avail, m, "ref"),
                                      _solve(q, avail, m, "pallas"))


def test_solver_parity_nan_guard(rng):
    """NaN-poisoned Q rows: both backends map NaN gains to the −1e18
    sentinel (DESIGN assumption log #13), never select a NaN-scored
    client pair, and agree bit for bit."""
    n = 24
    q = _rand_q(rng, n)
    q[5, :] = np.nan
    q[:, 5] = np.nan
    avail = np.ones(n, bool)
    s_ref = _solve(q, avail, 6, "ref")
    s_pal = _solve(q, avail, 6, "pallas")
    np.testing.assert_array_equal(s_ref, s_pal)
    assert s_pal.sum() == 6


def test_fedgs_select_fused_build_parity(rng):
    """fedgs_select(backend="pallas") — fused Q build + tiled solve — is
    bit-identical to the ref construction end to end."""
    for n in (7, 60, 130):
        h = rng.random((n, n)).astype(np.float32)
        h = 0.5 * (h + h.T)
        np.fill_diagonal(h, 0)
        counts = rng.integers(0, 6, n).astype(np.float32)
        avail = rng.random(n) < 0.8
        avail[0] = True
        m = min(5, int(avail.sum()))
        args = (jnp.asarray(h), jnp.asarray(counts), jnp.asarray(avail),
                jnp.float32(1.3))
        s_ref = np.asarray(_fedgs_select(*args, m=m, max_sweeps=12,
                                         m_target=5))
        s_pal = np.asarray(_fedgs_select(*args, m=m, max_sweeps=12,
                                         m_target=5, backend="pallas"))
        np.testing.assert_array_equal(s_ref, s_pal, err_msg=f"n={n}")


# -------------------------------------------------------------- host face
@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_host_face_fedgs_equals_device_select(rng, backend):
    """FedGSSampler.sample ≡ the device fedgs_select given identical Q
    inputs (same normalized H, counts, availability) on both backends."""
    from repro.core.graph_device import cap_and_normalize
    from repro.core.sampler import FedGSSampler
    n, m = 23, 5
    h = rng.random((n, n)) * 3
    h = 0.5 * (h + h.T)
    np.fill_diagonal(h, 0)
    counts = rng.integers(0, 4, n).astype(float)
    avail = rng.random(n) < 0.7
    avail[1] = True
    sampler = FedGSSampler(alpha=1.5, max_sweeps=16, solver_backend=backend)
    sampler.set_graph(h)
    sel = sampler.sample(avail=avail, m=m, rng=rng, counts=counts)
    hn = cap_and_normalize(jnp.asarray(h, jnp.float32))
    m_eff = min(m, int(avail.sum()))
    s = np.asarray(fedgs_select(hn, jnp.asarray(counts, jnp.float32),
                                jnp.asarray(avail), jnp.float32(1.5),
                                m=m_eff, max_sweeps=16, m_target=m,
                                backend=backend))
    np.testing.assert_array_equal(sel, np.flatnonzero(s))


def test_host_baselines_return_device_sets(rng):
    """Uniform/MD host faces are thin wrappers: same key -> same set as the
    device selects (the duplicated numpy choice logic is gone)."""
    from repro.core.sampler import MDSampler, UniformSampler
    n, m = 18, 4
    avail = np.zeros(n, bool)
    avail[2:14] = True
    sizes = rng.random(n) * 10
    for sampler, direct in (
            (UniformSampler(),
             lambda k: uniform_select(k, jnp.asarray(avail), m)),
            (MDSampler(),
             lambda k: md_select(k, jnp.asarray(sizes, jnp.float32),
                                 jnp.asarray(avail), m))):
        host_rng = np.random.default_rng(7)
        sel = sampler.sample(avail=avail, m=m, rng=host_rng,
                             data_sizes=sizes)
        key_rng = np.random.default_rng(7)
        key = jax.random.PRNGKey(int(key_rng.integers(2 ** 31 - 1)))
        np.testing.assert_array_equal(
            sel, np.flatnonzero(np.asarray(direct(key))))


# ---------------------------------------------------------- the switch step
def _step_fixture(rng, n=20, m=5, d=10):
    h = rng.random((n, n)).astype(np.float32)
    h = 0.5 * (h + h.T)
    np.fill_diagonal(h, 0)
    sizes = jnp.asarray(rng.random(n) * 9 + 1, jnp.float32)
    losses = jnp.asarray(rng.random(n), jnp.float32)
    inputs = {"h": jnp.asarray(h / h.max()),
              "counts": jnp.asarray(rng.integers(0, 3, n), jnp.float32),
              "params": (), "losses": losses}
    avail = jnp.asarray(rng.random(n) < 0.8).at[0].set(True)
    step = make_sampler_step(n, m, max_sweeps=8, d_cand=d)
    return step, inputs, avail, sizes, losses


def test_sampler_step_matches_direct_selects(rng):
    """Each switch branch reproduces its family's direct select bit for bit
    from the same key (the switch is dispatch, not reimplementation)."""
    step, inputs, avail, sizes, losses = _step_fixture(rng)
    n, m, d = 20, 5, 10
    key = jax.random.PRNGKey(11)
    state = {}

    def run(proc, data_sizes=None):
        sp = proc.params(data_sizes=np.asarray(data_sizes)
                         if data_sizes is not None else None, n_clients=n)
        s, st = step(sp, state, key, inputs, avail, 0)
        assert st == {}
        return np.asarray(s)

    np.testing.assert_array_equal(
        run(UniformProcess()), np.asarray(uniform_select(key, avail, m)))
    np.testing.assert_array_equal(
        run(MDProcess(), sizes), np.asarray(md_select(key, sizes, avail, m)))
    np.testing.assert_array_equal(
        run(FedGSProcess(alpha=1.0)),
        np.asarray(fedgs_select(inputs["h"], inputs["counts"], avail,
                                jnp.float32(1.0), m=m, max_sweeps=8)))
    # PoC: candidate draw on key, keep top-m of inputs["losses"][cand]
    cand = gumbel_topk_select(key, log_size_weights(sizes), avail, d)
    cidx, cvalid = select_k(cand, d)
    _, kk = jax.lax.top_k(jnp.where(cvalid, losses[cidx], -jnp.inf), m)
    want = np.asarray(jnp.zeros((n,), bool).at[cidx[kk]].set(cvalid[kk]))
    np.testing.assert_array_equal(run(PoCProcess(), sizes), want)


def test_sampler_step_traces_under_jit_and_vmap(rng):
    """One switch program serves a BATCH of heterogeneous families."""
    step, inputs, avail, sizes, _ = _step_fixture(rng)
    n = 20
    sps = [make_sampler_process(f, alpha=0.5).params(
        data_sizes=np.asarray(sizes)) for f in FAMILIES]
    batched = jax.tree_util.tree_map(lambda *x: jnp.stack(x), *sps)
    keys = jax.random.split(jax.random.PRNGKey(3), len(FAMILIES))

    run = jax.jit(jax.vmap(
        lambda sp, k: step(sp, {}, k, inputs, avail, 0)[0]))
    s_batch = np.asarray(run(batched, keys))
    for i, sp in enumerate(sps):
        s_single, _ = step(sp, {}, keys[i], inputs, avail, 0)
        np.testing.assert_array_equal(s_batch[i], np.asarray(s_single),
                                      err_msg=FAMILIES[i])
        assert s_batch[i].sum() == min(5, int(np.asarray(avail).sum()))


def test_sampler_process_protocol():
    """params/init follow the uniform-pytree protocol; the factory matches
    scan_engine.SAMPLERS; select() is the switch path."""
    n = 9
    sizes = np.arange(1.0, n + 1)
    for name in FAMILIES:
        proc = make_sampler_process(name, alpha=2.0)
        sp = proc.params(data_sizes=sizes)
        assert int(sp["family"]) == FAMILIES.index(name)
        assert sp["log_sizes"].shape == (n,)
        assert proc.init(jax.random.PRNGKey(0)) == {}
    assert float(make_sampler_process("fedgs", alpha=2.0).params(
        n_clients=n)["alpha"]) == 2.0
    with pytest.raises(ValueError):
        make_sampler_process("nope")
    # the convenience select IS the switch path
    proc = UniformProcess()
    avail = jnp.ones(n, bool)
    key = jax.random.PRNGKey(5)
    s, _ = proc.select({}, key, {}, avail, 0, m=3)
    np.testing.assert_array_equal(np.asarray(s),
                                  np.asarray(uniform_select(key, avail, 3)))
    # ... and data_sizes reaches the size-weighted families (an MDProcess
    # select without sizes would silently draw uniformly)
    s, _ = MDProcess().select({}, key, {}, avail, 0, m=3, data_sizes=sizes)
    np.testing.assert_array_equal(
        np.asarray(s),
        np.asarray(md_select(key, jnp.asarray(sizes, jnp.float32),
                             avail, 3)))


# ------------------------------------------------------------ distributional
def _md_inclusion_probs(w: np.ndarray, m: int) -> np.ndarray:
    """Exact inclusion probabilities of a weighted without-replacement draw
    of size m (enumerated over ordered prefixes; feasible for tiny n)."""
    import itertools
    n = len(w)
    p = np.zeros(n)
    for perm in itertools.permutations(range(n), m):
        rem = w.sum()
        prob = 1.0
        for i in perm:
            prob *= w[i] / rem
            rem -= w[i]
        for i in perm:
            p[i] += prob
    return p


def _inclusion_counts(select_fn, n, draws, seed=0):
    """Empirical inclusion counts over ``draws`` keys from the fold_in
    stream (DESIGN assumption-log seed rules: independent per-draw keys
    derive from one base key)."""
    base = jax.random.PRNGKey(seed)
    keys = jax.vmap(lambda i: jax.random.fold_in(base, i))(jnp.arange(draws))
    masks = jax.jit(jax.vmap(select_fn))(keys)
    return np.asarray(masks).sum(0)


def _chi2(obs, expected):
    keep = expected > 0
    return float(((obs[keep] - expected[keep]) ** 2 / expected[keep]).sum())


def test_gumbel_topk_frequencies_match_md_weights():
    """gumbel_topk_select inclusion frequencies match the MD sampler's
    without-replacement weights (χ² over the 5 clients, 3000 draws)."""
    w = np.array([1.0, 2.0, 3.0, 5.0, 9.0])
    n, m, draws = len(w), 2, 3000
    avail = jnp.ones(n, bool)
    lw = log_size_weights(w)
    obs = _inclusion_counts(lambda k: gumbel_topk_select(k, lw, avail, m),
                            n, draws)
    exp = draws * _md_inclusion_probs(w, m)
    assert obs.sum() == draws * m
    assert _chi2(obs, exp) < 20.0, (obs, exp)


def test_uniform_select_is_uniform():
    """uniform_select inclusion is m/|A| on the available set, 0 elsewhere
    (χ² tolerance, 3000 draws)."""
    n, m, draws = 8, 2, 3000
    avail_np = np.zeros(n, bool)
    avail_np[1:7] = True
    avail = jnp.asarray(avail_np)
    obs = _inclusion_counts(lambda k: uniform_select(k, avail, m), n, draws)
    assert obs[~avail_np].sum() == 0
    exp = np.where(avail_np, draws * m / avail_np.sum(), 0.0)
    assert _chi2(obs, exp) < 20.0, (obs, exp)


@pytest.mark.slow
def test_gumbel_topk_md_weights_high_precision():
    """The 30k-draw, tighter-χ² version of the MD frequency test."""
    w = np.array([1.0, 2.0, 3.0, 5.0, 9.0, 20.0])
    n, m, draws = len(w), 3, 30000
    avail = jnp.ones(n, bool)
    lw = log_size_weights(w)
    obs = _inclusion_counts(lambda k: gumbel_topk_select(k, lw, avail, m),
                            n, draws, seed=1)
    exp = draws * _md_inclusion_probs(w, m)
    assert _chi2(obs, exp) < 15.0, (obs, exp)


# -------------------------------------------------------- empty availability
def test_device_selects_empty_availability():
    """All-False A_t through the scan-path selects: every family returns
    the empty selection mask (the engines' force-one floor never feeds
    this, but the device functions must stay total)."""
    n = 11
    avail = jnp.zeros(n, bool)
    key = jax.random.PRNGKey(0)
    assert np.asarray(uniform_select(key, avail, 4)).sum() == 0
    assert np.asarray(md_select(key, jnp.arange(n, dtype=jnp.float32),
                                avail, 4)).sum() == 0
    for backend in ("ref", "pallas"):
        q = jnp.eye(n, dtype=jnp.float32)
        assert np.asarray(_fedgs_solve(q, avail, m=0, max_sweeps=4,
                                       backend=backend)).sum() == 0

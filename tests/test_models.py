"""Model-zoo unit tests: attention path agreement, SSD vs naive recurrence,
MoE dispatch vs per-token reference, RoPE."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.models import attention as A
from repro.models import ffn as F
from repro.models import ssd as S
from repro.models.layers import apply_rope, rope_angles, rms_norm


# ------------------------------------------------------------- attention
@pytest.fixture
def qkv(rng):
    b, s, h, d = 2, 128, 4, 16
    mk = lambda: jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    return mk(), mk(), mk()


def test_chunked_matches_dense(qkv, monkeypatch):
    q, k, v = qkv
    monkeypatch.setattr(A, "KV_CHUNK", 32)
    dense = A.attend_dense(q, k, v, causal=True, window=None)
    chunked = A.attend_chunked_full(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(chunked),
                               atol=2e-5, rtol=2e-5)


def test_windowed_matches_dense(qkv, monkeypatch):
    q, k, v = qkv
    monkeypatch.setattr(A, "Q_CHUNK", 32)
    w = 48
    dense = A.attend_dense(q, k, v, causal=True, window=w)
    windowed = A.attend_windowed(q, k, v, window=w)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(windowed),
                               atol=2e-5, rtol=2e-5)


def test_decode_attend_matches_dense_last_position(qkv):
    q, k, v = qkv
    b, s, h, d = q.shape
    full = A.attend_dense(q, k, v, causal=True, window=None)
    got = A.decode_attend(q[:, -1:], k, v, jnp.asarray(s, jnp.int32), window=None)
    np.testing.assert_allclose(np.asarray(full[:, -1:]), np.asarray(got),
                               atol=2e-5, rtol=2e-5)


def test_decode_attend_window_slices(qkv):
    q, k, v = qkv
    b, s, h, d = q.shape
    w = 32
    full = A.attend_dense(q, k, v, causal=True, window=w)
    got = A.decode_attend(q[:, -1:], k, v, jnp.asarray(s, jnp.int32), window=w)
    np.testing.assert_allclose(np.asarray(full[:, -1:]), np.asarray(got),
                               atol=2e-5, rtol=2e-5)


def test_repeat_kv():
    k = jnp.arange(2 * 3 * 2 * 4).reshape(2, 3, 2, 4).astype(jnp.float32)
    r = A._repeat_kv(k, 3)
    assert r.shape == (2, 3, 6, 4)
    np.testing.assert_array_equal(np.asarray(r[:, :, 0]), np.asarray(r[:, :, 1]))
    np.testing.assert_array_equal(np.asarray(r[:, :, 0]), np.asarray(k[:, :, 0]))


# ------------------------------------------------------------------ rope
def test_rope_preserves_norm(rng):
    x = jnp.asarray(rng.normal(size=(2, 8, 3, 16)), jnp.float32)
    cos, sin = rope_angles(jnp.arange(8), 16, 10000.0)
    y = apply_rope(x, cos, sin)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                               np.linalg.norm(np.asarray(y), axis=-1),
                               rtol=1e-5)


def test_rope_relative_position_property(rng):
    """<rope(q,i), rope(k,j)> depends only on i - j."""
    d = 16
    q = jnp.asarray(rng.normal(size=(1, 1, 1, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 1, 1, d)), jnp.float32)

    def dot_at(i, j):
        ci, si = rope_angles(jnp.asarray([i]), d, 10000.0)
        cj, sj = rope_angles(jnp.asarray([j]), d, 10000.0)
        qi = apply_rope(q, ci, si)
        kj = apply_rope(k, cj, sj)
        return float(jnp.sum(qi * kj))

    assert dot_at(5, 3) == pytest.approx(dot_at(12, 10), abs=1e-4)
    assert dot_at(7, 7) == pytest.approx(dot_at(0, 0), abs=1e-4)


def test_rms_norm_unit_scale(rng):
    x = jnp.asarray(rng.normal(size=(4, 32)) * 7, jnp.float32)
    y = rms_norm(x, jnp.ones(32))
    rms = np.sqrt(np.mean(np.asarray(y) ** 2, -1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-3)


# ------------------------------------------------------------------- ssd
def _naive_ssm(x, dt, Alog, B, C, D):
    """Direct per-step recurrence h_t = exp(dt A) h_{t-1} + dt B x; y = C h + D x."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    Aneg = -np.exp(Alog)
    st = np.zeros((b, h, p, n))
    ys = np.zeros_like(x)
    for t in range(s):
        decay = np.exp(dt[:, t] * Aneg[None])              # (b,h)
        upd = np.einsum("bh,bhp,bn->bhpn", dt[:, t], x[:, t], B[:, t])
        st = st * decay[..., None, None] + upd
        ys[:, t] = np.einsum("bhpn,bn->bhp", st, C[:, t]) + x[:, t] * D[None, :, None]
    return ys, st


@pytest.mark.parametrize("chunk", [4, 8, 32])
def test_ssd_chunked_matches_naive(rng, chunk):
    b, s, h, p, n = 2, 32, 3, 8, 4
    x = rng.normal(size=(b, s, h, p)).astype(np.float32)
    dt = rng.uniform(0.01, 0.2, (b, s, h)).astype(np.float32)
    Alog = rng.uniform(-1, 1, h).astype(np.float32)
    B = rng.normal(size=(b, s, n)).astype(np.float32)
    C = rng.normal(size=(b, s, n)).astype(np.float32)
    D = rng.normal(size=h).astype(np.float32)
    y, st = S.ssd_chunked(jnp.asarray(x), jnp.asarray(dt),
                          -jnp.exp(jnp.asarray(Alog)), jnp.asarray(B),
                          jnp.asarray(C), jnp.asarray(D), chunk=chunk)
    y_ref, st_ref = _naive_ssm(x, dt, Alog, B, C, D)
    np.testing.assert_allclose(np.asarray(y), y_ref, atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(st), st_ref, atol=2e-4, rtol=2e-4)


def test_ssd_decode_continues_prefill(rng):
    """apply_ssd on s steps == apply_ssd on s-1 steps + ssd_decode_step."""
    cfg = SSMConfig(d_state=4, head_dim=8, expand=2, chunk=8, d_conv=4)
    d_model = 16
    key = jax.random.PRNGKey(0)
    params = S.init_ssd(key, d_model, cfg, jnp.float32)
    x = jnp.asarray(rng.normal(size=(2, 17, d_model)), jnp.float32)

    y_full, (st_full, cv_full) = S.apply_ssd(params, x, cfg)
    y_pre, (st, cv) = S.apply_ssd(params, x[:, :-1], cfg)
    y_step, (st2, cv2) = S.ssd_decode_step(params, x[:, -1:], cfg, st, cv)

    np.testing.assert_allclose(np.asarray(y_full[:, -1:]), np.asarray(y_step),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(st_full), np.asarray(st2),
                               atol=2e-4, rtol=2e-4)


# ------------------------------------------------------------------- moe
def _naive_moe(p, x, top_k, kind):
    """Per-token loop reference (no capacity dropping)."""
    t, d = x.shape
    e = p["w_in"].shape[0]
    logits = x @ np.asarray(p["router"], np.float64)
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    out = np.zeros_like(x)
    for i in range(t):
        top = np.argsort(-probs[i])[:top_k]
        g = probs[i, top] / probs[i, top].sum()
        for gg, ee in zip(g, top):
            h = x[i] @ np.asarray(p["w_in"][ee])
            if kind == "swiglu":
                gate = x[i] @ np.asarray(p["w_gate"][ee])
                h = (gate / (1 + np.exp(-gate))) * h
            else:
                h = np.maximum(h, 0) ** 2
            out[i] += gg * (h @ np.asarray(p["w_out"][ee]))
    return out


@pytest.mark.parametrize("kind", ["swiglu", "squared_relu"])
def test_moe_matches_per_token_reference(rng, kind):
    d, dff, e, k = 8, 16, 4, 2
    key = jax.random.PRNGKey(1)
    p = F.init_moe(key, d, dff, e, kind, jnp.float32)
    x = jnp.asarray(rng.normal(size=(1, 12, d)), jnp.float32)
    # huge capacity => no token drops => must match the dense reference
    out, aux = F.apply_moe(p, x, top_k=k, capacity_factor=8.0, kind=kind)
    ref = _naive_moe(p, np.asarray(x[0], np.float64), k, kind)
    np.testing.assert_allclose(np.asarray(out[0]), ref, atol=1e-4, rtol=1e-3)
    assert float(aux) > 0


def test_moe_capacity_drops_dont_nan(rng):
    d, dff, e = 8, 16, 4
    p = F.init_moe(jax.random.PRNGKey(2), d, dff, e, "swiglu", jnp.float32)
    x = jnp.asarray(rng.normal(size=(2, 64, d)), jnp.float32)
    out, aux = F.apply_moe(p, x, top_k=2, capacity_factor=0.25, kind="swiglu")
    assert np.all(np.isfinite(np.asarray(out)))


def test_ffn_kinds(rng):
    d, dff = 8, 16
    x = jnp.asarray(rng.normal(size=(2, 4, d)), jnp.float32)
    for kind in ("swiglu", "squared_relu"):
        p = F.init_ffn(jax.random.PRNGKey(0), d, dff, kind, jnp.float32)
        y = F.apply_ffn(p, x, kind)
        assert y.shape == x.shape
        assert np.all(np.isfinite(np.asarray(y)))


def test_moe_grouped_matches_global(rng):
    """Group-local dispatch == global dispatch when capacity is ample."""
    d, dff, e, k = 8, 16, 4, 2
    key = jax.random.PRNGKey(3)
    p = F.init_moe(key, d, dff, e, "swiglu", jnp.float32)
    x = jnp.asarray(rng.normal(size=(4, 16, d)), jnp.float32)
    out1, _ = F.apply_moe(p, x, top_k=k, capacity_factor=8.0, kind="swiglu")
    old = F.MOE_GROUPS
    F.MOE_GROUPS = 4
    try:
        out2, _ = F.apply_moe(p, x, top_k=k, capacity_factor=8.0, kind="swiglu")
    finally:
        F.MOE_GROUPS = old
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               atol=1e-5, rtol=1e-5)

"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU): shape/dtype
sweeps per the assignment."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


# --------------------------------------------------------- floyd-warshall
@pytest.mark.parametrize("n", [4, 60, 128, 130, 256])
def test_floyd_warshall_sweep(rng, n):
    r = (rng.random((n, n)) * 10).astype(np.float32)
    r[rng.random((n, n)) < 0.4] = np.inf
    r = np.minimum(r, r.T)
    np.fill_diagonal(r, 0)
    got = np.asarray(ops.floyd_warshall(jnp.asarray(r)))
    want = np.asarray(ref.floyd_warshall_ref(jnp.asarray(r)))
    np.testing.assert_allclose(got, want, atol=1e-4)


def test_floyd_warshall_disconnected_stays_inf():
    r = np.full((8, 8), np.inf, np.float32)
    np.fill_diagonal(r, 0)
    r[0, 1] = r[1, 0] = 1.0
    h = np.asarray(ops.floyd_warshall(jnp.asarray(r)))
    assert h[0, 1] == 1.0 and np.isinf(h[0, 7])


# ------------------------------------------------------------- similarity
@pytest.mark.parametrize("n,d", [(10, 3), (128, 128), (200, 60), (50, 300)])
def test_pairwise_similarity_sweep(rng, n, d):
    u = rng.normal(size=(n, d)).astype(np.float32)
    got = np.asarray(ops.pairwise_similarity(jnp.asarray(u)))
    np.testing.assert_allclose(got, u @ u.T, atol=1e-2, rtol=1e-4)


@pytest.mark.parametrize("eps,sigma2", [(0.1, 0.01), (0.0, 1.0), (0.5, 0.1)])
def test_adjacency_epilogue(rng, eps, sigma2):
    v = rng.normal(size=(100, 100)).astype(np.float32)
    v = 0.5 * (v + v.T)
    got = np.asarray(ops.similarity_to_adjacency(jnp.asarray(v), eps=eps,
                                                 sigma2=sigma2))
    vn = (v - v.min()) / (v.max() - v.min())
    want = np.where(vn >= eps, np.exp(-vn / sigma2), np.inf)
    np.fill_diagonal(want, 0)
    mask = np.isfinite(want)
    np.testing.assert_allclose(got[mask], want[mask], atol=1e-4, rtol=1e-4)
    assert np.array_equal(np.isinf(got), np.isinf(want))


def test_build_3dg_kernel_end_to_end(rng):
    from repro.core.graph import build_3dg
    feats = rng.random((40, 16)).astype(np.float32)
    _, _, h_np = build_3dg(feats, eps=0.1, sigma2=0.01, backend="ref")
    v, r, h_k = ops.build_3dg_kernel(jnp.asarray(feats), eps=0.1, sigma2=0.01)
    mask = np.isfinite(h_np)
    np.testing.assert_allclose(np.asarray(h_k)[mask], h_np[mask], atol=1e-3,
                               rtol=1e-3)


# ------------------------------------------------------- window attention
@pytest.mark.parametrize("s,w,dtype", [
    (128, 32, jnp.float32),
    (256, 64, jnp.float32),
    (256, 100, jnp.float32),
    (384, 128, jnp.bfloat16),
])
def test_window_attention_sweep(rng, s, w, dtype):
    b, h, d = 2, 3, 32
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, s, h, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, s, h, d)), dtype)
    got = np.asarray(ops.window_attention(q, k, v, window=w), np.float32)
    want = np.asarray(ref.window_attention_ref(q, k, v, window=w), np.float32)
    atol = 3e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(got, want, atol=atol, rtol=atol)


def test_window_attention_is_causal(rng):
    """Changing future keys must not change past outputs."""
    b, s, h, d, w = 1, 128, 2, 16, 32
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    out1 = np.asarray(ops.window_attention(q, k, v, window=w))
    k2 = k.at[:, 100:].set(99.0)
    v2 = v.at[:, 100:].set(-99.0)
    out2 = np.asarray(ops.window_attention(q, k2, v2, window=w))
    np.testing.assert_allclose(out1[:, :100], out2[:, :100], atol=1e-5)


def test_window_attention_respects_window(rng):
    """Keys older than the window must not influence the output."""
    b, s, h, d, w = 1, 256, 1, 16, 64
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    out1 = np.asarray(ops.window_attention(q, k, v, window=w))
    # perturb keys/values well outside the last query's window
    k2 = k.at[:, :s - w - 64].set(7.0)
    v2 = v.at[:, :s - w - 64].set(-7.0)
    out2 = np.asarray(ops.window_attention(q, k2, v2, window=w))
    np.testing.assert_allclose(out1[:, -1], out2[:, -1], atol=1e-5)


# ------------------------------------------------- fused 3DG megakernel
# Parity contract (DESIGN.md §14): the fused similarity -> min-max ->
# adjacency grid is BIT-identical to the staged pallas stages at the same
# tile (identical tile shapes + op order), and agrees with the pure-jnp
# ref to float32 roundoff.  vs-ref equality is NOT bitwise by design:
# XLA's SIMD remainder lanes evaluate elementwise exp slightly differently
# for non-128-multiple widths (assumption log #18), which is why the
# bitwise pin is fused-vs-staged, not fused-vs-jnp.
@pytest.mark.parametrize("n", [7, 100, 130])
def test_fused_adjacency_bitwise_vs_staged(rng, n):
    u = jnp.asarray(rng.normal(size=(n, 16)).astype(np.float32))
    fused = np.asarray(ops.fused_adjacency(u, eps=0.1, sigma2=0.01, tile=128))
    v = ops.pairwise_similarity(u, tile=128)
    staged = np.asarray(ops.similarity_to_adjacency(v, eps=0.1, sigma2=0.01,
                                                    tile=128))
    assert np.array_equal(fused, staged, equal_nan=True)


@pytest.mark.parametrize("n", [7, 100, 130])
def test_fused_pipeline_vs_ref(rng, n):
    from repro.core.graph_device import minmax01, to_adjacency
    from repro.kernels.ref import floyd_warshall_ref
    u = jnp.asarray(rng.normal(size=(n, 16)).astype(np.float32))
    r_f, h_f = ops.build_3dg_fused(u, eps=0.1, sigma2=0.01)
    r_ref = to_adjacency(minmax01(u @ u.T), eps=0.1, sigma2=0.01)
    h_ref = np.asarray(floyd_warshall_ref(r_ref))
    for got, want in ((np.asarray(r_f), np.asarray(r_ref)),
                      (np.asarray(h_f), h_ref)):
        assert np.array_equal(np.isinf(got), np.isinf(want))
        fin = np.isfinite(want)
        np.testing.assert_allclose(got[fin], want[fin], atol=1e-5, rtol=1e-5)


def test_fused_adjacency_high_eps_no_nan(rng):
    """eps so high most edges drop: the inf no-edge entries must never
    leak NaN onto the diagonal (the inf*0 hazard to_adjacency documents)."""
    u = jnp.asarray(rng.normal(size=(33, 16)).astype(np.float32))
    r = np.asarray(ops.fused_adjacency(u, eps=0.95, sigma2=0.01))
    assert not np.any(np.isnan(r))
    assert np.array_equal(np.diag(r), np.zeros(33, np.float32))


def test_fused_pipeline_disconnected_clusters(rng):
    """Two orthogonal feature clusters: fused APSP must keep cross-cluster
    distances inf (padding rows must not create phantom paths)."""
    n = 20
    u = np.zeros((2 * n, 4), np.float32)
    u[:n, 0] = 1.0 + 0.1 * rng.random(n).astype(np.float32)
    u[n:, 1] = 1.0 + 0.1 * rng.random(n).astype(np.float32)
    # dot-similarity across clusters is exactly 0 -> normalized < eps
    _, h = ops.build_3dg_fused(jnp.asarray(u), eps=0.1, sigma2=0.01)
    h = np.asarray(h)
    assert np.all(np.isinf(h[:n, n:])) and np.all(np.isinf(h[n:, :n]))
    assert np.all(np.isfinite(h[:n, :n])) and np.all(np.isfinite(h[n:, n:]))


def test_fused_routing_matches_staged_build_h(rng):
    """core.graph_device.build_h(pallas) — which routes through the fused
    megakernel since PR 7 — must match the ref backend end to end."""
    from repro.core.graph_device import GraphConfig, build_h
    for sim in ("dot", "cosine", "functional"):
        u = jnp.asarray(rng.normal(size=(67, 8)).astype(np.float32))
        cfg = GraphConfig(similarity=sim)
        got = np.asarray(build_h(u, cfg, backend="pallas"))
        want = np.asarray(build_h(u, cfg, backend="ref"))
        np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)


# ------------------------------------------------------------- autotuner
def test_autotune_determinism(tmp_path):
    """Same timing table in -> byte-identical tuned_tiles.json out."""
    from repro.kernels import autotune

    def stub_timer(fn):               # deterministic: hash of the repr
        stub_timer.calls += 1
        return float(10 + stub_timer.calls % 7)
    specs = [("floyd_warshall", {"n": 256}), ("swap_gain", {"m": 64, "n": 2048})]
    texts = []
    for rep in range(2):
        stub_timer.calls = 0
        table = autotune.tune(specs, timer=stub_timer, platform="cpu",
                              base_table={}, verbose=False)
        p = tmp_path / f"t{rep}.json"
        autotune.save_table(table, p)
        texts.append(p.read_text())
    assert texts[0] == texts[1]
    table = autotune.tune(specs, timer=stub_timer, platform="cpu",
                          base_table={}, verbose=False)
    assert set(table) == {"floyd_warshall|n256|cpu", "swap_gain|m64,n2048|cpu"}
    for entry in table.values():
        assert entry["mode"] in ("interpret", "compiled")
        assert entry["tiles"] in [c[0] for c in entry["candidates"]]


def test_autotune_pick_best_tie_break():
    from repro.kernels.autotune import pick_best
    timed = [({"tile": 128}, 2.0), ({"tile": 256}, 1.0), ({"tile": 512}, 1.0)]
    assert pick_best(timed) == ({"tile": 256}, 1.0)


def test_autotune_resolve_and_fallback(tmp_path):
    from repro.kernels import autotune
    path = tmp_path / "tiles.json"
    autotune.save_table({
        autotune.table_key("floyd_warshall", "n256", "cpu"):
            {"tiles": {"tile": 256, "rogue_knob": 9}, "ms": 1.0,
             "mode": "interpret", "candidates": []}}, path)
    got = autotune.resolve("floyd_warshall", {"tile": 128}, platform="cpu",
                           path=path, n=200)          # tier n256 -> tuned
    assert got == {"tile": 256}                       # rogue knob filtered
    got = autotune.resolve("floyd_warshall", {"tile": 128}, platform="cpu",
                           path=path, n=2000)         # no n2048 entry
    assert got == {"tile": 128}
    assert autotune.shape_tier(n=130) == "n256"
    assert autotune.shape_tier(p=640, n=100) == "n128,p1024"


def test_tuned_table_checked_in_and_valid():
    """The committed table parses, every key round-trips through
    table_key, and every entry's winner is one of its candidates."""
    from repro.kernels import autotune
    table = autotune.load_table()
    assert table, "kernels/tuned_tiles.json missing or empty"
    for key, entry in table.items():
        kernel, tier, platform = key.split("|")
        assert autotune.table_key(kernel, tier, platform) == key
        assert kernel in autotune.KERNELS
        assert entry["mode"] in ("interpret", "compiled")
        assert entry["tiles"] in [c[0] for c in entry["candidates"]]


# ---------------------------------------------------------- window attn
@pytest.mark.parametrize("s,dtype", [(128, jnp.float32), (256, jnp.bfloat16)])
def test_flash_attention_full_causal(rng, s, dtype):
    """flash_attention == dense causal attention (the window covers all)."""
    from repro.models.attention import attend_dense
    b, h, d = 2, 2, 32
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, s, h, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, s, h, d)), dtype)
    got = np.asarray(ops.flash_attention(q, k, v), np.float32)
    want = np.asarray(attend_dense(q, k, v, causal=True, window=None), np.float32)
    atol = 3e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(got, want, atol=atol, rtol=atol)

"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU): shape/dtype
sweeps per the assignment."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


# --------------------------------------------------------- floyd-warshall
@pytest.mark.parametrize("n", [4, 60, 128, 130, 256])
def test_floyd_warshall_sweep(rng, n):
    r = (rng.random((n, n)) * 10).astype(np.float32)
    r[rng.random((n, n)) < 0.4] = np.inf
    r = np.minimum(r, r.T)
    np.fill_diagonal(r, 0)
    got = np.asarray(ops.floyd_warshall(jnp.asarray(r)))
    want = np.asarray(ref.floyd_warshall_ref(jnp.asarray(r)))
    np.testing.assert_allclose(got, want, atol=1e-4)


def test_floyd_warshall_disconnected_stays_inf():
    r = np.full((8, 8), np.inf, np.float32)
    np.fill_diagonal(r, 0)
    r[0, 1] = r[1, 0] = 1.0
    h = np.asarray(ops.floyd_warshall(jnp.asarray(r)))
    assert h[0, 1] == 1.0 and np.isinf(h[0, 7])


# ------------------------------------------------------------- similarity
@pytest.mark.parametrize("n,d", [(10, 3), (128, 128), (200, 60), (50, 300)])
def test_pairwise_similarity_sweep(rng, n, d):
    u = rng.normal(size=(n, d)).astype(np.float32)
    got = np.asarray(ops.pairwise_similarity(jnp.asarray(u)))
    np.testing.assert_allclose(got, u @ u.T, atol=1e-2, rtol=1e-4)


@pytest.mark.parametrize("eps,sigma2", [(0.1, 0.01), (0.0, 1.0), (0.5, 0.1)])
def test_adjacency_epilogue(rng, eps, sigma2):
    v = rng.normal(size=(100, 100)).astype(np.float32)
    v = 0.5 * (v + v.T)
    got = np.asarray(ops.similarity_to_adjacency(jnp.asarray(v), eps=eps,
                                                 sigma2=sigma2))
    vn = (v - v.min()) / (v.max() - v.min())
    want = np.where(vn >= eps, np.exp(-vn / sigma2), np.inf)
    np.fill_diagonal(want, 0)
    mask = np.isfinite(want)
    np.testing.assert_allclose(got[mask], want[mask], atol=1e-4, rtol=1e-4)
    assert np.array_equal(np.isinf(got), np.isinf(want))


def test_build_3dg_kernel_end_to_end(rng):
    from repro.core.graph import build_3dg
    feats = rng.random((40, 16)).astype(np.float32)
    _, _, h_np = build_3dg(feats, eps=0.1, sigma2=0.01, backend="ref")
    v, r, h_k = ops.build_3dg_kernel(jnp.asarray(feats), eps=0.1, sigma2=0.01)
    mask = np.isfinite(h_np)
    np.testing.assert_allclose(np.asarray(h_k)[mask], h_np[mask], atol=1e-3,
                               rtol=1e-3)


# ------------------------------------------------------- window attention
@pytest.mark.parametrize("s,w,dtype", [
    (128, 32, jnp.float32),
    (256, 64, jnp.float32),
    (256, 100, jnp.float32),
    (384, 128, jnp.bfloat16),
])
def test_window_attention_sweep(rng, s, w, dtype):
    b, h, d = 2, 3, 32
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, s, h, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, s, h, d)), dtype)
    got = np.asarray(ops.window_attention(q, k, v, window=w), np.float32)
    want = np.asarray(ref.window_attention_ref(q, k, v, window=w), np.float32)
    atol = 3e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(got, want, atol=atol, rtol=atol)


def test_window_attention_is_causal(rng):
    """Changing future keys must not change past outputs."""
    b, s, h, d, w = 1, 128, 2, 16, 32
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    out1 = np.asarray(ops.window_attention(q, k, v, window=w))
    k2 = k.at[:, 100:].set(99.0)
    v2 = v.at[:, 100:].set(-99.0)
    out2 = np.asarray(ops.window_attention(q, k2, v2, window=w))
    np.testing.assert_allclose(out1[:, :100], out2[:, :100], atol=1e-5)


def test_window_attention_respects_window(rng):
    """Keys older than the window must not influence the output."""
    b, s, h, d, w = 1, 256, 1, 16, 64
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    out1 = np.asarray(ops.window_attention(q, k, v, window=w))
    # perturb keys/values well outside the last query's window
    k2 = k.at[:, :s - w - 64].set(7.0)
    v2 = v.at[:, :s - w - 64].set(-7.0)
    out2 = np.asarray(ops.window_attention(q, k2, v2, window=w))
    np.testing.assert_allclose(out1[:, -1], out2[:, -1], atol=1e-5)


@pytest.mark.parametrize("s,dtype", [(128, jnp.float32), (256, jnp.bfloat16)])
def test_flash_attention_full_causal(rng, s, dtype):
    """flash_attention == dense causal attention (the window covers all)."""
    from repro.models.attention import attend_dense
    b, h, d = 2, 2, 32
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, s, h, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, s, h, d)), dtype)
    got = np.asarray(ops.flash_attention(q, k, v), np.float32)
    want = np.asarray(attend_dense(q, k, v, causal=True, window=None), np.float32)
    atol = 3e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(got, want, atol=atol, rtol=atol)

"""The device-native aggregator subsystem (fed/aggregator_device.py):

* legacy parity — the ``fedavg`` family (and ``fed/server.aggregate``) is
  BIT-identical to the legacy Eq. 18 formula, and the zero-weight guard
  returns the previous params instead of the all-zero pytree (regression:
  a forced all-unavailable round through the scan engine is a no-op);
* family math — each switch branch reproduces a manual numpy oracle
  (momentum, FedAdam moments, proximal re-weighting, memory
  scatter + staleness-discounted reduction);
* the aggregator switch — ``make_aggregator_step`` reproduces each
  family's ``AggregatorProcess.apply`` bit for bit, and state follows the
  uniform-pytree protocol;
* memory backend parity — the pallas scatter+reduce
  (``kernels/ops.memory_aggregate``) is bit-identical on the scattered
  panel and numerically equal on the reduction vs ref, at non-tile
  shapes incl. empty selections and invalid pads, standalone AND composed
  into a full scanned program;
* engine integration — FLEngine ≡ ScanEngine parity per family, and a
  MIXED-aggregator ``run_batch`` equals the per-cell runs (mirrors
  ``tests/test_sampler_device.py`` / ``test_scan_engine.py``).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.availability import make_mode
from repro.core.sampler import FedGSSampler
from repro.fed.aggregator_device import (
    FAMILIES, AggregatorProcess, FedAdamProcess, FedAvgMProcess,
    FedAvgProcess, FedProxWProcess, MemoryProcess, fedavg_combine,
    init_agg_state, make_aggregator_process, make_aggregator_step,
)
from repro.fed.engine import FLConfig, FLEngine
from repro.fed.models import logistic_regression
from repro.fed.scan_engine import (
    ScanConfig, ScanEngine, oracle_h, precompute_masks,
)


def _params(rng, dim=4, classes=3):
    return {"w": jnp.asarray(rng.normal(size=(dim, classes)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(classes,)), jnp.float32)}


def _stacked(rng, m, dim=4, classes=3):
    return {"w": jnp.asarray(rng.normal(size=(m, dim, classes)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(m, classes)), jnp.float32)}


def _flat(pt):
    return np.concatenate([np.asarray(x).reshape(-1)
                           for x in jax.tree_util.tree_leaves(pt)])


def _apply(proc, rng, n=12, m=4, t=3, state=None, data_sizes=None,
           backend="ref", sel=None):
    """One switch-step application on a random fixture; returns everything
    the oracles need."""
    prev = _params(rng)
    state = init_agg_state(prev, n) if state is None else state
    upd = _stacked(rng, m)
    w = jnp.asarray(rng.random(m) + 0.5, jnp.float32)
    if sel is None:
        sel = np.sort(rng.choice(n, size=m, replace=False))
    s = np.zeros(n, bool)
    s[sel] = True
    avail = jnp.ones(n, bool)
    key = jax.random.PRNGKey(0)
    params, state2 = proc.apply(state, key, upd, w, jnp.asarray(s), avail, t,
                                data_sizes=data_sizes, backend=backend)
    return dict(prev=state["prev"], state=state, upd=upd, w=w, sel=sel, s=s,
                params=params, state2=state2, t=t)


# ---------------------------------------------------------- legacy parity
def test_fedavg_bit_equals_legacy_aggregate(rng):
    """fedavg branch == fed/server.aggregate == the legacy Eq. 18 formula,
    bit for bit."""
    from repro.fed.server import aggregate
    m = 5
    stacked = _stacked(rng, m)
    weights = jnp.asarray(rng.random(m) * 3, jnp.float32)
    # the legacy op order, verbatim
    wn = weights / jnp.maximum(jnp.sum(weights), 1e-12)
    legacy = jax.tree_util.tree_map(
        lambda p: jnp.tensordot(wn.astype(p.dtype), p, axes=(0, 0)), stacked)
    for got in (aggregate(stacked, weights),
                fedavg_combine(stacked, weights)):
        for a, b in zip(jax.tree_util.tree_leaves(got),
                        jax.tree_util.tree_leaves(legacy)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # ... and through the switch step from the same inputs
    fx = _apply(FedAvgProcess(), rng, m=m)
    wn2 = fx["w"] / jnp.maximum(jnp.sum(fx["w"]), 1e-12)
    want = jax.tree_util.tree_map(
        lambda p: jnp.tensordot(wn2.astype(p.dtype), p, axes=(0, 0)),
        fx["upd"])
    for a, b in zip(jax.tree_util.tree_leaves(fx["params"]),
                    jax.tree_util.tree_leaves(want)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_zero_weight_guard_keeps_prev(rng):
    """All weights zero (a forced all-unavailable round): the guarded paths
    return the previous params, the prev-less legacy call keeps its
    documented all-zero average."""
    from repro.fed.server import aggregate
    prev = _params(rng)
    stacked = _stacked(rng, 3)
    zeros = jnp.zeros((3,), jnp.float32)
    out = aggregate(stacked, zeros, prev)
    for a, b in zip(jax.tree_util.tree_leaves(out),
                    jax.tree_util.tree_leaves(prev)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    legacy = aggregate(stacked, zeros)
    assert all(np.all(np.asarray(x) == 0)
               for x in jax.tree_util.tree_leaves(legacy))
    # every family's switch branch holds params on a zero-weight round
    # (the stateful ones may still drift by design: momentum keeps decaying)
    fx = _apply(FedAvgProcess(), rng)
    s0 = dict(fx["state2"])
    params, _ = FedAvgProcess().apply(
        s0, jax.random.PRNGKey(1), fx["upd"], fx["w"] * 0.0,
        jnp.zeros(12, bool), jnp.zeros(12, bool), 5)
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(s0["prev"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_scan_engine_all_unavailable_round_is_noop(synthetic_ds):
    """THE satellite regression: a round whose availability mask is all
    False must leave the global params unchanged (previously the Eq. 18
    ``0 / 1e-12`` wiped them to zero).  With eval_every=1 the round-1 val
    loss must equal round 0's exactly."""
    ds = synthetic_ds
    rounds, m = 4, 6
    masks = np.ones((rounds, ds.n_clients), bool)
    masks[1] = False                       # the forced all-unavailable round
    eng = ScanEngine(ds, logistic_regression(),
                     ScanConfig(rounds=rounds, m=m, local_steps=5,
                                batch_size=10, lr=0.1, eval_every=1,
                                sampler="uniform", max_sweeps=8),
                     use_masks=True)
    sh = eng.run(eng.cell(seed=0, masks=masks))
    assert np.isfinite(sh.val_loss).all()
    assert sh.val_loss[1] == sh.val_loss[0]          # params untouched
    assert sh.valid[1].sum() == 0                    # nothing was sampled
    assert sh.val_loss[2] != sh.val_loss[1]          # training resumed


def test_fedavg_scan_run_equals_legacy_path(synthetic_ds):
    """THE e2e acceptance: a ScanEngine round through the aggregator switch
    equals the legacy path — the same trainer composed with the legacy
    ``aggregate()`` formula on the host, from the engine's exact key
    streams and sampled set.  The host replication re-enters jit at the
    trainer/aggregate boundary, which costs 1 ulp of fusion reassociation
    (the assumption-log #3 class), hence atol=1e-8 here; the switch branch
    itself is pinned BIT-identical in
    ``test_fedavg_bit_equals_legacy_aggregate``, and a 10-round run of
    this engine was verified bitwise against the pre-subsystem engine at
    PR time (sel/counts/val_loss/params all exactly equal)."""
    import jax
    from repro.fed.client import make_local_trainer
    from repro.fed.server import aggregate

    ds = synthetic_ds
    n, m = ds.n_clients, 6
    masks = np.ones((1, n), bool)
    eng = ScanEngine(ds, logistic_regression(),
                     ScanConfig(rounds=1, m=m, local_steps=5, batch_size=10,
                                lr=0.1, eval_every=1, sampler="uniform",
                                max_sweeps=8),
                     use_masks=True)
    cell = eng.cell(seed=4, masks=masks)
    sh = eng.run(cell)

    # replay round 0 on the host with the engine's streams (DESIGN §5)
    model = logistic_regression()
    params = model.init(cell["key"])
    trainer = make_local_trainer(model.loss, local_steps=5, batch_size=10)
    sel, valid = sh.sel[0], sh.valid[0]
    key = jax.random.fold_in(cell["key"], 0)
    _, sub = jax.random.split(key)
    local = trainer(params, jnp.asarray(ds.x)[sel], jnp.asarray(ds.y)[sel],
                    jnp.asarray(ds.sizes)[sel],
                    jnp.asarray(np.float32(0.1)), jax.random.split(sub, m))
    want = aggregate(local, jnp.asarray(ds.sizes[sel], jnp.float32)
                     * valid)
    for a, b in zip(jax.tree_util.tree_leaves(eng.params),
                    jax.tree_util.tree_leaves(want)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-8)


# ------------------------------------------------------------- family math
def test_fedavgm_matches_manual(rng):
    lr_s, beta = 0.7, 0.85
    fx = _apply(FedAvgMProcess(server_lr=lr_s, beta=beta), rng)
    w = np.asarray(fx["w"], np.float64).astype(np.float32)
    wn = w / max(w.sum(), 1e-12)
    avg = {k: np.tensordot(wn, np.asarray(v), axes=(0, 0))
           for k, v in fx["upd"].items()}
    for k in ("w", "b"):
        mom = beta * 0.0 + (np.asarray(fx["prev"][k]) - avg[k])
        want = np.asarray(fx["prev"][k]) - lr_s * mom
        np.testing.assert_allclose(np.asarray(fx["params"][k]), want,
                                   atol=1e-6)
        np.testing.assert_allclose(np.asarray(fx["state2"]["m1"][k]), mom,
                                   atol=1e-6)


def test_fedadam_matches_manual(rng):
    lr_s, b1, b2, eps = 0.05, 0.9, 0.99, 1e-3
    proc = FedAdamProcess(server_lr=lr_s, beta1=b1, beta2=b2, eps=eps)
    fx = _apply(proc, rng)
    w = np.asarray(fx["w"], np.float32)
    wn = w / max(w.sum(), 1e-12)
    for k in ("w", "b"):
        avg = np.tensordot(wn, np.asarray(fx["upd"][k]), axes=(0, 0))
        d = avg - np.asarray(fx["prev"][k])
        m1 = (1 - b1) * d
        m2 = (1 - b2) * d * d
        want = np.asarray(fx["prev"][k]) + lr_s * m1 / (np.sqrt(m2) + eps)
        np.testing.assert_allclose(np.asarray(fx["params"][k]), want,
                                   atol=1e-6)
        np.testing.assert_allclose(np.asarray(fx["state2"]["m2"][k]), m2,
                                   atol=1e-7)


def test_fedprox_w_downweights_drifted(rng):
    mu = 0.5
    fx = _apply(FedProxWProcess(mu=mu), rng)
    prevf = _flat(fx["prev"])
    drift = np.array([np.sum((_flat({k: v[i] for k, v in fx["upd"].items()})
                              - prevf) ** 2) for i in range(4)])
    w2 = np.asarray(fx["w"]) / (1.0 + mu * drift)
    wn = w2 / max(w2.sum(), 1e-12)
    for k in ("w", "b"):
        want = np.tensordot(wn.astype(np.float32),
                            np.asarray(fx["upd"][k]), axes=(0, 0))
        np.testing.assert_allclose(np.asarray(fx["params"][k]), want,
                                   atol=1e-5)


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_memory_matches_manual(rng, backend):
    """Scatter + staleness-discounted reduction against a numpy oracle:
    participants' rows and tau refresh, every other client contributes its
    (initial-model) memory row discounted by gamma^age."""
    gamma, n, m, t = 0.8, 12, 4, 5
    sizes = rng.random(n) * 5 + 1
    fx = _apply(MemoryProcess(gamma=gamma), rng, n=n, m=m, t=t,
                data_sizes=sizes, backend=backend)
    mem = np.asarray(fx["state"]["mem"]).copy()
    for i, k in enumerate(fx["sel"]):
        mem[k] = _flat({kk: vv[i] for kk, vv in fx["upd"].items()})
    tau = np.zeros(n)
    tau[fx["sel"]] = t
    wmem = sizes * gamma ** (t - tau)
    wn = (wmem / wmem.sum()).astype(np.float32)
    want = np.tensordot(wn, mem, axes=(0, 0))
    np.testing.assert_allclose(_flat(fx["params"]), want, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(fx["state2"]["mem"]), mem)
    np.testing.assert_array_equal(np.asarray(fx["state2"]["tau"]), tau)


def test_memory_gamma_zero_is_sampled_fedavg(rng):
    """gamma -> 0: only the age-0 (just-sampled) rows keep weight, so the
    memory family degenerates to size-weighted FedAvg over the sampled
    set (the documented interpolation endpoint)."""
    n, m, t = 10, 3, 4
    sizes = rng.random(n) + 0.5
    fx = _apply(MemoryProcess(gamma=1e-6), rng, n=n, m=m, t=t,
                data_sizes=sizes)
    w = sizes[fx["sel"]].astype(np.float32)
    wn = w / w.sum()
    upd = np.stack([_flat({k: v[i] for k, v in fx["upd"].items()})
                    for i in range(m)])
    np.testing.assert_allclose(_flat(fx["params"]),
                               np.tensordot(wn, upd, axes=(0, 0)), atol=1e-4)


# ---------------------------------------------------------- the switch step
def test_switch_matches_direct_applies(rng):
    """One compiled step dispatches every family identically to the
    process's own apply (the switch is dispatch, not reimplementation)."""
    n, m = 12, 4
    prev = _params(rng)
    state = init_agg_state(prev, n)
    upd = _stacked(rng, m)
    w = jnp.asarray(rng.random(m) + 0.1, jnp.float32)
    sel = np.sort(rng.choice(n, size=m, replace=False))
    s = jnp.asarray(np.isin(np.arange(n), sel))
    avail = jnp.ones(n, bool)
    key = jax.random.PRNGKey(7)
    sizes = rng.random(n) + 0.5
    step = jax.jit(make_aggregator_step(n, m, prev, data_sizes=sizes))
    for name in FAMILIES:
        proc = make_aggregator_process(name)
        got_p, got_s = step(proc.params(), state, key, upd, w, s, avail, 2)
        want_p, want_s = proc.apply(state, key, upd, w, s, avail, 2,
                                    data_sizes=sizes)
        np.testing.assert_array_equal(_flat(got_p), _flat(want_p),
                                      err_msg=name)
        for a, b in zip(jax.tree_util.tree_leaves(got_s),
                        jax.tree_util.tree_leaves(want_s)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=name)
        # ... and the host face's single-branch step (family=...) IS the
        # same branch: bit-equal to the switch dispatch
        step1 = jax.jit(make_aggregator_step(n, m, prev, data_sizes=sizes,
                                             family=name))
        one_p, _ = step1(proc.params(), state, key, upd, w, s, avail, 2)
        np.testing.assert_array_equal(_flat(one_p), _flat(want_p),
                                      err_msg=f"{name} single-branch")


def test_process_protocol(rng):
    """params/init follow the uniform-pytree protocol; the factory matches
    scan_engine.AGGREGATORS."""
    from repro.fed.scan_engine import AGGREGATORS
    assert AGGREGATORS == FAMILIES
    prev = _params(rng)
    for name in FAMILIES:
        proc = make_aggregator_process(name)
        ap = proc.params()
        assert int(ap["family"]) == FAMILIES.index(name)
        assert ap["theta"].shape == (6,)
        state = proc.init(prev, 9)
        assert state["mem"].shape == (9, 15)       # 4*3 + 3 flat params
        assert state["tau"].shape == (9,)
        for a, b in zip(jax.tree_util.tree_leaves(state["prev"]),
                        jax.tree_util.tree_leaves(prev)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    with pytest.raises(ValueError):
        make_aggregator_process("nope")
    with pytest.raises(ValueError):
        make_aggregator_step(4, 2, prev, backend="nope")


# ------------------------------------------------- memory backend parity
@pytest.mark.parametrize("n,p,m", [(7, 5, 3), (30, 610, 6), (100, 130, 11),
                                   (300, 2100, 30), (2000, 300, 700)])
def test_memory_kernel_backend_parity(rng, n, p, m):
    """kernels/ops.memory_aggregate vs the jnp ref: scattered panel BIT
    identical, reduction numerically equal (non-tile-multiple shapes; the
    m = 700 row spans multiple 256-row update chunks — the M-tiling that
    keeps the kernel under VMEM at datacenter m)."""
    from repro.fed.aggregator_device import memory_scatter_reduce_ref
    from repro.kernels.ops import memory_aggregate
    mem = jnp.asarray(rng.normal(size=(n, p)), jnp.float32)
    upd = jnp.asarray(rng.normal(size=(m, p)), jnp.float32)
    sel = jnp.asarray(np.sort(rng.choice(n, size=m, replace=False)),
                      jnp.int32)
    valid = jnp.asarray(rng.random(m) < 0.8)
    w = jnp.asarray(rng.random(n), jnp.float32)
    w = w / w.sum()
    ref_mem, ref_red = memory_scatter_reduce_ref(mem, upd, sel, valid, w)
    nm, red = memory_aggregate(mem, upd, sel, valid, w)
    np.testing.assert_array_equal(np.asarray(nm), np.asarray(ref_mem))
    np.testing.assert_allclose(np.asarray(red), np.asarray(ref_red),
                               atol=1e-5, rtol=1e-5)


def test_memory_kernel_nan_containment(rng):
    """One diverged client's NaN update may poison ONLY that client's
    memory row: the kernel's one-hot matmul zeroes non-finite entries for
    the dot and restores them as NaN via a mask dot (0·NaN would otherwise
    leak across every scattered row of the chunk)."""
    from repro.kernels.ops import memory_aggregate
    n, p, m = 20, 33, 5
    mem = jnp.asarray(rng.normal(size=(n, p)), jnp.float32)
    upd = np.asarray(rng.normal(size=(m, p)), np.float32)
    upd[0, 2] = np.nan                     # client sel[0] diverged
    sel = jnp.asarray([3, 5, 9, 11, 17], jnp.int32)
    valid = jnp.ones(m, bool)
    w = jnp.full((n,), 1.0 / n, jnp.float32)
    nm, _ = memory_aggregate(mem, jnp.asarray(upd), sel, valid, w)
    nm = np.asarray(nm)
    assert np.isnan(nm[3, 2])              # the diverged row marks itself
    clean = np.delete(np.arange(n), 3)
    assert np.isfinite(nm[clean]).all()    # ... and nobody else
    np.testing.assert_array_equal(nm[5], upd[1])


def test_memory_kernel_empty_and_invalid(rng):
    """m = 0 and all-invalid selections: the panel passes through
    untouched, the reduction is the plain weighted row sum."""
    from repro.kernels.ops import memory_aggregate
    n, p = 16, 9
    mem = jnp.asarray(rng.normal(size=(n, p)), jnp.float32)
    w = jnp.full((n,), 1.0 / n, jnp.float32)
    for m in (0, 3):
        upd = jnp.asarray(rng.normal(size=(m, p)), jnp.float32)
        sel = jnp.asarray(np.arange(m), jnp.int32)
        valid = jnp.zeros((m,), bool)
        nm, red = memory_aggregate(mem, upd, sel, valid, w)
        np.testing.assert_array_equal(np.asarray(nm), np.asarray(mem))
        np.testing.assert_allclose(
            np.asarray(red), np.asarray(jnp.tensordot(w, mem, axes=(0, 0))),
            atol=1e-6)


def test_scan_agg_backend_pallas_matches_ref(synthetic_ds):
    """ScanConfig.agg_backend="pallas" routes the in-scan memory
    scatter+reduce through the fused kernel and reproduces the ref
    backend's trajectory (selected sets exact — FedGS ignores params —
    losses to float32 round-off)."""
    ds = synthetic_ds
    h = oracle_h(ds.opt_params)
    mode = make_mode("LN", n_clients=ds.n_clients, data_sizes=ds.sizes,
                     label_sets=ds.label_sets(), num_labels=ds.num_classes,
                     seed=7)
    hists = {}
    for backend in ("ref", "pallas"):
        eng = ScanEngine(ds, logistic_regression(),
                         ScanConfig(rounds=6, m=6, local_steps=5,
                                    batch_size=10, lr=0.1, eval_every=1,
                                    sampler="fedgs", max_sweeps=16,
                                    aggregator="memory",
                                    agg_backend=backend))
        hists[backend] = eng.run(eng.cell(seed=0, mode=mode, alpha=1.0, h=h))
    np.testing.assert_array_equal(hists["ref"].sel, hists["pallas"].sel)
    np.testing.assert_allclose(hists["ref"].val_loss,
                               hists["pallas"].val_loss, atol=1e-5)


# --------------------------------------------------------- engine parity
def _host_scan_pair(ds, proc, rounds=8, frac=0.2, seed=3):
    mode = make_mode("IDL", n_clients=ds.n_clients, data_sizes=ds.sizes,
                     label_sets=ds.label_sets(), num_labels=ds.num_classes,
                     seed=7)
    sampler = FedGSSampler(alpha=1.0, max_sweeps=16)
    cfg = FLConfig(rounds=rounds, sample_frac=frac, local_steps=5,
                   batch_size=10, lr=0.1, eval_every=1, seed=seed)
    eng = FLEngine(ds, logistic_regression(), sampler, mode, cfg,
                   aggregator=proc)
    eng.install_oracle_graph(ds.opt_params)
    hist = eng.run()
    masks = precompute_masks(mode, rounds, cfg.avail_seed)
    assert masks.sum(1).min() >= eng.m     # the parity precondition
    seng = ScanEngine(ds, logistic_regression(),
                      ScanConfig(rounds=rounds, m=eng.m, local_steps=5,
                                 batch_size=10, lr=0.1, eval_every=1,
                                 sampler="fedgs", max_sweeps=16),
                      use_masks=True)
    sh = seng.run(seng.cell(seed=seed, masks=masks, alpha=1.0,
                            h=eng.sampler._h, aggregator_process=proc))
    return eng, hist, sh


@pytest.mark.parametrize("family", FAMILIES)
def test_flengine_scanengine_parity_per_family(synthetic_ds, family):
    """FLEngine (ServerAggregator host face) ≡ ScanEngine (in-scan switch)
    under EVERY aggregator family: identical sampled sets, val loss within
    float32 round-off — both paths run the one device apply."""
    proc = make_aggregator_process(family)
    eng, hist, sh = _host_scan_pair(synthetic_ds, proc)
    for i, t in enumerate(hist.rounds):
        assert hist.sampled[i] == sh.sampled(t).tolist(), \
            f"{family} round {t}"
    np.testing.assert_allclose(
        sh.val_loss[np.asarray(hist.rounds)], np.asarray(hist.val_loss),
        atol=1e-4)
    np.testing.assert_array_equal(eng.counts, sh.counts)


def test_mixed_aggregator_batch_equals_per_cell(synthetic_ds):
    """THE aggregator-subsystem acceptance: one vmapped program running one
    cell per family (five server-update rules behind the one lax.switch
    step) equals the five per-cell runs."""
    ds = synthetic_ds
    h = oracle_h(ds.opt_params)
    mode = make_mode("LN", n_clients=ds.n_clients, data_sizes=ds.sizes,
                     label_sets=ds.label_sets(), num_labels=ds.num_classes,
                     seed=7)
    eng = ScanEngine(ds, logistic_regression(),
                     ScanConfig(rounds=8, m=6, local_steps=5, batch_size=10,
                                lr=0.1, eval_every=1, sampler="fedgs",
                                max_sweeps=16))
    procs = [make_aggregator_process(f) for f in FAMILIES]
    cells = [eng.cell(seed=i, mode=mode, h=h, aggregator_process=p,
                      avail_seed=80 + i) for i, p in enumerate(procs)]
    batch = eng.run_batch(cells)
    for proc, cell, b in zip(procs, cells, batch):
        single = eng.run(cell)
        np.testing.assert_array_equal(b.sel, single.sel,
                                      err_msg=proc.family)
        np.testing.assert_array_equal(b.counts, single.counts)
        np.testing.assert_allclose(b.val_loss, single.val_loss, atol=2e-6)
        assert np.isfinite(b.val_loss).all()

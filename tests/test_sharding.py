"""Sharding rules: divisibility-aware PartitionSpec derivation."""
from types import SimpleNamespace

import numpy as np
import pytest

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import INPUT_SHAPES
from repro.configs.registry import get_config
from repro.launch.specs import abstract_params, input_specs, variant_for_shape
from repro.models import lm
from repro.sharding import rules
from repro.sharding.ctx import ShardCtx


def _fake_mesh(shape=(16, 16), names=("data", "model")):
    """rules.* only reads axis_names and devices.shape — no jax needed."""
    return SimpleNamespace(axis_names=names, devices=np.empty(shape))


def _ctx(shape=(16, 16), names=("data", "model")):
    amap = {"dp": ("data",), "tp": ("model",), "fsdp": ("data",), "sp": ("data",)}
    if "pod" in names:
        amap["dp"] = ("pod", "data")
    return ShardCtx(axis_map=amap, mesh=_fake_mesh(shape, names))


def _check_divisible(tree, specs, sizes):
    flat_x = jax.tree_util.tree_leaves(tree)
    flat_s = jax.tree_util.tree_leaves(specs, is_leaf=lambda s: isinstance(s, P))
    assert len(flat_x) == len(flat_s)
    for x, spec in zip(flat_x, flat_s):
        for dim, axes in enumerate(spec):
            if axes is None:
                continue
            axes = (axes,) if isinstance(axes, str) else axes
            total = int(np.prod([sizes[a] for a in axes]))
            assert x.shape[dim] % total == 0, (x.shape, spec)


@pytest.mark.parametrize("arch", ["smollm-135m", "nemotron-4-340b",
                                  "olmoe-1b-7b", "mamba2-780m", "hymba-1.5b"])
def test_param_specs_always_divisible(arch):
    cfg = get_config(arch)
    ctx = _ctx()
    params = abstract_params(cfg)
    specs = rules.param_specs(params, ctx)
    _check_divisible(params, specs, {"data": 16, "model": 16})


def test_param_specs_2d_sharding_on_big_dense():
    """nemotron-340b weights must actually get both fsdp and tp axes."""
    cfg = get_config("nemotron-4-340b")
    ctx = _ctx()
    params = abstract_params(cfg)
    specs = rules.param_specs(params, ctx)
    wq_spec = specs["blocks"]["attn"]["wq"]
    # stacked (L, d, hq*dh): expect (None, "data", "model")
    assert wq_spec == P(None, "data", "model")


def test_hymba_attention_replicated():
    """25 heads / kv=5 aren't divisible by tp=16 -> replicate, don't crash."""
    cfg = get_config("hymba-1.5b")
    ctx = _ctx()
    params = abstract_params(cfg)
    specs = rules.param_specs(params, ctx)
    wq = specs["blocks"]["attn"]["wq"]      # (L, 1600, 1600): both dims 1600%16==0
    # d_model 1600 = 16*100 is divisible, so fsdp/tp apply on the projection
    assert wq == P(None, "data", "model")


def test_batch_specs_shard_batch_dim():
    cfg = get_config("smollm-135m")
    shape = INPUT_SHAPES["train_4k"]
    ctx = _ctx()
    batch = input_specs(cfg, shape)["batch"]
    specs = rules.batch_specs(batch, ctx)
    assert specs["tokens"] == P("data", None)
    assert specs["labels"] == P("data", None)


def test_batch_specs_multipod():
    cfg = get_config("smollm-135m")
    shape = INPUT_SHAPES["train_4k"]
    ctx = _ctx((2, 16, 16), ("pod", "data", "model"))
    batch = input_specs(cfg, shape)["batch"]
    specs = rules.batch_specs(batch, ctx)
    assert specs["tokens"] == P(("pod", "data"), None)


def test_cache_specs_batch_vs_seq_sharding():
    cfg = get_config("deepseek-coder-33b")
    ctx = _ctx()
    for shape_name, seq_shard in [("decode_32k", False), ("long_500k", True)]:
        shape = INPUT_SHAPES[shape_name]
        c = variant_for_shape(cfg, shape)
        cache = jax.eval_shape(
            lambda: lm.init_decode_cache(c, shape.global_batch, shape.seq_len))
        specs = rules.cache_specs(cache, ctx, seq_shard=seq_shard)
        kspec = specs["k"]
        if seq_shard:
            assert kspec[2] == "data" and kspec[1] is None   # (L,B,S,H,D): S sharded
        else:
            assert kspec[1] == "data"                        # batch sharded


def test_undivisible_batch_replicates():
    """global_batch=1 (long_500k) can't shard over 16 -> replicated."""
    cfg = get_config("mamba2-780m")
    ctx = _ctx()
    shape = INPUT_SHAPES["long_500k"]
    specs_in = input_specs(cfg, shape)
    cache_specs = rules.cache_specs(specs_in["cache"], ctx, seq_shard=True)
    ssm = cache_specs["ssm"]                # (L,B,H,P,N): B=1 -> None
    assert ssm[1] is None


def test_shard_act_noop_without_ctx():
    import jax.numpy as jnp
    from repro.sharding.ctx import shard_act
    x = jnp.ones((4, 4))
    y = shard_act(x, "dp", "tp")
    assert y.shape == x.shape

"""Launch entry points (train / serve) and dry-run record integrity."""
import json
import pathlib

import numpy as np
import pytest

DRYRUN = pathlib.Path(__file__).resolve().parents[1] / "benchmarks" / "results" / "dryrun"


def test_train_entry_runs():
    from repro.launch import train
    params, counts = train.main([
        "--arch", "smollm-135m", "--reduced", "--rounds", "2",
        "--clients", "4", "--local-steps", "1", "--batch", "2",
        "--seq", "16", "--sampler", "fedgs", "--mode", "LN"])
    assert counts.sum() == 2 * 1   # 2 rounds x m=1
    assert all(np.all(np.isfinite(np.asarray(x, np.float32)))
               for x in __import__("jax").tree_util.tree_leaves(params))


def test_serve_entry_runs():
    from repro.launch import serve
    gen = serve.main(["--arch", "smollm-135m", "--reduced", "--batch", "2",
                      "--prompt-len", "8", "--gen", "4"])
    assert gen.shape == (2, 4)
    assert gen.min() >= 0


@pytest.mark.skipif(not DRYRUN.exists(), reason="dry-run results not present")
def test_dryrun_matrix_all_green():
    """The 40x2 (arch x shape x mesh) baseline matrix must be fully green."""
    recs = [json.loads(f.read_text()) for f in DRYRUN.glob("*.json")]
    shapes = {"train_4k", "prefill_32k", "decode_32k", "long_500k"}
    base = [r for r in recs if r.get("variant") == "baseline"
            and r.get("shape") in shapes]
    if len(base) < 80:
        pytest.skip("matrix incomplete on this machine")
    by_mesh = {}
    for r in base:
        by_mesh.setdefault(r["mesh"], []).append(r)
    for mesh, rows in by_mesh.items():
        assert len(rows) == 40, (mesh, len(rows))
        bad = [f"{r['arch']}/{r['shape']}" for r in rows if not r["ok"]]
        assert not bad, (mesh, bad)
    # every record carries the roofline terms
    for r in base:
        for k in ("compute_term_s", "memory_term_s", "collective_term_s",
                  "dominant", "useful_flop_ratio"):
            assert k in r, (r["arch"], r["shape"], k)


def test_variants_registry_consistent():
    from repro.launch.variants import VARIANTS, apply_variant
    assert "baseline" in VARIANTS and "ring_cache" in VARIANTS
    for name in VARIANTS:
        with apply_variant(name):
            pass


def test_fedsim_records_green():
    """The federated-round dry-run (the paper's own program on the production
    mesh) must be green where present."""
    recs = [json.loads(f.read_text()) for f in DRYRUN.glob("fedsim__*.json")]
    if not recs:
        pytest.skip("no fedsim records")
    for r in recs:
        assert r["ok"], r.get("error")
        assert r["round"]["mem"].get("temp_size_in_bytes", 0) < 16e9

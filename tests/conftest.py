import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def synthetic_ds():
    """The paper's exact Synthetic(0.5, 0.5) dataset, 30 clients."""
    from repro.data.synthetic import make_synthetic
    return make_synthetic(n_clients=30, alpha=0.5, beta=0.5, seed=0)

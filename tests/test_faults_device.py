"""The fault-injection subsystem (fed/faults_device.py):

* oracle parity — every corruption family pinned against a plain-numpy
  oracle on the flat (M, P) panel (byz-and-valid masking, sign-flip /
  boost algebra, the AR(1) latency chain + stale-panel refresh protocol);
* the switch — jitted ``lax.switch`` dispatch is bitwise equal to the
  jitted single-family branch for every family (the engines always jit,
  so this IS the engine-level contract);
* identity guarantees — the ``none`` family and ``stale_enabled=False``
  straggler aliasing are exact identities; benign cells carry NO fault
  state (the program-variant gating);
* engine integration — FLEngine's ``HostFaultInjector`` path replays the
  matching ScanEngine cell (shared masks, sampler, fault stream), and a
  MIXED fault-family ``run_batch`` equals the per-cell runs bitwise.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.availability import make_mode
from repro.fed.aggregator_device import make_aggregator_process
from repro.fed.faults_device import (
    FAMILIES, GaussianNoiseFault, NoFault, ScaledFault, SignFlipFault,
    StragglerStaleFault, init_fault_state, make_fault_process,
    make_fault_step,
)
from repro.fed.models import logistic_regression
from repro.fed.scan_engine import ScanConfig, ScanEngine

N, M, P = 12, 5, 32


def _fixture(rng, proc, *, p=P):
    """Params/state/panel inputs for one corrupt() application."""
    key = jax.random.PRNGKey(3)
    fp = proc.params()
    state = proc.init(key)
    if proc.family == "straggler_stale":
        rows = jnp.asarray(rng.normal(size=(N, p)).astype(np.float32))
        state = {**state, "stale": rows}
    else:
        state = {**state, "stale": jnp.zeros((0, p), jnp.float32)}
    updf = jnp.asarray(rng.normal(size=(M, p)).astype(np.float32))
    prevf = jnp.asarray(rng.normal(size=(p,)).astype(np.float32))
    sel = jnp.asarray(rng.choice(N, size=M, replace=False), jnp.int32)
    valid = jnp.asarray(rng.random(M) < 0.8)
    avail = jnp.ones(N, bool)
    return fp, state, key, updf, prevf, avail, sel, valid


def _run(proc, fix, t=4, family=None):
    fp, state, key, updf, prevf, avail, sel, valid = fix
    step = make_fault_step(N, M,
                           stale_enabled=proc.family == "straggler_stale",
                           family=family)
    step = jax.jit(step)
    out, state2 = step(fp, state, jax.random.fold_in(key, t), updf, prevf,
                       avail, t, sel, valid)
    return np.asarray(out), state2


# ------------------------------------------------------------ the byz mask
def test_byz_mask_deterministic():
    p = SignFlipFault(N, frac=0.3, byz_seed=5)
    m1, m2 = p.byz_mask(), SignFlipFault(N, frac=0.3, byz_seed=5).byz_mask()
    assert np.array_equal(m1, m2)
    assert m1.sum() == int(np.ceil(0.3 * N))
    assert not np.array_equal(m1, SignFlipFault(N, frac=0.3,
                                                byz_seed=6).byz_mask())
    assert NoFault(N).byz_mask().sum() == 0
    assert SignFlipFault(N, frac=0.0).byz_mask().sum() == 0


# ------------------------------------------------------- per-family oracles
def test_none_is_bitwise_identity(rng):
    proc = NoFault(N)
    fix = _fixture(rng, proc)
    out, state2 = _run(proc, fix)
    np.testing.assert_array_equal(out, np.asarray(fix[3]))
    np.testing.assert_array_equal(np.asarray(state2["latency"]),
                                  np.asarray(fix[1]["latency"]))


@pytest.mark.parametrize("family,knob", [("sign_flip", 3.0), ("scaled", 7.0)])
def test_flip_boost_numpy_oracle(rng, family, knob):
    """sign_flip / scaled are elementwise f32 algebra on the byz-and-valid
    slots: ``prev -/+ knob (theta_k - prev)``.  XLA fuses the
    multiply-subtract into an FMA, so corrupted slots sit within 1 ulp of
    the separate-op numpy oracle; honest slots are untouched BITWISE."""
    proc = SignFlipFault(N, frac=0.4, scale=knob) if family == "sign_flip" \
        else ScaledFault(N, frac=0.4, boost=knob)
    fix = _fixture(rng, proc)
    fp, _, _, updf, prevf, _, sel, valid = fix
    out, _ = _run(proc, fix)

    u, pv = np.asarray(updf), np.asarray(prevf)
    byzm = proc.byz_mask()[np.asarray(sel)] & np.asarray(valid)
    sgn = np.float32(-knob if family == "sign_flip" else knob)
    oracle = np.where(byzm[:, None], pv[None, :] + sgn * (u - pv[None, :]),
                      u).astype(np.float32)
    np.testing.assert_allclose(out, oracle, rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(out[~byzm], u[~byzm])
    assert byzm.any() and not byzm.all()        # both paths exercised


def test_gaussian_oracle_masks_and_stream(rng):
    """Noise lands ONLY on byz-and-valid slots; the draw is a function of
    the round key alone (shape (M, P)), so the oracle replays it with the
    same jax draw and pins the masking numpy-side."""
    proc = GaussianNoiseFault(N, frac=0.4, sigma=0.7)
    fix = _fixture(rng, proc)
    fp, _, key, updf, prevf, _, sel, valid = fix
    t = 4
    out, _ = _run(proc, fix, t=t)
    noise = np.asarray(jax.random.normal(jax.random.fold_in(key, t),
                                         updf.shape))
    byzm = proc.byz_mask()[np.asarray(sel)] & np.asarray(valid)
    oracle = np.where(byzm[:, None],
                      np.asarray(updf) + np.float32(0.7) * noise,
                      np.asarray(updf)).astype(np.float32)
    np.testing.assert_allclose(out, oracle, atol=1e-6)
    np.testing.assert_array_equal(out[~byzm], np.asarray(updf)[~byzm])


def test_straggler_numpy_oracle_multiround(rng):
    """5 rounds of the AR(1) chain + stale panel against a numpy replay:
    late byz slots ship their pre-refresh panel row; on-time valid slots
    refresh their row; latency follows ``l' = rho l + (1-rho) mu + s eps``
    with the eps drawn from ``fold_in(fold_in(key, t), 2)``."""
    proc = StragglerStaleFault(N, frac=0.5, rho=0.7, sigma=0.3,
                               deadline=1.0)
    key = jax.random.PRNGKey(3)
    state = init_fault_state(proc.init(key),
                             {"w": jnp.zeros((P,), jnp.float32)}, N)
    # flat template of a (P,)-param model: panel rows are flat zeros
    fp = proc.params()
    step = jax.jit(make_fault_step(N, M, stale_enabled=True))

    lat = np.array(state["latency"], np.float32)
    stale = np.array(state["stale"], np.float32)
    mu, byz = np.asarray(fp["aux"], np.float32), proc.byz_mask()
    avail = jnp.ones(N, bool)
    for t in range(5):
        updf = jnp.asarray(rng.normal(size=(M, P)).astype(np.float32))
        prevf = jnp.asarray(rng.normal(size=(P,)).astype(np.float32))
        sel = rng.choice(N, size=M, replace=False)
        valid = rng.random(M) < 0.8
        fkey = jax.random.fold_in(key, t)
        out, state = step(fp, state, fkey, updf, prevf, avail, t,
                          jnp.asarray(sel, jnp.int32), jnp.asarray(valid))
        eps = np.asarray(jax.random.normal(jax.random.fold_in(fkey, 2),
                                           (N,)))
        lat = (np.float32(0.7) * lat + np.float32(1.0 - 0.7) * mu
               + np.float32(0.3) * eps).astype(np.float32)
        byzm = byz[sel] & valid
        late = byzm & (lat[sel] > 1.0)
        oracle = np.where(late[:, None], stale[sel], np.asarray(updf))
        refresh = valid & ~late
        stale[sel[refresh]] = np.asarray(updf)[refresh]
        np.testing.assert_allclose(np.asarray(out), oracle, atol=1e-6,
                                   err_msg=f"round {t}")
        np.testing.assert_allclose(np.asarray(state["latency"]), lat,
                                   atol=1e-5, err_msg=f"round {t} latency")
        np.testing.assert_allclose(np.asarray(state["stale"]), stale,
                                   atol=1e-6, err_msg=f"round {t} stale")


# ---------------------------------------------------------------- the switch
@pytest.mark.parametrize("family", FAMILIES)
def test_switch_equals_single_family_branch_jitted(rng, family):
    """Jitted lax.switch dispatch == jitted direct branch, bitwise — the
    engines always jit, so this is the engine-level parity contract (eager
    dispatch may differ by 1 ulp through FMA fusion; see DESIGN.md §16)."""
    proc = make_fault_process(family, N, frac=0.4)
    fix = _fixture(rng, proc)
    out_sw, st_sw = _run(proc, fix, family=None)
    out_br, st_br = _run(proc, fix, family=family)
    np.testing.assert_array_equal(out_sw, out_br)
    for a, b in zip(jax.tree_util.tree_leaves(st_sw),
                    jax.tree_util.tree_leaves(st_br)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_stale_disabled_aliases_straggler_to_none(rng):
    proc = StragglerStaleFault(N, frac=0.5, deadline=-10.0)   # always late
    fix = _fixture(rng, proc)
    fp, state, key, updf, prevf, avail, sel, valid = fix
    state0 = {**state, "stale": jnp.zeros((0, P), jnp.float32)}
    step = jax.jit(make_fault_step(N, M, stale_enabled=False))
    out, _ = step(fp, state0, key, updf, prevf, avail, 0, sel, valid)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(updf))
    with pytest.raises(ValueError):
        make_fault_step(N, M, stale_enabled=False, family="straggler_stale")


# ------------------------------------------------------- engine integration
@pytest.fixture(scope="module")
def ds12():
    from repro.data.synthetic import make_synthetic
    return make_synthetic(n_clients=12, alpha=0.5, beta=0.5, seed=0)


def _mode(ds, seed=7):
    return make_mode("IDL", n_clients=ds.n_clients, data_sizes=ds.sizes,
                     label_sets=ds.label_sets(), num_labels=ds.num_classes,
                     seed=seed)


def test_benign_cells_carry_no_fault_state(ds12):
    """Program-variant gating: an all-benign batch compiles WITHOUT the
    fault slot in the carry (default programs and checkpoints are bitwise
    those of the pre-fault repo), and a faulted batch adds it."""
    eng = ScanEngine(ds12, logistic_regression(),
                     ScanConfig(rounds=3, m=3, local_steps=2, batch_size=8,
                                sampler="uniform"))
    benign = [eng.cell(seed=0, mode=_mode(ds12))]
    faulted = [eng.cell(seed=0, mode=_mode(ds12),
                        fault_process=SignFlipFault(ds12.n_clients,
                                                    frac=0.25))]
    assert "fault" not in eng.carry_shapes(benign)
    assert "fault" in eng.carry_shapes(faulted)


def test_mixed_fault_batch_equals_per_cell(ds12):
    """One mixed-family run_batch (benign + every corruption family +
    straggler) == the per-cell runs, bitwise — and the benign cell is
    unperturbed by sharing a program with adversarial ones."""
    ds = ds12
    eng = ScanEngine(ds, logistic_regression(),
                     ScanConfig(rounds=5, m=3, local_steps=2, batch_size=8,
                                sampler="uniform"))
    cells = [eng.cell(seed=0, mode=_mode(ds))] + [
        eng.cell(seed=0, mode=_mode(ds),
                 fault_process=make_fault_process(f, ds.n_clients, frac=0.3))
        for f in FAMILIES[1:]]
    batch = eng.run_batch(cells)
    benign_solo = eng.run(eng.cell(seed=0, mode=_mode(ds)))
    np.testing.assert_array_equal(batch[0].val_loss, benign_solo.val_loss)
    np.testing.assert_array_equal(batch[0].sel, benign_solo.sel)
    for i, c in enumerate(cells):
        solo = eng.run(c)
        np.testing.assert_array_equal(batch[i].val_loss, solo.val_loss,
                                      err_msg=f"cell {i}")
        np.testing.assert_array_equal(batch[i].sel, solo.sel,
                                      err_msg=f"cell {i}")


@pytest.mark.parametrize("fault,agg", [("sign_flip", "krum"),
                                       ("scaled", "trimmed_mean")])
def test_flengine_matches_scan_cell_under_faults(ds12, fault, agg):
    """FLEngine + HostFaultInjector == the matching ScanEngine cell: same
    masks, the deterministic FedGS sampler, the same fault stream ->
    identical sampled sets and val-loss to f32 round-off (the
    test_scan_engine parity harness, now through the corruption seam)."""
    from repro.core.sampler import FedGSSampler
    from repro.fed.engine import FLConfig, FLEngine
    from repro.fed.scan_engine import precompute_masks

    ds, rounds = ds12, 6
    mode = _mode(ds)
    cfg = FLConfig(rounds=rounds, sample_frac=0.25, local_steps=2,
                   batch_size=8, lr=0.1, eval_every=1, seed=3)
    fproc = make_fault_process(fault, ds.n_clients, frac=0.3)
    eng = FLEngine(ds, logistic_regression(),
                   FedGSSampler(alpha=1.0, max_sweeps=16), mode,
                   cfg, fault=fproc,
                   aggregator=make_aggregator_process(agg))
    eng.install_oracle_graph(ds.opt_params)
    hist = eng.run()

    masks = precompute_masks(mode, rounds, cfg.avail_seed)
    assert masks.sum(1).min() >= eng.m
    seng = ScanEngine(ds, logistic_regression(),
                      ScanConfig(rounds=rounds, m=eng.m, local_steps=2,
                                 batch_size=8, lr=0.1, eval_every=1,
                                 sampler="fedgs", max_sweeps=16),
                      use_masks=True)
    sh = seng.run(seng.cell(seed=3, masks=masks, alpha=1.0,
                            h=eng.sampler._h, fault_process=fproc,
                            fault_seed=cfg.seed + 0xFA17,
                            aggregator_process=make_aggregator_process(agg)))
    for i, t in enumerate(hist.rounds):
        assert hist.sampled[i] == sh.sampled(t).tolist(), f"round {t}"
    np.testing.assert_allclose(hist.val_loss, sh.val_loss, atol=2e-5)

"""Substrate layers: optimizers, schedules, checkpointing, data pipeline,
HLO cost walker."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.optim.optimizers import adamw, sgd
from repro.optim.schedules import constant, cosine_warmup, round_decay


# --------------------------------------------------------------- optimizers
@pytest.mark.parametrize("opt", [sgd(), sgd(momentum=0.9), adamw(),
                                 adamw(state_dtype=jnp.bfloat16)])
def test_optimizer_minimizes_quadratic(opt):
    params = {"x": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum(jnp.square(p["x"])))(params)
        params, state = opt.update(g, state, params, 0.05)
    assert float(jnp.sum(jnp.abs(params["x"]))) < 0.05


def test_adamw_bf16_state_dtype():
    opt = adamw(state_dtype=jnp.bfloat16)
    params = {"x": jnp.zeros((4,), jnp.float32)}
    state = opt.init(params)
    assert state["m"]["x"].dtype == jnp.bfloat16
    assert state["v"]["x"].dtype == jnp.bfloat16


def test_weight_decay_shrinks():
    opt = sgd(weight_decay=0.1)
    params = {"x": jnp.asarray([1.0])}
    state = opt.init(params)
    zero_g = {"x": jnp.asarray([0.0])}
    p2, _ = opt.update(zero_g, state, params, 0.1)
    assert float(p2["x"][0]) < 1.0


# ---------------------------------------------------------------- schedules
def test_schedules():
    assert constant(0.1)(99) == 0.1
    assert round_decay(0.1, 0.998)(2) == pytest.approx(0.1 * 0.998 ** 2)
    cw = cosine_warmup(1.0, warmup=10, total=100)
    assert cw(0) < cw(9) <= 1.0
    assert cw(100) == pytest.approx(0.0, abs=1e-9)


# --------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint.ckpt import load_checkpoint, save_checkpoint
    tree = {"params": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                       "b": jnp.zeros((3,), jnp.bfloat16)},
            "counts": np.arange(5)}
    path = str(tmp_path / "ckpt")
    save_checkpoint(path, tree, metadata={"round": 7})
    back = load_checkpoint(path, like=tree)
    np.testing.assert_array_equal(np.asarray(back["params"]["w"]),
                                  np.asarray(tree["params"]["w"]))
    assert back["params"]["b"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(back["counts"], tree["counts"])


# --------------------------------------------------------------------- data
def test_synthetic_exact_recipe(synthetic_ds):
    ds = synthetic_ds
    assert ds.n_clients == 30
    assert ds.x.shape[-1] == 60
    assert ds.num_classes == 10
    assert hasattr(ds, "opt_params") and ds.opt_params.shape == (30, 610)
    # imbalanced lognormal(4,2) sizes
    assert ds.sizes.min() >= 1 and ds.sizes.max() > 2 * ds.sizes.min()


def test_two_label_partition(rng):
    from repro.data.partition import two_label_partition
    labels = rng.integers(0, 10, 2000)
    parts = two_label_partition(labels, 100, rng)
    assert len(parts) == 100
    for ix in parts:
        assert len(np.unique(labels[ix])) <= 3   # 2 shards -> usually 2 labels


def test_dirichlet_partition_sizes(rng):
    from repro.data.partition import dirichlet_label_partition, lognormal_sizes
    labels = rng.integers(0, 10, 5000)
    sizes = lognormal_sizes(5000, 50, rng)
    parts = dirichlet_label_partition(labels, 50, 1.75, rng, sizes)
    got = np.array([len(p) for p in parts])
    assert got.sum() <= 5000
    assert np.all(got > 0)


def test_vision_surrogates(rng):
    from repro.data.vision import make_cifar_like, make_fashion_like
    ds = make_cifar_like(n_clients=20, n_total=2000)
    assert ds.n_clients == 20 and ds.label_dist.shape == (20, 10)
    ds2 = make_fashion_like(n_clients=20, n_total=2000)
    for k in range(20):
        labels = np.unique(ds2.y[k][: ds2.sizes[k]])
        assert len(labels) <= 3


def test_token_streams(rng):
    from repro.data.lm_stream import token_batches
    pools = token_batches(vocab=64, n_clients=4, tokens_per_client=330,
                          seq_len=32, seed=0)
    assert pools.shape == (4, 10, 33)
    assert pools.min() >= 0 and pools.max() < 64
    # clients differ (distinct Markov chains)
    assert not np.array_equal(pools[0], pools[1])


# ------------------------------------------------------------ HLO cost walk
def test_hlo_walker_multiplies_loop_trips():
    from repro.utils.hlo import analyze

    def f_scan(x, ws):
        y, _ = jax.lax.scan(lambda c, w: (jnp.tanh(c @ w), None), x, ws)
        return y

    def f_unroll(x, ws):
        for i in range(ws.shape[0]):
            x = jnp.tanh(x @ ws[i])
        return x

    x = jax.ShapeDtypeStruct((64, 32), jnp.float32)
    ws = jax.ShapeDtypeStruct((5, 32, 32), jnp.float32)
    fs = analyze(jax.jit(f_scan).lower(x, ws).compile().as_text()).flops
    fu = analyze(jax.jit(f_unroll).lower(x, ws).compile().as_text()).flops
    assert fs == fu == 2 * 64 * 32 * 32 * 5


def test_hlo_walker_collectives_empty_on_single_device():
    from repro.utils.hlo import analyze
    c = jax.jit(lambda x: x @ x).lower(
        jax.ShapeDtypeStruct((8, 8), jnp.float32)).compile()
    a = analyze(c.as_text())
    assert a.collective_bytes == 0
    assert a.flops == 2 * 8 * 8 * 8

"""Per-architecture smoke tests (required by the assignment): every one of
the 10 assigned architectures instantiates a REDUCED variant (2 layers,
d_model<=512, <=4 experts) and runs one forward/train step + one decode step
on CPU, asserting output shapes and no NaNs."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape
from repro.configs.registry import REGISTRY, get_config, list_archs
from repro.launch.specs import concrete_inputs, input_specs, variant_for_shape
from repro.models import lm

SMALL_TRAIN = InputShape("t", 32, 2, "train")
SMALL_DECODE = InputShape("d", 48, 2, "decode")

ARCHS = list_archs()


def test_registry_has_all_ten():
    assert len(ARCHS) == 10
    families = {REGISTRY[a].family for a in ARCHS}
    assert families == {"dense", "moe", "ssm", "hybrid", "vlm", "audio"}


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_constraints(arch):
    r = get_config(arch).reduced()
    assert r.n_layers == 2
    assert r.d_model <= 512
    if r.moe is not None:
        assert r.moe.num_experts <= 4


@pytest.fixture(scope="module")
def params_cache():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_config(arch).reduced()
            cache[arch] = (cfg, lm.init_params(jax.random.PRNGKey(0), cfg))
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch, params_cache):
    cfg, params = params_cache(arch)
    batch = concrete_inputs(cfg, SMALL_TRAIN)["batch"]
    loss, grads = jax.value_and_grad(
        lambda p: lm.train_loss(p, cfg, batch, remat=False))(params)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
                for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_smoke(arch, params_cache):
    cfg, params = params_cache(arch)
    di = concrete_inputs(cfg, SMALL_DECODE)
    logits, cache = lm.decode_step(params, cfg, di["tokens"], di["cache"])
    assert logits.shape == (2, cfg.padded_vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    assert int(cache["len"]) == int(di["cache"]["len"]) + 1


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode_matches_forward(arch, params_cache):
    """Teacher-forced consistency: prefill(t tokens) then decode(token t) must
    equal prefill(t+1 tokens)'s last-position logits."""
    import dataclasses
    from repro.configs.base import MoEConfig
    cfg, params = params_cache(arch)
    if cfg.moe is not None:
        # ample capacity: token-drop patterns depend on the dispatch pool size,
        # which legitimately differs between prefill(t) and prefill(t+1)
        cfg = dataclasses.replace(cfg, moe=MoEConfig(
            num_experts=cfg.moe.num_experts, top_k=cfg.moe.top_k,
            capacity_factor=16.0))
    shape = InputShape("p", 17, 2, "prefill")
    batch = concrete_inputs(cfg, shape)["batch"]
    toks = batch["tokens"]

    full = dict(batch)
    logits_full, _ = lm.prefill(params, cfg, full)

    part = dict(batch)
    part["tokens"] = toks[:, :-1]
    logits_part, cache = lm.prefill(params, cfg, part)
    # grow cache by one slot for the decoded token
    def grow(k, x):
        if k in ("k", "v") and x.ndim == 5:
            pad = [(0, 0)] * 5
            pad[2] = (0, 1)
            return jnp.pad(x, pad)
        return x
    cache = {k: grow(k, v) for k, v in cache.items()}
    logits_step, _ = lm.decode_step(params, cfg, toks[:, -1], cache)

    np.testing.assert_allclose(
        np.asarray(logits_full, np.float32), np.asarray(logits_step, np.float32),
        atol=5e-2 if cfg.dtype == "bfloat16" else 2e-3, rtol=2e-2)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_abstract_params(arch):
    """The FULL configs are exercised via eval_shape only (no allocation)."""
    from repro.launch.specs import abstract_params
    cfg = get_config(arch)
    tree = abstract_params(cfg)
    leaves = jax.tree_util.tree_leaves(tree)
    assert all(hasattr(l, "shape") for l in leaves)
    total = sum(int(np.prod(l.shape)) for l in leaves)
    # analytic param_count agrees with the real pytree within 2%
    assert abs(total - cfg.param_count()) / cfg.param_count() < 0.02


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("shape_name", ["train_4k", "prefill_32k",
                                        "decode_32k", "long_500k"])
def test_input_specs_cover_all_pairs(arch, shape_name):
    from repro.configs.base import INPUT_SHAPES
    shape = INPUT_SHAPES[shape_name]
    cfg = variant_for_shape(get_config(arch), shape)
    if shape.name == "long_500k":
        assert cfg.attention in ("sliding_window", "none")
    specs = input_specs(cfg, shape)
    if shape.kind == "decode":
        assert specs["tokens"].shape == (shape.global_batch,)
        if cfg.attention != "none":
            assert specs["cache"]["k"].shape[2] == shape.seq_len
    else:
        total = specs["batch"]["tokens"].shape[1] + (
            cfg.n_image_tokens if cfg.family == "vlm" else 0)
        assert total == shape.seq_len
